"""Refactorization fast path benchmark -> experiments/BENCH_refactor.json.

The time-stepping scenario the `update_values` fast path exists for: the
sparsity pattern is fixed, values change every step.  For each benchmark
analogue this driver measures, per step,

  * rebuild_ms — the naive path: a full `from_csr(L_k, tune="auto",
    cache=False)` re-tuning (level analysis + portfolio + transform +
    schedule compile) for every new value set,
  * update_ms  — the fast path: `op.update_values(L_k)` (transform replay
    + schedule value repack, everything structural frozen),
  * solve_us   — warm per-solve cost through the updated operator,

and derives the amortized per-step cost of each regime (update/rebuild
plus one solve).  The headline guarantees (asserted by the committed-
artifact test in tests/test_benchmarks_smoke.py) are boolean, not
wall-clock: the fast path is never slower than the rebuild it replaces,
the amortized step cost approaches pure solve cost, and the updated
operator matches a fresh build bitwise at every step.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.solver import TriangularOperator
from repro.sparse import generators


def step_values(L, step: int):
    """Step k's matrix: same pattern, perturbed values (the diagonal is
    scaled, not noised, so the triangular systems stay well-conditioned)."""
    rng = np.random.default_rng(1000 + step)
    rows = np.repeat(np.arange(L.n_rows), L.row_nnz())
    d_mask = L.indices == rows
    data = L.data * (1.0 + 0.2 * rng.standard_normal(L.nnz))
    data[d_mask] = L.data[d_mask] * (1.2 + 0.1 * step)
    return L.with_data(data)


def _warm_solve_us(op, b, iters: int) -> float:
    op.solve(b, max_refine=0)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        op.solve(b, max_refine=0)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def bench_matrix(L, steps: int = 5, iters: int = 3, chunk: int = 256,
                 max_deps: int = 16) -> dict:
    rng = np.random.default_rng(0)
    b = rng.standard_normal(L.n_rows)
    kw = dict(chunk=chunk, max_deps=max_deps, cache=False)

    t0 = time.perf_counter()
    op = TriangularOperator.from_csr(L, tune="auto", **kw)
    build_ms = (time.perf_counter() - t0) * 1e3
    op.solve(b, max_refine=0)               # prime compiled fns + preamble

    rebuild_ms, update_ms, solve_us = [], [], []
    exact = True
    for k in range(steps):
        L_k = step_values(L, k)
        t0 = time.perf_counter()
        fresh = TriangularOperator.from_csr(L_k, tune="auto", **kw)
        rebuild_ms.append((time.perf_counter() - t0) * 1e3)
        t0 = time.perf_counter()
        op.update_values(L_k)
        update_ms.append((time.perf_counter() - t0) * 1e3)
        solve_us.append(_warm_solve_us(op, b, iters))
        exact = exact and np.array_equal(
            np.asarray(op.solve(b, max_refine=0)),
            np.asarray(fresh.solve(b, max_refine=0)))

    reb, upd = float(np.mean(rebuild_ms)), float(np.mean(update_ms))
    slv_ms = float(np.mean(solve_us)) / 1e3
    return {
        "n": L.n_rows, "nnz": L.nnz, "steps": steps,
        "strategy": op.strategy,
        "build_ms": round(build_ms, 2),
        "rebuild_ms": round(reb, 2),
        "update_ms": round(upd, 3),
        "solve_us": round(float(np.mean(solve_us)), 1),
        "amortized_rebuild_step_ms": round(reb + slv_ms, 2),
        "amortized_update_step_ms": round(upd + slv_ms, 3),
        "update_speedup_vs_rebuild": round(reb / max(upd, 1e-9), 1),
        # amortized step cost as a multiple of pure solve cost: -> 1.0 is
        # the "approaches pure solve" target the fast path is judged by
        "update_step_over_solve": round((upd + slv_ms) / max(slv_ms, 1e-9),
                                        2),
        "rebuild_step_over_solve": round((reb + slv_ms) / max(slv_ms, 1e-9),
                                         2),
        # boolean guarantees (asserted by the committed-artifact test;
        # never compare wall-clock at smoke scale)
        "update_not_slower_than_rebuild": bool(upd <= reb),
        "amortized_update_le_rebuild": bool(upd + slv_ms <= reb + slv_ms),
        "exact_match_fresh": bool(exact),
        "value_updates": op.stats.value_updates,
    }


def run(out_path="experiments/BENCH_refactor.json", scales=(0.1, 0.08),
        steps: int = 5, iters: int = 3, chunk: int = 256,
        max_deps: int = 16) -> dict:
    record = {
        "config": {"chunk": chunk, "max_deps": max_deps,
                   "scales": list(scales), "steps": steps, "iters": iters},
        "matrices": {},
    }
    for name, L in (
            (f"lung2_like@{scales[0]}", generators.lung2_like(scales[0])),
            (f"torso2_like@{scales[1]}", generators.torso2_like(scales[1]))):
        m = bench_matrix(L, steps=steps, iters=iters, chunk=chunk,
                         max_deps=max_deps)
        record["matrices"][name] = m
        print(f"{name}: rebuild {m['rebuild_ms']}ms/step vs update "
              f"{m['update_ms']}ms/step ({m['update_speedup_vs_rebuild']}x), "
              f"amortized step {m['amortized_update_step_ms']}ms "
              f"({m['update_step_over_solve']}x pure solve; rebuild regime "
              f"{m['rebuild_step_over_solve']}x), "
              f"exact={m['exact_match_fresh']}")
    if out_path:
        p = Path(out_path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(record, indent=2) + "\n")
    return record


if __name__ == "__main__":
    run()

"""Tuner-vs-fixed-strategy benchmark -> experiments/BENCH_operator.json.

For each benchmark matrix, builds a TriangularOperator per FIXED strategy
(the four shipped ones, default parameters) plus one auto-tuned operator,
and measures warm end-to-end per-solve wall time (host preamble + jitted
scan engine, refinement off) for a single RHS and a batched (n, k) block.

The headline check (mirrors the ISSUE acceptance criterion): the tuner's
pick is never slower than the WORST fixed strategy — i.e. "auto" protects
users from hand-picking the wrong rewrite for their matrix.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core import (AvgLevelCost, ConstrainedAvgLevelCost, ManualEveryK,
                        NoRewrite, strategy_label)
from repro.solver import TriangularOperator
from repro.sparse import generators


def fixed_strategies() -> list:
    return [NoRewrite(), AvgLevelCost(), ManualEveryK(),
            ConstrainedAvgLevelCost()]


def _solve_us(op: TriangularOperator, b: np.ndarray, iters: int) -> float:
    """Warm end-to-end per-solve wall time (preamble + engine, no refine);
    min over iters — the robust estimator under scheduler noise."""
    op.solve(b, max_refine=0)               # compile / warm the jit cache
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        op.solve(b, max_refine=0)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def bench_matrix(L, chunk: int = 256, max_deps: int = 16, iters: int = 3,
                 rhs_batch: int = 8, measure_top_k: int = 3,
                 engine=None) -> dict:
    rng = np.random.default_rng(0)
    b = rng.standard_normal(L.n_rows)
    B = rng.standard_normal((L.n_rows, rhs_batch))
    fixed = {}
    for strat in fixed_strategies():
        op = TriangularOperator.from_csr(L, tune=strat, chunk=chunk,
                                         max_deps=max_deps, cache=False,
                                         engine=engine)
        fixed[strategy_label(strat)] = {
            "measured_us": round(_solve_us(op, b, iters), 1),
            "batched_us": round(_solve_us(op, B, iters), 1),
            "steps": op.schedule.num_steps,
            "nnz_T": op.transformed.metrics.nnz_T,
        }
    op = TriangularOperator.from_csr(L, tune="auto", chunk=chunk,
                                     max_deps=max_deps, cache=False,
                                     measure_top_k=measure_top_k,
                                     engine=engine)
    tuner_us = round(_solve_us(op, b, iters), 1)
    worst = max(v["measured_us"] for v in fixed.values())
    best = min(v["measured_us"] for v in fixed.values())
    return {
        "n": L.n_rows, "nnz": L.nnz, "rhs_batch": rhs_batch,
        "fixed": fixed,
        "tuner": {
            "pick": op.strategy,
            "measured_us": tuner_us,
            "batched_us": round(_solve_us(op, B, iters), 1),
            "tune_ms": round(op.stats.tune_ms, 1),
            "report": op.report.to_dict() if op.report is not None else None,
        },
        "worst_fixed_us": worst,
        "best_fixed_us": best,
        "tuner_not_slower_than_worst": bool(tuner_us <= worst),
    }


def run(out_path="experiments/BENCH_operator.json", scales=(0.1, 0.08),
        iters: int = 3, chunk: int = 256, max_deps: int = 16,
        rhs_batch: int = 8, measure_top_k: int = 3, engine=None) -> dict:
    from repro.solver import resolve_engine
    record = {
        "config": {"chunk": chunk, "max_deps": max_deps,
                   "scales": list(scales), "iters": iters,
                   "rhs_batch": rhs_batch, "measure_top_k": measure_top_k,
                   "engine": resolve_engine(engine).name},
        "matrices": {},
    }
    for name, L in (
            (f"lung2_like@{scales[0]}", generators.lung2_like(scales[0])),
            (f"torso2_like@{scales[1]}", generators.torso2_like(scales[1]))):
        m = bench_matrix(L, chunk=chunk, max_deps=max_deps, iters=iters,
                         rhs_batch=rhs_batch, measure_top_k=measure_top_k,
                         engine=engine)
        record["matrices"][name] = m
        print(f"{name}: tuner pick = {m['tuner']['pick']} "
              f"({m['tuner']['measured_us']}us, batched x{rhs_batch} "
              f"{m['tuner']['batched_us']}us) vs fixed "
              f"[{m['best_fixed_us']} .. {m['worst_fixed_us']}]us "
              f"-> not_slower_than_worst={m['tuner_not_slower_than_worst']}")
        for label, v in m["fixed"].items():
            print(f"    {label:<42} {v['measured_us']:>10}us "
                  f"steps={v['steps']:<5} nnz_T={v['nnz_T']}")
    if out_path:
        p = Path(out_path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(record, indent=2) + "\n")
    return record


if __name__ == "__main__":
    run()

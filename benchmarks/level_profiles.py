"""Fig. 5 / Fig. 6 reproduction: per-level cost profiles before/after each
strategy (CSV: level,cost columns per strategy).  The thin flat runs vanish
after rewriting; the fat bumps are untouched — the paper's signature plot.
"""
from __future__ import annotations

import numpy as np

from repro.core import AvgLevelCost, ManualEveryK, NoRewrite, transform
from repro.sparse import io as sio


def profile(name: str, max_rows: int | None = None):
    from repro.solver import schedule_for_transformed
    L = sio.load_named(name)
    out = {}
    sched_stats = {}
    for strat in (NoRewrite(), AvgLevelCost(), ManualEveryK(10)):
        ts = transform(L, strat, validate=False, codegen=False)
        deps = ts.A.row_nnz()
        lc = np.zeros(ts.metrics.num_levels_after, dtype=np.int64)
        np.add.at(lc, ts.level_of_assigned, 2 * deps + 1)
        key = ts.metrics.strategy.split("(")[0]
        out[key] = lc
        s = schedule_for_transformed(ts, chunk=256, max_deps=16)
        sched_stats[key] = (s.num_steps, s.padded_flops(), s.flops(),
                            s.build_ms)
    return out, sched_stats


def run(csv_dir=None):
    for name in ("lung2", "torso2"):
        prof, sched = profile(name)
        print(f"# {name}: num_levels -> " + ", ".join(
            f"{k}:{len(v)}" for k, v in prof.items()))
        print(f"# {name}: avg_cost  -> " + ", ".join(
            f"{k}:{v.mean():.1f}" for k, v in prof.items()))
        print(f"# {name}: schedule (steps,padded,real,build_ms) -> " +
              ", ".join(f"{k}:{s[0]}/{s[1]}/{s[2]}/{s[3]:.1f}"
                        for k, s in sched.items()))
        if csv_dir:
            from pathlib import Path
            for k, v in prof.items():
                p = Path(csv_dir) / f"profile_{name}_{k}.csv"
                p.write_text("level,cost\n" + "\n".join(
                    f"{i},{c}" for i, c in enumerate(v)) + "\n")
    return True


if __name__ == "__main__":
    run("experiments")

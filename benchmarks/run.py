"""Benchmark aggregator — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # full run
    PYTHONPATH=src python -m benchmarks.run --smoke    # reduced-scale CI run

Sections:
  engines         — capability smoke: every available registered engine
                    solves one system through the registry (smoke only)
  table1          — paper Table I (strategy comparison, lung2/torso2)
  level_profiles  — paper Fig. 5/6 (per-level cost profiles)
  solver_bench    — solve wall time (CPU measured + TPU roofline model)
  schedule        — schedule-compiler before/after (BENCH_schedule.json)
  operator        — auto-tuner vs fixed strategies (BENCH_operator.json)
  iterative       — end-to-end IC(0)-PCG, tuned vs no_rewriting
                    (BENCH_iterative.json)
  refactor        — value-update fast path vs full re-tuned rebuild per
                    time step (BENCH_refactor.json)
  distributed     — sharded-engine scaling curve + steps-vs-all_gathers
                    table (BENCH_distributed.json; full mode runs in a
                    subprocess with 8 forced host devices, smoke runs
                    in-process on the available devices)
  serving         — solve-service load sweep: micro-batched throughput
                    vs sequential, cold-start latency anatomy, hot-swap
                    guarantee (BENCH_serving.json)

--smoke runs every section at reduced scale (seconds, not minutes) so the
tier-1 suite can import-check and execute the drivers (pytest -m bench).
The full run writes experiments/BENCH_schedule.json (build ms, steps,
padded vs real FLOPs, us_per_solve before/after — the schedule compiler's
perf trajectory) and experiments/BENCH_operator.json (tuner-vs-fixed-
strategy table — the portfolio auto-tuner's guarantee).  Smoke mode
executes every driver but persists nothing unless smoke() is given
explicit out paths — the committed full-scale artifacts must not be
clobbered by reduced-scale runs.
"""
from __future__ import annotations

import contextlib
import json
import sys
import time
from pathlib import Path


@contextlib.contextmanager
def traced_section(name: str, trace_dir):
    """Wrap one bench section in a fresh tracer (--trace DIR): on exit,
    write `<dir>/<name>.trace.json` (Chrome trace-event) and
    `<dir>/<name>.metrics.json` (default metrics-registry snapshot).
    No-op when trace_dir is falsy — the default full/smoke runs pay
    nothing."""
    if not trace_dir:
        yield
        return
    from repro import obs
    d = Path(trace_dir)
    d.mkdir(parents=True, exist_ok=True)
    tracer = obs.enable()
    try:
        yield
    finally:
        obs.disable()
        obs.export.write_chrome_trace(d / f"{name}.trace.json", tracer)
        (d / f"{name}.metrics.json").write_text(json.dumps(
            obs.default_registry().snapshot(), indent=2, default=str) + "\n")


def write_bench_summary(out_path="experiments/BENCH_summary.json",
                        exp_dir="experiments"):
    """Distill the committed BENCH_*.json artifacts into one
    section -> headline-numbers map.  Purely derived (no measurement):
    regenerating from the same artifacts is byte-identical, which the
    benchmark smoke test asserts.  Returns the summary dict, or None when
    no artifact exists."""
    exp = Path(exp_dir)
    summary: dict = {}

    def load(name):
        p = exp / f"BENCH_{name}.json"
        return json.loads(p.read_text()) if p.exists() else None

    rec = load("schedule")
    if rec:
        summary["schedule"] = {name: {
            "n": m["n"], "steps": [m["before"]["steps"], m["after"]["steps"]],
            "padded_flops_reduction": m["padded_flops_reduction"],
            "build_speedup_vs_legacy": m["build_speedup_vs_legacy"],
            "us_per_solve": [m["before"].get("us_per_solve"),
                             m["after"].get("us_per_solve")],
        } for name, m in rec["matrices"].items()}
    rec = load("operator")
    if rec:
        summary["operator"] = {name: {
            "pick": m["tuner"]["pick"],
            "tuner_us": m["tuner"]["measured_us"],
            "best_fixed_us": m.get("best_fixed_us"),
            "worst_fixed_us": m.get("worst_fixed_us"),
            "tuner_not_slower_than_worst":
                m.get("tuner_not_slower_than_worst"),
        } for name, m in rec["matrices"].items()}
    rec = load("iterative")
    if rec:
        summary["iterative"] = {name: {
            "unpreconditioned_iterations":
                m["unpreconditioned"]["iterations"],
            "pcg_iterations": m["tuned"]["iterations"],
            "tuned_pick": m["tuned"]["pick"],
            "tuned_solve_ms": m["tuned"]["solve_ms"],
            "no_rewriting_solve_ms": m["no_rewriting"]["solve_ms"],
            "tuned_speedup": round(
                m["no_rewriting"]["solve_ms"] / m["tuned"]["solve_ms"], 2),
        } for name, m in rec["matrices"].items()}
    rec = load("refactor")
    if rec:
        summary["refactor"] = {name: {
            "strategy": m["strategy"],
            "update_speedup_vs_rebuild": m["update_speedup_vs_rebuild"],
            "update_not_slower_than_rebuild":
                m["update_not_slower_than_rebuild"],
            "exact_match_fresh": m["exact_match_fresh"],
        } for name, m in rec["matrices"].items()}
    rec = load("distributed")
    if rec:
        summary["distributed"] = {name: {
            "steps": m["steps"], "all_gather_calls": m["all_gather_calls"],
            "transformed_not_slower": m["transformed_not_slower"],
        } for name, m in rec["matrices"].items()}
        summary["distributed"]["transformed_not_slower_any"] = \
            rec["transformed_not_slower_any"]
    rec = load("serving")
    if rec:
        summary["serving"] = {name: {
            "strategy": m["strategy"],
            "saturation_speedup_vs_sequential":
                m["saturation_speedup_vs_sequential"],
            "batched_beats_sequential": m["batched_beats_sequential"],
            "hot_swap_landed": m["hot_swap_landed"],
            "cold_start_le_untuned": m["cold_start"]["cold_start_le_untuned"],
        } for name, m in rec["matrices"].items()}
    if not summary:
        return None
    if out_path:
        p = Path(out_path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(summary, indent=2, sort_keys=True) + "\n")
    return summary


def bench_schedule(out_path="experiments/BENCH_schedule.json",
                   scales=(0.25, 0.15), reps=5, time_solve=True) -> dict:
    """Schedule-compiler before/after on the benchmark analogues."""
    from benchmarks.solver_bench import schedule_metrics
    from repro.sparse import generators
    record = {
        "config": {"chunk": 256, "max_deps": 16, "scales": list(scales)},
        "matrices": {},
    }
    for name, L in (
            (f"lung2_like@{scales[0]}", generators.lung2_like(scales[0])),
            (f"torso2_like@{scales[1]}", generators.torso2_like(scales[1]))):
        record["matrices"][name] = schedule_metrics(
            L, chunk=256, max_deps=16, reps=reps, time_solve=time_solve)
    if out_path:
        p = Path(out_path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(record, indent=2) + "\n")
    return record


def verify_smoke(scales=(0.12, 0.08)) -> dict:
    """Static-verification smoke over both benchmark analogues: transform
    + schedule every registered strategy and certify each artifact
    (`python -m benchmarks.run --verify`; the CI static-analysis job's
    second gate).  Any invariant violation raises a typed
    ScheduleInvariantError/TransformInvariantError and fails the run."""
    from repro.analysis import certificate_dict, verify_level_schedule
    from repro.analysis.verify import audit_transformed_system
    from repro.core.portfolio import STRATEGY_REGISTRY, make_strategy
    from repro.core.transform import transform
    from repro.solver.schedule import schedule_for_transformed
    from repro.sparse import generators
    out: dict = {}
    for name, L in ((f"lung2_like@{scales[0]}",
                     generators.lung2_like(scales[0])),
                    (f"torso2_like@{scales[1]}",
                     generators.torso2_like(scales[1]))):
        out[name] = {}
        for strategy in STRATEGY_REGISTRY:
            ts = transform(L, make_strategy(strategy), validate=False,
                           codegen=False)
            audit_transformed_system(ts, where=f"{name}/{strategy}")
            cert = verify_level_schedule(
                ts_sched := schedule_for_transformed(ts, chunk=256,
                                                     max_deps=16),
                ts.A, ts.diag, where=f"{name}/{strategy}")
            assert cert.steps == ts_sched.num_steps
            out[name][strategy] = certificate_dict(cert)
    return out


def engine_capability_smoke(n: int = 200) -> dict:
    """Solve one small system through every *available* registered engine
    (registry dispatch, pallas-interpret included) and check it against the
    sequential reference — the CI engine-capability gate."""
    import jax.numpy as jnp
    import numpy as np
    from repro.solver import (available_engines, engine_capabilities,
                              resolve_engine, schedule_for_csr,
                              solve_csr_seq, to_device)
    from repro.sparse import build_levels, generators

    L = generators.random_lower(n, avg_offdiag=2.5, seed=0, max_back=25)
    sched = schedule_for_csr(L, build_levels(L), chunk=64, max_deps=8)
    ds = to_device(sched)
    rng = np.random.default_rng(0)
    b = rng.standard_normal(n)
    B = rng.standard_normal((n, 3))
    x_ref = solve_csr_seq(L, b)
    out = {"capabilities": engine_capabilities(), "rel_err": {}}
    for name in available_engines():
        eng = resolve_engine(name)
        fn = eng.compile(ds)
        x = np.asarray(fn(jnp.asarray(b, ds.dtype)))
        err = float(np.abs(x - x_ref).max() / max(1.0, np.abs(x_ref).max()))
        assert err < 2e-4, f"engine {name}: rel err {err:.2e}"
        if eng.supports_batched_rhs:
            X = np.asarray(fn(jnp.asarray(B, ds.dtype)))
            assert X.shape == (n, 3), f"engine {name}: batched shape"
        out["rel_err"][name] = err
        print(f"engine {name:<18} rel_err={err:.2e} "
              f"{eng.capabilities()}")
    return out


def smoke(out_path=None, operator_out=None, iterative_out=None,
          trace_dir=None) -> dict:
    """Reduced-scale pass over every benchmark driver (tier-1 smoke)."""
    import benchmarks.distributed_bench as db
    import benchmarks.iterative_bench as ib
    import benchmarks.level_profiles as lp
    import benchmarks.operator_bench as ob
    import benchmarks.refactor_bench as rb
    import benchmarks.serving_bench as svb
    import benchmarks.solver_bench as sb
    import benchmarks.table1 as t1
    from repro.sparse import generators
    from repro.sparse import io as sio

    with traced_section("engines", trace_dir):
        engines = engine_capability_smoke()
    with traced_section("distributed", trace_dir):
        distributed = db.smoke_record()
    real_load = sio.load_named
    try:
        sio.load_named = lambda name: (
            generators.lung2_like(scale=0.04) if name == "lung2"
            else generators.torso2_like(scale=0.04))
        with traced_section("table1", trace_dir):
            t1.run(csv_out=None)
        with traced_section("level_profiles", trace_dir):
            lp.run(csv_dir=None)
        with traced_section("solver_bench", trace_dir):
            sb.run(csv_out=None, scales=(0.05, 0.05), iters=2)
    finally:
        sio.load_named = real_load
    with traced_section("operator", trace_dir):
        ob.run(out_path=operator_out, scales=(0.04, 0.04), iters=1,
               measure_top_k=0)
    with traced_section("iterative", trace_dir):
        it_rec = ib.run(out_path=iterative_out, scales=(0.02, 0.02),
                        iters=1, maxiter=200, measure_top_k=2)
    with traced_section("refactor", trace_dir):
        refactor = rb.run(out_path=None, scales=(0.04, 0.04), steps=2,
                          iters=1)
    with traced_section("serving", trace_dir):
        serving = svb.run(out_path=None, scales=(0.03, 0.03),
                          widths=(1, 4), rounds=3)
    with traced_section("schedule", trace_dir):
        rec = bench_schedule(None, scales=(0.08, 0.06), reps=2,
                             time_solve=False)
    rec["engines"] = engines
    rec["iterative"] = it_rec
    rec["distributed_smoke"] = distributed
    rec["refactor_smoke"] = refactor
    rec["serving_smoke"] = serving
    if out_path:        # persist WITH the engine section (record == file)
        p = Path(out_path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(rec, indent=2) + "\n")
    return rec


def main() -> None:
    import os
    if os.environ.get("REPRO_STRICT_DEPRECATIONS") == "1":
        # CI gate: DeprecationWarnings issued from repro's own modules are
        # errors (the string-engine shims must not regress into internal
        # use).  Regex module match — PYTHONWARNINGS can't prefix-match.
        import warnings
        warnings.filterwarnings("error", category=DeprecationWarning,
                                module=r"repro\..*")
    trace_dir = None
    if "--trace" in sys.argv:
        i = sys.argv.index("--trace")
        trace_dir = (sys.argv[i + 1]
                     if i + 1 < len(sys.argv)
                     and not sys.argv[i + 1].startswith("--")
                     else "experiments/traces")
    if "--smoke" in sys.argv:
        t0 = time.time()
        rec = smoke(trace_dir=trace_dir)
        print(json.dumps(rec, indent=2))
        print(f"\nsmoke total {time.time() - t0:.1f}s")
        return
    if "--verify" in sys.argv:
        t0 = time.time()
        rec = verify_smoke()
        for name, strategies in rec.items():
            for strategy, cert in strategies.items():
                print(f"{name:20s} {strategy:16s} steps={cert['steps']:>5} "
                      f"critical_path={cert['critical_path']:>5} "
                      f"padded_flops={cert['padded_flops']}")
        print(f"\nall artifacts certified in {time.time() - t0:.1f}s")
        return
    from benchmarks import level_profiles, solver_bench, table1
    t0 = time.time()
    print("== Table I: strategy comparison (paper values inline) ==")
    with traced_section("table1", trace_dir):
        table1.run(csv_out="experiments/table1.csv")
    print("\n== Fig 5/6: level-cost profiles ==")
    with traced_section("level_profiles", trace_dir):
        level_profiles.run(csv_dir="experiments")
    print("\n== Solver wall-time (name,strategy,steps,levels,us,model_us,"
          "speedup,build_ms,padded,real) ==")
    with traced_section("solver_bench", trace_dir):
        solver_bench.run(csv_out="experiments/solver_bench.csv")
    print("\n== Schedule compiler before/after ==")
    with traced_section("schedule", trace_dir):
        rec = bench_schedule()
    for name, m in rec["matrices"].items():
        print(f"{name}: legacy_build={m['legacy_build_ms']}ms -> "
              f"after={m['after']['build_ms']}ms "
              f"({m['build_speedup_vs_legacy']}x), steps "
              f"{m['before']['steps']} -> {m['after']['steps']} "
              f"(levels {m['after']['levels']}), padded_flops "
              f"{m['before']['padded_flops']} -> "
              f"{m['after']['padded_flops']} "
              f"(-{m['padded_flops_reduction']:.0%})")
    print("\n== Operator auto-tuner vs fixed strategies ==")
    from benchmarks import operator_bench
    with traced_section("operator", trace_dir):
        operator_bench.run(out_path="experiments/BENCH_operator.json")
    print("\n== End-to-end IC(0)-PCG: tuned vs no_rewriting ==")
    from benchmarks import iterative_bench
    with traced_section("iterative", trace_dir):
        iterative_bench.run(out_path="experiments/BENCH_iterative.json")
    print("\n== Refactorization fast path: update_values vs full "
          "rebuild per step ==")
    from benchmarks import refactor_bench
    with traced_section("refactor", trace_dir):
        refactor_bench.run(out_path="experiments/BENCH_refactor.json")
    print("\n== Sharded scaling curve + steps-vs-all_gathers "
          "(8 forced host devices, subprocess) ==")
    from benchmarks import distributed_bench
    with traced_section("distributed", trace_dir):
        distributed_bench.run(out_path="experiments/BENCH_distributed.json")
    print("\n== Solve service: micro-batched load sweep + cold-start "
          "anatomy ==")
    from benchmarks import serving_bench
    with traced_section("serving", trace_dir):
        serving_bench.run(out_path="experiments/BENCH_serving.json")
    _roofline_summary()
    write_bench_summary()
    print("wrote experiments/BENCH_summary.json")
    print(f"\ntotal {time.time() - t0:.1f}s")


def _roofline_summary() -> None:
    """Summarize the latest dry-run roofline records, if present."""
    src = Path("experiments/dryrun_results.json")
    if not src.exists():
        print("\n(no dry-run records; run repro.launch.dryrun --all "
              "--both-meshes first)")
        return
    rs = [r for r in json.loads(src.read_text()) if "roofline" in r]
    print("\n== Dry-run roofline summary (arch,shape,mesh,dominant,"
          "useful,MFU_hi,MFU_lo) ==")
    for r in rs:
        rf = r["roofline"]
        print(f"{r['arch']},{r['shape']},{r['mesh_tag']},"
              f"{rf['dominant_min']},{rf['useful_fraction']:.2f},"
              f"{rf['roofline_mfu']:.3f},{rf['roofline_mfu_min']:.3f}")
    skips = [r for r in json.loads(src.read_text()) if "skip" in r]
    print(f"cells: {len(rs)} compiled OK, {len(skips)} assignment skips")


if __name__ == "__main__":
    main()

"""Benchmark aggregator — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # full run
    PYTHONPATH=src python -m benchmarks.run --smoke    # reduced-scale CI run

Sections:
  table1          — paper Table I (strategy comparison, lung2/torso2)
  level_profiles  — paper Fig. 5/6 (per-level cost profiles)
  solver_bench    — solve wall time (CPU measured + TPU roofline model)
  schedule        — schedule-compiler before/after (BENCH_schedule.json)
  operator        — auto-tuner vs fixed strategies (BENCH_operator.json)

--smoke runs every section at reduced scale (seconds, not minutes) so the
tier-1 suite can import-check and execute the drivers (pytest -m bench).
The full run writes experiments/BENCH_schedule.json (build ms, steps,
padded vs real FLOPs, us_per_solve before/after — the schedule compiler's
perf trajectory) and experiments/BENCH_operator.json (tuner-vs-fixed-
strategy table — the portfolio auto-tuner's guarantee).  Smoke mode
executes every driver but persists nothing unless smoke() is given
explicit out paths — the committed full-scale artifacts must not be
clobbered by reduced-scale runs.
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path


def bench_schedule(out_path="experiments/BENCH_schedule.json",
                   scales=(0.25, 0.15), reps=5, time_solve=True) -> dict:
    """Schedule-compiler before/after on the benchmark analogues."""
    from benchmarks.solver_bench import schedule_metrics
    from repro.sparse import generators
    record = {
        "config": {"chunk": 256, "max_deps": 16, "scales": list(scales)},
        "matrices": {},
    }
    for name, L in (
            (f"lung2_like@{scales[0]}", generators.lung2_like(scales[0])),
            (f"torso2_like@{scales[1]}", generators.torso2_like(scales[1]))):
        record["matrices"][name] = schedule_metrics(
            L, chunk=256, max_deps=16, reps=reps, time_solve=time_solve)
    if out_path:
        p = Path(out_path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(record, indent=2) + "\n")
    return record


def smoke(out_path=None, operator_out=None) -> dict:
    """Reduced-scale pass over every benchmark driver (tier-1 smoke)."""
    import benchmarks.level_profiles as lp
    import benchmarks.operator_bench as ob
    import benchmarks.solver_bench as sb
    import benchmarks.table1 as t1
    from repro.sparse import generators
    from repro.sparse import io as sio

    real_load = sio.load_named
    try:
        sio.load_named = lambda name: (
            generators.lung2_like(scale=0.04) if name == "lung2"
            else generators.torso2_like(scale=0.04))
        t1.run(csv_out=None)
        lp.run(csv_dir=None)
        sb.run(csv_out=None, scales=(0.05, 0.05), iters=2)
    finally:
        sio.load_named = real_load
    ob.run(out_path=operator_out, scales=(0.04, 0.04), iters=1,
           measure_top_k=0)
    return bench_schedule(out_path, scales=(0.08, 0.06), reps=2,
                          time_solve=False)


def main() -> None:
    if "--smoke" in sys.argv:
        t0 = time.time()
        rec = smoke()
        print(json.dumps(rec, indent=2))
        print(f"\nsmoke total {time.time() - t0:.1f}s")
        return
    from benchmarks import level_profiles, solver_bench, table1
    t0 = time.time()
    print("== Table I: strategy comparison (paper values inline) ==")
    table1.run(csv_out="experiments/table1.csv")
    print("\n== Fig 5/6: level-cost profiles ==")
    level_profiles.run(csv_dir="experiments")
    print("\n== Solver wall-time (name,strategy,steps,levels,us,model_us,"
          "speedup,build_ms,padded,real) ==")
    solver_bench.run(csv_out="experiments/solver_bench.csv")
    print("\n== Schedule compiler before/after ==")
    rec = bench_schedule()
    for name, m in rec["matrices"].items():
        print(f"{name}: legacy_build={m['legacy_build_ms']}ms -> "
              f"after={m['after']['build_ms']}ms "
              f"({m['build_speedup_vs_legacy']}x), steps "
              f"{m['before']['steps']} -> {m['after']['steps']} "
              f"(levels {m['after']['levels']}), padded_flops "
              f"{m['before']['padded_flops']} -> "
              f"{m['after']['padded_flops']} "
              f"(-{m['padded_flops_reduction']:.0%})")
    print("\n== Operator auto-tuner vs fixed strategies ==")
    from benchmarks import operator_bench
    operator_bench.run(out_path="experiments/BENCH_operator.json")
    _roofline_summary()
    print(f"\ntotal {time.time() - t0:.1f}s")


def _roofline_summary() -> None:
    """Summarize the latest dry-run roofline records, if present."""
    src = Path("experiments/dryrun_results.json")
    if not src.exists():
        print("\n(no dry-run records; run repro.launch.dryrun --all "
              "--both-meshes first)")
        return
    rs = [r for r in json.loads(src.read_text()) if "roofline" in r]
    print("\n== Dry-run roofline summary (arch,shape,mesh,dominant,"
          "useful,MFU_hi,MFU_lo) ==")
    for r in rs:
        rf = r["roofline"]
        print(f"{r['arch']},{r['shape']},{r['mesh_tag']},"
              f"{rf['dominant_min']},{rf['useful_fraction']:.2f},"
              f"{rf['roofline_mfu']:.3f},{rf['roofline_mfu_min']:.3f}")
    skips = [r for r in json.loads(src.read_text()) if "skip" in r]
    print(f"cells: {len(rs)} compiled OK, {len(skips)} assignment skips")


if __name__ == "__main__":
    main()

"""Benchmark aggregator — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run

Sections:
  table1          — paper Table I (strategy comparison, lung2/torso2)
  level_profiles  — paper Fig. 5/6 (per-level cost profiles)
  solver_bench    — solve wall time (CPU measured + TPU roofline model)
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import level_profiles, solver_bench, table1
    t0 = time.time()
    print("== Table I: strategy comparison (paper values inline) ==")
    table1.run(csv_out="experiments/table1.csv")
    print("\n== Fig 5/6: level-cost profiles ==")
    level_profiles.run(csv_dir="experiments")
    print("\n== Solver wall-time (name,strategy,steps,levels,us,model_us,"
          "speedup) ==")
    solver_bench.run(csv_out="experiments/solver_bench.csv")
    _roofline_summary()
    print(f"\ntotal {time.time() - t0:.1f}s")


def _roofline_summary() -> None:
    """Summarize the latest dry-run roofline records, if present."""
    import json
    from pathlib import Path
    src = Path("experiments/dryrun_results.json")
    if not src.exists():
        print("\n(no dry-run records; run repro.launch.dryrun --all "
              "--both-meshes first)")
        return
    rs = [r for r in json.loads(src.read_text()) if "roofline" in r]
    print("\n== Dry-run roofline summary (arch,shape,mesh,dominant,"
          "useful,MFU_hi,MFU_lo) ==")
    for r in rs:
        rf = r["roofline"]
        print(f"{r['arch']},{r['shape']},{r['mesh_tag']},"
              f"{rf['dominant_min']},{rf['useful_fraction']:.2f},"
              f"{rf['roofline_mfu']:.3f},{rf['roofline_mfu_min']:.3f}")
    skips = [r for r in json.loads(src.read_text()) if "skip" in r]
    print(f"cells: {len(rs)} compiled OK, {len(skips)} assignment skips")


if __name__ == "__main__":
    main()

"""Benchmark aggregator — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # full run
    PYTHONPATH=src python -m benchmarks.run --smoke    # reduced-scale CI run

Sections:
  engines         — capability smoke: every available registered engine
                    solves one system through the registry (smoke only)
  table1          — paper Table I (strategy comparison, lung2/torso2)
  level_profiles  — paper Fig. 5/6 (per-level cost profiles)
  solver_bench    — solve wall time (CPU measured + TPU roofline model)
  schedule        — schedule-compiler before/after (BENCH_schedule.json)
  operator        — auto-tuner vs fixed strategies (BENCH_operator.json)
  iterative       — end-to-end IC(0)-PCG, tuned vs no_rewriting
                    (BENCH_iterative.json)
  refactor        — value-update fast path vs full re-tuned rebuild per
                    time step (BENCH_refactor.json)
  distributed     — sharded-engine scaling curve + steps-vs-all_gathers
                    table (BENCH_distributed.json; full mode runs in a
                    subprocess with 8 forced host devices, smoke runs
                    in-process on the available devices)
  serving         — solve-service load sweep: micro-batched throughput
                    vs sequential, cold-start latency anatomy, hot-swap
                    guarantee (BENCH_serving.json)

--smoke runs every section at reduced scale (seconds, not minutes) so the
tier-1 suite can import-check and execute the drivers (pytest -m bench).
The full run writes experiments/BENCH_schedule.json (build ms, steps,
padded vs real FLOPs, us_per_solve before/after — the schedule compiler's
perf trajectory) and experiments/BENCH_operator.json (tuner-vs-fixed-
strategy table — the portfolio auto-tuner's guarantee).  Smoke mode
executes every driver but persists nothing unless smoke() is given
explicit out paths — the committed full-scale artifacts must not be
clobbered by reduced-scale runs.
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path


def bench_schedule(out_path="experiments/BENCH_schedule.json",
                   scales=(0.25, 0.15), reps=5, time_solve=True) -> dict:
    """Schedule-compiler before/after on the benchmark analogues."""
    from benchmarks.solver_bench import schedule_metrics
    from repro.sparse import generators
    record = {
        "config": {"chunk": 256, "max_deps": 16, "scales": list(scales)},
        "matrices": {},
    }
    for name, L in (
            (f"lung2_like@{scales[0]}", generators.lung2_like(scales[0])),
            (f"torso2_like@{scales[1]}", generators.torso2_like(scales[1]))):
        record["matrices"][name] = schedule_metrics(
            L, chunk=256, max_deps=16, reps=reps, time_solve=time_solve)
    if out_path:
        p = Path(out_path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(record, indent=2) + "\n")
    return record


def engine_capability_smoke(n: int = 200) -> dict:
    """Solve one small system through every *available* registered engine
    (registry dispatch, pallas-interpret included) and check it against the
    sequential reference — the CI engine-capability gate."""
    import jax.numpy as jnp
    import numpy as np
    from repro.solver import (available_engines, engine_capabilities,
                              resolve_engine, schedule_for_csr,
                              solve_csr_seq, to_device)
    from repro.sparse import build_levels, generators

    L = generators.random_lower(n, avg_offdiag=2.5, seed=0, max_back=25)
    sched = schedule_for_csr(L, build_levels(L), chunk=64, max_deps=8)
    ds = to_device(sched)
    rng = np.random.default_rng(0)
    b = rng.standard_normal(n)
    B = rng.standard_normal((n, 3))
    x_ref = solve_csr_seq(L, b)
    out = {"capabilities": engine_capabilities(), "rel_err": {}}
    for name in available_engines():
        eng = resolve_engine(name)
        fn = eng.compile(ds)
        x = np.asarray(fn(jnp.asarray(b, ds.dtype)))
        err = float(np.abs(x - x_ref).max() / max(1.0, np.abs(x_ref).max()))
        assert err < 2e-4, f"engine {name}: rel err {err:.2e}"
        if eng.supports_batched_rhs:
            X = np.asarray(fn(jnp.asarray(B, ds.dtype)))
            assert X.shape == (n, 3), f"engine {name}: batched shape"
        out["rel_err"][name] = err
        print(f"engine {name:<18} rel_err={err:.2e} "
              f"{eng.capabilities()}")
    return out


def smoke(out_path=None, operator_out=None, iterative_out=None) -> dict:
    """Reduced-scale pass over every benchmark driver (tier-1 smoke)."""
    import benchmarks.distributed_bench as db
    import benchmarks.iterative_bench as ib
    import benchmarks.level_profiles as lp
    import benchmarks.operator_bench as ob
    import benchmarks.refactor_bench as rb
    import benchmarks.serving_bench as svb
    import benchmarks.solver_bench as sb
    import benchmarks.table1 as t1
    from repro.sparse import generators
    from repro.sparse import io as sio

    engines = engine_capability_smoke()
    distributed = db.smoke_record()
    real_load = sio.load_named
    try:
        sio.load_named = lambda name: (
            generators.lung2_like(scale=0.04) if name == "lung2"
            else generators.torso2_like(scale=0.04))
        t1.run(csv_out=None)
        lp.run(csv_dir=None)
        sb.run(csv_out=None, scales=(0.05, 0.05), iters=2)
    finally:
        sio.load_named = real_load
    ob.run(out_path=operator_out, scales=(0.04, 0.04), iters=1,
           measure_top_k=0)
    it_rec = ib.run(out_path=iterative_out, scales=(0.02, 0.02), iters=1,
                    maxiter=200, measure_top_k=2)
    refactor = rb.run(out_path=None, scales=(0.04, 0.04), steps=2, iters=1)
    serving = svb.run(out_path=None, scales=(0.03, 0.03), widths=(1, 4),
                      rounds=3)
    rec = bench_schedule(None, scales=(0.08, 0.06), reps=2,
                         time_solve=False)
    rec["engines"] = engines
    rec["iterative"] = it_rec
    rec["distributed_smoke"] = distributed
    rec["refactor_smoke"] = refactor
    rec["serving_smoke"] = serving
    if out_path:        # persist WITH the engine section (record == file)
        p = Path(out_path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(rec, indent=2) + "\n")
    return rec


def main() -> None:
    import os
    if os.environ.get("REPRO_STRICT_DEPRECATIONS") == "1":
        # CI gate: DeprecationWarnings issued from repro's own modules are
        # errors (the string-engine shims must not regress into internal
        # use).  Regex module match — PYTHONWARNINGS can't prefix-match.
        import warnings
        warnings.filterwarnings("error", category=DeprecationWarning,
                                module=r"repro\..*")
    if "--smoke" in sys.argv:
        t0 = time.time()
        rec = smoke()
        print(json.dumps(rec, indent=2))
        print(f"\nsmoke total {time.time() - t0:.1f}s")
        return
    from benchmarks import level_profiles, solver_bench, table1
    t0 = time.time()
    print("== Table I: strategy comparison (paper values inline) ==")
    table1.run(csv_out="experiments/table1.csv")
    print("\n== Fig 5/6: level-cost profiles ==")
    level_profiles.run(csv_dir="experiments")
    print("\n== Solver wall-time (name,strategy,steps,levels,us,model_us,"
          "speedup,build_ms,padded,real) ==")
    solver_bench.run(csv_out="experiments/solver_bench.csv")
    print("\n== Schedule compiler before/after ==")
    rec = bench_schedule()
    for name, m in rec["matrices"].items():
        print(f"{name}: legacy_build={m['legacy_build_ms']}ms -> "
              f"after={m['after']['build_ms']}ms "
              f"({m['build_speedup_vs_legacy']}x), steps "
              f"{m['before']['steps']} -> {m['after']['steps']} "
              f"(levels {m['after']['levels']}), padded_flops "
              f"{m['before']['padded_flops']} -> "
              f"{m['after']['padded_flops']} "
              f"(-{m['padded_flops_reduction']:.0%})")
    print("\n== Operator auto-tuner vs fixed strategies ==")
    from benchmarks import operator_bench
    operator_bench.run(out_path="experiments/BENCH_operator.json")
    print("\n== End-to-end IC(0)-PCG: tuned vs no_rewriting ==")
    from benchmarks import iterative_bench
    iterative_bench.run(out_path="experiments/BENCH_iterative.json")
    print("\n== Refactorization fast path: update_values vs full "
          "rebuild per step ==")
    from benchmarks import refactor_bench
    refactor_bench.run(out_path="experiments/BENCH_refactor.json")
    print("\n== Sharded scaling curve + steps-vs-all_gathers "
          "(8 forced host devices, subprocess) ==")
    from benchmarks import distributed_bench
    distributed_bench.run(out_path="experiments/BENCH_distributed.json")
    print("\n== Solve service: micro-batched load sweep + cold-start "
          "anatomy ==")
    from benchmarks import serving_bench
    serving_bench.run(out_path="experiments/BENCH_serving.json")
    _roofline_summary()
    print(f"\ntotal {time.time() - t0:.1f}s")


def _roofline_summary() -> None:
    """Summarize the latest dry-run roofline records, if present."""
    src = Path("experiments/dryrun_results.json")
    if not src.exists():
        print("\n(no dry-run records; run repro.launch.dryrun --all "
              "--both-meshes first)")
        return
    rs = [r for r in json.loads(src.read_text()) if "roofline" in r]
    print("\n== Dry-run roofline summary (arch,shape,mesh,dominant,"
          "useful,MFU_hi,MFU_lo) ==")
    for r in rs:
        rf = r["roofline"]
        print(f"{r['arch']},{r['shape']},{r['mesh_tag']},"
              f"{rf['dominant_min']},{rf['useful_fraction']:.2f},"
              f"{rf['roofline_mfu']:.3f},{rf['roofline_mfu_min']:.3f}")
    skips = [r for r in json.loads(src.read_text()) if "skip" in r]
    print(f"cells: {len(rs)} compiled OK, {len(skips)} assignment skips")


if __name__ == "__main__":
    main()

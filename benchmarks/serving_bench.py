"""Solve-service benchmark -> experiments/BENCH_serving.json.

Two claims the serving tier (src/repro/serving/) is judged by, measured
on both benchmark analogues:

1. **Micro-batching wins at load.**  A closed-loop sweep drives the
   service with c concurrent clients at batcher width c (c = 1, 2, 4,
   ...): every client submits one request at a time and waits, so
   offered load rises with c while the batcher coalesces concurrent
   requests into one (n, c) solve.  Reported per point: throughput
   (requests/s), p50/p99 request latency, and the achieved mean batch
   width.  The headline boolean `batched_beats_sequential` asserts
   throughput at saturation (the widest point) exceeds the sequential
   baseline — a bare `op.solve(b)` loop on one thread with zero service
   overhead.

2. **Tuning never blocks admission.**  A fresh background-mode service
   is cold-started on the matrix: the first request's response time is
   compared against (a) a direct untuned `no_rewriting` build + solve —
   admission's latency budget — and (b) the full portfolio-tuned build,
   which is what a naive serve-after-tune design would charge the first
   request.  `cold_start_not_tuner_bound` asserts the first response
   landed well under the tuned-build regime, and `hot_swap_landed`
   asserts the background tune still arrived afterwards.

As everywhere in benchmarks/, the committed-artifact test asserts the
BOOLEAN guarantees of the full-scale record; wall-clock numbers are
context, never assertions at smoke scale.
"""
from __future__ import annotations

import concurrent.futures
import json
import time
from pathlib import Path

import numpy as np

from repro.serving import OperatorRegistry, SolveService
from repro.solver import TriangularOperator
from repro.sparse import generators


def _percentile(xs, q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else float("nan")


def _closed_loop(svc: SolveService, L, b, *, clients: int,
                 rounds: int) -> dict:
    """`clients` threads, each submitting one request at a time."""
    lat_ms = [[] for _ in range(clients)]

    def client(j: int) -> None:
        for _ in range(rounds):
            t0 = time.perf_counter()
            svc.submit(b, L, tenant=f"c{j}").result(timeout=300)
            lat_ms[j].append((time.perf_counter() - t0) * 1e3)

    t0 = time.perf_counter()
    with concurrent.futures.ThreadPoolExecutor(max_workers=clients) as pool:
        list(pool.map(client, range(clients)))
    elapsed = time.perf_counter() - t0
    flat = [x for series in lat_ms for x in series]
    return {"clients": clients, "requests": clients * rounds,
            "elapsed_s": round(elapsed, 3),
            "throughput_rps": round(clients * rounds / elapsed, 1),
            "p50_ms": round(_percentile(flat, 50), 3),
            "p99_ms": round(_percentile(flat, 99), 3)}


def bench_cold_start(L, b, *, chunk: int = 256, max_deps: int = 16) -> dict:
    """Cold-start latency anatomy: untuned direct build vs service first
    response (background tuning) vs the full tuned build."""
    kw = dict(chunk=chunk, max_deps=max_deps, cache=False)
    # admission's latency budget: plain level scheduling + first solve
    t0 = time.perf_counter()
    op = TriangularOperator.from_csr(L, tune="no_rewriting", **kw)
    op.solve(b, max_refine=0)
    untuned_ms = (time.perf_counter() - t0) * 1e3
    # what serve-after-tune would charge the first request
    t0 = time.perf_counter()
    TriangularOperator.from_csr(L, tune="auto", **kw)
    tuned_build_ms = (time.perf_counter() - t0) * 1e3

    svc = SolveService(max_width=8, max_linger_s=0.001, workers=2,
                       tune_mode="background", **kw)
    try:
        t0 = time.perf_counter()
        svc.submit(b, L).result(timeout=300)
        first_response_ms = (time.perf_counter() - t0) * 1e3
        warmed = svc.wait_warm(timeout=600)
        snap = svc.snapshot()
    finally:
        svc.close()
    hot_swaps = snap["registry"]["hot_swaps"]
    return {
        "untuned_build_solve_ms": round(untuned_ms, 1),
        "tuned_build_ms": round(tuned_build_ms, 1),
        "first_response_ms": round(first_response_ms, 1),
        "admission_overhead_ms": round(first_response_ms - untuned_ms, 1),
        "hot_swap_landed": bool(warmed and hot_swaps >= 1),
        # the first response must track the untuned budget (generous
        # allowance for jit/session noise) ...
        "cold_start_le_untuned": bool(
            first_response_ms <= 1.5 * untuned_ms + 100.0),
        # ... and must clearly NOT have waited for the portfolio tuner
        "cold_start_not_tuner_bound": bool(
            first_response_ms < untuned_ms + 0.5 * tuned_build_ms),
    }


def bench_matrix(L, *, widths=(1, 2, 4, 8, 16), rounds: int = 20,
                 chunk: int = 256, max_deps: int = 16,
                 linger_s: float = 0.005) -> dict:
    rng = np.random.default_rng(0)
    b = rng.standard_normal(L.n_rows)

    cold = bench_cold_start(L, b, chunk=chunk, max_deps=max_deps)

    # one tuned registry shared by every sweep point: the sweep measures
    # the batching tier, not repeated tuning
    registry = OperatorRegistry(tune_mode="sync", chunk=chunk,
                                max_deps=max_deps, cache=False)
    try:
        entry, _, _ = registry.admit(L)
        op = entry.op
        op.solve(b, max_refine=0)           # prime compiled fns + preamble
        # prime every padded batch shape the sweep can produce: the
        # service pads to power-of-two width buckets (service.pad_widths),
        # so these are the only multi-column shapes the engines will see
        k = 2
        while k <= 1 << (max(widths) - 1).bit_length():
            op.solve(np.zeros((L.n_rows, k), dtype=np.float32),
                     max_refine=0)
            k *= 2

        # sequential baseline: zero service overhead, zero batching
        n_seq = max(widths) * rounds
        t0 = time.perf_counter()
        for _ in range(n_seq):
            op.solve(b, max_refine=0)
        seq_elapsed = time.perf_counter() - t0
        sequential = {"requests": n_seq,
                      "throughput_rps": round(n_seq / seq_elapsed, 1),
                      "mean_ms": round(seq_elapsed / n_seq * 1e3, 3)}

        sweep = []
        for w in widths:
            svc = SolveService(max_width=w, max_linger_s=linger_s,
                               workers=2, registry=registry)
            try:
                svc.submit(b, L).result(timeout=300)    # warm the path
                point = _closed_loop(svc, L, b, clients=w, rounds=rounds)
                point["width"] = w
                point["mean_batch_width"] = round(
                    svc.stats.mean_width(), 2)
                sweep.append(point)
            finally:
                svc.close()
    finally:
        registry.close()

    saturation = sweep[-1]
    return {
        "n": L.n_rows, "nnz": L.nnz, "strategy": op.strategy,
        "cold_start": cold,
        "sequential": sequential,
        "load_sweep": sweep,
        "saturation_speedup_vs_sequential": round(
            saturation["throughput_rps"] / sequential["throughput_rps"], 2),
        # boolean guarantees (committed-artifact test)
        "batched_beats_sequential": bool(
            saturation["throughput_rps"] > sequential["throughput_rps"]),
        "tuning_never_blocked": bool(cold["cold_start_le_untuned"]
                                     and cold["cold_start_not_tuner_bound"]),
        "hot_swap_landed": cold["hot_swap_landed"],
    }


def run(out_path="experiments/BENCH_serving.json", scales=(0.1, 0.08),
        widths=(1, 2, 4, 8, 16), rounds: int = 20, chunk: int = 256,
        max_deps: int = 16) -> dict:
    record = {
        "config": {"chunk": chunk, "max_deps": max_deps,
                   "scales": list(scales), "widths": list(widths),
                   "rounds": rounds, "solve_kwargs": {"max_refine": 0}},
        "matrices": {},
    }
    for name, L in (
            (f"lung2_like@{scales[0]}", generators.lung2_like(scales[0])),
            (f"torso2_like@{scales[1]}", generators.torso2_like(scales[1]))):
        m = bench_matrix(L, widths=widths, rounds=rounds, chunk=chunk,
                         max_deps=max_deps)
        record["matrices"][name] = m
        sat = m["load_sweep"][-1]
        print(f"{name}: seq {m['sequential']['throughput_rps']} rps -> "
              f"width {sat['width']} {sat['throughput_rps']} rps "
              f"({m['saturation_speedup_vs_sequential']}x, mean batch "
              f"{sat['mean_batch_width']}, p99 {sat['p99_ms']}ms); cold "
              f"first response {m['cold_start']['first_response_ms']}ms vs "
              f"untuned {m['cold_start']['untuned_build_solve_ms']}ms / "
              f"tuned {m['cold_start']['tuned_build_ms']}ms, "
              f"hot_swap={m['hot_swap_landed']}")
    if out_path:
        p = Path(out_path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(record, indent=2) + "\n")
    return record


if __name__ == "__main__":
    run()

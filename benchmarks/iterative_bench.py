"""End-to-end preconditioned-solver benchmark -> BENCH_iterative.json.

The paper's payoff scenario measured for real: repeated L/L^T solves
inside a full PCG loop.  For each benchmark analogue (lung2-like,
torso2-like — SPD systems whose tril pattern equals the paper matrices'
structural analogues via `spd_from_lower`), this driver runs:

  * unpreconditioned CG              (iteration-count baseline),
  * IC(0)-PCG with `no_rewriting`    (level scheduling, no transform),
  * IC(0)-PCG pair-tuned ("auto" + measured re-ranking, CPU cost model),

each as ONE jitted float64 program whose M^-1 is the device-native
operator pair, and records iterations, residuals, factorization/tuning
time, schedule shapes, and warm per-solve wall time (min over reps).

Headline check (mirrors the ISSUE 4 acceptance criterion): tuned-schedule
PCG wall time <= `no_rewriting` PCG wall time on both analogues — the
transformation payoff compounds over the iteration loop, or at worst the
tuner picks `no_rewriting` itself.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core.portfolio import CostModel
from repro.iterative import cg
from repro.precond import Preconditioner
from repro.sparse import generators


def _solve_ms(fn, b, iters: int) -> float:
    """Warm wall time of one full jitted PCG solve (min over iters)."""
    import jax
    jax.block_until_ready(fn(b))            # compile + warm outside timer
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(b))
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def bench_matrix(L, iters: int = 3, tol: float = 1e-8,
                 maxiter: int = 400, chunk: int = 256, max_deps: int = 16,
                 measure_top_k: int = 3, seed: int = 0) -> dict:
    """One analogue: baseline CG + the two PCG variants (module doc)."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    A = generators.spd_from_lower(L, seed=seed)
    rng = np.random.default_rng(seed)
    x_true = rng.standard_normal(A.n_rows)
    b_host = A.matvec(x_true)

    t0 = time.perf_counter()
    plain_p = Preconditioner.ic0(A, tune="no_rewriting", cache=False,
                                 chunk=chunk, max_deps=max_deps)
    plain_build_ms = (time.perf_counter() - t0) * 1e3
    t0 = time.perf_counter()
    Preconditioner.clear_pair_decisions()
    tuned_p = Preconditioner.ic0(A, tune="auto", cache=False, chunk=chunk,
                                 max_deps=max_deps,
                                 cost_model=CostModel.cpu(),
                                 measure_top_k=measure_top_k)
    tuned_build_ms = (time.perf_counter() - t0) * 1e3

    out = {"n": A.n_rows, "nnz": A.nnz, "tol": tol,
           "nnz_L": plain_p.factors.L.nnz,
           "ic0_shift": plain_p.factors.shift}
    with enable_x64():
        b = jnp.asarray(b_host)
        base = cg(A, b, tol=tol, maxiter=maxiter)
        out["unpreconditioned"] = {
            "iterations": int(base.iterations),
            "converged": bool(base.converged),
            "residual": float(base.final_residual()),
        }
        for name, P, build_ms in (("no_rewriting", plain_p, plain_build_ms),
                                  ("tuned", tuned_p, tuned_build_ms)):
            fn = jax.jit(lambda bb, P=P: cg(A, bb, preconditioner=P,
                                            tol=tol, maxiter=maxiter))
            res = fn(b)
            err = float(np.abs(np.asarray(res.x) - x_true).max())
            out[name] = {
                "pick": P.strategy,
                "iterations": int(res.iterations),
                "converged": bool(res.converged),
                "residual": float(res.final_residual()),
                "max_err": err,
                "build_ms": round(build_ms, 1),
                "steps_fwd": P.forward.schedule.num_steps,
                "steps_bwd": P.backward.schedule.num_steps,
                "solve_ms": round(_solve_ms(fn, b, iters), 2),
            }
    out["pcg_fewer_iters_than_cg"] = bool(
        out["tuned"]["iterations"] < out["unpreconditioned"]["iterations"])
    # 10% timer-noise margin: when the tuner's measured guardrail picks
    # no_rewriting itself the two pipelines are identical and only noise
    # separates them
    out["tuned_not_slower"] = bool(
        out["tuned"]["solve_ms"] <= 1.10 * out["no_rewriting"]["solve_ms"])
    return out


def run(out_path="experiments/BENCH_iterative.json", scales=(0.08, 0.06),
        iters: int = 3, tol: float = 1e-8, maxiter: int = 400,
        measure_top_k: int = 3) -> dict:
    record = {
        "config": {"scales": list(scales), "iters": iters, "tol": tol,
                   "maxiter": maxiter, "measure_top_k": measure_top_k,
                   "chunk": 256, "max_deps": 16,
                   "cost_model": "cpu", "solver": "cg+ic0", "dtype": "f64"},
        "matrices": {},
    }
    for name, L in (
            (f"lung2_like@{scales[0]}", generators.lung2_like(scales[0])),
            (f"torso2_like@{scales[1]}", generators.torso2_like(scales[1]))):
        m = bench_matrix(L, iters=iters, tol=tol, maxiter=maxiter,
                         measure_top_k=measure_top_k)
        record["matrices"][name] = m
        print(f"{name}: n={m['n']} cg {m['unpreconditioned']['iterations']} "
              f"iters -> pcg {m['tuned']['iterations']} iters | "
              f"no_rewriting {m['no_rewriting']['solve_ms']}ms "
              f"(steps {m['no_rewriting']['steps_fwd']}"
              f"+{m['no_rewriting']['steps_bwd']}) vs tuned "
              f"{m['tuned']['solve_ms']}ms "
              f"(steps {m['tuned']['steps_fwd']}+{m['tuned']['steps_bwd']}, "
              f"pick={m['tuned']['pick']}) -> "
              f"not_slower={m['tuned_not_slower']}")
    if out_path:
        p = Path(out_path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(record, indent=2) + "\n")
    return record


if __name__ == "__main__":
    run()

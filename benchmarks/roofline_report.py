"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from
experiments/dryrun_results.json.

    PYTHONPATH=src python -m benchmarks.roofline_report [json] [out_md]
"""
from __future__ import annotations

import json
import sys
from pathlib import Path


def fmt_s(v):
    if v is None:
        return "-"
    if v >= 1:
        return f"{v:.2f}s"
    if v >= 1e-3:
        return f"{v * 1e3:.1f}ms"
    return f"{v * 1e6:.0f}us"


def render(results: list[dict], mesh_tag: str) -> list[str]:
    rows = ["| arch | shape | kind | compile | HLO TFLOP | coll GB (#) | "
            "temp GB | compute | mem(hi/lo) | coll | dominant | useful | "
            "MFU(hi/lo) | note |",
            "|---|---|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in results:
        if r.get("mesh_tag") != mesh_tag:
            continue
        if "skip" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - "
                        f"| - | - | - | SKIP | - | - | {r['skip'][:40]}… |")
            continue
        if "error" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | - | FAIL | - | - | "
                        f"- | - | - | - | - | - | - | {r['error'][:40]} |")
            continue
        rf = r["roofline"]
        coll = r["collectives"]
        note = ""
        temp = (r["memory"]["temp_bytes"] or 0) / 1e9
        if temp > 16:
            note = "over 16G/chip (see §Perf)"
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | "
            f"{r['compile_s']}s | {r['hlo_flops'] / 1e12:.2f} | "
            f"{coll['total_bytes'] / 1e9:.1f} ({coll['total_count']}) | "
            f"{temp:.1f} | {fmt_s(rf['compute_s'])} | "
            f"{fmt_s(rf['memory_s'])}/{fmt_s(rf['memory_min_s'])} | "
            f"{fmt_s(rf['collective_s'])} | {rf['dominant']}/"
            f"{rf['dominant_min']} | {rf['useful_fraction']:.2f} | "
            f"{rf['roofline_mfu']:.3f}/{rf['roofline_mfu_min']:.3f} | "
            f"{note} |")
    return rows


def pick_hillclimb(results: list[dict]) -> list[str]:
    """worst roofline fraction / most collective-bound / most representative
    (train cell with heavy level-like scan structure)."""
    ok = [r for r in results
          if r.get("mesh_tag") == "1pod" and "roofline" in r]
    worst = min(ok, key=lambda r: r["roofline"]["roofline_mfu_min"])
    collb = max(ok, key=lambda r: r["roofline"]["collective_s"])
    return [f"{worst['arch']}|{worst['shape']}",
            f"{collb['arch']}|{collb['shape']}"]


def main():
    src = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun_results.json"
    results = json.loads(Path(src).read_text())
    out = []
    for tag, title in (("1pod", "single-pod 16x16 (256 chips)"),
                       ("2pod", "multi-pod 2x16x16 (512 chips)")):
        out.append(f"\n### Mesh {title}\n")
        out.extend(render(results, tag))
    text = "\n".join(out)
    print(text)
    print("\nsuggested hillclimb cells:", pick_hillclimb(results))
    if len(sys.argv) > 2:
        Path(sys.argv[2]).write_text(text + "\n")


if __name__ == "__main__":
    main()

"""Distributed SpTRSV scaling bench (experiments/BENCH_distributed.json).

Measures the sharded execution path (repro.solver.engines.ShardedEngine,
docs/distributed.md) on the paper analogues:

* a scaling curve over mesh sizes (1/2/4/8 forced host devices by
  default): per-solve wall time of the sharded sweep, transformed vs.
  untransformed, with correctness checked against the sequential
  reference at every size;
* the steps-vs-all_gathers table: `count_all_gathers` audits that every
  schedule issues exactly ONE all_gather family (synchronization barrier)
  per step, so the transformation's step reduction IS the barrier
  reduction the paper headlines.

The full run (`run()`, wired into `python -m benchmarks.run`) executes the
sweep in a subprocess with XLA_FLAGS forcing 8 host devices, keeping the
parent's single-device view intact; `smoke_record()` runs in-process at
reduced scale on whatever devices the current process has (the tier-1 /
CI form — under the CI distributed job the process itself is started with
8 forced host devices).

Timings are the sharded sweep only (the any-b preamble is a host/device
charge shared with the single-device path and benchmarked by
operator_bench); `transformed_not_slower` compares the two sweeps at
equal mesh size.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent


def _measure_record(scales=(0.08, 0.06), device_counts=(1, 2, 4, 8),
                    iters: int = 3, chunk: int = 64,
                    max_deps: int = 8) -> dict:
    """The in-process measurement pass (jax must already be initialized
    with however many devices the caller arranged)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import AvgLevelCost, transform
    from repro.solver import (schedule_for_csr, schedule_for_transformed,
                              solve_csr_seq)
    from repro.solver.distributed import count_all_gathers, default_mesh
    from repro.solver.engines import sharded_engine
    from repro.sparse import build_levels, generators

    devs = jax.devices()
    counts = [d for d in device_counts if d <= len(devs)]
    rec = {
        "config": {"scales": list(scales), "device_counts": counts,
                   "iters": iters, "chunk": chunk, "max_deps": max_deps,
                   "backend": devs[0].platform, "num_devices": len(devs)},
        "matrices": {},
    }
    for name, L in (
            (f"lung2_like@{scales[0]}", generators.lung2_like(scales[0])),
            (f"torso2_like@{scales[1]}", generators.torso2_like(scales[1]))):
        b = np.random.default_rng(0).standard_normal(L.n_rows)
        x_ref = solve_csr_seq(L, b)
        xscale = max(1.0, float(np.abs(x_ref).max()))
        s0 = schedule_for_csr(L, build_levels(L), chunk=chunk,
                              max_deps=max_deps)
        ts = transform(L, AvgLevelCost(), validate=False, codegen=False)
        s1 = schedule_for_transformed(ts, chunk=chunk, max_deps=max_deps)
        c1 = ts.preamble(b)
        g0, g1 = count_all_gathers(s0), count_all_gathers(s1)
        entry = {
            "n": L.n_rows, "nnz": L.nnz,
            "steps": {"no_rewriting": s0.num_steps,
                      "transformed": s1.num_steps},
            # one all_gather family (synchronization barrier) per step —
            # the invariant tests assert on the committed artifact
            "all_gathers": {"no_rewriting": g0["families"],
                            "transformed": g1["families"]},
            "all_gather_calls": {"no_rewriting": g0["calls"],
                                 "transformed": g1["calls"]},
            "curve": [],
        }

        def timed(fn, c):
            x = np.asarray(fn(c))               # compile outside the timer
            best = float("inf")
            for _ in range(iters):
                t0 = time.perf_counter()
                jax_block(fn(c))
                best = min(best, time.perf_counter() - t0)
            return x, best * 1e6

        jax_block = jax.block_until_ready
        for d in counts:
            mesh = default_mesh(devices=devs[:d])
            eng = sharded_engine(mesh)
            fn0, fn1 = eng.compile(s0), eng.compile(s1)
            x0, us0 = timed(fn0, jnp.asarray(b, s0.dtype))
            x1, us1 = timed(fn1, jnp.asarray(c1, s1.dtype))
            entry["curve"].append({
                "devices": d,
                "no_rewriting_us": round(us0, 1),
                "transformed_us": round(us1, 1),
                "err_no_rewriting": float(np.abs(x0 - x_ref).max() / xscale),
                "err_transformed": float(np.abs(x1 - x_ref).max() / xscale),
            })
        entry["transformed_not_slower"] = any(
            p["transformed_us"] <= p["no_rewriting_us"]
            for p in entry["curve"])
        rec["matrices"][name] = entry
    rec["transformed_not_slower_any"] = any(
        m["transformed_not_slower"] for m in rec["matrices"].values())
    return rec


def smoke_record(scales=(0.02, 0.02), iters: int = 1) -> dict:
    """Reduced-scale in-process pass over the available devices (the
    `distributed_smoke` section of benchmarks/run.py --smoke)."""
    return _measure_record(scales=scales, device_counts=(1, 2, 4, 8),
                           iters=iters, chunk=32, max_deps=4)


def run(out_path="experiments/BENCH_distributed.json", scales=(0.08, 0.06),
        device_counts=(1, 2, 4, 8), iters: int = 3,
        forced_devices: int = 8, timeout: int = 1200) -> dict:
    """Full sweep in a subprocess with `forced_devices` forced host devices
    (the parent process keeps its own device view); writes the artifact
    when `out_path` is given."""
    payload = {"scales": list(scales), "device_counts": list(device_counts),
               "iters": iters}
    env = dict(os.environ)
    env["XLA_FLAGS"] = " ".join(
        [env.get("XLA_FLAGS", ""),
         f"--xla_force_host_platform_device_count={forced_devices}"]).strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.distributed_bench", "--worker",
         json.dumps(payload)],
        capture_output=True, text=True, cwd=REPO_ROOT, env=env,
        timeout=timeout)
    if out.returncode != 0:
        raise RuntimeError(f"distributed bench worker failed:\n"
                           f"{out.stderr[-4000:]}")
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    if out_path:
        p = Path(out_path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(rec, indent=2) + "\n")
    _print_summary(rec)
    return rec


def _print_summary(rec: dict) -> None:
    for name, m in rec["matrices"].items():
        st, ag = m["steps"], m["all_gathers"]
        print(f"{name}: steps {st['no_rewriting']} -> {st['transformed']}, "
              f"all_gather families {ag['no_rewriting']} -> "
              f"{ag['transformed']} "
              f"(-{1 - st['transformed'] / st['no_rewriting']:.0%} barriers)")
        for p in m["curve"]:
            print(f"  devices={p['devices']}: no_rewriting "
                  f"{p['no_rewriting_us']:.0f}us, transformed "
                  f"{p['transformed_us']:.0f}us")


def main() -> None:
    if "--worker" in sys.argv:
        cfg = json.loads(sys.argv[sys.argv.index("--worker") + 1])
        rec = _measure_record(scales=tuple(cfg["scales"]),
                              device_counts=tuple(cfg["device_counts"]),
                              iters=cfg["iters"])
        print(json.dumps(rec))
        return
    run()


if __name__ == "__main__":
    main()

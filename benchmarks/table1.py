"""Table I reproduction: strategy comparison on lung2/torso2 analogues.

Emits CSV rows: matrix,strategy,num_levels,levels_red_pct,avg_cost_ratio,
total_cost_delta_pct,code_MB,rows_rewritten + the paper's reported values
side by side (EXPERIMENTS.md §Paper-validation).
"""
from __future__ import annotations

import time

from repro.core import AvgLevelCost, ManualEveryK, NoRewrite, transform
from repro.sparse import io as sio

PAPER = {  # (levels, avg_ratio, total_delta_pct, code_MB, rows_rewritten)
    ("lung2", "no_rewriting"): (479, 1.0, 0.0, 9.7, 0),
    ("lung2", "avgLevelCost"): (23, 20.71, -1.0, 8.6, 1304),
    ("lung2", "manual_every_k"): (67, 7.13, -1.0, 9.5, 898),
    ("torso2", "no_rewriting"): (513, 1.0, 0.0, 21.0, 0),
    ("torso2", "avgLevelCost"): (341, 1.53, 0.2, 21.0, 14655),
    ("torso2", "manual_every_k"): (284, 2.51, 40.0, None, 18147),
}


def run(csv_out=None):
    rows = ["matrix,strategy,num_levels,paper_levels,levels_red_pct,"
            "avg_cost_ratio,paper_avg_ratio,total_cost_delta_pct,"
            "paper_delta_pct,code_MB,paper_code_MB,rows_rewritten,"
            "paper_rows,seconds"]
    for name in ("lung2", "torso2"):
        L = sio.load_named(name)
        for strat in (NoRewrite(), AvgLevelCost(), ManualEveryK(10)):
            t0 = time.time()
            ts = transform(L, strat, validate=False, codegen=True)
            dt = time.time() - t0
            m = ts.metrics.table1_row()
            key = (name, ts.metrics.strategy.split("(")[0])
            p = PAPER.get(key, (None,) * 5)
            rows.append(
                f"{name},{m['strategy']},{m['num_levels']},{p[0]},"
                f"{m['levels_reduction_pct']:.1f},{m['avg_cost_ratio']:.2f},"
                f"{p[1]},{m['total_cost_delta_pct']:.1f},{p[2]},"
                f"{m['code_MB']:.1f},{p[3]},{m['rows_rewritten']},{p[4]},"
                f"{dt:.1f}")
    out = "\n".join(rows)
    print(out)
    if csv_out:
        from pathlib import Path
        Path(csv_out).write_text(out + "\n")
    return rows


if __name__ == "__main__":
    run()

"""Solver wall-time + schedule-compiler benchmark.

Measures, per matrix and strategy:
  * the JAX level-scheduled solver (CPU wall time, jitted, warm),
  * schedule-compiler quality: steps vs levels, padded vs real FLOPs,
    schedule memory, and build time — for the legacy-shaped configuration
    (level-aligned, one global max_deps-wide bucket, per-lane Python build)
    vs the current compiler (vectorized build, dependency-aware compaction,
    width-bucketed tiles),
  * a TPU roofline model: per-step cost = max(bytes/HBM_BW, flops/VPU) +
    step latency; the transformation's win is mostly the removed
    per-step/per-level overhead and barrier latency.

CSV: matrix,strategy,steps,levels,us_per_solve,model_tpu_us,speedup.
The schedule-compiler before/after numbers go to BENCH_schedule.json via
benchmarks.run.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import AvgLevelCost, ConstrainedAvgLevelCost, NoRewrite, \
    transform
from repro.solver import build_schedule, resolve_engine, schedule_for_csr, \
    schedule_for_transformed, solve, to_device
from repro.sparse import build_levels, generators
from repro.sparse import io as sio
from repro.sparse.csr import tril

HBM_BW = 819e9
VPU_FLOPS = 4e12          # ~VPU f32 throughput per chip
STEP_LATENCY = 2e-6       # scan-step / grid-step overhead (s)


def tpu_model_us(sched) -> float:
    per_step_bytes = sched.memory_bytes() / max(sched.num_steps, 1)
    per_step_flops = sched.padded_flops() / max(sched.num_steps, 1)
    per_step = max(per_step_bytes / HBM_BW, per_step_flops / VPU_FLOPS)
    return (sched.num_steps * (per_step + STEP_LATENCY)) * 1e6


def legacy_build_ms(A, diag, level_of, chunk=256, max_deps=16,
                    dtype=np.float32) -> float:
    """Time the seed's per-row/per-lane Python packing loop (the baseline
    the vectorized compiler replaces).  Faithful to the original cost
    profile: per-lane list appends + per-lane ELL tile fills."""
    t0 = time.perf_counter()
    n = A.n_rows
    num_levels = int(level_of.max()) + 1 if n else 0
    order = np.lexsort((np.arange(n), level_of))
    indptr, indices, data = A.indptr, A.indices, A.data
    lane_rows, lane_deps, lane_final = [], [], []
    lanes_per_level = []
    pos = 0
    for lvl in range(num_levels):
        start = len(lane_rows)
        while pos < n and level_of[order[pos]] == lvl:
            i = int(order[pos]); pos += 1
            lo, hi = int(indptr[i]), int(indptr[i + 1])
            nseg = max(1, -(-(hi - lo) // max_deps))
            for s in range(nseg):
                lane_rows.append(i)
                lane_deps.append((lo + s * max_deps,
                                  min(lo + (s + 1) * max_deps, hi)))
                lane_final.append(s == nseg - 1)
        lanes_per_level.append(len(lane_rows) - start)
    steps = []
    lane_ptr = 0
    for lvl in range(num_levels):
        cnt = lanes_per_level[lvl]
        lanes = list(range(lane_ptr, lane_ptr + cnt))
        lane_ptr += cnt
        by_row_seen, buckets = {}, []
        for ln in lanes:
            k = by_row_seen.get(lane_rows[ln], 0)
            by_row_seen[lane_rows[ln]] = k + 1
            while len(buckets) <= k:
                buckets.append([])
            buckets[k].append(ln)
        for bucket in buckets:
            for s in range(0, len(bucket), chunk):
                steps.append(bucket[s:s + chunk])
        if not buckets:
            steps.append([])
    S, C, D = len(steps), chunk, max_deps
    dep_idx = np.full((S, C, D), n, dtype=np.int32)
    dep_coef = np.zeros((S, C, D), dtype=dtype)
    row_ids = np.full((S, C), n, dtype=np.int32)
    dinv = np.zeros((S, C), dtype=dtype)
    for si, lanes in enumerate(steps):
        for lane_pos, ln in enumerate(lanes):
            lo, hi = lane_deps[ln]
            k = hi - lo
            dep_idx[si, lane_pos, :k] = indices[lo:hi]
            dep_coef[si, lane_pos, :k] = data[lo:hi]
            if lane_final[ln]:
                row_ids[si, lane_pos] = lane_rows[ln]
                dinv[si, lane_pos] = 1.0 / diag[lane_rows[ln]]
    return (time.perf_counter() - t0) * 1e3


def _solve_us(sched, b, iters=3, engine=None) -> float:
    import jax.numpy as jnp
    ds = to_device(sched)
    fn = resolve_engine(engine).compile(ds)
    cc = jnp.asarray(b, dtype=ds.dtype)
    fn(cc).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(cc).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def schedule_metrics(L, chunk=256, max_deps=16, reps=5,
                     time_solve=True) -> dict:
    """Before/after schedule-compiler comparison on one matrix: legacy
    per-lane build vs vectorized build, level-aligned single-bucket layout
    vs compacted width-bucketed layout."""
    lv = build_levels(L)
    A = tril(L, keep_diagonal=False)
    diag = L.diagonal_fast()
    legacy_ms = min(legacy_build_ms(A, diag, lv.level_of, chunk, max_deps)
                    for _ in range(max(1, reps // 2)))
    before = after = None
    before_ms, after_ms = [], []
    for _ in range(reps):
        before = build_schedule(A, diag, lv.level_of, chunk=chunk,
                                max_deps=max_deps, legacy_shape=True)
        before_ms.append(before.build_ms)
        after = schedule_for_csr(L, lv, chunk=chunk, max_deps=max_deps,
                                 compact=True)
        after_ms.append(after.build_ms)

    def row(s, build):
        return dict(build_ms=round(build, 3), steps=s.num_steps,
                    levels=s.num_levels, padded_flops=s.padded_flops(),
                    real_flops=s.flops(), memory_bytes=s.memory_bytes(),
                    group_widths=list(s.group_widths),
                    model_tpu_us=round(tpu_model_us(s), 1))

    out = dict(
        n=L.n_rows, nnz=L.nnz, chunk=chunk, max_deps=max_deps,
        legacy_build_ms=round(legacy_ms, 2),
        before=row(before, min(before_ms)),
        after=row(after, min(after_ms)),
    )
    if time_solve:
        b = np.random.default_rng(0).standard_normal(L.n_rows)
        out["before"]["us_per_solve"] = round(_solve_us(before, b), 1)
        out["after"]["us_per_solve"] = round(_solve_us(after, b), 1)
    out["build_speedup_vs_legacy"] = round(
        legacy_ms / max(min(after_ms), 1e-9), 1)
    out["padded_flops_reduction"] = round(
        1 - after.padded_flops() / before.padded_flops(), 3)
    out["steps_reduction"] = before.num_steps - after.num_steps
    # the quality metrics above, re-derived by the static verifier — the
    # committed artifact carries a *certified* block per matrix (timing
    # fields excluded: the certificate is deterministic across machines)
    from repro.analysis import certificate_dict, verify_level_schedule
    out["certificate"] = certificate_dict(
        verify_level_schedule(after, A, diag, where="schedule_metrics"))
    return out


def bench_one(L, name: str, scale_note: str, chunk=256, max_deps=16,
              iters=5, engine=None):
    import jax.numpy as jnp
    b = np.random.default_rng(0).standard_normal(L.n_rows)
    rows = []
    base_us = None
    eng = resolve_engine(engine)
    for strat in (NoRewrite(), AvgLevelCost(),
                  ConstrainedAvgLevelCost(alpha=12, beta=64, coef_cap=1e8)):
        ts = transform(L, strat, validate=False, codegen=False)
        sched = schedule_for_transformed(ts, chunk=chunk, max_deps=max_deps)
        c = ts.preamble(b).astype(np.float32)
        ds = to_device(sched)
        fn = eng.compile(ds)
        cc = jnp.asarray(c)
        fn(cc).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(iters):
            fn(cc).block_until_ready()
        us = (time.perf_counter() - t0) / iters * 1e6
        if base_us is None:
            base_us = us
        rows.append(f"{name}{scale_note},{ts.metrics.strategy.split('(')[0]},"
                    f"{sched.num_steps},{sched.num_levels},{us:.0f},"
                    f"{tpu_model_us(sched):.0f},{base_us / us:.2f},"
                    f"{sched.build_ms:.2f},{sched.padded_flops()},"
                    f"{sched.flops()}")
    return rows


def run(csv_out=None, scales=(0.25, 0.15), iters=5):
    header = ("matrix,strategy,steps,levels,us_per_solve,model_tpu_us,"
              "speedup_vs_norewrite,build_ms,padded_flops,real_flops")
    rows = [header]
    rng_mats = [
        (generators.lung2_like(scale=scales[0]), "lung2_like",
         f"@{scales[0]}"),
        (generators.torso2_like(scale=scales[1]), "torso2_like",
         f"@{scales[1]}"),
    ]
    for L, name, note in rng_mats:
        rows.extend(bench_one(L, name, note, iters=iters))
    out = "\n".join(rows)
    print(out)
    if csv_out:
        from pathlib import Path
        Path(csv_out).write_text(out + "\n")
    return rows


if __name__ == "__main__":
    run()

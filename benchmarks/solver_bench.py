"""Solver wall-time benchmark (the runtime table the paper omits).

Measures the JAX level-scheduled solver (CPU wall time, jitted, warm) for
no-rewriting vs avgLevelCost vs constrained strategies, plus a TPU roofline
model: per-step cost = max(bytes/HBM_BW, flops/VPU) + step latency; the
transformation's win is mostly the removed per-step/per-level overhead and
barrier latency.

CSV: matrix,strategy,steps,levels,us_per_solve,model_tpu_us,speedup.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import AvgLevelCost, ConstrainedAvgLevelCost, NoRewrite, \
    transform
from repro.solver import schedule_for_csr, schedule_for_transformed, solve, \
    to_device
from repro.solver.levelset import solve_scan
from repro.sparse import build_levels, generators
from repro.sparse import io as sio

HBM_BW = 819e9
VPU_FLOPS = 4e12          # ~VPU f32 throughput per chip
STEP_LATENCY = 2e-6       # scan-step / grid-step overhead (s)


def tpu_model_us(sched) -> float:
    per_step_bytes = sched.memory_bytes() / max(sched.num_steps, 1)
    per_step_flops = sched.padded_flops() / max(sched.num_steps, 1)
    per_step = max(per_step_bytes / HBM_BW, per_step_flops / VPU_FLOPS)
    return (sched.num_steps * (per_step + STEP_LATENCY)) * 1e6


def bench_one(L, name: str, scale_note: str, chunk=256, max_deps=8,
              iters=5):
    import jax
    import jax.numpy as jnp
    lv = build_levels(L)
    b = np.random.default_rng(0).standard_normal(L.n_rows)
    rows = []
    base_us = None
    for strat in (NoRewrite(), AvgLevelCost(),
                  ConstrainedAvgLevelCost(alpha=12, beta=64, coef_cap=1e8)):
        ts = transform(L, strat, validate=False, codegen=False)
        sched = schedule_for_transformed(ts, chunk=chunk, max_deps=max_deps)
        c = ts.preamble(b).astype(np.float32)
        ds = to_device(sched)
        fn = jax.jit(lambda cc: solve_scan(ds, cc))
        cc = jnp.asarray(c)
        fn(cc).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(iters):
            fn(cc).block_until_ready()
        us = (time.perf_counter() - t0) / iters * 1e6
        if base_us is None:
            base_us = us
        rows.append(f"{name}{scale_note},{ts.metrics.strategy.split('(')[0]},"
                    f"{sched.num_steps},{sched.num_levels},{us:.0f},"
                    f"{tpu_model_us(sched):.0f},{base_us / us:.2f}")
    return rows


def run(csv_out=None):
    header = ("matrix,strategy,steps,levels,us_per_solve,model_tpu_us,"
              "speedup_vs_norewrite")
    rows = [header]
    rng_mats = [
        (generators.lung2_like(scale=0.25), "lung2_like", "@0.25"),
        (generators.torso2_like(scale=0.15), "torso2_like", "@0.15"),
    ]
    for L, name, note in rng_mats:
        rows.extend(bench_one(L, name, note))
    out = "\n".join(rows)
    print(out)
    if csv_out:
        from pathlib import Path
        Path(csv_out).write_text(out + "\n")
    return rows


if __name__ == "__main__":
    run()

import os

# Tests run on the single real CPU device (the dry-run module, never
# imported from tests, is the only place that forces 512 host devices).
# A small device count is forced for the distributed-solver tests via a
# subprocess (see test_distributed.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)

"""Schedule-compiler equivalence and invariants (ISSUE 1 tentpole).

Every configuration of the compiler — level-aligned vs compacted, single
vs multi width bucket, whole rows vs partial-row splits — must solve the
same systems as the sequential reference, and every emitted schedule must
satisfy the structural invariants validate_schedule audits (no same-step
dependency, carry chains ordered, rows finalized exactly once).
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _optional_deps import given, settings, st

from repro.core import AvgLevelCost, NoRewrite, transform
from repro.kernels import ops
from repro.solver import (schedule_for_csr, schedule_for_preamble,
                          schedule_for_transformed, solve, solve_csr_seq,
                          to_device, validate_schedule)
from repro.solver.levelset import solve_scan, solve_unrolled
from repro.sparse import build_levels, generators
from repro.sparse.csr import tril


def _check(L, chunk, max_deps, compact, widths=(4, 8, 16, 32),
           engine=None, rtol=2e-5):
    lv = build_levels(L)
    b = np.random.default_rng(0).standard_normal(L.n_rows)
    x_ref = solve_csr_seq(L, b)
    sched = schedule_for_csr(L, lv, chunk=chunk, max_deps=max_deps,
                             compact=compact, widths=widths,
                             dtype=np.float32)
    validate_schedule(sched, tril(L, keep_diagonal=False), L.diagonal_fast())
    x = solve(sched, b, engine=engine)
    scale = np.maximum(1.0, np.abs(x_ref).max())
    assert np.abs(x - x_ref).max() / scale < rtol
    return sched


GENS = [
    (generators.chain, dict(n=60)),
    (generators.banded, dict(n=90, bandwidth=7, seed=3)),
    (generators.random_lower, dict(n=250, avg_offdiag=2.5, seed=11,
                                   max_back=40)),
    (generators.poisson2d_ic0, dict(nx=11, ny=8)),
]


@pytest.mark.parametrize("compact", [False, True])
@pytest.mark.parametrize("gen,kw", GENS)
def test_equivalence_across_generators(gen, kw, compact):
    L = gen(**kw)
    _check(L, chunk=32, max_deps=4, compact=compact)


@pytest.mark.parametrize("compact", [False, True])
def test_partial_row_splits(compact):
    """max_deps < row nnz forces carry-chained partial rows."""
    L = generators.banded(80, 11, seed=5)      # rows with 11 deps
    sched = _check(L, chunk=16, max_deps=3, compact=compact)
    assert sched.n_carry > 1                   # splitting happened
    assert any(g.carry_in is not None for g in sched.groups)


def test_compaction_overlaps_partial_rows_with_earlier_levels():
    """Leading segments of split rows start before their row's level, so
    compaction needs far fewer steps than the level-aligned layout."""
    L = generators.banded(96, 10, seed=2)
    lv = build_levels(L)
    aligned = schedule_for_csr(L, lv, chunk=16, max_deps=4, compact=False)
    compacted = schedule_for_csr(L, lv, chunk=16, max_deps=4, compact=True)
    assert compacted.num_steps < aligned.num_steps
    assert compacted.num_steps <= aligned.num_levels


def test_compaction_never_exceeds_level_aligned_steps():
    for gen, kw in GENS:
        L = gen(**kw)
        lv = build_levels(L)
        for chunk, md in [(8, 2), (64, 8)]:
            s0 = schedule_for_csr(L, lv, chunk=chunk, max_deps=md,
                                  compact=False)
            s1 = schedule_for_csr(L, lv, chunk=chunk, max_deps=md,
                                  compact=True)
            assert s1.num_steps <= s0.num_steps


def test_width_bucketing_cuts_padded_flops():
    """Multi-bucket schedules do the same real FLOPs with less padding than
    a single global max_deps-wide bucket."""
    L = generators.random_lower(400, avg_offdiag=2.0, seed=7, max_back=60)
    lv = build_levels(L)
    wide = schedule_for_csr(L, lv, chunk=64, max_deps=16, widths=(16,))
    bucketed = schedule_for_csr(L, lv, chunk=64, max_deps=16,
                                widths=(4, 8, 16, 32))
    assert bucketed.flops() == wide.flops()
    assert bucketed.padded_flops() < wide.padded_flops()
    assert len(bucketed.groups) > 1
    b = np.random.default_rng(1).standard_normal(400)
    x_ref = solve_csr_seq(L, b)
    for s in (wide, bucketed):
        x = solve(s, b)
        assert np.abs(x - x_ref).max() / max(1.0, np.abs(x_ref).max()) < 2e-5


def test_multi_rhs_bucketed():
    L = generators.random_lower(150, avg_offdiag=2.5, seed=8, max_back=20)
    lv = build_levels(L)
    sched = schedule_for_csr(L, lv, chunk=32, max_deps=4, compact=True)
    B = np.random.default_rng(1).standard_normal((150, 6))
    ds = to_device(sched)
    X = np.asarray(solve_scan(ds, jnp.asarray(B, jnp.float32)))
    Xu = np.asarray(solve_unrolled(ds, jnp.asarray(B, jnp.float32)))
    for j in range(6):
        x_ref = solve_csr_seq(L, B[:, j])
        assert np.abs(X[:, j] - x_ref).max() < 2e-4
        assert np.abs(Xu[:, j] - x_ref).max() < 2e-4


def test_transformed_compacted_matches_reference():
    """Compaction of transformed (merged-level) systems still solves right
    and beats the untransformed step count."""
    L = generators.lung2_like(scale=0.08)
    lv = build_levels(L)
    ts = transform(L, AvgLevelCost(), validate=False, codegen=False)
    s0 = schedule_for_csr(L, lv, chunk=128, max_deps=8)
    s1 = schedule_for_transformed(ts, chunk=128, max_deps=8)
    validate_schedule(s1, ts.A, ts.diag)
    assert s1.num_steps < s0.num_steps
    b = np.random.default_rng(2).standard_normal(L.n_rows)
    c = ts.preamble(b)
    x = solve(s1, c)
    x_ref = solve_csr_seq(L, b)
    scale = np.maximum(1.0, np.abs(x_ref).max())
    assert np.abs(x - x_ref).max() / scale < 2e-4


def test_preamble_schedule_compacted():
    L = generators.lung2_like(scale=0.05)
    ts = transform(L, AvgLevelCost(), validate=False, codegen=False)
    b = np.random.default_rng(3).standard_normal(L.n_rows)
    c_ref = ts.preamble(b)
    psched, src, row_pos = schedule_for_preamble(ts, chunk=64, max_deps=8)
    assert psched is not None
    c_ent = solve(psched, b[src].astype(np.float32))
    np.testing.assert_allclose(c_ent[row_pos], c_ref, rtol=2e-4, atol=2e-4)


def test_pallas_kernel_bucketed_groups():
    """The grouped Pallas kernel path handles multi-bucket, carry-chained
    schedules (interpret mode) identically to the jnp oracle."""
    L = generators.banded(90, 9, seed=4)
    lv = build_levels(L)
    sched = schedule_for_csr(L, lv, chunk=16, max_deps=4, compact=True,
                             widths=(2, 4))
    b = np.random.default_rng(5).standard_normal(90)
    x_ref = solve_csr_seq(L, b)
    x_pal = ops.sptrsv_solve(sched, b, interpret=True)
    x_orc = ops.sptrsv_solve(sched, b, use_ref=True)
    np.testing.assert_allclose(x_pal, x_orc, rtol=1e-6, atol=1e-6)
    assert np.abs(x_pal - x_ref).max() < 1e-3


@given(st.integers(20, 160), st.integers(0, 10**5),
       st.sampled_from([(8, 2, False), (8, 2, True), (16, 4, True),
                        (64, 8, True)]))
@settings(max_examples=20, deadline=None)
def test_property_random_matrices(n, seed, cfg):
    chunk, max_deps, compact = cfg
    L = generators.random_lower(n, avg_offdiag=2.5, seed=seed, max_back=12)
    _check(L, chunk, max_deps, compact, rtol=5e-4)

"""Solver engines: schedule packing, scan/unrolled engines, multi-RHS."""
import jax.numpy as jnp
import numpy as np
import pytest
from _optional_deps import given, settings, st

from repro.core import AvgLevelCost, NoRewrite, transform
from repro.solver import (resolve_engine, schedule_for_csr,
                          schedule_for_transformed, solve, solve_csr_seq,
                          to_device)
from repro.solver.levelset import solve_scan, solve_unrolled
from repro.sparse import build_levels, generators


def _solve_and_check(L, chunk, max_deps, engine=None, rtol=2e-5):
    lv = build_levels(L)
    b = np.random.default_rng(0).standard_normal(L.n_rows)
    x_ref = solve_csr_seq(L, b)
    sched = schedule_for_csr(L, lv, chunk=chunk, max_deps=max_deps,
                             dtype=np.float32)
    x = solve(sched, b, engine=engine)
    scale = np.maximum(1.0, np.abs(x_ref).max())
    assert np.abs(x - x_ref).max() / scale < rtol
    return sched


@pytest.mark.parametrize("chunk,max_deps", [(8, 2), (32, 4), (128, 8)])
def test_schedule_shapes_and_solve(chunk, max_deps):
    L = generators.random_lower(300, avg_offdiag=2.0, seed=4, max_back=30)
    sched = _solve_and_check(L, chunk, max_deps)
    assert sched.chunk == chunk and sched.max_deps == max_deps


def test_row_splitting_wide_rows():
    """Rows wider than max_deps split into carry-chained segments."""
    L = generators.banded(60, 12, seed=1)      # rows with 12 deps
    sched = _solve_and_check(L, chunk=16, max_deps=4)
    assert sched.n_carry > 0                   # splitting happened


def test_unrolled_engine_matches():
    L = generators.random_lower(150, avg_offdiag=2.0, seed=6, max_back=12)
    _solve_and_check(L, 32, 4, engine=resolve_engine("unrolled"))


def test_multi_rhs():
    L = generators.random_lower(120, avg_offdiag=2.0, seed=8, max_back=12)
    lv = build_levels(L)
    sched = schedule_for_csr(L, lv, chunk=32, max_deps=4, dtype=np.float32)
    B = np.random.default_rng(1).standard_normal((120, 5))
    ds = to_device(sched)
    X = np.asarray(solve_scan(ds, jnp.asarray(B, jnp.float32)))
    for j in range(5):
        x_ref = solve_csr_seq(L, B[:, j])
        assert np.abs(X[:, j] - x_ref).max() < 2e-4


def test_transformed_schedule_fewer_steps():
    L = generators.lung2_like(scale=0.1)
    lv = build_levels(L)
    s0 = schedule_for_csr(L, lv, chunk=64, max_deps=4)
    ts = transform(L, AvgLevelCost(), validate=False, codegen=False)
    s1 = schedule_for_transformed(ts, chunk=64, max_deps=4)
    assert s1.num_steps < s0.num_steps
    assert s1.num_levels < s0.num_levels
    # end-to-end solve through the transformed schedule
    b = np.random.default_rng(2).standard_normal(L.n_rows)
    c = ts.preamble(b)
    x = solve(s1, c)
    x_ref = solve_csr_seq(L, b)
    scale = np.maximum(1.0, np.abs(x_ref).max())
    assert np.abs(x - x_ref).max() / scale < 2e-4


@given(st.integers(20, 150), st.integers(0, 10**5),
       st.sampled_from([(8, 2), (16, 4), (64, 8)]))
@settings(max_examples=15, deadline=None)
def test_engine_property(n, seed, cm):
    chunk, max_deps = cm
    L = generators.random_lower(n, avg_offdiag=2.0, seed=seed, max_back=10)
    _solve_and_check(L, chunk, max_deps, rtol=5e-4)


def test_schedule_flop_accounting():
    L = generators.random_lower(100, avg_offdiag=2.0, seed=3)
    lv = build_levels(L)
    sched = schedule_for_csr(L, lv, chunk=16, max_deps=4)
    assert sched.flops() <= sched.padded_flops()
    assert sched.memory_bytes() > 0


def test_preamble_as_schedule():
    """The T-factor preamble solved through the SAME level-scheduled engine
    (and the Pallas kernel) matches the host preamble."""
    from repro.core import AvgLevelCost, transform
    from repro.kernels import ops
    from repro.solver import schedule_for_preamble
    L = generators.lung2_like(scale=0.05)
    ts = transform(L, AvgLevelCost(), validate=False, codegen=False)
    b = np.random.default_rng(3).standard_normal(L.n_rows)
    c_ref = ts.preamble(b)
    psched, src, row_pos = schedule_for_preamble(ts, chunk=64, max_deps=8)
    assert psched is not None
    c_ent = solve(psched, b[src].astype(np.float32))
    np.testing.assert_allclose(c_ent[row_pos], c_ref, rtol=2e-4, atol=2e-4)
    # through the pallas kernel too
    c_pal = ops.sptrsv_solve(psched, b[src].astype(np.float32))
    np.testing.assert_allclose(c_pal[row_pos], c_ref, rtol=2e-4, atol=2e-4)

    # full end-to-end: preamble schedule + main schedule
    from repro.solver import schedule_for_transformed, solve_csr_seq
    s1 = schedule_for_transformed(ts, chunk=64, max_deps=8)
    x = solve(s1, c_ent[row_pos])
    x_ref = solve_csr_seq(L, b)
    scale = max(1.0, np.abs(x_ref).max())
    assert np.abs(x - x_ref).max() / scale < 5e-4

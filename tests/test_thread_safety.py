"""Concurrency regression suite for the serving-facing shared state.

The serving tier (src/repro/serving/) calls `from_csr`, `solve`, and the
engine/tuner memos from worker threads, so the facade's process-wide
structures — the bounded in-memory operator cache + pattern index, the
OperatorStats record, the sharded lowering memo, the pair-decision memo
— must survive concurrent hammering without corruption.  These tests
shrink the bounds (tiny `_memory_cache_max`) and hammer from a thread
pool; before the locks landed, the OrderedDict eviction loop and the
read-modify-write stats fields lost updates or blew up under exactly
this load.
"""
import collections
import concurrent.futures
import threading

import numpy as np
import pytest

from repro.solver import TriangularOperator
from repro.solver.reference import solve_csr_seq
from repro.sparse import generators


def _matrices(k=6, n=80):
    return [generators.random_lower(n, avg_offdiag=2.5, seed=100 + i)
            for i in range(k)]


@pytest.fixture(autouse=True)
def _fresh_memory_cache():
    TriangularOperator.clear_memory_cache()
    yield
    TriangularOperator.clear_memory_cache()


def test_from_csr_hammer_with_tiny_lru(tmp_path, monkeypatch):
    """12 threads x 6 matrices through a 3-slot memory LRU: constant
    eviction + pattern-index churn, every solve still correct."""
    monkeypatch.setattr(TriangularOperator, "_memory_cache_max", 3)
    mats = _matrices()
    refs = [solve_csr_seq(L, np.ones(L.n_rows)) for L in mats]
    errors = []

    def worker(tid: int) -> None:
        rng = np.random.default_rng(tid)
        for _ in range(8):
            i = int(rng.integers(len(mats)))
            try:
                op = TriangularOperator.from_csr(
                    mats[i], tune="no_rewriting", cache=True,
                    cache_dir=tmp_path)
                x = op.solve(np.ones(mats[i].n_rows), max_refine=2)
                err = float(np.max(np.abs(np.asarray(x) - refs[i])))
                if err > 1e-6:
                    errors.append(f"thread {tid}: matrix {i} err {err:.2e}")
            except Exception as exc:    # noqa: BLE001 - collect everything
                errors.append(f"thread {tid}: {type(exc).__name__}: {exc}")

    with concurrent.futures.ThreadPoolExecutor(max_workers=12) as pool:
        list(pool.map(worker, range(12)))
    assert errors == []
    # the LRU respected its bound through the churn
    assert len(TriangularOperator._memory_cache) <= 3


def test_concurrent_clear_during_from_csr_is_safe(tmp_path):
    """clear_memory_cache racing builders: no KeyError from the pattern
    index pointing at an evicted entry, results stay correct."""
    mats = _matrices(k=3)
    stop = threading.Event()
    errors = []

    def clearer() -> None:
        while not stop.is_set():
            TriangularOperator.clear_memory_cache()

    def builder(tid: int) -> None:
        for i in range(12):
            L = mats[(tid + i) % len(mats)]
            try:
                op = TriangularOperator.from_csr(
                    L, tune="no_rewriting", cache=True, cache_dir=tmp_path)
                op.solve(np.ones(L.n_rows), max_refine=0)
            except Exception as exc:    # noqa: BLE001
                errors.append(f"thread {tid}: {type(exc).__name__}: {exc}")

    t = threading.Thread(target=clearer)
    t.start()
    try:
        with concurrent.futures.ThreadPoolExecutor(max_workers=6) as pool:
            list(pool.map(builder, range(6)))
    finally:
        stop.set()
        t.join()
    assert errors == []


def test_operator_stats_counters_exact_under_thread_pool():
    """T x K concurrent solves on ONE operator: every counter lands
    exactly (atomic per-event commit), nothing is lost to interleaving."""
    L = generators.random_lower(120, avg_offdiag=2.5, seed=0)
    op = TriangularOperator.from_csr(L, tune="no_rewriting", cache=False)
    b = np.ones(L.n_rows)
    op.solve(b, max_refine=0)                   # prime compiled fns
    base = op.stats.to_dict()
    T, K = 8, 10

    def worker(_tid: int) -> None:
        for _ in range(K):
            op.solve(b, max_refine=0)

    with concurrent.futures.ThreadPoolExecutor(max_workers=T) as pool:
        list(pool.map(worker, range(T)))
    snap = op.stats.to_dict()
    assert snap["solves"] - base["solves"] == T * K
    assert snap["rhs_columns"] - base["rhs_columns"] == T * K
    assert snap["total_solve_ms"] > base["total_solve_ms"]
    assert snap["last_solve_ms"] > 0


def test_stats_record_methods_are_atomic_without_solves():
    """The record_* surface itself, hammered directly: per-event atomicity
    means paired fields never drift apart."""
    from repro.solver import OperatorStats
    stats = OperatorStats()
    T, K = 16, 200

    def worker(_tid: int) -> None:
        for _ in range(K):
            stats.record_solve(ms=0.5, columns=2, rounds=1, residual=1e-12)
            stats.record_fallback("scan->scan")
            stats.record_value_update(ms=0.1, cache_source="pattern")

    with concurrent.futures.ThreadPoolExecutor(max_workers=T) as pool:
        list(pool.map(worker, range(T)))
    assert stats.solves == T * K
    assert stats.rhs_columns == 2 * T * K
    assert stats.refine_rounds == T * K
    assert stats.total_solve_ms == pytest.approx(0.5 * T * K)
    assert stats.fallbacks == T * K
    assert stats.value_updates == T * K
    d = stats.to_dict()
    assert "_lock" not in d and d["solves"] == T * K


def test_pair_decision_memo_concurrent_access():
    """The Preconditioner pair-decision LRU under concurrent factorize
    calls: one decision per pattern, no corruption (the memo dedupes
    concurrent builders' results; tuning itself runs unlocked)."""
    from repro.precond import Preconditioner

    A = generators.poisson2d_spd(8, 8)
    Preconditioner.clear_pair_decisions()
    errors = []

    def worker(tid: int) -> None:
        try:
            M = Preconditioner.ic0(A, tune="auto", cache=False)
            y = M.apply(np.ones(A.n_rows))
            if not np.all(np.isfinite(np.asarray(y))):
                errors.append(f"thread {tid}: non-finite apply")
        except Exception as exc:    # noqa: BLE001
            errors.append(f"thread {tid}: {type(exc).__name__}: {exc}")

    with concurrent.futures.ThreadPoolExecutor(max_workers=6) as pool:
        list(pool.map(worker, range(6)))
    assert errors == []
    assert len(Preconditioner._pair_decisions) == 1    # one pattern, one slot


def test_metrics_registry_hammer_exact_totals():
    """PR 9: the MetricsRegistry itself under contention — 16 threads
    hammering the same counter (plain + labeled), gauge, and histogram
    must produce EXACT totals, not approximately-correct ones.  The
    registry is the single backing store for every stats plane, so a
    lost update here silently corrupts serving dashboards."""
    from repro.obs.metrics import MetricsRegistry

    reg = MetricsRegistry(prefix="hammer")
    c = reg.counter("ops", "ops")
    g = reg.gauge("level", "level")
    h = reg.histogram("lat_ms", "latency", reservoir=200_000)
    T, K = 16, 500

    barrier = threading.Barrier(T)

    def worker(tid: int) -> None:
        barrier.wait()          # maximize interleaving
        for i in range(K):
            c.inc()
            c.inc(2, route=f"r{tid % 4}")
            g.add(1.0)
            h.observe(float(i % 7))
            with reg.lock:      # multi-instrument atomic commit
                c.inc(route="atomic")
                h.observe(100.0)

    with concurrent.futures.ThreadPoolExecutor(max_workers=T) as pool:
        list(pool.map(worker, range(T)))

    assert c.value() == T * K
    assert c.value(route="atomic") == T * K
    for r in range(4):
        assert c.value(route=f"r{r}") == 2 * K * (T // 4)
    assert c.total() == T * K + T * K + 2 * T * K
    assert g.value() == float(T * K)
    assert h.count() == 2 * T * K
    expected_sum = T * K * 100.0 + T * sum(i % 7 for i in range(K))
    assert h.sum() == pytest.approx(expected_sum)
    assert len(h.samples()) == 2 * T * K
    # snapshot under load is coherent too
    snap = reg.snapshot()
    assert snap["ops"]["series"][""] == T * K


def test_disabled_tracer_overhead_on_cached_solve():
    """PR 9 acceptance: with tracing DISABLED (the default), the no-op
    span machinery on the solve path must cost <=5% of a cached lung2
    solve.  Measured directly: per-call cost of the no-op `span()` /
    `event()` path x a generous per-solve call budget, against the
    median time of a warm repeat solve."""
    import time

    from repro import obs
    from repro.obs.trace import NULL_SPAN

    obs.disable()
    assert not obs.enabled()

    L = generators.lung2_like(scale=0.03)
    op = TriangularOperator.from_csr(L, tune="no_rewriting", cache=False)
    b = np.ones(L.n_rows)
    op.solve(b, max_refine=0)                   # compile/warm

    def med(fn, reps=7):
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            ts.append(time.perf_counter() - t0)
        return sorted(ts)[len(ts) // 2]

    solve_s = med(lambda: np.asarray(op.solve(b, max_refine=0)))

    N = 10_000
    def noop_spans():
        for _ in range(N):
            with obs.span("solver.hot", n=1) as sp:
                sp.set(k=2)
                obs.event("hot.event", i=3)

    per_call_s = med(noop_spans) / N
    assert obs.span("x") is NULL_SPAN           # really the no-op path
    # a solve crosses at most a handful of spans; 50 is a generous bound
    overhead = 50 * per_call_s
    assert overhead <= 0.05 * solve_s, (
        f"no-op tracing would cost {overhead * 1e6:.1f}us against a "
        f"{solve_s * 1e3:.2f}ms cached solve (> 5%)")

"""Graph transformation: numerical equivalence (property-based), strategy
invariants, Table-I metric behaviour."""
import numpy as np
import pytest
from _optional_deps import given, settings, st

from repro.core import (AvgLevelCost, ConstrainedAvgLevelCost, GraphView,
                        ManualEveryK, NoRewrite, transform)
from repro.solver.reference import solve_csr_seq, solve_dense, \
    solve_transformed_seq
from repro.sparse import build_levels, generators


STRATS = [NoRewrite(), AvgLevelCost(), ManualEveryK(5), ManualEveryK(10),
          ConstrainedAvgLevelCost(alpha=6, beta=16, coef_cap=1e4)]


@pytest.mark.parametrize("strategy", STRATS, ids=lambda s: s.name)
@pytest.mark.parametrize("gen,kw", [
    (generators.chain, dict(n=60)),
    (generators.random_lower, dict(n=300, avg_offdiag=2.0, seed=7,
                                   max_back=25)),
    (generators.poisson2d_ic0, dict(nx=10, ny=10)),
])
def test_solution_preserved(strategy, gen, kw):
    L = gen(**kw)
    ts = transform(L, strategy, validate=True, codegen=False)
    b = np.random.default_rng(5).standard_normal(L.n_rows)
    x0 = solve_csr_seq(L, b)
    x1 = solve_transformed_seq(ts, b)
    np.testing.assert_allclose(x1, x0, rtol=1e-9, atol=1e-9)


@given(st.integers(10, 150), st.floats(1.0, 3.5), st.integers(0, 10**6),
       st.sampled_from(["avg", "manual", "constrained"]))
@settings(max_examples=25, deadline=None)
def test_equivalence_property(n, avg_deg, seed, sname):
    """Any strategy on any random DAG preserves the solution (exact
    rearranged substitution is pure algebra)."""
    L = generators.random_lower(n, avg_offdiag=avg_deg, seed=seed,
                                max_back=20)
    strat = {"avg": AvgLevelCost(), "manual": ManualEveryK(4),
             "constrained": ConstrainedAvgLevelCost()}[sname]
    ts = transform(L, strat, validate=False, codegen=False)
    rng = np.random.default_rng(seed ^ 0xABCDEF)
    for _ in range(2):
        b = rng.standard_normal(n)
        x0 = solve_dense(L, b)
        x1 = solve_transformed_seq(ts, b)
        scale = np.maximum(1.0, np.abs(x0).max())
        assert np.abs(x0 - x1).max() / scale < 1e-8


@given(st.integers(30, 200), st.integers(0, 10**6))
@settings(max_examples=20, deadline=None)
def test_avglevelcost_invariants(n, seed):
    L = generators.random_lower(n, avg_offdiag=1.5, seed=seed, max_back=8)
    view = GraphView(L)
    ts = transform(L, AvgLevelCost(), validate=False, codegen=False)
    m = ts.metrics
    # never increases level count; recomputed never exceeds assigned
    assert m.num_levels_after <= m.num_levels_before
    assert m.num_levels_recomputed <= m.num_levels_after
    # avgLevelCost is a hard cap for levels that were targets: every level's
    # cost after <= max(avg, original fat-level cost)
    lc_after = np.zeros(m.num_levels_after, dtype=np.int64)
    deps = ts.A.row_nnz()
    np.add.at(lc_after, ts.level_of_assigned, 2 * deps + 1)
    fat_max = view.level_cost.max()
    assert lc_after.max() <= max(np.ceil(view.avg_level_cost), fat_max)


def test_empty_levels_deleted():
    L = generators.chain(40)
    ts = transform(L, ManualEveryK(4), validate=True, codegen=False)
    used = np.unique(ts.level_of_assigned)
    np.testing.assert_array_equal(used, np.arange(used.size))


def test_rewrite_distance_and_skips_constrained():
    L = generators.chain(100)
    ts = transform(L, ConstrainedAvgLevelCost(alpha=2, beta=5, coef_cap=1e3),
                   validate=True, codegen=False)
    assert ts.metrics.max_rewrite_distance <= 5


def test_manual_grouping_respects_runs():
    """Manual strategy must not group thin levels across fat gaps."""
    import numpy as np
    sizes = np.array([50] + [2] * 12 + [50] + [2] * 12)
    L = generators.from_level_profile(
        sizes, lambda rng, lvl, k: np.ones(k, np.int64),
        lambda rng, lvl, k: np.ones(k, np.int64), seed=1)
    ts = transform(L, ManualEveryK(10), validate=True, codegen=False)
    assert ts.metrics.max_rewrite_distance <= 9


def test_metrics_total_cost_flat_for_chains():
    """Chain rewrites keep in-degree <= 1: total paper cost must not grow
    (lung2 behaviour in Table I)."""
    L = generators.lung2_like(scale=0.2)
    ts = transform(L, AvgLevelCost(), validate=False, codegen=False)
    m = ts.metrics
    assert m.total_level_cost_after <= m.total_level_cost_before * 1.01
    assert m.num_levels_after < m.num_levels_before * 0.3


def test_codegen_bytes_and_source():
    from repro.core import generate_c_source
    L = generators.random_lower(50, avg_offdiag=2.0, seed=2)
    ts = transform(L, AvgLevelCost(), validate=True, codegen=True)
    assert ts.metrics.code_bytes_after > 0
    lv = ts.levelsets(assigned=True)
    src = generate_c_source(ts.A, None, ts.diag, ts.level_of_assigned,
                            max_rows=20)
    assert "void calculate0" in src and "x[" in src


def test_preamble_identity_for_norewrite():
    L = generators.random_lower(80, avg_offdiag=2.0, seed=9)
    ts = transform(L, NoRewrite(), validate=True, codegen=False)
    assert ts.identity_preamble
    b = np.random.default_rng(0).standard_normal(80)
    np.testing.assert_allclose(ts.preamble(b), b)


def test_materialize_b_matches_tfactor():
    L = generators.random_lower(120, avg_offdiag=2.0, seed=11, max_back=15)
    ts = transform(L, AvgLevelCost(), validate=True, codegen=False,
                   materialize_b=True)
    b = np.random.default_rng(1).standard_normal(120)
    c_t = ts.preamble(b)
    c_b = ts.B.matvec(b)
    np.testing.assert_allclose(c_b, c_t, rtol=1e-10, atol=1e-12)


def test_critical_path_strategy():
    """Beyond-paper critical-path strategy shrinks DAG depth with minimal
    rewrites and preserves the solution."""
    from repro.core import transform
    from repro.core.strategies import CriticalPathRewrite
    L = generators.chain(64)
    ts = transform(L, CriticalPathRewrite(beta=8), validate=True,
                   codegen=False)
    m = ts.metrics
    assert m.num_levels_after <= (m.num_levels_before + 7) // 8 + 1
    L2 = generators.random_lower(200, avg_offdiag=2.0, seed=5, max_back=12)
    ts2 = transform(L2, CriticalPathRewrite(beta=4, alpha=16),
                    validate=True, codegen=False)
    assert ts2.metrics.num_levels_after <= ts2.metrics.num_levels_before

"""Benchmark drivers are import-checked and executed at reduced scale in
tier-1 (ISSUE 1 satellite: `benchmarks/run.py --smoke` wired to a pytest
marker)."""
import json

import pytest


@pytest.mark.bench
def test_run_smoke_emits_bench_schedule(tmp_path):
    from benchmarks import run as brun

    out = tmp_path / "BENCH_schedule.json"
    rec = brun.smoke(out_path=str(out))
    assert out.exists()
    data = json.loads(out.read_text())
    assert data == rec
    assert set(data["matrices"]) == {"lung2_like@0.08", "torso2_like@0.06"}
    for m in data["matrices"].values():
        assert m["after"]["build_ms"] > 0
        assert m["after"]["steps"] <= m["before"]["steps"]
        assert m["after"]["padded_flops"] < m["before"]["padded_flops"]
        assert m["legacy_build_ms"] > m["after"]["build_ms"]
        assert m["after"]["real_flops"] == m["before"]["real_flops"]


@pytest.mark.bench
def test_operator_bench_emits_table(tmp_path):
    """BENCH_operator.json: tuner-vs-fixed table with the never-slower-than-
    worst guarantee (ISSUE 2 acceptance criterion)."""
    from benchmarks import operator_bench as ob

    out = tmp_path / "BENCH_operator.json"
    rec = ob.run(out_path=str(out), scales=(0.03, 0.03), iters=2,
                 measure_top_k=0)
    assert out.exists()
    assert json.loads(out.read_text()) == rec
    for m in rec["matrices"].values():
        assert len(m["fixed"]) == 4
        assert m["tuner"]["pick"]
        assert m["tuner"]["report"]["candidates"]
        assert m["worst_fixed_us"] >= m["best_fixed_us"] > 0
        assert m["tuner"]["measured_us"] > 0
        # no wall-clock comparisons at this tiny smoke scale — single-
        # digit-ms timings flake on shared runners; the tuner guarantee
        # is held to the strict flag on the committed full-scale artifact
        # (test below)


@pytest.mark.bench
def test_committed_operator_artifact_guarantee():
    """The committed experiments/BENCH_operator.json upholds the ISSUE 2
    acceptance criterion: the tuner's pick is never slower than the worst
    fixed strategy on either analogue."""
    from pathlib import Path

    src = Path("experiments/BENCH_operator.json")
    assert src.exists(), "run benchmarks.operator_bench to regenerate"
    data = json.loads(src.read_text())
    assert set(data["matrices"]) >= {
        f"lung2_like@{data['config']['scales'][0]}",
        f"torso2_like@{data['config']['scales'][1]}"}
    for m in data["matrices"].values():
        assert m["tuner_not_slower_than_worst"]
        assert m["tuner"]["measured_us"] <= m["worst_fixed_us"]


@pytest.mark.bench
def test_iterative_bench_emits_table(tmp_path):
    """BENCH_iterative.json: end-to-end PCG comparison (ISSUE 4 satellite).
    Correctness fields are asserted at smoke scale; wall-clock comparisons
    are held to the committed full-scale artifact (test below)."""
    from benchmarks import iterative_bench as ib

    out = tmp_path / "BENCH_iterative.json"
    rec = ib.run(out_path=str(out), scales=(0.02, 0.02), iters=1,
                 maxiter=200, measure_top_k=0)
    assert out.exists()
    assert json.loads(out.read_text()) == rec
    for m in rec["matrices"].values():
        assert m["pcg_fewer_iters_than_cg"]
        for variant in ("no_rewriting", "tuned"):
            v = m[variant]
            assert v["converged"]
            assert v["residual"] < 1e-4      # tol * ||b|| at these scales
            assert v["iterations"] < m["unpreconditioned"]["iterations"]
            assert v["steps_fwd"] > 0 and v["steps_bwd"] > 0
            assert v["solve_ms"] > 0


@pytest.mark.bench
def test_committed_iterative_artifact_guarantee():
    """The committed experiments/BENCH_iterative.json upholds the ISSUE 4
    acceptance criterion: tuned-schedule PCG is not slower than
    no_rewriting PCG, and PCG beats unpreconditioned CG on iterations,
    on both analogues."""
    from pathlib import Path

    src = Path("experiments/BENCH_iterative.json")
    assert src.exists(), "run benchmarks.iterative_bench to regenerate"
    data = json.loads(src.read_text())
    assert set(data["matrices"]) == {
        f"lung2_like@{data['config']['scales'][0]}",
        f"torso2_like@{data['config']['scales'][1]}"}
    for m in data["matrices"].values():
        assert m["tuned_not_slower"]
        assert m["pcg_fewer_iters_than_cg"]
        assert m["tuned"]["converged"] and m["no_rewriting"]["converged"]


@pytest.mark.bench
def test_refactor_bench_emits_table(tmp_path):
    """BENCH_refactor.json: value-update vs full-rebuild per-step table
    (ISSUE 7 tentpole).  Correctness fields assert at smoke scale;
    wall-clock guarantees are held to the committed full-scale artifact
    (test below)."""
    from benchmarks import refactor_bench as rb

    out = tmp_path / "BENCH_refactor.json"
    rec = rb.run(out_path=str(out), scales=(0.03, 0.03), steps=2, iters=1)
    assert out.exists()
    assert json.loads(out.read_text()) == rec
    for m in rec["matrices"].values():
        assert m["exact_match_fresh"]
        assert m["value_updates"] == m["steps"] == 2
        assert m["update_ms"] > 0 and m["rebuild_ms"] > 0
        assert m["solve_us"] > 0
        assert m["strategy"]


@pytest.mark.bench
def test_run_smoke_has_refactor_section(tmp_path):
    """--smoke carries a refactor_smoke section (wired in benchmarks.run)."""
    from benchmarks import refactor_bench as rb
    from benchmarks import run as brun
    import inspect

    # the section is produced by the same driver smoke() calls; assert the
    # wiring without re-running the whole aggregator (covered above)
    assert "refactor_smoke" in inspect.getsource(brun.smoke)
    rec = rb.run(out_path=None, scales=(0.03, 0.03), steps=1, iters=1)
    assert set(rec["matrices"]) == {"lung2_like@0.03", "torso2_like@0.03"}


@pytest.mark.bench
def test_committed_refactor_artifact_guarantee():
    """The committed experiments/BENCH_refactor.json upholds the ISSUE 7
    acceptance criterion on both analogues: the amortized per-step cost of
    the update fast path is <= the full re-tuned rebuild cost, the updated
    operator matches a fresh build bitwise, and the amortized step cost
    sits far closer to pure solve cost than the rebuild regime."""
    from pathlib import Path

    src = Path("experiments/BENCH_refactor.json")
    assert src.exists(), "run benchmarks.refactor_bench to regenerate"
    data = json.loads(src.read_text())
    assert set(data["matrices"]) == {
        f"lung2_like@{data['config']['scales'][0]}",
        f"torso2_like@{data['config']['scales'][1]}"}
    for m in data["matrices"].values():
        assert m["update_not_slower_than_rebuild"]
        assert m["amortized_update_le_rebuild"]
        assert m["exact_match_fresh"]
        assert m["amortized_update_step_ms"] <= m["amortized_rebuild_step_ms"]
        assert m["update_step_over_solve"] <= m["rebuild_step_over_solve"]


@pytest.mark.bench
def test_run_smoke_has_distributed_section(tmp_path):
    """--smoke carries a distributed_smoke section: sharded solves checked
    on the available devices, with the barrier invariant intact."""
    from benchmarks import distributed_bench as db

    rec = db.smoke_record()
    assert rec["matrices"]
    for m in rec["matrices"].values():
        assert m["all_gathers"]["no_rewriting"] == m["steps"]["no_rewriting"]
        assert m["all_gathers"]["transformed"] == m["steps"]["transformed"]
        assert m["steps"]["transformed"] <= m["steps"]["no_rewriting"]
        for p in m["curve"]:
            assert p["err_no_rewriting"] < 1e-3
            assert p["err_transformed"] < 1e-3
        # no wall-clock assertions at smoke scale (see operator smoke
        # note above); timing guarantees live on the committed artifact


@pytest.mark.bench
def test_committed_distributed_artifact_guarantee():
    """The committed experiments/BENCH_distributed.json upholds the ISSUE 5
    acceptance criteria: the all_gather count equals the step count for
    every schedule, and the transformed schedule's sharded solve is not
    slower than the untransformed one on at least one analogue."""
    from pathlib import Path

    src = Path("experiments/BENCH_distributed.json")
    assert src.exists(), "run benchmarks.distributed_bench to regenerate"
    data = json.loads(src.read_text())
    assert set(data["matrices"]) == {
        f"lung2_like@{data['config']['scales'][0]}",
        f"torso2_like@{data['config']['scales'][1]}"}
    for m in data["matrices"].values():
        for variant in ("no_rewriting", "transformed"):
            assert m["all_gathers"][variant] == m["steps"][variant]
        assert m["steps"]["transformed"] <= m["steps"]["no_rewriting"]
        assert {p["devices"] for p in m["curve"]} == {1, 2, 4, 8}
        for p in m["curve"]:
            assert p["err_no_rewriting"] < 1e-3
            assert p["err_transformed"] < 1e-3
    assert data["transformed_not_slower_any"]
    assert any(m["transformed_not_slower"]
               for m in data["matrices"].values())


@pytest.mark.bench
def test_serving_bench_emits_table(tmp_path):
    """BENCH_serving.json: load sweep + cold-start anatomy (PR 8
    tentpole).  Structure and liveness assert at smoke scale; throughput
    and latency guarantees are held to the committed full-scale artifact
    (test below) — never wall-clock at smoke scale."""
    from benchmarks import serving_bench as svb

    out = tmp_path / "BENCH_serving.json"
    rec = svb.run(out_path=str(out), scales=(0.03, 0.03), widths=(1, 2),
                  rounds=2)
    assert out.exists()
    assert json.loads(out.read_text()) == rec
    for m in rec["matrices"].values():
        assert m["hot_swap_landed"]
        assert m["cold_start"]["first_response_ms"] > 0
        assert m["cold_start"]["untuned_build_solve_ms"] > 0
        assert m["cold_start"]["tuned_build_ms"] > 0
        assert m["sequential"]["throughput_rps"] > 0
        assert [p["width"] for p in m["load_sweep"]] == [1, 2]
        for p in m["load_sweep"]:
            assert p["requests"] == p["clients"] * 2
            assert p["throughput_rps"] > 0
            assert p["p50_ms"] <= p["p99_ms"]


@pytest.mark.bench
def test_run_smoke_has_serving_section():
    """--smoke carries a serving_smoke section (wired in benchmarks.run)."""
    import inspect

    from benchmarks import run as brun

    assert "serving_smoke" in inspect.getsource(brun.smoke)


@pytest.mark.bench
def test_committed_serving_artifact_guarantee():
    """The committed experiments/BENCH_serving.json upholds the PR 8
    acceptance criteria on both analogues: micro-batched throughput at
    saturation beats the sequential baseline, cold-start first-response
    latency tracks the untuned build (admission never waits for the
    tuner), and the background tune hot-swapped in."""
    from pathlib import Path

    src = Path("experiments/BENCH_serving.json")
    assert src.exists(), "run benchmarks.serving_bench to regenerate"
    data = json.loads(src.read_text())
    assert set(data["matrices"]) == {
        f"lung2_like@{data['config']['scales'][0]}",
        f"torso2_like@{data['config']['scales'][1]}"}
    for m in data["matrices"].values():
        assert m["batched_beats_sequential"]
        assert m["tuning_never_blocked"]
        assert m["hot_swap_landed"]
        sat = m["load_sweep"][-1]
        assert sat["throughput_rps"] > m["sequential"]["throughput_rps"]
        assert m["saturation_speedup_vs_sequential"] > 1.0
        cold = m["cold_start"]
        assert cold["cold_start_le_untuned"]
        assert cold["cold_start_not_tuner_bound"]
        assert cold["first_response_ms"] < cold["tuned_build_ms"]
        # batching actually happened at saturation
        assert sat["mean_batch_width"] > 1.0


@pytest.mark.bench
def test_bench_schedule_fields(tmp_path):
    """BENCH_schedule.json carries the perf-trajectory fields."""
    from benchmarks.run import bench_schedule

    rec = bench_schedule(out_path=str(tmp_path / "b.json"),
                         scales=(0.05, 0.05), reps=1, time_solve=True)
    for m in rec["matrices"].values():
        for side in ("before", "after"):
            for field in ("build_ms", "steps", "levels", "padded_flops",
                          "real_flops", "us_per_solve", "model_tpu_us"):
                assert field in m[side]
        assert "build_speedup_vs_legacy" in m
        assert "padded_flops_reduction" in m
        # ISSUE 10 satellite: each matrix carries a certified block whose
        # quality metrics agree with the 'after' schedule's own accounting
        cert = m["certificate"]
        assert cert["steps"] == m["after"]["steps"]
        assert cert["padded_flops"] == m["after"]["padded_flops"]
        assert cert["flops"] == m["after"]["real_flops"]
        assert 0 < cert["critical_path"] <= cert["steps"]


@pytest.mark.bench
def test_committed_schedule_artifact_certified():
    """The committed experiments/BENCH_schedule.json carries a
    per-matrix `certificate` block (ISSUE 10 satellite) that is
    self-consistent with the benchmarked 'after' schedule."""
    from pathlib import Path

    from repro.analysis.verify import STRUCTURAL_CHECKS, VALUE_CHECKS

    src = Path("experiments/BENCH_schedule.json")
    assert src.exists(), "run benchmarks.run (full) to regenerate"
    data = json.loads(src.read_text())
    assert data["matrices"], "empty schedule artifact"
    for name, m in data["matrices"].items():
        cert = m.get("certificate")
        assert cert is not None, f"{name}: no certificate block"
        assert cert["steps"] == m["after"]["steps"]
        assert cert["padded_flops"] == m["after"]["padded_flops"]
        assert cert["flops"] == m["after"]["real_flops"]
        assert cert["n"] == m["n"]
        assert 0 < cert["critical_path"] <= cert["steps"]
        # every structural + value pass ran when the artifact was written
        assert set(cert["checks"]) >= set(STRUCTURAL_CHECKS) | \
            set(VALUE_CHECKS)


@pytest.mark.bench
def test_bench_summary_matches_committed(tmp_path):
    """PR 9 satellite: `write_bench_summary` distilled from the committed
    BENCH artifacts must reproduce the committed
    experiments/BENCH_summary.json exactly — the summary is a pure
    function of the artifacts, so drift means someone edited one side."""
    from pathlib import Path

    from benchmarks.run import write_bench_summary

    committed = Path("experiments/BENCH_summary.json")
    assert committed.exists(), "run benchmarks.run (full) to regenerate"
    out = tmp_path / "BENCH_summary.json"
    rec = write_bench_summary(out_path=str(out))
    assert rec is not None
    assert json.loads(out.read_text()) == json.loads(committed.read_text())
    # the summary's headline guarantees hold
    assert all(m["batched_beats_sequential"]
               for m in rec["serving"].values())
    assert all(m["padded_flops_reduction"] > 0
               for m in rec["schedule"].values())

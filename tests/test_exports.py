"""Public-surface regression: every repro package's __all__ resolves.

The PR 2 `strategies.py` fix established the contract that `__all__` is
the package's real surface — every listed name importable, no duplicates,
no stale entries.  This test enforces it across ALL repro packages (the
new precond/iterative subsystems included), so export drift fails fast
instead of surfacing as a user-facing AttributeError.
"""
import importlib

import pytest

PACKAGES = [
    "repro.core",
    "repro.core.portfolio",
    "repro.core.strategies",
    "repro.sparse",
    "repro.sparse.csr",
    "repro.sparse.generators",
    "repro.sparse.levels",
    "repro.solver",
    "repro.solver.engines",
    "repro.solver.operator",
    "repro.solver.api",
    "repro.precond",
    "repro.precond.api",
    "repro.precond.factorize",
    "repro.iterative",
    "repro.iterative.krylov",
    "repro.iterative.operators",
    "repro.serving",
    "repro.serving.batcher",
    "repro.serving.registry",
    "repro.serving.service",
    "repro.obs",
    "repro.obs.trace",
    "repro.obs.metrics",
    "repro.obs.profile",
    "repro.obs.export",
]


@pytest.mark.parametrize("modname", PACKAGES)
def test_all_names_resolve(modname):
    mod = importlib.import_module(modname)
    assert hasattr(mod, "__all__"), f"{modname} must declare __all__"
    names = mod.__all__
    assert len(names) == len(set(names)), f"{modname}: duplicate __all__"
    for name in names:
        assert hasattr(mod, name), f"{modname}.__all__ lists missing {name}"


def test_new_subsystem_surfaces():
    """The ISSUE 4 surfaces are exported at package level."""
    import repro.iterative as it
    import repro.precond as pc
    from repro.core import portfolio
    from repro.sparse import generators
    assert {"Preconditioner", "IdentityPreconditioner", "FactorResult",
            "FactorizationBreakdown", "ic0", "ilu0"} <= set(pc.__all__)
    assert {"SolveResult", "cg", "bicgstab", "gmres", "device_matvec",
            "as_matvec", "as_preconditioner"} <= set(it.__all__)
    assert {"poisson2d_spd", "poisson3d_spd", "random_spd",
            "spd_from_lower"} <= set(generators.__all__)
    assert "PairReport" in portfolio.__all__
    import repro.core as core
    assert "PairReport" in core.__all__


def test_serving_subsystem_surfaces():
    """The PR 8 serving tier exports its full surface at package level,
    including the typed admission/tuner failure taxonomy re-exports."""
    import repro.serving as sv
    from repro.core import resilience
    assert {"MicroBatcher", "BatchKey", "SolveRequest", "Batch",
            "OperatorRegistry", "OperatorEntry", "EntryKey",
            "SolveService", "ServiceStats",
            "AdmissionError", "TunerFailureWarning"} <= set(sv.__all__)
    assert {"AdmissionError", "TunerFailureWarning"} <= set(
        resilience.__all__)
    from repro.core import faults
    assert {"fail_tuner", "slow_tuner"} <= set(faults.__all__)


def test_operator_device_surface():
    """device_solve_fn is part of the operator's public behavior (used by
    repro.iterative adapters); guard it against accidental removal."""
    from repro.solver import TriangularOperator
    assert callable(getattr(TriangularOperator, "device_solve_fn"))

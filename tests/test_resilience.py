"""Chaos suite: every injected fault class must recover or raise a typed
error — zero silent wrong answers (ISSUE 6 tentpole).

Each test injects one failure mode through `repro.core.faults` (poisoned
schedule payloads, corrupt cache pickles, failing engine compiles, lost
meshes, breakdown pivots) and asserts the resilience layer's contract:
recovered solves match the scipy oracle bit-for-tolerance, downgrades are
recorded in OperatorStats and warned, and unrecoverable faults raise
NumericalHealthError / EngineFallbackError / FactorizationBreakdown with
actionable detail.  Run standalone via the `chaos` marker:

    pytest -m chaos tests/test_resilience.py
"""
import os
import pickle
import threading

import numpy as np
import pytest

from repro.core import faults
from repro.core.resilience import (CacheQuarantineWarning,
                                   EngineFallbackError,
                                   EngineFallbackWarning, HealthPolicy,
                                   HealthRepairWarning, NumericalHealthError,
                                   RetryPolicy, resolve_health_policy)
from repro.solver import TriangularOperator, sptrsv
from repro.solver.operator import CACHE_VERSION
from repro.sparse import generators

pytestmark = pytest.mark.chaos


@pytest.fixture(autouse=True)
def _fresh_memory_cache():
    TriangularOperator.clear_memory_cache()
    yield
    TriangularOperator.clear_memory_cache()


@pytest.fixture(scope="module")
def L_small():
    return generators.random_lower(120, avg_offdiag=2.5, seed=0, max_back=20)


@pytest.fixture(scope="module")
def b_small(L_small):
    return np.random.default_rng(1).standard_normal(L_small.n_rows)


def _oracle(L, b):
    import scipy.sparse as sp
    from scipy.sparse.linalg import spsolve_triangular
    mat = sp.csr_matrix((np.asarray(L.data, np.float64), L.indices,
                         L.indptr), shape=L.shape)
    return spsolve_triangular(mat, np.asarray(b, np.float64), lower=True)


# -- health policy resolution -------------------------------------------------


def test_policy_resolution_named_and_env(monkeypatch):
    assert resolve_health_policy("off") == HealthPolicy.off()
    assert resolve_health_policy("strict").residual_check
    assert resolve_health_policy("repair").on_nonfinite == "repair"
    p = HealthPolicy(residual_tol=1e-3)
    assert resolve_health_policy(p) is p
    monkeypatch.setenv("REPRO_HEALTH_CHECKS", "fallback")
    assert resolve_health_policy(None).on_nonfinite == "fallback"
    monkeypatch.delenv("REPRO_HEALTH_CHECKS")
    assert resolve_health_policy(None) == HealthPolicy()       # default "on"
    with pytest.raises(ValueError, match="unknown health policy"):
        resolve_health_policy("bogus")
    with pytest.raises(TypeError):
        resolve_health_policy(1.5)
    with pytest.raises(ValueError, match="on_nonfinite"):
        HealthPolicy(on_nonfinite="explode")


# -- input / output health guards ---------------------------------------------


def test_nonfinite_rhs_raises_typed_input_error(L_small, b_small):
    op = TriangularOperator.from_csr(L_small, cache=False)
    bad = np.array(b_small)
    bad[3] = np.nan
    with pytest.raises(NumericalHealthError, match="right-hand side") as ei:
        op.solve(bad)
    assert ei.value.stage == "input"
    bad[3] = np.inf
    with pytest.raises(NumericalHealthError):
        op.solve(bad)
    assert op.stats.solves == 0         # rejected before any device work


def test_poisoned_payload_raises_by_default(L_small, b_small):
    with faults.nan_schedule_payload():
        op = TriangularOperator.from_csr(L_small, cache=False)
        with pytest.raises(NumericalHealthError) as ei:
            op.solve(b_small)
    assert ei.value.stage == "output"
    assert op.stats.last_health_event == "output:raised"


def test_poisoned_payload_fallback_matches_oracle(L_small, b_small):
    x_ref = _oracle(L_small, b_small)
    with faults.nan_schedule_payload():
        op = TriangularOperator.from_csr(L_small, cache=False)
        with pytest.warns(HealthRepairWarning):
            x = op.solve(b_small, health="fallback")
    np.testing.assert_allclose(x, x_ref, rtol=1e-8, atol=1e-10)
    assert op.stats.health_events == 1
    assert op.stats.last_health_event == "output:reference"


def test_poisoned_payload_repair_escalates_to_reference(L_small, b_small):
    # the device pipeline is poisoned, so refinement corrections are NaN
    # too: "repair" must escalate to the host reference, not loop forever
    x_ref = _oracle(L_small, b_small)
    with faults.nan_schedule_payload():
        op = TriangularOperator.from_csr(L_small, cache=False)
        with pytest.warns(HealthRepairWarning):
            x = op.solve(b_small, health="repair")
    np.testing.assert_allclose(x, x_ref, rtol=1e-8, atol=1e-10)
    assert op.stats.last_health_event == "output:reference"


def test_wrong_values_caught_only_by_strict(L_small, b_small):
    """The silent-wrong-answer fault class: finite output, wrong numbers.
    Finiteness checks pass; only the strict residual check catches it."""
    x_ref = _oracle(L_small, b_small)
    with faults.wrong_schedule_values(3.0):
        op = TriangularOperator.from_csr(L_small, cache=False)
        x = op.solve(b_small, max_refine=0)             # default: no check
        assert np.isfinite(x).all()
        assert np.abs(x - x_ref).max() > 1e-3           # silently wrong
        with pytest.raises(NumericalHealthError) as ei:
            op.solve(b_small, max_refine=0, health="strict")
    assert ei.value.stage == "residual"
    assert "residual" in str(ei.value)


def test_strict_passes_on_healthy_solves(L_small, b_small):
    op = TriangularOperator.from_csr(L_small, cache=False)
    x = op.solve(b_small, health="strict")
    np.testing.assert_allclose(x, _oracle(L_small, b_small), rtol=1e-8,
                               atol=1e-10)
    assert op.stats.health_events == 0


# -- engine fallback chains ---------------------------------------------------


def test_engine_compile_failure_downgrades_to_scan(L_small, b_small):
    x_ref = _oracle(L_small, b_small)
    with faults.fail_engine_compile("pallas-interpret") as count:
        op = TriangularOperator.from_csr(L_small, cache=False,
                                         engine="pallas-interpret")
        with pytest.warns(EngineFallbackWarning, match="downgraded"):
            x = op.solve(b_small)
    assert count["failed"] == 1                     # the fault really fired
    np.testing.assert_allclose(x, x_ref, rtol=1e-8, atol=1e-10)
    assert op.stats.fallbacks == 1
    assert op.stats.last_fallback == "pallas-interpret->scan"


def test_downgrade_warns_once_but_counts_every_solve(L_small, b_small):
    import warnings as _w
    with faults.fail_engine_compile("pallas-interpret"):
        op = TriangularOperator.from_csr(L_small, cache=False,
                                         engine="pallas-interpret")
        with pytest.warns(EngineFallbackWarning):
            op.solve(b_small)
        with _w.catch_warnings(record=True) as rec:
            _w.simplefilter("always")
            op.solve(b_small)
        assert not [w for w in rec
                    if issubclass(w.category, EngineFallbackWarning)]
    assert op.stats.fallbacks == 2                  # every event counted


def test_engine_unavailable_downgrades(L_small, b_small):
    with faults.engine_unavailable("pallas-interpret"):
        op = TriangularOperator.from_csr(L_small, cache=False,
                                         engine="pallas-interpret")
        with pytest.warns(EngineFallbackWarning, match="unavailable"):
            x = op.solve(b_small)
    np.testing.assert_allclose(x, _oracle(L_small, b_small), rtol=1e-8,
                               atol=1e-10)
    assert op.stats.last_fallback == "pallas-interpret->scan"


def test_dtype_capability_rejection_downgrades(L_small, b_small):
    """A float64 schedule on the float32-only pallas kernel: the eager
    capability check raises inside compile and the chain serves via scan."""
    with pytest.warns(EngineFallbackWarning):
        op = TriangularOperator.from_csr(L_small, cache=False,
                                         engine="pallas-interpret",
                                         dtype=np.float64)
        x = op.solve(b_small)
    np.testing.assert_allclose(x, _oracle(L_small, b_small), rtol=1e-5,
                               atol=1e-5)
    assert op.stats.last_fallback == "pallas-interpret->scan"


def test_mesh_loss_downgrades_sharded_to_scan(L_small, b_small):
    with faults.lose_mesh():
        op = TriangularOperator.from_csr(L_small, cache=False,
                                         engine="sharded")
        with pytest.warns(EngineFallbackWarning, match="mesh"):
            x = op.solve(b_small)
    np.testing.assert_allclose(x, _oracle(L_small, b_small), rtol=1e-8,
                               atol=1e-10)
    assert op.stats.last_fallback == "sharded->scan"


def test_exhausted_chain_raises_named_attempts(L_small, b_small):
    with faults.fail_engine_compile("scan"):
        op = TriangularOperator.from_csr(L_small, cache=False)
        with pytest.raises(EngineFallbackError) as ei:
            op.solve(b_small)
    assert [name for name, _ in ei.value.attempts] == ["scan"]
    assert "injected compile failure" in str(ei.value)


def test_exhausted_chain_with_fallback_policy_serves_reference(
        L_small, b_small):
    with faults.fail_engine_compile("scan"):
        op = TriangularOperator.from_csr(L_small, cache=False)
        with pytest.warns(HealthRepairWarning, match="host reference"):
            x = op.solve(b_small, health="fallback")
    np.testing.assert_allclose(x, _oracle(L_small, b_small), rtol=1e-8,
                               atol=1e-10)
    assert op.stats.last_health_event == "engine:reference"


# -- hardened disk cache ------------------------------------------------------


def _fake_payload(tag: int) -> dict:
    return {"version": CACHE_VERSION, "tag": tag,
            "blob": np.full(4096, tag, dtype=np.float64)}


def test_concurrent_writers_never_tear_the_artifact(tmp_path):
    """N writer threads race on ONE cache key while a reader loads in a
    loop: every successful load must be a complete payload from some
    writer — never a torn/interleaved pickle — and no tmp files remain."""
    key = "deadbeef" * 4 + "-" + "0" * 16
    stop = threading.Event()
    bad = []

    def writer(tag):
        for _ in range(40):
            TriangularOperator._disk_store(key, _fake_payload(tag), tmp_path)

    def reader():
        while not stop.is_set():
            payload = TriangularOperator._disk_load(key, tmp_path)
            if payload is None:
                continue
            tag = payload["tag"]
            if not (payload["blob"] == tag).all():
                bad.append(payload)     # pragma: no cover - the failure case

    threads = [threading.Thread(target=writer, args=(t,)) for t in range(4)]
    rdr = threading.Thread(target=reader)
    rdr.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    rdr.join()
    assert not bad
    assert not list(tmp_path.glob("*.tmp"))         # all tmps published
    final = TriangularOperator._disk_load(key, tmp_path)
    assert final is not None and (final["blob"] == final["tag"]).all()


@pytest.mark.parametrize("mode", ["garbage", "truncate", "stale"])
def test_corrupt_entries_quarantined_not_deleted(L_small, b_small, tmp_path,
                                                mode):
    kw = dict(tune="no_rewriting", cache_dir=tmp_path)
    TriangularOperator.from_csr(L_small, **kw)
    corrupted = faults.corrupt_cache_entries(tmp_path, mode=mode)
    assert len(corrupted) == 1
    TriangularOperator.clear_memory_cache()
    with pytest.warns(CacheQuarantineWarning):
        op = TriangularOperator.from_csr(L_small, **kw)
    assert op.stats.cache_source == "built"         # rebuilt, no raise
    # the bad bytes are preserved for diagnosis in the .bad/ sibling
    quarantined = list((tmp_path / ".bad").glob("op-*.pkl"))
    assert len(quarantined) == 1
    # and the rebuilt artifact is valid: a clean process disk-hits it
    TriangularOperator.clear_memory_cache()
    op2 = TriangularOperator.from_csr(L_small, **kw)
    assert op2.stats.cache_source == "disk"
    np.testing.assert_allclose(op2.solve(b_small),
                               _oracle(L_small, b_small), rtol=1e-8,
                               atol=1e-10)


# -- declarative retry (RetryPolicy) ------------------------------------------


def test_retry_policy_parameter_ladder():
    p = RetryPolicy(max_attempts=3, scale0=0.5, growth=2.0)
    assert list(p.params()) == [0.0, 0.5, 1.0, 2.0]
    assert list(RetryPolicy(max_attempts=0).params()) == [0.0]


def test_retry_policy_run_semantics():
    calls = []

    def attempt(a):
        calls.append(a)
        if len(calls) < 3:
            raise KeyError("flaky")
        return a * 10

    result, param, attempts = RetryPolicy(
        max_attempts=5, scale0=1.0).run(attempt, retry_on=(KeyError,))
    assert (result, param, attempts) == (20.0, 2.0, 3)
    # exhaustion re-raises the last retry_on error
    with pytest.raises(KeyError):
        RetryPolicy(max_attempts=1).run(
            lambda a: (_ for _ in ()).throw(KeyError("always")),
            retry_on=(KeyError,))
    # foreign exception types propagate immediately (no retry burned)
    seen = []

    def boom(a):
        seen.append(a)
        raise ValueError("not retryable")

    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=5).run(boom, retry_on=(KeyError,))
    assert len(seen) == 1


def test_factorization_breakdown_uses_retry_ladder():
    import scipy.sparse as sp
    from repro.precond.factorize import (FactorizationBreakdown, ic0, ilu0)
    from repro.sparse.csr import CSR
    n = 12
    M = sp.diags(np.linspace(-1.0, 1.0, n)).tocsr()     # indefinite diag
    A = CSR(indptr=M.indptr, indices=M.indices, data=M.data, shape=M.shape)
    with pytest.raises(FactorizationBreakdown):
        ic0(A, check_symmetric=False, max_shift_attempts=0)
    f = ic0(A, check_symmetric=False)
    assert f.attempts > 1 and f.shift > 0.0
    # ilu0 breaks down on ~zero pivots: an explicit zero diagonal entry
    Z = CSR(indptr=np.array([0, 2, 4]), indices=np.array([0, 1, 0, 1]),
            data=np.array([0.0, 1.0, 1.0, 1.0]), shape=(2, 2))
    with pytest.raises(FactorizationBreakdown):
        ilu0(Z, max_shift_attempts=0)
    f2 = ilu0(Z)
    assert f2.attempts > 1 and f2.shift > 0.0


# -- hardened portfolio measurement -------------------------------------------


def test_measure_failure_does_not_kill_tuning(L_small):
    from repro.core.portfolio import StrategyPortfolio
    tuner = StrategyPortfolio(measure_top_k=2, measure_iters=1)
    with faults.fail_engine_compile("scan"):
        rep = tuner.tune(L_small)
    measured = [c for c in rep.candidates if c.measured_us is not None]
    assert len(measured) == 2
    assert all(c.measured_us == float("inf") for c in measured)
    assert all("measure failed" in (c.measure_note or "") for c in measured)
    assert rep.best.sched is not None               # tuning still produced


def test_measure_timeout_records_note(L_small):
    from repro.core.portfolio import StrategyPortfolio
    tuner = StrategyPortfolio(measure_top_k=1, measure_iters=5,
                              measure_timeout_s=0.0)
    rep = tuner.tune(L_small)
    c = rep.candidates[0]
    assert c.measured_us is not None and np.isfinite(c.measured_us)
    assert "timeout" in c.measure_note


# -- krylov breakdown status --------------------------------------------------


def test_krylov_poisoned_preconditioner_reports_breakdown():
    import jax.numpy as jnp
    from repro.iterative.krylov import (STATUS_BREAKDOWN, STATUS_CONVERGED,
                                        bicgstab, cg, gmres, status_labels)
    from repro.sparse import generators as g
    A = g.poisson2d_spd(8, 8)
    b = np.random.default_rng(0).standard_normal(A.n_rows).astype(np.float32)
    for drv in (cg, bicgstab, gmres):
        res = drv(A, b, tol=1e-6)
        assert int(res.status) == STATUS_CONVERGED, drv.__name__
        res = drv(A, b, preconditioner=lambda r: r * jnp.nan, maxiter=15)
        assert int(res.status) == STATUS_BREAKDOWN, drv.__name__
        assert not bool(res.converged), drv.__name__
        # frozen at the last healthy iterate — never a poisoned x
        assert np.isfinite(np.asarray(res.x)).all(), drv.__name__
        assert status_labels(res.status) == "breakdown"


def test_krylov_batched_breakdown_is_per_column():
    from repro.iterative.krylov import (STATUS_BREAKDOWN, STATUS_CONVERGED,
                                        bicgstab, cg, gmres)
    from repro.sparse import generators as g
    A = g.poisson2d_spd(8, 8)
    n = A.n_rows
    good = np.random.default_rng(0).standard_normal(n).astype(np.float32)
    B = np.stack([good, np.full(n, np.nan, np.float32)], axis=1)
    for drv in (cg, bicgstab, gmres):
        res = drv(A, B, tol=1e-6, maxiter=200)
        status = np.asarray(res.status)
        assert status[0] == STATUS_CONVERGED, drv.__name__
        assert status[1] == STATUS_BREAKDOWN, drv.__name__
        assert np.isfinite(np.asarray(res.x)).all(), drv.__name__


# -- facade pass-through ------------------------------------------------------


def test_sptrsv_health_passthrough(L_small, b_small):
    bad = np.array(b_small)
    bad[0] = np.nan
    with pytest.raises(NumericalHealthError):
        sptrsv(L_small, bad, cache=False)
    x_ref = _oracle(L_small, b_small)
    with faults.nan_schedule_payload():
        with pytest.warns(HealthRepairWarning):
            x = sptrsv(L_small, b_small, cache=False, health="fallback")
    np.testing.assert_allclose(x, x_ref, rtol=1e-8, atol=1e-10)


def test_preconditioner_apply_health_passthrough():
    from repro.precond import Preconditioner
    from repro.sparse import generators as g
    A = g.poisson2d_spd(6, 6)
    P = Preconditioner.ic0(A, tune="no_rewriting", cache=False)
    bad = np.full(A.n_rows, np.nan)
    with pytest.raises(NumericalHealthError):
        P.apply(bad)
    r = np.ones(A.n_rows)
    z = P.apply(r, health="strict", max_refine=3)
    assert np.isfinite(z).all()


def test_happy_path_health_overhead_is_negligible(L_small, b_small):
    """Acceptance: health checks cost <= 5% on the happy path.  Timed over
    enough reps to dodge scheduler noise; asserted with slack (2x) so CI
    jitter cannot flake the suite — a real regression (e.g. an extra host
    solve per call) is orders of magnitude above this bar."""
    import time
    op = TriangularOperator.from_csr(L_small, cache=False)
    op.solve(b_small)                   # compile outside the timers

    def best_of(health, reps=5, inner=20):
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            for _ in range(inner):
                op.solve(b_small, health=health)
            best = min(best, time.perf_counter() - t0)
        return best

    off = best_of("off")
    on = best_of("on")
    assert on <= off * 2.0, (on, off)

"""Solve-service suite: correctness, lifecycle, tenancy, and chaos.

Covers the serving tier end to end: deterministic pump-mode batching is
bitwise-faithful to a direct operator solve, the mixed workload (hot
repeats + cold admissions + update_values traffic from several tenant
threads) matches the host oracle with zero drops, the background tuner
hot-swaps atomically (and keeps the LATEST values when updates race the
tune), tenant caps reject with typed AdmissionError, and the chaos
section (pytest -m chaos) proves tuner failures degrade gracefully —
the untuned operator keeps serving, nothing blocks, nothing is poisoned.
"""
import threading
import time
import warnings

import numpy as np
import pytest

from repro.core import faults
from repro.core.resilience import AdmissionError, TunerFailureWarning
from repro.serving import (EntryKey, OperatorRegistry, SolveService)
from repro.serving.server import run_workload, step_values
from repro.solver import TriangularOperator, matrix_fingerprint
from repro.solver.reference import solve_csr_seq
from repro.sparse import generators


@pytest.fixture(scope="module")
def L():
    return generators.lung2_like(scale=0.03)


@pytest.fixture(scope="module")
def L2():
    return generators.torso2_like(scale=0.03)


def _rhs(L, seed=0):
    return np.random.default_rng(seed).standard_normal(L.n_rows)


# -- deterministic pump mode --------------------------------------------------

def test_pump_mode_is_bitwise_faithful_to_direct_batched_solve(L):
    """Three requests coalesce into one (n, 3) solve whose columns match
    an independently built operator's batched solve bitwise."""
    b_cols = [_rhs(L, s) for s in range(3)]
    svc = SolveService(max_width=8, max_linger_s=60.0, auto_dispatch=False,
                       pad_widths=False, tune_mode="off", cache=False)
    try:
        futs = [svc.submit(b, L) for b in b_cols]
        assert not any(f.done() for f in futs)
        assert svc.pump() == 1                  # ONE batch for all three
        xs = [f.result(timeout=0) for f in futs]
    finally:
        svc.close()
    ref_op = TriangularOperator.from_csr(L, tune="no_rewriting", cache=False)
    X = np.asarray(ref_op.solve(np.stack(b_cols, axis=1), max_refine=0))
    for j, x in enumerate(xs):
        np.testing.assert_array_equal(np.asarray(x), X[:, j])
    snap = svc.snapshot()
    assert snap["width_hist"] == {3: 1}
    assert snap["flush_reasons"] == {"drain": 1}
    assert snap["submitted"] == snap["completed"] == 3


def test_width_padding_matches_unpadded_results(L):
    """pad_widths bucketing (3 -> 4 zero-padded columns) changes compile
    shapes only — solved columns agree with the unpadded service."""
    b_cols = [_rhs(L, 10 + s) for s in range(3)]
    out = {}
    for pad in (False, True):
        svc = SolveService(max_width=8, max_linger_s=60.0,
                           auto_dispatch=False, pad_widths=pad,
                           tune_mode="off", cache=False)
        try:
            futs = [svc.submit(b, L) for b in b_cols]
            svc.pump()
            out[pad] = [np.asarray(f.result(0)) for f in futs]
        finally:
            svc.close()
    for a, b in zip(out[False], out[True]):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


def test_value_fingerprints_never_share_a_batch(L):
    """Same pattern + different values queue under different keys and
    solve against their own numerics (the update_values routing)."""
    L_new = step_values(L, 3)
    b = _rhs(L)
    svc = SolveService(max_width=8, max_linger_s=60.0, auto_dispatch=False,
                       tune_mode="off", cache=False)
    try:
        f_old = svc.submit(b, L)
        f_new = svc.submit(b, L_new)
        assert svc.pump() == 2                  # distinct batches
        x_old, x_new = f_old.result(0), f_new.result(0)
    finally:
        svc.close()
    for x, mat in ((x_old, L), (x_new, L_new)):
        ref = solve_csr_seq(mat, b.astype(np.float64))
        err = np.max(np.abs(np.asarray(x, dtype=np.float64) - ref))
        assert err / max(1.0, np.max(np.abs(ref))) < 5e-5
    # one entry, re-bound in place — not two operators
    reg = svc.registry.stats()
    assert reg["admissions"] == 1
    entry_snap = next(iter(reg["entries"].values()))
    assert entry_snap["op"]["value_updates"] >= 1


def test_batch_error_resolves_every_future_and_service_survives(L):
    """A solve blow-up resolves the whole batch's futures with the error;
    subsequent requests still serve."""
    b = _rhs(L)
    svc = SolveService(max_width=8, max_linger_s=60.0, auto_dispatch=False,
                       tune_mode="off", cache=False,
                       solve_kwargs={"max_refine": 0, "engine": "bogus"})
    try:
        fut = svc.submit(b, L)
        svc.pump()
        with pytest.raises(Exception):
            fut.result(0)
        assert svc.snapshot()["failed"] == 1
        svc.solve_kwargs = {"max_refine": 0}    # heal; service still alive
        f = svc.submit(b, L)
        svc.pump()
        f.result(0)
        assert svc.snapshot()["completed"] == 1
    finally:
        svc.close()


def test_wrong_shape_rhs_rejected_at_submit(L):
    svc = SolveService(auto_dispatch=False, tune_mode="off", cache=False)
    try:
        with pytest.raises(ValueError, match="b must be"):
            svc.submit(np.zeros(L.n_rows + 1), L)
        assert svc.inflight() == 0              # the slot was released
    finally:
        svc.close()


# -- tenancy ------------------------------------------------------------------

def test_tenant_cap_rejects_with_typed_error_and_spares_others(L):
    b = _rhs(L)
    svc = SolveService(max_width=64, max_linger_s=60.0, auto_dispatch=False,
                       tenant_cap=2, tune_mode="off", cache=False)
    try:
        svc.submit(b, L, tenant="alice")
        svc.submit(b, L, tenant="alice")
        with pytest.raises(AdmissionError) as ei:
            svc.submit(b, L, tenant="alice")
        assert ei.value.tenant == "alice"
        assert ei.value.depth == 2 and ei.value.limit == 2
        # bob is untouched by alice's burst
        f = svc.submit(b, L, tenant="bob")
        svc.pump()
        f.result(0)
        snap = svc.snapshot()
        assert snap["rejected"] == 1
        assert snap["rejected_by_tenant"] == {"alice": 1}
        assert snap["completed"] == 3
    finally:
        svc.close()


def test_completed_requests_release_tenant_slots(L):
    b = _rhs(L)
    svc = SolveService(max_width=64, max_linger_s=60.0, auto_dispatch=False,
                       tenant_cap=1, tune_mode="off", cache=False)
    try:
        svc.submit(b, L, tenant="t")
        svc.pump()
        svc.submit(b, L, tenant="t")            # slot came back
        svc.pump()
        assert svc.snapshot()["completed"] == 2
    finally:
        svc.close()


# -- registry lifecycle -------------------------------------------------------

def test_cold_warming_hot_lifecycle_and_atomic_swap(L):
    """Background tuning hot-swaps without dropping or corrupting solves."""
    b = _rhs(L)
    svc = SolveService(max_width=4, max_linger_s=0.001, workers=2,
                       tune_mode="background", cache=False)
    try:
        xs = [svc.submit(b, L).result(60) for _ in range(3)]
        assert svc.wait_warm(timeout=300)
        xs.append(svc.submit(b, L).result(60))      # post-swap solve
        reg = svc.registry.stats()
        assert reg["hot_swaps"] == 1
        assert dict(reg["states"]) == {"hot": 1}
        entry_snap = next(iter(reg["entries"].values()))
        assert entry_snap["tune_error"] == ""
    finally:
        svc.close()
    ref = solve_csr_seq(L, b.astype(np.float64))
    for x in xs:
        err = np.max(np.abs(np.asarray(x, dtype=np.float64) - ref))
        assert err / max(1.0, np.max(np.abs(ref))) < 5e-5


def test_hot_swap_keeps_latest_values_when_updates_race_the_tune(L):
    """Values updated while the tuner runs: the swapped-in tuned operator
    must serve the NEW values, not the admission-time ones."""
    b = _rhs(L)
    L_new = step_values(L, 5)
    with faults.slow_tuner(delay_s=0.4) as count:
        svc = SolveService(max_width=4, max_linger_s=0.001, workers=2,
                           tune_mode="background", cache=False)
        try:
            svc.submit(b, L).result(60)             # cold admission
            assert svc.registry.stats()["states"].get("warming") == 1
            x_new = svc.submit(b, L_new).result(60)  # update while warming
            assert svc.wait_warm(timeout=300)
            x_post = svc.submit(b, L_new).result(60)  # served post-swap
            reg = svc.registry.stats()
        finally:
            svc.close()
    assert count["calls"] == 1
    assert reg["hot_swaps"] == 1
    ref_new = solve_csr_seq(L_new, b.astype(np.float64))
    for x in (x_new, x_post):
        err = np.max(np.abs(np.asarray(x, dtype=np.float64) - ref_new))
        assert err / max(1.0, np.max(np.abs(ref_new))) < 5e-5


def test_sync_mode_is_hot_immediately(L):
    reg = OperatorRegistry(tune_mode="sync", cache=False)
    try:
        entry, bkey, created = reg.admit(L)
        assert created and entry.state == "hot"
        assert entry.hot_swaps == 0             # tuned from the start
        _, _, again = reg.admit(L)
        assert not again and len(reg) == 1
    finally:
        reg.close()


def test_registry_eviction_bounds_live_entries(L, L2):
    reg = OperatorRegistry(tune_mode="off", cache=False, max_entries=1)
    try:
        reg.admit(L)
        reg.admit(L2)
        assert len(reg) == 1 and reg.evictions == 1
        # the surviving entry is the newest admission
        assert reg.get(EntryKey(pattern_fp=matrix_fingerprint(
            L2, include_values=False))) is not None
    finally:
        reg.close()


def test_orientation_is_part_of_the_entry_key(L):
    """lower and transposed sweeps of one pattern are distinct entries."""
    b = _rhs(L)
    svc = SolveService(max_width=8, max_linger_s=60.0, auto_dispatch=False,
                       tune_mode="off", cache=False)
    try:
        f_fwd = svc.submit(b, L)
        f_t = svc.submit(b, L, transpose=True)
        assert svc.pump() == 2
        x_fwd, x_t = f_fwd.result(0), f_t.result(0)
        assert svc.registry.stats()["admissions"] == 2
    finally:
        svc.close()
    # the two sweeps solve different systems (L vs L^T)
    assert not np.allclose(np.asarray(x_fwd), np.asarray(x_t))


# -- mixed workload (the integration acceptance test) -------------------------

@pytest.mark.slow
def test_mixed_workload_matches_oracle_with_zero_drops(L, L2):
    """Concurrent hot solves + cold admissions + update_values traffic
    from three tenant threads: every response matches the float64 host
    oracle at 1e-8 (refined solves), nothing is dropped or rejected, the
    registry shows live pattern entries and at least one atomic hot-swap,
    and the operator-level stats surface the value-update fast path."""
    svc = SolveService(max_width=8, max_linger_s=0.002, workers=2,
                       tenant_cap=64, tune_mode="background", cache=False,
                       solve_kwargs={"max_refine": 6})
    try:
        result = run_workload(svc, [L, L2], requests=48, tenants=3,
                              value_steps=2, seed=0, rel_tol=1e-8)
        assert svc.wait_warm(timeout=600)
    finally:
        svc.close()
    assert result["errors"] == []
    assert result["checked"] == 48
    snap = svc.snapshot()
    assert snap["submitted"] == snap["completed"] == 48
    assert snap["rejected"] == 0 and snap["failed"] == 0
    assert snap["registry"]["hot_swaps"] >= 1
    assert snap["cache_sources"]["registry"] >= 40    # warm path dominates
    reg = svc.registry.stats()
    assert reg["admissions"] == 2                     # one entry per pattern
    # update_values traffic re-bound live operators at dispatch time (the
    # entry-level counter, unlike op.stats, survives the hot-swap)
    assert reg["value_rebinds"] >= 1
    assert sum(snap["width_hist"].values()) == snap["batches"]


# -- chaos: tuner faults (pytest -m chaos) ------------------------------------

@pytest.mark.chaos
def test_fail_tuner_degrades_entry_but_serving_continues(L):
    b = _rhs(L)
    with faults.fail_tuner() as count:
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            svc = SolveService(max_width=4, max_linger_s=0.001, workers=2,
                               tune_mode="background", cache=False)
            try:
                x0 = svc.submit(b, L).result(60)
                assert svc.wait_warm(timeout=300)   # job finished (failed)
                x1 = svc.submit(b, L).result(60)    # still serving, untuned
                reg = svc.registry.stats()
            finally:
                svc.close()
    assert count["calls"] == 1
    assert dict(reg["states"]) == {"degraded": 1}
    assert reg["hot_swaps"] == 0
    assert reg["tuner_failures"] == 1
    entry_snap = next(iter(reg["entries"].values()))
    assert "injected tuner failure" in entry_snap["tune_error"]
    assert entry_snap["strategy"] == "no_rewriting"
    assert any(issubclass(w.category, TunerFailureWarning) for w in caught)
    ref = solve_csr_seq(L, b.astype(np.float64))
    for x in (x0, x1):
        err = np.max(np.abs(np.asarray(x, dtype=np.float64) - ref))
        assert err / max(1.0, np.max(np.abs(ref))) < 5e-5


@pytest.mark.chaos
def test_slow_tuner_never_blocks_the_request_path(L):
    """With the tuner stalled, a burst of requests completes while the
    entry is still warming; the swap lands afterwards anyway."""
    b = _rhs(L)
    with faults.slow_tuner(delay_s=0.6):
        svc = SolveService(max_width=4, max_linger_s=0.001, workers=2,
                           tune_mode="background", cache=False)
        try:
            t0 = time.perf_counter()
            xs = [svc.submit(b, L).result(60) for _ in range(4)]
            served_s = time.perf_counter() - t0
            state_during = dict(svc.registry.stats()["states"])
            assert svc.wait_warm(timeout=300)
            reg = svc.registry.stats()
        finally:
            svc.close()
    assert state_during == {"warming": 1}       # burst beat the tuner
    assert reg["hot_swaps"] == 1 and dict(reg["states"]) == {"hot": 1}
    ref = solve_csr_seq(L, b.astype(np.float64))
    for x in xs:
        err = np.max(np.abs(np.asarray(x, dtype=np.float64) - ref))
        assert err / max(1.0, np.max(np.abs(ref))) < 5e-5

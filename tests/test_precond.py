"""Numeric IC(0)/ILU(0) factorization + Preconditioner facade (ISSUE 4).

Value checks run against straightforward dense reference implementations
(triple-loop up-looking sweeps over the sparsity pattern) and against
scipy: on patterns closed under elimination (tridiagonal) ILU(0) equals
the COMPLETE natural-ordering LU, so `scipy.sparse.linalg.splu` is an
exact oracle; `scipy.sparse.linalg.spilu` applies SuperLU's own dropping
even at drop_tol=0, so it serves as a preconditioner-quality comparison
rather than a value oracle.
"""
import numpy as np
import pytest

from repro.precond import (FactorizationBreakdown, IdentityPreconditioner,
                           Preconditioner, ic0, ilu0)
from repro.sparse import generators
from repro.sparse.csr import CSR, from_coo


# -- dense references ---------------------------------------------------------

def dense_ic0(A: np.ndarray) -> np.ndarray:
    n = A.shape[0]
    pat = A != 0
    L = np.zeros_like(A)
    for i in range(n):
        for j in range(i):
            if not pat[i, j]:
                continue
            s = sum(L[i, k] * L[j, k] for k in range(j)
                    if pat[i, k] and pat[j, k])
            L[i, j] = (A[i, j] - s) / L[j, j]
        L[i, i] = np.sqrt(A[i, i] - sum(L[i, k] ** 2 for k in range(i)
                                        if pat[i, k]))
    return L


def dense_ilu0(A: np.ndarray):
    n = A.shape[0]
    pat = A != 0
    W = A.copy()
    for i in range(n):
        for k in range(i):
            if not pat[i, k]:
                continue
            W[i, k] /= W[k, k]
            for j in range(k + 1, n):
                if pat[i, j] and pat[k, j]:
                    W[i, j] -= W[i, k] * W[k, j]
    return np.tril(W, -1) + np.eye(n), np.triu(W)


def nonsymmetric(n=70, seed=5):
    """Sparse diagonally-dominant matrix with a symmetric pattern but
    nonsymmetric values."""
    rng = np.random.default_rng(seed)
    A = generators.random_spd(n, avg_offdiag=2.5, seed=seed)
    return CSR(indptr=A.indptr, indices=A.indices,
               data=A.data + 0.25 * rng.uniform(-1, 1, A.nnz), shape=A.shape)


# -- ic0 value/pattern checks -------------------------------------------------

@pytest.mark.parametrize("A", [
    generators.poisson2d_spd(6, 5),
    generators.poisson3d_spd(3, 3, 3),
    generators.random_spd(80, seed=3),
    generators.spd_from_lower(generators.lung2_like(0.01)),
])
def test_ic0_matches_dense_reference(A):
    fac = ic0(A)
    assert fac.kind == "ic0" and fac.U is None
    assert fac.shift == 0.0 and fac.attempts == 1
    np.testing.assert_allclose(fac.L.to_dense(), dense_ic0(A.to_dense()),
                               rtol=1e-12, atol=1e-12)


def test_ic0_pattern_is_tril_of_A():
    A = generators.poisson2d_spd(7, 7)
    fac = ic0(A)
    from repro.sparse.csr import tril
    low = tril(A)
    assert np.array_equal(fac.L.indptr, low.indptr)
    assert np.array_equal(fac.L.indices, low.indices)


def test_ic0_no_fill_pattern_equals_cholesky():
    """Tridiagonal pattern: no fill is dropped, IC(0) == exact Cholesky."""
    A = generators.spd_from_lower(generators.banded(50, 1, seed=1))
    fac = ic0(A)
    np.testing.assert_allclose(fac.L.to_dense(),
                               np.linalg.cholesky(A.to_dense()),
                               rtol=1e-12, atol=1e-12)


# -- ilu0 value/pattern checks ------------------------------------------------

def test_ilu0_matches_dense_reference():
    A = nonsymmetric()
    fac = ilu0(A)
    Lref, Uref = dense_ilu0(A.to_dense())
    np.testing.assert_allclose(fac.L.to_dense(), Lref, rtol=1e-12,
                               atol=1e-12)
    np.testing.assert_allclose(fac.U.to_dense(), Uref, rtol=1e-12,
                               atol=1e-12)


def test_ilu0_defining_property_on_pattern():
    """(L U)[i, j] == A[i, j] exactly for every (i, j) in A's pattern."""
    A = nonsymmetric(n=60, seed=9)
    fac = ilu0(A)
    P = fac.L.to_dense() @ fac.U.to_dense()
    D = A.to_dense()
    mask = D != 0
    assert np.abs((P - D)[mask]).max() < 1e-12


def test_ilu0_unit_lower_and_upper_shapes():
    A = nonsymmetric(n=40)
    fac = ilu0(A)
    Ld = fac.L.to_dense()
    assert np.allclose(np.diag(Ld), 1.0)
    assert np.allclose(np.triu(Ld, 1), 0.0)
    assert np.allclose(np.tril(fac.U.to_dense(), -1), 0.0)


def test_ilu0_equals_scipy_splu_on_nofill_pattern():
    """Tridiagonal: ILU(0) == complete LU == scipy splu (natural order,
    no pivoting)."""
    sp = pytest.importorskip("scipy.sparse")
    spla = pytest.importorskip("scipy.sparse.linalg")
    A = generators.spd_from_lower(generators.banded(50, 1, seed=1))
    As = sp.csc_matrix(
        sp.csr_matrix((A.data, A.indices, A.indptr), shape=A.shape))
    lu = spla.splu(As, permc_spec="NATURAL", diag_pivot_thresh=0.0,
                   options=dict(Equil=False, RowPerm="NOROWPERM"))
    assert (lu.perm_r == np.arange(A.n_rows)).all()
    fac = ilu0(A)
    np.testing.assert_allclose(fac.L.to_dense(), lu.L.toarray(),
                               rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(fac.U.to_dense(), lu.U.toarray(),
                               rtol=1e-12, atol=1e-12)


def test_ilu0_preconditioner_quality_vs_scipy_spilu():
    """Our ILU(0) cuts GMRES iterations at least as well as SuperLU's
    incomplete LU (spilu keeps MORE information per fill_factor>=1, so it
    bounds the achievable quality from above; ours must land in the same
    regime, far below unpreconditioned)."""
    sp = pytest.importorskip("scipy.sparse")
    spla = pytest.importorskip("scipy.sparse.linalg")
    from repro.iterative import gmres, solve_callback
    A = nonsymmetric(n=120, seed=11)
    b = np.asarray(A.matvec(np.ones(A.n_rows)), dtype=np.float32)
    plain = gmres(A, b, tol=1e-5)
    P = Preconditioner.ilu0(A, tune="no_rewriting", cache=False)
    ours = gmres(A, b, preconditioner=P, tol=1e-5)
    As = sp.csc_matrix(
        sp.csr_matrix((A.data, A.indices, A.indptr), shape=A.shape))
    silu = spla.spilu(As, drop_tol=0.0, fill_factor=1.0,
                      permc_spec="NATURAL", diag_pivot_thresh=0.0)
    scipy_p = gmres(A, b, preconditioner=solve_callback(silu.solve),
                    tol=1e-5)
    assert bool(ours.converged) and bool(scipy_p.converged)
    assert int(ours.iterations) < int(plain.iterations)
    assert int(ours.iterations) <= 2 * int(scipy_p.iterations)


# -- rejection / breakdown paths ---------------------------------------------

def indefinite_spd_shaped():
    """Symmetric, positive diagonal, but indefinite: ic0 breaks down."""
    C = np.array([[1.0, 2.0, 0.0], [2.0, 1.0, 2.0], [0.0, 2.0, 1.0]])
    r, c = np.nonzero(C)
    return from_coo(r, c, C[r, c], (3, 3))


def test_ic0_rejects_nonsymmetric_values():
    with pytest.raises(ValueError, match="symmetric"):
        ic0(nonsymmetric())


def test_ic0_rejects_triangular_input():
    with pytest.raises(ValueError, match="FULL matrix"):
        ic0(generators.poisson2d_ic0(5, 5))


def test_ic0_rejects_nonpositive_diagonal():
    D = np.diag([1.0, -2.0, 3.0])
    r, c = np.nonzero(D)
    with pytest.raises(ValueError, match="cannot be SPD"):
        ic0(from_coo(r, c, D[r, c], (3, 3)))


def test_ic0_rejects_nonsquare():
    m = from_coo([0, 1], [0, 1], [1.0, 1.0], (2, 3))
    with pytest.raises(ValueError, match="square"):
        ic0(m, check_symmetric=False)


def test_missing_diagonal_raises():
    m = from_coo([0, 1, 1], [0, 0, 0], [1.0, 1.0, 0.0], (2, 2),
                 sum_duplicates=True)     # row 1 has no diagonal entry
    with pytest.raises(ValueError, match="diagonal"):
        ilu0(m)


def test_ic0_breakdown_shifts_then_succeeds():
    fac = ic0(indefinite_spd_shaped())
    assert fac.shift > 0 and fac.attempts > 1
    assert np.isfinite(fac.L.data).all()
    assert (fac.L.diagonal_fast() > 0).all()


def test_ic0_breakdown_raises_when_shifting_disabled():
    with pytest.raises(FactorizationBreakdown, match="pivot"):
        ic0(indefinite_spd_shaped(), max_shift_attempts=0)


def test_ilu0_breakdown_shifts_and_raises():
    E = np.array([[1e-20, 1.0], [1.0, 1e-20]])
    r, c = np.nonzero(E)
    Ec = from_coo(r, c, E[r, c], (2, 2))
    fac = ilu0(Ec)
    assert fac.shift > 0
    with pytest.raises(FactorizationBreakdown, match="pivot"):
        ilu0(Ec, max_shift_attempts=0)


def test_shifted_factor_still_factors_shifted_matrix():
    """After a shift, L L^T must match IC(0) of the SHIFTED matrix (the
    shift is a property of the factorization, not silent data loss)."""
    A = indefinite_spd_shaped()
    fac = ic0(A)
    D = A.to_dense()
    D[np.arange(3), np.arange(3)] += fac.shift * np.abs(np.diag(D))
    np.testing.assert_allclose(fac.L.to_dense(), dense_ic0(D),
                               rtol=1e-12, atol=1e-12)


# -- Preconditioner facade ----------------------------------------------------

@pytest.fixture()
def spd():
    return generators.poisson2d_spd(10, 9)


def test_facade_ic0_apply_matches_dense(spd):
    P = Preconditioner.ic0(spd, tune="no_rewriting", cache=False)
    L = P.factors.L.to_dense()
    rng = np.random.default_rng(0)
    r = rng.standard_normal(spd.n_rows)
    np.testing.assert_allclose(P(r), np.linalg.solve(L @ L.T, r),
                               rtol=1e-4, atol=1e-5)
    R = rng.standard_normal((spd.n_rows, 3))
    np.testing.assert_allclose(P(R), np.linalg.solve(L @ L.T, R),
                               rtol=1e-4, atol=1e-5)


def test_facade_ilu0_apply_matches_dense():
    A = nonsymmetric(n=50)
    P = Preconditioner.ilu0(A, tune="no_rewriting", cache=False)
    M = P.factors.L.to_dense() @ P.factors.U.to_dense()
    r = np.random.default_rng(1).standard_normal(A.n_rows)
    np.testing.assert_allclose(P(r), np.linalg.solve(M, r),
                               rtol=1e-4, atol=1e-5)


def test_facade_operator_pair_orientation(spd):
    P = Preconditioner.ic0(spd, tune="no_rewriting", cache=False)
    assert P.forward.side == "lower" and not P.forward.transpose
    assert P.backward.side == "lower" and P.backward.transpose
    A = nonsymmetric(n=40)
    Q = Preconditioner.ilu0(A, tune="no_rewriting", cache=False)
    assert Q.backward.side == "upper" and not Q.backward.transpose


def test_facade_device_apply_matches_host(spd):
    import jax.numpy as jnp
    P = Preconditioner.ic0(spd, tune="avgLevelCost", cache=False)
    r = np.random.default_rng(2).standard_normal(spd.n_rows)
    z_host = P.apply(r)
    z_dev = np.asarray(P(jnp.asarray(r, jnp.float32)))
    np.testing.assert_allclose(z_dev, z_host, rtol=1e-4, atol=1e-4)


def test_facade_host_apply_returns_float64(spd):
    """The facade's host-path contract (module doc): numpy in, float64
    numpy out — even though the underlying refinement-free operator
    solves now run (and return) in the schedule dtype."""
    P = Preconditioner.ic0(spd, tune="no_rewriting", cache=False)
    z = P.apply(np.random.default_rng(7).standard_normal(spd.n_rows))
    assert z.dtype == np.float64
    z2 = P(np.random.default_rng(8).standard_normal(spd.n_rows)
           .astype(np.float32))
    assert z2.dtype == np.float64


def test_facade_jit_apply(spd):
    import jax
    import jax.numpy as jnp
    P = Preconditioner.ic0(spd, tune="no_rewriting", cache=False)
    r = jnp.asarray(np.random.default_rng(3).standard_normal(spd.n_rows),
                    jnp.float32)
    z = jax.jit(lambda v: P(v))(r)
    np.testing.assert_allclose(np.asarray(z), P.apply(np.asarray(r)),
                               rtol=1e-4, atol=1e-4)


def test_pair_decision_memoized(spd):
    Preconditioner.clear_pair_decisions()
    P1 = Preconditioner.ic0(spd, tune="auto", cache=False)
    assert len(Preconditioner._pair_decisions) == 1
    assert P1.report is not None
    assert P1.report.best_label == P1.strategy
    P2 = Preconditioner.ic0(spd, tune="auto", cache=False)
    assert len(Preconditioner._pair_decisions) == 1      # hit, not re-tuned
    assert P2.strategy == P1.strategy
    Preconditioner.clear_pair_decisions()


def test_pair_report_combines_both_sweeps(spd):
    Preconditioner.clear_pair_decisions()
    P = Preconditioner.ic0(spd, tune="auto", cache=False)
    rep = P.report
    labels = {c["label"] for c in rep.combined}
    assert rep.best_label in labels
    for c in rep.combined:
        assert c["total_us"] == pytest.approx(c["fwd_us"] + c["bwd_us"],
                                              abs=0.2)
    # ranked: the pick has the smallest total among same-scored entries
    first = rep.combined[0]
    same = [c for c in rep.combined if c["measured"] == first["measured"]]
    assert first["total_us"] == min(c["total_us"] for c in same)
    assert "fwd" in rep.to_dict() and "bwd" in rep.to_dict()
    assert rep.table()
    Preconditioner.clear_pair_decisions()


def test_stats_surface(spd):
    P = Preconditioner.ic0(spd, tune="no_rewriting", cache=False)
    P.apply(np.ones(spd.n_rows))
    st = P.stats()
    assert st["kind"] == "ic0" and st["shift"] == 0.0
    assert st["forward"]["solves"] == 1 and st["backward"]["solves"] == 1


def test_identity_preconditioner():
    I = IdentityPreconditioner()
    r = np.arange(4.0)
    np.testing.assert_array_equal(I(r), r)
    assert I.stats()["kind"] == "identity"

"""jit-native Krylov drivers (ISSUE 4): convergence, batching, jit, history.

The acceptance-criterion test (`test_cg_acceptance_end_to_end`) runs the
full pipeline: ic0-derived, portfolio-tuned preconditioner; absolute
residual <= 1e-8 on a poisson2d_spd system; fewer iterations than
unpreconditioned CG; and the same solve under jax.jit for single and
batched right-hand sides.  Float64 iterations run inside a scoped
`jax.experimental.enable_x64()` — possible with no global config flip
because the device-native preconditioner path has no host callbacks.
"""
import numpy as np
import pytest

from repro.iterative import (SolveResult, as_matvec, as_preconditioner,
                             bicgstab, cg, device_matvec, gmres)
from repro.precond import Preconditioner
from repro.sparse import generators
from repro.sparse.csr import CSR


def nonsymmetric(n=120, seed=7):
    rng = np.random.default_rng(seed)
    A = generators.random_spd(n, avg_offdiag=2.5, seed=seed)
    return CSR(indptr=A.indptr, indices=A.indices,
               data=A.data + 0.25 * rng.uniform(-1, 1, A.nnz), shape=A.shape)


# -- adapters -----------------------------------------------------------------

def test_device_matvec_matches_csr():
    import jax.numpy as jnp
    A = nonsymmetric(n=60)
    mv = device_matvec(A)
    rng = np.random.default_rng(0)
    x = rng.standard_normal(A.n_rows)
    np.testing.assert_allclose(np.asarray(mv(jnp.asarray(x, jnp.float32))),
                               A.matvec(x), rtol=1e-5, atol=1e-4)
    X = rng.standard_normal((A.n_rows, 3))
    np.testing.assert_allclose(np.asarray(mv(jnp.asarray(X, jnp.float32))),
                               A.matvec(X), rtol=1e-5, atol=1e-4)


def test_as_matvec_passthrough_and_reject():
    fn = as_matvec(lambda v: v)
    assert fn(3) == 3
    with pytest.raises(TypeError, match="CSR matrix or a callable"):
        as_matvec(42)


def test_as_preconditioner_adapters():
    import jax.numpy as jnp
    ident = as_preconditioner(None)
    assert ident(5) == 5
    fn = as_preconditioner(lambda r: 2 * r)
    assert fn(3) == 6
    with pytest.raises(TypeError, match="ambiguous"):
        as_preconditioner(generators.poisson2d_spd(3, 3))
    with pytest.raises(TypeError, match="preconditioner"):
        as_preconditioner(object())
    # a TriangularOperator resolves to its device pipeline
    from repro.solver import TriangularOperator
    L = generators.poisson2d_ic0(5, 5)
    op = TriangularOperator.from_csr(L, tune="no_rewriting", cache=False)
    apply = as_preconditioner(op)
    b = np.random.default_rng(0).standard_normal(L.n_rows)
    z = np.asarray(apply(jnp.asarray(b, jnp.float32)))
    np.testing.assert_allclose(z, op.solve(b), rtol=1e-4, atol=1e-4)


# -- cg -----------------------------------------------------------------------

def test_cg_matches_direct_float32():
    import jax.numpy as jnp
    A = generators.poisson2d_spd(9, 8)
    xt = np.random.default_rng(0).standard_normal(A.n_rows)
    b = jnp.asarray(A.matvec(xt), jnp.float32)
    res = cg(A, b, tol=1e-6)
    assert bool(res.converged)
    np.testing.assert_allclose(np.asarray(res.x), xt, rtol=1e-3, atol=1e-3)


def test_cg_acceptance_end_to_end():
    """ISSUE 4 acceptance: tuned ic0-PCG to ||r|| <= 1e-8, fewer iterations
    than plain CG, jit-compatible for single and batched RHS."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    A = generators.poisson2d_spd(16, 16)
    rng = np.random.default_rng(0)
    xt = rng.standard_normal(A.n_rows)
    b_host = A.matvec(xt)
    Preconditioner.clear_pair_decisions()
    P = Preconditioner.ic0(A, tune="auto", cache=False)
    assert P.report is not None            # the portfolio actually ran
    with enable_x64():
        b = jnp.asarray(b_host)
        plain = cg(A, b, tol=0.0, atol=1e-8, maxiter=800)
        tuned = cg(A, b, preconditioner=P, tol=0.0, atol=1e-8, maxiter=800)
        assert bool(plain.converged) and bool(tuned.converged)
        assert float(tuned.final_residual()) <= 1e-8
        # the residual recorded in the history is the TRUE one
        r_true = b_host - A.matvec(np.asarray(tuned.x))
        assert np.linalg.norm(r_true) <= 2e-8
        assert int(tuned.iterations) < int(plain.iterations)
        # under jit: single and batched RHS
        jit_cg = jax.jit(lambda bb: cg(A, bb, preconditioner=P, tol=0.0,
                                       atol=1e-8, maxiter=800))
        rj = jit_cg(b)
        assert bool(rj.converged) and float(rj.final_residual()) <= 1e-8
        B = jnp.asarray(rng.standard_normal((A.n_rows, 4)))
        jit_cg_b = jax.jit(lambda bb: cg(A, bb, preconditioner=P, tol=0.0,
                                         atol=1e-8, maxiter=800))
        rb = jit_cg_b(B)
        assert bool(rb.converged.all())
        xr = np.linalg.solve(A.to_dense(), np.asarray(B))
        np.testing.assert_allclose(np.asarray(rb.x), xr, rtol=1e-5,
                                   atol=1e-6)


def test_cg_batched_columns_match_single():
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    A = generators.poisson2d_spd(8, 8)
    rng = np.random.default_rng(1)
    B_host = rng.standard_normal((A.n_rows, 3))
    with enable_x64():
        B = jnp.asarray(B_host)
        resb = cg(A, B, tol=1e-10)
        for k in range(3):
            rk = cg(A, B[:, k], tol=1e-10)
            np.testing.assert_allclose(np.asarray(resb.x[:, k]),
                                       np.asarray(rk.x), rtol=1e-7,
                                       atol=1e-8)
        assert resb.iterations.shape == (3,)
        assert resb.converged.shape == (3,)


def test_residual_history_contract():
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    A = generators.poisson2d_spd(8, 7)
    b_host = np.random.default_rng(2).standard_normal(A.n_rows)
    with enable_x64():
        b = jnp.asarray(b_host)
        res = cg(A, b, tol=1e-10, maxiter=300)
        h = np.asarray(res.residual_norms)
        it = int(res.iterations)
        assert h.shape == (301,)
        assert h[0] == pytest.approx(np.linalg.norm(b_host), rel=1e-12)
        assert np.isfinite(h[:it + 1]).all()
        assert np.isnan(h[it + 1:]).all()
        assert h[it] < h[0]
        assert float(res.final_residual()) == pytest.approx(h[it])


def test_maxiter_cap_reports_not_converged():
    import jax.numpy as jnp
    A = generators.poisson2d_spd(10, 10)
    b = jnp.asarray(np.ones(A.n_rows), jnp.float32)
    res = cg(A, b, tol=1e-12, maxiter=3)
    assert not bool(res.converged)
    assert int(res.iterations) == 3


def test_cg_x0_warm_start():
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    A = generators.poisson2d_spd(8, 8)
    xt = np.random.default_rng(3).standard_normal(A.n_rows)
    with enable_x64():
        b = jnp.asarray(A.matvec(xt))
        cold = cg(A, b, tol=1e-10)
        warm = cg(A, b, x0=jnp.asarray(xt + 1e-6), tol=1e-10)
        assert int(warm.iterations) < int(cold.iterations)


def test_cg_rejects_bad_shape():
    with pytest.raises(ValueError, match=r"\(n,\) or \(n, k\)"):
        cg(generators.poisson2d_spd(3, 3), np.ones((3, 3, 3)))


# -- bicgstab / gmres ---------------------------------------------------------

def test_bicgstab_nonsymmetric_with_ilu0():
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    A = nonsymmetric()
    xt = np.random.default_rng(4).standard_normal(A.n_rows)
    P = Preconditioner.ilu0(A, tune="no_rewriting", cache=False)
    with enable_x64():
        b = jnp.asarray(A.matvec(xt))
        plain = bicgstab(A, b, tol=1e-10)
        pre = bicgstab(A, b, preconditioner=P, tol=1e-10)
        assert bool(plain.converged) and bool(pre.converged)
        assert int(pre.iterations) < int(plain.iterations)
        np.testing.assert_allclose(np.asarray(pre.x), xt, rtol=1e-6,
                                   atol=1e-7)


def test_gmres_nonsymmetric_with_ilu0():
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    A = nonsymmetric()
    xt = np.random.default_rng(5).standard_normal(A.n_rows)
    P = Preconditioner.ilu0(A, tune="no_rewriting", cache=False)
    with enable_x64():
        b = jnp.asarray(A.matvec(xt))
        plain = gmres(A, b, tol=1e-10)
        pre = gmres(A, b, preconditioner=P, tol=1e-10)
        assert bool(plain.converged) and bool(pre.converged)
        assert int(pre.iterations) < int(plain.iterations)
        np.testing.assert_allclose(np.asarray(pre.x), xt, rtol=1e-6,
                                   atol=1e-7)


def test_gmres_restart_still_converges():
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    A = nonsymmetric(n=80, seed=9)
    xt = np.random.default_rng(6).standard_normal(A.n_rows)
    with enable_x64():
        b = jnp.asarray(A.matvec(xt))
        res = gmres(A, b, tol=1e-9, restart=8, maxiter=40)
        assert bool(res.converged)
        np.testing.assert_allclose(np.asarray(res.x), xt, rtol=1e-5,
                                   atol=1e-6)
        # a restart cycle caps the per-cycle iteration count
        assert int(res.iterations) > 8      # needed more than one cycle


def test_gmres_bicgstab_jit_batched():
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    A = nonsymmetric(n=90, seed=10)
    P = Preconditioner.ilu0(A, tune="no_rewriting", cache=False)
    rng = np.random.default_rng(7)
    with enable_x64():
        B = jnp.asarray(rng.standard_normal((A.n_rows, 3)))
        xr = np.linalg.solve(A.to_dense(), np.asarray(B))
        for drv in (bicgstab, gmres):
            rb = jax.jit(lambda bb: drv(A, bb, preconditioner=P,
                                        tol=1e-9))(B)
            assert bool(rb.converged.all()), drv.__name__
            np.testing.assert_allclose(np.asarray(rb.x), xr, rtol=1e-5,
                                       atol=1e-6)


# -- SolveResult --------------------------------------------------------------

def test_solve_result_is_pytree():
    import jax
    res = SolveResult(x=np.ones(3), converged=np.bool_(True),
                      iterations=np.int32(2),
                      residual_norms=np.ones(4))
    leaves = jax.tree_util.tree_leaves(res)
    assert len(leaves) == 4     # stats=None contributes no leaf
    rebuilt = jax.tree_util.tree_map(lambda x: x, res)
    assert isinstance(rebuilt, SolveResult)


def test_stats_attached_outside_jit_only():
    import jax
    import jax.numpy as jnp
    A = generators.poisson2d_spd(6, 6)
    P = Preconditioner.ic0(A, tune="no_rewriting", cache=False)
    b = jnp.asarray(np.ones(A.n_rows), jnp.float32)
    host = cg(A, b, preconditioner=P, tol=1e-5)
    assert host.stats is not None and host.stats["kind"] == "ic0"
    jitted = jax.jit(lambda bb: cg(A, bb, preconditioner=P,
                                   tol=1e-5))(b)
    assert jitted.stats is None

"""HLO analyzer: exactness on hand-built programs (loop-corrected FLOPs,
collective bytes, sharded per-chip totals)."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.hlo_analysis import analyze_hlo

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=13)
        return y.sum()

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    res = analyze_hlo(jax.jit(f).lower(x, w).compile().as_text())
    expect = 2 * 128 * 256 * 256 * 13

    mesh = jax.make_mesh((8,), ("d",))
    c2 = jax.jit(f, in_shardings=(
        NamedSharding(mesh, P("d", None)),
        NamedSharding(mesh, P(None, "d")))).lower(x, w).compile()
    res2 = analyze_hlo(c2.as_text())
    print(json.dumps({
        "flops": res["flops"], "expect": expect,
        "sharded_flops": res2["flops"], "expect_shard": expect / 8,
        "coll_counts": res2["collective_counts"],
        "coll_bytes": res2["collective_bytes"],
    }))
""")


def test_hlo_analyzer_exact_on_scan_matmul():
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},
        cwd=Path(__file__).parent.parent, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["flops"] == res["expect"]
    assert res["sharded_flops"] == res["expect_shard"]
    assert res["coll_counts"].get("all-gather", 0) >= 1
    assert res["coll_bytes"] > 0

"""Strategy-portfolio auto-tuner: naming contract, cost-model ranking
determinism, measured-mode agreement (ISSUE 2 tentpole)."""
import numpy as np
import pytest

from repro.core import (AvgLevelCost, ConstrainedAvgLevelCost,
                        CriticalPathRewrite, ManualEveryK, NoRewrite,
                        StrategyPortfolio, TuningCostModel,
                        default_candidates, make_strategy, strategy_label,
                        transform)
from repro.sparse import generators


@pytest.fixture(scope="module")
def lung_small():
    return generators.lung2_like(scale=0.03)


# -- naming contract (ISSUE satellite: stable names + __all__) ----------------

def test_stable_names_and_labels():
    assert NoRewrite.name == "no_rewriting"
    assert AvgLevelCost.name == "avgLevelCost"
    assert ManualEveryK.name == "manual_every_k"
    assert ConstrainedAvgLevelCost.name == "constrained_avg"
    assert CriticalPathRewrite.name == "critical_path"
    # instance labels: stable name + canonical parameter suffix
    assert strategy_label(NoRewrite()) == "no_rewriting"
    assert strategy_label(ManualEveryK(k=7)) == "manual_every_k(k=7,gap=1)"
    assert strategy_label(CriticalPathRewrite(beta=4)) == \
        "critical_path(beta=4,alpha=32,rounds=10000)"
    s = ConstrainedAvgLevelCost(alpha=4, beta=32, coef_cap=None)
    assert s.name == "constrained_avg"
    assert strategy_label(s) == "constrained_avg(a=4,b=32,c=none,dyn=0)"
    # label.split("(")[0] always recovers the stable name (CSV consumers)
    for strat in default_candidates():
        assert strategy_label(strat).split("(")[0] == strat.name


def test_critical_path_exported():
    import repro.core.strategies as S
    assert "CriticalPathRewrite" in S.__all__
    from repro.core import CriticalPathRewrite as CP
    assert CP is S.CriticalPathRewrite


def test_metrics_strategy_carries_label():
    L = generators.random_lower(80, avg_offdiag=2.0, seed=0, max_back=10)
    ts = transform(L, ManualEveryK(k=5), validate=False, codegen=False)
    assert ts.metrics.strategy == "manual_every_k(k=5,gap=1)"


def test_make_strategy():
    assert isinstance(make_strategy("no_rewriting"), NoRewrite)
    assert isinstance(make_strategy("avgLevelCost"), AvgLevelCost)
    s = ManualEveryK(k=3)
    assert make_strategy(s) is s
    with pytest.raises(ValueError, match="unknown strategy"):
        make_strategy("bogus")
    with pytest.raises(TypeError):
        make_strategy(42)


# -- cost-model ranking -------------------------------------------------------

def test_ranking_deterministic(lung_small):
    port = StrategyPortfolio(chunk=128, max_deps=8)
    r1 = port.tune(lung_small)
    r2 = StrategyPortfolio(chunk=128, max_deps=8).tune(lung_small)
    assert [c.label for c in r1.candidates] == \
        [c.label for c in r2.candidates]
    assert [c.predicted_us for c in r1.candidates] == \
        [c.predicted_us for c in r2.candidates]
    # ranked ascending by predicted cost
    preds = [c.predicted_us for c in r1.candidates if c.error is None]
    assert preds == sorted(preds)


def test_cost_model_prefers_transform_on_thin_levels(lung_small):
    """lung2's 453 two-row levels are the paper's motivating case: any
    sensible cost model must rank the untransformed baseline last-ish."""
    rep = StrategyPortfolio(chunk=128, max_deps=8).tune(lung_small)
    assert rep.best.label != "no_rewriting"
    by_label = {c.label: c for c in rep.candidates}
    assert by_label["no_rewriting"].predicted_us > rep.best.predicted_us
    # the pick also compiled to fewer steps than the baseline
    assert rep.best.steps < by_label["no_rewriting"].steps


def test_cost_model_breakdown_fields(lung_small):
    rep = StrategyPortfolio(chunk=128, max_deps=8).tune(lung_small)
    for c in rep.candidates:
        if c.error is not None:
            continue
        bd = c.breakdown
        assert set(bd) == {"steps_us", "flops_us", "bytes_us",
                           "preamble_us", "collectives_us", "total_us"}
        assert bd["total_us"] == pytest.approx(
            bd["steps_us"] + bd["flops_us"] + bd["bytes_us"]
            + bd["preamble_us"] + bd["collectives_us"])
        assert bd["collectives_us"] == 0.0      # default: single-device
        assert c.predicted_us == bd["total_us"]
    # nnz_T charge: no_rewriting pays zero preamble
    nr = next(c for c in rep.candidates if c.label == "no_rewriting")
    assert nr.breakdown["preamble_us"] == 0.0 and nr.nnz_T == 0


def test_cost_model_collective_term_ranks_by_steps(lung_small):
    """The sharded preset charges every step its all_gather family
    (latency x step count) — synchronization cost as a first-class tuning
    objective.  A latency high enough to dominate must rank candidates by
    step count, and the charge itself must equal latency x steps."""
    cm = TuningCostModel.sharded(collective_latency_us=1e4)
    assert cm.collective_latency_us == 1e4
    rep = StrategyPortfolio(chunk=128, max_deps=8, cost_model=cm) \
        .tune(lung_small)
    ok = [c for c in rep.candidates if c.error is None]
    for c in ok:
        assert c.breakdown["collectives_us"] == \
            pytest.approx(c.steps * 1e4)
    steps = [c.steps for c in ok]
    assert steps == sorted(steps)       # latency-dominated => rank by steps
    # the transformation wins under a barrier-dominated model: its whole
    # point is fewer synchronization steps
    assert rep.best.steps <= min(
        c.steps for c in ok if c.label == "no_rewriting")


def test_report_serializes(lung_small):
    import json
    rep = StrategyPortfolio(chunk=128, max_deps=8).tune(lung_small)
    d = rep.to_dict()
    json.dumps(d)       # JSON-clean
    assert d["matrix"]["n"] == lung_small.n_rows
    assert d["candidates"][0]["rank"] == 0
    table = rep.table()
    for c in rep.candidates:
        assert c.label in table
    slim = rep.slim()
    assert slim.best.ts is None and slim.best.sched is None
    assert slim.best.label == rep.best.label


def test_failed_candidate_is_reported_not_fatal(lung_small):
    class Exploding:
        name = "exploding"

        def apply(self, store, view):
            raise RuntimeError("boom")

    rep = StrategyPortfolio(candidates=[NoRewrite(), Exploding()],
                            chunk=128, max_deps=8).tune(lung_small)
    assert rep.best.label == "no_rewriting"
    failed = [c for c in rep.candidates if c.error is not None]
    assert len(failed) == 1 and "boom" in failed[0].error
    assert "FAILED" in rep.table()
    import json
    json.dumps(rep.to_dict(), allow_nan=False)      # strict-JSON clean


# -- measured mode ------------------------------------------------------------

@pytest.mark.slow
def test_measured_mode_agrees_with_cost_ordering():
    """On both synthetic analogues, the tuner's pick (model- or
    measurement-ranked) must beat the measured no_rewriting baseline — the
    relaxed 'cost model agrees with measured ordering' contract that stays
    robust to CI timing noise."""
    cands = [NoRewrite(), AvgLevelCost(), ManualEveryK(k=10)]
    for L in (generators.lung2_like(scale=0.03),
              generators.torso2_like(scale=0.03)):
        port = StrategyPortfolio(candidates=cands, chunk=128, max_deps=8,
                                 measure_top_k=3, measure_iters=2)
        rep = port.tune(L)
        measured = {c.label: c.measured_us for c in rep.candidates
                    if c.measured_us is not None}
        assert len(measured) == 3
        assert rep.best.measured_us == min(measured.values())
        # the model-worst candidate on thin-level matrices is the baseline;
        # the pick must not be slower than it (acceptance criterion)
        assert rep.best.measured_us <= measured["no_rewriting"]

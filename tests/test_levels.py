"""Level-set construction: vs networkx longest-path oracle + invariants.

Optional deps (hypothesis, networkx) must not break collection: property
tests skip via the _optional_deps shim, oracle tests via importorskip-style
guards.
"""
import numpy as np
import pytest
from _optional_deps import given, settings, st

try:
    import networkx as nx
except ModuleNotFoundError:             # pragma: no cover - env dependent
    nx = None

from repro.sparse import build_levels, generators, level_costs
from repro.sparse.csr import CSR, from_coo


def _nx_levels(L: CSR) -> np.ndarray:
    g = nx.DiGraph()
    g.add_nodes_from(range(L.n_rows))
    rows = np.repeat(np.arange(L.n_rows), L.row_nnz())
    for r, c in zip(rows, L.indices):
        if c != r:
            g.add_edge(int(c), int(r))
    level = np.zeros(L.n_rows, dtype=np.int64)
    for n in nx.topological_sort(g):
        preds = list(g.predecessors(n))
        if preds:
            level[n] = 1 + max(level[p] for p in preds)
    return level


@pytest.mark.skipif(nx is None, reason="networkx not installed")
@pytest.mark.parametrize("gen,kw", [
    (generators.chain, dict(n=50)),
    (generators.banded, dict(n=80, bandwidth=3)),
    (generators.random_lower, dict(n=200, avg_offdiag=2.5, seed=1)),
    (generators.poisson2d_ic0, dict(nx=12, ny=9)),
])
def test_levels_match_networkx(gen, kw):
    L = gen(**kw)
    ours = build_levels(L).level_of
    ref = _nx_levels(L)
    np.testing.assert_array_equal(ours, ref)


def test_chain_has_n_levels():
    L = generators.chain(64)
    assert build_levels(L).num_levels == 64


def test_banded_level_structure():
    L = generators.banded(30, 2)
    lv = build_levels(L)
    # bandwidth-2 band: level increments by 1 each row after warmup
    assert lv.num_levels == 30


@given(st.integers(2, 120), st.floats(0.5, 4.0), st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_levels_valid_on_random(n, avg, seed):
    L = generators.random_lower(n, avg_offdiag=avg, seed=seed)
    lv = build_levels(L)
    rows = np.repeat(np.arange(n), L.row_nnz())
    strict = L.indices < rows
    # every dependency sits at a strictly smaller level
    assert (lv.level_of[L.indices[strict]] < lv.level_of[rows[strict]]).all()
    # levels are contiguous 0..max
    assert set(np.unique(lv.level_of)) == set(range(lv.num_levels))


def test_level_costs_paper_formula():
    L = generators.random_lower(100, avg_offdiag=2.0, seed=3)
    lv = build_levels(L)
    lc = level_costs(L, lv)
    assert lc.sum() == 2 * L.nnz - L.n_rows


def test_profile_generator_exact():
    sizes = np.array([5, 3, 4, 2, 6])
    m = generators.from_level_profile(
        sizes, lambda rng, lvl, k: np.ones(k, np.int64),
        lambda rng, lvl, k: np.ones(k, np.int64), seed=0)
    lv = build_levels(m)
    np.testing.assert_array_equal(lv.level_sizes(), sizes)


def test_calibrated_analogues():
    L = generators.lung2_like()
    lv = build_levels(L)
    sizes = lv.level_sizes()
    assert L.n_rows == 109_460
    assert lv.num_levels == 479
    assert (sizes == 2).sum() == 453          # 94% two-row levels (paper)
    T = generators.torso2_like(scale=0.25)
    lvt = build_levels(T)
    assert lvt.num_levels == 513


def test_matrixmarket_roundtrip(tmp_path):
    from repro.sparse import io as sio
    m = generators.random_lower(40, avg_offdiag=2.0, seed=1)
    p = tmp_path / "m.mtx"
    sio.write_matrix_market(m, p)
    m2 = sio.read_matrix_market(p)
    np.testing.assert_array_equal(m.indptr, m2.indptr)
    np.testing.assert_array_equal(m.indices, m2.indices)
    np.testing.assert_allclose(m.data, m2.data)


def test_load_named_falls_back_to_analogue():
    from repro.sparse import io as sio
    L = sio.load_named("lung2")
    assert L.n_rows == 109_460

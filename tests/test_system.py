"""End-to-end behaviour of the paper's system: matrix -> transform ->
schedule -> solve (all engines) -> distributed barrier count, plus the
benchmark drivers as smoke checks."""
import numpy as np

from repro.core import AvgLevelCost, ConstrainedAvgLevelCost, NoRewrite, \
    transform
from repro.kernels import ops
from repro.solver import (schedule_for_csr, schedule_for_transformed, solve,
                          solve_csr_seq)
from repro.sparse import build_levels, generators


def test_end_to_end_pipeline():
    """The full paper pipeline on a lung2-like analogue."""
    L = generators.lung2_like(scale=0.08)
    levels = build_levels(L)
    b = np.random.default_rng(0).standard_normal(L.n_rows)
    x_ref = solve_csr_seq(L, b)

    # 1. transformation reduces barriers massively, keeps cost ~flat
    ts = transform(L, AvgLevelCost(), validate=True, codegen=True)
    m = ts.metrics
    assert m.num_levels_after < 0.25 * m.num_levels_before
    assert m.total_level_cost_after <= 1.02 * m.total_level_cost_before
    assert m.code_bytes_after > 0

    # 2. schedules shrink and still solve exactly
    s0 = schedule_for_csr(L, levels, chunk=128, max_deps=4)
    s1 = schedule_for_transformed(ts, chunk=128, max_deps=4)
    assert s1.num_steps < s0.num_steps
    c = ts.preamble(b).astype(np.float32)
    for x in (solve(s0, b), solve(s1, c),
              ops.sptrsv_solve(s1, c, interpret=True)):
        scale = max(1.0, np.abs(x_ref).max())
        assert np.abs(x - x_ref).max() / scale < 5e-4

    # 3. the beyond-paper constrained strategy bounds the rewrite radius
    ts2 = transform(L, ConstrainedAvgLevelCost(alpha=4, beta=8),
                    validate=True, codegen=False)
    assert ts2.metrics.max_rewrite_distance <= 8


def test_benchmark_drivers_smoke(tmp_path, monkeypatch):
    """Table-I + profile drivers run end to end on the analogues."""
    import benchmarks.level_profiles as lp
    import benchmarks.table1 as t1
    from repro.sparse import io as sio

    def reduced(name):
        return (generators.lung2_like(scale=0.05) if name == "lung2"
                else generators.torso2_like(scale=0.05))

    monkeypatch.setattr(sio, "load_named", reduced)
    rows = t1.run(csv_out=str(tmp_path / "t1.csv"))
    assert len(rows) == 7  # header + 2 matrices x 3 strategies
    assert lp.run(csv_dir=str(tmp_path))

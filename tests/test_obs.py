"""Unified observability layer (PR 9): tracing core, metrics registry,
stats-plane views, per-step profiler, exporters, and the end-to-end
serving trace.

Organization mirrors src/repro/obs/:

* tracer semantics under injected fake clocks (exact durations, nesting,
  cross-thread retroactive spans, the off-by-default no-op path);
* metrics instruments + registry (labels, kind mismatch, percentile
  parity with the serving reservoirs);
* `OperatorStats` / `ServiceStats` as views over the registry — the
  snapshot surface must be IDENTICAL to an independently-computed
  expected dict (no dual bookkeeping to drift);
* the fallback counter semantics satellite (`fallbacks` = downgraded
  dispatches, `fallback_downgrades` = unique pairs = warnings);
* per-step profiler exactness, `CostModel.calibrate`, the chaos
  `slow_step` localization test;
* exporters and their validators (including failure detection);
* one traced batched serving request on the lung2 analogue, exported to
  a schema-valid Chrome trace with the queue -> batch -> solve -> engine
  chain (the PR's acceptance trace).
"""
import json
import threading
import types

import numpy as np
import pytest

from repro import obs
from repro.obs.export import (chrome_trace, prometheus_text,
                              validate_chrome_trace,
                              validate_prometheus_text, write_chrome_trace,
                              write_jsonl)
from repro.obs.metrics import (DEFAULT_MS_BUCKETS, MetricsRegistry,
                               nearest_rank_percentile)
from repro.obs.trace import NULL_SPAN, Tracer
from repro.sparse import generators


@pytest.fixture(autouse=True)
def _no_global_tracer():
    """Every test starts and ends with tracing disabled."""
    obs.disable()
    yield
    obs.disable()


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt
        return self.t


# ----------------------------------------------------------------------
# tracing core


def test_span_nesting_and_exact_durations():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    with tr.span("outer", n=3) as outer:
        clk.advance(1.0)
        with tr.span("inner") as inner:
            clk.advance(0.25)
            inner.event("mark", k=1)
            clk.advance(0.25)
        clk.advance(0.5)
    assert outer.duration == pytest.approx(2.0)
    assert inner.duration == pytest.approx(0.5)
    assert inner.parent_id == outer.span_id
    assert outer.parent_id is None
    assert outer.attrs["n"] == 3
    name, t, attrs = inner.events[0]
    assert name == "mark" and t == pytest.approx(1.25) and attrs == {"k": 1}
    assert tr.open_spans() == []
    assert [s.name for s in tr.spans()] == ["inner", "outer"]


def test_span_records_error_attr():
    tr = Tracer(clock=FakeClock())
    with pytest.raises(ValueError):
        with tr.span("boom"):
            raise ValueError("x")
    (sp,) = tr.spans()
    assert sp.attrs["error"] == "ValueError"


def test_record_span_cross_thread_parenting():
    clk = FakeClock(10.0)
    tr = Tracer(clock=clk)
    with tr.span("batch") as bsp:
        sp = tr.record_span("queue", 9.0, 10.0, parent=bsp, tenant="a")
    assert sp.parent_id == bsp.span_id
    assert sp.duration == pytest.approx(1.0)
    assert sp.attrs == {"tenant": "a"}
    # a non-span, non-id parent (NULL_SPAN from a mid-flight enable) is
    # dropped, not stored as an unresolvable object
    orphan = tr.record_span("queue", 0.0, 1.0, parent=NULL_SPAN)
    assert orphan.parent_id is None


def test_event_outside_span_is_orphan():
    tr = Tracer(clock=FakeClock(5.0))
    tr.event("loose", why="no span open")
    (name, t, attrs, tid) = tr.orphan_events()[0]
    assert name == "loose" and t == 5.0
    assert tid == threading.get_ident()


def test_module_helpers_are_noop_when_disabled():
    assert not obs.enabled()
    sp = obs.span("anything", k=1)
    assert sp is NULL_SPAN
    with sp as s:
        s.set(a=1).event("e")          # all no-ops, nothing raised
    obs.event("loose")
    assert obs.record_span("x", 0.0, 1.0) is NULL_SPAN


def test_enable_disable_roundtrip():
    tr = obs.enable(clock=FakeClock())
    assert obs.enabled() and obs.get_tracer() is tr
    with obs.span("s"):
        pass
    assert [s.name for s in tr.spans()] == ["s"]
    assert obs.disable() is tr
    assert not obs.enabled()


def test_per_thread_stacks_do_not_cross():
    tr = Tracer(clock=FakeClock())
    seen = {}

    def worker():
        with tr.span("child-thread") as sp:
            seen["parent"] = sp.parent_id

    with tr.span("main-thread"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    # the other thread's span must NOT inherit this thread's stack
    assert seen["parent"] is None


# ----------------------------------------------------------------------
# metrics registry


def test_counter_gauge_text_histogram_basics():
    reg = MetricsRegistry(prefix="t")
    c = reg.counter("hits", "hits")
    c.inc()
    c.inc(2, route="a")
    assert c.value() == 1 and c.value(route="a") == 2 and c.total() == 3
    g = reg.gauge("depth", "queue depth")
    g.set(4.0)
    g.add(-1.0)
    assert g.value() == 3.0
    t = reg.text("source", "cache source")
    t.set("disk")
    assert t.value() == "disk"
    h = reg.histogram("lat", "latency", bounds=(1.0, 10.0), reservoir=4)
    for v in (0.5, 5.0, 50.0):
        h.observe(v)
    assert h.count() == 3 and h.sum() == pytest.approx(55.5)
    assert h.buckets() == {1.0: 1, 10.0: 1, float("inf"): 1}
    assert h.samples() == [0.5, 5.0, 50.0]


def test_histogram_reservoir_bounds_memory_not_counts():
    reg = MetricsRegistry()
    h = reg.histogram("lat", "l", bounds=(10.0,), reservoir=2)
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    assert h.count() == 4                 # counts keep going
    assert h.samples() == [1.0, 2.0]      # reservoir stops admitting


def test_registry_get_or_create_and_kind_mismatch():
    reg = MetricsRegistry()
    c1 = reg.counter("n", "help")
    assert reg.counter("n") is c1
    with pytest.raises(TypeError):
        reg.gauge("n")
    assert reg.get("missing") is None


def test_percentile_matches_serving_formula():
    from repro.serving.service import _percentile
    rng = np.random.default_rng(0)
    samples = list(rng.standard_normal(37))
    reg = MetricsRegistry()
    h = reg.histogram("x", "x", reservoir=100)
    for v in samples:
        h.observe(float(v))
    for q in (0, 25, 50, 99, 100):
        assert h.percentile(q) == _percentile(samples, q)
        assert nearest_rank_percentile(samples, q) == _percentile(samples, q)
    assert np.isnan(nearest_rank_percentile([], 50))


def test_shared_lock_is_reentrant_and_registry_wide():
    reg = MetricsRegistry()
    c = reg.counter("a", "a")
    with reg.lock:
        with reg.lock:          # RLock: multi-instrument commits can nest
            c.inc()
    assert c.value() == 1


# ----------------------------------------------------------------------
# stats planes as registry views


def test_operator_stats_snapshot_is_exact_view():
    from repro.solver.operator import OperatorStats
    st = OperatorStats(cache_source="disk", tune_ms=12.5)
    st.record_solve(ms=2.0, columns=4, rounds=1, residual=1e-9)
    st.record_solve(ms=3.0, columns=1, rounds=0, residual=2e-9)
    st.record_fallback("pallas->scan", new_pair=True)
    st.record_fallback("pallas->scan", new_pair=False)
    st.record_health_event("solve:nonfinite")
    st.record_value_update(ms=0.7, cache_source="pattern")
    expected = {
        "solves": 2, "rhs_columns": 5, "refine_rounds": 1,
        "total_solve_ms": 5.0, "last_solve_ms": 3.0, "last_residual": 2e-9,
        "cache_source": "pattern", "tune_ms": 12.5, "value_updates": 1,
        "last_update_ms": 0.7, "fallbacks": 2, "fallback_downgrades": 1,
        "last_fallback": "pallas->scan", "health_events": 1,
        "last_health_event": "solve:nonfinite",
    }
    assert st.to_dict() == expected
    # the view IS the registry: the same numbers come out of snapshot()
    reg_snap = st.registry.snapshot()
    assert reg_snap["solves"]["series"][""] == 2
    assert reg_snap["fallbacks"]["series"][""] == 2
    assert reg_snap["fallback_downgrades"]["series"][""] == 1
    # attribute writes (legacy surface) commit through the instruments
    st.solves = 10
    assert st.registry.get("solves").value() == 10


def test_service_stats_snapshot_is_exact_view():
    from repro.serving.service import ServiceStats, _percentile
    st = ServiceStats()
    st.record_submit("built")
    st.record_submit("registry")
    st.record_submit("registry")
    st.record_reject("tenant-b")
    batch = types.SimpleNamespace(width=2, reason="width")
    st.record_batch(batch, [1.0, 3.0], 7.5)
    st.record_batch(types.SimpleNamespace(width=1, reason="linger"),
                    [2.0], 4.5)
    st.record_batch_error(types.SimpleNamespace(width=1, reason="drain"))
    snap = st.snapshot()
    expected = {
        "submitted": 3, "completed": 3, "rejected": 1, "failed": 1,
        "batches": 3, "batch_errors": 1,
        "width_hist": {1: 2, 2: 1},
        "flush_reasons": {"width": 1, "linger": 1, "drain": 1},
        "cache_sources": {"built": 1, "registry": 2},
        "rejected_by_tenant": {"tenant-b": 1},
        "queue_ms": {"p50": _percentile([1.0, 3.0, 2.0], 50),
                     "p99": _percentile([1.0, 3.0, 2.0], 99)},
        "solve_ms": {"p50": _percentile([7.5, 4.5], 50),
                     "p99": _percentile([7.5, 4.5], 99)},
        "mean_width": 4 / 3,
    }
    assert snap == expected
    # legacy attribute surface still reads through the registry
    assert st.submitted == 3 and st.batches == 3
    assert st.width_hist == {1: 2, 2: 1}
    assert st.queue_ms == [1.0, 3.0, 2.0]
    assert st.mean_width() == pytest.approx(4 / 3)


def test_registry_lifecycle_counters_are_metrics_backed():
    from repro.serving import OperatorRegistry
    reg = OperatorRegistry(tune_mode="off", cache=False)
    L = generators.random_lower(60, avg_offdiag=2.0, seed=3)
    try:
        reg.admit(L)
        reg.admit(L)                        # warm re-admission
    finally:
        reg.close()
    assert reg.admissions == 1
    assert reg.metrics.get("admissions").value() == 1
    assert reg.stats()["admissions"] == 1


def test_fallback_attempts_vs_unique_downgrades():
    """Satellite: `fallbacks` counts every downgraded dispatch (can exceed
    solves under refinement), `fallback_downgrades` counts unique
    (requested -> used) pairs and matches the warn-once behavior."""
    import warnings
    from repro.core import faults
    from repro.core.resilience import EngineFallbackWarning
    from repro.solver import TriangularOperator

    L = generators.random_lower(80, avg_offdiag=2.0, seed=1)
    op = TriangularOperator.from_csr(L, tune="no_rewriting", cache=False)
    b = np.ones(L.n_rows)
    with faults.fail_engine_compile("pallas-interpret"):
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            op.solve(b, engine="pallas-interpret", max_refine=0)
            op.solve(b, engine="pallas-interpret", max_refine=0)
    fb = [w for w in rec if issubclass(w.category, EngineFallbackWarning)]
    assert op.stats.fallbacks == 2            # one per downgraded dispatch
    assert op.stats.fallback_downgrades == 1  # one unique pair...
    assert len(fb) == 1                       # ...and exactly one warning
    assert op.stats.last_fallback == "pallas-interpret->scan"


# ----------------------------------------------------------------------
# per-step profiler + calibration


@pytest.fixture(scope="module")
def small_L():
    return generators.random_lower(150, avg_offdiag=2.5, seed=2,
                                   max_back=30)


def test_profile_schedule_is_exact_and_consistent(small_L):
    from repro.obs.profile import profile_schedule
    from repro.core.strategies import NoRewrite
    from repro.core.transform import transform
    from repro.solver.reference import solve_csr_seq
    from repro.solver.schedule import schedule_for_transformed

    ts = transform(small_L, NoRewrite(), validate=False, codegen=False)
    sched = schedule_for_transformed(ts, chunk=64, max_deps=8)
    b = np.random.default_rng(0).standard_normal(small_L.n_rows)
    prof = profile_schedule(sched, ts.preamble(b), reps=2, warmup=1)
    assert prof.engine == "stepwise"
    assert prof.num_steps == sched.num_steps
    assert len(prof.step_ms) == sched.num_steps
    assert np.all(prof.step_ms >= 0)
    assert prof.total_ms() == pytest.approx(float(prof.step_ms.sum()))
    assert 0 < prof.critical_path_share() <= 1.0
    assert 0 < prof.utilization() <= 1.0
    assert int(prof.step_padded_flops.sum()) == sched.padded_flops()
    assert int(prof.step_real_flops.sum()) == sched.flops()
    hist = prof.step_histogram()
    assert sum(hist["counts"]) == sched.num_steps
    assert hist["bounds"] == list(DEFAULT_MS_BUCKETS)
    d = prof.to_dict()
    json.dumps(d)                      # JSON-serializable end to end
    assert d["slowest_steps"] == prof.slowest_steps()


def test_profiling_engine_solves_exactly(small_L):
    from repro.obs.profile import ProfilingEngine
    from repro.solver import TriangularOperator
    from repro.solver.reference import solve_csr_seq

    eng = ProfilingEngine()
    op = TriangularOperator.from_csr(small_L, tune="no_rewriting",
                                     cache=False, engine=eng)
    b = np.random.default_rng(1).standard_normal(small_L.n_rows)
    x = op.solve(b, max_refine=0)
    ref = solve_csr_seq(small_L, b)
    assert float(np.max(np.abs(np.asarray(x, np.float64) - ref))) < 1e-4
    prof = eng.last_profile
    assert prof is not None and prof.num_steps > 0


def test_profile_operator_routes_orientation(small_L):
    from repro.obs.profile import profile_operator
    from repro.solver import TriangularOperator

    op = TriangularOperator.from_csr(small_L, tune="no_rewriting",
                                     cache=False)
    prof = profile_operator(op, reps=1, warmup=0)
    assert prof.num_steps == op._sched.num_steps


def test_cost_model_calibrate_recovers_synthetic_constants():
    from repro.core.portfolio import CostModel
    from repro.obs.profile import ScheduleProfile

    rng = np.random.default_rng(0)
    flops = rng.integers(1000, 5000, size=12).astype(np.int64)
    bytes_ = np.full(12, 4096.0)               # degenerate column
    true_overhead, true_flop_rate = 3.0, 2e-3
    t_us = true_overhead + true_flop_rate * flops
    prof = ScheduleProfile(
        engine="stepwise", num_steps=12, reps=1, step_ms=t_us / 1e3,
        collective_ms=None, step_padded_flops=flops,
        step_real_flops=flops, step_bytes=bytes_, width_buckets=[])
    base = CostModel(us_per_byte=1e-4)
    cm = base.calibrate(prof)
    assert cm.us_per_padded_flop == pytest.approx(true_flop_rate, rel=1e-6)
    # the constant bytes column is excluded; its charge at the EXISTING
    # rate is folded out of the intercept so predict() reproduces the fit
    recon = (cm.step_overhead_us + flops * cm.us_per_padded_flop
             + bytes_ * cm.us_per_byte)
    assert np.allclose(recon, t_us, rtol=1e-6)


def test_cost_model_calibrate_collective_split():
    from repro.core.portfolio import CostModel
    from repro.obs.profile import ScheduleProfile

    flops = np.array([1000, 2000, 3000, 4000], dtype=np.int64)
    coll_ms = np.array([0.004, 0.005, 0.006, 0.005])
    comp_us = 2.0 + 1e-3 * flops
    prof = ScheduleProfile(
        engine="sharded", num_steps=4, reps=1,
        step_ms=comp_us / 1e3 + coll_ms, collective_ms=coll_ms,
        step_padded_flops=flops, step_real_flops=flops,
        step_bytes=np.full(4, 64.0), width_buckets=[])
    cm = CostModel.sharded().calibrate(prof)
    assert cm.collective_latency_us == pytest.approx(5.0)
    assert cm.us_per_padded_flop == pytest.approx(1e-3, rel=1e-6)


def test_calibrate_empty_profile_is_identity():
    from repro.core.portfolio import CostModel
    prof = types.SimpleNamespace(step_ms=np.array([]), collective_ms=None,
                                 step_padded_flops=np.array([]),
                                 step_bytes=np.array([]))
    cm = CostModel()
    assert cm.calibrate(prof) == cm


@pytest.mark.chaos
def test_slow_step_fault_is_localized_by_profiler(small_L):
    """Satellite: a stall injected into step 3 must show up as step 3's
    histogram bucket / argmax, and the stall must be visible inside the
    profile span's trace."""
    from repro.core import faults
    from repro.obs.profile import profile_schedule
    from repro.core.strategies import NoRewrite
    from repro.core.transform import transform
    from repro.solver.schedule import schedule_for_transformed

    ts = transform(small_L, NoRewrite(), validate=False, codegen=False)
    sched = schedule_for_transformed(ts, chunk=64, max_deps=8)
    assert sched.num_steps > 4
    b = np.random.default_rng(0).standard_normal(small_L.n_rows)
    tr = obs.enable()
    try:
        with faults.slow_step(3, 0.05):
            prof = profile_schedule(sched, ts.preamble(b), reps=1,
                                    warmup=1)
    finally:
        obs.disable()
    assert int(np.argmax(prof.step_ms)) == 3
    assert prof.step_ms[3] >= 45.0             # the injected 50 ms stall
    hist = prof.step_histogram()
    # the stalled step lands in a bucket above 25 ms; every other step is
    # far below it on this tiny system
    stalled_bucket = next(i for i, bnd in enumerate(hist["bounds"])
                          if prof.step_ms[3] <= bnd)
    assert hist["counts"][stalled_bucket] >= 1
    (psp,) = [s for s in tr.spans() if s.name == "profile.schedule"]
    steps_evts = [a for n, _, a in psp.events if n == "profile.step"]
    assert any(e["step"] == 3 for e in steps_evts)
    assert psp.attrs["total_ms"] == pytest.approx(prof.total_ms())


# ----------------------------------------------------------------------
# exporters + validators


def _sample_tracer():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    with tr.span("serving.batch", width=2) as bsp:
        clk.advance(0.001)
        tr.record_span("serving.queue", 0.0, 0.001, parent=bsp)
        with tr.span("operator.solve"):
            clk.advance(0.002)
        bsp.event("mark")
    tr.event("loose.orphan")
    return tr


def test_chrome_trace_schema_and_validation(tmp_path):
    tr = _sample_tracer()
    doc = write_chrome_trace(tmp_path / "t.json", tr)
    assert validate_chrome_trace(doc) == []
    loaded = json.loads((tmp_path / "t.json").read_text())
    assert validate_chrome_trace(loaded) == []
    xs = [e for e in loaded["traceEvents"] if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"serving.batch", "serving.queue",
                                       "operator.solve"}
    by_name = {e["name"]: e for e in xs}
    bid = by_name["serving.batch"]["args"]["span_id"]
    assert by_name["serving.queue"]["args"]["parent_id"] == bid
    assert by_name["operator.solve"]["args"]["parent_id"] == bid
    # ts are rebased to the earliest span, µs units
    assert by_name["serving.batch"]["ts"] == 0.0
    assert by_name["serving.batch"]["dur"] == pytest.approx(3000.0)
    instants = [e for e in loaded["traceEvents"] if e["ph"] == "i"]
    assert {e["name"] for e in instants} == {"mark", "loose.orphan"}


def test_chrome_validator_flags_problems():
    tr = Tracer(clock=FakeClock())
    sp = tr.span("never.closed")
    sp.__enter__()
    doc = chrome_trace(tr)
    assert any("unclosed" in p for p in validate_chrome_trace(doc))
    bad = {"traceEvents": [
        {"name": "x", "ph": "X", "ts": 0.0, "dur": 1.0,
         "args": {"span_id": 1, "parent_id": 99}}]}
    assert any("does not resolve" in p for p in validate_chrome_trace(bad))
    assert validate_chrome_trace({"nope": 1})


def test_jsonl_export(tmp_path):
    tr = _sample_tracer()
    reg = MetricsRegistry(prefix="t")
    reg.counter("hits", "h").inc(3)
    n = write_jsonl(tmp_path / "log.jsonl", tracer=tr, registries=[reg])
    lines = [json.loads(l) for l in
             (tmp_path / "log.jsonl").read_text().splitlines()]
    assert len(lines) == n
    kinds = {l["type"] for l in lines}
    assert kinds == {"span", "event", "metrics"}
    m = [l for l in lines if l["type"] == "metrics"][0]
    assert m["snapshot"]["hits"]["series"][""] == 3


def test_prometheus_text_round_trip():
    reg = MetricsRegistry(prefix="repro_test")
    reg.counter("hits", "total hits").inc(5, route="a")
    reg.gauge("depth", "queue depth").set(2.5)
    reg.text("source", "cache source").set('we"ird\nvalue')
    h = reg.histogram("lat_ms", "latency", bounds=(1.0, 10.0))
    for v in (0.5, 5.0, 50.0):
        h.observe(v)
    page = prometheus_text(reg)
    assert validate_prometheus_text(page) == []
    assert 'repro_test_hits{route="a"} 5' in page
    assert "# TYPE repro_test_lat_ms histogram" in page
    assert 'repro_test_lat_ms_bucket{le="+Inf"} 3' in page
    assert "repro_test_lat_ms_count 3" in page
    # per-entry merge: same prefix twice under one TYPE header, labeled
    reg2 = MetricsRegistry(prefix="repro_test")
    reg2.counter("hits", "total hits").inc(1, route="a")
    merged = prometheus_text((reg, {"entry": "e1"}), (reg2, {"entry": "e2"}))
    assert validate_prometheus_text(merged) == []
    assert merged.count("# TYPE repro_test_hits counter") == 1
    assert 'entry="e2"' in merged


def test_prometheus_validator_flags_problems():
    assert validate_prometheus_text("repro_x 1\n")      # sample before TYPE
    bad = "# TYPE repro_x counter\nrepro_x{bad-label=\"v\"} 1\n"
    assert any("malformed sample" in p
               for p in validate_prometheus_text(bad))
    ok = "# TYPE repro_x counter\nrepro_x NaN\nrepro_x 1.5e-3\n"
    assert validate_prometheus_text(ok) == []


# ----------------------------------------------------------------------
# krylov residual events


def test_krylov_emits_residual_events():
    from repro.iterative import cg
    from repro.precond import Preconditioner

    A = generators.poisson2d_spd(10, 10)
    b = np.ones(A.n_rows)
    M = Preconditioner.ic0(A, tune="no_rewriting", cache=False)
    tr = obs.enable()
    try:
        res = cg(A, b, preconditioner=M, tol=1e-8)
    finally:
        obs.disable()
    assert bool(np.all(res.converged))
    evts = [(n, a) for n, _, a, _ in tr.orphan_events()
            if n == "krylov.residual"]
    assert evts and all(a["driver"] == "cg" for _, a in evts)
    assert evts[0][1]["iteration"] == 0
    assert len(evts) <= 64 + 1
    # the recorded residual trail matches the result history
    hist = np.asarray(res.residual_norms, dtype=float)
    for _, a in evts:
        assert hist[a["iteration"]] == pytest.approx(a["residual"])


# ----------------------------------------------------------------------
# the end-to-end acceptance trace


def test_traced_serving_request_exports_valid_nested_trace(tmp_path):
    """One batched serving request on the lung2 analogue: the exported
    Chrome trace is schema-valid and carries the nested
    submit/queue -> batch -> solve -> operator -> engine chain plus the
    registry admit/tune spans (the PR's acceptance criterion)."""
    from repro.serving import SolveService
    from repro.solver.reference import solve_csr_seq

    L = generators.lung2_like(scale=0.02)
    rng = np.random.default_rng(0)
    tr = obs.enable()
    try:
        with SolveService(tune_mode="sync", max_width=4,
                          auto_dispatch=False, cache=False) as svc:
            futs = [svc.submit(rng.standard_normal(L.n_rows), L)
                    for _ in range(4)]
            svc.pump()
            xs = [f.result(timeout=60) for f in futs]
            snap = svc.snapshot()
            prom = svc.prometheus_text()
    finally:
        obs.disable()

    assert snap["completed"] == 4 and snap["batches"] >= 1
    assert validate_prometheus_text(prom) == []
    assert "repro_service_completed 4" in prom
    assert "repro_registry_admissions 1" in prom
    assert "repro_operator_solves" in prom      # per-entry stats merged in

    doc = write_chrome_trace(tmp_path / "serve.trace.json", tr)
    assert validate_chrome_trace(doc) == []
    spans = {e["args"]["span_id"]: e for e in doc["traceEvents"]
             if e["ph"] == "X"}
    by_name: dict = {}
    for e in spans.values():
        by_name.setdefault(e["name"], []).append(e)
    for required in ("serving.submit", "registry.admit", "serving.queue",
                     "serving.batch", "serving.solve", "operator.solve",
                     "operator.tune", "engine.compile", "engine.solve"):
        assert required in by_name, f"missing span {required}"
    # the chain: queue and solve under the batch, operator under solve,
    # engine dispatch under the operator
    batch = by_name["serving.batch"][0]
    bid = batch["args"]["span_id"]
    assert all(q["args"]["parent_id"] == bid
               for q in by_name["serving.queue"])
    ssolve = by_name["serving.solve"][0]
    assert ssolve["args"]["parent_id"] == bid
    opsolve = by_name["operator.solve"][0]
    assert opsolve["args"]["parent_id"] == ssolve["args"]["span_id"]
    esolve = by_name["engine.solve"][0]
    assert esolve["args"]["parent_id"] == opsolve["args"]["span_id"]
    # admit nests under the submit that triggered it
    admit = by_name["registry.admit"][0]
    submit_ids = {e["args"]["span_id"] for e in by_name["serving.submit"]}
    assert admit["args"]["parent_id"] in submit_ids
    # solutions are real: spot-check one column against the oracle
    ref = solve_csr_seq(L, np.asarray(
        rng.standard_normal(L.n_rows)))      # just shape sanity for rng
    assert xs[0].shape == (L.n_rows,)
    assert np.all(np.isfinite(xs[0]))

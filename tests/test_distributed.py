"""Distributed SpTRSV (shard_map) — runs in a subprocess with 8 forced host
devices so the main test process keeps its single-device view."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax
    from repro.core import AvgLevelCost, NoRewrite, transform
    from repro.solver import schedule_for_csr, schedule_for_transformed, \\
        solve_csr_seq
    from repro.solver.distributed import solve_sharded
    from repro.sparse import build_levels, generators

    mesh = jax.make_mesh((8,), ("model",))
    L = generators.random_lower(400, avg_offdiag=2.0, seed=3, max_back=24)
    lv = build_levels(L)
    b = np.random.default_rng(0).standard_normal(400)
    x_ref = solve_csr_seq(L, b)
    sched = schedule_for_csr(L, lv, chunk=32, max_deps=4, dtype=np.float32)
    x = solve_sharded(sched, b, mesh, axis="model")
    err0 = float(np.abs(x - x_ref).max())

    # transformed system: fewer steps => fewer all_gathers
    ts = transform(L, AvgLevelCost(), validate=False, codegen=False)
    s1 = schedule_for_transformed(ts, chunk=32, max_deps=4)
    c = ts.preamble(b).astype(np.float32)
    x1 = solve_sharded(s1, c, mesh, axis="model")
    err1 = float(np.abs(x1 - x_ref).max())
    print(json.dumps({"err0": err0, "err1": err1,
                      "steps0": sched.num_steps, "steps1": s1.num_steps}))
""")


def test_sharded_solver_subprocess():
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd=Path(__file__).parent.parent, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["err0"] < 1e-3 and res["err1"] < 1e-3
    assert res["steps1"] <= res["steps0"]

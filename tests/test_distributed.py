"""Distributed SpTRSV: the ShardedEngine (ISSUE 5 tentpole).

In-process tests run on the single real CPU device (a 1-device mesh is a
degenerate but fully exercised shard_map program); the multi-device
matrix — mesh sizes 1/2/4/8, carry-bearing schedules, batched RHS, and an
end-to-end PCG under one mesh — runs in a subprocess with 8 forced host
devices so the main test process keeps its single-device view.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.solver import (ShardedEngine, get_engine, registered_engines,
                          resolve_engine, schedule_for_csr, sharded_engine,
                          solve_csr_seq)
from repro.solver import distributed as dist
from repro.solver.distributed import count_all_gathers, solve_sharded
from repro.sparse import build_levels, generators


def _small(n=120, seed=7, chunk=32, max_deps=4):
    L = generators.random_lower(n, avg_offdiag=2.0, seed=seed, max_back=15)
    sched = schedule_for_csr(L, build_levels(L), chunk=chunk,
                             max_deps=max_deps)
    b = np.random.default_rng(0).standard_normal(n)
    return L, sched, b


# -- registry + capability (in-process, 1-device mesh) ------------------------

def test_sharded_engine_registered_and_resolvable():
    assert "sharded" in registered_engines()
    eng = resolve_engine("sharded")
    assert isinstance(eng, ShardedEngine)
    caps = eng.capabilities()
    assert caps["supports_batched_rhs"] and caps["available"]
    # the mesh-less default instance and sharded_engine() are one object,
    # so lowering memoization is shared across call sites
    assert sharded_engine() is eng


def test_sharded_cache_token_is_mesh_qualified():
    """Measured-mode cache keys record which engine was TIMED; two sharded
    engines over different meshes measure different collective costs and
    must never collide on the bare name."""
    import jax
    devs = jax.devices()
    e1 = ShardedEngine(dist.default_mesh(devices=devs[:1]))
    e_all = ShardedEngine(dist.default_mesh())
    assert e1.cache_token().startswith("sharded[")
    assert e1.cache_token() != "sharded"
    assert get_engine("scan").cache_token() == "scan"
    if len(devs) > 1:       # distinct meshes => distinct tokens
        assert e1.cache_token() != e_all.cache_token()
    e_other_axis = ShardedEngine(
        dist.default_mesh(axis="data", devices=devs[:1]), axis="data")
    assert e_other_axis.cache_token() != e1.cache_token()


def test_sharded_engine_default_mesh_unifies_with_registry():
    """sharded_engine(default_mesh()) and the registered "sharded"
    instance must be ONE object — two instances over the identical mesh
    would split the lowering memo and pad/stage/compile twice."""
    eng = get_engine("sharded")
    assert sharded_engine(dist.default_mesh()) is eng
    assert sharded_engine(None) is eng


def test_mesh_auto_tune_defaults_to_sharded_cost_model(tmp_path):
    """tune="auto" under mesh= must price the per-step collective: the
    serving configuration and the tuning objective have to agree."""
    from repro.solver import TriangularOperator
    L = generators.random_lower(120, avg_offdiag=2.0, seed=4, max_back=12)
    mesh = dist.default_mesh()
    op = TriangularOperator.from_csr(L, tune="auto", chunk=32, max_deps=4,
                                     mesh=mesh, cache_dir=tmp_path)
    assert op.report.cost_model.collective_latency_us > 0
    for c in op.report.candidates:
        if c.error is None:
            assert c.breakdown["collectives_us"] > 0
    # single-device auto-tune keeps the single-device default
    op2 = TriangularOperator.from_csr(L, tune="auto", chunk=32, max_deps=4,
                                      cache_dir=tmp_path)
    assert op2.report.cost_model.collective_latency_us == 0
    # distinct objectives, distinct cache entries — no collision
    assert op2.stats.cache_source == "built"
    # an explicit cost_model is never overridden
    from repro.core import TuningCostModel
    op3 = TriangularOperator.from_csr(L, tune="auto", chunk=32, max_deps=4,
                                      mesh=mesh, cache=False,
                                      cost_model=TuningCostModel.cpu())
    assert op3.report.cost_model.collective_latency_us == 0


def test_sharded_operator_never_stages_unpadded_schedules():
    """Host-lowering engines must not trigger the unpadded DeviceSchedule
    staging — neither for the main schedule nor for the T-factor
    preamble; the sharded lowering pads and stages its own copies."""
    import jax.numpy as jnp
    from repro.solver import TriangularOperator
    L = generators.lung2_like(scale=0.02)
    op = TriangularOperator.from_csr(L, tune="avgLevelCost", chunk=32,
                                     max_deps=4, mesh=dist.default_mesh(),
                                     cache=False)
    b = np.random.default_rng(5).standard_normal(L.n_rows)
    x = op.solve(b, max_refine=0)
    fn = op.device_solve_fn()
    y = np.asarray(fn(jnp.asarray(b, np.float32)))
    x_ref = solve_csr_seq(L, b)
    scale = max(1.0, np.abs(x_ref).max())
    assert np.abs(x - x_ref).max() / scale < 1e-3
    assert np.abs(y - x_ref).max() / scale < 1e-3
    assert op._runtime.get("dsched") is None
    assert op._runtime.get("preamble") is None
    assert "preamble_host" in op._runtime


def test_mesh_pair_decision_defaults_to_sharded_cost_model():
    from repro.precond import Preconditioner
    A = generators.poisson2d_spd(10, 10)
    Preconditioner.clear_pair_decisions()
    P = Preconditioner.ic0(A, tune="auto", mesh=dist.default_mesh(),
                           cache=False)
    assert P.forward.engine == "sharded"
    assert P.report.fwd.cost_model.collective_latency_us > 0


def test_sharded_solves_single_and_batched():
    L, sched, b = _small()
    fn = get_engine("sharded").compile(sched)
    x_ref = solve_csr_seq(L, b)
    import jax.numpy as jnp
    x = np.asarray(fn(jnp.asarray(b, np.float32)))
    assert np.abs(x - x_ref).max() < 2e-4
    B = np.random.default_rng(1).standard_normal((L.n_rows, 3))
    X = np.asarray(fn(jnp.asarray(B, np.float32)))
    assert X.shape == (L.n_rows, 3)
    for j in range(3):
        assert np.abs(X[:, j] - solve_csr_seq(L, B[:, j])).max() < 2e-4


def test_sharded_mismatched_rhs_raises():
    """Regression (ISSUE 5 satellite): a wrong-length RHS used to die with
    an opaque concatenate shape error deep inside shard_map; the lowered
    fn must validate the leading dimension eagerly."""
    _, sched, _ = _small()
    fn = get_engine("sharded").compile(sched)
    n = sched.n
    with pytest.raises(ValueError, match=rf"\({n},\) or \({n}, k\)"):
        fn(np.zeros(n + 1, np.float32))
    with pytest.raises(ValueError, match="right-hand side"):
        fn(np.zeros((n - 1, 2), np.float32))
    with pytest.raises(ValueError, match="right-hand side"):
        fn(np.zeros((n, 2, 2), np.float32))


def test_axis_name_mismatch_is_a_clear_error():
    """A mesh whose axis name differs from mesh_axis/axis must raise an
    eager ValueError naming the mesh's axes — not a KeyError from deep
    inside lowering."""
    import jax
    from repro.iterative.operators import device_matvec
    from repro.solver import TriangularOperator
    mesh = dist.default_mesh(axis="data", devices=jax.devices()[:1])
    with pytest.raises(ValueError, match=r"no axis 'model'.*'data'"):
        ShardedEngine(mesh)                 # default axis="model"
    L, sched, b = _small()
    with pytest.raises(ValueError, match="no axis"):
        dist.solve_sharded(sched, b, mesh)
    with pytest.raises(ValueError, match="no axis"):
        TriangularOperator.from_csr(L, tune="no_rewriting", chunk=32,
                                    max_deps=4, mesh=mesh, cache=False)
    with pytest.raises(ValueError, match="no axis"):
        device_matvec(L, mesh=mesh)
    with pytest.raises(ValueError, match="no axis"):
        dist.count_all_gathers(sched, mesh)


def test_sharded_compile_memoizes_lowering(monkeypatch):
    """Repeat compiles of one schedule return the identical callable and
    never re-pad the groups (the seed re-padded and re-staged per call)."""
    _, sched, b = _small()
    calls = {"pad": 0}
    real_pad = dist._pad_group

    def counting_pad(*a, **kw):
        calls["pad"] += 1
        return real_pad(*a, **kw)

    monkeypatch.setattr(dist, "_pad_group", counting_pad)
    eng = ShardedEngine()               # fresh instance: first compile pads
    fn1 = eng.compile(sched)
    pads_after_first = calls["pad"]
    assert pads_after_first > 0
    from repro.solver import to_device
    fn2 = eng.compile(sched)
    fn3 = eng.compile(to_device(sched))     # DeviceSchedule resolves .host
    assert fn1 is fn2 is fn3
    assert calls["pad"] == pads_after_first
    # a different schedule is a different lowering, not a stale hit
    _, other, _ = _small(seed=11)
    assert eng.compile(other) is not fn1
    assert calls["pad"] > pads_after_first


def test_sharded_compile_inside_jit_trace_stays_usable():
    """Regression: a lowering first triggered INSIDE a jit trace (an
    operator first used as a traced preconditioner) must memoize concrete
    staged arrays, not tracer-backed constants — later solves outside the
    trace used to die with UnexpectedTracerError."""
    import jax
    import jax.numpy as jnp
    L, sched, b = _small(seed=13)
    eng = ShardedEngine()

    @jax.jit
    def traced(v):
        return eng.compile(sched)(v)

    ref = solve_csr_seq(L, b)
    y = np.asarray(traced(jnp.asarray(b, np.float32)))
    assert np.abs(y - ref).max() < 2e-4
    # the memoized fn (same object) must stay usable outside the trace
    x = np.asarray(eng.compile(sched)(jnp.asarray(b, np.float32)))
    assert np.abs(x - ref).max() < 2e-4


def test_solve_sharded_reuses_engine_lowering():
    import jax
    L, sched, b = _small()
    mesh = dist.default_mesh(devices=jax.devices()[:1])
    x = solve_sharded(sched, b, mesh)
    assert np.abs(x - solve_csr_seq(L, b)).max() < 2e-4
    eng = sharded_engine(mesh)
    fn = eng.compile(sched)             # memo hit from solve_sharded's call
    assert eng.compile(sched) is fn


# -- collective-count invariant ----------------------------------------------

def test_all_gather_families_equal_steps():
    _, sched, _ = _small()
    g = count_all_gathers(sched)
    assert g["families"] == g["steps"] == sched.num_steps
    assert g["calls"] >= 2 * g["steps"]


def test_all_gather_families_equal_steps_with_carries():
    """Split-row (carry-bearing) schedules ship their carry updates in the
    SAME per-step family — synchronization points must not double."""
    Lb = generators.banded(160, 12, seed=1)
    sb = schedule_for_csr(Lb, build_levels(Lb), chunk=16, max_deps=4)
    assert sb.n_carry > 0               # the premise: carries exist
    g = count_all_gathers(sb)
    assert g["families"] == g["steps"] == sb.num_steps
    # carry steps gather (xi, rids, tots, couts): more calls, same barriers
    assert g["calls"] > 2 * g["steps"]
    bb = np.random.default_rng(1).standard_normal(160)
    import jax
    mesh = dist.default_mesh(devices=jax.devices()[:1])
    xb = solve_sharded(sb, bb, mesh)
    assert np.abs(xb - solve_csr_seq(Lb, bb)).max() < 2e-4


# -- multi-device matrix (subprocess, 8 forced host devices) ------------------

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.core import AvgLevelCost, transform
    from repro.iterative import cg
    from repro.iterative.operators import device_matvec
    from repro.precond import Preconditioner
    from repro.solver import (get_engine, schedule_for_csr,
                              schedule_for_transformed, sharded_engine,
                              solve_csr_seq)
    from repro.solver.distributed import (count_all_gathers, default_mesh,
                                          solve_sharded)
    from repro.sparse import build_levels, generators

    res = {}
    devs = jax.devices()
    assert len(devs) == 8

    L = generators.random_lower(400, avg_offdiag=2.0, seed=3, max_back=24)
    lv = build_levels(L)
    b = np.random.default_rng(0).standard_normal(400)
    x_ref = solve_csr_seq(L, b)
    sched = schedule_for_csr(L, lv, chunk=32, max_deps=4, dtype=np.float32)

    # mesh-size sweep: 1/2/4/8 shards of the same schedule
    res["mesh_errs"] = {}
    for d in (1, 2, 4, 8):
        mesh = default_mesh(devices=devs[:d])
        x = solve_sharded(sched, b, mesh, axis="model")
        res["mesh_errs"][str(d)] = float(np.abs(x - x_ref).max())

    # transformed system: fewer steps => fewer all_gather families
    mesh8 = default_mesh(devices=devs)
    ts = transform(L, AvgLevelCost(), validate=False, codegen=False)
    s1 = schedule_for_transformed(ts, chunk=32, max_deps=4)
    c = ts.preamble(b).astype(np.float32)
    x1 = solve_sharded(s1, c, mesh8, axis="model")
    res["err_transformed"] = float(np.abs(x1 - x_ref).max())
    res["steps0"], res["steps1"] = sched.num_steps, s1.num_steps
    res["gathers0"] = count_all_gathers(sched, mesh8)["families"]
    res["gathers1"] = count_all_gathers(s1, mesh8)["families"]

    # carry-bearing (split-row) schedule under 8-way sharding
    Lb = generators.banded(160, 12, seed=1)
    sb = schedule_for_csr(Lb, build_levels(Lb), chunk=16, max_deps=4)
    assert sb.n_carry > 0
    bb = np.random.default_rng(1).standard_normal(160)
    xb = solve_sharded(sb, bb, mesh8)
    res["err_carry"] = float(np.abs(xb - solve_csr_seq(Lb, bb)).max())

    # batched (n, k) RHS through the engine: lanes sharded, columns
    # replicated
    eng = sharded_engine(mesh8)
    fn = eng.compile(sched)
    B = np.random.default_rng(2).standard_normal((400, 3))
    X = np.asarray(fn(jnp.asarray(B, np.float32)))
    res["err_batched"] = float(max(
        np.abs(X[:, j] - solve_csr_seq(L, B[:, j])).max()
        for j in range(3)))
    res["memoized"] = fn is eng.compile(sched)

    # end-to-end PCG under ONE mesh: sharded SpMV + sharded M^-1 sweeps,
    # no host round-trips between matvec and preconditioner
    A = generators.poisson2d_spd(12, 12)
    P = Preconditioner.ic0(A, tune="no_rewriting", mesh=mesh8, cache=False)
    assert P.forward.engine == "sharded" and P.backward.engine == "sharded"
    mv = device_matvec(A, mesh=mesh8)
    rhs = np.random.default_rng(3).standard_normal(A.n_rows)
    y = np.asarray(mv(jnp.asarray(rhs, np.float32)))
    res["err_spmv"] = float(np.abs(y - A.matvec(rhs)).max())
    out = cg(mv, jnp.asarray(rhs, np.float32), preconditioner=P,
             tol=1e-5, maxiter=300)
    r = rhs - np.asarray(mv(out.x), dtype=np.float64)
    res["pcg_converged"] = bool(out.converged)
    res["pcg_iters"] = int(out.iterations)
    res["pcg_resid"] = float(np.abs(r).max())
    print(json.dumps(res))
""")


def test_sharded_solver_subprocess():
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd=Path(__file__).parent.parent, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    for d, err in res["mesh_errs"].items():
        assert err < 1e-3, f"mesh size {d}"
    assert res["err_transformed"] < 1e-3
    assert res["err_carry"] < 1e-3
    assert res["err_batched"] < 1e-3
    assert res["memoized"]
    # the paper's claim, made literal: fewer steps == fewer barriers
    assert res["steps1"] <= res["steps0"]
    assert res["gathers0"] == res["steps0"]
    assert res["gathers1"] == res["steps1"]
    # one-mesh PCG: sharded matvec is exact-ish, the loop converges
    assert res["err_spmv"] < 1e-3
    assert res["pcg_converged"] and res["pcg_resid"] < 1e-3
    assert 0 < res["pcg_iters"] < 100

"""Optional test dependencies: property-based tests skip when hypothesis is
missing instead of breaking collection of the whole module (ISSUE 1
satellite: the tier-1 suite must run without optional deps)."""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ModuleNotFoundError:             # pragma: no cover - env dependent
    HAS_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _AnyStrategy:
        """Placeholder so module-level strategy expressions still evaluate."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()

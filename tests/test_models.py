"""Per-arch smoke tests (reduced configs) + model-level correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_ids, get_config, get_reduced
from repro.models import get_model
from repro.launch.specs import SHAPES, cell_is_applicable, input_specs


def _batch_for(cfg, rng, B=2, S=32):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32),
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.family == "encdec":
        batch["src_embeds"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    elif cfg.frontend != "none":
        batch["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.frontend_positions, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", all_arch_ids())
def test_smoke_forward_and_train_step(arch, rng):
    """REQUIRED smoke: reduced config, one forward + one train step on CPU,
    output shapes + no NaNs."""
    cfg = get_reduced(arch)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    batch = _batch_for(cfg, rng, B, S)
    if cfg.family == "encdec":
        logits, _ = jax.jit(lambda p: model.forward(
            p, batch["src_embeds"], batch["tokens"], cfg))(params)
        assert logits.shape == (B, S, cfg.padded_vocab)
    else:
        logits, _ = jax.jit(lambda p: model.forward(
            p, batch["tokens"], cfg,
            prefix_embeds=batch.get("prefix_embeds")))(params)
        S_total = S + (cfg.frontend_positions if cfg.frontend != "none"
                       else 0)
        assert logits.shape == (B, S_total, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    # one real train step
    from repro.train import AdamWConfig, make_train_step
    from repro.train.optimizer import init_opt_state
    step = make_train_step(cfg, AdamWConfig(lr=1e-3))
    state = {"params": params, "opt": init_opt_state(params)}
    state, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))


@pytest.mark.parametrize("arch", all_arch_ids())
def test_full_config_matches_assignment(arch):
    """Exact assigned hyperparameters (guards against config drift)."""
    cfg = get_config(arch)
    expected = {
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "mamba2-130m": (24, 768, 1, 1, 0, 50280),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_ff,
           cfg.vocab)
    assert got == expected
    if arch == "llama4-scout-17b-a16e":
        assert cfg.moe.num_experts == 16 and cfg.moe.top_k == 1
    if arch == "granite-moe-1b-a400m":
        assert cfg.moe.num_experts == 32 and cfg.moe.top_k == 8
    if arch == "mamba2-130m":
        assert cfg.ssm.state_dim == 128
    if arch == "qwen2-7b":
        assert cfg.qkv_bias
    if arch == "gemma-7b":
        assert cfg.resolved_head_dim == 256


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "gemma-7b",
                                  "mamba2-130m", "recurrentgemma-9b",
                                  "seamless-m4t-large-v2"])
def test_prefill_decode_matches_forward(arch, rng):
    """prefill(prompt) + decode_step == forward(prompt + token) logits."""
    cfg = get_reduced(arch)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1), cfg)
    B, S = 2, 12
    toks = jnp.asarray(rng.integers(2, cfg.vocab, (B, S + 1)), jnp.int32)
    prompt, nxt = toks[:, :S], toks[:, S:]
    kw = {}
    if cfg.family == "encdec":
        kw["src_embeds"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    cache_len = S + 4
    logits_p, cache = model.prefill(params, prompt, cfg,
                                    cache_len=cache_len, **kw)
    logits_d, _ = model.decode_step(params, nxt, cache, jnp.int32(S), cfg)
    if cfg.family == "encdec":
        full, _ = model.forward(params, kw["src_embeds"],
                                jnp.concatenate([prompt, nxt], 1), cfg)
    else:
        full, _ = model.forward(params, jnp.concatenate([prompt, nxt], 1),
                                cfg)
    np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(logits_p[:, -1]),
                               np.asarray(full[:, S - 1]), rtol=2e-2,
                               atol=2e-2)


def test_local_attention_window_ring(rng):
    """recurrentgemma ring cache: decode at pos >= window stays finite and
    ignores out-of-window history."""
    cfg = get_reduced("recurrentgemma-9b")
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(2), cfg)
    B, S = 1, 24  # > window 16
    prompt = jnp.asarray(rng.integers(2, cfg.vocab, (B, S)), jnp.int32)
    logits, cache = model.prefill(params, prompt, cfg, cache_len=cfg.window)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    for i in range(4):
        logits, cache = model.decode_step(params, tok, cache,
                                          jnp.int32(S + i), cfg)
        assert bool(jnp.isfinite(logits).all())


def test_mamba2_ssd_matches_naive(rng):
    """Chunked SSD == naive recurrence on small shapes."""
    from repro.models.mamba2 import _ssd_chunked
    B, S, H, P, N = 2, 48, 3, 4, 8
    xh = jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 0.5, (B, S, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 1.5, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    y, hT = _ssd_chunked(xh, dt, A, Bm, Cm, chunk=16)
    # naive recurrence
    h = np.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        a = np.exp(np.asarray(dt[:, t]) * np.asarray(A)[None])   # (B,H)
        xdt = np.asarray(xh[:, t]) * np.asarray(dt[:, t])[..., None]
        h = h * a[..., None, None] + np.einsum(
            "bn,bhp->bhpn", np.asarray(Bm[:, t]), xdt)
        ys.append(np.einsum("bn,bhpn->bhp", np.asarray(Cm[:, t]), h))
    y_ref = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hT), h, rtol=2e-4, atol=2e-4)


def test_rglru_scan_matches_naive(rng):
    from repro.models.rglru import _rglru, _init_rec_block
    from repro.configs import get_reduced
    cfg = get_reduced("recurrentgemma-9b")
    bp = _init_rec_block(jax.random.PRNGKey(3), cfg, jnp.float32)
    B, S, w = 2, 20, cfg.recurrent.lru_width
    xb = jnp.asarray(rng.standard_normal((B, S, w)) * 0.3, jnp.float32)
    y, h_last = _rglru(bp, xb)
    # naive step-by-step using the decode path
    h = jnp.zeros((B, w), jnp.float32)
    for t in range(S):
        yt, h = _rglru(bp, xb[:, t:t + 1], h0=h)
        np.testing.assert_allclose(np.asarray(yt[:, 0]), np.asarray(y[:, t]),
                                   rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_last), rtol=2e-4,
                               atol=2e-4)


def test_moe_routing_invariants(rng):
    """Hypothesis-style invariants: capacity respected, gates normalized,
    dropped tokens pass through as zeros."""
    from repro.models.mlp import init_moe, moe
    cfg = get_reduced("granite-moe-1b-a400m")
    p = init_moe(jax.random.PRNGKey(4), cfg, jnp.float32)
    B, S, d = 3, 16, cfg.d_model
    x = jnp.asarray(rng.standard_normal((B, S, d)), jnp.float32)
    y, aux = moe(p, x, cfg, cfg.act)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(aux))
    # determinism
    y2, _ = moe(p, x, cfg, cfg.act)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y2))


def test_vlm_prefix_positions_excluded_from_loss(rng):
    cfg = get_reduced("internvl2-1b")
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(5), cfg)
    B, S = 2, 16
    batch = _batch_for(cfg, rng, B, S)
    loss = model.loss_fn(params, batch, cfg)
    assert bool(jnp.isfinite(loss))


def test_input_specs_cover_assignment():
    """All 40 cells are defined; long_500k applicability follows family."""
    n_cells = 0
    n_skips = 0
    for arch in all_arch_ids():
        cfg = get_config(arch)
        for shape in SHAPES:
            n_cells += 1
            if not cell_is_applicable(cfg, shape):
                n_skips += 1
                assert shape == "long_500k" and not cfg.sub_quadratic
                continue
            spec = input_specs(cfg, shape)
            assert spec["kind"] in ("train", "prefill", "decode")
    assert n_cells == 40
    assert n_skips == 8  # 8 full-attention archs skip long_500k

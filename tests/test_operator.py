"""TriangularOperator: accuracy (1-D + batched), fingerprint cache
round-trips, engines, stats (ISSUE 2 tentpole)."""
import numpy as np
import pytest

from repro.solver import (TriangularOperator, matrix_fingerprint,
                          solve_csr_seq)
from repro.sparse import generators


@pytest.fixture(autouse=True)
def _fresh_memory_cache():
    TriangularOperator.clear_memory_cache()
    yield
    TriangularOperator.clear_memory_cache()


@pytest.fixture(scope="module")
def lung_small():
    return generators.lung2_like(scale=0.04)


def _rel_err(x, x_ref):
    return np.abs(x - x_ref).max() / max(1.0, np.abs(x_ref).max())


def test_auto_operator_1d_and_batched(lung_small, tmp_path):
    """The acceptance path: from_csr(L, tune='auto').solve(B) for 1-D and
    (n, k) RHS, matching the sequential reference to 1e-8."""
    L = lung_small
    op = TriangularOperator.from_csr(L, tune="auto", chunk=128, max_deps=8,
                                     cache_dir=tmp_path)
    assert op.report is not None and op.report.best.label == op.strategy
    b = np.random.default_rng(0).standard_normal(L.n_rows)
    x = op.solve(b)
    x_ref = solve_csr_seq(L, b)
    assert x.shape == (L.n_rows,)
    assert _rel_err(x, x_ref) < 1e-8
    B = np.random.default_rng(1).standard_normal((L.n_rows, 6))
    X = op.solve(B)
    assert X.shape == (L.n_rows, 6)
    for j in range(6):      # batched == column-by-column reference
        assert _rel_err(X[:, j], solve_csr_seq(L, B[:, j])) < 1e-8


def test_cache_roundtrip(lung_small, tmp_path):
    L = lung_small
    op1 = TriangularOperator.from_csr(L, tune="avgLevelCost", chunk=128,
                                      max_deps=8, cache_dir=tmp_path)
    assert op1.stats.cache_source == "built"
    assert list(tmp_path.glob("op-*.pkl"))          # persisted
    # warm memory cache
    op2 = TriangularOperator.from_csr(L, tune="avgLevelCost", chunk=128,
                                      max_deps=8, cache_dir=tmp_path)
    assert op2.stats.cache_source == "memory"
    # cold process (memory cleared) -> disk hit, identical artifact
    TriangularOperator.clear_memory_cache()
    op3 = TriangularOperator.from_csr(L, tune="avgLevelCost", chunk=128,
                                      max_deps=8, cache_dir=tmp_path)
    assert op3.stats.cache_source == "disk"
    assert op3.strategy == op1.strategy
    assert op3.schedule.num_steps == op1.schedule.num_steps
    b = np.random.default_rng(2).standard_normal(L.n_rows)
    assert _rel_err(op3.solve(b), solve_csr_seq(L, b)) < 1e-8
    # different configuration -> different key -> rebuild
    op4 = TriangularOperator.from_csr(L, tune="avgLevelCost", chunk=64,
                                      max_deps=8, cache_dir=tmp_path)
    assert op4.stats.cache_source == "built"


def test_cache_auto_report_survives_disk(lung_small, tmp_path):
    L = lung_small
    op1 = TriangularOperator.from_csr(L, tune="auto", chunk=128, max_deps=8,
                                      cache_dir=tmp_path)
    TriangularOperator.clear_memory_cache()
    op2 = TriangularOperator.from_csr(L, tune="auto", chunk=128, max_deps=8,
                                      cache_dir=tmp_path)
    assert op2.stats.cache_source == "disk"
    assert op2.strategy == op1.strategy
    # the slim ranked report rides along in the cached artifact
    assert [c.label for c in op2.report.candidates] == \
        [c.label for c in op1.report.candidates]


def test_cache_disabled_writes_nothing(lung_small, tmp_path):
    TriangularOperator.from_csr(lung_small, tune="no_rewriting", chunk=128,
                                max_deps=8, cache=False, cache_dir=tmp_path)
    assert not list(tmp_path.iterdir())
    assert not TriangularOperator._memory_cache


def test_memory_cache_is_lru_bounded(tmp_path):
    old = TriangularOperator._memory_cache_max
    TriangularOperator._memory_cache_max = 2
    try:
        for seed in range(3):
            L = generators.random_lower(60, avg_offdiag=2.0, seed=seed,
                                        max_back=10)
            TriangularOperator.from_csr(L, tune="no_rewriting", chunk=16,
                                        max_deps=4, cache_dir=tmp_path)
        assert len(TriangularOperator._memory_cache) == 2
        assert len(list(tmp_path.glob("op-*.pkl"))) == 3    # disk keeps all
    finally:
        TriangularOperator._memory_cache_max = old


def test_cost_model_is_part_of_cache_key(lung_small, tmp_path):
    from repro.core import TuningCostModel
    op1 = TriangularOperator.from_csr(lung_small, tune="auto", chunk=128,
                                      max_deps=8, cache_dir=tmp_path)
    op2 = TriangularOperator.from_csr(lung_small, tune="auto", chunk=128,
                                      max_deps=8, cache_dir=tmp_path,
                                      cost_model=TuningCostModel.cpu())
    assert op1.stats.cache_source == "built"
    assert op2.stats.cache_source == "built"    # distinct key, no collision
    assert len(list(tmp_path.glob("op-*.pkl"))) == 2


def test_fingerprint_sensitivity(lung_small):
    L = lung_small
    fp = matrix_fingerprint(L)
    assert fp == matrix_fingerprint(L)
    revalued = generators.with_values(L, seed=99)
    assert matrix_fingerprint(revalued) != fp                  # values count
    assert matrix_fingerprint(revalued, include_values=False) == \
        matrix_fingerprint(L, include_values=False)            # pattern only
    other = generators.random_lower(L.n_rows, avg_offdiag=2.0, seed=1)
    assert matrix_fingerprint(other, include_values=False) != \
        matrix_fingerprint(L, include_values=False)


def test_engines_match(tmp_path):
    L = generators.banded(80, 12, seed=1)      # splits rows -> carry lanes
    b = np.random.default_rng(3).standard_normal(L.n_rows)
    x_ref = solve_csr_seq(L, b)
    op = TriangularOperator.from_csr(L, tune="no_rewriting", chunk=16,
                                     max_deps=4, cache=False)
    for engine in ("scan", "unrolled", "pallas"):
        assert _rel_err(op.solve(b, engine=engine), x_ref) < 1e-8, engine
    B = np.random.default_rng(4).standard_normal((L.n_rows, 3))
    X = op.solve(B, engine="pallas")           # batched Pallas path
    for j in range(3):
        assert _rel_err(X[:, j], solve_csr_seq(L, B[:, j])) < 1e-8


def test_solve_stats_and_validation(lung_small, tmp_path):
    L = lung_small
    op = TriangularOperator.from_csr(L, tune="constrained_avg", chunk=128,
                                     max_deps=8, cache=False)
    b = np.random.default_rng(5).standard_normal(L.n_rows)
    op.solve(b)
    op.solve(np.tile(b[:, None], (1, 4)))
    st = op.stats
    assert st.solves == 2 and st.rhs_columns == 5
    assert st.total_solve_ms >= st.last_solve_ms > 0
    assert st.last_residual < 1e-10
    with pytest.raises(ValueError, match="b must be"):
        op.solve(np.zeros(L.n_rows + 1))
    with pytest.raises(ValueError, match="b must be"):
        op.solve(np.zeros((L.n_rows, 2, 2)))


def _disk_cache_file(tmp_path):
    files = list(tmp_path.glob("op-*.pkl"))
    assert len(files) == 1
    return files[0]


def test_corrupt_disk_cache_rebuilds(lung_small, tmp_path):
    """Corrupt/truncated pickle entries fall back to a clean rebuild
    instead of raising (ISSUE 3 satellite)."""
    L = lung_small
    kw = dict(tune="no_rewriting", chunk=128, max_deps=8, cache_dir=tmp_path)
    op1 = TriangularOperator.from_csr(L, **kw)
    assert op1.stats.cache_source == "built"
    path = _disk_cache_file(tmp_path)

    # garbage bytes
    path.write_bytes(b"this is not a pickle")
    TriangularOperator.clear_memory_cache()
    op2 = TriangularOperator.from_csr(L, **kw)
    assert op2.stats.cache_source == "built"        # rebuilt, no raise

    # truncated but pickle-prefixed entry (rewritten by the rebuild above)
    raw = path.read_bytes()
    path.write_bytes(raw[: max(1, len(raw) // 3)])
    TriangularOperator.clear_memory_cache()
    op3 = TriangularOperator.from_csr(L, **kw)
    assert op3.stats.cache_source == "built"
    b = np.random.default_rng(7).standard_normal(L.n_rows)
    assert _rel_err(op3.solve(b), solve_csr_seq(L, b)) < 1e-8


def test_cache_version_bump_rebuilds(lung_small, tmp_path):
    """A payload written under a different CACHE_VERSION is ignored (clean
    rebuild), never deserialized into a live operator."""
    import pickle
    L = lung_small
    kw = dict(tune="no_rewriting", chunk=128, max_deps=8, cache_dir=tmp_path)
    TriangularOperator.from_csr(L, **kw)
    path = _disk_cache_file(tmp_path)
    payload = pickle.loads(path.read_bytes())
    payload["version"] = payload["version"] - 1     # stale-format entry
    path.write_bytes(pickle.dumps(payload))
    TriangularOperator.clear_memory_cache()
    op = TriangularOperator.from_csr(L, **kw)
    assert op.stats.cache_source == "built"


def test_engine_is_not_in_cache_key(lung_small, tmp_path):
    """The compiled artifact is engine-independent: switching engines on
    the same matrix is a cache hit, and each operator still honors its own
    engine choice.  (With measured re-ranking the engine IS keyed, since
    the tuner's pick then depends on it.)"""
    L = lung_small
    op1 = TriangularOperator.from_csr(L, tune="no_rewriting", chunk=128,
                                      max_deps=8, cache_dir=tmp_path)
    op2 = TriangularOperator.from_csr(L, tune="no_rewriting", chunk=128,
                                      max_deps=8, cache_dir=tmp_path,
                                      engine="pallas-interpret")
    assert op1.stats.cache_source == "built"
    assert op2.stats.cache_source == "memory"       # no rebuild
    assert op1.engine == "scan" and op2.engine == "pallas-interpret"
    assert len(list(tmp_path.glob("op-*.pkl"))) == 1
    b = np.random.default_rng(8).standard_normal(L.n_rows)
    assert _rel_err(op2.solve(b), solve_csr_seq(L, b)) < 1e-8


def test_orientation_bits_in_cache_key(lung_small, tmp_path):
    """side/transpose are part of the fingerprint key: all four sweeps of
    one matrix coexist on disk and none collides (ISSUE 3 satellite)."""
    L = lung_small
    built = []
    for side, transpose in (("lower", False), ("lower", True),
                            ("upper", False), ("upper", True)):
        A = L if side == "lower" else L.transpose()
        op = TriangularOperator.from_csr(A, tune="no_rewriting", side=side,
                                         transpose=transpose, chunk=128,
                                         max_deps=8, cache_dir=tmp_path)
        built.append(op.stats.cache_source)
    assert built == ["built"] * 4
    # lower/upper pairs share the matrix only pairwise -> 4 distinct keys
    assert len(list(tmp_path.glob("op-*.pkl"))) == 4
    # same orientation again: cache hit, not a rebuild
    op = TriangularOperator.from_csr(L, tune="no_rewriting", side="lower",
                                     transpose=True, chunk=128, max_deps=8,
                                     cache_dir=tmp_path)
    assert op.stats.cache_source == "memory"


def test_no_refine_is_device_precision(lung_small):
    """max_refine=0 returns the raw float32 device solve (~1e-5), while the
    default refinement buys back float64 (~1e-10) — the contract the
    operator's accuracy guarantee rests on."""
    L = lung_small
    op = TriangularOperator.from_csr(L, tune="avgLevelCost", chunk=128,
                                     max_deps=8, cache=False)
    b = np.random.default_rng(6).standard_normal(L.n_rows)
    x_ref = solve_csr_seq(L, b)
    raw = _rel_err(op.solve(b, max_refine=0), x_ref)
    refined = _rel_err(op.solve(b), x_ref)
    assert refined < 1e-8 < raw < 1e-3


def test_no_refine_skips_float64_promotion(lung_small):
    """Regression (ISSUE 5 satellite): max_refine=0 is sold as the
    cheapest per-solve path, yet solve() used to copy b to host float64
    and cast the device result up unconditionally.  With refinement off
    the result must come back in the schedule dtype (float32 here), for
    single and batched RHS; refined solves still return float64."""
    L = lung_small
    op = TriangularOperator.from_csr(L, tune="no_rewriting", chunk=128,
                                     max_deps=8, cache=False)
    b32 = np.random.default_rng(9).standard_normal(L.n_rows) \
        .astype(np.float32)
    x = op.solve(b32, max_refine=0)
    assert x.dtype == np.float32            # no fp64 copy anywhere
    assert np.isnan(op.stats.last_residual)     # no host residual matvec
    X = op.solve(np.tile(b32[:, None], (1, 3)), max_refine=0)
    assert X.dtype == np.float32 and X.shape == (L.n_rows, 3)
    # a float64 b stays float64-free on the output too: the device
    # pipeline's natural dtype is the schedule dtype
    assert op.solve(b32.astype(np.float64), max_refine=0).dtype \
        == np.float32
    assert op.solve(b32).dtype == np.float64    # refinement: fp64 contract

"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AvgLevelCost, transform
from repro.kernels import ops
from repro.solver import schedule_for_csr, schedule_for_transformed, \
    solve_csr_seq
from repro.sparse import build_levels, generators


@pytest.mark.parametrize("n,avg,chunk,max_deps", [
    (64, 1.5, 8, 2),
    (200, 2.5, 32, 4),
    (331, 3.0, 16, 8),       # non-multiple row count
    (512, 2.0, 128, 4),
])
def test_sptrsv_kernel_shapes(n, avg, chunk, max_deps):
    L = generators.random_lower(n, avg_offdiag=avg, seed=n, max_back=24)
    lv = build_levels(L)
    sched = schedule_for_csr(L, lv, chunk=chunk, max_deps=max_deps,
                             dtype=np.float32)
    b = np.random.default_rng(n).standard_normal(n)
    x_ref = solve_csr_seq(L, b)
    x_pal = ops.sptrsv_solve(sched, b, interpret=True)
    x_oracle = ops.sptrsv_solve(sched, b, use_ref=True)
    scale = np.maximum(1.0, np.abs(x_ref).max())
    np.testing.assert_allclose(x_pal, x_oracle, rtol=1e-6, atol=1e-6)
    assert np.abs(x_pal - x_ref).max() / scale < 5e-4


@pytest.mark.parametrize("dtype", [np.float32])
def test_sptrsv_kernel_wide_rows(dtype):
    L = generators.banded(96, 10, seed=2)       # forces row splitting
    lv = build_levels(L)
    sched = schedule_for_csr(L, lv, chunk=16, max_deps=4, dtype=dtype)
    b = np.random.default_rng(0).standard_normal(96)
    x_ref = solve_csr_seq(L, b)
    x_pal = ops.sptrsv_solve(sched, b, interpret=True)
    assert np.abs(x_pal - x_ref).max() < 1e-3


def test_sptrsv_kernel_transformed():
    L = generators.lung2_like(scale=0.05)
    ts = transform(L, AvgLevelCost(), validate=True, codegen=False)
    sched = schedule_for_transformed(ts, chunk=64, max_deps=8)
    b = np.random.default_rng(1).standard_normal(L.n_rows)
    c = ts.preamble(b)
    x_ref = solve_csr_seq(L, b)
    x_pal = ops.sptrsv_solve(sched, c.astype(np.float32), interpret=True)
    scale = np.maximum(1.0, np.abs(x_ref).max())
    assert np.abs(x_pal - x_ref).max() / scale < 5e-4


@pytest.mark.parametrize("n,avg,block", [
    (100, 2.0, 32), (500, 3.0, 128), (77, 1.0, 16),
])
def test_spmv_kernel(n, avg, block):
    m = generators.random_lower(n, avg_offdiag=avg, seed=7)
    x = np.random.default_rng(3).standard_normal(n)
    y_ref = m.matvec(x)
    y_pal = ops.spmv_ell(m, x, interpret=True, block_rows=block)
    y_oracle = ops.spmv_ell(m, x, use_ref=True, block_rows=block)
    np.testing.assert_allclose(y_pal, y_oracle, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(y_pal, y_ref, rtol=1e-4, atol=1e-4)

"""CSR container: materialized transpose, transpose-aware matvec, and the
reversal permutation backing upper/transpose solves (ISSUE 3 satellites)."""
import numpy as np
from _optional_deps import given, settings, st

from repro.sparse import generators
from repro.sparse.csr import CSR, from_coo, reverse_both, tril, triu


def _random_rect(n_rows, n_cols, density, seed):
    rng = np.random.default_rng(seed)
    mask = rng.random((n_rows, n_cols)) < density
    rows, cols = np.nonzero(mask)
    vals = rng.standard_normal(rows.size)
    return from_coo(rows, cols, vals, (n_rows, n_cols), sum_duplicates=False)


def test_transpose_matches_dense():
    for seed in range(3):
        A = _random_rect(40, 25, 0.15, seed)
        At = A.transpose()
        assert At.shape == (25, 40)
        np.testing.assert_array_equal(At.to_dense(), A.to_dense().T)
        At.check()                          # valid, sorted, duplicate-free
        # involution
        np.testing.assert_array_equal(At.transpose().to_dense(),
                                      A.to_dense())


@given(st.integers(5, 60), st.integers(0, 10**5))
@settings(max_examples=20, deadline=None)
def test_transpose_property(n, seed):
    L = generators.random_lower(n, avg_offdiag=2.0, seed=seed, max_back=10)
    np.testing.assert_array_equal(L.transpose().to_dense(), L.to_dense().T)


def test_matvec_transpose_vector_and_batched():
    A = _random_rect(30, 22, 0.2, 3)
    x = np.random.default_rng(0).standard_normal(30)
    X = np.random.default_rng(1).standard_normal((30, 4))
    np.testing.assert_allclose(A.matvec(x, transpose=True),
                               A.to_dense().T @ x, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(A.matvec(X, transpose=True),
                               A.to_dense().T @ X, rtol=1e-12, atol=1e-12)
    # forward path unchanged
    y = np.random.default_rng(2).standard_normal(22)
    np.testing.assert_allclose(A.matvec(y), A.to_dense() @ y,
                               rtol=1e-12, atol=1e-12)


def test_matvec_transpose_equals_transposed_matvec():
    L = generators.banded(50, 7, seed=5)
    x = np.random.default_rng(3).standard_normal(50)
    np.testing.assert_allclose(L.matvec(x, transpose=True),
                               L.transpose().matvec(x),
                               rtol=1e-12, atol=1e-12)


def test_reverse_both_matches_dense():
    L = generators.random_lower(35, avg_offdiag=2.0, seed=6, max_back=8)
    U = L.transpose()
    R = reverse_both(U)
    np.testing.assert_array_equal(R.to_dense(), U.to_dense()[::-1, ::-1])
    # reversing an upper-triangular matrix yields a lower-triangular one
    assert np.allclose(np.triu(R.to_dense(), 1), 0.0)
    R.check()


def test_triu_mirrors_tril():
    A = _random_rect(20, 20, 0.3, 7)
    d = A.to_dense()
    np.testing.assert_array_equal(triu(A).to_dense(), np.triu(d))
    np.testing.assert_array_equal(triu(A, keep_diagonal=False).to_dense(),
                                  np.triu(d, 1))
    np.testing.assert_array_equal(tril(A).to_dense(), np.tril(d))

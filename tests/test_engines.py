"""Engine protocol + registry: resolution, capabilities, error paths, and
the string-kwarg deprecation shim (ISSUE 3 tentpole)."""
import warnings

import numpy as np
import pytest

from repro.solver import (Engine, PallasEngine, available_engines,
                          default_engine, engine_capabilities, get_engine,
                          register_engine, registered_engines,
                          resolve_engine, schedule_for_csr, solve,
                          solve_csr_seq, to_device)
from repro.solver import engines as engines_mod
from repro.sparse import build_levels, generators


def _small_problem(n=120, seed=7):
    L = generators.random_lower(n, avg_offdiag=2.0, seed=seed, max_back=15)
    sched = schedule_for_csr(L, build_levels(L), chunk=32, max_deps=4)
    b = np.random.default_rng(0).standard_normal(n)
    return L, sched, b


def test_registry_contents_and_capabilities():
    names = registered_engines()
    assert {"scan", "unrolled", "pallas", "pallas-interpret",
            "sharded"} <= set(names)
    assert set(available_engines()) <= set(names)
    caps = engine_capabilities()
    for name in names:
        c = caps[name]
        assert c["name"] == name
        assert isinstance(c["supports_batched_rhs"], bool)
        assert isinstance(c["supports_pallas_backend"], bool)
        assert c["dtypes"]
    assert caps["scan"]["supports_batched_rhs"]
    assert caps["pallas"]["supports_pallas_backend"]
    assert not caps["scan"]["supports_pallas_backend"]


def test_resolve_engine_paths():
    assert resolve_engine(None) is default_engine()
    assert resolve_engine("scan").name == "scan"
    eng = get_engine("unrolled")
    assert resolve_engine(eng) is eng              # instance passes through
    with pytest.raises(TypeError, match="engine spec"):
        resolve_engine(42)


def test_unknown_engine_raises_with_registered_list():
    with pytest.raises(ValueError, match="unknown engine 'palas'"):
        get_engine("palas")
    with pytest.raises(ValueError, match="scan"):      # names the options
        resolve_engine("definitely-not-an-engine")


def test_levelset_solve_unknown_engine_raises():
    """Regression (ISSUE 3 satellite): the seed silently sent 'pallas' and
    any typo to the unrolled engine; unknown names must now raise naming
    the registered options."""
    _, sched, b = _small_problem()
    with pytest.raises(ValueError, match="registered engines"):
        solve(sched, b, engine="unroled")       # typo must not fall through
    with pytest.raises(ValueError, match="scan"):
        solve(sched, b, engine="no-such-engine")


def test_levelset_solve_pallas_actually_runs_pallas():
    """'pallas' used to silently mean 'unrolled'; through the registry it
    must produce the (correct) pallas-kernel solve."""
    L, sched, b = _small_problem()
    x = solve(sched, b, engine=get_engine("pallas-interpret"))
    x_ref = solve_csr_seq(L, b)
    assert np.abs(x - x_ref).max() / max(1.0, np.abs(x_ref).max()) < 2e-5


def test_levelset_solve_string_shim_warns_and_works():
    L, sched, b = _small_problem()
    with pytest.warns(DeprecationWarning, match="deprecated"):
        x = solve(sched, b, engine="scan")
    x_ref = solve_csr_seq(L, b)
    assert np.abs(x - x_ref).max() / max(1.0, np.abs(x_ref).max()) < 2e-5


def test_levelset_solve_engine_instance_no_warning():
    _, sched, b = _small_problem()
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        solve(sched, b, engine=get_engine("scan"))
        solve(sched, b)                         # default: no shim, no warning


def test_every_available_engine_solves_batched():
    L, sched, b = _small_problem()
    import jax.numpy as jnp
    ds = to_device(sched)
    B = np.random.default_rng(1).standard_normal((L.n_rows, 3))
    for name in available_engines():
        eng = get_engine(name)
        fn = eng.compile(ds)
        x = np.asarray(fn(jnp.asarray(b, ds.dtype)))
        x_ref = solve_csr_seq(L, b)
        assert np.abs(x - x_ref).max() < 2e-4, name
        if eng.supports_batched_rhs:
            X = np.asarray(fn(jnp.asarray(B, ds.dtype)))
            for j in range(3):
                assert np.abs(X[:, j] - solve_csr_seq(L, B[:, j])).max() \
                    < 2e-4, name


def test_internal_string_shim_use_is_an_error():
    """The CI deprecation gate (pytest.ini filterwarnings): a string-engine
    shim warning ORIGINATING FROM a repro module frame is an error, while
    the same call from user/test code only warns (covered above).  Simulated
    by exec'ing a caller into repro.solver.levelset's own namespace."""
    from repro.solver import levelset
    _, sched, b = _small_problem()
    src = ("def _internal_caller(sched, b):\n"
           "    return solve(sched, b, engine='scan')\n")
    exec(compile(src, levelset.__file__, "exec"), levelset.__dict__)
    try:
        with pytest.raises(DeprecationWarning):
            levelset._internal_caller(sched, b)
    finally:
        del levelset.__dict__["_internal_caller"]


def test_register_engine_collision_and_custom():
    class EchoEngine(Engine):
        name = "echo-test"
        supports_batched_rhs = False

        def compile(self, dsched):
            return lambda c: c

    try:
        register_engine(EchoEngine())
        assert "echo-test" in registered_engines()
        with pytest.raises(ValueError, match="already registered"):
            register_engine(EchoEngine())
        register_engine(EchoEngine(), overwrite=True)   # explicit replace ok
        assert resolve_engine("echo-test").name == "echo-test"
    finally:
        engines_mod._REGISTRY.pop("echo-test", None)


def test_operator_accepts_unregistered_engine_instance():
    """from_csr must honor a custom Engine instance that is NOT in the
    registry (and not silently swap a same-named registered instance in);
    compiled-fn caching is per instance, not per name."""
    from repro.solver import TriangularOperator, solve_csr_seq

    class CountingScan(Engine):
        name = "scan"                   # shadows the registered name

        def __init__(self):
            self.compiles = 0

        def compile(self, dsched):
            self.compiles += 1
            import jax
            from repro.solver.levelset import solve_scan
            return jax.jit(lambda c: solve_scan(dsched, c))

    L = generators.random_lower(60, avg_offdiag=2.0, seed=2, max_back=8)
    mine = CountingScan()
    op = TriangularOperator.from_csr(L, tune="no_rewriting", chunk=16,
                                     max_deps=4, engine=mine, cache=False)
    assert op._engine is mine           # not replaced by the registry's scan
    b = np.random.default_rng(4).standard_normal(60)
    x = op.solve(b)
    assert mine.compiles == 1
    op.solve(b)                         # same instance: compiled fn reused
    assert mine.compiles == 1
    op.solve(b, engine=get_engine("scan"))      # same name, other instance:
    assert mine.compiles == 1                   # must not reuse mine's fn
    x_ref = solve_csr_seq(L, b)
    assert np.abs(x - x_ref).max() / max(1.0, np.abs(x_ref).max()) < 1e-8

    class Unnamed(Engine):
        name = "not-registered-anywhere"

        def compile(self, dsched):
            import jax
            from repro.solver.levelset import solve_scan
            return jax.jit(lambda c: solve_scan(dsched, c))

    op2 = TriangularOperator.from_csr(L, tune="no_rewriting", chunk=16,
                                      max_deps=4, engine=Unnamed(),
                                      cache=False)
    assert op2.engine == "not-registered-anywhere"
    assert np.abs(op2.solve(b) - x_ref).max() < 1e-8


def test_pallas_engine_interpret_pinning():
    eng = PallasEngine(interpret=True, name="tmp-pallas")
    assert eng.interpret is True
    assert get_engine("pallas").interpret is None       # env-default instance
    assert get_engine("pallas-interpret").interpret is True


# -- dtype capability enforcement (ISSUE 5 satellite) -------------------------

def _schedule_with_dtype(dtype, n=100, seed=3):
    L = generators.random_lower(n, avg_offdiag=2.0, seed=seed, max_back=12)
    return L, schedule_for_csr(L, build_levels(L), chunk=32, max_deps=4,
                               dtype=dtype)


def test_pallas_rejects_float64_schedule():
    """Regression: PallasEngine declares dtypes=("float32",) but compile()
    used to silently accept (and cast) a float64 schedule — the module's
    own "never a silent fallback" contract.  The error must name the
    engine and the offending dtype."""
    _, s64 = _schedule_with_dtype(np.float64)
    ds = to_device(s64)
    for name in ("pallas", "pallas-interpret"):
        with pytest.raises(ValueError, match=rf"{name}.*float64"):
            get_engine(name).compile(ds)


def test_dtype_capable_engines_still_compile_float64():
    L, s64 = _schedule_with_dtype(np.float64)
    b = np.random.default_rng(0).standard_normal(L.n_rows)
    x_ref = solve_csr_seq(L, b)
    for name in ("scan", "unrolled"):
        eng = get_engine(name)
        assert "float64" in eng.dtypes
        x = np.asarray(eng.compile(to_device(s64))(b))
        assert np.abs(x - x_ref).max() < 1e-4, name


def test_operator_surfaces_pallas_dtype_violation():
    """The capability check fires through the serving facade too: a
    float64 operator asked to solve via the pallas engine never silently
    casts to float32 — the rejection downgrades the solve through the
    engine fallback chain, warned and recorded in op.stats
    (docs/robustness.md; the bare-engine compile raise is covered by
    test_pallas_rejects_float64_schedule)."""
    from repro.core.resilience import EngineFallbackWarning
    from repro.solver import TriangularOperator
    L = generators.random_lower(80, avg_offdiag=2.0, seed=9, max_back=10)
    op = TriangularOperator.from_csr(L, tune="no_rewriting", chunk=16,
                                     max_deps=4, dtype=np.float64,
                                     cache=False)
    b = np.random.default_rng(1).standard_normal(80)
    assert np.isfinite(op.solve(b)).all()       # scan path: float64 is fine
    with pytest.warns(EngineFallbackWarning, match="float64"):
        x = op.solve(b, engine="pallas-interpret")
    assert np.isfinite(x).all()
    assert op.stats.last_fallback == "pallas-interpret->scan"

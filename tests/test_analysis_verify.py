"""Static verifier suite (ISSUE 10 tentpole + satellites).

Three layers:

* acceptance — every strategy's compiled schedule certifies, and the
  certificate's quality metrics agree with the schedule's own accounting
  (`num_steps`, `flops`, `padded_flops`);
* rejection — each manufactured static defect (step reorder, duplicate
  row finalization, out-of-bounds ELL gather, corrupt replay plan) is
  refused with a typed error naming the check/step/lane, both through the
  pure mutators and (chaos-marked) through the `core.faults` injectors
  wrapping a strict `from_csr` — i.e. BEFORE a solve could return a
  finite wrong answer;
* wiring — strict health verifies once per built payload, the
  certificate rides the memory/disk cache, and the re-verification cost
  on a warm cache is bounded (acceptance criterion: <= 10%).
"""
import time

import numpy as np
import pytest

from _optional_deps import given, settings, st
from repro.analysis import (certificate_dict, verify_level_schedule,
                            verify_schedule_values)
from repro.analysis.verify import audit_transformed_system
from repro.core import faults
from repro.core.portfolio import make_strategy
from repro.core.resilience import (ScheduleInvariantError,
                                   TransformInvariantError)
from repro.core.transform import transform
from repro.solver import (TriangularOperator, solve_csr_seq,
                          validate_schedule)
from repro.solver.schedule import schedule_for_csr, schedule_for_transformed
from repro.sparse import build_levels, generators
from repro.sparse.csr import tril

STRATEGIES = ("no_rewriting", "avgLevelCost", "constrained_avg",
              "critical_path")


@pytest.fixture(autouse=True)
def _fresh_memory_cache():
    TriangularOperator.clear_memory_cache()
    yield
    TriangularOperator.clear_memory_cache()


def _build(L, strategy, chunk=64, max_deps=8):
    ts = transform(L, make_strategy(strategy), validate=False, codegen=False)
    sched = schedule_for_transformed(ts, chunk=chunk, max_deps=max_deps)
    return ts, sched


# -- acceptance ---------------------------------------------------------------

@pytest.mark.parametrize("strategy", STRATEGIES)
def test_valid_schedules_certify(strategy):
    L = generators.lung2_like(scale=0.08)
    ts, sched = _build(L, strategy)
    cert = verify_level_schedule(sched, ts.A, ts.diag)
    assert cert.steps == sched.num_steps
    assert cert.flops == sched.flops()
    assert cert.padded_flops == sched.padded_flops()
    assert cert.n == L.n_rows
    assert cert.nnz == int((ts.A.data != 0).sum())
    assert 0 < cert.critical_path <= cert.steps
    assert cert.checks  # every structural + value pass ran


@pytest.mark.parametrize("gen, kwargs", [
    (generators.chain, dict(n=60)),
    (generators.banded, dict(n=200, bandwidth=5)),
    (generators.random_lower, dict(n=150, avg_offdiag=2.5, max_back=20)),
])
def test_untransformed_schedules_certify(gen, kwargs):
    L = gen(**kwargs, seed=3)
    sched = schedule_for_csr(L, build_levels(L), chunk=32, max_deps=4)
    cert = verify_level_schedule(sched, tril(L, keep_diagonal=False),
                                 L.diagonal_fast())
    assert cert.steps == sched.num_steps
    assert cert.padded_flops == sched.padded_flops()


def test_certificate_dict_roundtrip():
    L = generators.banded(n=100, bandwidth=4, seed=1)
    sched = schedule_for_csr(L, build_levels(L), chunk=32, max_deps=4)
    cert = verify_level_schedule(sched, tril(L, keep_diagonal=False),
                                 L.diagonal_fast(), devices=4)
    d = certificate_dict(cert)
    assert d["steps"] == cert.steps
    assert d["devices"] == 4
    assert d["cross_device_edges"] == cert.cross_device_edges
    assert isinstance(d["group_widths"], list)
    import json
    json.dumps(d)   # JSON-able end to end


def test_transform_audit_accepts_all_strategies():
    L = generators.lung2_like(scale=0.08)
    for strategy in STRATEGIES:
        ts = transform(L, make_strategy(strategy), validate=False,
                       codegen=False)
        facts = audit_transformed_system(ts)
        assert facts["rows"] == L.n_rows
        assert facts["nnz_A"] == ts.A.nnz


# -- rejection: pure mutators -------------------------------------------------

def _banded_sched():
    L = generators.banded(n=200, bandwidth=5, seed=7)
    return L, schedule_for_csr(L, build_levels(L), chunk=32, max_deps=4)


def test_reordered_step_is_a_race():
    L, sched = _banded_sched()
    bad = faults.swap_schedule_steps(sched)
    with pytest.raises(ScheduleInvariantError) as ei:
        verify_level_schedule(bad, tril(L, keep_diagonal=False),
                              L.diagonal_fast())
    assert ei.value.check == "race"
    assert ei.value.step >= 0 and ei.value.lane >= 0
    assert "step" in str(ei.value)      # error names the location


def test_duplicate_row_breaks_bijection():
    L, sched = _banded_sched()
    bad = faults.duplicate_schedule_row(sched)
    with pytest.raises(ScheduleInvariantError) as ei:
        verify_level_schedule(bad, tril(L, keep_diagonal=False),
                              L.diagonal_fast())
    assert ei.value.check == "bijection"
    assert ei.value.step >= 0 and ei.value.lane >= 0


def test_oob_index_is_caught():
    L, sched = _banded_sched()
    bad = faults.oob_schedule_index(sched)
    with pytest.raises(ScheduleInvariantError) as ei:
        verify_level_schedule(bad, tril(L, keep_diagonal=False),
                              L.diagonal_fast())
    assert ei.value.check == "index-bounds"
    assert ei.value.step >= 0 and ei.value.lane >= 0


def test_corrupt_plan_fails_transform_audit():
    L = generators.banded(n=200, bandwidth=5, seed=7)
    ts = transform(L, make_strategy("avgLevelCost"), validate=False,
                   codegen=False)
    for mode in ("target", "row"):
        with pytest.raises(TransformInvariantError) as ei:
            audit_transformed_system(faults.corrupt_plan(ts, mode))
        assert ei.value.check == "replay-bounds"


def test_poisoned_values_fail_value_checks():
    L, sched = _banded_sched()
    bad = faults.poison_schedule(sched)
    with pytest.raises(ScheduleInvariantError) as ei:
        verify_schedule_values(bad, tril(L, keep_diagonal=False),
                               L.diagonal_fast())
    assert ei.value.check in ("finite", "dinv")
    wrong = faults.scale_schedule(sched, 2.0)
    with pytest.raises(ScheduleInvariantError) as ei:
        verify_schedule_values(wrong, tril(L, keep_diagonal=False),
                               L.diagonal_fast())
    assert ei.value.check == "dinv"


def test_validate_schedule_shim_raises_typed():
    L, sched = _banded_sched()
    bad = faults.swap_schedule_steps(sched)
    with pytest.raises(ScheduleInvariantError):
        validate_schedule(bad, tril(L, keep_diagonal=False),
                          L.diagonal_fast())


# -- rejection: injectors through the strict build path (chaos) ---------------

@pytest.mark.chaos
@pytest.mark.parametrize("injector, exc, check", [
    (faults.reorder_schedule_step, ScheduleInvariantError, "race"),
    (faults.duplicate_lane_row, ScheduleInvariantError, "bijection"),
    (faults.oob_ell_index, ScheduleInvariantError, "index-bounds"),
    (faults.corrupt_replay_plan, TransformInvariantError, "replay-bounds"),
])
def test_injected_defects_rejected_before_solve(injector, exc, check):
    """Every static-defect class dies in from_csr(health='strict') — no
    operator exists afterwards, so no solve can return a finite wrong
    answer from the defective artifact."""
    L = generators.banded(n=200, bandwidth=5, seed=7)
    with injector() as count:
        with pytest.raises(exc) as ei:
            TriangularOperator.from_csr(L, "avgLevelCost", cache=False,
                                        health="strict")
        assert count["calls"] >= 1          # the fault actually fired
    assert ei.value.check == check
    if isinstance(ei.value, ScheduleInvariantError):
        assert ei.value.step >= 0 and ei.value.lane >= 0


@pytest.mark.chaos
def test_defect_solves_finite_without_verifier():
    """The threat model is real: a reordered schedule still SOLVES to a
    finite (wrong) answer on the refinement-free serving path when
    verification is off — only the verifier turns it into a typed
    build-time rejection.  (Iterative refinement can repair a mild race
    after the fact, which is exactly why the defect is silent.)"""
    L = generators.banded(n=200, bandwidth=5, seed=7)
    b = np.random.default_rng(0).standard_normal(L.n_rows)
    x_ref = solve_csr_seq(L, b)
    with faults.reorder_schedule_step():
        op = TriangularOperator.from_csr(L, "no_rewriting", cache=False,
                                         health="off")
        x = np.asarray(op.solve(b, health="off", max_refine=0))
    assert np.isfinite(x).all()
    assert np.abs(x - x_ref).max() > 1e-3   # ...and silently wrong


# -- strict wiring ------------------------------------------------------------

def test_strict_build_certifies_and_caches(tmp_path):
    L = generators.banded(n=200, bandwidth=5, seed=9)
    op = TriangularOperator.from_csr(L, "no_rewriting", cache_dir=tmp_path,
                                     health="strict")
    cert = op.certificate
    assert cert is not None and cert.steps == op._sched.num_steps
    # memory hit reuses the stashed certificate (same object, no re-run)
    op2 = TriangularOperator.from_csr(L, "no_rewriting", cache_dir=tmp_path,
                                      health="strict")
    assert op2.stats.cache_source == "memory"
    assert op2.certificate is cert
    # the certificate rides the DISK artifact too (verified pre-store)
    TriangularOperator.clear_memory_cache()
    op3 = TriangularOperator.from_csr(L, "no_rewriting", cache_dir=tmp_path,
                                      health="strict")
    assert op3.stats.cache_source == "disk"
    assert op3.certificate is not None
    assert op3.certificate.steps == cert.steps


def test_default_build_skips_verification(tmp_path):
    L = generators.banded(n=150, bandwidth=4, seed=2)
    op = TriangularOperator.from_csr(L, "no_rewriting", cache_dir=tmp_path)
    assert op.certificate is None
    # explicit verify() works regardless of policy and stashes the proof
    cert = op.verify(devices=2)
    assert op.certificate is cert and cert.devices == 2


def test_update_values_strict_verifies_values(tmp_path):
    L = generators.banded(n=200, bandwidth=5, seed=4)
    op = TriangularOperator.from_csr(L, "avgLevelCost", cache_dir=tmp_path,
                                     health="strict")
    b = np.random.default_rng(1).standard_normal(L.n_rows)
    L2 = L.with_data(L.data * 1.7)
    op.update_values(L2, health="strict")
    x = np.asarray(op.solve(b))
    assert np.abs(x - solve_csr_seq(L2, b)).max() < 1e-3
    # a poisoned value repack dies in update_values, operator unchanged
    with faults.corrupt_values_payload() as count:
        with pytest.raises(ScheduleInvariantError) as ei:
            op.update_values(L.with_data(L.data * 0.5), health="strict")
    assert count["calls"] >= 1
    assert ei.value.check in ("finite", "dinv")
    x2 = np.asarray(op.solve(b))            # still bound to L2's values
    assert np.abs(x2 - solve_csr_seq(L2, b)).max() < 1e-3


def test_cached_strict_overhead_bounded(tmp_path):
    """Acceptance criterion: verify overhead on a cached lung2 build is
    <= 10% — strict cache hits reuse the stashed certificate instead of
    re-verifying."""
    L = generators.lung2_like(scale=0.3)
    TriangularOperator.from_csr(L, "avgLevelCost", cache_dir=tmp_path,
                                health="strict")    # warm + certified

    def best_of(health, reps=7):
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            op = TriangularOperator.from_csr(
                L, "avgLevelCost", cache_dir=tmp_path, health=health)
            best = min(best, time.perf_counter() - t0)
            assert op.stats.cache_source == "memory"
        return best

    t_off = best_of(None)
    t_strict = best_of("strict")
    # 10% relative plus a 2ms absolute floor for scheduler/timer noise on
    # a sub-millisecond cache hit
    assert t_strict <= 1.10 * t_off + 2e-3, (t_strict, t_off)


# -- property-based (hypothesis; skipped when not installed) ------------------

@given(st.integers(20, 90), st.integers(0, 10**5),
       st.sampled_from(STRATEGIES))
@settings(max_examples=16, deadline=None)
def test_property_accept_iff_oracle(n, seed, strategy):
    """verifier-accepts <=> schedule-matches-oracle: a strict build (which
    certifies the artifact) must solve to the sequential oracle."""
    L = generators.random_lower(n, avg_offdiag=2.5, seed=seed, max_back=12)
    op = TriangularOperator.from_csr(L, strategy, cache=False,
                                     health="strict")
    assert op.certificate is not None
    b = np.random.default_rng(seed + 1).standard_normal(n)
    x = np.asarray(op.solve(b))
    x_ref = solve_csr_seq(L, b)
    scale = max(1.0, np.abs(x_ref).max())
    assert np.abs(x - x_ref).max() / scale < 5e-4


@given(st.integers(20, 90), st.integers(0, 10**5))
@settings(max_examples=16, deadline=None)
def test_property_mutations_rejected(n, seed):
    """Unconditional defect classes never certify, whatever the system."""
    from hypothesis import assume
    L = generators.random_lower(n, avg_offdiag=2.5, seed=seed, max_back=12)
    sched = schedule_for_csr(L, build_levels(L), chunk=16, max_deps=4)
    A, diag = tril(L, keep_diagonal=False), L.diagonal_fast()
    try:
        bad = faults.oob_schedule_index(sched)
    except ValueError:
        assume(False)       # diagonal-only system: nothing to corrupt
    with pytest.raises(ScheduleInvariantError):
        verify_level_schedule(bad, A, diag)
    try:
        bad = faults.duplicate_schedule_row(sched)
    except ValueError:
        return              # fully packed: no padding lane to duplicate on
    with pytest.raises(ScheduleInvariantError):
        verify_level_schedule(bad, A, diag)


@given(st.integers(20, 120), st.integers(0, 10**5),
       st.sampled_from([(16, 4), (64, 8)]))
@settings(max_examples=16, deadline=None)
def test_property_certificate_agrees_with_schedule(n, seed, cfg):
    chunk, max_deps = cfg
    L = generators.random_lower(n, avg_offdiag=2.5, seed=seed, max_back=12)
    sched = schedule_for_csr(L, build_levels(L), chunk=chunk,
                             max_deps=max_deps)
    cert = verify_level_schedule(sched, tril(L, keep_diagonal=False),
                                 L.diagonal_fast())
    assert cert.steps == sched.num_steps
    assert cert.flops == sched.flops()
    assert cert.padded_flops == sched.padded_flops()
    assert cert.critical_path <= cert.steps

"""Pattern-frozen refactorization fast path (ISSUE 7).

Differential suite: `op.update_values(L2)` / `Preconditioner.refactor(A2)`
must be BITWISE identical to a fresh build on the new values for every
engine and sweep orientation — and must provably skip the structure-derived
staging (level analysis, transformation, tuning, schedule compilation).
Cache-key regression: the pattern/value key split; property-based checks
ride behind the optional-hypothesis guard; chaos cases prove a poisoned or
drifted update is caught by typed guards, never a finite wrong answer.
"""
import sys

import numpy as np
import pytest

from repro.core import faults
from repro.core.resilience import NumericalHealthError, PatternMismatchError
from repro.precond import Preconditioner, ic0, ilu0, refactor
from repro.solver.operator import (TriangularOperator, matrix_fingerprint,
                                   value_fingerprint)
from repro.sparse import generators
from repro.sparse.csr import CSR, from_coo, same_pattern

from _optional_deps import HAS_HYPOTHESIS, given, settings, st


# -- shared fixtures ----------------------------------------------------------


def _lower(n=160, seed=0):
    return generators.random_lower(n, avg_offdiag=2.5, seed=seed,
                                   max_back=25)


def _revalued(L, seed=1, diag_scale=1.6):
    """Same pattern, perturbed values; the diagonal is scaled (not noised)
    so triangular solves stay well-conditioned."""
    rng = np.random.default_rng(seed)
    rows = np.repeat(np.arange(L.n_rows), L.row_nnz())
    d_mask = L.indices == rows
    data = L.data * (1.0 + 0.25 * rng.standard_normal(L.nnz))
    data[d_mask] = L.data[d_mask] * diag_scale
    return L.with_data(data)


def _general_square(n=150, seed=5):
    """General square matrix with a full diagonal (for ilu0)."""
    B = generators.random_lower(n, avg_offdiag=2.0, seed=seed, max_back=20)
    Bt = B.transpose()
    rows = np.concatenate([np.repeat(np.arange(n), B.row_nnz()),
                           np.repeat(np.arange(n), Bt.row_nnz())])
    cols = np.concatenate([B.indices, Bt.indices])
    vals = np.concatenate([B.data, 0.3 * Bt.data])
    return from_coo(rows, cols, vals, (n, n))


def _revalued_diag_dominant(A, seed=2):
    rng = np.random.default_rng(seed)
    rows = np.repeat(np.arange(A.n_rows), A.row_nnz())
    d_mask = A.indices == rows
    data = A.data * (1.0 + 0.1 * rng.standard_normal(A.nnz))
    data[d_mask] = A.data[d_mask] * 2.0
    return A.with_data(data)


def _revalued_spd(A, seed=2):
    """Symmetric value perturbation (keeps ic0's SPD validation happy):
    one deterministic factor per unordered index pair, boosted diagonal."""
    rows = np.repeat(np.arange(A.n_rows), A.row_nnz())
    key = (np.minimum(rows, A.indices) * A.n_cols
           + np.maximum(rows, A.indices))
    data = A.data * (1.0 + 0.1 * np.sin(key * 12.9898 + seed))
    d_mask = A.indices == rows
    data[d_mask] = A.data[d_mask] * 2.0
    return A.with_data(data)


@pytest.fixture(scope="module")
def rhs():
    return np.random.default_rng(42).standard_normal(160)


# -- differential suite: every engine x every sweep ---------------------------

SWEEPS = [("lower", False), ("lower", True), ("upper", False),
          ("upper", True)]
ENGINES = ["scan", "unrolled", "pallas-interpret", "sharded"]


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("side,transpose", SWEEPS)
def test_update_values_matches_fresh_bitwise(engine, side, transpose, rhs):
    L = _lower()
    M = L if side == "lower" else L.transpose()
    M2 = _revalued(M, seed=3)
    kw = dict(side=side, transpose=transpose, engine=engine, cache=False)
    op = TriangularOperator.from_csr(M, "avgLevelCost", **kw)
    op.solve(rhs)                              # prime compiled fns/preamble
    fresh = TriangularOperator.from_csr(M2, "avgLevelCost", **kw)
    assert fresh.strategy == op.strategy
    x_fresh = fresh.solve(rhs)
    assert op.update_values(M2) is op
    x_upd = op.solve(rhs)
    assert np.array_equal(np.asarray(x_upd), np.asarray(x_fresh))
    assert op.stats.value_updates == 1
    assert op.stats.last_update_ms >= 0.0


def test_update_values_autotuned_matches_fresh(rhs):
    """Auto-tuned operators refactor too: the frozen tuner pick is reused
    and the solve matches a fresh build pinned to the same strategy."""
    L = _lower()
    L2 = _revalued(L, seed=9)
    op = TriangularOperator.from_csr(L, cache=False)
    op.update_values(L2)
    # model-ranked tuning scores the PATTERN, so a fresh auto-tune on the
    # revalued matrix lands on the same pick
    fresh = TriangularOperator.from_csr(L2, cache=False)
    assert fresh.strategy == op.strategy
    assert np.array_equal(np.asarray(op.solve(rhs)),
                          np.asarray(fresh.solve(rhs)))


def test_update_values_batched_rhs(rhs):
    L, L2 = _lower(), _revalued(_lower(), seed=4)
    B = np.random.default_rng(0).standard_normal((160, 3))
    op = TriangularOperator.from_csr(L, "avgLevelCost", cache=False)
    fresh = TriangularOperator.from_csr(L2, "avgLevelCost", cache=False)
    op.update_values(L2)
    assert np.array_equal(np.asarray(op.solve(B)),
                          np.asarray(fresh.solve(B)))


def test_update_values_refined_fp64(rhs):
    """The fp64 iterative-refinement path sees the NEW matrix (residuals
    against L2, not the stale L)."""
    L, L2 = _lower(), _revalued(_lower(), seed=6)
    op = TriangularOperator.from_csr(L, "avgLevelCost", cache=False,
                                     dtype=np.float64)
    fresh = TriangularOperator.from_csr(L2, "avgLevelCost", cache=False,
                                        dtype=np.float64)
    op.update_values(L2)
    x = np.asarray(op.solve(rhs, max_refine=4, refine_tol=1e-12))
    assert np.array_equal(x, np.asarray(fresh.solve(rhs, max_refine=4,
                                                    refine_tol=1e-12)))
    r = rhs - L2.matvec(x)
    assert np.linalg.norm(r) <= 1e-10 * np.linalg.norm(rhs)


def test_update_values_repeated_steps(rhs):
    """A time-stepping sequence of updates stays exact at every step."""
    L = _lower()
    op = TriangularOperator.from_csr(L, "avgLevelCost", cache=False)
    for step in range(4):
        L_k = _revalued(L, seed=100 + step)
        op.update_values(L_k)
        fresh = TriangularOperator.from_csr(L_k, "avgLevelCost", cache=False)
        assert np.array_equal(np.asarray(op.solve(rhs)),
                              np.asarray(fresh.solve(rhs)))
    assert op.stats.value_updates == 4


# -- staging must NOT re-run (acceptance: counters/monkeypatch) ---------------


class _Boom(Exception):
    pass


@pytest.fixture()
def forbid_staging(monkeypatch):
    """Arms a tripwire: after calling the returned function, transform /
    portfolio tuning / schedule compilation raise if re-entered —
    update_values and refactor must never call them.  (Armed AFTER the
    initial from_csr builds, which legitimately stage.)"""
    import repro.core.portfolio as portfolio_mod
    transform_mod = sys.modules["repro.core.transform"]
    schedule_mod = sys.modules["repro.solver.schedule"]

    def boom(*a, **k):
        raise _Boom("from_csr-style staging re-entered on the fast path")

    def arm():
        monkeypatch.setattr(transform_mod, "transform", boom)
        monkeypatch.setattr(portfolio_mod.StrategyPortfolio, "tune", boom)
        monkeypatch.setattr(portfolio_mod.StrategyPortfolio, "tune_pair",
                            boom)
        monkeypatch.setattr(schedule_mod, "build_schedule", boom)

    return arm


def test_update_values_skips_staging(forbid_staging, rhs):
    L = _lower()
    op = TriangularOperator.from_csr(L, "avgLevelCost", cache=False)
    op.solve(rhs)
    forbid_staging()
    op.update_values(_revalued(L, seed=8))
    x = op.solve(rhs)
    assert np.isfinite(np.asarray(x)).all()


def test_update_values_skips_staging_before_first_solve(forbid_staging):
    """Even an operator that never solved (no materialized preamble) must
    not re-enter staging during the update itself."""
    L = _lower()
    op = TriangularOperator.from_csr(L, "avgLevelCost", cache=False)
    forbid_staging()
    op.update_values(_revalued(L, seed=8))


def test_precond_refactor_skips_staging(forbid_staging):
    A = generators.poisson2d_spd(10, 10)
    P = Preconditioner.ic0(A, "avgLevelCost", cache=False)
    r = np.random.default_rng(1).standard_normal(A.n_rows)
    P.apply(r)
    forbid_staging()
    P.refactor(_revalued_spd(A))
    assert np.isfinite(P.apply(r)).all()


def test_scan_executable_reused_across_update(rhs):
    """The scan engine's staged jit keys on tile shapes, so a value-only
    repack reuses the already-compiled XLA executable (no retrace)."""
    from repro.solver import levelset
    cache_size = getattr(levelset._scan_jit, "_cache_size", None)
    if cache_size is None:
        pytest.skip("jax jit cache-size introspection unavailable")
    L = _lower()
    op = TriangularOperator.from_csr(L, "avgLevelCost", cache=False,
                                     engine="scan")
    op.solve(rhs)
    before = cache_size()
    op.update_values(_revalued(L, seed=11))
    op.solve(rhs)
    assert cache_size() == before


# -- pattern mismatch ---------------------------------------------------------


def test_update_values_pattern_mismatch_raises():
    L = _lower()
    op = TriangularOperator.from_csr(L, "avgLevelCost", cache=False)
    other = generators.random_lower(160, avg_offdiag=2.5, seed=99,
                                    max_back=25)
    with pytest.raises(PatternMismatchError) as ei:
        op.update_values(other)
    assert "update_values" in str(ei.value)
    # shape mismatch reported distinctly
    small = generators.random_lower(40, avg_offdiag=2.0, seed=0, max_back=5)
    with pytest.raises(PatternMismatchError, match="shape"):
        op.update_values(small)


def test_pattern_mismatch_is_typed_resilience_error():
    from repro.core.resilience import ResilienceError
    assert issubclass(PatternMismatchError, ResilienceError)
    e = PatternMismatchError("boom", where="here", detail="why")
    assert e.where == "here" and e.detail == "why"
    assert "here" in str(e) and "why" in str(e)


# -- cache-key split ----------------------------------------------------------


def test_pattern_key_shared_value_key_not():
    L = _lower()
    L2 = _revalued(L, seed=5)
    assert matrix_fingerprint(L, include_values=False) == \
        matrix_fingerprint(L2, include_values=False)
    assert matrix_fingerprint(L) != matrix_fingerprint(L2)
    assert value_fingerprint(L) != value_fingerprint(L2)
    assert value_fingerprint(L) == value_fingerprint(L.with_data(L.data))


def test_from_csr_pattern_cache_hit(tmp_path, rhs):
    """Equal pattern + different values: from_csr derives the payload from
    the cached artifact (cache_source 'pattern') and matches an uncached
    fresh build bitwise."""
    TriangularOperator.clear_memory_cache()
    L = _lower()
    L2 = _revalued(L, seed=7)
    op = TriangularOperator.from_csr(L, "avgLevelCost", cache_dir=tmp_path)
    assert op.stats.cache_source == "built"
    op2 = TriangularOperator.from_csr(L2, "avgLevelCost", cache_dir=tmp_path)
    assert op2.stats.cache_source == "pattern"
    fresh = TriangularOperator.from_csr(L2, "avgLevelCost", cache=False)
    assert np.array_equal(np.asarray(op2.solve(rhs)),
                          np.asarray(fresh.solve(rhs)))
    # the derived payload was stored under its own full key: exact re-ask
    # is a memory hit now
    op3 = TriangularOperator.from_csr(L2, "avgLevelCost", cache_dir=tmp_path)
    assert op3.stats.cache_source == "memory"


def test_from_csr_pattern_hit_from_disk_only(tmp_path, rhs):
    """The pattern match also works via the disk glob after the memory
    cache (and its pattern index) is gone."""
    TriangularOperator.clear_memory_cache()
    L = _lower()
    TriangularOperator.from_csr(L, "avgLevelCost", cache_dir=tmp_path)
    TriangularOperator.clear_memory_cache()
    op2 = TriangularOperator.from_csr(_revalued(L, seed=13), "avgLevelCost",
                                      cache_dir=tmp_path)
    assert op2.stats.cache_source == "pattern"


def test_update_values_stores_under_new_value_key(tmp_path):
    TriangularOperator.clear_memory_cache()
    L = _lower()
    L2 = _revalued(L, seed=21)
    op = TriangularOperator.from_csr(L, "avgLevelCost", cache_dir=tmp_path)
    op.update_values(L2)
    assert op.stats.cache_source == "pattern"
    # both value keys now live on disk under the shared pattern prefix
    pkey = TriangularOperator._pattern_cache_key(L, op._config)
    found = sorted(tmp_path.glob(f"op-{pkey}-*.pkl"))
    assert len(found) == 2
    # a second update to the SAME values is a memory hit
    op.update_values(L2.with_data(L2.data.copy()))
    assert op.stats.cache_source == "memory"


def test_stale_version_artifact_quarantined(tmp_path):
    """CACHE_VERSION 2 artifacts (and any stale version) quarantine
    cleanly under version 3 — warned, moved to .bad/, rebuilt."""
    import pickle
    from repro.core.resilience import CacheQuarantineWarning
    TriangularOperator.clear_memory_cache()
    L = _lower()
    TriangularOperator.from_csr(L, "avgLevelCost", cache_dir=tmp_path)
    for p in tmp_path.glob("op-*.pkl"):
        payload = pickle.loads(p.read_bytes())
        payload["version"] = 2
        p.write_bytes(pickle.dumps(payload))
    TriangularOperator.clear_memory_cache()
    with pytest.warns(CacheQuarantineWarning, match="stale version 2"):
        op = TriangularOperator.from_csr(L, "avgLevelCost",
                                         cache_dir=tmp_path)
    assert op.stats.cache_source == "built"
    assert list((tmp_path / ".bad").glob("op-*.pkl"))


def test_pattern_derive_never_uses_stale_artifacts(tmp_path):
    """A stale-version artifact must not serve as a pattern-derive base
    either (the glob loader runs the same version gate)."""
    TriangularOperator.clear_memory_cache()
    L = _lower()
    TriangularOperator.from_csr(L, "avgLevelCost", cache_dir=tmp_path)
    faults.corrupt_cache_entries(tmp_path, mode="stale")
    TriangularOperator.clear_memory_cache()
    with pytest.warns(Warning):     # quarantine warning on the glob path
        op = TriangularOperator.from_csr(_revalued(L, seed=2),
                                         "avgLevelCost", cache_dir=tmp_path)
    assert op.stats.cache_source == "built"


# -- preconditioner refactor --------------------------------------------------


def test_ic0_refactor_matches_fresh_bitwise():
    A = generators.poisson2d_spd(12, 12)
    A2 = _revalued_spd(A)
    fac = ic0(A)
    fac2 = refactor(fac, A2)
    fresh = ic0(A2)
    assert np.array_equal(fac2.L.data, fresh.L.data)
    assert same_pattern(fac2.L, fac.L)
    assert fac2.plan is fac.plan


def test_ilu0_refactor_matches_fresh_bitwise():
    G = _general_square()
    G2 = _revalued_diag_dominant(G)
    fac = ilu0(G)
    fac2 = refactor(fac, G2)
    fresh = ilu0(G2)
    assert np.array_equal(fac2.L.data, fresh.L.data)
    assert np.array_equal(fac2.U.data, fresh.U.data)


def test_refactor_no_plan_raises():
    fac = ic0(generators.poisson2d_spd(6, 6))
    import dataclasses
    stripped = dataclasses.replace(fac, plan=None)
    with pytest.raises(ValueError, match="no pattern plan"):
        refactor(stripped, generators.poisson2d_spd(6, 6))


def test_refactor_pattern_mismatch_raises():
    fac = ic0(generators.poisson2d_spd(10, 10))
    with pytest.raises(PatternMismatchError, match="ic0"):
        refactor(fac, generators.poisson2d_spd(11, 11))
    G = _general_square()
    gfac = ilu0(G)
    with pytest.raises(PatternMismatchError, match="ilu0"):
        refactor(gfac, generators.poisson2d_spd(10, 10))


def test_ic0_refactor_matches_dense_cholesky_oracle():
    """On a no-fill (tridiagonal) pattern IC(0) IS the exact Cholesky
    factor — the refactored values must match the dense oracle too."""
    la = pytest.importorskip("numpy.linalg")
    n = 50
    rng = np.random.default_rng(17)
    main = 4.0 + rng.random(n)
    off = -1.0 + 0.1 * rng.random(n - 1)
    rows = np.concatenate([np.arange(n), np.arange(1, n), np.arange(n - 1)])
    cols = np.concatenate([np.arange(n), np.arange(n - 1), np.arange(1, n)])
    vals = np.concatenate([main, off, off])
    T = from_coo(rows, cols, vals, (n, n))
    fac = ic0(T)
    T2 = from_coo(rows, cols, np.concatenate([main * 1.4, off, off]), (n, n))
    fac2 = refactor(fac, T2)

    def dense_L(f):
        Ld = np.zeros((n, n))
        Ld[np.repeat(np.arange(n), f.L.row_nnz()), f.L.indices] = f.L.data
        return Ld

    dense = np.zeros((n, n))
    dense[rows, cols] = vals
    dense2 = np.zeros((n, n))
    dense2[rows, cols] = np.concatenate([main * 1.4, off, off])
    assert np.allclose(dense_L(fac), la.cholesky(dense), rtol=1e-12,
                       atol=1e-12)
    assert np.allclose(dense_L(fac2), la.cholesky(dense2), rtol=1e-12,
                       atol=1e-12)


@pytest.mark.parametrize("kind", ["ic0", "ilu0"])
def test_precond_refactor_apply_matches_fresh(kind):
    if kind == "ic0":
        A = generators.poisson2d_spd(11, 11)
        A2 = _revalued_spd(A)
    else:
        A = _general_square(121)
        A2 = _revalued_diag_dominant(A)
    build = getattr(Preconditioner, kind)
    P = build(A, "avgLevelCost", cache=False)
    r = np.random.default_rng(5).standard_normal(A.n_rows)
    z_before = P.apply(r)
    assert P.refactor(A2) is P
    P_fresh = build(A2, "avgLevelCost", cache=False)
    assert np.array_equal(P.apply(r), P_fresh.apply(r))
    assert not np.array_equal(P.apply(r), z_before)
    assert P.forward.stats.value_updates == 1
    assert P.backward.stats.value_updates == 1


def test_precond_refactor_device_apply_recomposes():
    """device_apply closures over the old payload are dropped on refactor."""
    import jax.numpy as jnp
    A = generators.poisson2d_spd(9, 9)
    P = Preconditioner.ic0(A, "avgLevelCost", cache=False)
    r = np.random.default_rng(2).standard_normal(A.n_rows)
    np.asarray(P.jax_apply(jnp.asarray(r, dtype=np.float32)))
    P.refactor(_revalued_spd(A))
    z_dev = np.asarray(P.jax_apply(jnp.asarray(r, dtype=np.float32)),
                       dtype=np.float64)
    z_host = Preconditioner.ic0(_revalued_spd(A), "avgLevelCost",
                                cache=False).apply(r)
    assert np.allclose(z_dev, z_host, rtol=1e-5, atol=1e-6)


def test_precond_refactor_pattern_mismatch():
    A = generators.poisson2d_spd(10, 10)
    P = Preconditioner.ic0(A, "avgLevelCost", cache=False)
    with pytest.raises(PatternMismatchError):
        P.refactor(generators.poisson2d_spd(11, 11))


# -- chaos: poisoned / drifted updates are caught, never silently wrong ------


@pytest.mark.chaos
def test_chaos_poisoned_update_caught_by_health_guard(rhs):
    L = _lower()
    op = TriangularOperator.from_csr(L, "avgLevelCost", cache=False)
    with faults.corrupt_values_payload() as count:
        op.update_values(_revalued(L, seed=30))
    assert count["calls"] >= 1
    with pytest.raises(NumericalHealthError):
        op.solve(rhs, health="on")


@pytest.mark.chaos
def test_chaos_poisoned_update_recovers_under_fallback(rhs):
    L = _lower()
    L2 = _revalued(L, seed=31)
    op = TriangularOperator.from_csr(L, "avgLevelCost", cache=False)
    with faults.corrupt_values_payload():
        op.update_values(L2)
    from repro.core.resilience import HealthRepairWarning
    with pytest.warns(HealthRepairWarning):
        x = np.asarray(op.solve(rhs, health="fallback"))
    r = rhs - L2.matvec(x)
    assert np.linalg.norm(r) <= 1e-5 * np.linalg.norm(rhs)


@pytest.mark.chaos
def test_chaos_pattern_drift_raises_never_wrong(rhs):
    L = _lower()
    op = TriangularOperator.from_csr(L, "avgLevelCost", cache=False)
    x_before = np.asarray(op.solve(rhs)).copy()
    drifted = faults.pattern_drift(L)
    assert drifted.nnz == L.nnz and drifted.shape == L.shape
    assert not same_pattern(drifted, L)
    with pytest.raises(PatternMismatchError):
        op.update_values(drifted)
    # the operator is untouched: still solves the ORIGINAL system exactly
    assert np.array_equal(np.asarray(op.solve(rhs)), x_before)


@pytest.mark.chaos
def test_chaos_pattern_drift_on_precond(rhs):
    A = generators.poisson2d_spd(10, 10)
    P = Preconditioner.ic0(A, "avgLevelCost", cache=False)
    with pytest.raises(PatternMismatchError):
        P.refactor(faults.pattern_drift(A))


@pytest.mark.chaos
def test_chaos_nonfinite_update_rejected(rhs):
    L = _lower()
    op = TriangularOperator.from_csr(L, "avgLevelCost", cache=False)
    bad = L.with_data(np.where(np.arange(L.nnz) == 3, np.inf, L.data))
    with pytest.raises(NumericalHealthError):
        op.update_values(bad)
    with pytest.raises(NumericalHealthError):
        op.update_values(bad, health="strict")
    # health="off" skips the input gate by explicit request
    op.update_values(bad, health="off")


# -- property-based (hypothesis; skipped when not installed) ------------------


if HAS_HYPOTHESIS:
    matrices = st.integers(min_value=12, max_value=64).flatmap(
        lambda n: st.tuples(st.just(n),
                            st.integers(min_value=0, max_value=10 ** 6),
                            st.integers(min_value=0, max_value=10 ** 6)))
else:                                   # placeholder; tests skip anyway
    matrices = None


@given(matrices)
@settings(max_examples=20, deadline=None)
def test_property_refactor_equals_fresh(params):
    """Random pattern + value sequence: update_values either matches the
    fresh build bitwise or (never) silently diverges."""
    n, seed_pat, seed_val = params
    L = generators.random_lower(n, avg_offdiag=2.0, seed=seed_pat,
                                max_back=max(2, n // 8))
    L2 = _revalued(L, seed=seed_val)
    b = np.random.default_rng(seed_val).standard_normal(n)
    op = TriangularOperator.from_csr(L, "avgLevelCost", cache=False)
    op.update_values(L2)
    fresh = TriangularOperator.from_csr(L2, "avgLevelCost", cache=False)
    assert np.array_equal(np.asarray(op.solve(b)),
                          np.asarray(fresh.solve(b)))


@given(matrices)
@settings(max_examples=20, deadline=None)
def test_property_pattern_fingerprint_invariance(params):
    """Pattern fingerprint is invariant under any value change; the value
    fingerprint is sensitive to every value change."""
    n, seed_pat, seed_val = params
    L = generators.random_lower(n, avg_offdiag=2.0, seed=seed_pat,
                                max_back=max(2, n // 8))
    L2 = _revalued(L, seed=seed_val)
    assert matrix_fingerprint(L, include_values=False) == \
        matrix_fingerprint(L2, include_values=False)
    if not np.array_equal(L.data, L2.data):
        assert value_fingerprint(L) != value_fingerprint(L2)


@given(matrices)
@settings(max_examples=10, deadline=None)
def test_property_drift_always_detected(params):
    """Any single-entry column drift raises PatternMismatchError — never a
    finite wrong answer."""
    n, seed_pat, _ = params
    L = generators.random_lower(n, avg_offdiag=2.5, seed=seed_pat,
                                max_back=max(2, n // 8))
    try:
        drifted = faults.pattern_drift(L)
    except ValueError:
        return                          # no shiftable entry in this draw
    op = TriangularOperator.from_csr(L, "avgLevelCost", cache=False)
    with pytest.raises(PatternMismatchError):
        op.update_values(drifted)

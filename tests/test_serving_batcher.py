"""Micro-batcher flush-policy suite: deterministic units + property tests.

The batcher is pure logic over a synthetic clock (time only enters as
the `now` argument), so every policy claim is testable without
wall-clock races.  The hypothesis section (skipped cleanly when the
optional dep is absent; tests/_optional_deps.py) drives randomized
enqueue/poll schedules and asserts the three invariants the service
relies on: batches never mix keys, FIFO holds within a key, and no
request outlives its linger deadline when `due()` is polled on time.
"""
import numpy as np
import pytest

from repro.serving import Batch, BatchKey, MicroBatcher, SolveRequest
from tests._optional_deps import HAS_HYPOTHESIS, given, settings, st

KA = BatchKey("patA", "v0")
KB = BatchKey("patB", "v0")
KA_V1 = BatchKey("patA", "v1")


def req(key=KA, n=4, tenant="default"):
    return SolveRequest(key=key, b=np.zeros(n), tenant=tenant)


# -- construction -------------------------------------------------------------

def test_invalid_policy_params_raise():
    with pytest.raises(ValueError):
        MicroBatcher(max_width=0)
    with pytest.raises(ValueError):
        MicroBatcher(max_linger_s=-1.0)


# -- width flush --------------------------------------------------------------

def test_width_flush_returns_full_batch_in_fifo_order():
    mb = MicroBatcher(max_width=3, max_linger_s=1.0)
    r1, r2, r3 = req(), req(), req()
    assert mb.enqueue(r1, now=0.0) is None
    assert mb.enqueue(r2, now=0.1) is None
    batch = mb.enqueue(r3, now=0.2)
    assert batch is not None
    assert batch.reason == "width"
    assert batch.requests == [r1, r2, r3]       # FIFO within the key
    assert batch.width == 3
    assert mb.pending() == 0


def test_zero_linger_degenerates_to_immediate_width1():
    mb = MicroBatcher(max_width=8, max_linger_s=0.0)
    batch = mb.enqueue(req(), now=0.0)
    assert batch is not None and batch.width == 1
    assert mb.pending() == 0


def test_keys_never_mix_on_width_flush():
    mb = MicroBatcher(max_width=2, max_linger_s=1.0)
    mb.enqueue(req(KA), now=0.0)
    assert mb.enqueue(req(KB), now=0.1) is None     # different pattern
    assert mb.enqueue(req(KA_V1), now=0.2) is None  # same pattern, new values
    batch = mb.enqueue(req(KA), now=0.3)
    assert batch is not None and batch.key == KA and batch.width == 2
    assert mb.pending() == 2                        # KB and KA_V1 still queued


# -- linger flush -------------------------------------------------------------

def test_linger_deadline_flushes_partial_batch():
    mb = MicroBatcher(max_width=8, max_linger_s=0.5)
    mb.enqueue(req(), now=10.0)
    mb.enqueue(req(), now=10.2)
    assert mb.due(10.4) == []                   # oldest deadline is 10.5
    assert mb.next_deadline() == pytest.approx(10.5)
    [batch] = mb.due(10.5)
    assert batch.reason == "linger" and batch.width == 2
    assert mb.due(10.5) == []                   # idempotent once drained
    assert mb.next_deadline() is None


def test_due_flushes_multiple_keys_in_deadline_order():
    mb = MicroBatcher(max_width=8, max_linger_s=0.5)
    mb.enqueue(req(KB), now=0.0)
    mb.enqueue(req(KA), now=0.2)
    batches = mb.due(1.0)
    assert [b.key for b in batches] == [KB, KA]     # oldest deadline first


def test_enqueue_after_linger_flush_restarts_the_clock():
    mb = MicroBatcher(max_width=8, max_linger_s=0.5)
    mb.enqueue(req(), now=0.0)
    mb.due(0.5)
    mb.enqueue(req(), now=2.0)
    assert mb.due(2.4) == []                # new deadline 2.5, not stale 0.5
    assert len(mb.due(2.5)) == 1


# -- drain --------------------------------------------------------------------

def test_flush_all_drains_every_key_oldest_first():
    mb = MicroBatcher(max_width=8, max_linger_s=100.0)
    mb.enqueue(req(KB), now=0.0)
    mb.enqueue(req(KA), now=0.1)
    mb.enqueue(req(KB), now=0.2)
    batches = mb.flush_all()
    assert [b.key for b in batches] == [KB, KA]
    assert [b.width for b in batches] == [2, 1]
    assert all(b.reason == "drain" for b in batches)
    assert mb.pending() == 0 and mb.pending_keys() == 0


# -- stacking -----------------------------------------------------------------

def test_stack_and_column_round_trip():
    mb = MicroBatcher(max_width=3, max_linger_s=1.0)
    cols = [np.arange(4, dtype=float) + 10 * j for j in range(3)]
    for c in cols:
        last = mb.enqueue(SolveRequest(key=KA, b=c), now=0.0)
    B = last.stack()
    assert B.shape == (4, 3)
    for j, c in enumerate(cols):
        np.testing.assert_array_equal(last.column(B, j), c)


def test_single_request_stack_stays_1d():
    b = Batch(key=KA, requests=[req()])
    assert b.stack().shape == (4,)
    np.testing.assert_array_equal(b.column(b.stack(), 0), np.zeros(4))


# -- property tests (hypothesis; skipped without the optional dep) ------------

# each event: (key_index, gap to next event, poll_before_enqueue)
_EVENTS = st.lists(
    st.tuples(st.integers(min_value=0, max_value=3),
              st.floats(min_value=0.0, max_value=0.3,
                        allow_nan=False, allow_infinity=False),
              st.booleans()),
    min_size=1, max_size=60) if HAS_HYPOTHESIS else None

_KEYS = [KA, KB, KA_V1, BatchKey("patC", "v0", dtype="float64")]


def _drive(events, max_width, max_linger_s):
    """Replay an event schedule, polling due() whenever the next deadline
    has passed; returns (batches, all_requests)."""
    mb = MicroBatcher(max_width=max_width, max_linger_s=max_linger_s)
    batches, requests = [], []
    now = 0.0
    for key_i, gap, poll in events:
        nd = mb.next_deadline()
        if poll and nd is not None and nd <= now:
            batches.extend(mb.due(now))
        r = req(_KEYS[key_i])
        requests.append(r)
        out = mb.enqueue(r, now)
        if out is not None:
            batches.append(out)
        now += gap
        # a timely dispatcher: poll at every deadline that fell in the gap
        while True:
            nd = mb.next_deadline()
            if nd is None or nd > now:
                break
            batches.extend(mb.due(nd))
    batches.extend(mb.flush_all(now))
    return batches, requests


@pytest.mark.skipif(not HAS_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=200, deadline=None)
@given(events=_EVENTS,
       max_width=st.integers(min_value=1, max_value=5),
       linger=st.floats(min_value=0.0, max_value=0.5,
                        allow_nan=False, allow_infinity=False))
def test_batcher_invariants(events, max_width, linger):
    batches, requests = _drive(events, max_width, linger)

    # completeness: every request is served exactly once
    served = [r for b in batches for r in b.requests]
    assert sorted(r.seq for r in served) == sorted(r.seq for r in requests)
    assert len(served) == len(requests)

    for b in batches:
        # width bound and single-key purity
        assert 1 <= b.width <= max_width
        assert all(r.key == b.key for r in b.requests)
        # FIFO within the batch
        seqs = [r.seq for r in b.requests]
        assert seqs == sorted(seqs)
        # linger bound: nothing flushed by the timely dispatcher waited
        # past its deadline (drain batches flush at shutdown by design)
        if b.reason != "drain":
            for r in b.requests:
                assert b.t_flush <= r.deadline + 1e-12

    # global FIFO per key: across batches, a key's requests appear in
    # enqueue order
    for key in _KEYS:
        seqs = [r.seq for b in batches for r in b.requests if r.key == key]
        assert seqs == sorted(seqs)


@pytest.mark.skipif(not HAS_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=100, deadline=None)
@given(n=st.integers(min_value=1, max_value=40),
       max_width=st.integers(min_value=1, max_value=6))
def test_width_flush_exact_multiples(n, max_width):
    """Same-instant enqueues of one key flush exactly every max_width."""
    mb = MicroBatcher(max_width=max_width, max_linger_s=10.0)
    flushed = 0
    for i in range(n):
        out = mb.enqueue(req(), now=0.0)
        if out is not None:
            assert out.width == max_width
            flushed += 1
    assert flushed == n // max_width
    assert mb.pending() == n % max_width

"""sptrsv: the unified solve surface — upper/transpose sweeps vs scipy,
ILU-style round trips, and jax.grad through the custom VJP (ISSUE 3)."""
import numpy as np
import pytest

from repro.solver import TriangularOperator, available_engines, sptrsv
from repro.sparse import generators

try:
    from scipy.sparse import csr_matrix
    from scipy.sparse.linalg import spsolve_triangular
    HAS_SCIPY = True
except ModuleNotFoundError:             # pragma: no cover - env dependent
    HAS_SCIPY = False


@pytest.fixture(autouse=True)
def _fresh_memory_cache():
    TriangularOperator.clear_memory_cache()
    yield
    TriangularOperator.clear_memory_cache()


def _rel_err(x, x_ref):
    return np.abs(x - x_ref).max() / max(1.0, np.abs(x_ref).max())


def _ref_solve(A, b, lower, transpose):
    """scipy.sparse.linalg.spsolve_triangular when present, dense fallback."""
    if HAS_SCIPY:
        M = csr_matrix(A.to_dense())
        if transpose:
            M = M.T.tocsr()
        return spsolve_triangular(M, b, lower=(lower == (not transpose)))
    import numpy.linalg as la
    M = A.to_dense().T if transpose else A.to_dense()
    return la.solve(M, b)


GENS = [
    generators.random_lower(150, avg_offdiag=2.5, seed=3, max_back=20),
    generators.banded(80, 12, seed=1),          # splits rows -> carry lanes
]


@pytest.mark.parametrize("lower", [True, False])
@pytest.mark.parametrize("transpose", [False, True])
def test_all_sweeps_match_scipy_under_every_engine(lower, transpose):
    """Acceptance: upper and transpose solves match spsolve_triangular to
    <= 1e-8 relative on the generator suite under every registered
    (available) engine."""
    for L in GENS:
        A = L if lower else L.transpose()
        b = np.random.default_rng(0).standard_normal(A.n_rows)
        x_ref = _ref_solve(A, b, lower, transpose)
        for name in available_engines():
            x = sptrsv(A, b, lower=lower, transpose=transpose, engine=name,
                       chunk=64, max_deps=8, cache=False)
            assert _rel_err(x, x_ref) < 1e-8, (name, lower, transpose)


def test_all_sweeps_thin_level_analogue():
    """The lung2-like thin-level analogue through all four sweeps (scan
    and pallas engines; unrolled would pay a minutes-long XLA compile on
    ~500-step untransformed schedules — docs/strategies.md)."""
    L = generators.lung2_like(scale=0.03)
    b = np.random.default_rng(0).standard_normal(L.n_rows)
    for lower in (True, False):
        for transpose in (False, True):
            A = L if lower else L.transpose()
            x_ref = _ref_solve(A, b, lower, transpose)
            for name in ("scan", "pallas-interpret"):
                x = sptrsv(A, b, lower=lower, transpose=transpose,
                           engine=name, chunk=64, max_deps=8, cache=False)
                assert _rel_err(x, x_ref) < 1e-8, (name, lower, transpose)


def test_batched_rhs_all_sweeps():
    L = generators.random_lower(100, avg_offdiag=2.0, seed=9, max_back=12)
    B = np.random.default_rng(2).standard_normal((100, 4))
    for lower in (True, False):
        for transpose in (False, True):
            A = L if lower else L.transpose()
            X = sptrsv(A, B, lower=lower, transpose=transpose, chunk=32,
                       max_deps=4, cache=False)
            assert X.shape == B.shape
            for j in range(B.shape[1]):
                x_ref = _ref_solve(A, B[:, j], lower, transpose)
                assert _rel_err(X[:, j], x_ref) < 1e-8


def test_unit_diagonal_matches_scipy():
    L = generators.random_lower(90, avg_offdiag=2.0, seed=4, max_back=10)
    b = np.random.default_rng(3).standard_normal(90)
    x = sptrsv(L, b, unit_diagonal=True, cache=False)
    dense = L.to_dense()
    np.fill_diagonal(dense, 1.0)
    x_ref = np.linalg.solve(dense, b)
    assert _rel_err(x, x_ref) < 1e-8


def test_ilu_round_trip_via_cached_operator(tmp_path):
    """Acceptance: an L-then-L^T ILU-style round trip through the cached
    operator — solve L y = b, then L^T z = y, vs the dense reference."""
    L = generators.lung2_like(scale=0.03)
    b = np.random.default_rng(5).standard_normal(L.n_rows)
    op_f = TriangularOperator.from_csr(L, tune="avgLevelCost", chunk=64,
                                       max_deps=8, cache_dir=tmp_path)
    op_b = op_f.transposed()
    assert op_b.transpose and op_b.side == "lower"
    y = op_f.solve(b)
    z = op_b.solve(y)
    dense = L.to_dense()
    z_ref = np.linalg.solve(dense.T, np.linalg.solve(dense, b))
    assert _rel_err(z, z_ref) < 1e-8
    # the pair round-trips the cache: rebuilding both is a disk/memory hit
    op_f2 = TriangularOperator.from_csr(L, tune="avgLevelCost", chunk=64,
                                        max_deps=8, cache_dir=tmp_path)
    op_b2 = op_f2.transposed()
    assert op_f2.stats.cache_source in ("memory", "disk")
    assert op_b2.stats.cache_source in ("memory", "disk")
    assert _rel_err(op_b2.solve(op_f2.solve(b)), z_ref) < 1e-8


def test_upper_solve_refines_to_float64():
    """Refinement residuals use the transpose-aware matvec, so non-forward
    sweeps reach float64 accuracy too (not just raw device f32)."""
    L = generators.banded(70, 9, seed=2)
    U = L.transpose()
    b = np.random.default_rng(6).standard_normal(70)
    op = TriangularOperator.from_csr(U, tune="no_rewriting", side="upper",
                                     chunk=32, max_deps=4, cache=False)
    x = op.solve(b)
    assert op.stats.last_residual < 1e-10
    x_ref = np.linalg.solve(U.to_dense(), b)
    assert _rel_err(x, x_ref) < 1e-8


def test_numpy_path_returns_float64_even_without_refinement():
    """sptrsv's public numpy contract is float64 out regardless of
    max_refine: the refinement-free operator path now runs fp64-copy-free
    in the schedule dtype internally (ISSUE 5 satellite), but the surface
    casts the returned array up."""
    L = generators.random_lower(90, avg_offdiag=2.0, seed=3, max_back=10)
    b = np.random.default_rng(4).standard_normal(90).astype(np.float32)
    x0 = sptrsv(L, b, max_refine=0, cache=False)
    assert isinstance(x0, np.ndarray) and x0.dtype == np.float64
    x = sptrsv(L, b, cache=False)
    assert x.dtype == np.float64
    assert _rel_err(x0, x) < 1e-3       # same solve, device precision


def test_grad_matches_finite_differences():
    """Acceptance: jax.grad of sum(sptrsv(L, b)) w.r.t. b matches finite
    differences to <= 1e-4."""
    import jax
    import jax.numpy as jnp
    n = 50
    L = generators.random_lower(n, avg_offdiag=2.0, seed=11, max_back=8)
    b = np.random.default_rng(7).standard_normal(n)

    g = jax.grad(lambda bb: jnp.sum(sptrsv(L, bb, cache=False)))(
        jnp.asarray(b, jnp.float32))
    g = np.asarray(g, dtype=np.float64)

    h = 1e-5
    fd = np.zeros(n)
    for i in range(n):
        e = np.zeros(n)
        e[i] = h
        fd[i] = (np.sum(sptrsv(L, b + e, cache=False)) -
                 np.sum(sptrsv(L, b - e, cache=False))) / (2 * h)
    assert _rel_err(g, fd) < 1e-4
    # and the analytic cotangent is the transpose solve: L^-T @ ones
    g_ref = np.linalg.solve(L.to_dense().T, np.ones(n))
    assert _rel_err(g, g_ref) < 1e-4


def test_grad_through_transpose_and_upper_sweeps():
    """The backward pass of a transpose solve is the forward solve (the
    VJP flips the transpose bit both ways)."""
    import jax
    import jax.numpy as jnp
    n = 40
    L = generators.random_lower(n, avg_offdiag=2.0, seed=13, max_back=6)
    b = np.random.default_rng(8).standard_normal(n)
    g = jax.grad(lambda bb: jnp.sum(sptrsv(L, bb, transpose=True,
                                           cache=False)))(
        jnp.asarray(b, jnp.float32))
    g_ref = np.linalg.solve(L.to_dense(), np.ones(n))       # (L^T)^-T = L^-1
    assert _rel_err(np.asarray(g, np.float64), g_ref) < 1e-4


def test_second_order_grad_composes():
    """The custom VJP's backward pass routes through the custom_vjp'd solve
    itself, so grad-of-grad (HVPs, double backward) works: for
    f(b) = sum(sptrsv(L, b)^2)/2, grad f = L^-T L^-1 b is linear in b, so
    grad of (v . grad f) w.r.t. b is the constant L^-T L^-1 v."""
    import jax
    import jax.numpy as jnp
    n = 30
    L = generators.random_lower(n, avg_offdiag=2.0, seed=17, max_back=5)
    b = np.random.default_rng(11).standard_normal(n)
    v = np.random.default_rng(12).standard_normal(n)

    def f(bb):
        x = sptrsv(L, bb, cache=False)
        return 0.5 * jnp.sum(x * x)

    hvp = jax.grad(lambda bb: jnp.vdot(jax.grad(f)(bb),
                                       jnp.asarray(v, jnp.float32)))(
        jnp.asarray(b, jnp.float32))
    dense = L.to_dense()
    hvp_ref = np.linalg.solve(dense.T, np.linalg.solve(dense, v))
    assert _rel_err(np.asarray(hvp, np.float64), hvp_ref) < 1e-4


def test_sptrsv_jit_and_jax_array_roundtrip():
    import jax
    import jax.numpy as jnp
    n = 60
    L = generators.random_lower(n, avg_offdiag=2.0, seed=15, max_back=9)
    b = np.random.default_rng(9).standard_normal(n)
    x_np = sptrsv(L, b, cache=False)
    assert isinstance(x_np, np.ndarray)                     # numpy in/out
    x_j = jax.jit(lambda bb: sptrsv(L, bb, cache=False))(
        jnp.asarray(b, jnp.float32))
    assert isinstance(x_j, jax.Array)                       # jax in/out
    assert _rel_err(np.asarray(x_j, np.float64), x_np) < 1e-5


def test_sptrsv_tune_and_engine_specs():
    from repro.solver import resolve_engine
    L = generators.lung2_like(scale=0.02)
    b = np.random.default_rng(10).standard_normal(L.n_rows)
    x_ref = _ref_solve(L, b, True, False)
    x = sptrsv(L, b, tune="avgLevelCost", engine=resolve_engine("unrolled"),
               chunk=64, max_deps=8, cache=False)
    assert _rel_err(x, x_ref) < 1e-8
    with pytest.raises(ValueError, match="registered engines"):
        sptrsv(L, b, engine="not-an-engine", cache=False)
    with pytest.raises(ValueError, match="side"):
        TriangularOperator.from_csr(L, tune="no_rewriting", side="diagonal",
                                    cache=False)

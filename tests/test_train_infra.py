"""Training infrastructure: optimizer, checkpoint roundtrip + crash
consistency, data determinism, compression, resilience, end-to-end loss
decrease, restart equivalence."""
import json
import math
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import get_model
from repro.train import AdamWConfig, checkpoint as ck, make_train_step
from repro.train.data import Prefetcher, SyntheticLM
from repro.train.optimizer import adamw_update, cosine_lr, init_opt_state
from repro.train.resilience import RunGuard, StepMonitor, replan_mesh


def test_adamw_quadratic_convergence():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, min_lr_ratio=1.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = init_opt_state(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(cfg, params, grads, opt)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(cosine_lr(cfg, jnp.int32(s))) for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0 and lrs[1] == pytest.approx(0.5, abs=0.01)
    assert lrs[2] == pytest.approx(1.0, abs=0.01)
    assert lrs[-1] == pytest.approx(0.1, abs=0.01)


def test_grad_clip():
    cfg = AdamWConfig(lr=0.0, clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    opt = init_opt_state(params)
    _, _, stats = adamw_update(cfg, params, {"w": jnp.asarray([3.0, 4.0, 0.0])},
                               opt)
    assert float(stats["grad_norm"]) == pytest.approx(5.0)


def test_checkpoint_roundtrip(tmp_path):
    state = {"params": {"a": np.arange(6, dtype=np.float32).reshape(2, 3)},
             "opt": {"step": np.int32(7)}}
    ck.save(tmp_path, 7, state)
    restored, step = ck.restore(tmp_path, state)
    assert step == 7
    np.testing.assert_array_equal(restored["params"]["a"],
                                  state["params"]["a"])


def test_checkpoint_crash_consistency(tmp_path):
    """A checkpoint without MANIFEST.json must be invisible."""
    state = {"w": np.ones(3, np.float32)}
    ck.save(tmp_path, 1, state)
    # fake a crashed save at step 2: shard present, no manifest
    d = tmp_path / "step_00000002"
    d.mkdir()
    np.savez(d / "shard_0.npz", w=np.zeros(3, np.float32))
    assert ck.latest_step(tmp_path) == 1
    restored, step = ck.restore(tmp_path, state)
    assert step == 1 and restored["w"][0] == 1.0


def test_checkpointer_async_and_gc(tmp_path):
    c = ck.Checkpointer(tmp_path, keep=2)
    state = {"w": np.ones(2, np.float32)}
    for s in (10, 20, 30):
        c.save_async(s, state)
    c.wait()
    steps = sorted(int(d.name.split("_")[1]) for d in tmp_path.iterdir()
                   if d.name.startswith("step_"))
    assert steps == [20, 30]


def test_data_determinism_and_prefetch():
    d1 = SyntheticLM(vocab=100, seq_len=16, global_batch=4, seed=9)
    d2 = SyntheticLM(vocab=100, seq_len=16, global_batch=4, seed=9)
    b1, b2 = d1.batch_at(3), d2.batch_at(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    it = Prefetcher(iter([d1.batch_at(i) for i in range(3)]))
    got = list(it)
    assert len(got) == 3


def test_host_sharding_partition():
    full = SyntheticLM(vocab=50, seq_len=8, global_batch=8, seed=1)
    h0 = SyntheticLM(vocab=50, seq_len=8, global_batch=8, num_hosts=2,
                     host_id=0, seed=1)
    assert h0.batch == 4 and full.batch == 8


def test_compression_error_feedback():
    """int8 EF compression: single-device psum == near-identity with
    residual carrying the quantization error."""
    from repro.train.compression import compressed_psum_tree, init_residuals
    import jax
    from jax.sharding import PartitionSpec as P
    shard_map = getattr(jax, "shard_map", None)
    if shard_map is None:       # jax < 0.7 keeps shard_map in experimental
        from jax.experimental.shard_map import shard_map
    mesh = jax.make_mesh((1,), ("pod",))
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal(64),
                          jnp.float32)}
    r = init_residuals(g)

    def f(g, r):
        return compressed_psum_tree(g, r, "pod")

    out, new_r = jax.jit(shard_map(
        f, mesh=mesh, in_specs=(P(), P()), out_specs=P()))(g, r)
    # compressed value + residual == original (error feedback identity)
    np.testing.assert_allclose(np.asarray(out["w"] + new_r["w"]),
                               np.asarray(g["w"]), rtol=1e-5, atol=1e-6)


def test_replan_mesh():
    m = replan_mesh(1, prefer_model=16)
    assert dict(m.shape) == {"data": 1, "model": 1}


def test_step_monitor_straggler():
    mon = StepMonitor(alpha=1.0, straggler_factor=1.5)
    mon.start(); mon.ema = 0.001
    import time
    time.sleep(0.01)
    t = mon.finish()
    assert t["straggler_alarm"]


def test_runguard_nan_rollback(tmp_path):
    g = RunGuard(None, interval=10, max_rollbacks=1)
    assert g.check_loss(1.0)
    assert not g.check_loss(float("nan"))
    with pytest.raises(RuntimeError):
        g.check_loss(float("nan"))


@pytest.mark.slow
def test_end_to_end_training_loss_decreases(tmp_path):
    """Integration: 60 steps on the reduced internlm2; loss must drop."""
    from repro.launch.train import main
    losses = main(["--arch", "internlm2-1.8b", "--reduced", "--steps", "60",
                   "--batch", "4", "--seq", "64", "--ckpt-dir",
                   str(tmp_path / "ck"), "--log-every", "30"])
    assert losses[-1] < losses[0] - 0.3


@pytest.mark.slow
def test_restart_equivalence(tmp_path):
    """Kill-and-restart: resuming from the checkpoint reproduces the same
    final loss as an uninterrupted run (same data stream)."""
    from repro.launch.train import main
    ck1 = str(tmp_path / "a")
    full = main(["--arch", "internlm2-1.8b", "--reduced", "--steps", "40",
                 "--batch", "4", "--seq", "64", "--ckpt-dir", ck1,
                 "--ckpt-every", "20", "--log-every", "100"])
    ck2 = str(tmp_path / "b")
    part = main(["--arch", "internlm2-1.8b", "--reduced", "--steps", "40",
                 "--batch", "4", "--seq", "64", "--ckpt-dir", ck2,
                 "--ckpt-every", "20", "--log-every", "100",
                 "--abort-after", "25"])
    resumed = main(["--arch", "internlm2-1.8b", "--reduced", "--steps", "40",
                    "--batch", "4", "--seq", "64", "--ckpt-dir", ck2,
                    "--resume", "--ckpt-every", "100", "--log-every", "100"])
    assert resumed[-1] == pytest.approx(full[-1], rel=1e-3)


def test_serve_generate():
    from repro.launch.serve import main
    out = main(["--arch", "internlm2-1.8b", "--reduced", "--batch", "2",
                "--prompt-len", "8", "--gen", "4"])
    assert out.shape == (2, 4)


def test_replan_mesh_after_failures():
    """Elastic re-mesh: losing devices still yields a valid (data, model)
    factorization, preferring to keep the TP degree."""
    for n, want in ((16, (4, 4)), (12, (3, 4)), (6, (3, 2)), (5, (5, 1))):
        m = replan_mesh(n, prefer_model=4, devices=list(range(n)))
        assert dict(m.shape) == {"data": want[0], "model": want[1]}, (n, m)

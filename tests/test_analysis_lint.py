"""Repo-rule lint suite: each rule fires on a minimal synthetic snippet,
stays quiet on the idiomatic counterpart, suppressions downgrade findings,
and — the acceptance criterion — the real `src/repro` tree lints clean
with zero unsuppressed findings."""
from pathlib import Path

from repro.analysis.lint import (CLOCK_INJECTED, RULES, Finding,
                                 lint_paths, lint_source, render_report)

REPO = Path(__file__).resolve().parent.parent


def _rules(findings, suppressed=None):
    return [f.rule for f in findings
            if suppressed is None or f.suppressed == suppressed]


# -- bare-except --------------------------------------------------------------

def test_bare_except_fires():
    src = "try:\n    x = 1\nexcept:\n    pass\n"
    assert _rules(lint_source(src, "m.py")) == ["bare-except"]


def test_typed_except_clean():
    src = "try:\n    x = 1\nexcept (ValueError, KeyError):\n    pass\n"
    assert not lint_source(src, "m.py")


# -- wall-clock ---------------------------------------------------------------

def test_wall_clock_fires_in_clock_injected_module():
    src = "import time\n\ndef f():\n    return time.perf_counter()\n"
    found = lint_source(src, "src/repro/serving/batcher.py")
    assert _rules(found) == ["wall-clock"]
    # the same code is fine outside the clock-injected set
    assert not lint_source(src, "src/repro/solver/operator.py")


def test_wall_clock_reference_as_default_is_fine():
    src = ("import time\n\n"
           "def f(clock=time.perf_counter):\n    return clock()\n")
    assert not lint_source(src, "src/repro/serving/registry.py")


def test_wall_clock_from_import_and_datetime():
    src = ("from time import perf_counter\nimport datetime\n\n"
           "def f():\n    return perf_counter()\n\n"
           "def g():\n    return datetime.datetime.now()\n")
    found = lint_source(src, "src/repro/obs/trace.py")
    assert _rules(found) == ["wall-clock", "wall-clock"]


# -- host-callback-in-loop ----------------------------------------------------

def test_numpy_in_scan_body_fires():
    src = ("import numpy as np\nfrom jax import lax\n\n"
           "def body(carry, t):\n"
           "    return carry + np.asarray(t), None\n\n"
           "def run(xs):\n    return lax.scan(body, 0.0, xs)\n")
    assert _rules(lint_source(src, "m.py")) == ["host-callback-in-loop"]


def test_pure_callback_in_lambda_body_fires():
    src = ("import jax\nfrom jax import lax\n\n"
           "def run(xs):\n"
           "    return lax.fori_loop(0, 3, "
           "lambda i, v: jax.pure_callback(print, None, v), xs)\n")
    assert _rules(lint_source(src, "m.py")) == ["host-callback-in-loop"]


def test_jnp_in_scan_body_clean():
    src = ("import jax.numpy as jnp\nfrom jax import lax\n\n"
           "def body(carry, t):\n    return carry + jnp.sin(t), None\n\n"
           "def run(xs):\n    return lax.scan(body, 0.0, xs)\n")
    assert not lint_source(src, "m.py")


def test_numpy_outside_loop_body_clean():
    src = ("import numpy as np\nfrom jax import lax\n\n"
           "def body(c, t):\n    return c + t, None\n\n"
           "def run(xs):\n"
           "    xs = np.asarray(xs)\n    return lax.scan(body, 0.0, xs)\n")
    assert not lint_source(src, "m.py")


# -- unlocked-memo-mutation ---------------------------------------------------

_MEMO_HEADER = ("import threading\n"
                "_CACHE: dict = {}\n"
                "_CACHE_LOCK = threading.RLock()\n\n")


def test_unlocked_memo_write_fires():
    src = _MEMO_HEADER + "def put(k, v):\n    _CACHE[k] = v\n"
    assert _rules(lint_source(src, "m.py")) == ["unlocked-memo-mutation"]


def test_locked_memo_write_clean():
    src = _MEMO_HEADER + ("def put(k, v):\n"
                          "    with _CACHE_LOCK:\n        _CACHE[k] = v\n")
    assert not lint_source(src, "m.py")


def test_memo_method_mutation_and_class_scope():
    src = ("import threading\nimport collections\n\n"
           "class C:\n"
           "    _memo = collections.OrderedDict()\n"
           "    _lock = threading.Lock()\n\n"
           "    def evict(self):\n        self._memo.popitem(last=False)\n\n"
           "    def ok(self):\n"
           "        with self._lock:\n            self._memo.clear()\n")
    assert _rules(lint_source(src, "m.py")) == ["unlocked-memo-mutation"]


def test_memo_without_lock_not_flagged():
    # a config dict with no sibling lock is not a concurrency memo
    src = "_CHAINS: dict = {}\n\ndef set_chain(k, v):\n    _CHAINS[k] = v\n"
    assert not lint_source(src, "m.py")


def test_import_time_memo_init_clean():
    src = _MEMO_HEADER + "_CACHE['seed'] = 1\n"    # module top level
    assert not lint_source(src, "m.py")


# -- require-dtype-gate -------------------------------------------------------

def test_engine_without_dtype_gate_fires():
    src = ("class FastEngine(Engine):\n"
           "    def compile(self, dsched):\n        return lambda c: c\n")
    assert _rules(lint_source(src, "m.py")) == ["require-dtype-gate"]


def test_engine_with_gate_and_abstract_clean():
    src = ("class Engine:\n"
           "    def compile(self, dsched):\n"
           "        raise NotImplementedError\n\n"
           "class GatedEngine(Engine):\n"
           "    def compile(self, dsched):\n"
           "        self._require_dtype(dsched)\n        return lambda c: c\n")
    assert not lint_source(src, "m.py")


# -- suppression + report -----------------------------------------------------

def test_suppression_downgrades_finding():
    src = "try:\n    x = 1\nexcept:  # lint: allow=bare-except\n    pass\n"
    found = lint_source(src, "m.py")
    assert len(found) == 1 and found[0].suppressed
    # a suppression for a DIFFERENT rule does not apply
    src2 = "try:\n    x = 1\nexcept:  # lint: allow=wall-clock\n    pass\n"
    found2 = lint_source(src2, "m.py")
    assert len(found2) == 1 and not found2[0].suppressed


def test_render_report_counts():
    f1 = Finding(path="a.py", line=3, rule="bare-except", message="m")
    f2 = Finding(path="a.py", line=9, rule="wall-clock", message="m",
                 suppressed=True)
    rep = render_report([f1, f2])
    assert "1 finding(s), 1 suppressed" in rep
    assert "a.py:3: [bare-except]" in rep and "[suppressed]" in rep


def test_rule_catalog_is_documented():
    # every rule the linter can emit is in the catalog (and docs build
    # the table from it)
    src = "try:\n    x = 1\nexcept:\n    pass\n"
    for f in lint_source(src, "m.py"):
        assert f.rule in RULES
    assert set(CLOCK_INJECTED)      # non-empty module set


# -- acceptance criterion: the real tree is clean -----------------------------

def test_src_repro_lints_clean():
    findings = lint_paths([REPO / "src" / "repro"], root=REPO)
    live = [f for f in findings if not f.suppressed]
    assert not live, render_report(findings)


def test_src_repro_has_no_suppressions():
    # the CI job must land green WITHOUT suppressions (ISSUE 10 satellite)
    findings = lint_paths([REPO / "src" / "repro"], root=REPO)
    assert not findings, render_report(findings)

"""End-to-end LM training with mid-run failure + restart (fault tolerance).

Trains a ~100M-param reduced InternLM2 for a few hundred steps on CPU,
simulates a node failure at step 120, restarts from the last committed
checkpoint, and verifies the loss curve continues.

    PYTHONPATH=src python examples/train_lm.py [--steps 240]
"""
import argparse
import tempfile
from pathlib import Path

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=240)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    ckpt = Path(tempfile.mkdtemp(prefix="repro_lm_"))
    common = ["--arch", "internlm2-1.8b", "--reduced",
              "--steps", str(args.steps), "--batch", str(args.batch),
              "--seq", str(args.seq), "--ckpt-dir", str(ckpt),
              "--ckpt-every", "40", "--log-every", "20"]
    print("=== phase 1: train until simulated failure at step "
          f"{args.steps // 2} ===")
    losses1 = train_main(common + ["--abort-after", str(args.steps // 2)])
    print("=== phase 2: restart from checkpoint ===")
    losses2 = train_main(common + ["--resume"])
    print(f"phase1 first/last: {losses1[0]:.3f}/{losses1[-1]:.3f}; "
          f"phase2 last: {losses2[-1]:.3f}")
    assert losses2[-1] < losses1[0], "training did not improve across restart"
    print("OK: loss improved across the simulated failure + restart")


if __name__ == "__main__":
    main()

"""Time stepping with pattern-frozen refactorization — the scenario the
`update_values` fast path exists for.  An implicit time stepper solves

    (I + dt * K_k) x_{k+1} = x_k

where the stiffness values K_k change every step (nonlinear coefficients,
moving loads) but the MESH — the sparsity pattern — never does.  The
expensive work (level analysis, graph transformation, portfolio tuning,
schedule compilation, XLA compiles) depends only on the pattern, so it is
paid ONCE; each step then rebinds the numeric payload:

    op = TriangularOperator.from_csr(L_0, tune="auto")   # once
    for k in steps:
        op.update_values(L_k)        # transform replay + value repack
        x = op.solve(b)              # same compiled executables

The same contract holds one level up: `Preconditioner.refactor(A_k)`
re-runs only the numeric IC(0) sweeps over the pattern-precomputed plan
and value-updates both triangular halves in place, so a PCG-in-the-loop
stepper never re-tunes either.

    PYTHONPATH=src python examples/timestepping.py
"""
import time

import numpy as np

from repro.iterative import cg
from repro.precond import Preconditioner
from repro.solver import TriangularOperator
from repro.sparse import generators


def step_lower(L, k: int):
    """Step k's lower factor: same pattern, perturbed values."""
    rng = np.random.default_rng(100 + k)
    rows = np.repeat(np.arange(L.n_rows), L.row_nnz())
    d = L.indices == rows
    data = L.data * (1.0 + 0.2 * rng.standard_normal(L.nnz))
    data[d] = L.data[d] * (1.2 + 0.1 * k)
    return L.with_data(data)


def step_spd(A, k: int):
    """Step k's SPD system: symmetric value perturbation, heavier diagonal
    (a shifted/damped implicit step), identical pattern."""
    rows = np.repeat(np.arange(A.n_rows), A.row_nnz())
    pair = np.minimum(rows, A.indices) * A.n_cols + \
        np.maximum(rows, A.indices)
    data = A.data * (1.0 + 0.05 * np.sin(pair * 12.9898 + k))
    data[rows == A.indices] = A.data[rows == A.indices] * (2.0 + 0.1 * k)
    return A.with_data(data)


def main():
    # -- triangular operator: update_values per step --------------------------
    L = generators.random_lower(1500, avg_offdiag=3.0, seed=0, max_back=40)
    rng = np.random.default_rng(0)
    b = rng.standard_normal(L.n_rows)

    t0 = time.perf_counter()
    op = TriangularOperator.from_csr(L, tune="auto")
    op.solve(b)                              # prime compiled executables
    build_ms = (time.perf_counter() - t0) * 1e3
    print(f"build+tune once: {build_ms:7.1f}ms  pick={op.strategy}")

    for k in range(4):
        L_k = step_lower(L, k)
        t0 = time.perf_counter()
        op.update_values(L_k)                # pattern frozen, values rebound
        x = np.asarray(op.solve(b))
        step_ms = (time.perf_counter() - t0) * 1e3
        r = np.abs(L_k.matvec(x) - b).max()
        print(f"step {k}: update+solve {step_ms:7.2f}ms  "
              f"residual={r:.2e}  (update #{op.stats.value_updates}, "
              f"{op.stats.last_update_ms:.2f}ms)")
    assert op.stats.value_updates == 4

    # -- preconditioner: refactor per step ------------------------------------
    A = generators.poisson2d_spd(28, 28)
    bj = np.random.default_rng(1).standard_normal(A.n_rows)
    t0 = time.perf_counter()
    P = Preconditioner.ic0(A, tune="auto")
    print(f"\nic0 factor+tune once: {(time.perf_counter() - t0) * 1e3:7.1f}ms"
          f"  pick={P.strategy}")

    for k in range(3):
        A_k = step_spd(A, k)
        t0 = time.perf_counter()
        P.refactor(A_k)                      # numeric sweeps only
        res = cg(A_k, bj, preconditioner=P, tol=1e-6)
        step_ms = (time.perf_counter() - t0) * 1e3
        print(f"step {k}: refactor+pcg {step_ms:7.1f}ms  "
              f"iters={int(res.iterations):3d} "
              f"resid={float(res.final_residual()):.2e}")
        assert bool(res.converged), k
    assert P.forward.stats.value_updates == 3

    # pattern drift is rejected, never silently absorbed
    from repro.core import faults
    from repro.core.transform import PatternMismatchError
    try:
        op.update_values(faults.pattern_drift(L))
    except PatternMismatchError as e:
        print(f"\ndrifted pattern rejected: {e}")


if __name__ == "__main__":
    main()

"""Table-I reproduction study + beyond-paper constrained strategies.

    PYTHONPATH=src python examples/transform_study.py
"""
from repro.core import (AvgLevelCost, ConstrainedAvgLevelCost, ManualEveryK,
                        NoRewrite, transform)
from repro.sparse import io as sio


def main():
    for name in ("lung2", "torso2"):
        L = sio.load_named(name)
        print(f"== {name} (n={L.n_rows}, nnz={L.nnz}) ==")
        for strat in (
                NoRewrite(), AvgLevelCost(), ManualEveryK(10),
                # paper §III.A proposed-but-unimplemented constraints:
                ConstrainedAvgLevelCost(alpha=8, beta=32, coef_cap=1e6),
                ConstrainedAvgLevelCost(alpha=8, beta=32, coef_cap=1e6,
                                        update_avg=True)):
            ts = transform(L, strat, validate=False, codegen=False)
            m = ts.metrics
            r = m.table1_row()
            print(f"  {m.strategy:38s} levels {m.num_levels_before:4d}->"
                  f"{m.num_levels_after:4d} avg x{r['avg_cost_ratio']:6.2f} "
                  f"total {r['total_cost_delta_pct']:+6.1f}% "
                  f"rewr {m.rows_rewritten:6d} maxdist "
                  f"{m.max_rewrite_distance:4d} maxcoef {m.max_abs_coef:.1e}")


if __name__ == "__main__":
    main()

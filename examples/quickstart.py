"""Quickstart: auto-tune, compile, and solve a sparse triangular system.

    PYTHONPATH=src python examples/quickstart.py

The README's quickstart snippet is kept in sync with this file.
"""
import numpy as np

from repro.solver import TriangularOperator, solve_csr_seq
from repro.sparse import build_levels, generators


def main():
    # 1. a matrix with thin levels (long dependency chains)
    L = generators.lung2_like(scale=0.1)
    levels = build_levels(L)
    print(f"matrix: n={L.n_rows} nnz={L.nnz} levels={levels.num_levels}")

    # 2. one entry point: the portfolio auto-tuner picks the best
    #    transformation strategy, compiles the schedule, and caches the
    #    artifact keyed by the matrix fingerprint (second run is instant)
    op = TriangularOperator.from_csr(L, tune="auto", chunk=128, max_deps=8)
    print(f"\ntuner pick: {op.strategy} "
          f"({op.schedule.num_steps} steps, cache={op.stats.cache_source})")
    print("\nranked strategy report:")
    print(op.report.table() if op.report is not None else "(cached)")

    # 3. solve — single RHS, float64 accuracy via iterative refinement
    b = np.random.default_rng(0).standard_normal(L.n_rows)
    x = op.solve(b)
    x_ref = solve_csr_seq(L, b)
    print(f"\nsingle RHS: max err {np.abs(x - x_ref).max():.2e} "
          f"(residual {op.stats.last_residual:.2e}, "
          f"{op.stats.refine_rounds} refinement rounds)")

    # 4. batched multi-RHS — one transformed matrix amortized over many b's
    B = np.random.default_rng(1).standard_normal((L.n_rows, 8))
    X = op.solve(B)
    errs = [np.abs(X[:, j] - solve_csr_seq(L, B[:, j])).max()
            for j in range(B.shape[1])]
    print(f"batched (n, 8): max err {max(errs):.2e}")

    # 5. the same solve through the Pallas TPU kernel (interpret mode on
    #    CPU) — engines resolve through the repro.solver.engines registry
    from repro.solver import resolve_engine
    x2 = op.solve(b, engine=resolve_engine("pallas"))
    print(f"pallas engine: max err {np.abs(x2 - x_ref).max():.2e}")
    print(f"\nper-solve stats: {op.stats.to_dict()}")

    # 6. ILU-style forward/backward pair: solve L y = b then L^T z = y —
    #    the transpose operator reuses the same compiler/engines (and the
    #    same disk cache) by solving the reversed-transposed lower system
    op_t = TriangularOperator.from_csr(L, tune="auto", chunk=128, max_deps=8,
                                       transpose=True)
    y = op.solve(b)
    z = op_t.solve(y)
    z_ref = np.linalg.solve(L.to_dense().T, solve_csr_seq(L, b))
    print(f"\nL then L^T round-trip: max err {np.abs(z - z_ref).max():.2e}")

    # 7. differentiable solves: sptrsv routes jax arrays through a
    #    custom_vjp whose backward pass is the transpose operator itself
    import jax
    import jax.numpy as jnp
    from repro.solver import sptrsv
    g = jax.grad(lambda bb: jnp.sum(sptrsv(L, bb)))(jnp.asarray(b,
                                                                jnp.float32))
    g_ref = np.linalg.solve(L.to_dense().T, np.ones(L.n_rows))   # L^-T 1
    print(f"jax.grad through sptrsv: max err "
          f"{np.abs(np.asarray(g) - g_ref).max():.2e}")


if __name__ == "__main__":
    main()

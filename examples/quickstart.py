"""Quickstart: transform a sparse triangular system and solve it.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import AvgLevelCost, transform
from repro.solver import (schedule_for_csr, schedule_for_transformed, solve,
                          solve_csr_seq)
from repro.sparse import build_levels, generators


def main():
    # 1. a matrix with thin levels (long dependency chains)
    L = generators.lung2_like(scale=0.1)
    levels = build_levels(L)
    print(f"matrix: n={L.n_rows} nnz={L.nnz} levels={levels.num_levels}")

    # 2. the paper's transformation: fatten thin levels by equation rewriting
    ts = transform(L, AvgLevelCost())
    m = ts.metrics
    print(f"transformed: levels {m.num_levels_before} -> "
          f"{m.num_levels_after} "
          f"({100 * (1 - m.num_levels_after / m.num_levels_before):.0f}% "
          f"fewer barriers), total cost {m.total_level_cost_before} -> "
          f"{m.total_level_cost_after}")

    # 3. solve both ways — identical solutions
    b = np.random.default_rng(0).standard_normal(L.n_rows)
    x_ref = solve_csr_seq(L, b)

    s0 = schedule_for_csr(L, levels, chunk=128, max_deps=8)
    x0 = solve(s0, b)
    s1 = schedule_for_transformed(ts, chunk=128, max_deps=8)
    x1 = solve(s1, ts.preamble(b).astype(np.float32))
    print(f"schedule steps: {s0.num_steps} -> {s1.num_steps}")
    print(f"max err untransformed {np.abs(x0 - x_ref).max():.2e}, "
          f"transformed {np.abs(x1 - x_ref).max():.2e}")

    # 4. the same solve through the Pallas TPU kernel (interpret mode on CPU)
    from repro.kernels import ops
    x2 = ops.sptrsv_solve(s1, ts.preamble(b).astype(np.float32))
    print(f"pallas kernel err {np.abs(x2 - x_ref).max():.2e}")


if __name__ == "__main__":
    main()

"""Preconditioned CG with an IC(0)-style triangular preconditioner whose
solves go through the transformed SpTRSV operator — the paper's §I
motivation ("building block to preconditioners for sparse iterative
solvers") end to end.  Both halves of M^-1 = (L L^T)^-1 run through the
level-scheduled engines: the forward L-sweep via the transformed schedule,
the backward L^T-sweep via the transpose operator
(TriangularOperator.from_csr(..., transpose=True)).

    PYTHONPATH=src python examples/pcg_ic0.py
"""
import numpy as np

from repro.core import AvgLevelCost, NoRewrite, transform
from repro.solver import (TriangularOperator, resolve_engine,
                          schedule_for_transformed, to_device)
from repro.sparse import generators
from repro.sparse.csr import CSR, from_coo


def spd_from_grid(nx: int, ny: int, seed=0):
    """SPD matrix A = L L^T from a Poisson-like lower factor."""
    L = generators.poisson2d_ic0(nx, ny, seed=seed)
    n = L.n_rows
    dense = L.to_dense()
    A = dense @ dense.T
    return L, A


def pcg(A, b, Lfac, ts, iters=80, tol=1e-8):
    """CG on Ax=b, preconditioner M^-1 = (L L^T)^-1 via two triangular
    solves — the forward sweep through the transformed level-scheduled
    engine, the backward L^T sweep through the transpose operator (same
    compiler and engines), both compiled once outside the loop."""
    import jax.numpy as jnp

    sched = schedule_for_transformed(ts, chunk=128, max_deps=8,
                                     dtype=np.float64)
    ds = to_device(sched)
    fwd = resolve_engine("scan").compile(ds)
    bwd = TriangularOperator.from_csr(Lfac, tune="no_rewriting",
                                      transpose=True, chunk=128, max_deps=8,
                                      cache=False)

    def apply_minv(r):
        c = ts.preamble(r)
        y = np.asarray(fwd(jnp.asarray(c, jnp.float32))).astype(np.float64)
        return bwd.solve(y)

    x = np.zeros_like(b)
    r = b - A @ x
    z = apply_minv(r)
    p = z.copy()
    rz = r @ z
    for it in range(iters):
        Ap = A @ p
        alpha = rz / (p @ Ap)
        x += alpha * p
        r -= alpha * Ap
        rn = np.linalg.norm(r)
        if rn < tol:
            return x, it + 1, rn
        z = apply_minv(r)
        rz_new = r @ z
        p = z + (rz_new / rz) * p
        rz = rz_new
    return x, iters, np.linalg.norm(r)


def main():
    Lfac, A = spd_from_grid(24, 24)
    n = A.shape[0]
    rng = np.random.default_rng(0)
    x_true = rng.standard_normal(n)
    b = A @ x_true

    for name, strat in (("no_rewriting", NoRewrite()),
                        ("avgLevelCost", AvgLevelCost())):
        ts = transform(Lfac, strat, validate=False, codegen=False)
        x, iters, rn = pcg(A, b, Lfac, ts)
        err = np.abs(x - x_true).max()
        sched = schedule_for_transformed(ts, chunk=128, max_deps=8)
        print(f"{name:14s} levels={ts.metrics.num_levels_after:4d} "
              f"sched_steps={sched.num_steps:4d} cg_iters={iters:3d} "
              f"resid={rn:.2e} err={err:.2e}")


if __name__ == "__main__":
    main()

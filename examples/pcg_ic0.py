"""Preconditioned CG through the full subsystem — the paper's §I motivation
("building block to preconditioners for sparse iterative solvers") end to
end, with zero hand-rolled solver code:

    A = poisson2d_spd(nx, ny)            # the user's SPD system
    P = Preconditioner.ic0(A, tune=...)  # numeric IC(0) + pair-tuned,
                                         #   cached TriangularOperators
    res = iterative.cg(A, b, preconditioner=P)

Both halves of M^-1 = (L L^T)^-1 run as ONE traceable device computation
(compiled T-factor preamble + width-bucketed schedule per sweep, forward L
and backward L^T via transpose=True), and the CG loop itself is a pure JAX
program — jit the whole solve if you like.  Compare the iteration counts
against unpreconditioned CG, and the schedule shapes across strategies.

    PYTHONPATH=src python examples/pcg_ic0.py
"""
import numpy as np
from jax.experimental import enable_x64

from repro.iterative import cg
from repro.precond import Preconditioner
from repro.sparse import generators


def main():
    A = generators.poisson2d_spd(24, 24)
    n = A.n_rows
    rng = np.random.default_rng(0)
    x_true = rng.standard_normal(n)
    b = A.matvec(x_true)

    with enable_x64():      # float64 outer iterations (M^-1 runs float32)
        import jax.numpy as jnp
        bj = jnp.asarray(b)

        base = cg(A, bj, tol=1e-8)
        print(f"{'unpreconditioned':>16s}  cg_iters={int(base.iterations):3d} "
              f"resid={float(base.final_residual()):.2e}")

        for tune in ("no_rewriting", "avgLevelCost", "auto"):
            P = Preconditioner.ic0(A, tune=tune)
            res = cg(A, bj, preconditioner=P, tol=1e-8)
            err = float(jnp.abs(res.x - x_true).max())
            sched = P.forward.schedule
            label = P.strategy if tune == "auto" else tune
            print(f"{tune:>16s}  cg_iters={int(res.iterations):3d} "
                  f"resid={float(res.final_residual()):.2e} err={err:.2e} "
                  f"sched_steps={sched.num_steps:3d} pick={label}")
            assert bool(res.converged), tune
            assert int(res.iterations) < int(base.iterations), \
                f"{tune}: preconditioning must cut iterations"

        # batched right-hand sides stream the same schedules once per step
        B = jnp.asarray(rng.standard_normal((n, 8)))
        P = Preconditioner.ic0(A, tune="auto")
        resb = cg(A, B, preconditioner=P, tol=1e-8)
        print(f"{'batched k=8':>16s}  cg_iters={np.asarray(resb.iterations)} "
              f"all_converged={bool(resb.converged.all())}")


if __name__ == "__main__":
    main()

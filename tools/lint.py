#!/usr/bin/env python3
"""Run the repo-rule lint (repro.analysis.lint) from the command line.

    python -m tools.lint                 # lint src/repro (the default)
    python -m tools.lint src tests       # explicit targets
    python -m tools.lint --json          # machine-readable findings
    python -m tools.lint --list-rules    # rule catalog

Exit code 1 iff any unsuppressed finding remains.  CI runs this as the
blocking `static-analysis` job (docs/analysis.md has the rule catalog and
the `# lint: allow=<rule>` suppression syntax).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.analysis.lint import (RULES, lint_paths,  # noqa: E402
                                 render_report)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="tools.lint", description="repo-rule static lint")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/directories to lint (default: src/repro)")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, desc in sorted(RULES.items()):
            print(f"{rule:24s} {desc}")
        return 0

    paths = args.paths or [str(REPO_ROOT / "src" / "repro")]
    findings = lint_paths(paths, root=REPO_ROOT)
    if args.json:
        print(json.dumps([f.__dict__ for f in findings], indent=2))
    else:
        print(render_report(findings))
    return 1 if any(not f.suppressed for f in findings) else 0


if __name__ == "__main__":
    raise SystemExit(main())

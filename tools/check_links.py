#!/usr/bin/env python3
"""Fail on broken intra-repo markdown links.

    python tools/check_links.py README.md docs

Arguments are markdown files and/or directories (scanned for *.md).  Checks
every inline link/image target that is not external (http/https/mailto) or
a pure in-page anchor: the referenced path, resolved relative to the file
containing the link, must exist.  Exit code 1 lists the broken links.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# inline markdown links [text](target) / images ![alt](target); stops at
# the first ')' so title suffixes ("target "title"") are tolerated
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def iter_md_files(args: list[str]) -> list[Path]:
    files: list[Path] = []
    for a in args:
        p = Path(a)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.md")))
        else:
            files.append(p)
    return files


def check_file(md: Path) -> list[str]:
    broken = []
    text = md.read_text(encoding="utf-8")
    in_code = False
    for lineno, line in enumerate(text.splitlines(), 1):
        if line.lstrip().startswith("```"):
            in_code = not in_code
        if in_code:
            continue
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            if target.startswith(EXTERNAL) or target.startswith("#"):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                broken.append(f"{md}:{lineno}: broken link -> {target}")
    return broken


def main(argv: list[str]) -> int:
    if not argv:
        print(__doc__)
        return 2
    files = iter_md_files(argv)
    missing = [str(f) for f in files if not f.exists()]
    if missing:
        print("no such file(s): " + ", ".join(missing))
        return 2
    broken = [b for f in files for b in check_file(f)]
    for b in broken:
        print(b)
    print(f"checked {len(files)} file(s): "
          f"{'OK' if not broken else f'{len(broken)} broken link(s)'}")
    return 1 if broken else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))

"""Pallas TPU kernel: level-scheduled SpTRSV over a width-bucketed schedule.

TPU-native design:
  * one grid step per schedule step — the TPU grid executes sequentially, so
    cross-step dependencies are carried in VMEM scratch (x, carry);
  * x and carry live in VMEM for the whole solve (n <= ~1.5M fp32);
  * each step streams one (C_g, D_g) ELL tile per width group HBM->VMEM
    through its own BlockSpec: rows padded to sublane multiples (C_g = 8k),
    deps bucketed to the schedule's width classes (D in {4, 8, 16, 32} by
    default) so thin rows don't pay a global max_deps pad;
  * width groups of one step execute back to back — the schedule compiler
    guarantees no lane reads a row or carry finalized in the same step, so
    intra-step ordering is free;
  * groups without partial-row lanes ship no carry maps and skip the carry
    gather/scatter entirely (the common case after bucketing);
  * the kernel is VPU/memory-bound (gather + FMA + scatter) — no MXU use;
    the roofline term that matters is HBM bytes = schedule bytes, and the
    sequential-step count is what the paper's transformation minimizes.

Kernel body per step, per width group:
    partial = sum(dep_coef * x[dep_idx], axis=-1)      # (C_g,)
    tot     = partial + carry[carry_in]                # if group has carries
    xi      = (c[row_ids] - tot) * dinv
    x[row_ids]       = xi   (padding/partial lanes hit the garbage slot)
    carry[carry_out] = tot  (if group has carries)

Validated in interpret mode on CPU against ref.sptrsv_levels_grouped_ref;
real-TPU deployment notes: dynamic gather/scatter over a VMEM-resident
vector lowers to Mosaic gather ops; bucketed D keeps a (C, D) tile at most
8k x 32 x 4B = 1 MiB of VMEM traffic per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["sptrsv_levels_pallas", "sptrsv_groups_pallas",
           "sptrsv_groups_pallas_multi"]


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def _make_kernel(group_sizes: tuple):
    """Kernel over a flat ref list: per group either 4 refs (row_ids,
    dep_idx, dep_coef, dinv) or 6 (+ carry_in, carry_out), then c_pad,
    out, and the x/carry VMEM scratch."""

    def kernel(*refs):
        pos = 0
        group_refs = []
        for sz in group_sizes:
            group_refs.append(refs[pos:pos + sz])
            pos += sz
        c_pad_ref, out_ref, x_ref, carry_ref = refs[pos:pos + 4]
        s = pl.program_id(0)

        @pl.when(s == 0)
        def _init():
            x_ref[...] = jnp.zeros_like(x_ref)
            carry_ref[...] = jnp.zeros_like(carry_ref)

        for g in group_refs:
            row_ids = g[0][0]                    # (C,)
            idx = g[1][0]                        # (C, D)
            coef = g[2][0]
            dinv = g[3][0]
            x = x_ref[...]                       # (n_pad,) or (n_pad, R)
            gathered = jnp.take(x, idx, axis=0)  # (C, D) or (C, D, R)
            if x.ndim == 2:                      # batched multi-RHS
                partial = jnp.einsum("cd,cdr->cr", coef, gathered)
                dinv = dinv[:, None]
            else:
                partial = jnp.sum(coef * gathered, axis=-1)      # (C,)
            if len(g) == 6:
                carry = carry_ref[...]
                tot = partial + jnp.take(carry, g[4][0], axis=0)
                carry_ref[...] = carry.at[g[5][0]].set(tot)
            else:
                tot = partial
            c_here = jnp.take(c_pad_ref[...], row_ids, axis=0)
            x_ref[...] = x.at[row_ids].set((c_here - tot) * dinv)

        @pl.when(s == pl.num_programs(0) - 1)
        def _done():
            out_ref[...] = x_ref[...]

    return kernel


@functools.partial(jax.jit, static_argnames=("n", "n_carry", "interpret"))
def sptrsv_groups_pallas(groups, c_pad, *, n: int, n_carry: int,
                         interpret: bool = True) -> jax.Array:
    """Solve a width-bucketed schedule; returns x (n,).

    `groups` is a tuple of per-group leaf tuples — (row_ids (S, C_g),
    dep_idx (S, C_g, D_g), dep_coef, dinv) plus (carry_in, carry_out) for
    groups holding partial-row lanes.  c_pad has n+1 entries (last = 0).
    """
    S = groups[0][0].shape[0]
    dtype = groups[0][2].dtype
    n_pad = _round_up(n + 1, 128)
    nc_pad = _round_up(n_carry + 2, 128)
    c_full = jnp.zeros((n_pad,), dtype).at[: n + 1].set(c_pad.astype(dtype))

    step2 = lambda s: (s, 0)        # (S, C) blocks
    step3 = lambda s: (s, 0, 0)     # (S, C, D) blocks
    whole = lambda s: (0,)          # VMEM-resident vectors

    in_specs = []
    args = []
    group_sizes = []
    for g in groups:
        C = g[0].shape[1]
        D = g[1].shape[2]
        in_specs += [pl.BlockSpec((1, C), step2),       # row_ids
                     pl.BlockSpec((1, C, D), step3),    # dep_idx
                     pl.BlockSpec((1, C, D), step3),    # dep_coef
                     pl.BlockSpec((1, C), step2)]       # dinv
        args += [g[0], g[1], g[2].astype(dtype), g[3].astype(dtype)]
        if len(g) == 6:
            in_specs += [pl.BlockSpec((1, C), step2)] * 2
            args += [g[4], g[5]]
        group_sizes.append(len(g))
    in_specs.append(pl.BlockSpec((n_pad,), whole))      # c_pad

    out = pl.pallas_call(
        _make_kernel(tuple(group_sizes)),
        grid=(S,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((n_pad,), whole),
        out_shape=jax.ShapeDtypeStruct((n_pad,), dtype),
        scratch_shapes=[
            pltpu.VMEM((n_pad,), dtype),     # x resident in VMEM
            pltpu.VMEM((nc_pad,), dtype),    # partial-row carry slots
        ],
        interpret=interpret,
    )(*args, c_full)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("n", "n_carry", "interpret"))
def sptrsv_groups_pallas_multi(groups, c_pad, *, n: int, n_carry: int,
                               interpret: bool = True) -> jax.Array:
    """Batched multi-RHS variant: c_pad is (n + 1, R), returns x (n, R).

    One kernel invocation amortizes the schedule's HBM traffic over all R
    right-hand sides (the serving scenario): the ELL tiles stream exactly
    once, x/carry scratch become (n_pad, R_pad), and the per-lane dot turns
    into an einsum over the RHS axis.  R is padded to the 8-sublane tile —
    padding it to a full 128-lane vreg would blow the (n_pad, R_pad) VMEM
    planes up 16x for the typical R~8 serving batch; real-TPU deployment
    at larger R would instead tile the RHS axis into 128-wide blocks.
    """
    S = groups[0][0].shape[0]
    dtype = groups[0][2].dtype
    R = c_pad.shape[1]
    n_pad = _round_up(n + 1, 128)
    r_pad = _round_up(R, 8)
    c_full = jnp.zeros((n_pad, r_pad), dtype)
    c_full = c_full.at[: n + 1, :R].set(c_pad.astype(dtype))

    step2 = lambda s: (s, 0)        # (S, C) blocks
    step3 = lambda s: (s, 0, 0)     # (S, C, D) blocks
    whole2 = lambda s: (0, 0)       # VMEM-resident (n_pad, R_pad) planes

    in_specs = []
    args = []
    group_sizes = []
    for g in groups:
        C = g[0].shape[1]
        D = g[1].shape[2]
        in_specs += [pl.BlockSpec((1, C), step2),       # row_ids
                     pl.BlockSpec((1, C, D), step3),    # dep_idx
                     pl.BlockSpec((1, C, D), step3),    # dep_coef
                     pl.BlockSpec((1, C), step2)]       # dinv
        args += [g[0], g[1], g[2].astype(dtype), g[3].astype(dtype)]
        if len(g) == 6:
            in_specs += [pl.BlockSpec((1, C), step2)] * 2
            args += [g[4], g[5]]
        group_sizes.append(len(g))
    in_specs.append(pl.BlockSpec((n_pad, r_pad), whole2))   # c_pad

    out = pl.pallas_call(
        _make_kernel(tuple(group_sizes)),
        grid=(S,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((n_pad, r_pad), whole2),
        out_shape=jax.ShapeDtypeStruct((n_pad, r_pad), dtype),
        scratch_shapes=[
            pltpu.VMEM((n_pad, r_pad), dtype),               # x
            pltpu.VMEM((_round_up(n_carry + 2, 128), r_pad), dtype),
        ],
        interpret=interpret,
    )(*args, c_full)
    return out[:n, :R]


def sptrsv_levels_pallas(row_ids, dep_idx, dep_coef, dinv, carry_in,
                         carry_out, c_ids, c_pad, *, n: int, n_carry: int,
                         interpret: bool = True) -> jax.Array:
    """Single-group compatibility wrapper (legacy flat signature; c_ids is
    accepted and ignored — row_ids doubles as the c gather index)."""
    del c_ids
    group = (row_ids, dep_idx, dep_coef, dinv, carry_in, carry_out)
    return sptrsv_groups_pallas((group,), c_pad, n=n, n_carry=n_carry,
                                interpret=interpret)

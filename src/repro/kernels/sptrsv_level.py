"""Pallas TPU kernel: level-scheduled SpTRSV over a static ELL schedule.

TPU-native design (DESIGN.md §3):
  * one grid step per schedule step — the TPU grid executes sequentially, so
    cross-step dependencies are carried in VMEM scratch (x, carry);
  * x and carry live in VMEM for the whole solve (n <= ~1.5M fp32);
  * each step streams its (C, D) ELL tile HBM->VMEM through BlockSpecs: rows
    padded to sublane multiples (C = 8k), deps padded to lanes (D | 128 for
    full tiles; smaller D still vectorizes on the 8x128 VPU);
  * the kernel is VPU/memory-bound (gather + FMA + scatter) — no MXU use;
    the roofline term that matters is HBM bytes = schedule bytes, and the
    sequential-step count is what the paper's transformation minimizes.

Kernel body per step:
    partial = sum(dep_coef * x[dep_idx], axis=-1)      # (C,)
    tot     = partial + carry[carry_in]
    xi      = (c[c_ids] - tot) * dinv
    x[row_ids]    = xi    (final lanes; padding lanes hit garbage slot)
    carry[carry_out] = tot

Validated in interpret mode on CPU against ref.sptrsv_levels_ref; real-TPU
deployment notes: dynamic gather/scatter over a VMEM-resident vector lowers
to Mosaic gather ops; D is kept <= 32 so a (C, D) tile is at most
8k x 32 x 4B = 1 MiB of VMEM traffic per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["sptrsv_levels_pallas"]


def _kernel(row_ids_ref, dep_idx_ref, dep_coef_ref, dinv_ref, carry_in_ref,
            carry_out_ref, c_ids_ref, c_pad_ref, out_ref, x_ref, carry_ref):
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init():
        x_ref[...] = jnp.zeros_like(x_ref)
        carry_ref[...] = jnp.zeros_like(carry_ref)

    idx = dep_idx_ref[0]                     # (C, D) int32
    coef = dep_coef_ref[0]                   # (C, D)
    x = x_ref[...]
    gathered = jnp.take(x, idx, axis=0)      # (C, D) VMEM gather
    partial = jnp.sum(coef * gathered, axis=-1)              # (C,)
    carry = carry_ref[...]
    tot = partial + jnp.take(carry, carry_in_ref[0], axis=0)
    c_here = jnp.take(c_pad_ref[...], c_ids_ref[0], axis=0)
    xi = (c_here - tot) * dinv_ref[0]
    x_ref[...] = x.at[row_ids_ref[0]].set(xi)
    carry_ref[...] = carry.at[carry_out_ref[0]].set(tot)

    @pl.when(s == pl.num_programs(0) - 1)
    def _done():
        out_ref[...] = x_ref[...]


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


@functools.partial(jax.jit, static_argnames=("n", "n_carry", "interpret"))
def sptrsv_levels_pallas(row_ids, dep_idx, dep_coef, dinv, carry_in,
                         carry_out, c_ids, c_pad, *, n: int, n_carry: int,
                         interpret: bool = True) -> jax.Array:
    """Solve the level schedule; returns x (n,).

    Argument shapes match ref.sptrsv_levels_ref.  c_pad has n+1 entries
    (last = 0 garbage slot).
    """
    S, C = row_ids.shape
    D = dep_idx.shape[2]
    dtype = dep_coef.dtype
    n_pad = _round_up(n + 1, 128)
    nc_pad = _round_up(n_carry + 2, 128)
    c_full = jnp.zeros((n_pad,), dtype).at[: n + 1].set(c_pad.astype(dtype))

    step2 = lambda s: (s, 0)        # (S, C) blocks
    step3 = lambda s: (s, 0, 0)     # (S, C, D) blocks
    whole = lambda s: (0,)          # resident vectors

    out = pl.pallas_call(
        _kernel,
        grid=(S,),
        in_specs=[
            pl.BlockSpec((1, C), step2),       # row_ids
            pl.BlockSpec((1, C, D), step3),    # dep_idx
            pl.BlockSpec((1, C, D), step3),    # dep_coef
            pl.BlockSpec((1, C), step2),       # dinv
            pl.BlockSpec((1, C), step2),       # carry_in
            pl.BlockSpec((1, C), step2),       # carry_out
            pl.BlockSpec((1, C), step2),       # c_ids
            pl.BlockSpec((n_pad,), whole),     # c_pad (VMEM resident)
        ],
        out_specs=pl.BlockSpec((n_pad,), whole),
        out_shape=jax.ShapeDtypeStruct((n_pad,), dtype),
        scratch_shapes=[
            pltpu.VMEM((n_pad,), dtype),     # x resident in VMEM
            pltpu.VMEM((nc_pad,), dtype),    # partial-row carry slots
        ],
        interpret=interpret,
    )(row_ids, dep_idx, dep_coef.astype(dtype), dinv.astype(dtype),
      carry_in, carry_out, c_ids, c_full)
    return out[:n]

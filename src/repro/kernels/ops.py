"""Jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels execute in interpret mode (the kernel body
runs as jnp ops — bit-exact semantics, no TPU lowering); on TPU set
REPRO_PALLAS_INTERPRET=0 (or pass interpret=False) to compile with Mosaic.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..solver.schedule import LevelSchedule
from ..solver.levelset import to_device
from ..solver.engines import PallasEngine, default_interpret, get_engine
from .spmv_ell import spmv_ell_pallas
from . import ref

__all__ = ["default_interpret", "sptrsv_solve", "spmv_ell", "ell_pack_csr"]


def sptrsv_solve(sched: LevelSchedule, c: np.ndarray,
                 interpret: bool | None = None,
                 use_ref: bool = False, dsched=None) -> np.ndarray:
    """Solve a LevelSchedule with the Pallas kernel (or the jnp oracle).

    c may be (n,) or batched (n, R) — batched solves run the multi-RHS
    kernel, streaming the schedule once for all right-hand sides.  Pass a
    pre-staged DeviceSchedule as `dsched` to skip restaging on repeated
    solves.  Dispatches through the engine registry: interpret=None uses
    the registered "pallas" engine (REPRO_PALLAS_INTERPRET default), an
    explicit bool pins interpret mode for this call.
    """
    ds = dsched if dsched is not None else to_device(sched)
    if use_ref:
        dtype = sched.dtype
        cc = jnp.asarray(c, dtype=dtype)
        tail = (cc.shape[1],) if cc.ndim == 2 else ()
        c_pad = jnp.concatenate([cc, jnp.zeros((1,) + tail, dtype)], axis=0)
        out = ref.sptrsv_levels_grouped_ref(ds.groups, c_pad, n=sched.n,
                                            n_carry=sched.n_carry)
    else:
        eng = get_engine("pallas") if interpret is None \
            else PallasEngine(interpret=interpret)
        out = eng.compile(ds)(c)
    return np.asarray(out)


def ell_pack_csr(m, block_rows: int = 512, dtype=np.float32):
    """Pack a CSR matrix into ELL arrays for spmv_ell (vectorized scatter).

    Returns (ell_idx (n_pad, D), ell_coef (n_pad, D), n).  Padding indices
    point at x_pad's final zero slot.
    """
    n = m.n_rows
    deg = m.row_nnz()
    D = max(int(deg.max()), 1)
    n_pad = -(-n // block_rows) * block_rows
    ell_idx = np.full((n_pad, D), m.n_cols, dtype=np.int32)
    ell_coef = np.zeros((n_pad, D), dtype=dtype)
    indptr = np.asarray(m.indptr, dtype=np.int64)
    flat = np.repeat(np.arange(n, dtype=np.int64) * D, deg) + \
        (np.arange(indptr[-1]) - np.repeat(indptr[:-1], deg))
    ell_idx.reshape(-1)[flat] = m.indices
    ell_coef.reshape(-1)[flat] = m.data
    return ell_idx, ell_coef, n


def spmv_ell(m, x: np.ndarray, interpret: bool | None = None,
             use_ref: bool = False, block_rows: int = 512) -> np.ndarray:
    """y = m @ x via the ELL Pallas kernel."""
    interpret = default_interpret() if interpret is None else interpret
    ell_idx, ell_coef, n = ell_pack_csr(m, block_rows=block_rows)
    x_pad = jnp.concatenate([jnp.asarray(x, dtype=ell_coef.dtype),
                             jnp.zeros((1,), ell_coef.dtype)])
    if use_ref:
        y = ref.spmv_ell_ref(jnp.asarray(ell_idx), jnp.asarray(ell_coef), x_pad)
    else:
        y = spmv_ell_pallas(jnp.asarray(ell_idx), jnp.asarray(ell_coef),
                            x_pad, block_rows=block_rows, interpret=interpret)
    return np.asarray(y[:n])

"""Pallas TPU kernel: ELL SpMV (y = A @ x) — the dependency-free building
block used by the transformed solve's B'-preamble and by the PCG example.

Grid over row blocks; x stays VMEM-resident across the whole sweep (rows are
independent — unlike the SpTRSV kernel there is no sequential carry); each
block streams a (C, D) ELL tile.  BlockSpec tiling: C rows (sublane-aligned),
D dep slots (lane dimension).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["spmv_ell_pallas"]


def _kernel(ell_idx_ref, ell_coef_ref, x_ref, y_ref):
    idx = ell_idx_ref[...]                   # (C, D)
    coef = ell_coef_ref[...]
    gathered = jnp.take(x_ref[...], idx, axis=0)
    y_ref[...] = jnp.sum(coef * gathered, axis=-1)


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def spmv_ell_pallas(ell_idx, ell_coef, x_pad, *, block_rows: int = 512,
                    interpret: bool = True) -> jax.Array:
    """y (n_pad,) = ELL(A) @ x.

    ell_idx/ell_coef: (n_pad, D) with n_pad % block_rows == 0; padding slots
    index the final (zero) entry of x_pad with coef 0.
    """
    n_pad, D = ell_idx.shape
    assert n_pad % block_rows == 0, (n_pad, block_rows)
    dtype = ell_coef.dtype
    nx = _round_up(x_pad.shape[0], 128)
    x_full = jnp.zeros((nx,), dtype).at[: x_pad.shape[0]].set(
        x_pad.astype(dtype))
    grid = (n_pad // block_rows,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((nx,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), dtype),
        interpret=interpret,
    )(ell_idx, ell_coef.astype(dtype), x_full)

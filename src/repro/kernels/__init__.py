from . import ops, ref
from .sptrsv_level import sptrsv_levels_pallas
from .spmv_ell import spmv_ell_pallas

__all__ = ["ops", "ref", "sptrsv_levels_pallas", "spmv_ell_pallas"]

"""Pure-jnp oracles for the Pallas kernels (numerics ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["sptrsv_levels_ref", "sptrsv_levels_grouped_ref", "spmv_ell_ref"]


def sptrsv_levels_grouped_ref(groups, c_pad, n: int, n_carry: int) -> jax.Array:
    """Reference for the width-bucketed level-scheduled SpTRSV kernel.

    `groups` is a tuple of per-group leaf tuples: (row_ids (S, C_g),
    dep_idx (S, C_g, D_g), dep_coef, dinv[, carry_in, carry_out]); groups
    without carry maps hold no partial-row lanes.  c_pad has n+1 entries
    (last = 0), or shape (n + 1, R) for batched multi-RHS.  Returns x (n,)
    or (n, R).
    """
    S = groups[0][0].shape[0]
    tail = (c_pad.shape[1],) if c_pad.ndim == 2 else ()
    x = jnp.zeros((n + 1,) + tail, dtype=c_pad.dtype)
    carry = jnp.zeros((n_carry + 2,) + tail, dtype=c_pad.dtype)

    def body(state, s):
        x, carry = state
        for g in groups:
            row_ids = g[0][s]
            if tail:
                partial = jnp.einsum("cd,cdr->cr", g[2][s], x[g[1][s]])
                dinv = g[3][s][:, None]
            else:
                partial = jnp.sum(g[2][s] * x[g[1][s]], axis=-1)
                dinv = g[3][s]
            if len(g) == 6:
                tot = partial + carry[g[4][s]]
                carry = carry.at[g[5][s]].set(tot)
            else:
                tot = partial
            x = x.at[row_ids].set((c_pad[row_ids] - tot) * dinv)
        return (x, carry), None

    (x, _), _ = jax.lax.scan(body, (x, carry), jnp.arange(S))
    return x[:n]


def sptrsv_levels_ref(row_ids, dep_idx, dep_coef, dinv, carry_in, carry_out,
                      c_ids, c_pad, n: int, n_carry: int) -> jax.Array:
    """Single-group compatibility oracle (legacy flat signature; c_ids is
    accepted and ignored — row_ids doubles as the c gather index)."""
    del c_ids
    group = (row_ids, dep_idx, dep_coef, dinv, carry_in, carry_out)
    return sptrsv_levels_grouped_ref((group,), c_pad, n=n, n_carry=n_carry)


def spmv_ell_ref(ell_idx, ell_coef, x_pad) -> jax.Array:
    """y = A @ x for ELL-packed A.

    ell_idx (n_pad, D) i32 (padding -> len(x_pad)-1), ell_coef (n_pad, D) f,
    x_pad (n+1,) f.  Returns y (n_pad,).
    """
    return jnp.sum(ell_coef * x_pad[ell_idx], axis=-1)

"""Pure-jnp oracles for the Pallas kernels (numerics ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["sptrsv_levels_ref", "spmv_ell_ref"]


def sptrsv_levels_ref(row_ids, dep_idx, dep_coef, dinv, carry_in, carry_out,
                      c_ids, c_pad, n: int, n_carry: int) -> jax.Array:
    """Reference for the level-scheduled SpTRSV kernel.

    Shapes: row_ids (S,C) i32; dep_idx (S,C,D) i32; dep_coef (S,C,D) f;
    dinv (S,C) f; carry_in/out (S,C) i32; c_ids (S,C) i32; c_pad (n+1,) f.
    Returns x (n,).
    """
    x = jnp.zeros((n + 1,), dtype=c_pad.dtype)
    carry = jnp.zeros((n_carry + 2,), dtype=c_pad.dtype)

    def body(state, s):
        x, carry = state
        gathered = x[dep_idx[s]]
        partial = jnp.sum(dep_coef[s] * gathered, axis=-1)
        tot = partial + carry[carry_in[s]]
        xi = (c_pad[c_ids[s]] - tot) * dinv[s]
        x = x.at[row_ids[s]].set(xi)
        carry = carry.at[carry_out[s]].set(tot)
        return (x, carry), None

    (x, _), _ = jax.lax.scan(body, (x, carry), jnp.arange(row_ids.shape[0]))
    return x[:n]


def spmv_ell_ref(ell_idx, ell_coef, x_pad) -> jax.Array:
    """y = A @ x for ELL-packed A.

    ell_idx (n_pad, D) i32 (padding -> len(x_pad)-1), ell_coef (n_pad, D) f,
    x_pad (n+1,) f.  Returns y (n_pad,).
    """
    return jnp.sum(ell_coef * x_pad[ell_idx], axis=-1)

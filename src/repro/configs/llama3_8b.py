"""Llama-3-8B [arXiv:2407.21783; unverified]: 32L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=128256."""
from repro.models.config import ArchConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="llama3-8b", family="dense", n_layers=32, d_model=4096,
        n_heads=32, n_kv=8, d_ff=14336, vocab=128256, rope_theta=500000.0)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="llama3-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=8, n_kv=2, d_ff=160, vocab=512, param_dtype="float32",
        activation_dtype="float32")

"""Gemma-7B [arXiv:2403.08295; hf]: 28L d_model=3072 16H (kv=16) d_ff=24576
GeGLU, head_dim=256, vocab 256000, tied embeddings."""
from repro.models.config import ArchConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="gemma-7b", family="dense", n_layers=28, d_model=3072,
        n_heads=16, n_kv=16, d_ff=24576, vocab=256000, head_dim=256,
        act="gelu", tie_embeddings=True)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="gemma-7b-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv=4, d_ff=128, vocab=512, head_dim=32, act="gelu",
        tie_embeddings=True, param_dtype="float32",
        activation_dtype="float32")

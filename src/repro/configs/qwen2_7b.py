"""Qwen2-7B [arXiv:2407.10671; hf]: 28L d_model=3584 28H (GQA kv=4)
d_ff=18944 vocab=152064, QKV bias."""
from repro.models.config import ArchConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-7b", family="dense", n_layers=28, d_model=3584,
        n_heads=28, n_kv=4, d_ff=18944, vocab=152064, qkv_bias=True,
        rope_theta=1e6)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="qwen2-smoke", family="dense", n_layers=2, d_model=56,
        n_heads=7, n_kv=1, d_ff=128, vocab=512, qkv_bias=True,
        param_dtype="float32", activation_dtype="float32")

"""InternVL2-1B — InternViT frontend (STUB) + InternLM2-chat-1.8b-ish 0.5B
text backbone [arXiv:2404.16821; hf].  Backbone per assignment: 24L
d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655; patch embeddings arrive
precomputed (frontend_positions=256)."""
from repro.models.config import ArchConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="internvl2-1b", family="dense", n_layers=24, d_model=896,
        n_heads=14, n_kv=2, d_ff=4864, vocab=151655, rope_theta=1e6,
        act="silu", frontend="vlm", frontend_positions=256,
        tie_embeddings=True)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="internvl2-1b-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_ff=128, vocab=512, frontend="vlm",
        frontend_positions=8, tie_embeddings=True, param_dtype="float32",
        activation_dtype="float32")

"""SeamlessM4T-large-v2 backbone [arXiv:2308.11596; hf]: enc-dec, 24L
encoder + 24L decoder, d_model=1024 16H (kv=16) d_ff=8192 vocab=256206.
Audio frontend is a STUB: input_specs supplies precomputed frame
embeddings."""
from repro.models.config import ArchConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="seamless-m4t-large-v2", family="encdec", n_layers=24,
        n_layers_decoder=24, d_model=1024, n_heads=16, n_kv=16, d_ff=8192,
        vocab=256206, frontend="audio", act="gelu")


def reduced() -> ArchConfig:
    return ArchConfig(
        name="seamless-smoke", family="encdec", n_layers=2,
        n_layers_decoder=2, d_model=64, n_heads=4, n_kv=4, d_ff=128,
        vocab=512, frontend="audio", act="gelu", param_dtype="float32",
        activation_dtype="float32")

"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]:
24L d_model=1024 16H (GQA kv=8) vocab=49155; MoE 32 experts top-8,
d_ff_expert=512."""
from repro.models.config import ArchConfig, MoEConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="granite-moe-1b-a400m", family="moe", n_layers=24, d_model=1024,
        n_heads=16, n_kv=8, d_ff=512, vocab=49155, tie_embeddings=True,
        moe=MoEConfig(num_experts=32, top_k=8, d_ff_expert=512))


def reduced() -> ArchConfig:
    return ArchConfig(
        name="granite-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_ff=64, vocab=512, tie_embeddings=True,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64),
        param_dtype="float32", activation_dtype="float32")

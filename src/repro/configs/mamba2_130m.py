"""Mamba2-130M [arXiv:2405.21060; unverified]: 24L d_model=768 attn-free,
vocab=50280, ssm_state=128, SSD (state-space duality)."""
from repro.models.config import ArchConfig, SSMConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-130m", family="ssm", n_layers=24, d_model=768,
        n_heads=1, n_kv=1, d_ff=0, vocab=50280, attention="none",
        tie_embeddings=True,
        ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk=128))


def reduced() -> ArchConfig:
    return ArchConfig(
        name="mamba2-smoke", family="ssm", n_layers=2, d_model=64,
        n_heads=1, n_kv=1, d_ff=0, vocab=512, attention="none",
        tie_embeddings=True,
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, chunk=32),
        param_dtype="float32", activation_dtype="float32")

"""Assigned architecture configs (public-literature values; see each file).

registry(): name -> module with get_config() / reduced() / input shape info.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "internvl2_1b", "gemma_7b", "internlm2_1_8b", "llama3_8b", "qwen2_7b",
    "llama4_scout_17b_a16e", "granite_moe_1b_a400m", "seamless_m4t_large_v2",
    "mamba2_130m", "recurrentgemma_9b",
]

# canonical CLI ids (assignment spelling)
CLI_IDS = {
    "internvl2-1b": "internvl2_1b",
    "gemma-7b": "gemma_7b",
    "internlm2-1.8b": "internlm2_1_8b",
    "llama3-8b": "llama3_8b",
    "qwen2-7b": "qwen2_7b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "mamba2-130m": "mamba2_130m",
    "recurrentgemma-9b": "recurrentgemma_9b",
}


def _module(name: str) -> str:
    if name in CLI_IDS:
        return CLI_IDS[name]
    norm = name.replace("-", "_").replace(".", "_")
    return norm if norm in ARCHS else name


def get_config(name: str):
    return importlib.import_module(
        f"repro.configs.{_module(name)}").get_config()


def get_reduced(name: str):
    return importlib.import_module(
        f"repro.configs.{_module(name)}").reduced()


def all_arch_ids() -> list[str]:
    return sorted(CLI_IDS)

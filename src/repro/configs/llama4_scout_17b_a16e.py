"""Llama-4-Scout-17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]:
48L d_model=5120 40H (GQA kv=8) vocab=202048; MoE 16 experts top-1 with
d_ff_expert=8192 + shared expert (d_ff=8192)."""
from repro.models.config import ArchConfig, MoEConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="llama4-scout-17b-a16e", family="moe", n_layers=48,
        d_model=5120, n_heads=40, n_kv=8, d_ff=8192, vocab=202048,
        rope_theta=500000.0, remat_group=8,
        moe=MoEConfig(num_experts=16, top_k=1, d_ff_expert=8192,
                      shared_expert=True))


def reduced() -> ArchConfig:
    return ArchConfig(
        name="llama4-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_ff=96, vocab=512,
        moe=MoEConfig(num_experts=4, top_k=1, d_ff_expert=96,
                      shared_expert=True),
        param_dtype="float32", activation_dtype="float32")

"""RecurrentGemma-9B [arXiv:2402.19427; unverified]: 38L d_model=4096
16H (MQA kv=1) d_ff=12288 vocab=256000; RG-LRU + local attention (window
2048), pattern rec,rec,attn (1:2)."""
from repro.models.config import ArchConfig, RecurrentConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="recurrentgemma-9b", family="hybrid", n_layers=38,
        d_model=4096, n_heads=16, n_kv=1, d_ff=12288, vocab=256000,
        head_dim=256, act="gelu", attention="local", window=2048,
        tie_embeddings=True, scan_layers=False,
        recurrent=RecurrentConfig(lru_width=4096, window=2048))


def reduced() -> ArchConfig:
    return ArchConfig(
        name="rgemma-smoke", family="hybrid", n_layers=3, d_model=64,
        n_heads=4, n_kv=1, d_ff=128, vocab=512, head_dim=16, act="gelu",
        attention="local", window=16, tie_embeddings=True,
        scan_layers=False, recurrent=RecurrentConfig(lru_width=64, window=16),
        param_dtype="float32", activation_dtype="float32")

"""Level-set construction for lower-triangular sparse matrices.

The dependency DAG of L has an edge j -> i for every strict-lower nonzero
L[i, j].  level(i) = 1 + max(level(j) for j in deps(i)), level = 0 for rows
with no strict-lower nonzeros.  This is the classic level-set / wavefront
method [Anderson & Saad 1989; Saltz 1990] the paper builds on.

Implementation: vectorized topological sweep.  Because L is lower triangular,
row order is already a topological order, so a single forward pass computes
exact levels in O(nnz) with numpy, without Kahn queues.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .csr import CSR

__all__ = ["LevelSets", "build_levels", "level_costs", "row_costs"]


@dataclasses.dataclass(frozen=True)
class LevelSets:
    """Partition of rows into dependency levels.

    level_of:    (n,) int64, level id per row
    order:       (n,) int64, rows sorted by (level, row id)
    level_ptr:   (num_levels + 1,) int64 offsets into `order`
    """

    level_of: np.ndarray
    order: np.ndarray
    level_ptr: np.ndarray

    @property
    def num_levels(self) -> int:
        return int(self.level_ptr.shape[0] - 1)

    def rows_in_level(self, lvl: int) -> np.ndarray:
        return self.order[self.level_ptr[lvl]:self.level_ptr[lvl + 1]]

    def level_sizes(self) -> np.ndarray:
        return np.diff(self.level_ptr)


def build_levels(L: CSR) -> LevelSets:
    """Compute level sets of lower-triangular CSR matrix L.

    Pure-python loop over rows would be O(n) python overhead; instead we do a
    blocked forward sweep: process rows in order, but vectorize the
    max-over-deps with np.maximum.reduceat per row block.  For full
    vectorization we exploit that dependencies always point backwards.
    """
    n = L.n_rows
    indptr, indices = L.indptr, L.indices
    # strict-lower mask per entry
    rows = np.repeat(np.arange(n), np.diff(indptr))
    strict = indices < rows
    # level[i] = 1 + max(level[j] for j strict deps); level[j] values are
    # produced during the same sweep, so a fully vectorized one-shot pass is
    # impossible in general.  Instead sweep in "waves": repeatedly assign
    # levels to rows whose deps are all assigned.  Number of waves = DAG
    # depth, each wave vectorized -> O(depth * nnz) worst case.
    sl_counts = np.zeros(n, dtype=np.int64)
    np.add.at(sl_counts, rows[strict], 1)
    level = _wave_sweep(n, rows, indices, strict, sl_counts)
    order = np.lexsort((np.arange(n), level))
    num_levels = int(level.max()) + 1 if n else 0
    counts = np.bincount(level, minlength=num_levels)
    level_ptr = np.zeros(num_levels + 1, dtype=np.int64)
    level_ptr[1:] = np.cumsum(counts)
    return LevelSets(level_of=level, order=order, level_ptr=level_ptr)


def _wave_sweep(n: int, rows: np.ndarray, cols: np.ndarray, strict: np.ndarray,
                sl_counts: np.ndarray) -> np.ndarray:
    """Kahn-style wavefront levelization, vectorized per wave."""
    level = np.full(n, -1, dtype=np.int64)
    remaining = sl_counts.copy()
    # adjacency in CSC-ish form: for each column j, the dependent rows i
    srows, scols = rows[strict], cols[strict]
    order = np.argsort(scols, kind="stable")
    srows_by_col = srows[order]
    colptr = np.zeros(n + 1, dtype=np.int64)
    colptr[1:] = np.cumsum(np.bincount(scols, minlength=n))

    frontier = np.flatnonzero(remaining == 0)
    level[frontier] = 0
    cur = 0
    while frontier.size:
        # all rows depending on the frontier get their counters decremented
        lo, hi = colptr[frontier], colptr[frontier + 1]
        if lo.size == 0:
            break
        # gather dependents
        seg_lens = hi - lo
        total = int(seg_lens.sum())
        if total == 0:
            break
        idx = np.repeat(lo, seg_lens) + _segment_arange(seg_lens)
        dependents = srows_by_col[idx]
        np.subtract.at(remaining, dependents, 1)
        ready = np.unique(dependents[remaining[dependents] == 0])
        cur += 1
        level[ready] = cur
        frontier = ready
    assert (level >= 0).all(), "cycle detected — matrix not lower-triangular?"
    return level


def _segment_arange(seg_lens: np.ndarray) -> np.ndarray:
    """[0..l0-1, 0..l1-1, ...] for segment lengths seg_lens (vectorized)."""
    total = int(seg_lens.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    ends = np.cumsum(seg_lens)
    starts = ends - seg_lens
    r = np.arange(total, dtype=np.int64)
    return r - np.repeat(starts, seg_lens)


# -- cost model (paper §III) -------------------------------------------------

def row_costs(L: CSR) -> np.ndarray:
    """cost(row) = 2*nnz(row) - 1 (nnz includes the diagonal)."""
    return 2 * L.row_nnz() - 1


def level_costs(L: CSR, levels: LevelSets) -> np.ndarray:
    """cost(level) = sum of row costs = 2*sum(nnz) - n_rows_in_level."""
    rc = row_costs(L)
    out = np.zeros(levels.num_levels, dtype=np.int64)
    np.add.at(out, levels.level_of, rc)
    return out

from .csr import CSR, from_coo, identity, tril
from .levels import LevelSets, build_levels, level_costs, row_costs
from . import generators, io

__all__ = [
    "CSR", "from_coo", "identity", "tril",
    "LevelSets", "build_levels", "level_costs", "row_costs",
    "generators", "io",
]

"""CSR sparse-matrix container used throughout the framework.

Preprocessing (level sets, graph transformation) runs on numpy int/float
arrays; execution-side structures (ELL level schedules) are converted to JAX
arrays by the solver layer.  We deliberately do not depend on scipy for the
core container (scipy is only used as a test oracle).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

import numpy as np

__all__ = ["CSR", "from_coo", "identity", "tril", "triu", "reverse_both",
           "same_pattern"]


@dataclasses.dataclass(frozen=True)
class CSR:
    """Compressed-sparse-row matrix.

    indptr:  (n_rows + 1,) int64
    indices: (nnz,)        int64, column ids, sorted within a row
    data:    (nnz,)        float64 (or other float dtype)
    shape:   (n_rows, n_cols)
    """

    indptr: np.ndarray
    indices: np.ndarray
    data: np.ndarray
    shape: tuple[int, int]

    # -- basic properties ---------------------------------------------------
    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_cols(self) -> int:
        return self.shape[1]

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    @property
    def dtype(self):
        return self.data.dtype

    def row(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        """(column ids, values) of row i."""
        lo, hi = self.indptr[i], self.indptr[i + 1]
        return self.indices[lo:hi], self.data[lo:hi]

    def row_nnz(self) -> np.ndarray:
        return np.diff(self.indptr)

    # -- ops ----------------------------------------------------------------
    def diagonal(self) -> np.ndarray:
        d = np.zeros(min(self.shape), dtype=self.data.dtype)
        for i in range(min(self.shape)):
            cols, vals = self.row(i)
            hit = np.searchsorted(cols, i)
            if hit < cols.shape[0] and cols[hit] == i:
                d[i] = vals[hit]
        return d

    def diagonal_fast(self) -> np.ndarray:
        """Vectorized diagonal extraction (rows must be column-sorted)."""
        n = min(self.shape)
        rows = np.repeat(np.arange(self.n_rows), self.row_nnz())
        mask = rows == self.indices
        d = np.zeros(n, dtype=self.data.dtype)
        d[rows[mask]] = self.data[mask]
        return d

    def matvec(self, x: np.ndarray, transpose: bool = False) -> np.ndarray:
        """y = A @ x (or A.T @ x) for x of shape (n,) or batched (n, k).

        The transpose path scatters instead of gathers — no materialized
        A.T needed, so iterative-refinement residuals for L^T solves stay
        O(nnz) with zero preprocessing.
        """
        rows = np.repeat(np.arange(self.n_rows), self.row_nnz())
        src, dst, n_out = ((rows, self.indices, self.n_cols) if transpose
                           else (self.indices, rows, self.n_rows))
        gathered = x[src]
        prod = (self.data * gathered if gathered.ndim == 1
                else self.data[:, None] * gathered)
        out = np.zeros((n_out,) + x.shape[1:],
                       dtype=np.result_type(self.data, x))
        np.add.at(out, dst, prod)
        return out

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=self.data.dtype)
        rows = np.repeat(np.arange(self.n_rows), self.row_nnz())
        out[rows, self.indices] = self.data
        return out

    def transpose_csc_view(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Return (colptr, row_indices, perm) — CSC view of the same matrix.

        perm maps CSC-order positions back into CSR `data` order, so
        data[perm] gives values in CSC order.
        """
        order = np.argsort(self.indices, kind="stable")
        rows = np.repeat(np.arange(self.n_rows), self.row_nnz())
        colptr = np.zeros(self.n_cols + 1, dtype=np.int64)
        counts = np.bincount(self.indices, minlength=self.n_cols)
        colptr[1:] = np.cumsum(counts)
        return colptr, rows[order], order

    def transpose(self) -> "CSR":
        """Materialized A.T as CSR (the CSC view reinterpreted).

        The stable argsort in transpose_csc_view keeps CSR order within a
        column, so the result's rows come out column-sorted without a
        re-sort.
        """
        colptr, rows, perm = self.transpose_csc_view()
        return CSR(indptr=colptr, indices=rows, data=self.data[perm],
                   shape=(self.n_cols, self.n_rows))

    def with_data(self, data: np.ndarray) -> "CSR":
        """Same pattern, new values (shares indptr/indices arrays).

        The primitive under the pattern-frozen refactorization paths:
        `data` must be in this matrix's CSR entry order and is the only
        thing that changes — no re-sort, no structural work.
        """
        data = np.asarray(data)
        if data.shape != self.data.shape:
            raise ValueError(
                f"with_data: expected {self.data.shape[0]} values for the "
                f"frozen pattern, got {data.shape}")
        return CSR(indptr=self.indptr, indices=self.indices, data=data,
                   shape=self.shape)

    def check(self) -> None:
        assert self.indptr.shape == (self.n_rows + 1,)
        assert self.indptr[0] == 0 and self.indptr[-1] == self.nnz
        assert np.all(np.diff(self.indptr) >= 0)
        if self.nnz:
            assert self.indices.min() >= 0 and self.indices.max() < self.n_cols
        # sorted within rows, no duplicates
        for i in range(self.n_rows):
            cols, _ = self.row(i)
            assert np.all(np.diff(cols) > 0), f"row {i} unsorted/dup"

    def __repr__(self) -> str:  # pragma: no cover
        return f"CSR(shape={self.shape}, nnz={self.nnz}, dtype={self.dtype})"


def from_coo(rows: Iterable[int], cols: Iterable[int], vals: Iterable[float],
             shape: tuple[int, int], sum_duplicates: bool = True) -> CSR:
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    vals = np.asarray(vals, dtype=np.float64)
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    if sum_duplicates and rows.size:
        key_same = (rows[1:] == rows[:-1]) & (cols[1:] == cols[:-1])
        if key_same.any():
            group = np.concatenate([[0], np.cumsum(~key_same)])
            n_groups = group[-1] + 1
            new_vals = np.zeros(n_groups, dtype=vals.dtype)
            np.add.at(new_vals, group, vals)
            first = np.concatenate([[True], ~key_same])
            rows, cols, vals = rows[first], cols[first], new_vals
    indptr = np.zeros(shape[0] + 1, dtype=np.int64)
    np.add.at(indptr, rows + 1, 1)
    indptr = np.cumsum(indptr)
    return CSR(indptr=indptr, indices=cols, data=vals, shape=shape)


def identity(n: int, dtype=np.float64) -> CSR:
    return CSR(indptr=np.arange(n + 1, dtype=np.int64),
               indices=np.arange(n, dtype=np.int64),
               data=np.ones(n, dtype=dtype), shape=(n, n))


def tril(m: CSR, keep_diagonal: bool = True) -> CSR:
    """Lower-triangular part of `m` (optionally including the diagonal)."""
    rows = np.repeat(np.arange(m.n_rows), m.row_nnz())
    keep = m.indices < rows + (1 if keep_diagonal else 0)
    return from_coo(rows[keep], m.indices[keep], m.data[keep], m.shape,
                    sum_duplicates=False)


def triu(m: CSR, keep_diagonal: bool = True) -> CSR:
    """Upper-triangular part of `m` (optionally including the diagonal)."""
    rows = np.repeat(np.arange(m.n_rows), m.row_nnz())
    keep = m.indices > rows - (1 if keep_diagonal else 0)
    return from_coo(rows[keep], m.indices[keep], m.data[keep], m.shape,
                    sum_duplicates=False)


def same_pattern(a: CSR, b: CSR) -> bool:
    """True when `a` and `b` have identical sparsity patterns.

    Identical means the same shape and bitwise-equal indptr/indices — the
    exact precondition of every value-only fast path (`update_values`,
    `factorize.refactor`): equal patterns guarantee entry k of one matrix
    addresses the same (row, col) as entry k of the other.
    """
    return (a.shape == b.shape
            and a.indptr.shape == b.indptr.shape
            and a.indices.shape == b.indices.shape
            and np.array_equal(a.indptr, b.indptr)
            and np.array_equal(a.indices, b.indices))


def reverse_both(m: CSR) -> CSR:
    """P @ m @ P for the reversal permutation P (i -> n-1-i on both axes).

    Reversing both axes turns an upper-triangular matrix into a
    lower-triangular one with the same dependency DAG (edges reversed in
    row order) — the bridge that lets upper/transpose solves reuse the
    lower-triangular schedule compiler: solve(U, b) == reverse(
    solve(reverse_both(U), reverse(b))).
    """
    rows = np.repeat(np.arange(m.n_rows), m.row_nnz())
    return from_coo(m.n_rows - 1 - rows, m.n_cols - 1 - m.indices, m.data,
                    m.shape, sum_duplicates=False)

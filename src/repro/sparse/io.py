"""MatrixMarket IO so real SuiteSparse matrices (lung2, torso2) can be used
when available (REPRO_MATRIX_DIR); the container itself is offline."""
from __future__ import annotations

import gzip
import os
from pathlib import Path

import numpy as np

from .csr import CSR, from_coo, tril

__all__ = ["read_matrix_market", "write_matrix_market", "load_named"]


def read_matrix_market(path: str | Path) -> CSR:
    path = Path(path)
    opener = gzip.open if path.suffix == ".gz" else open
    with opener(path, "rt") as f:
        header = f.readline().strip().split()
        assert header[0] == "%%MatrixMarket" and header[1] == "matrix"
        fmt, field, symmetry = header[2], header[3], header[4]
        assert fmt == "coordinate", "only coordinate format supported"
        line = f.readline()
        while line.startswith("%"):
            line = f.readline()
        nr, nc, nnz = (int(t) for t in line.split())
        data = np.loadtxt(f, max_rows=nnz, ndmin=2)
    rows = data[:, 0].astype(np.int64) - 1
    cols = data[:, 1].astype(np.int64) - 1
    if field == "pattern":
        vals = np.ones(rows.shape[0])
    else:
        vals = data[:, 2].astype(np.float64)
    if symmetry in ("symmetric", "skew-symmetric", "hermitian"):
        off = rows != cols
        sign = -1.0 if symmetry == "skew-symmetric" else 1.0
        rows = np.concatenate([rows, cols[off]])
        cols_new = np.concatenate([cols, data[:, 0].astype(np.int64)[off] - 1])
        vals = np.concatenate([vals, sign * vals[off]])
        cols = cols_new
    return from_coo(rows, cols, vals, (nr, nc))


def write_matrix_market(m: CSR, path: str | Path) -> None:
    path = Path(path)
    rows = np.repeat(np.arange(m.n_rows), m.row_nnz())
    with open(path, "w") as f:
        f.write("%%MatrixMarket matrix coordinate real general\n")
        f.write(f"{m.n_rows} {m.n_cols} {m.nnz}\n")
        for r, c, v in zip(rows, m.indices, m.data):
            f.write(f"{r + 1} {c + 1} {v:.17g}\n")


def load_named(name: str) -> CSR:
    """Load a real matrix (lower-triangular part) from REPRO_MATRIX_DIR, or
    fall back to the calibrated synthetic analogue."""
    mdir = os.environ.get("REPRO_MATRIX_DIR")
    if mdir:
        for cand in (Path(mdir) / f"{name}.mtx", Path(mdir) / f"{name}.mtx.gz"):
            if cand.exists():
                full = read_matrix_market(cand)
                L = tril(full, keep_diagonal=True)
                # ensure nonzero diagonal
                d = L.diagonal_fast()
                if np.any(d == 0):
                    raise ValueError(f"{name}: zero diagonal in tril; fixup needed")
                return L
    from . import generators
    if name == "lung2":
        return generators.lung2_like()
    if name == "torso2":
        return generators.torso2_like()
    raise KeyError(name)

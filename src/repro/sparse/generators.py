"""Synthetic lower-triangular matrix generators.

The container has no network access, so the SuiteSparse matrices used by the
paper (lung2, torso2) are replaced by *structural analogues* calibrated to the
statistics reported in the paper (see DESIGN.md §5).  All generators return
CSR lower-triangular matrices with unit-scale, diagonally-dominant values so
triangular solves are numerically well-behaved in tests.

Level structure is controlled exactly: a generator takes a rows-per-level
profile and an in-degree distribution, then wires each row's dependencies to
rows in *previous* levels (at controlled level distances), guaranteeing the
level-set builder recovers the intended profile.
"""
from __future__ import annotations

import numpy as np

from .csr import CSR, from_coo

__all__ = [
    "chain", "random_lower", "banded", "poisson2d_ic0",
    "from_level_profile", "lung2_like", "torso2_like", "with_values",
    "poisson2d_spd", "poisson3d_spd", "random_spd", "spd_from_lower",
]


def _values_for(rows: np.ndarray, cols: np.ndarray, n: int,
                rng: np.random.Generator) -> np.ndarray:
    """Diagonally dominant values: |diag| > sum |off-diag| per row."""
    vals = rng.uniform(-1.0, 1.0, size=rows.shape[0])
    diag_mask = rows == cols
    # set diagonal to (sum of |offdiag| in the row) + U(1, 2)
    abssum = np.zeros(n)
    np.add.at(abssum, rows[~diag_mask], np.abs(vals[~diag_mask]))
    vals[diag_mask] = (abssum[rows[diag_mask]] + rng.uniform(1.0, 2.0, diag_mask.sum()))
    return vals


def chain(n: int, seed: int = 0) -> CSR:
    """Pure dependency chain: row i depends on row i-1.  Worst case: n levels."""
    rng = np.random.default_rng(seed)
    rows = np.concatenate([np.arange(n), np.arange(1, n)])
    cols = np.concatenate([np.arange(n), np.arange(n - 1)])
    vals = _values_for(rows, cols, n, rng)
    return from_coo(rows, cols, vals, (n, n), sum_duplicates=False)


def banded(n: int, bandwidth: int, seed: int = 0) -> CSR:
    """Dense band of width `bandwidth` below the diagonal."""
    rng = np.random.default_rng(seed)
    r, c = [], []
    for b in range(bandwidth + 1):
        r.append(np.arange(b, n))
        c.append(np.arange(0, n - b))
    rows, cols = np.concatenate(r), np.concatenate(c)
    vals = _values_for(rows, cols, n, rng)
    return from_coo(rows, cols, vals, (n, n))


def random_lower(n: int, avg_offdiag: float = 3.0, seed: int = 0,
                 max_back: int | None = None) -> CSR:
    """Random lower-triangular matrix with ~avg_offdiag strict-lower nnz/row.

    max_back limits how far back dependencies reach (bandwidth-ish bound).
    """
    rng = np.random.default_rng(seed)
    counts = rng.poisson(avg_offdiag, size=n)
    counts = np.minimum(counts, np.arange(n))  # row i has at most i deps
    if max_back is not None:
        counts = np.minimum(counts, max_back)
    total = int(counts.sum())
    rows = np.repeat(np.arange(n), counts)
    lo = rows - (max_back if max_back is not None else rows)
    lo = np.maximum(lo, 0)
    u = rng.random(total)
    cols = (lo + u * (rows - lo)).astype(np.int64)
    cols = np.minimum(cols, rows - 1)
    # diagonal
    rows = np.concatenate([rows, np.arange(n)])
    cols = np.concatenate([cols, np.arange(n)])
    vals = np.ones(rows.shape[0])
    m = from_coo(rows, cols, vals, (n, n), sum_duplicates=True)
    # re-randomize values after dedup
    r2 = np.repeat(np.arange(n), m.row_nnz())
    data = _values_for(r2, m.indices, n, rng)
    return CSR(indptr=m.indptr, indices=m.indices, data=data, shape=m.shape)


def poisson2d_ic0(nx: int, ny: int, seed: int = 0) -> CSR:
    """Lower-triangular part of the 5-point Laplacian on an nx*ny grid.

    Structure matches the IC(0) factor sparsity used in preconditioned CG —
    the paper's motivating application class.
    """
    rng = np.random.default_rng(seed)
    n = nx * ny
    idx = np.arange(n)
    ix, iy = idx % nx, idx // nx
    rows, cols = [idx], [idx]
    west = idx[ix > 0]
    rows.append(west); cols.append(west - 1)
    south = idx[iy > 0]
    rows.append(south); cols.append(south - nx)
    rows, cols = np.concatenate(rows), np.concatenate(cols)
    vals = _values_for(rows, cols, n, rng)
    return from_coo(rows, cols, vals, (n, n))


def from_level_profile(level_sizes: np.ndarray,
                       indegree_sampler,
                       distance_sampler,
                       seed: int = 0,
                       locality: float | None = None) -> CSR:
    """Build a lower-triangular matrix with an exact rows-per-level profile.

    level_sizes:      rows per level, level_sizes[0] >= 1 (roots).
    indegree_sampler: f(rng, level_id, n_rows) -> int array of strict-lower
                      in-degrees for that level's rows (>=1 for level>0).
    distance_sampler: f(rng, level_id, k) -> int array (k,) of level distances
                      (>=1) for dependency targets; one dep per row is forced
                      to distance 1 so the row's level is exact.
    locality:         if set (e.g. 0.02), rows carry a spatial coordinate
                      u = rank/level_size and dependencies target rows with
                      similar u in earlier levels (sigma = locality).  This is
                      the mesh-locality of FEM discretizations (torso2): deep
                      substitution chains then share ancestors, so the
                      rearrangement step of the rewriting engine dedupes them
                      instead of exploding the in-degree.
    """
    rng = np.random.default_rng(seed)
    level_sizes = np.asarray(level_sizes, dtype=np.int64)
    assert level_sizes[0] >= 1
    num_levels = level_sizes.shape[0]
    n = int(level_sizes.sum())
    # row ids per level (contiguous, ascending with level => lower triangular)
    starts = np.concatenate([[0], np.cumsum(level_sizes)])
    rows_list, cols_list = [], []

    def _pick(tgt_lvl: np.ndarray, u: np.ndarray) -> np.ndarray:
        """Choose one row id inside each target level, locality-aware."""
        lo, hi = starts[tgt_lvl], starts[tgt_lvl + 1]
        if locality is None:
            return lo + (rng.random(tgt_lvl.shape[0]) * (hi - lo)).astype(np.int64)
        uu = np.clip(u + rng.normal(0.0, locality, size=u.shape[0]), 0.0, 1.0 - 1e-9)
        return lo + (uu * (hi - lo)).astype(np.int64)

    for lvl in range(1, num_levels):
        m = int(level_sizes[lvl])
        rids = np.arange(starts[lvl], starts[lvl + 1])
        upos = (np.arange(m) + 0.5) / m
        indeg = np.asarray(indegree_sampler(rng, lvl, m), dtype=np.int64)
        indeg = np.maximum(indeg, 1)
        # dep #1: distance 1 (pins the level)
        dep1 = _pick(np.full(m, lvl - 1, dtype=np.int64), upos)
        rows_list.append(rids); cols_list.append(dep1)
        # extra deps: sampled level distances
        extra = indeg - 1
        tot = int(extra.sum())
        if tot:
            rr = np.repeat(rids, extra)
            uu = np.repeat(upos, extra)
            dist = np.asarray(distance_sampler(rng, lvl, tot), dtype=np.int64)
            dist = np.clip(dist, 1, lvl)
            cc = _pick(lvl - dist, uu)
            rows_list.append(rr); cols_list.append(cc)
    rows = np.concatenate(rows_list) if rows_list else np.zeros(0, np.int64)
    cols = np.concatenate(cols_list) if cols_list else np.zeros(0, np.int64)
    # diagonal
    rows = np.concatenate([rows, np.arange(n)])
    cols = np.concatenate([cols, np.arange(n)])
    vals = np.ones(rows.shape[0])
    m = from_coo(rows, cols, vals, (n, n), sum_duplicates=True)
    r2 = np.repeat(np.arange(n), m.row_nnz())
    data = _values_for(r2, m.indices, n, rng)
    return CSR(indptr=m.indptr, indices=m.indices, data=data, shape=m.shape)


def lung2_like(scale: float = 1.0, seed: int = 7) -> CSR:
    """Structural analogue of SuiteSparse lung2's lower-triangular part.

    Calibration targets (paper Table I + text): n = 109,460; nnz(L) ~ 273,647;
    479 levels; 453 levels (94%) with exactly 2 rows; total level cost 437,834
    (cost = 2*nnz - n); avg level cost ~ 914.

    Structure: 26 fat levels carrying ~108.5k rows, interleaved with 6 runs of
    2-row thin chain levels (453 total).  Thin rows have in-degree 1; fat rows
    in-degree ~1.5 (to hit the nnz budget).
    """
    # 479 levels: fat levels at positions spread out; thin runs between.
    n_target = int(round(109_460 * scale))
    thin_levels = 453
    fat_levels = 26
    thin_rows = 2 * thin_levels
    fat_rows_total = n_target - thin_rows
    fat_sizes = _spread(fat_rows_total, fat_levels)
    # interleave: fat0 [thin run] fat1 [thin run] ... runs roughly equal
    runs = _spread(thin_levels, fat_levels - 1)  # thin run between fats
    sizes = []
    kinds = []
    for i in range(fat_levels):
        sizes.append(fat_sizes[i]); kinds.append("fat")
        if i < fat_levels - 1:
            sizes.extend([2] * runs[i]); kinds.extend(["thin"] * runs[i])
    level_sizes = np.asarray(sizes, dtype=np.int64)
    assert level_sizes.sum() == n_target and level_sizes.shape[0] == 479

    kinds = np.asarray(kinds)

    def indeg(rng, lvl, m):
        if kinds[lvl] == "thin":
            return np.ones(m, dtype=np.int64)
        # fat rows: mostly 1 dep, some 2 — tune to hit nnz ~ 273,647
        return 1 + (rng.random(m) < 0.50).astype(np.int64)

    def dist(rng, lvl, k):
        # deps point to nearby levels (spatial locality of lung2 discretization)
        return 1 + rng.geometric(0.8, size=k) - 1 + 1  # mostly 1-2

    return from_level_profile(level_sizes, indeg, dist, seed=seed)


def torso2_like(scale: float = 1.0, seed: int = 11) -> CSR:
    """Structural analogue of SuiteSparse torso2's lower-triangular part.

    Calibration targets: n = 115,967; nnz(L) ~ 575,726; 513 levels; a smooth
    triangular rows-per-level profile (no long 2-row chains); total level cost
    ~1,035,484; avg level cost ~2014.6.
    """
    n_target = int(round(115_967 * scale))
    num_levels = 513
    # triangular rows-per-level profile (paper: "torso2 has a triangular shape
    # in terms of number of rows in a level" and "many rows in a level, the
    # variation is much less across levels"): linear taper from ~2x mean to
    # ~1/4 mean — thin levels are *moderately* thin, no 2-row chains.
    x = np.arange(num_levels, dtype=np.float64)
    prof = 2.0 - 1.75 * x / (num_levels - 1)
    sizes = np.maximum(1, np.round(prof / prof.sum() * n_target)).astype(np.int64)
    # fix rounding to hit n exactly
    diff = n_target - int(sizes.sum())
    sizes[np.argmax(sizes)] += diff
    assert sizes.sum() == n_target

    def indeg(rng, lvl, m):
        # ~5 nnz/row in L => ~4 strict-lower deps, varying
        return 1 + rng.poisson(3.2, size=m)

    def dist(rng, lvl, k):
        return 1 + rng.geometric(0.45, size=k) - 1 + 1  # spread over few levels

    # mesh locality: FEM neighbours share ancestors (see from_level_profile)
    return from_level_profile(sizes, indeg, dist, seed=seed, locality=0.003)


# -- SPD generators (factorization inputs for repro.precond) ------------------
#
# The triangular generators above produce *factors*; the preconditioning
# subsystem needs full SPD (or general square) *systems* to factor.  These
# return symmetric positive-definite CSR matrices directly, so examples and
# tests no longer have to assemble A = L @ L.T by hand.


def _grid_laplacian(dims: tuple[int, ...]) -> CSR:
    """(2*ndim)+1-point Laplacian on a regular grid: diag = 2*ndim,
    nearest-neighbour off-diagonals = -1.  Symmetric, irreducibly
    diagonally dominant with positive diagonal => SPD."""
    n = int(np.prod(dims))
    idx = np.arange(n)
    coords = []
    rem = idx
    for d in dims:                      # x fastest, matching poisson2d_ic0
        coords.append(rem % d)
        rem = rem // d
    rows = [idx]
    cols = [idx]
    vals = [np.full(n, 2.0 * len(dims))]
    stride = 1
    for axis, d in enumerate(dims):
        has_prev = idx[coords[axis] > 0]
        for r, c in ((has_prev, has_prev - stride),
                     (has_prev - stride, has_prev)):
            rows.append(r)
            cols.append(c)
            vals.append(np.full(r.shape[0], -1.0))
        stride *= d
    return from_coo(np.concatenate(rows), np.concatenate(cols),
                    np.concatenate(vals), (n, n), sum_duplicates=False)


def poisson2d_spd(nx: int, ny: int) -> CSR:
    """5-point Laplacian on an nx*ny grid — the canonical SPD test system
    (its IC(0) factor has the poisson2d_ic0 sparsity structure)."""
    return _grid_laplacian((nx, ny))


def poisson3d_spd(nx: int, ny: int, nz: int) -> CSR:
    """7-point Laplacian on an nx*ny*nz grid (SPD)."""
    return _grid_laplacian((nx, ny, nz))


def _spd_from_strict_lower(rows: np.ndarray, cols: np.ndarray, n: int,
                           rng: np.random.Generator) -> CSR:
    """Symmetric diagonally-dominant CSR from strict-lower pattern entries.

    Mirrors the entries, draws one value per unordered pair, then sets
    diag[i] = sum_j |offdiag[i, j]| + U(1, 2): symmetric + strictly
    diagonally dominant + positive diagonal => positive definite.
    """
    vals = rng.uniform(-1.0, 1.0, size=rows.shape[0])
    r = np.concatenate([rows, cols, np.arange(n)])
    c = np.concatenate([cols, rows, np.arange(n)])
    v = np.concatenate([vals, vals, np.zeros(n)])
    abssum = np.zeros(n)
    np.add.at(abssum, rows, np.abs(vals))
    np.add.at(abssum, cols, np.abs(vals))
    v[-n:] = abssum + rng.uniform(1.0, 2.0, n)
    return from_coo(r, c, v, (n, n), sum_duplicates=True)


def random_spd(n: int, avg_offdiag: float = 3.0, seed: int = 0,
               max_back: int | None = None) -> CSR:
    """Random sparse SPD matrix (~avg_offdiag strict-lower nnz per row).

    Diagonally dominant by construction, so both `ic0` and `ilu0` factor it
    without breakdown; `max_back` bounds the bandwidth like random_lower.
    """
    rng = np.random.default_rng(seed)
    pat = random_lower(n, avg_offdiag=avg_offdiag, seed=seed,
                       max_back=max_back)
    prows = np.repeat(np.arange(n), pat.row_nnz())
    strict = pat.indices < prows
    return _spd_from_strict_lower(prows[strict], pat.indices[strict], n, rng)


def spd_from_lower(L: CSR, seed: int = 0) -> CSR:
    """SPD matrix whose strict-lower pattern equals L's strict-lower pattern.

    tril(A) then has exactly L's sparsity, so IC(0) factors of A inherit the
    level/dependency structure of the benchmark analogues (lung2_like,
    torso2_like) — the bridge from "triangular-factor generator" to
    "end-to-end preconditioned-solver benchmark".
    """
    rng = np.random.default_rng(seed)
    rows = np.repeat(np.arange(L.n_rows), L.row_nnz())
    strict = L.indices < rows
    return _spd_from_strict_lower(rows[strict], L.indices[strict],
                                  L.n_rows, rng)


def with_values(m: CSR, seed: int = 0) -> CSR:
    """Re-randomize values of an existing pattern (diag-dominant)."""
    rng = np.random.default_rng(seed)
    rows = np.repeat(np.arange(m.n_rows), m.row_nnz())
    data = _values_for(rows, m.indices, m.n_rows, rng)
    return CSR(indptr=m.indptr, indices=m.indices, data=data, shape=m.shape)


def _spread(total: int, parts: int) -> list[int]:
    base = total // parts
    rem = total - base * parts
    return [base + (1 if i < rem else 0) for i in range(parts)]

"""Synthetic deterministic data pipeline.

A real deployment would stream tokenized shards; here the pipeline generates
a reproducible synthetic LM stream (mixture of Zipf unigrams + copy motifs so
the loss actually decreases), sharded per host, with background prefetch.
The interface (iterator of batches + host_shard metadata) is what train.py
consumes, so swapping in a real loader touches nothing else.
"""
from __future__ import annotations

import queue
import threading

import numpy as np

__all__ = ["SyntheticLM", "Prefetcher"]


class SyntheticLM:
    """Deterministic synthetic token stream.

    Each document interleaves Zipf-distributed tokens with repeated motifs;
    labels are next-token; mask is all-ones.  Seeded per (step, host) so any
    restart reproduces the exact stream (important for checkpoint/restart
    equivalence tests).
    """

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 host_id: int = 0, num_hosts: int = 1, seed: int = 1234,
                 frontend: str = "none", frontend_positions: int = 0,
                 d_model: int = 0, encdec: bool = False):
        assert global_batch % num_hosts == 0
        self.vocab, self.seq = vocab, seq_len
        self.batch = global_batch // num_hosts
        self.host_id, self.seed = host_id, seed
        self.frontend, self.fpos = frontend, frontend_positions
        self.d_model, self.encdec = d_model, encdec

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 131 + self.host_id)
        B, S = self.batch, self.seq
        # zipf base stream (clipped to vocab)
        toks = rng.zipf(1.3, size=(B, S + 1)).astype(np.int64)
        toks = np.minimum(toks, self.vocab - 1)
        # motif copies: each row repeats a short motif at a random offset —
        # learnable structure so training loss visibly drops
        motif_len = min(16, max(2, S // 4))
        motif = rng.integers(2, min(self.vocab, 1000), size=(B, motif_len))
        for rep in range(3):
            off = rng.integers(0, max(1, S - motif_len), size=B)
            rows = np.arange(B)[:, None]
            cols = off[:, None] + np.arange(motif_len)[None, :]
            toks[rows, cols] = motif
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        out = {"tokens": tokens, "labels": labels,
               "mask": np.ones_like(tokens, np.float32)}
        if self.encdec:
            out["src_embeds"] = rng.standard_normal(
                (B, S, self.d_model)).astype(np.float32)
        elif self.frontend != "none":
            out["prefix_embeds"] = rng.standard_normal(
                (B, self.fpos, self.d_model)).astype(np.float32)
        return out

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch queue over any batch iterator."""

    def __init__(self, it, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = iter(it)
        self._done = object()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item

"""Sharding rules: parameter-path-pattern -> PartitionSpec.

Scheme (DESIGN.md §6): 2D sharding — tensor-parallel dims over 'model',
FSDP dims over 'data'; the multi-pod 'pod' axis carries data parallelism
(batch) only, with params replicated across pods (gradients all-reduce over
'pod' implicitly via pjit).  Every rule degrades gracefully: an axis is only
applied if the dimension is divisible by the mesh axis size (replicate
otherwise) — this keeps every (arch x shape x mesh) cell lowerable even for
odd head counts / vocab sizes.

Scan-stacked block params carry a leading n_layers axis: rules are written
for the unstacked shape and a leading None is prepended automatically.
"""
from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["param_specs", "param_shardings", "batch_specs", "logical_rules",
           "spec_for_path"]

# (path regex, spec for the UNSTACKED tensor, applied right-aligned)
RULES: list[tuple[str, tuple]] = [
    (r"embed/w$",               ("model", "data")),    # (V, d)
    (r"unembed/w$",             ("data", "model")),    # (d, V)
    (r"attn/[qkv]/w$",          ("data", "model")),    # (d, H*hd)
    (r"attn/o/w$",              ("model", "data")),    # (H*hd, d)
    (r"cross/[qkv]/w$",         ("data", "model")),
    (r"cross/o/w$",             ("model", "data")),
    (r"(mlp|shared)/(gate|up)/w$", ("data", "model")),  # (d, ff)
    (r"(mlp|shared)/down/w$",   ("model", "data")),     # (ff, d)
    (r"moe/router/w$",          ("data", None)),
    (r"moe/(gate_w|up_w)$",     ("model", "data", None)),  # (E, d, f)
    (r"moe/down_w$",            ("model", None, "data")),  # (E, f, d)
    (r"in_proj/w$",             ("data", "model")),
    (r"out_proj/w$",            ("model", "data")),
    (r"(in_x|in_gate)/w$",      ("data", "model")),
    (r"(gate_a|gate_x)/w$",     (None, "model")),
    (r"out/w$",                 ("model", "data")),
    # everything else (norms, biases, convs, lam, A_log, D, dt_bias): replicate
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def spec_for_path(path_str: str, shape: tuple, mesh: Mesh,
                  fsdp_axis: str = "data", tp_axis: str = "model") -> P:
    axis_map = {"data": fsdp_axis, "model": tp_axis}
    for pat, rule in RULES:
        if re.search(pat, path_str):
            nlead = len(shape) - len(rule)
            entries: list = [None] * nlead
            for dim, ax in zip(shape[nlead:], rule):
                if ax is None:
                    entries.append(None)
                    continue
                ax_name = axis_map[ax]
                if ax_name in mesh.shape and dim % mesh.shape[ax_name] == 0:
                    entries.append(ax_name)
                else:
                    entries.append(None)
            return P(*entries)
    return P()


def param_specs(params_shape, mesh: Mesh, fsdp_axis: str = "data",
                tp_axis: str = "model"):
    """PartitionSpec tree matching a params (or ShapeDtypeStruct) tree."""
    def one(path, leaf):
        return spec_for_path(_path_str(path), leaf.shape, mesh,
                             fsdp_axis, tp_axis)
    return jax.tree_util.tree_map_with_path(one, params_shape)


def param_shardings(params_shape, mesh: Mesh, **kw):
    specs = param_specs(params_shape, mesh, **kw)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_specs(mesh: Mesh, multi_pod: bool | None = None):
    """Batch dimension spec: data parallel over ('pod','data')."""
    if multi_pod is None:
        multi_pod = "pod" in mesh.shape
    return ("pod", "data") if multi_pod else ("data",)


def logical_rules(mesh: Mesh):  # documentation helper
    return {pat: rule for pat, rule in RULES}

from . import checkpoint, compression, data, optimizer, resilience, sharding
from .optimizer import AdamWConfig
from .train_step import init_state, make_serve_fns, make_train_step, \
    state_shardings

__all__ = ["checkpoint", "compression", "data", "optimizer", "resilience",
           "sharding", "AdamWConfig", "init_state", "make_serve_fns",
           "make_train_step", "state_shardings"]

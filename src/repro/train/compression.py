"""Error-feedback int8 gradient compression for the cross-pod all-reduce.

Distributed-optimization trick (DESIGN.md §6): on a multi-pod mesh the
gradient all-reduce over the 'pod' axis crosses the slow inter-pod links.
We quantize each gradient leaf to int8 with a per-leaf scale before the
psum and dequantize after; the quantization residual is fed back into the
next step's gradient (error feedback keeps the method unbiased over time —
1-bit Adam / EF-SGD lineage).

Used inside shard_map over the 'pod' axis; within a pod gradients reduce in
full precision as part of pjit's normal FSDP reduce-scatter.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["init_residuals", "compressed_psum_tree"]


def init_residuals(grads_shape):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_shape)


def _quantize(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum_tree(grads, residuals, axis_name: str):
    """psum(grads) over axis_name with int8 EF compression.

    Returns (mean_grads, new_residuals).  Call INSIDE shard_map/pjit with
    `axis_name` bound.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = _quantize(g32)
        deq = q.astype(jnp.float32) * scale
        new_r = g32 - deq
        summed = jax.lax.psum(deq, axis_name)
        return (summed / n).astype(g.dtype), new_r

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))

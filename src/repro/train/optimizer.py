"""Minimal production AdamW (no external deps): decoupled weight decay,
global-norm clipping, cosine schedule with linear warmup.  Optimizer state
is sharded like the parameters (ZeRO-3 style) by construction: m/v mirror
the param tree, so the same PartitionSpecs apply.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update", "cosine_lr"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def init_opt_state(params):
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(cfg: AdamWConfig, params, grads, opt_state):
    """Returns (new_params, new_opt_state, stats)."""
    step = opt_state["step"] + 1
    lr = cosine_lr(cfg, step)
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, stats

"""train_step / serve_step builders with pjit shardings.

make_train_step: loss -> grads -> AdamW, with optional microbatch gradient
accumulation (lax.scan over microbatches — compute/comm overlap comes from
XLA pipelining the per-microbatch FSDP all-gathers against the previous
microbatch's compute) and optional int8 error-feedback compression of the
cross-pod gradient reduction.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.api import get_model
from .optimizer import AdamWConfig, adamw_update, init_opt_state
from .sharding import batch_specs, param_shardings, param_specs

__all__ = ["make_train_step", "make_serve_fns", "TrainState", "init_state"]

TrainState = dict  # {"params": ..., "opt": ..., "residuals": optional}


def init_state(key, cfg, opt_cfg: AdamWConfig | None = None):
    model = get_model(cfg)
    params = model.init_params(key, cfg)
    return {"params": params, "opt": init_opt_state(params)}


def state_shardings(state_shape, mesh: Mesh):
    """NamedSharding tree for a TrainState shape tree."""
    def for_params(tree):
        return param_shardings(tree, mesh)
    out = {
        "params": for_params(state_shape["params"]),
        "opt": {
            "m": for_params(state_shape["opt"]["m"]),
            "v": for_params(state_shape["opt"]["v"]),
            "step": NamedSharding(mesh, P()),
        },
    }
    if "residuals" in state_shape:
        out["residuals"] = for_params(state_shape["residuals"])
    return out


def batch_shardings(batch_shape, mesh: Mesh):
    bspec = batch_specs(mesh)
    def one(leaf):
        entries = [bspec] + [None] * (len(leaf.shape) - 1)
        # guard divisibility of the batch dim
        import numpy as np
        sz = int(np.prod([mesh.shape[a] for a in bspec]))
        if leaf.shape[0] % sz != 0:
            entries[0] = None
        return NamedSharding(mesh, P(*entries))
    return jax.tree.map(one, batch_shape)


def make_train_step(cfg, opt_cfg: AdamWConfig, *, microbatch: int = 1,
                    compress_pod: bool = False) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    microbatch: number of gradient-accumulation slices (must divide the
    global batch).  compress_pod: int8 EF compression of the cross-pod
    gradient mean (requires state["residuals"]; multi-pod mesh).
    """
    model = get_model(cfg)
    loss_fn = functools.partial(model.loss_fn, cfg=cfg)

    def compute_grads(params, batch):
        if microbatch <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def slice_mb(i, leaf):
            mb = leaf.shape[0] // microbatch
            return jax.lax.dynamic_slice_in_dim(leaf, i * mb, mb, axis=0)

        def body(carry, i):
            loss_acc, g_acc = carry
            mb = jax.tree.map(functools.partial(slice_mb, i), batch)
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                 g_acc, g)
            return (loss_acc + l, g_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), g0),
                                        jnp.arange(microbatch))
        inv = 1.0 / microbatch
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def train_step(state, batch):
        loss, grads = compute_grads(state["params"], batch)
        new_residuals = None
        if compress_pod and "residuals" in state:
            from .compression import compressed_psum_tree
            # NOTE: pjit handles intra-pod reduction; the explicit pod psum
            # path is exercised via shard_map in train.py when enabled.
            grads, new_residuals = compressed_psum_tree(
                grads, state["residuals"], "pod")
        params, opt, stats = adamw_update(opt_cfg, state["params"], grads,
                                          state["opt"])
        out = {"params": params, "opt": opt}
        if new_residuals is not None:
            out["residuals"] = new_residuals
        elif "residuals" in state:
            out["residuals"] = state["residuals"]
        metrics = {"loss": loss, **stats}
        return out, metrics

    return train_step


def make_serve_fns(cfg):
    """(prefill_fn, decode_fn) for the arch family."""
    model = get_model(cfg)

    def prefill_fn(params, tokens, cache_len, **kw):
        return model.prefill(params, tokens, cfg, cache_len=cache_len, **kw)

    def decode_fn(params, token, cache, pos):
        return model.decode_step(params, token, cache, pos, cfg)

    return prefill_fn, decode_fn

"""Global mesh context for in-model sharding anchors.

GSPMD propagates shardings from inputs, but loop carries seeded from fresh
broadcasts (flash-attention accumulators, scan-carried hidden states) can
collapse to replicated — catastrophic at global-batch scale.  Models call
`constrain_batch` at block boundaries to anchor the batch dimension; the
launcher/dry-run sets the context before tracing.  No-op when unset (pure
single-device tests are unaffected).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["set_mesh_context", "clear_mesh_context", "constrain_batch",
           "constrain_tokens", "constrain_group_expert", "model_axis_size",
           "mesh_context"]


def model_axis_size(model_axis: str = "model") -> int:
    """Size of the model axis in the active context (1 when unset)."""
    if _CTX is None:
        return 1
    mesh, _ = _CTX
    return int(mesh.shape.get(model_axis, 1))

_CTX: tuple[Mesh, tuple[str, ...]] | None = None


def set_mesh_context(mesh: Mesh, batch_axes: tuple[str, ...] = ("data",)):
    global _CTX
    _CTX = (mesh, tuple(batch_axes))


def clear_mesh_context():
    global _CTX
    _CTX = None


class mesh_context:
    def __init__(self, mesh, batch_axes=("data",)):
        self.mesh, self.axes = mesh, batch_axes

    def __enter__(self):
        set_mesh_context(self.mesh, self.axes)
        return self

    def __exit__(self, *exc):
        clear_mesh_context()


def constrain_tokens(x, dim: int = 0, model_axis: str = "model"):
    """Shard a token-group dim over as many mesh axes as divisibility
    allows (data axes + model) — used by the MoE dispatch stage."""
    if _CTX is None or not hasattr(x, "ndim") or x.ndim == 0:
        return x
    mesh, baxes = _CTX
    cands = [tuple(baxes) + (model_axis,), tuple(baxes), (model_axis,)]
    for axes in cands:
        if not all(a in mesh.shape for a in axes):
            continue
        sz = int(np.prod([mesh.shape[a] for a in axes]))
        if x.shape[dim] % sz == 0 and x.shape[dim] >= sz:
            entries: list = [None] * x.ndim
            entries[dim] = axes if len(axes) > 1 else axes[0]
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*entries)))
    return x


def constrain_group_expert(x, g_dim: int = 0, e_dim: int = 1,
                           model_axis: str = "model"):
    """Shard (groups over data axes, experts over model) — the MoE expert-
    compute stage; the transition from constrain_tokens lowers to the
    canonical MoE all-to-all."""
    if _CTX is None or not hasattr(x, "ndim") or x.ndim == 0:
        return x
    mesh, baxes = _CTX
    entries: list = [None] * x.ndim
    bsz = int(np.prod([mesh.shape[a] for a in baxes]))
    if x.shape[g_dim] % bsz == 0 and x.shape[g_dim] >= bsz:
        entries[g_dim] = tuple(baxes) if len(baxes) > 1 else baxes[0]
    if (model_axis in mesh.shape
            and x.shape[e_dim] % mesh.shape[model_axis] == 0):
        entries[e_dim] = model_axis
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))


def constrain_batch(x, batch_dim: int = 0, model_dim: int | None = None,
                    model_axis: str = "model"):
    """Anchor x's batch dim to the data-parallel axes and (optionally) a
    tensor dim to the model axis — both only when divisible."""
    if _CTX is None or not hasattr(x, "ndim"):
        return x
    mesh, baxes = _CTX
    sz = int(np.prod([mesh.shape[a] for a in baxes]))
    if x.ndim == 0 or x.shape[batch_dim] % sz != 0 or x.shape[batch_dim] < sz:
        return x
    entries: list = [None] * x.ndim
    entries[batch_dim] = baxes if len(baxes) > 1 else baxes[0]
    if (model_dim is not None and model_axis in mesh.shape
            and x.shape[model_dim] % mesh.shape[model_axis] == 0):
        entries[model_dim] = model_axis
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))

"""Step-atomic checkpointing with manifest commit + async save.

Layout:
    <dir>/step_000123/shard_<host>.npz     flat param/opt arrays
    <dir>/step_000123/MANIFEST.json        committed LAST (atomic rename)

A checkpoint without MANIFEST.json is incomplete (crashed save) and is
ignored by restore/latest_step — this is the crash-consistency contract the
resilience layer relies on.  Saves can run on a background thread
(async_save) so the train loop is not blocked; the previous async save is
joined before a new one starts (bounded staleness of 1).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "Checkpointer"]


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten_into(tree_like, arrays: dict):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = arrays[key]
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape,
                                                       leaf.shape)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(ckpt_dir: str | Path, step: int, state, host_id: int = 0,
         extra: dict | None = None) -> Path:
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}_{host_id}"
    tmp.mkdir(parents=True, exist_ok=True)
    final.mkdir(parents=True, exist_ok=True)
    arrays = _flatten(state)
    np.savez(tmp / f"shard_{host_id}.npz", **arrays)
    os.replace(tmp / f"shard_{host_id}.npz", final / f"shard_{host_id}.npz")
    shutil.rmtree(tmp, ignore_errors=True)
    # manifest commit (host 0)
    if host_id == 0:
        manifest = {"step": step, "time": time.time(),
                    "keys": sorted(arrays.keys()), **(extra or {})}
        mtmp = final / ".MANIFEST.tmp"
        mtmp.write_text(json.dumps(manifest))
        os.replace(mtmp, final / "MANIFEST.json")
    return final


def latest_step(ckpt_dir: str | Path) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for d in ckpt_dir.iterdir():
        if d.name.startswith("step_") and (d / "MANIFEST.json").exists():
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, state_like, step: int | None = None,
            host_id: int = 0):
    """Restore into the structure of `state_like` (shapes must match)."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    assert (d / "MANIFEST.json").exists(), f"uncommitted checkpoint {d}"
    arrays = dict(np.load(d / f"shard_{host_id}.npz"))
    return _unflatten_into(state_like, arrays), step


class Checkpointer:
    """Async checkpoint manager with retention."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3,
                 host_id: int = 0):
        self.dir = Path(ckpt_dir)
        self.keep = keep
        self.host_id = host_id
        self._thread: threading.Thread | None = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, state, extra: dict | None = None):
        self.wait()
        host_state = jax.tree.map(np.asarray, state)  # device->host copy now

        def work():
            save(self.dir, step, host_state, self.host_id, extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(
            int(d.name.split("_")[1]) for d in self.dir.iterdir()
            if d.name.startswith("step_") and (d / "MANIFEST.json").exists())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

"""Fault tolerance: restart-from-checkpoint, elastic re-mesh, straggler
and NaN monitoring.

Designed for 1000+ node operation:
  * every step is covered by a committed checkpoint at most `interval` steps
    old (async, manifest-committed — see checkpoint.py);
  * on device/host loss the runner rebuilds the largest valid mesh from the
    surviving devices (`replan_mesh`) and reshards the restored state — the
    sharding rules are divisibility-aware so any (data, model) factorization
    lowers;
  * StepMonitor tracks a step-time EMA; a step slower than `straggler_factor`
    x EMA raises a straggler alarm (on real fleets: triggers pre-emptive
    re-scheduling; here: logged + counted, and hard timeouts abort);
  * non-finite loss triggers rollback to the last checkpoint with a skip
    marker (classic loss-spike recovery).
"""
from __future__ import annotations

import dataclasses
import math
import time

import jax
import numpy as np

__all__ = ["replan_mesh", "StepMonitor", "RunGuard"]


def replan_mesh(n_devices: int, *, model_axis_max: int = 16,
                prefer_model: int = 16, devices=None):
    """Largest (data, model) mesh from n_devices.

    Keeps the model axis as close to `prefer_model` as divisibility allows
    (TP degree is architecture-bound; data parallelism absorbs the loss of
    nodes).  Returns a jax Mesh over the first data*model devices.
    """
    model = min(prefer_model, model_axis_max)
    while model > 1 and n_devices % model != 0:
        model //= 2
    data = n_devices // model
    devs = (devices if devices is not None else jax.devices())[:data * model]
    arr = np.asarray(devs).reshape(data, model)
    from jax.sharding import Mesh
    return Mesh(arr, ("data", "model"))


@dataclasses.dataclass
class StepMonitor:
    """Step-time EMA + straggler detection + throughput accounting."""
    ema: float = 0.0
    alpha: float = 0.1
    straggler_factor: float = 3.0
    hard_timeout_s: float = 3600.0
    stragglers: int = 0
    steps: int = 0
    _t0: float = 0.0

    def start(self):
        self._t0 = time.monotonic()

    def finish(self) -> dict:
        dt = time.monotonic() - self._t0
        self.steps += 1
        alarm = False
        if self.ema > 0 and dt > self.straggler_factor * self.ema:
            self.stragglers += 1
            alarm = True
        if dt > self.hard_timeout_s:
            raise TimeoutError(f"step exceeded hard timeout ({dt:.1f}s)")
        self.ema = dt if self.ema == 0 else \
            (1 - self.alpha) * self.ema + self.alpha * dt
        return {"step_time_s": dt, "step_time_ema_s": self.ema,
                "straggler_alarm": alarm}


class RunGuard:
    """Wraps the train loop body: NaN rollback + checkpoint cadence."""

    def __init__(self, checkpointer, interval: int = 50,
                 max_rollbacks: int = 3):
        self.ckpt = checkpointer
        self.interval = interval
        self.rollbacks = 0
        self.max_rollbacks = max_rollbacks

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.interval == 0

    def check_loss(self, loss: float) -> bool:
        """True if the step is healthy; False => caller must roll back."""
        if math.isfinite(loss):
            return True
        self.rollbacks += 1
        if self.rollbacks > self.max_rollbacks:
            raise RuntimeError("too many NaN rollbacks — aborting run")
        return False

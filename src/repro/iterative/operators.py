"""Adapters turning repo objects into the callables Krylov drivers consume.

The drivers in `repro.iterative.krylov` accept any `(matvec,
preconditioner)` pair of JAX-traceable callables.  This module produces
those callables from the repo's native objects:

    as_matvec(A)          CSR -> jit-native scatter-add SpMV closure;
                          callables pass through.
    as_preconditioner(M)  None -> identity; Preconditioner -> its fully
                          device-native application (device_apply);
                          TriangularOperator -> its device_solve_fn;
                          objects with only a host .solve -> a
                          pure_callback wrapper; callables pass through.

Everything returned is traceable under jit/vmap and handles single `(n,)`
and batched `(n, k)` operands, matching the engine-registry contract for
batched right-hand sides.
"""
from __future__ import annotations

import numpy as np

from ..sparse.csr import CSR

__all__ = ["device_matvec", "as_matvec", "as_preconditioner",
           "solve_callback"]


def device_matvec(A: CSR, mesh=None, axis: str = "model"):
    """y = A @ x as a jit-native JAX closure (scatter-add SpMV).

    The CSR arrays ride into the trace as constants cast to x's dtype, so
    the same closure serves float32 and float64 (x64-enabled) programs and
    batched (n, k) operands.

    With `mesh`, the nonzeros are sharded over `axis` and each device
    scatter-adds its partial products into a full-length accumulator that
    one psum reduces — ONE collective per matvec, so a Krylov iteration
    under a mesh synchronizes at the matvec and at the preconditioner's
    per-step all_gathers only, with no host round-trips in between
    (docs/distributed.md).  x is replicated, matching the sharded
    triangular sweeps' replicated carry contract.
    """
    import jax.numpy as jnp
    rows_np = np.repeat(np.arange(A.n_rows), A.row_nnz())
    cols_np = np.asarray(A.indices)
    data_np = np.asarray(A.data)
    n_rows = A.n_rows

    if mesh is None:
        def matvec(x):
            data = jnp.asarray(data_np, dtype=x.dtype)
            gathered = x[cols_np]
            prod = (data * gathered if x.ndim == 1
                    else data[:, None] * gathered)
            out = jnp.zeros((n_rows,) + x.shape[1:], dtype=x.dtype)
            return out.at[rows_np].add(prod)

        return matvec

    import jax
    from jax.sharding import PartitionSpec as P
    from ..solver.distributed import require_axis, shard_map_compat
    require_axis(mesh, axis)
    nshards = mesh.shape[axis]
    # pad the nnz triplet to a multiple of the axis size with inert
    # entries: row n_rows is a garbage accumulator slot dropped at the end
    nnz_pad = -(-max(rows_np.size, 1) // nshards) * nshards
    pad = nnz_pad - rows_np.size
    rows_sh = np.concatenate([rows_np, np.full(pad, n_rows, rows_np.dtype)])
    cols_sh = np.concatenate([cols_np, np.zeros(pad, cols_np.dtype)])
    data_sh = np.concatenate([data_np, np.zeros(pad, data_np.dtype)])

    def body(rows, cols, data, x):
        gathered = x[cols]
        prod = data * gathered if x.ndim == 1 else data[:, None] * gathered
        out = jnp.zeros((n_rows + 1,) + x.shape[1:], dtype=x.dtype)
        out = out.at[rows].add(prod)
        return jax.lax.psum(out, axis)

    shmapped = shard_map_compat(body, mesh,
                                (P(axis), P(axis), P(axis), P()), P())
    # the index triplet lives on device once (it is dtype-independent);
    # the coefficient array is staged once per RHS dtype — repeat eager
    # matvecs then transfer nothing
    rows_dev, cols_dev = jnp.asarray(rows_sh), jnp.asarray(cols_sh)
    data_by_dtype: dict = {}

    def matvec(x):
        data = data_by_dtype.get(x.dtype)
        if data is None:
            with jax.ensure_compile_time_eval():    # never cache tracers
                data = jnp.asarray(data_sh, dtype=x.dtype)
            data_by_dtype[x.dtype] = data
        return shmapped(rows_dev, cols_dev, data, x)[:n_rows]

    return matvec


def as_matvec(spec, mesh=None, axis: str = "model"):
    """CSR -> device_matvec(spec, mesh, axis); callables pass through."""
    if isinstance(spec, CSR):
        return device_matvec(spec, mesh=mesh, axis=axis)
    if callable(spec):
        return spec
    raise TypeError(f"matvec must be a CSR matrix or a callable, got "
                    f"{type(spec).__name__}")


def solve_callback(solve_fn):
    """Lift a host solve (e.g. TriangularOperator.solve) into a JAX-
    traceable callable via pure_callback: output shape/dtype == input's."""
    import jax

    def apply(r):
        out = jax.ShapeDtypeStruct(r.shape, r.dtype)

        def cb(rr):
            return np.asarray(solve_fn(np.asarray(rr, dtype=np.float64)),
                              dtype=out.dtype)

        return jax.pure_callback(cb, out, r, vmap_method="sequential")

    return apply


def as_preconditioner(spec):
    """Resolve a preconditioner spec to a traceable callable (module doc).

    Order matters: the device-native paths (`.device_apply` on a
    Preconditioner, `.device_solve_fn` on a TriangularOperator) beat
    plain callability, so those objects run as pure device computations
    with no host callback in the Krylov hot loop; a host-only `.solve`
    falls back to a pure_callback wrapper (note: under a scoped
    enable_x64() XLA may execute callbacks on worker threads that do not
    see the scope — prefer the device-native objects inside jit).
    """
    if spec is None:
        return lambda r: r
    if hasattr(spec, "device_apply"):
        return spec.device_apply()
    if hasattr(spec, "device_solve_fn"):
        return spec.device_solve_fn()
    if hasattr(spec, "jax_apply"):
        return spec.jax_apply
    if isinstance(spec, CSR):
        raise TypeError(
            "a raw CSR matrix is ambiguous as a preconditioner (M or "
            "M^-1?); pass repro.precond.Preconditioner.ic0/ilu0(A) or an "
            "explicit callable applying M^-1")
    if callable(spec):
        return spec
    if hasattr(spec, "solve"):
        return solve_callback(spec.solve)
    raise TypeError(f"cannot interpret {type(spec).__name__} as a "
                    f"preconditioner: expected None, a callable, a "
                    f"Preconditioner, or an object with .solve/.jax_apply")

"""Adapters turning repo objects into the callables Krylov drivers consume.

The drivers in `repro.iterative.krylov` accept any `(matvec,
preconditioner)` pair of JAX-traceable callables.  This module produces
those callables from the repo's native objects:

    as_matvec(A)          CSR -> jit-native scatter-add SpMV closure;
                          callables pass through.
    as_preconditioner(M)  None -> identity; Preconditioner -> its fully
                          device-native application (device_apply);
                          TriangularOperator -> its device_solve_fn;
                          objects with only a host .solve -> a
                          pure_callback wrapper; callables pass through.

Everything returned is traceable under jit/vmap and handles single `(n,)`
and batched `(n, k)` operands, matching the engine-registry contract for
batched right-hand sides.
"""
from __future__ import annotations

import numpy as np

from ..sparse.csr import CSR

__all__ = ["device_matvec", "as_matvec", "as_preconditioner",
           "solve_callback"]


def device_matvec(A: CSR):
    """y = A @ x as a jit-native JAX closure (scatter-add SpMV).

    The CSR arrays ride into the trace as constants cast to x's dtype, so
    the same closure serves float32 and float64 (x64-enabled) programs and
    batched (n, k) operands.
    """
    import jax.numpy as jnp
    rows_np = np.repeat(np.arange(A.n_rows), A.row_nnz())
    cols_np = np.asarray(A.indices)
    data_np = np.asarray(A.data)
    n_rows = A.n_rows

    def matvec(x):
        data = jnp.asarray(data_np, dtype=x.dtype)
        gathered = x[cols_np]
        prod = data * gathered if x.ndim == 1 else data[:, None] * gathered
        out = jnp.zeros((n_rows,) + x.shape[1:], dtype=x.dtype)
        return out.at[rows_np].add(prod)

    return matvec


def as_matvec(spec):
    """CSR -> device_matvec(spec); callables pass through."""
    if isinstance(spec, CSR):
        return device_matvec(spec)
    if callable(spec):
        return spec
    raise TypeError(f"matvec must be a CSR matrix or a callable, got "
                    f"{type(spec).__name__}")


def solve_callback(solve_fn):
    """Lift a host solve (e.g. TriangularOperator.solve) into a JAX-
    traceable callable via pure_callback: output shape/dtype == input's."""
    import jax

    def apply(r):
        out = jax.ShapeDtypeStruct(r.shape, r.dtype)

        def cb(rr):
            return np.asarray(solve_fn(np.asarray(rr, dtype=np.float64)),
                              dtype=out.dtype)

        return jax.pure_callback(cb, out, r, vmap_method="sequential")

    return apply


def as_preconditioner(spec):
    """Resolve a preconditioner spec to a traceable callable (module doc).

    Order matters: the device-native paths (`.device_apply` on a
    Preconditioner, `.device_solve_fn` on a TriangularOperator) beat
    plain callability, so those objects run as pure device computations
    with no host callback in the Krylov hot loop; a host-only `.solve`
    falls back to a pure_callback wrapper (note: under a scoped
    enable_x64() XLA may execute callbacks on worker threads that do not
    see the scope — prefer the device-native objects inside jit).
    """
    if spec is None:
        return lambda r: r
    if hasattr(spec, "device_apply"):
        return spec.device_apply()
    if hasattr(spec, "device_solve_fn"):
        return spec.device_solve_fn()
    if hasattr(spec, "jax_apply"):
        return spec.jax_apply
    if isinstance(spec, CSR):
        raise TypeError(
            "a raw CSR matrix is ambiguous as a preconditioner (M or "
            "M^-1?); pass repro.precond.Preconditioner.ic0/ilu0(A) or an "
            "explicit callable applying M^-1")
    if callable(spec):
        return spec
    if hasattr(spec, "solve"):
        return solve_callback(spec.solve)
    raise TypeError(f"cannot interpret {type(spec).__name__} as a "
                    f"preconditioner: expected None, a callable, a "
                    f"Preconditioner, or an object with .solve/.jax_apply")

"""Iterative-solver subsystem: jit-native Krylov drivers.

The consumer side of the preconditioning pipeline — `cg`, `bicgstab`, and
restarted `gmres` over any `(matvec, preconditioner)` pair, with the
paper's transformed SpTRSV serving as the preconditioner kernel:

    from repro.iterative import cg
    from repro.precond import Preconditioner

    P = Preconditioner.ic0(A, tune="auto")
    res = cg(A, b, preconditioner=P, tol=1e-8)       # b: (n,) or (n, k)

All drivers are pure JAX programs (jit/vmap-composable, early exit via
lax.while_loop) returning a `SolveResult` pytree with per-iteration
residual history.  See docs/iterative.md for the factor -> tune -> solve
walkthrough and convergence knobs.
"""
from .krylov import SolveResult, bicgstab, cg, gmres
from .operators import (as_matvec, as_preconditioner, device_matvec,
                        solve_callback)

__all__ = [
    "SolveResult", "cg", "bicgstab", "gmres",
    "as_matvec", "as_preconditioner", "device_matvec", "solve_callback",
]

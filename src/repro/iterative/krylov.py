"""jit-native Krylov drivers: cg, bicgstab, restarted gmres.

The consumer side of the preconditioning subsystem — iterative solvers
whose inner kernel is the paper's transformed SpTRSV (via
`repro.precond.Preconditioner`), written entirely in JAX:

    A = generators.poisson2d_spd(64, 64)
    P = Preconditioner.ic0(A, tune="auto")
    res = cg(A, b, preconditioner=P, tol=1e-8)
    res.x, res.iterations, res.residual_norms

Driver contract
===============
* `matvec` is a CSR matrix (compiled to a jit-native scatter-add SpMV) or
  any traceable callable; `preconditioner` is None, a `Preconditioner`,
  a `TriangularOperator`, or a traceable callable applying M^-1 (see
  `repro.iterative.operators` for the adapter rules).
* Right-hand sides are single `(n,)` or batched `(n, k)`; batched columns
  converge independently (per-column masking), matching the engine
  registry's batched-RHS contract so one schedule streams all k columns.
* Every driver is a pure JAX program built on `lax.while_loop` — it
  composes with `jax.jit`, stops early when all columns converge, and
  returns a `SolveResult` pytree.  Run under `jax.experimental.
  enable_x64()` for float64 iterations (the repo default elsewhere:
  float32 device math + float64 host refinement).
* Convergence: ||r||_2 <= max(tol * ||b||_2, atol) per column, residuals
  in the driver's working dtype (= b's dtype).  `gmres` iterates on the
  left-preconditioned system, so its tolerance and recorded history are
  PRECONDITIONED residual norms (cg/bicgstab record true residuals).

`SolveResult.residual_norms` carries the per-iteration history in a
fixed-shape `(maxiter+1,) + batch` buffer (NaN beyond each column's last
iteration — `jnp.nanmin` and friends compose); `iterations` counts the
iterations each column actually ran.  `SolveResult.status` classifies each
column's outcome — STATUS_CONVERGED, STATUS_MAXITER, or STATUS_BREAKDOWN
(`status_labels` decodes) — and every driver detects a non-finite iterate
INSIDE its `lax.while_loop`: a poisoned column (NaN/Inf from an unstable
preconditioner, a singular operator, or a bad right-hand side) is frozen
at its last healthy iterate and reported as a breakdown instead of
silently returning a garbage x with converged=False (host-side health
guards cannot see inside jit, so the drivers carry their own detection).  When the preconditioner is a
`Preconditioner` object and the call runs outside jit, `stats` carries
its metadata (factorization kind/shift/strategy + host-path operator
counters; traced in-loop applications are not host-observable) — inside
jit it is None.

docs/iterative.md walks the full factor -> tune -> solve pipeline,
convergence knobs included.
"""
from __future__ import annotations

import math
import typing

import numpy as np

from .operators import as_matvec, as_preconditioner

__all__ = ["SolveResult", "cg", "bicgstab", "gmres",
           "STATUS_MAXITER", "STATUS_CONVERGED", "STATUS_BREAKDOWN",
           "STATUS_LABELS", "status_labels"]

# per-column outcome codes carried in SolveResult.status (int32, jit-safe)
STATUS_MAXITER = 0      # ran out of iterations without converging
STATUS_CONVERGED = 1    # hit the residual target
STATUS_BREAKDOWN = 2    # frozen at the last healthy iterate (non-finite
#                         step, or a bicgstab rho/omega collapse)
STATUS_LABELS = ("maxiter", "converged", "breakdown")


def status_labels(status):
    """Host-side decoder: a SolveResult.status array -> label strings."""
    return np.asarray(STATUS_LABELS, dtype=object)[np.asarray(status)]


class SolveResult(typing.NamedTuple):
    """Outcome of a Krylov solve (a JAX pytree; jit-transparent).

    x:              solution, same shape as b.
    converged:      bool per column (batch shape).
    iterations:     int32 per column — iterations actually run.
    residual_norms: (maxiter+1,) + batch, residual 2-norms per iteration
                    (index 0 = initial residual), NaN-padded past each
                    column's final iteration.
    status:         int32 per column — STATUS_CONVERGED, STATUS_MAXITER,
                    or STATUS_BREAKDOWN (`status_labels` decodes).
                    Breakdown columns are frozen at their last healthy
                    iterate: `x` is finite and usable, just not converged.
                    None when constructed without one (back-compat).
    stats:          preconditioner metadata dict (factorization kind,
                    shift, strategy, per-operator counters) when the call
                    ran outside jit with a Preconditioner object, else
                    None.  NOTE: in-loop M^-1 applications run through
                    the traced device pipeline, which host-side counters
                    cannot observe — the solve/walltime counters only
                    reflect explicit host `P.apply()` calls.
    """

    x: typing.Any
    converged: typing.Any
    iterations: typing.Any
    residual_norms: typing.Any
    status: typing.Any = None
    stats: typing.Any = None

    def final_residual(self):
        """Last recorded residual norm per column (NaN-aware)."""
        import jax.numpy as jnp
        hist = self.residual_norms
        idx = jnp.asarray(self.iterations, dtype=jnp.int32)
        return jnp.take_along_axis(hist, idx[None, ...], axis=0)[0]


def _vdot(u, v):
    return (u * v).sum(axis=0)


def _norm(v):
    import jax.numpy as jnp
    return jnp.sqrt(_vdot(v, v))


def _guard(d):
    """Replace ~zero denominators by 1 (the quotient is masked anyway)."""
    import jax.numpy as jnp
    return jnp.where(d == 0, jnp.ones_like(d), d)


def _prepare(matvec, preconditioner, b, x0, tol, atol):
    """Shared setup: resolve operators, initial x/r, convergence target."""
    import jax.numpy as jnp
    A = as_matvec(matvec)
    M = as_preconditioner(preconditioner)
    b = jnp.asarray(b)
    if b.ndim not in (1, 2):
        raise ValueError(f"b must be (n,) or (n, k), got shape {b.shape}")
    if x0 is None:
        x = jnp.zeros_like(b)
        r = b
    else:
        x = jnp.asarray(x0, dtype=b.dtype)
        r = b - A(x)
    target = jnp.maximum(tol * _norm(b), atol).astype(b.dtype)
    return A, M, b, x, r, target


def _attach_stats(result: SolveResult, preconditioner) -> SolveResult:
    """Host-path convenience: merge Preconditioner operator stats into the
    result.  Inside jit `x` is a tracer and stats stay None (trace-time
    host counters would be stale constants)."""
    import jax
    if isinstance(result.x, jax.core.Tracer):
        return result
    stats_fn = getattr(preconditioner, "stats", None)
    if callable(stats_fn):
        return result._replace(stats=stats_fn())
    return result


# per-driver residual events are capped: a 10k-iteration solve must not
# flood the trace, so the history is thinned to evenly spaced samples
_TRACE_EVENT_CAP = 64


def _trace_iterations(result: SolveResult, driver: str) -> None:
    """Host-path per-iteration `krylov.residual` events from the recorded
    history (first column when batched).  Inside jit x is a tracer and the
    history is unreadable — nothing is emitted, same guard as
    `_attach_stats`."""
    from ..obs import trace as _obs
    if not _obs.enabled():
        return
    import jax
    if isinstance(result.x, jax.core.Tracer):
        return
    hist = np.asarray(result.residual_norms, dtype=float)
    col = hist if hist.ndim == 1 else hist[:, 0]
    last = int(np.max(np.asarray(result.iterations)))
    idx = np.arange(min(last + 1, col.shape[0]))
    if idx.size > _TRACE_EVENT_CAP:
        idx = np.unique(np.linspace(0, idx[-1],
                                    _TRACE_EVENT_CAP).astype(int))
    for i in idx:
        if np.isfinite(col[i]):
            _obs.event("krylov.residual", driver=driver, iteration=int(i),
                       residual=float(col[i]))


def _finish(result: SolveResult, preconditioner, driver: str) -> SolveResult:
    _trace_iterations(result, driver)
    return _attach_stats(result, preconditioner)


def cg(matvec, b, *, preconditioner=None, x0=None, tol: float = 1e-8,
       atol: float = 0.0, maxiter: int | None = None) -> SolveResult:
    """Preconditioned conjugate gradient for SPD systems.

    matvec/preconditioner: see module doc (M^-1 must be SPD — ic0 is).
    maxiter: history length and iteration cap; defaults to n.
    """
    import jax
    import jax.numpy as jnp
    A, M, b, x, r, target = _prepare(matvec, preconditioner, b, x0, tol,
                                     atol)
    n = b.shape[0]
    maxiter = n if maxiter is None else int(maxiter)
    batch = b.shape[1:]
    hist = jnp.full((maxiter + 1,) + batch, jnp.nan, dtype=b.dtype)
    rn0 = _norm(r)
    hist = hist.at[0].set(rn0)
    z = M(r)
    p = z
    rz = _vdot(r, z)
    done0 = rn0 <= target
    brk0 = jnp.zeros(batch, dtype=bool)
    iters0 = jnp.zeros(batch, dtype=jnp.int32)

    def cond(state):
        it, _, _, _, _, _, done, brk, _ = state
        return (it < maxiter) & ~(done | brk).all()

    def body(state):
        it, x, r, p, rz, hist, done, brk, iters = state
        stop = done | brk
        Ap = A(p)
        alpha = jnp.where(stop, 0.0, rz / _guard(_vdot(p, Ap))) \
            .astype(b.dtype)
        x_new = x + alpha * p
        r_new = r - alpha * Ap
        rn = _norm(r_new)
        z = M(r_new)
        rz_new = _vdot(r_new, z)
        # a non-finite residual or curvature means this step poisoned the
        # column (singular A, unstable M, overflow): freeze it at the last
        # healthy iterate and report breakdown, never return garbage
        bad = ~stop & ~(jnp.isfinite(rn) & jnp.isfinite(rz_new))
        ok = ~stop & ~bad
        x = jnp.where(ok, x_new, x)
        r = jnp.where(ok, r_new, r)
        hist = hist.at[it + 1].set(jnp.where(ok, rn, jnp.nan))
        iters = iters + jnp.where(ok, 1, 0).astype(jnp.int32)
        beta = (rz_new / _guard(rz)).astype(b.dtype)
        p = jnp.where(ok, z + beta * p, p)
        rz = jnp.where(ok, rz_new, rz)
        done = done | (ok & (rn <= target))
        brk = brk | bad
        return it + 1, x, r, p, rz, hist, done, brk, iters

    _, x, r, _, _, hist, done, brk, iters = jax.lax.while_loop(
        cond, body, (jnp.int32(0), x, r, p, rz, hist, done0, brk0, iters0))
    status = jnp.where(done, STATUS_CONVERGED,
                       jnp.where(brk, STATUS_BREAKDOWN,
                                 STATUS_MAXITER)).astype(jnp.int32)
    return _finish(
        SolveResult(x=x, converged=done, iterations=iters,
                    residual_norms=hist, status=status), preconditioner,
        "cg")


def bicgstab(matvec, b, *, preconditioner=None, x0=None, tol: float = 1e-8,
             atol: float = 0.0, maxiter: int | None = None) -> SolveResult:
    """Preconditioned BiCGStab for general (nonsymmetric) systems.

    Right-preconditioned van der Vorst form: two matvecs and two M^-1
    applications per iteration; the recorded history is the TRUE residual
    norm.  Breakdown (rho or omega collapsing) freezes the affected
    column with converged=False.
    """
    import jax
    import jax.numpy as jnp
    A, M, b, x, r, target = _prepare(matvec, preconditioner, b, x0, tol,
                                     atol)
    n = b.shape[0]
    maxiter = n if maxiter is None else int(maxiter)
    batch = b.shape[1:]
    hist = jnp.full((maxiter + 1,) + batch, jnp.nan, dtype=b.dtype)
    rn0 = _norm(r)
    hist = hist.at[0].set(rn0)
    rhat = r
    rho = jnp.ones(batch, dtype=b.dtype)
    alpha = jnp.ones(batch, dtype=b.dtype)
    omega = jnp.ones(batch, dtype=b.dtype)
    v = jnp.zeros_like(b)
    p = jnp.zeros_like(b)
    done0 = rn0 <= target
    brk0 = jnp.zeros(batch, dtype=bool)
    iters0 = jnp.zeros(batch, dtype=jnp.int32)
    eps = jnp.asarray(np.finfo(np.dtype(b.dtype)).tiny * 1e3, b.dtype)

    def cond(state):
        it = state[0]
        done, brk = state[-3], state[-2]
        return (it < maxiter) & ~(done | brk).all()

    def body(state):
        (it, x, r, rhat, rho, alpha, omega, v, p, hist, done, brk,
         iters) = state
        stop = done | brk
        rho_new = _vdot(rhat, r)
        broke = jnp.abs(rho_new) < eps
        beta = ((rho_new / _guard(rho)) * (alpha / _guard(omega))) \
            .astype(b.dtype)
        p = jnp.where(stop, p, r + beta * (p - omega * v))
        phat = M(p)
        v_new = A(phat)
        denom = _vdot(rhat, v_new)
        broke = broke | (jnp.abs(denom) < eps)
        alpha_new = jnp.where(stop | broke, 0.0,
                              rho_new / _guard(denom)).astype(b.dtype)
        s = r - alpha_new * v_new
        shat = M(s)
        t = A(shat)
        tt = _vdot(t, t)
        omega_new = jnp.where(stop | broke, 0.0,
                              _vdot(t, s) / _guard(tt)).astype(b.dtype)
        x_cand = x + alpha_new * phat + omega_new * shat
        r_cand = s - omega_new * t
        rn = _norm(r_cand)
        # a non-finite candidate (unstable M, singular A, overflow) is a
        # breakdown like rho/omega collapse: freeze the column at its last
        # healthy iterate, never commit a poisoned x
        broke = broke | ~jnp.isfinite(rn)
        upd = ~(stop | broke)
        x = jnp.where(upd, x_cand, x)
        r = jnp.where(upd, r_cand, r)
        # a breakdown step is NOT a productive iteration: x/r are frozen,
        # so record nothing and leave the count at the last real step
        hist = hist.at[it + 1].set(jnp.where(upd, rn, jnp.nan))
        iters = iters + jnp.where(upd, 1, 0).astype(jnp.int32)
        v = jnp.where(upd, v_new, v)
        rho = jnp.where(upd, rho_new, rho)
        alpha = jnp.where(upd, alpha_new, alpha)
        omega = jnp.where(upd, omega_new, omega)
        done = done | (upd & (rn <= target))
        brk = brk | (~stop & broke)
        return (it + 1, x, r, rhat, rho, alpha, omega, v, p, hist, done,
                brk, iters)

    state = (jnp.int32(0), x, r, rhat, rho, alpha, omega, v, p, hist,
             done0, brk0, iters0)
    state = jax.lax.while_loop(cond, body, state)
    _, x, r, *_rest = state
    hist, done, brk, iters = state[-4], state[-3], state[-2], state[-1]
    status = jnp.where(done, STATUS_CONVERGED,
                       jnp.where(brk, STATUS_BREAKDOWN,
                                 STATUS_MAXITER)).astype(jnp.int32)
    return _finish(
        SolveResult(x=x, converged=done, iterations=iters,
                    residual_norms=hist, status=status), preconditioner,
        "bicgstab")


def gmres(matvec, b, *, preconditioner=None, x0=None, tol: float = 1e-8,
          atol: float = 0.0, restart: int = 30,
          maxiter: int | None = None) -> SolveResult:
    """Restarted GMRES(m) for general systems, left-preconditioned.

    Arnoldi with twice-iterated classical Gram-Schmidt (CGS2 — fully
    vectorized over batched columns) and Givens-rotation least squares;
    `restart` is the Krylov dimension m, `maxiter` the number of restart
    cycles (default: enough cycles to cover n total iterations).

    Iterates on M^-1 A x = M^-1 b: tolerance and recorded history are
    PRECONDITIONED residual norms (|g_{j+1}| estimates inside a cycle, the
    recomputed true value of M^-1(b - Ax) at cycle boundaries).  History
    entries are written at per-column positions, so `iterations` counts
    each column's productive inner iterations and `hist[iterations]` is
    its last recorded estimate even when a column pauses mid-cycle.
    """
    import jax
    import jax.numpy as jnp
    # _prepare's target tracks the UNpreconditioned rhs; gmres replaces it
    # below with the preconditioned one (left-preconditioned iteration)
    A, M, b, x, _r0, _ = _prepare(matvec, preconditioner, b, x0, tol, atol)
    n = b.shape[0]
    m = max(1, min(int(restart), n))
    maxiter = max(1, math.ceil(n / m)) if maxiter is None else int(maxiter)
    batch = b.shape[1:]
    bmask = (slice(None),) + (None,) * len(batch)   # lift (m+1,) over batch
    mb = M(b)
    target = jnp.maximum(tol * _norm(mb), atol).astype(b.dtype)
    hist = jnp.full((maxiter * m + 1,) + batch, jnp.nan, dtype=b.dtype)
    r = M(b - A(x)) if x0 is not None else mb
    rn0 = _norm(r)
    hist = hist.at[0].set(rn0)
    done0 = rn0 <= target
    iters0 = jnp.zeros(batch, dtype=jnp.int32)
    basis_idx = jnp.arange(m + 1)

    # per-COLUMN history positions (iters + 1), not the absolute cycle
    # index: a column whose |g| estimate converges mid-cycle but whose
    # cycle-end recompute disagrees resumes writing right after its last
    # entry, so `iterations` stays the productive count and
    # hist[iterations] is always the last recorded estimate, gap-free
    if batch:
        col_idx = jnp.arange(batch[0])

        def hist_write(h, pos, val):
            return h.at[pos, col_idx].set(val)
    else:
        def hist_write(h, pos, val):
            return h.at[pos].set(val)

    def inner_body(j, carry):
        V, H, cs, sn, g, hist, inner_done, iters, cycle = carry
        w = M(A(V[j]))
        # CGS2: two passes of classical Gram-Schmidt against V[0..j],
        # vectorized over the basis axis with an i<=j mask
        mask = (basis_idx <= j)[bmask]
        h1 = jnp.where(mask, (V * w[None]).sum(axis=1), 0.0)
        w = w - (h1[:, None] * V).sum(axis=0)
        h2 = jnp.where(mask, (V * w[None]).sum(axis=1), 0.0)
        w = w - (h2[:, None] * V).sum(axis=0)
        hcol = (h1 + h2).astype(b.dtype)
        hnext = _norm(w)
        V = V.at[j + 1].set(jnp.where(inner_done, V[j + 1],
                                      w / _guard(hnext)))

        # apply the stored Givens rotations 0..j-1 to the new column
        def rot_body(i, hc):
            hi, hi1 = hc[i], hc[i + 1]
            new_hi = cs[i] * hi + sn[i] * hi1
            new_hi1 = -sn[i] * hi + cs[i] * hi1
            use = i < j
            hc = hc.at[i].set(jnp.where(use, new_hi, hi))
            return hc.at[i + 1].set(jnp.where(use, new_hi1, hi1))

        hcol = jax.lax.fori_loop(0, m, rot_body, hcol)
        # new rotation zeroing the subdiagonal h_{j+1,j}
        hj = hcol[j]
        d = jnp.sqrt(hj ** 2 + hnext ** 2)
        cs_j = jnp.where(d == 0, 1.0, hj / _guard(d)).astype(b.dtype)
        sn_j = jnp.where(d == 0, 0.0, hnext / _guard(d)).astype(b.dtype)
        hcol = hcol.at[j].set(d.astype(b.dtype)).at[j + 1].set(
            jnp.zeros_like(d, dtype=b.dtype))
        H = H.at[:, j].set(jnp.where(inner_done, H[:, j], hcol))
        cs = cs.at[j].set(jnp.where(inner_done, cs[j], cs_j))
        sn = sn.at[j].set(jnp.where(inner_done, sn[j], sn_j))
        g_j, g_next = g[j], -sn_j * g[j]
        g = g.at[j].set(jnp.where(inner_done, g_j, cs_j * g_j))
        g = g.at[j + 1].set(jnp.where(inner_done, g[j + 1], g_next))
        res_est = jnp.abs(g[j + 1])
        pos = jnp.minimum(iters + 1, maxiter * m)
        hist = hist_write(hist, pos, jnp.where(inner_done, jnp.nan,
                                               res_est))
        iters = iters + jnp.where(inner_done, 0, 1).astype(jnp.int32)
        inner_done = inner_done | (res_est <= target) | (hnext == 0)
        return V, H, cs, sn, g, hist, inner_done, iters, cycle

    def outer_cond(state):
        cycle = state[0]
        done, brk = state[-2], state[-1]
        return (cycle < maxiter) & ~(done | brk).all()

    def outer_body(state):
        cycle, x, r, rn, hist, iters, done, brk = state
        iters_in = iters        # rollback point for a poisoned cycle
        beta = rn
        V = jnp.zeros((m + 1, n) + batch, dtype=b.dtype)
        V = V.at[0].set(r / _guard(beta))
        H = jnp.zeros((m + 1, m) + batch, dtype=b.dtype)
        cs = jnp.zeros((m + 1,) + batch, dtype=b.dtype)
        sn = jnp.zeros((m + 1,) + batch, dtype=b.dtype)
        g = jnp.zeros((m + 1,) + batch, dtype=b.dtype).at[0].set(beta)
        carry = (V, H, cs, sn, g, hist, done | brk, iters, cycle)
        V, H, cs, sn, g, hist, _, iters, _ = jax.lax.fori_loop(
            0, m, inner_body, carry)
        # back-substitute H y = g on the m x m triangle; columns the cycle
        # never reached have H[i,i] == 0 and g[i] == 0 -> y_i = 0
        y = jnp.zeros((m,) + batch, dtype=b.dtype)

        def back_body(l, y):
            i = m - 1 - l
            s = (H[i] * y).sum(axis=0)      # y[l] == 0 for l <= i still
            yi = (g[i] - s) / _guard(H[i, i])
            return y.at[i].set(jnp.where(jnp.abs(H[i, i]) > 0, yi, 0.0))

        y = jax.lax.fori_loop(0, m, back_body, y)
        x_new = x + (y[:, None] * V[:m]).sum(axis=0)
        r_new = M(b - A(x_new))
        rn_new = _norm(r_new)
        # a non-finite recomputed residual means the cycle poisoned the
        # column (unstable M, singular A, NaN rhs): roll x and the
        # iteration count back to the cycle start and report breakdown
        active = ~(done | brk)
        bad = active & ~jnp.isfinite(rn_new)
        ok = active & ~bad
        x = jnp.where(ok, x_new, x)
        r = jnp.where(ok, r_new, r)
        rn = jnp.where(ok, rn_new, rn)
        iters = jnp.where(bad, iters_in, iters)
        done = done | (ok & (rn_new <= target))
        brk = brk | bad
        return cycle + 1, x, r, rn, hist, iters, done, brk

    brk0 = jnp.zeros(batch, dtype=bool)
    state = (jnp.int32(0), x, r, rn0, hist, iters0, done0, brk0)
    _, x, r, rn, hist, iters, done, brk = jax.lax.while_loop(
        outer_cond, outer_body, state)
    status = jnp.where(done, STATUS_CONVERGED,
                       jnp.where(brk, STATUS_BREAKDOWN,
                                 STATUS_MAXITER)).astype(jnp.int32)
    return _finish(
        SolveResult(x=x, converged=done, iterations=iters,
                    residual_norms=hist, status=status), preconditioner,
        "gmres")

"""Level-scheduled SpTRSV execution engines in JAX.

Engines (all consume a LevelSchedule):
  * solve_scan      — lax.scan over steps; HLO size O(1) in step count.
  * solve_unrolled  — python loop over steps at trace time; exposes each
                      level to XLA (bigger HLO, more fusion freedom).  Only
                      sensible AFTER the transformation shrank the level
                      count — which is precisely the paper's point.
  * multi-RHS via vmap (b may be (n,) or (n, R)).

The preamble c = B'b (transformed systems) is applied outside: either a
materialized-B' SpMV or a second schedule built on the T factor.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .schedule import LevelSchedule

__all__ = ["DeviceSchedule", "to_device", "solve_scan", "solve_unrolled",
           "solve"]


class DeviceSchedule:
    """LevelSchedule staged as jnp arrays (a pytree of leaves)."""

    def __init__(self, sched: LevelSchedule):
        self.row_ids = jnp.asarray(sched.row_ids)
        self.dep_idx = jnp.asarray(sched.dep_idx)
        self.dep_coef = jnp.asarray(sched.dep_coef)
        self.dinv = jnp.asarray(sched.dinv)
        self.carry_in = jnp.asarray(sched.carry_in)
        self.carry_out = jnp.asarray(sched.carry_out)
        self.c_ids = jnp.asarray(sched.c_ids)
        self.is_final = jnp.asarray(sched.is_final)
        self.n = sched.n
        self.n_carry = sched.n_carry
        self.num_steps = sched.num_steps
        self.dtype = sched.dep_coef.dtype

    def leaves(self):
        return (self.row_ids, self.dep_idx, self.dep_coef, self.dinv,
                self.carry_in, self.carry_out, self.c_ids, self.is_final)


def to_device(sched: LevelSchedule) -> DeviceSchedule:
    return DeviceSchedule(sched)


def _step_body(x, carry, c_pad, leaves_s):
    (row_ids, dep_idx, dep_coef, dinv, carry_in, carry_out, c_ids,
     is_final) = leaves_s
    gathered = x[dep_idx]                      # (C, D) or (C, D, R)
    if gathered.ndim == 3:
        partial = jnp.einsum("cd,cdr->cr", dep_coef, gathered)
        tot = partial + carry[carry_in]
        xi = (c_pad[c_ids] - tot) * dinv[:, None]
    else:
        partial = jnp.sum(dep_coef * gathered, axis=-1)   # (C,)
        tot = partial + carry[carry_in]
        xi = (c_pad[c_ids] - tot) * dinv
    # padding lanes all write the garbage slot (index n / n_carry): in-bounds,
    # duplicate-safe with plain scatter-set
    x = x.at[row_ids].set(xi)
    carry = carry.at[carry_out].set(tot)
    return x, carry


def solve_scan(dsched: DeviceSchedule, c: jax.Array) -> jax.Array:
    """Solve given preamble vector c (= b for untransformed systems)."""
    n = dsched.n
    multi = c.ndim == 2
    tail = (c.shape[1],) if multi else ()
    x0 = jnp.zeros((n + 1,) + tail, dtype=c.dtype)
    carry0 = jnp.zeros((dsched.n_carry + 2,) + tail, dtype=c.dtype)
    c_pad = jnp.concatenate([c, jnp.zeros((1,) + tail, c.dtype)], axis=0)

    def body(state, leaves_s):
        x, carry = state
        x, carry = _step_body(x, carry, c_pad, leaves_s)
        return (x, carry), None

    (x, _), _ = jax.lax.scan(body, (x0, carry0), dsched.leaves())
    return x[:n]


def solve_unrolled(dsched: DeviceSchedule, c: jax.Array) -> jax.Array:
    """Trace-time unrolled engine (use when step count is small — i.e. after
    the transformation)."""
    n = dsched.n
    multi = c.ndim == 2
    tail = (c.shape[1],) if multi else ()
    x = jnp.zeros((n + 1,) + tail, dtype=c.dtype)
    carry = jnp.zeros((dsched.n_carry + 2,) + tail, dtype=c.dtype)
    c_pad = jnp.concatenate([c, jnp.zeros((1,) + tail, c.dtype)], axis=0)
    leaves = dsched.leaves()
    for s in range(dsched.num_steps):
        leaves_s = tuple(l[s] for l in leaves)
        x, carry = _step_body(x, carry, c_pad, leaves_s)
    return x[:n]


def solve(sched: LevelSchedule, c: np.ndarray, engine: str = "scan",
          dsched: DeviceSchedule | None = None) -> np.ndarray:
    """Convenience host-level entry point (jits per schedule identity)."""
    ds = dsched if dsched is not None else to_device(sched)
    fn = solve_scan if engine == "scan" else solve_unrolled
    out = jax.jit(lambda cc: fn(ds, cc))(jnp.asarray(c, dtype=ds.dtype))
    return np.asarray(out)

"""Level-scheduled SpTRSV execution engines in JAX.

Engines (all consume a width-bucketed LevelSchedule, see schedule.py DESIGN):
  * solve_scan      — lax.scan over steps; HLO size O(num width groups),
                      independent of step count.
  * solve_unrolled  — python loop over steps at trace time; exposes each
                      step to XLA (bigger HLO, more fusion freedom).  Only
                      sensible AFTER the transformation shrank the step
                      count — which is precisely the paper's point.
  * multi-RHS via vmap-style batched gathers (b may be (n,) or (n, R)).

Each step applies its width groups sequentially.  That is safe because the
schedule compiler guarantees no lane reads a row (or carry) finalized in the
same step, so intra-step ordering is free.

The preamble c = B'b (transformed systems) is applied outside: either a
materialized-B' SpMV or a second schedule built on the T factor.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .schedule import LevelSchedule

__all__ = ["DeviceSchedule", "to_device", "solve_scan", "solve_unrolled",
           "staged_scan_fn", "staged_unrolled_fn", "solve"]

# leaf order within a group (row_ids doubles as the c gather index —
# padding lanes hit the zero slot).  Carry leaves are present only for
# groups holding partial-row lanes.
GROUP_LEAVES = ("row_ids", "dep_idx", "dep_coef", "dinv")
CARRY_LEAVES = ("carry_in", "carry_out")


class DeviceSchedule:
    """LevelSchedule staged as jnp arrays: a tuple of per-group leaf tuples
    (4 leaves for carry-free groups, 6 with the carry slot maps)."""

    def __init__(self, sched: LevelSchedule):
        # the host LevelSchedule rides along: engines whose lowering is a
        # host-side pass (ShardedEngine pads lane capacities in numpy and
        # memoizes per schedule identity) start from it rather than from
        # the staged arrays
        self.host = sched
        self.groups = tuple(
            tuple(jnp.asarray(getattr(g, name)) for name in GROUP_LEAVES) +
            (tuple(jnp.asarray(getattr(g, name)) for name in CARRY_LEAVES)
             if g.carry_in is not None else ())
            for g in sched.groups)
        self.group_widths = sched.group_widths
        self.n = sched.n
        self.n_carry = sched.n_carry
        self.num_steps = sched.num_steps
        self.dtype = sched.dtype

    def leaves(self):
        """Pytree of stacked leaves; every array has leading dim num_steps."""
        return self.groups


def to_device(sched: LevelSchedule) -> DeviceSchedule:
    return DeviceSchedule(sched)


def _group_body(x, carry, c_pad, leaves_g):
    """Apply one width-group tile of one step."""
    row_ids, dep_idx, dep_coef, dinv = leaves_g[:4]
    has_carry = len(leaves_g) == 6
    gathered = x[dep_idx]                      # (C, D) or (C, D, R)
    if gathered.ndim == 3:
        partial = jnp.einsum("cd,cdr->cr", dep_coef, gathered)
        tot = partial + carry[leaves_g[4]] if has_carry else partial
        xi = (c_pad[row_ids] - tot) * dinv[:, None]
    else:
        partial = jnp.sum(dep_coef * gathered, axis=-1)   # (C,)
        tot = partial + carry[leaves_g[4]] if has_carry else partial
        xi = (c_pad[row_ids] - tot) * dinv
    # padding lanes all write the garbage slot (index n / n_carry+1):
    # in-bounds, duplicate-safe with plain scatter-set
    x = x.at[row_ids].set(xi)
    if has_carry:
        carry = carry.at[leaves_g[5]].set(tot)
    return x, carry


def _step_body(x, carry, c_pad, step_groups):
    for leaves_g in step_groups:
        x, carry = _group_body(x, carry, c_pad, leaves_g)
    return x, carry


def _init_state(n: int, n_carry: int, c: jax.Array):
    tail = (c.shape[1],) if c.ndim == 2 else ()
    x0 = jnp.zeros((n + 1,) + tail, dtype=c.dtype)
    carry0 = jnp.zeros((n_carry + 2,) + tail, dtype=c.dtype)
    c_pad = jnp.concatenate([c, jnp.zeros((1,) + tail, c.dtype)], axis=0)
    return x0, carry0, c_pad


# The staged implementations take the schedule leaves as a PYTREE ARGUMENT
# (not a trace-time closure): the module-level jit wrappers below then key
# their executable cache on leaf structure/shapes only, so a value-only
# schedule repack (`schedule.repack_schedule_values` via
# `TriangularOperator.update_values`) reuses the already-compiled XLA
# executable — new coefficients ride in as arguments, nothing retraces.

def _scan_impl(leaves, n: int, n_carry: int, c: jax.Array) -> jax.Array:
    x0, carry0, c_pad = _init_state(n, n_carry, c)

    def body(state, step_groups):
        x, carry = _step_body(*state, c_pad, step_groups)
        return (x, carry), None

    (x, _), _ = jax.lax.scan(body, (x0, carry0), leaves)
    return x[:n]


def _unrolled_impl(leaves, n: int, n_carry: int, c: jax.Array) -> jax.Array:
    x, carry, c_pad = _init_state(n, n_carry, c)
    num_steps = int(leaves[0][0].shape[0]) if leaves else 0
    for s in range(num_steps):
        step_groups = tuple(tuple(l[s] for l in g) for g in leaves)
        x, carry = _step_body(x, carry, c_pad, step_groups)
    return x[:n]


_scan_jit = jax.jit(_scan_impl, static_argnums=(1, 2))
_unrolled_jit = jax.jit(_unrolled_impl, static_argnums=(1, 2))


def solve_scan(dsched: DeviceSchedule, c: jax.Array) -> jax.Array:
    """Solve given preamble vector c (= b for untransformed systems)."""
    return _scan_impl(dsched.leaves(), dsched.n, dsched.n_carry, c)


def solve_unrolled(dsched: DeviceSchedule, c: jax.Array) -> jax.Array:
    """Trace-time unrolled engine (use when step count is small — i.e. after
    the transformation)."""
    return _unrolled_impl(dsched.leaves(), dsched.n, dsched.n_carry, c)


def staged_scan_fn(dsched: DeviceSchedule):
    """Serving callable for the scan engine: jit with the staged leaves as
    arguments, so schedules sharing a tile layout share one executable."""
    leaves, n, n_carry = dsched.leaves(), dsched.n, dsched.n_carry
    return lambda c: _scan_jit(leaves, n, n_carry, c)


def staged_unrolled_fn(dsched: DeviceSchedule):
    """Serving callable for the unrolled engine (see staged_scan_fn)."""
    leaves, n, n_carry = dsched.leaves(), dsched.n, dsched.n_carry
    return lambda c: _unrolled_jit(leaves, n, n_carry, c)


def solve(sched: LevelSchedule, c: np.ndarray, engine=None,
          dsched: DeviceSchedule | None = None) -> np.ndarray:
    """Convenience host-level entry point (compiles per schedule identity).

    engine: an Engine from repro.solver.engines, a registered name, or None
    for the default scan engine.  Unknown names raise ValueError listing the
    registered engines.  Bare strings are a deprecation shim — pass Engine
    objects (or use repro.solver.api.sptrsv) in new code.
    """
    from .engines import resolve_engine_shim
    eng = resolve_engine_shim(engine, where="levelset.solve(engine=...)")
    ds = dsched if dsched is not None else to_device(sched)
    out = eng.compile(ds)(jnp.asarray(c, dtype=ds.dtype))
    return np.asarray(out)

"""Distributed SpTRSV via shard_map: rows of each step sharded over a mesh
axis; x is replicated and re-synchronized with one all_gather per step.

The collective count is therefore proportional to the number of steps —
i.e. to the level count the paper's transformation minimizes.  On a TPU
mesh the transformation's "95% fewer synchronization barriers" is literally
"95% fewer all_gathers" here (EXPERIMENTS.md §Perf quantifies this from the
lowered HLO).

The schedule's chunk dimension C must be divisible by the axis size; each
device owns C/devices lanes of every step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .levelset import DeviceSchedule
from .schedule import LevelSchedule

__all__ = ["solve_sharded", "lower_sharded"]


def _sharded_body(c_pad, *leaves, n, n_carry, axis):
    (row_ids, dep_idx, dep_coef, dinv, carry_in, carry_out, c_ids,
     is_final) = leaves
    C_local = row_ids.shape[1]
    x0 = jnp.zeros((n + 1,), dtype=c_pad.dtype)
    carry0 = jnp.zeros((n_carry + 2,), dtype=c_pad.dtype)
    # loop carries become device-varying after the per-step all_gather;
    # mark the (identical) initial values as varying to match
    x0 = jax.lax.pcast(x0, (axis,), to="varying")
    carry0 = jax.lax.pcast(carry0, (axis,), to="varying")

    def body(state, s_leaves):
        x, carry = state
        (rids, didx, dcoef, dnv, cin, cout, cids, fin) = s_leaves
        gathered = x[didx]                              # (C_local, D)
        partial = jnp.sum(dcoef * gathered, axis=-1)
        tot = partial + carry[cin]
        xi = (c_pad[cids] - tot) * dnv
        # publish this step's results to every device: one collective per
        # step — the quantity the graph transformation minimizes
        xi_all = jax.lax.all_gather(xi, axis, tiled=True)        # (C,)
        rids_all = jax.lax.all_gather(rids, axis, tiled=True)
        tot_all = jax.lax.all_gather(tot, axis, tiled=True)
        cout_all = jax.lax.all_gather(cout, axis, tiled=True)
        x = x.at[rids_all].set(xi_all)
        carry = carry.at[cout_all].set(tot_all)
        return (x, carry), None

    (x, _), _ = jax.lax.scan(body, (x0, carry0), leaves)
    return x[:n]


def solve_sharded(sched: LevelSchedule, c: np.ndarray, mesh: Mesh,
                  axis: str = "model") -> np.ndarray:
    """Solve with step lanes sharded over `axis` of `mesh`."""
    fn = lower_sharded(sched, mesh, axis=axis)
    return np.asarray(fn(jnp.asarray(c, dtype=sched.dep_coef.dtype)))


def lower_sharded(sched: LevelSchedule, mesh: Mesh, axis: str = "model"):
    """Build the jitted sharded solver fn(c) -> x for a fixed schedule."""
    nshards = mesh.shape[axis]
    assert sched.chunk % nshards == 0, \
        f"chunk {sched.chunk} not divisible by axis size {nshards}"
    ds = DeviceSchedule(sched)
    leaves = ds.leaves()
    # lanes sharded over the chunk dimension; indices/carries replicated math
    lane_spec = tuple(
        P(None, axis) if l.ndim == 2 else P(None, axis, None) for l in leaves)
    body = functools.partial(_sharded_body, n=ds.n, n_carry=ds.n_carry,
                             axis=axis)
    shmapped = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(),) + lane_spec,
        out_specs=P(),
        # x ends replicated (every device applies the same gathered
        # updates), but the varying-axis tracker can't prove it
        check_vma=False)

    @jax.jit
    def run(c):
        c_pad = jnp.concatenate([c, jnp.zeros((1,), c.dtype)])
        return shmapped(c_pad, *leaves)

    return run

"""Distributed SpTRSV via shard_map: lanes of each step sharded over a mesh
axis; x is replicated and re-synchronized with one all_gather family per
step.

The collective count is therefore proportional to the number of steps —
i.e. to the step count the schedule compiler minimizes (compaction) on top
of the level count the paper's transformation minimizes.  On a TPU mesh the
transformation's "95% fewer synchronization barriers" is literally "95%
fewer all_gathers" here.

Width groups are sharded independently over their lane dimension and their
per-step updates are concatenated before the gather, so the number of
collectives per step stays constant no matter how many width classes the
schedule uses.  Every group's lane capacity is padded up to a multiple of
the axis size on the host before sharding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .levelset import DeviceSchedule
from .schedule import LevelSchedule, WidthGroup

__all__ = ["solve_sharded", "lower_sharded"]

# jax >= 0.7 exposes shard_map/pcast at the top level; older releases keep
# shard_map in jax.experimental and have no pcast (check_rep=False covers
# the same replication-tracking escape hatch)
if hasattr(jax, "shard_map"):
    def _shard_map(f, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
else:                                                   # pragma: no cover
    from jax.experimental.shard_map import shard_map as _esm

    def _shard_map(f, mesh, in_specs, out_specs):
        return _esm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                    check_rep=False)

_pcast = getattr(jax.lax, "pcast", None)


def _mark_varying(x, axis):
    return _pcast(x, (axis,), to="varying") if _pcast is not None else x


def _pad_group(g: WidthGroup, mult: int, n: int, n_carry: int) -> WidthGroup:
    """Pad the lane dimension to a multiple of `mult` with inert lanes."""
    S, C = g.row_ids.shape
    C_new = -(-C // mult) * mult
    if C_new == C:
        return g
    pad = C_new - C

    def pad2(a, fill):
        out = np.full((S, C_new), fill, dtype=a.dtype)
        out[:, :C] = a
        return out

    dep_idx = np.zeros((S, C_new, g.dep_idx.shape[2]), dtype=g.dep_idx.dtype)
    dep_idx[:, :C] = g.dep_idx
    dep_coef = np.zeros((S, C_new, g.dep_coef.shape[2]),
                        dtype=g.dep_coef.dtype)
    dep_coef[:, :C] = g.dep_coef
    return WidthGroup(
        width=g.width, n=n,
        row_ids=pad2(g.row_ids, n),
        dep_idx=dep_idx,
        dep_coef=dep_coef,
        dinv=pad2(g.dinv, 0),
        carry_in=None if g.carry_in is None else pad2(g.carry_in, n_carry),
        carry_out=None if g.carry_out is None else
        pad2(g.carry_out, n_carry + 1))


def _sharded_body(c_pad, groups, *, n, n_carry, axis):
    x0 = jnp.zeros((n + 1,), dtype=c_pad.dtype)
    carry0 = jnp.zeros((n_carry + 2,), dtype=c_pad.dtype)
    # loop carries become device-varying after the per-step all_gather;
    # mark the (identical) initial values as varying to match
    x0 = _mark_varying(x0, axis)
    carry0 = _mark_varying(carry0, axis)

    def body(state, step_groups):
        x, carry = state
        # carry machinery is dropped from the collective entirely when no
        # group ships carry maps (the common, no-split-row case)
        any_carries = any(len(g) == 6 for g in step_groups)
        xis, tots, rids_l, couts_l = [], [], [], []
        for g in step_groups:
            rids, didx, dcoef, dnv = g[:4]
            partial = jnp.sum(dcoef * x[didx], axis=-1)     # (C_local,)
            tot = partial + carry[g[4]] if len(g) == 6 else partial
            xis.append((c_pad[rids] - tot) * dnv)
            rids_l.append(rids)
            if any_carries:
                tots.append(tot)
                couts_l.append(g[5] if len(g) == 6 else
                               jnp.full(rids.shape, n_carry + 1, jnp.int32))
        # publish this step's results to every device: one concatenated
        # all_gather family per step — the quantity compaction minimizes
        xi_all = jax.lax.all_gather(jnp.concatenate(xis), axis, tiled=True)
        rid_all = jax.lax.all_gather(jnp.concatenate(rids_l), axis,
                                     tiled=True)
        x = x.at[rid_all].set(xi_all)
        if any_carries:
            tot_all = jax.lax.all_gather(jnp.concatenate(tots), axis,
                                         tiled=True)
            cout_all = jax.lax.all_gather(jnp.concatenate(couts_l), axis,
                                          tiled=True)
            carry = carry.at[cout_all].set(tot_all)
        return (x, carry), None

    (x, _), _ = jax.lax.scan(body, (x0, carry0), groups)
    return x[:n]


def solve_sharded(sched: LevelSchedule, c: np.ndarray, mesh: Mesh,
                  axis: str = "model") -> np.ndarray:
    """Solve with step lanes sharded over `axis` of `mesh`."""
    fn = lower_sharded(sched, mesh, axis=axis)
    return np.asarray(fn(jnp.asarray(c, dtype=sched.dtype)))


def lower_sharded(sched: LevelSchedule, mesh: Mesh, axis: str = "model"):
    """Build the jitted sharded solver fn(c) -> x for a fixed schedule."""
    nshards = mesh.shape[axis]
    padded = LevelSchedule(
        groups=tuple(_pad_group(g, nshards, sched.n, sched.n_carry)
                     for g in sched.groups),
        n=sched.n, n_carry=sched.n_carry, num_levels=sched.num_levels,
        chunk=sched.chunk, max_deps=sched.max_deps,
        compacted=sched.compacted, build_ms=sched.build_ms)
    ds = DeviceSchedule(padded)
    groups = ds.leaves()
    # lanes sharded over their group's lane dimension; x/c replicated
    group_specs = tuple(
        tuple(P(None, axis) if l.ndim == 2 else P(None, axis, None)
              for l in g) for g in groups)
    body = functools.partial(_sharded_body, n=ds.n, n_carry=ds.n_carry,
                             axis=axis)
    # x ends replicated (every device applies the same gathered updates),
    # but the replication tracker can't prove it — hence the escape hatch
    # inside _shard_map
    shmapped = _shard_map(body, mesh, (P(), group_specs), P())

    @jax.jit
    def run(c):
        c_pad = jnp.concatenate([c, jnp.zeros((1,), c.dtype)])
        return shmapped(c_pad, groups)

    return run

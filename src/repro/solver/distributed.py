"""Distributed SpTRSV via shard_map: lanes of each step sharded over a mesh
axis; x is replicated and re-synchronized with one all_gather family per
step.

The collective count is therefore proportional to the number of steps —
i.e. to the step count the schedule compiler minimizes (compaction) on top
of the level count the paper's transformation minimizes.  On a TPU mesh the
transformation's "95% fewer synchronization barriers" is literally "95%
fewer all_gathers" here.  `count_all_gathers` verifies the invariant by
tracing an unrolled copy of the sharded body with a counting collective:
exactly one all_gather family (synchronization point) per schedule step,
carry gathers riding in the same family.

Width groups are sharded independently over their lane dimension and their
per-step updates are concatenated before the gather, so the number of
collectives per step stays constant no matter how many width classes the
schedule uses.  Every group's lane capacity is padded up to a multiple of
the axis size on the host before sharding.  Right-hand sides may be single
`(n,)` or batched `(n, k)` — lanes are sharded, RHS columns replicated,
and the gather concatenates along the lane axis only.

This module is the lowering backend of the registered `ShardedEngine`
(repro.solver.engines): engine compiles are memoized per (schedule
identity, mesh, axis), so serving paths never re-pad or re-stage groups
for a schedule they already lowered.  See docs/distributed.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .levelset import DeviceSchedule
from .schedule import LevelSchedule, WidthGroup

__all__ = ["solve_sharded", "lower_sharded", "count_all_gathers",
           "default_mesh", "require_axis", "shard_map_compat"]

# jax >= 0.7 exposes shard_map/pcast at the top level; older releases keep
# shard_map in jax.experimental and have no pcast (check_rep=False covers
# the same replication-tracking escape hatch)
if hasattr(jax, "shard_map"):
    def _shard_map(f, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
else:                                                   # pragma: no cover
    from jax.experimental.shard_map import shard_map as _esm

    def _shard_map(f, mesh, in_specs, out_specs):
        return _esm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                    check_rep=False)

# public alias: other layers (repro.iterative's sharded SpMV) build their
# own shard_map programs and must ride the same version-compat shim
shard_map_compat = _shard_map

_pcast = getattr(jax.lax, "pcast", None)


def _mark_varying(x, axis):
    return _pcast(x, (axis,), to="varying") if _pcast is not None else x


@functools.lru_cache(maxsize=8)
def _default_mesh_cached(axis: str) -> Mesh:
    return Mesh(np.array(jax.devices()), (axis,))


def default_mesh(axis: str = "model", devices=None) -> Mesh:
    """One-axis mesh over `devices` (default: every local device).

    The no-argument form is cached per axis name, so repeat calls return
    the identical Mesh object and memoized lowerings keyed on it hit.
    """
    if devices is None:
        return _default_mesh_cached(axis)
    return Mesh(np.asarray(devices), (axis,))


def require_axis(mesh: Mesh, axis: str) -> None:
    """Validate that `axis` names an axis of `mesh` — a mismatch must be
    an eager ValueError naming the mesh's axes, not a KeyError from deep
    inside lowering."""
    if axis not in mesh.shape:
        raise ValueError(
            f"mesh has no axis {axis!r}; its axes are "
            f"{tuple(mesh.axis_names)} — pass mesh_axis=/axis= naming one "
            f"of them")


def _pad_group(g: WidthGroup, mult: int, n: int, n_carry: int) -> WidthGroup:
    """Pad the lane dimension to a multiple of `mult` with inert lanes."""
    S, C = g.row_ids.shape
    C_new = -(-C // mult) * mult
    if C_new == C:
        return g
    pad = C_new - C

    def pad2(a, fill):
        out = np.full((S, C_new), fill, dtype=a.dtype)
        out[:, :C] = a
        return out

    dep_idx = np.zeros((S, C_new, g.dep_idx.shape[2]), dtype=g.dep_idx.dtype)
    dep_idx[:, :C] = g.dep_idx
    dep_coef = np.zeros((S, C_new, g.dep_coef.shape[2]),
                        dtype=g.dep_coef.dtype)
    dep_coef[:, :C] = g.dep_coef
    return WidthGroup(
        width=g.width, n=n,
        row_ids=pad2(g.row_ids, n),
        dep_idx=dep_idx,
        dep_coef=dep_coef,
        dinv=pad2(g.dinv, 0),
        carry_in=None if g.carry_in is None else pad2(g.carry_in, n_carry),
        carry_out=None if g.carry_out is None else
        pad2(g.carry_out, n_carry + 1))


def _padded_schedule(sched: LevelSchedule, nshards: int) -> LevelSchedule:
    """The schedule with every group's lane capacity padded to a multiple
    of `nshards` (host-side numpy, no staging)."""
    return LevelSchedule(
        groups=tuple(_pad_group(g, nshards, sched.n, sched.n_carry)
                     for g in sched.groups),
        n=sched.n, n_carry=sched.n_carry, num_levels=sched.num_levels,
        chunk=sched.chunk, max_deps=sched.max_deps,
        compacted=sched.compacted, build_ms=sched.build_ms)


def _stage_padded(sched: LevelSchedule, nshards: int) -> DeviceSchedule:
    """Pad and stage (the one host-side pass the engine memoizes)."""
    return DeviceSchedule(_padded_schedule(sched, nshards))


def _gather(v, axis):
    return jax.lax.all_gather(v, axis, tiled=True)


def _step_update(x, carry, c_pad, step_groups, *, n_carry, axis,
                 gather=_gather):
    """One schedule step on one device's lane shard, published to every
    device by one all_gather family (the per-step synchronization point).
    `gather` is injectable so `count_all_gathers` can audit the family
    count; carry machinery is dropped from the collective entirely when no
    group ships carry maps (the common, no-split-row case)."""
    any_carries = any(len(g) == 6 for g in step_groups)
    xis, tots, rids_l, couts_l = [], [], [], []
    for g in step_groups:
        rids, didx, dcoef, dnv = g[:4]
        gathered = x[didx]                     # (C, D) or (C, D, R)
        if gathered.ndim == 3:
            partial = jnp.einsum("cd,cdr->cr", dcoef, gathered)
        else:
            partial = jnp.sum(dcoef * gathered, axis=-1)    # (C,)
        tot = partial + carry[g[4]] if len(g) == 6 else partial
        xi = (c_pad[rids] - tot) * (dnv if tot.ndim == 1 else dnv[:, None])
        xis.append(xi)
        rids_l.append(rids)
        if any_carries:
            tots.append(tot)
            couts_l.append(g[5] if len(g) == 6 else
                           jnp.full(rids.shape, n_carry + 1, jnp.int32))
    # publish this step's results to every device: one concatenated
    # all_gather family per step — the quantity compaction minimizes
    xi_all = gather(jnp.concatenate(xis), axis)
    rid_all = gather(jnp.concatenate(rids_l), axis)
    x = x.at[rid_all].set(xi_all)
    if any_carries:
        tot_all = gather(jnp.concatenate(tots), axis)
        cout_all = gather(jnp.concatenate(couts_l), axis)
        carry = carry.at[cout_all].set(tot_all)
    return x, carry


def _sharded_body(c_pad, groups, *, n, n_carry, axis):
    tail = c_pad.shape[1:]                  # () single RHS, (R,) batched
    x0 = jnp.zeros((n + 1,) + tail, dtype=c_pad.dtype)
    carry0 = jnp.zeros((n_carry + 2,) + tail, dtype=c_pad.dtype)
    # loop carries become device-varying after the per-step all_gather;
    # mark the (identical) initial values as varying to match
    x0 = _mark_varying(x0, axis)
    carry0 = _mark_varying(carry0, axis)

    def body(state, step_groups):
        x, carry = _step_update(*state, c_pad, step_groups,
                                n_carry=n_carry, axis=axis)
        return (x, carry), None

    (x, _), _ = jax.lax.scan(body, (x0, carry0), groups)
    return x[:n]


def solve_sharded(sched: LevelSchedule, c: np.ndarray, mesh: Mesh,
                  axis: str = "model") -> np.ndarray:
    """Solve with step lanes sharded over `axis` of `mesh`.

    Routed through the `ShardedEngine` machinery, so repeat calls on the
    same schedule object reuse the memoized lowering instead of re-padding
    and re-staging the groups per call.  `c` may be `(n,)` or batched
    `(n, k)`; a leading dimension that does not match the schedule raises
    ValueError (never an opaque concatenate error).
    """
    from .engines import sharded_engine
    fn = sharded_engine(mesh, axis).compile(sched)
    return np.asarray(fn(jnp.asarray(c, dtype=sched.dtype)))


def lower_sharded(sched: LevelSchedule, mesh: Mesh, axis: str = "model"):
    """Build the jitted sharded solver fn(c) -> x for a fixed schedule.

    The returned fn accepts `(n,)` or batched `(n, k)` right-hand sides
    (lanes sharded over `axis`, RHS columns replicated) and validates the
    leading dimension eagerly.  Prefer `ShardedEngine.compile` (or
    `solve_sharded`), which memoizes this lowering per schedule identity.
    """
    require_axis(mesh, axis)
    nshards = mesh.shape[axis]
    # lowering may be triggered lazily from INSIDE a jit trace (an
    # operator first used as a traced preconditioner); the staged arrays
    # are memoized on the engine, so they must be concrete, never tracers
    with jax.ensure_compile_time_eval():
        ds = _stage_padded(sched, nshards)
    groups = ds.leaves()
    # lanes sharded over their group's lane dimension; x/c replicated
    group_specs = tuple(
        tuple(P(None, axis) if l.ndim == 2 else P(None, axis, None)
              for l in g) for g in groups)
    body = functools.partial(_sharded_body, n=ds.n, n_carry=ds.n_carry,
                             axis=axis)
    # x ends replicated (every device applies the same gathered updates),
    # but the replication tracker can't prove it — hence the escape hatch
    # inside _shard_map
    shmapped = _shard_map(body, mesh, (P(), group_specs), P())

    @jax.jit
    def run_padded(c):
        zero = jnp.zeros((1,) + c.shape[1:], c.dtype)
        return shmapped(jnp.concatenate([c, zero], axis=0), groups)

    def run(c):
        c = jnp.asarray(c, dtype=ds.dtype)
        if c.ndim not in (1, 2) or c.shape[0] != ds.n:
            raise ValueError(
                f"right-hand side must be ({ds.n},) or ({ds.n}, k) to "
                f"match the schedule, got shape {c.shape}")
        return run_padded(c)

    return run


def count_all_gathers(sched: LevelSchedule, mesh: Mesh | None = None,
                      axis: str = "model") -> dict:
    """Audit the collective count of one sharded solve by abstract
    tracing (no execution, no device staging, any mesh size — default a
    1-device mesh).

    Traces an unrolled copy of the sharded body over the padded HOST
    schedule with a counting collective and returns ``{"steps",
    "families", "calls"}`` where `families` is the number of steps that
    issued at least one all_gather — the number of per-step
    synchronization barriers — and `calls` the raw all_gather
    invocations: 2 per step (values + row ids), uniformly 4 per step on
    schedules with any split-row group (the carry machinery keys off the
    static leaf structure, which is shared by every step, not off
    per-step carry placement).  The module invariant, which
    benchmarks/tests assert, is ``families == steps``.
    """
    from .levelset import CARRY_LEAVES, GROUP_LEAVES
    if mesh is None:
        mesh = default_mesh(axis=axis, devices=jax.devices()[:1])
    require_axis(mesh, axis)
    padded = _padded_schedule(sched, mesh.shape[axis])
    # numpy leaves, same per-group layout as DeviceSchedule.leaves():
    # the audit only traces, so nothing needs to live on the device
    groups = tuple(
        tuple(getattr(g, name) for name in GROUP_LEAVES) +
        (tuple(getattr(g, name) for name in CARRY_LEAVES)
         if g.carry_in is not None else ())
        for g in padded.groups)
    per_step: list[int] = []

    def gather(v, ax):
        per_step[-1] += 1
        return jax.lax.all_gather(v, ax, tiled=True)

    def body(c_pad):
        x = jnp.zeros((padded.n + 1,), dtype=c_pad.dtype)
        carry = jnp.zeros((padded.n_carry + 2,), dtype=c_pad.dtype)
        for s in range(padded.num_steps):
            per_step.append(0)
            step_groups = tuple(tuple(l[s] for l in g) for g in groups)
            x, carry = _step_update(x, carry, c_pad, step_groups,
                                    n_carry=padded.n_carry, axis=axis,
                                    gather=gather)
        return x[:padded.n]

    # groups ride in as replicated closure constants: only the collective
    # structure matters here, and it is independent of the lane sharding
    shmapped = _shard_map(body, mesh, (P(),), P())
    jax.eval_shape(shmapped,
                   jax.ShapeDtypeStruct((padded.n + 1,), padded.dtype))
    return {"steps": padded.num_steps,
            "families": sum(1 for k in per_step if k > 0),
            "calls": sum(per_step)}

"""`sptrsv`: the one-call triangular-solve surface (forward, backward, grad).

The paper's motivating workload is the triangular solves inside
preconditioned iterative methods, which come in forward/backward pairs
(`L x = b` then `L^T y = x`, or `U y = x`).  This module exposes all four
sweeps through a single function:

    x = sptrsv(L, b)                                  # L x = b
    y = sptrsv(L, x, transpose=True)                  # L^T y = x
    z = sptrsv(U, b, lower=False)                     # U z = b
    w = sptrsv(U, b, lower=False, transpose=True)     # U^T w = b

Under the hood every call builds (or cache-hits) a `TriangularOperator`
with the matching orientation bits, so the transform portfolio, the
width-bucketed schedule compiler, and every registered engine serve all
sweeps — repeat calls on the same matrix + configuration are memory-cache
hits that skip straight to the compiled schedule.

Differentiability: when `b` is a JAX array (including tracers under
jit/grad/vmap), the solve routes through a `jax.custom_vjp` whose backward
pass is *the transpose operator itself* — the cotangent of `x = A^{-1} b`
is `b_bar = A^{-T} g`, i.e. the new surface is its own backward pass.  The
host-side operator (iterative refinement included) runs inside
`jax.pure_callback`, so `sptrsv` composes with `jit` and `grad` and is
usable inside trained/differentiated JAX programs.  Gradients flow through
`b`; the matrix is a static (non-differentiable) argument.

Engines resolve through the repro.solver.engines registry; `engine=` takes
a registered name, an Engine instance, or None for the default.
"""
from __future__ import annotations

import functools

import numpy as np

from ..sparse.csr import CSR, from_coo
from .operator import TriangularOperator

__all__ = ["sptrsv", "with_unit_diagonal"]


def with_unit_diagonal(A: CSR) -> CSR:
    """A with its diagonal forced to 1 (existing entries replaced, missing
    ones inserted) — the `unit_diagonal=True` semantics of sptrsv, matching
    scipy.sparse.linalg.spsolve_triangular."""
    n = min(A.shape)
    rows = np.repeat(np.arange(A.n_rows), A.row_nnz())
    off = rows != A.indices
    rows = np.concatenate([rows[off], np.arange(n)])
    cols = np.concatenate([A.indices[off], np.arange(n)])
    vals = np.concatenate([A.data[off], np.ones(n, dtype=A.data.dtype)])
    return from_coo(rows, cols, vals, A.shape, sum_duplicates=False)


class _BoundSolve:
    """Forward/adjoint operator pair closed over solve options.

    Hashable by identity — it rides through `jax.custom_vjp` as a
    non-differentiable argument.  The adjoint operator is built lazily on
    the first backward pass (from_csr, so it shares the operator cache).
    """

    def __init__(self, op: TriangularOperator, refine_tol: float,
                 max_refine: int, health=None):
        self.op = op
        self.refine_tol = refine_tol
        self.max_refine = max_refine
        self.health = health
        self._adjoint = None
        self._flipped = None

    @property
    def adjoint(self) -> TriangularOperator:
        if self._adjoint is None:
            self._adjoint = self.op.transposed()
        return self._adjoint

    def flipped(self) -> "_BoundSolve":
        """The adjoint solve as its own _BoundSolve, whose adjoint is this
        one's forward op — so the backward pass is itself differentiable
        (grad-of-grad composes to any order)."""
        if self._flipped is None:
            f = _BoundSolve(self.adjoint, self.refine_tol, self.max_refine,
                            health=self.health)
            f._adjoint = self.op
            f._flipped = self
            self._flipped = f
        return self._flipped

    def host_solve(self, b: np.ndarray) -> np.ndarray:
        # the operator promotes b itself when refining; with refinement
        # off it runs fp64-copy-free in the schedule dtype and only the
        # returned array is cast up — sptrsv's numpy path contract is
        # float64 out either way
        x = self.op.solve(np.asarray(b), refine_tol=self.refine_tol,
                          max_refine=self.max_refine, health=self.health)
        return np.asarray(x, dtype=np.float64)


def _callback_solve(bound: _BoundSolve, b):
    """Host operator solve lifted into the JAX program (jit-compatible)."""
    import jax
    out = jax.ShapeDtypeStruct(b.shape, b.dtype)

    def cb(bb):
        return np.asarray(bound.host_solve(bb), dtype=out.dtype)

    return jax.pure_callback(cb, out, b, vmap_method="sequential")


@functools.cache
def _solve_jax():
    """The custom_vjp'd solve, built lazily so importing repro.solver does
    not import jax."""
    import jax

    @functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
    def solve(bound, b):
        return _callback_solve(bound, b)

    def fwd(bound, b):
        return solve(bound, b), None        # cotangent needs no residuals

    def bwd(bound, _res, g):
        # d/db of x = A^{-1} b contracted with g is A^{-T} g: the backward
        # sweep is the forward surface with the transpose bit flipped —
        # routed back through the custom_vjp'd solve so the cotangent is
        # itself differentiable (second-order AD / HVPs compose)
        return (solve(bound.flipped(), g),)

    solve.defvjp(fwd, bwd)
    return solve


def sptrsv(A: CSR, b, *, lower: bool = True, transpose: bool = False,
           unit_diagonal: bool = False, engine=None, mesh=None,
           mesh_axis: str = "model", tune="no_rewriting",
           chunk: int = 256, max_deps: int = 16, dtype=np.float32,
           cache: bool = True, cache_dir=None, refine_tol: float = 1e-10,
           max_refine: int = 6, health=None):
    """Solve the triangular system `op(A) x = b` (module doc for the map
    of sweeps).

    A:      CSR triangular matrix — lower when `lower=True`, else upper.
    b:      (n,) or batched (n, k).  A numpy array returns float64 numpy
            (refined by default; with max_refine=0 the device math runs
            fp64-copy-free in the schedule dtype and only the returned
            array is cast up); a JAX array (or tracer) returns a JAX
            array of the same dtype and is differentiable w.r.t. b.
    lower/transpose/unit_diagonal: orientation of the solve, matching
            scipy.sparse.linalg.spsolve_triangular's vocabulary.
    engine: registered engine name, Engine instance, or None (scan).
    mesh/mesh_axis: a jax Mesh routes the solve through the sharded
            engine over `mesh_axis` — one all_gather family per schedule
            step (docs/distributed.md).  Mutually exclusive with engine=.
    tune:   transform selection forwarded to TriangularOperator.from_csr —
            "no_rewriting" (default: plain level scheduling), any stable
            strategy name, a Strategy instance, or "auto" for the
            portfolio auto-tuner.
    cache:  reuse/persist the compiled operator artifact across calls.
    health: solve-path health policy — a `repro.core.HealthPolicy`, a
            named level ("off" | "on" | "strict" | "repair" | "fallback"),
            or None for the REPRO_HEALTH_CHECKS environment default.
            Applies to every host solve this call performs, backward
            (adjoint) passes included; see TriangularOperator.solve and
            docs/robustness.md.
    """
    if unit_diagonal:
        A = with_unit_diagonal(A)
    op = TriangularOperator.from_csr(
        A, tune, side="lower" if lower else "upper",
        transpose=bool(transpose), chunk=chunk, max_deps=max_deps,
        dtype=dtype, engine=engine, mesh=mesh, mesh_axis=mesh_axis,
        cache=cache, cache_dir=cache_dir)
    bound = _BoundSolve(op, refine_tol=refine_tol, max_refine=max_refine,
                        health=health)
    try:
        import jax
        is_jax = isinstance(b, jax.Array)
    except ModuleNotFoundError:         # pragma: no cover - env dependent
        is_jax = False
    if is_jax:
        return _solve_jax()(bound, b)
    return bound.host_solve(np.asarray(b))

"""Execution-engine protocol + registry: the single seam for solve dispatch.

Before this module, engine selection was bare strings ("scan" / "unrolled" /
"pallas") if/else-dispatched independently in solver/levelset.py,
solver/operator.py, and kernels/ops.py — adding a backend meant touching all
three, and a typo silently fell through to the unrolled engine.  Now every
engine is a registered object with capability metadata, and every consumer
(`levelset.solve`, `TriangularOperator`, `sptrsv`, the portfolio's measured
mode, `benchmarks/`) resolves it through one entry point:

    eng = resolve_engine("scan")          # name, Engine instance, or None
    fn  = eng.compile(dsched)             # DeviceSchedule -> jnp callable
    x   = fn(c)                           # c: (n,) or batched (n, R)

Engine contract
===============
* `name`                    — stable registry key (also the cache-key form).
* `supports_batched_rhs`    — accepts (n, R) right-hand sides.
* `supports_pallas_backend` — lowers through the Pallas kernel path.
* `dtypes`                  — schedule dtypes the engine is validated for.
* `available()`             — importable/usable in this process (an engine
  may be registered but unavailable, e.g. a TPU-only backend on CPU).
* `compile(dsched)`         — returns `fn(c) -> x` over jnp arrays in the
  schedule dtype; `fn` may be called repeatedly (serving path) and must not
  restage the schedule.

Unknown names raise `ValueError` listing the registered engines — never a
silent fallback.  String engine names remain accepted at the public entry
points as thin shims that resolve here; `levelset.solve`'s legacy string
kwarg additionally emits a `DeprecationWarning` (CI fails on such warnings
originating from repro's own modules, so internal code must pass Engine
objects).
"""
from __future__ import annotations

import os
import warnings

__all__ = ["Engine", "ScanEngine", "UnrolledEngine", "PallasEngine",
           "register_engine", "resolve_engine", "get_engine",
           "registered_engines", "available_engines", "default_engine",
           "default_interpret", "engine_capabilities", "DEFAULT_ENGINE"]

DEFAULT_ENGINE = "scan"


def default_interpret() -> bool:
    """Pallas interpret mode default: on unless REPRO_PALLAS_INTERPRET=0."""
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


class Engine:
    """Base class / protocol for SpTRSV execution engines (module doc)."""

    name: str = "abstract"
    supports_batched_rhs: bool = True
    supports_pallas_backend: bool = False
    dtypes: tuple = ("float32", "float64")

    def available(self) -> bool:
        return True

    def compile(self, dsched):
        """DeviceSchedule -> callable fn(c) -> x over jnp arrays."""
        raise NotImplementedError

    def capabilities(self) -> dict:
        return {
            "name": self.name,
            "supports_batched_rhs": self.supports_batched_rhs,
            "supports_pallas_backend": self.supports_pallas_backend,
            "dtypes": list(self.dtypes),        # list: JSON round-trip stable
            "available": self.available(),
        }

    def __repr__(self) -> str:  # pragma: no cover
        return f"{type(self).__name__}(name={self.name!r})"


class ScanEngine(Engine):
    """`lax.scan` over steps — HLO size independent of step count (default)."""

    name = "scan"

    def compile(self, dsched):
        import jax
        from .levelset import solve_scan
        return jax.jit(lambda c: solve_scan(dsched, c))


class UnrolledEngine(Engine):
    """Trace-time unrolled steps — bigger HLO, more fusion freedom; sensible
    after the transformation shrank the step count."""

    name = "unrolled"

    def compile(self, dsched):
        import jax
        from .levelset import solve_unrolled
        return jax.jit(lambda c: solve_unrolled(dsched, c))


class PallasEngine(Engine):
    """Pallas TPU kernel (interpret mode on CPU): one grid step per schedule
    step, x/carry resident in VMEM.  `interpret=None` follows the
    REPRO_PALLAS_INTERPRET env default at compile time."""

    supports_pallas_backend = True
    dtypes = ("float32",)

    def __init__(self, interpret: bool | None = None, name: str = "pallas"):
        self.name = name
        self.interpret = interpret

    def available(self) -> bool:
        try:
            import jax.experimental.pallas  # noqa: F401
        except Exception:  # pragma: no cover - env dependent
            return False
        return True

    def compile(self, dsched):
        import jax.numpy as jnp
        from ..kernels.sptrsv_level import (sptrsv_groups_pallas,
                                            sptrsv_groups_pallas_multi)
        interpret = (default_interpret() if self.interpret is None
                     else self.interpret)
        groups, n, n_carry = dsched.groups, dsched.n, dsched.n_carry
        dtype = dsched.dtype

        def fn(c):
            c = jnp.asarray(c, dtype=dtype)
            tail = (c.shape[1],) if c.ndim == 2 else ()
            c_pad = jnp.concatenate([c, jnp.zeros((1,) + tail, dtype)],
                                    axis=0)
            kern = sptrsv_groups_pallas_multi if tail else sptrsv_groups_pallas
            return kern(groups, c_pad, n=n, n_carry=n_carry,
                        interpret=interpret)

        return fn


# -- registry -----------------------------------------------------------------

_REGISTRY: dict[str, Engine] = {}


def register_engine(engine: Engine, overwrite: bool = False) -> Engine:
    """Register an engine under `engine.name`; returns it for chaining."""
    if not isinstance(engine.name, str) or not engine.name:
        raise TypeError(f"engine must carry a non-empty string name: "
                        f"{engine!r}")
    if engine.name in _REGISTRY and not overwrite:
        raise ValueError(f"engine {engine.name!r} already registered "
                         f"(pass overwrite=True to replace)")
    _REGISTRY[engine.name] = engine
    return engine


def registered_engines() -> tuple:
    """Sorted names of every registered engine (available or not)."""
    return tuple(sorted(_REGISTRY))


def available_engines() -> tuple:
    """Sorted names of registered engines whose available() is True."""
    return tuple(name for name in registered_engines()
                 if _REGISTRY[name].available())


def engine_capabilities() -> dict:
    """name -> capability dict for every registered engine (CI smoke uses
    this to print the capability matrix)."""
    return {name: _REGISTRY[name].capabilities()
            for name in registered_engines()}


def get_engine(name: str) -> Engine:
    """Look a registered engine up by name; unknown names raise ValueError
    listing the registered options (never a silent fallback)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; registered engines: "
            f"{list(registered_engines())}") from None


def default_engine() -> Engine:
    return _REGISTRY[DEFAULT_ENGINE]


def resolve_engine(spec=None) -> Engine:
    """Resolve an engine spec: None -> default, a name string -> registry
    lookup, an Engine (or anything with name + compile) passes through."""
    if spec is None:
        return default_engine()
    if isinstance(spec, str):
        return get_engine(spec)
    if isinstance(spec, Engine) or (hasattr(spec, "compile")
                                    and hasattr(spec, "name")):
        return spec
    raise TypeError(f"engine spec must be None, a registered name, or an "
                    f"Engine instance, got {type(spec).__name__}")


def resolve_engine_shim(spec, where: str, stacklevel: int = 3) -> Engine:
    """Legacy string-kwarg shim: same resolution as resolve_engine, but a
    bare string additionally emits a DeprecationWarning attributed to the
    caller (so CI can fail on internal use while user code keeps working).
    Resolution happens first: typos raise the ValueError naming the
    registered engines, never the deprecation notice."""
    if isinstance(spec, str):
        eng = get_engine(spec)
        warnings.warn(
            f"passing engine name strings to {where} is deprecated; pass an "
            f"Engine from repro.solver.engines (e.g. resolve_engine({spec!r}))",
            DeprecationWarning, stacklevel=stacklevel)
        return eng
    return resolve_engine(spec)


register_engine(ScanEngine())
register_engine(UnrolledEngine())
register_engine(PallasEngine(interpret=None, name="pallas"))
register_engine(PallasEngine(interpret=True, name="pallas-interpret"))

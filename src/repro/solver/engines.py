"""Execution-engine protocol + registry: the single seam for solve dispatch.

Before this module, engine selection was bare strings ("scan" / "unrolled" /
"pallas") if/else-dispatched independently in solver/levelset.py,
solver/operator.py, and kernels/ops.py — adding a backend meant touching all
three, and a typo silently fell through to the unrolled engine.  Now every
engine is a registered object with capability metadata, and every consumer
(`levelset.solve`, `TriangularOperator`, `sptrsv`, the portfolio's measured
mode, `benchmarks/`) resolves it through one entry point:

    eng = resolve_engine("scan")          # name, Engine instance, or None
    fn  = eng.compile(dsched)             # DeviceSchedule -> jnp callable
    x   = fn(c)                           # c: (n,) or batched (n, R)

Engine contract
===============
* `name`                    — stable registry key (also the cache-key form).
* `supports_batched_rhs`    — accepts (n, R) right-hand sides.
* `supports_pallas_backend` — lowers through the Pallas kernel path.
* `dtypes`                  — schedule dtypes the engine is validated for.
* `available()`             — importable/usable in this process (an engine
  may be registered but unavailable, e.g. a TPU-only backend on CPU).
* `compile(dsched)`         — returns `fn(c) -> x` over jnp arrays in the
  schedule dtype; `fn` may be called repeatedly (serving path) and must not
  restage the schedule.

Unknown names raise `ValueError` listing the registered engines — never a
silent fallback.  String engine names remain accepted at the public entry
points as thin shims that resolve here; `levelset.solve`'s legacy string
kwarg additionally emits a `DeprecationWarning` (CI fails on such warnings
originating from repro's own modules, so internal code must pass Engine
objects).
"""
from __future__ import annotations

import collections
import os
import threading
import warnings

__all__ = ["Engine", "ScanEngine", "UnrolledEngine", "PallasEngine",
           "ShardedEngine", "sharded_engine", "compile_source",
           "register_engine", "resolve_engine", "get_engine",
           "registered_engines", "available_engines", "default_engine",
           "default_interpret", "engine_capabilities", "DEFAULT_ENGINE",
           "engine_fallbacks", "set_fallback_chain", "fallback_chains"]

DEFAULT_ENGINE = "scan"


def compile_source(engine, sched, staged_fn):
    """The schedule form an engine's `compile()` consumes: the host
    LevelSchedule for host-lowering engines (`lowers_from_host` — they
    pad/stage their own copy), else `staged_fn()` (a DeviceSchedule
    supplier, typically a cached staging).  The ONE branch every consumer
    — serving (`TriangularOperator`) and measuring (portfolio /
    preconditioner pair timing) — goes through, so what gets timed is
    always lowered the same way as what gets served."""
    if getattr(engine, "lowers_from_host", False):
        return sched
    return staged_fn()


def default_interpret() -> bool:
    """Pallas interpret mode default: on unless REPRO_PALLAS_INTERPRET=0."""
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


class Engine:
    """Base class / protocol for SpTRSV execution engines (module doc)."""

    name: str = "abstract"
    supports_batched_rhs: bool = True
    supports_pallas_backend: bool = False
    dtypes: tuple = ("float32", "float64")
    # engines whose lowering is a host-side pass (ShardedEngine pads lane
    # capacities in numpy) set this so consumers hand compile() the host
    # LevelSchedule instead of staging an unpadded DeviceSchedule the
    # engine would ignore (a wasted H2D transfer + pinned device copy)
    lowers_from_host: bool = False

    def available(self) -> bool:
        return True

    def compile(self, dsched):
        """DeviceSchedule -> callable fn(c) -> x over jnp arrays."""
        raise NotImplementedError

    def _require_dtype(self, dsched) -> None:
        """Enforce the declared dtype capability (module contract: never a
        silent fallback).  Every concrete compile() calls this first, so a
        schedule whose dtype the engine is not validated for raises — with
        the engine name and the offending dtype — instead of silently
        casting the solve down/up.

        The capability describes what the engine's kernels are validated
        for; it is not a jax-config check.  Executing a float64 schedule
        additionally requires jax x64 mode (JAX_ENABLE_X64=1) — without
        it jax itself truncates device arrays to float32 and says so with
        its own UserWarning."""
        import numpy as np
        got = np.dtype(dsched.dtype).name
        if got not in self.dtypes:
            raise ValueError(
                f"engine {self.name!r} supports dtypes "
                f"{tuple(self.dtypes)} but the schedule dtype is {got!r}; "
                f"recompile the schedule with a supported dtype or "
                f"resolve an engine that declares {got!r}")

    def cache_token(self) -> str:
        """Identity recorded in measured-mode cache keys ("which engine
        was timed").  The registry name by default; engines whose timings
        depend on more than the name must qualify it (ShardedEngine adds
        the mesh, since the same schedule measures differently per mesh).
        """
        return self.name

    def capabilities(self) -> dict:
        return {
            "name": self.name,
            "supports_batched_rhs": self.supports_batched_rhs,
            "supports_pallas_backend": self.supports_pallas_backend,
            "dtypes": list(self.dtypes),        # list: JSON round-trip stable
            "available": self.available(),
        }

    def __repr__(self) -> str:  # pragma: no cover
        return f"{type(self).__name__}(name={self.name!r})"


class ScanEngine(Engine):
    """`lax.scan` over steps — HLO size independent of step count (default)."""

    name = "scan"

    def compile(self, dsched):
        # staged_scan_fn passes the schedule leaves as jit ARGUMENTS, so
        # compiling a value-repacked schedule with unchanged tile shapes
        # (update_values) reuses the cached XLA executable
        from .levelset import staged_scan_fn
        self._require_dtype(dsched)
        return staged_scan_fn(dsched)


class UnrolledEngine(Engine):
    """Trace-time unrolled steps — bigger HLO, more fusion freedom; sensible
    after the transformation shrank the step count."""

    name = "unrolled"

    def compile(self, dsched):
        from .levelset import staged_unrolled_fn
        self._require_dtype(dsched)
        return staged_unrolled_fn(dsched)


class PallasEngine(Engine):
    """Pallas TPU kernel (interpret mode on CPU): one grid step per schedule
    step, x/carry resident in VMEM.  `interpret=None` follows the
    REPRO_PALLAS_INTERPRET env default at compile time."""

    supports_pallas_backend = True
    dtypes = ("float32",)

    def __init__(self, interpret: bool | None = None, name: str = "pallas"):
        self.name = name
        self.interpret = interpret

    def available(self) -> bool:
        try:
            import jax.experimental.pallas  # noqa: F401
        except Exception:  # pragma: no cover - env dependent
            return False
        return True

    def compile(self, dsched):
        import jax.numpy as jnp
        from ..kernels.sptrsv_level import (sptrsv_groups_pallas,
                                            sptrsv_groups_pallas_multi)
        # the kernel is validated for float32 only: a float64 schedule
        # must raise here, not silently cast (regression: the capability
        # metadata used to be declarative-only)
        self._require_dtype(dsched)
        interpret = (default_interpret() if self.interpret is None
                     else self.interpret)
        groups, n, n_carry = dsched.groups, dsched.n, dsched.n_carry
        dtype = dsched.dtype

        def fn(c):
            c = jnp.asarray(c, dtype=dtype)
            tail = (c.shape[1],) if c.ndim == 2 else ()
            c_pad = jnp.concatenate([c, jnp.zeros((1,) + tail, dtype)],
                                    axis=0)
            kern = sptrsv_groups_pallas_multi if tail else sptrsv_groups_pallas
            return kern(groups, c_pad, n=n, n_carry=n_carry,
                        interpret=interpret)

        return fn


class ShardedEngine(Engine):
    """shard_map distributed engine: lanes of each step sharded over one
    mesh axis, x replicated, ONE all_gather family per schedule step — the
    transformation's "fewer barriers" is literally fewer collectives
    (solver/distributed.py, docs/distributed.md).  Batched (n, k) RHS run
    with lanes sharded and RHS columns replicated, meeting the same
    `supports_batched_rhs` contract as the single-device engines.

    `mesh=None` (the registered default instance) lazily meshes every
    local device along `axis` at compile time.  Lowering is memoized per
    (schedule identity, mesh, axis): repeat compiles of the same schedule
    return the identical callable and never re-pad or re-stage the groups
    — the serving path pays the host-side padding exactly once.
    """

    lowers_from_host = True

    def __init__(self, mesh=None, axis: str = "model",
                 name: str = "sharded"):
        if mesh is not None:
            # fail at construction, not with a KeyError deep in lowering
            from .distributed import require_axis
            require_axis(mesh, axis)
        self.name = name
        self.mesh = mesh            # None: all local devices, resolved lazily
        self.axis = axis
        # (id(schedule), mesh, axis) -> (weakref(schedule), compiled fn);
        # the weakref guards against id() reuse after garbage collection.
        # Bounded LRU: each entry pins a padded staged schedule (device
        # memory), and the registered instance lives for the process —
        # eviction only costs a re-lowering on a later compile
        self._lowered: "collections.OrderedDict" = collections.OrderedDict()
        self._lowered_max: int = 32
        # serving-tier workers compile from multiple threads; an OrderedDict
        # mid-move_to_end/popitem must not be mutated concurrently.  Held
        # across the lowering itself so one schedule is lowered once, not
        # racing-ly re-padded by every thread that misses
        self._lowered_lock = threading.RLock()

    def available(self) -> bool:
        try:
            import jax.sharding  # noqa: F401
        except Exception:  # pragma: no cover - env dependent
            return False
        return True

    def resolve_mesh(self):
        """The engine's mesh: the constructor-pinned one, else the cached
        all-local-devices mesh along `axis`."""
        if self.mesh is not None:
            return self.mesh
        from .distributed import default_mesh
        return default_mesh(axis=self.axis)

    def cache_token(self) -> str:
        """Mesh-qualified identity: two sharded engines over different
        meshes must never share a measured-mode cache entry — collective
        costs are a function of the mesh."""
        mesh = self.resolve_mesh()
        devs = ",".join(str(d.id) for d in mesh.devices.flat)
        return f"{self.name}[{self.axis}:{devs}]"

    def compile(self, dsched):
        import weakref
        from .distributed import lower_sharded
        self._require_dtype(dsched)
        # lowering starts from the HOST schedule (padding is a numpy
        # pass); a DeviceSchedule hands it back via .host, and a bare
        # LevelSchedule is accepted directly (solve_sharded's path)
        host = getattr(dsched, "host", dsched)
        mesh = self.resolve_mesh()
        key = (id(host), mesh, self.axis)
        with self._lowered_lock:
            hit = self._lowered.get(key)
            if hit is not None and hit[0]() is host:
                self._lowered.move_to_end(key)
                return hit[1]
            fn = lower_sharded(host, mesh, axis=self.axis)
            for k in [k for k, v in self._lowered.items()
                      if v[0]() is None]:
                del self._lowered[k]                 # drop collected entries
            self._lowered[key] = (weakref.ref(host), fn)
            while len(self._lowered) > self._lowered_max:
                self._lowered.popitem(last=False)
            return fn


# -- fallback chains ----------------------------------------------------------

# engine name -> ordered degradation chain tried when the preferred engine
# is unavailable or its compile/solve raises (repro.core.resilience:
# EngineFallbackWarning on every downgrade, EngineFallbackError when the
# whole chain fails — never a silent substitution).  The scan engine is
# the terminal fallback everywhere: pure lax.scan, no Pallas, no mesh, no
# dtype restrictions — the most conservative compiled path in the repo.
_FALLBACK_CHAINS: dict[str, tuple] = {
    "pallas": ("scan",),
    "pallas-interpret": ("scan",),
    "sharded": ("scan",),
    "unrolled": ("scan",),
}


def fallback_chains() -> dict:
    """Copy of the configured name -> chain map (docs/robustness.md)."""
    return dict(_FALLBACK_CHAINS)


def set_fallback_chain(name: str, chain) -> None:
    """Configure the degradation chain for an engine name.  `chain` is an
    ordered iterable of registered engine names; an empty chain means
    "fail fast, no downgrade"."""
    _FALLBACK_CHAINS[name] = tuple(chain)


def engine_fallbacks(engine) -> tuple:
    """The resolved degradation chain for an engine: registered Engine
    instances, in order, the engine itself excluded.  Names in the chain
    that are not registered are skipped (a chain must never raise during
    resolution — it is consulted on the failure path)."""
    out = []
    for name in _FALLBACK_CHAINS.get(getattr(engine, "name", None), ()):
        eng = _REGISTRY.get(name)
        if eng is not None and eng is not engine and eng not in out:
            out.append(eng)
    return tuple(out)


# -- registry -----------------------------------------------------------------

_REGISTRY: dict[str, Engine] = {}
# register_engine's exists-check + insert must be atomic under concurrent
# registration (serving workers registering custom engines at startup)
_REGISTRY_LOCK = threading.RLock()
# bounded LRU: each retained instance pins its memoized lowerings, and a
# process sweeping many device-subset meshes must not accumulate engines
# (and their closed-over staged schedules) forever
_SHARDED_INSTANCES: collections.OrderedDict = collections.OrderedDict()
_SHARDED_INSTANCES_MAX = 8
# concurrent sharded_engine() resolutions (serving workers under mesh=)
# must not interleave OrderedDict eviction
_SHARDED_INSTANCES_LOCK = threading.RLock()


def sharded_engine(mesh=None, axis: str = "model") -> ShardedEngine:
    """Memoized ShardedEngine per (mesh, axis): `mesh=None` — or an
    explicit mesh that equals the default instance's resolved
    all-local-devices mesh — returns the registered default instance;
    other meshes share one instance each (bounded LRU).  Every call site
    (solve_sharded, TriangularOperator(mesh=...), Preconditioner(mesh=...),
    engine="sharded") therefore lands on ONE instance per distinct mesh,
    so the lowering memo is never split."""
    reg = _REGISTRY.get("sharded")
    default = reg if isinstance(reg, ShardedEngine) else None
    if default is not None and default.axis == axis and (
            mesh is None or (default.mesh is None
                             and mesh == default.resolve_mesh())):
        return default
    key = (mesh, axis)
    with _SHARDED_INSTANCES_LOCK:
        eng = _SHARDED_INSTANCES.get(key)
        if eng is None:
            eng = _SHARDED_INSTANCES[key] = ShardedEngine(mesh, axis=axis)
        _SHARDED_INSTANCES.move_to_end(key)
        while len(_SHARDED_INSTANCES) > _SHARDED_INSTANCES_MAX:
            _SHARDED_INSTANCES.popitem(last=False)
        return eng


def register_engine(engine: Engine, overwrite: bool = False) -> Engine:
    """Register an engine under `engine.name`; returns it for chaining."""
    if not isinstance(engine.name, str) or not engine.name:
        raise TypeError(f"engine must carry a non-empty string name: "
                        f"{engine!r}")
    with _REGISTRY_LOCK:
        if engine.name in _REGISTRY and not overwrite:
            raise ValueError(f"engine {engine.name!r} already registered "
                             f"(pass overwrite=True to replace)")
        _REGISTRY[engine.name] = engine
    return engine


def registered_engines() -> tuple:
    """Sorted names of every registered engine (available or not)."""
    return tuple(sorted(_REGISTRY))


def available_engines() -> tuple:
    """Sorted names of registered engines whose available() is True."""
    return tuple(name for name in registered_engines()
                 if _REGISTRY[name].available())


def engine_capabilities() -> dict:
    """name -> capability dict for every registered engine (CI smoke uses
    this to print the capability matrix)."""
    return {name: _REGISTRY[name].capabilities()
            for name in registered_engines()}


def get_engine(name: str) -> Engine:
    """Look a registered engine up by name; unknown names raise ValueError
    listing the registered options (never a silent fallback)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; registered engines: "
            f"{list(registered_engines())}") from None


def default_engine() -> Engine:
    return _REGISTRY[DEFAULT_ENGINE]


def resolve_engine(spec=None, *, mesh=None, mesh_axis: str = "model") \
        -> Engine:
    """Resolve an engine spec: None -> default, a name string -> registry
    lookup, an Engine (or anything with name + compile) passes through.

    `mesh=` (with `mesh_axis=`) resolves to the shared ShardedEngine for
    that mesh instead — the ONE place the facades' mesh option maps to an
    engine — and is mutually exclusive with an explicit spec."""
    if mesh is not None:
        if spec is not None:
            raise ValueError("pass either mesh= or engine=, not both "
                             "(mesh= implies the sharded engine)")
        return sharded_engine(mesh, mesh_axis)
    if spec is None:
        return default_engine()
    if isinstance(spec, str):
        return get_engine(spec)
    if isinstance(spec, Engine) or (hasattr(spec, "compile")
                                    and hasattr(spec, "name")):
        return spec
    raise TypeError(f"engine spec must be None, a registered name, or an "
                    f"Engine instance, got {type(spec).__name__}")


def resolve_engine_shim(spec, where: str, stacklevel: int = 3) -> Engine:
    """Legacy string-kwarg shim: same resolution as resolve_engine, but a
    bare string additionally emits a DeprecationWarning attributed to the
    caller (so CI can fail on internal use while user code keeps working).
    Resolution happens first: typos raise the ValueError naming the
    registered engines, never the deprecation notice."""
    if isinstance(spec, str):
        eng = get_engine(spec)
        warnings.warn(
            f"passing engine name strings to {where} is deprecated; pass an "
            f"Engine from repro.solver.engines (e.g. resolve_engine({spec!r}))",
            DeprecationWarning, stacklevel=stacklevel)
        return eng
    return resolve_engine(spec)


register_engine(ScanEngine())
register_engine(UnrolledEngine())
register_engine(PallasEngine(interpret=None, name="pallas"))
register_engine(PallasEngine(interpret=True, name="pallas-interpret"))
register_engine(ShardedEngine())

from .reference import solve_csr_seq, solve_transformed_seq, solve_dense
from .schedule import (LevelSchedule, WidthGroup, build_schedule,
                       schedule_for_csr, schedule_for_preamble,
                       schedule_for_transformed, validate_schedule)
from .levelset import (DeviceSchedule, to_device, solve_scan, solve_unrolled,
                       solve)
from .engines import (Engine, ScanEngine, UnrolledEngine, PallasEngine,
                      ShardedEngine, sharded_engine,
                      register_engine, resolve_engine, get_engine,
                      registered_engines, available_engines, default_engine,
                      engine_capabilities)
from .operator import (TriangularOperator, OperatorStats, matrix_fingerprint,
                       value_fingerprint, default_cache_dir, orient_lower)
from .api import sptrsv, with_unit_diagonal
from . import distributed

__all__ = [
    "solve_csr_seq", "solve_transformed_seq", "solve_dense",
    "LevelSchedule", "WidthGroup", "build_schedule", "schedule_for_csr",
    "schedule_for_preamble", "schedule_for_transformed", "validate_schedule",
    "DeviceSchedule", "to_device", "solve_scan", "solve_unrolled", "solve",
    "Engine", "ScanEngine", "UnrolledEngine", "PallasEngine",
    "ShardedEngine", "sharded_engine",
    "register_engine", "resolve_engine", "get_engine", "registered_engines",
    "available_engines", "default_engine", "engine_capabilities",
    "TriangularOperator", "OperatorStats", "matrix_fingerprint",
    "value_fingerprint", "default_cache_dir", "orient_lower",
    "sptrsv", "with_unit_diagonal",
    "distributed",
]

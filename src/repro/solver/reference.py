"""Sequential reference SpTRSV solvers (numpy) — oracles for everything else."""
from __future__ import annotations

import numpy as np

from ..sparse.csr import CSR

__all__ = ["solve_csr_seq", "solve_transformed_seq", "solve_dense"]


def solve_csr_seq(L: CSR, b: np.ndarray) -> np.ndarray:
    """Forward substitution, row by row (paper Fig. 1 Algorithm 1)."""
    n = L.n_rows
    x = np.zeros(n, dtype=np.result_type(L.data, b))
    indptr, indices, data = L.indptr, L.indices, L.data
    for i in range(n):
        lo, hi = indptr[i], indptr[i + 1]
        cols = indices[lo:hi]
        vals = data[lo:hi]
        diag = None
        s = 0.0
        for c, v in zip(cols, vals):
            if c == i:
                diag = v
            else:
                s += v * x[c]
        x[i] = (b[i] - s) / diag
    return x


def solve_transformed_seq(ts, b: np.ndarray) -> np.ndarray:
    """Solve via (A', T, d): c = (I+T)^{-1} b; forward substitution over A'.

    Uses the materialized B' when present (c = B'b SpMV), else the T-factor
    preamble (see repro.core.rewrite docstring).
    """
    c = ts.B.matvec(b) if ts.B is not None else ts.preamble(b)
    n = ts.A.n_rows
    x = np.zeros(n, dtype=np.result_type(ts.A.data, b))
    indptr, indices, data = ts.A.indptr, ts.A.indices, ts.A.data
    order = np.argsort(ts.level_of_recomputed, kind="stable")
    for i in order:
        lo, hi = indptr[i], indptr[i + 1]
        s = data[lo:hi] @ x[indices[lo:hi]] if hi > lo else 0.0
        x[i] = (c[i] - s) / ts.diag[i]
    return x


def solve_dense(L: CSR, b: np.ndarray) -> np.ndarray:
    """scipy-based oracle (dense fallback for tiny tests)."""
    import scipy.linalg
    return scipy.linalg.solve_triangular(L.to_dense(), b, lower=True)

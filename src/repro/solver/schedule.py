"""Schedule compiler: (transformed) triangular system -> bucketed ELL schedule.

DESIGN — the schedule-compiler pipeline
=======================================
(How this layer fits the transform -> compile -> engines -> operator stack
is documented in docs/architecture.md.)

The paper's testbed compiles a matrix into specialized C code; our TPU-native
analogue compiles it into a *static ELL schedule*: a sequence of fixed-shape
steps executed in order, with all cross-step dependencies resolved at compile
(build) time.  The compiler runs four vectorized passes — no per-row or
per-lane Python loops anywhere on the hot path:

1. **Lane construction** (`_build_lanes`).  Rows are ordered by (level, id)
   and expanded into *lanes*.  A row with nnz <= max_deps is one lane; a
   fatter row is split into ceil(nnz / max_deps) partial-row lanes that chain
   through a *carry slot*: leading segments accumulate partial dot products
   into the slot, the final segment adds the carry, subtracts from c and
   divides by the diagonal.  Lane dep lists are contiguous slices of the CSR
   arrays re-gathered into lane order, so all later passes address them with
   (ptr, width) pairs.  Carry-slot ids are assigned with one cumsum.

2. **Step assignment**.  Two modes:
   * *level-aligned* (`compact=False`) — the classic layout: each level
     becomes its own run of steps (split segments in distinct sub-steps,
     chunks of `chunk` lanes).  Fully vectorized with bincount/cumsum
     arithmetic; reproduces the legacy step structure bit-for-bit.
   * *dependency-aware compaction* (`compact=True`, the default) — a greedy
     list scheduler.  Each lane's earliest step is 1 + max(step of the rows
     it reads); lanes are packed into the earliest step with free capacity
     (`chunk` lanes/step), so under-full steps absorb rows from later
     levels and leading segments of split rows start as soon as *their own*
     dependencies allow — `num_steps` drops to the dependency-critical path
     instead of the level count.  The invariant "no lane reads a row
     finalized in the same step" is what makes intra-step execution order
     free (engines and the Pallas kernel exploit this).  When the level
     assignment is *tight* (level == 1 + max dep level, true for recomputed
     level sets), runs of regular levels are batch-assigned in one shot;
     only oversized (> chunk lanes) or split-row levels take the slow path.

3. **Width bucketing** (`_materialize`).  Lanes of one step are grouped into
   dependency-width classes D in `widths` (clipped to the widest real lane),
   and the schedule is materialized as one `WidthGroup` per class: arrays of
   shape (S, C_g) / (S, C_g, D_g) where C_g is the max class population over
   steps, rounded to the 8-sublane TPU tile.  Thin rows no longer pay for a
   global max_deps ELL pad — `padded_flops()` and HBM bytes scale with the
   per-class widths actually present.

4. **Tile fill**.  All ELL tiles are scattered array-at-a-time: one flat
   index expression per group fills dep_idx/dep_coef for every lane at once.

Execution model: engines run groups of a step in any (sequential) order,
then advance to the next step; `x` and the carry vector are the only state
carried across steps.  Padding lanes write the garbage slots (`n` for x,
`n_carry+1` for carries), so no masking is needed anywhere.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from ..sparse.csr import CSR
from ..sparse.levels import LevelSets

__all__ = ["WidthGroup", "LevelSchedule", "SchedValuePlan", "build_schedule",
           "repack_schedule_values", "schedule_for_csr",
           "schedule_for_transformed", "schedule_for_preamble",
           "validate_schedule", "DEFAULT_WIDTHS"]

DEFAULT_WIDTHS = (4, 8, 16, 32)


@dataclasses.dataclass(frozen=True)
class WidthGroup:
    """One dependency-width class of the schedule, stacked over all steps.

    All arrays have leading dim S (number of steps); C_g lanes per step.
      row_ids:  (S, C) int32   output row per lane; n => padding/partial lane
      dep_idx:  (S, C, D) int32 gather indices into x; padding slots hold 0
                 and are inert because their dep_coef is 0
      dep_coef: (S, C, D) float
      dinv:     (S, C) float    1/diag (0 for padding/partial lanes)
      carry_in: (S, C) int32    carry slot to add (n_carry => zero slot);
                 None when the group holds no partial-row lanes — engines
                 then skip the carry machinery entirely
      carry_out:(S, C) int32    carry slot to write (n_carry+1 => sink);
                 None together with carry_in
    A lane finalizes its row iff row_ids != n (partial lanes park at the
    padding slot), and row_ids doubles as the c gather index.
    """

    width: int
    n: int
    row_ids: np.ndarray
    dep_idx: np.ndarray
    dep_coef: np.ndarray
    dinv: np.ndarray
    carry_in: np.ndarray | None = None
    carry_out: np.ndarray | None = None

    @property
    def is_final(self) -> np.ndarray:
        """Derived, not materialized: only final lanes carry a real row id."""
        return self.row_ids != self.n

    @property
    def c_ids(self) -> np.ndarray:
        """c gather indices coincide with row_ids (padding lanes hit the
        zero slot either way) — kept as an alias, not materialized."""
        return self.row_ids

    @property
    def lanes(self) -> int:
        return int(self.row_ids.shape[1])


@dataclasses.dataclass(frozen=True)
class SchedValuePlan:
    """Value-scatter map recorded at materialization time (pattern-only).

    The lane/tile layout is a pure function of the sparsity pattern and the
    level assignment, but the mapping "matrix entry k -> ELL tile slot" is
    unrecoverable from the materialized tiles (split-row partial lanes park
    at the padding row).  Recording it lets `repack_schedule_values` refill
    `dep_coef`/`dinv` for new values on the frozen pattern without re-running
    lane construction, step assignment, or bucketing.

    nnz:        expected length of the value vector.
    ent_src:    gather from data order into packed-entry (lane) order;
                None when they coincide.
    coef_dst:   flat scatter positions into the concatenated dep-slot buffer,
                one per packed entry, in lane-entry order.
    lane_slot:  flat positions into the concatenated lane-scalar buffer,
                one per lane, in (group, step)-sorted lane order.
    lane_row:   output row per sorted lane.
    lane_final: which sorted lanes finalize their row (partial-row lanes
                get dinv 0, like the original fill).
    """

    nnz: int
    ent_src: np.ndarray | None
    coef_dst: np.ndarray
    lane_slot: np.ndarray
    lane_row: np.ndarray
    lane_final: np.ndarray


@dataclasses.dataclass(frozen=True)
class LevelSchedule:
    """Compiled ELL schedule: a tuple of WidthGroups sharing the step axis.

    groups:   one WidthGroup per dependency-width class, ordered by width.
    n:        system size; n_carry: number of carry slots (>= 1).
    num_levels: level count of the *input* level assignment (compaction may
      use fewer steps when the assignment skips levels).
    chunk / max_deps: the configured capacity caps (C_g <= chunk per class,
      D_g <= max_deps).
    compacted: whether dependency-aware step compaction ran.
    build_ms: wall-clock schedule-compile time.
    value_plan: entry->tile scatter map for pattern-frozen value repacks
      (`repack_schedule_values`); None only for schedules constructed by
      hand without `build_schedule`.
    """

    groups: tuple
    n: int
    n_carry: int
    num_levels: int
    chunk: int
    max_deps: int
    compacted: bool
    build_ms: float
    value_plan: SchedValuePlan | None = None

    @property
    def num_steps(self) -> int:
        return int(self.groups[0].row_ids.shape[0]) if self.groups else 0

    @property
    def num_groups(self) -> int:
        return len(self.groups)

    @property
    def group_widths(self) -> tuple:
        return tuple(g.width for g in self.groups)

    @property
    def dtype(self):
        return self.groups[0].dep_coef.dtype

    @property
    def dep_coef(self):
        """Widest group's coefficients (dtype/back-compat accessor)."""
        return self.groups[-1].dep_coef

    def memory_bytes(self) -> int:
        return sum(a.nbytes for g in self.groups for a in (
            g.row_ids, g.dep_idx, g.dep_coef, g.dinv, g.carry_in,
            g.carry_out) if a is not None)

    def flops(self) -> int:
        """Real FLOPs executed (2 per dep + 1 div per final lane)."""
        return int(sum(2 * (g.dep_coef != 0).sum() + g.is_final.sum()
                       for g in self.groups))

    def padded_flops(self) -> int:
        """FLOPs including padding lanes — what the hardware actually does."""
        tot = 0
        for g in self.groups:
            s, c, d = g.dep_idx.shape
            tot += 2 * s * c * d + s * c
        return int(tot)

    def lanes_per_step(self) -> np.ndarray:
        """Real (non-padding) lanes per step, summed over groups."""
        out = np.zeros(self.num_steps, dtype=np.int64)
        for g in self.groups:
            live = g.is_final
            if g.carry_out is not None:
                live = live | (g.carry_out != self.n_carry + 1)
            out += live.sum(1)
        return out


# -- small vector helpers -----------------------------------------------------

def _segment_arange(seg_lens: np.ndarray) -> np.ndarray:
    """[0..l0-1, 0..l1-1, ...] for segment lengths (vectorized)."""
    total = int(seg_lens.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    ends = np.cumsum(seg_lens)
    starts = ends - seg_lens
    return np.arange(total, dtype=np.int64) - np.repeat(starts, seg_lens)


def _segment_max(vals: np.ndarray, ptr: np.ndarray, empty: int) -> np.ndarray:
    """Per-segment max of vals over slices ptr[i]:ptr[i+1]; `empty` for
    zero-width segments."""
    nseg = len(ptr) - 1
    out = np.full(nseg, empty, dtype=np.int64)
    widths = np.diff(ptr)
    nz = np.flatnonzero(widths > 0)
    if nz.size:
        out[nz] = np.maximum.reduceat(vals, ptr[nz])
    return out


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


# -- pass 1: lane construction ------------------------------------------------

class _Lanes:
    """Vectorized lane streams (see module DESIGN §1)."""

    __slots__ = ("row", "seg", "width", "ptr", "final", "cin", "cout",
                 "ent_cols", "ent_vals", "ent_src", "lvl", "lvl_ptr",
                 "n_carry", "count", "has_splits", "nnz")

    def __init__(self, A: CSR, level_of: np.ndarray, num_levels: int,
                 max_deps: int):
        n = A.n_rows
        indptr = np.asarray(A.indptr, dtype=np.int64)
        deg = np.diff(indptr)
        rord = np.lexsort((np.arange(n), level_of))
        identity = bool(np.array_equal(rord, np.arange(n)))
        deg_o = deg if identity else deg[rord]
        self.nnz = int(indptr[-1])
        self.ent_src = None     # packed-entry order == data order
        self.has_splits = bool((deg_o > max_deps).any())
        if not self.has_splits:
            # fast path: one lane per row, dep lists stay CSR-contiguous
            self.count = n
            self.row = rord
            self.seg = np.zeros(n, dtype=np.int64)
            self.final = np.ones(n, dtype=bool)
            self.width = deg_o
            if identity:
                self.ent_cols = np.asarray(A.indices, dtype=np.int64)
                self.ent_vals = A.data
                self.ptr = indptr
            else:
                ent_gather = np.repeat(indptr[rord], deg_o) + \
                    _segment_arange(deg_o)
                self.ent_cols = A.indices[ent_gather].astype(np.int64)
                self.ent_vals = A.data[ent_gather]
                self.ent_src = ent_gather
                self.ptr = np.zeros(n + 1, dtype=np.int64)
                np.cumsum(deg_o, out=self.ptr[1:])
            self.n_carry = 1
            self.cin = np.full(n, self.n_carry, dtype=np.int64)
            self.cout = np.full(n, self.n_carry + 1, dtype=np.int64)
        else:
            nseg = np.maximum(1, -(-deg_o // max_deps))
            self.count = int(nseg.sum())
            lane_start = np.cumsum(nseg) - nseg
            final_idx = lane_start + nseg - 1
            self.row = np.repeat(rord, nseg)
            self.seg = _segment_arange(nseg)
            self.final = np.zeros(self.count, dtype=bool)
            self.final[final_idx] = True
            self.width = np.full(self.count, max_deps, dtype=np.int64)
            self.width[final_idx] = deg_o - (nseg - 1) * max_deps
            # lane dep lists are contiguous in lane order (segments tile each
            # row's CSR range consecutively): regather only if rows moved
            if identity:
                self.ent_cols = np.asarray(A.indices, dtype=np.int64)
                self.ent_vals = A.data
            else:
                ent_gather = np.repeat(indptr[rord], deg_o) + \
                    _segment_arange(deg_o)
                self.ent_cols = A.indices[ent_gather].astype(np.int64)
                self.ent_vals = A.data[ent_gather]
                self.ent_src = ent_gather
            self.ptr = np.zeros(self.count + 1, dtype=np.int64)
            np.cumsum(self.width, out=self.ptr[1:])
            # carry slots: nseg-1 per split row, chained in segment order
            # (slot ids assigned to the few non-final lanes by scatter)
            split_rows = np.flatnonzero(nseg > 1)
            cnts = nseg[split_rows] - 1
            self.n_carry = max(int(cnts.sum()), 1)
            nonfinal = np.repeat(lane_start[split_rows], cnts) + \
                _segment_arange(cnts)
            slots = np.arange(nonfinal.size, dtype=np.int64)
            self.cin = np.full(self.count, self.n_carry, dtype=np.int64)
            self.cin[nonfinal + 1] = slots
            self.cout = np.full(self.count, self.n_carry + 1, dtype=np.int64)
            self.cout[nonfinal] = slots
        self.lvl = level_of[self.row]
        self.lvl_ptr = np.searchsorted(self.lvl, np.arange(num_levels + 1))


# -- pass 2a: level-aligned step assignment (legacy layout, vectorized) -------

def _assign_level_aligned(lanes: _Lanes, num_levels: int, chunk: int):
    """Each level -> its own run of steps; split segments in distinct
    sub-steps; `chunk` lanes per step.  Pure bincount/cumsum arithmetic."""
    if lanes.count == 0:
        return np.zeros(0, dtype=np.int64), max(num_levels, 0)
    # global sort by (level, seg, row): groups are (level, seg) buckets
    order = np.lexsort((lanes.row, lanes.seg, lanes.lvl))
    glvl, gseg = lanes.lvl[order], lanes.seg[order]
    new = np.ones(lanes.count, dtype=bool)
    new[1:] = (np.diff(glvl) != 0) | (np.diff(gseg) != 0)
    gid = np.cumsum(new) - 1
    starts = np.flatnonzero(new)
    counts = np.diff(np.append(starts, lanes.count))
    rank = np.arange(lanes.count) - starts[gid]
    steps_per_grp = -(-counts // chunk)
    # per-level step totals (empty levels still get one step, like legacy)
    grp_lvl = glvl[starts]
    steps_per_level = np.zeros(num_levels, dtype=np.int64)
    np.add.at(steps_per_level, grp_lvl, steps_per_grp)
    steps_per_level = np.maximum(steps_per_level, 1)
    level_base = np.zeros(num_levels, dtype=np.int64)
    level_base[1:] = np.cumsum(steps_per_level)[:-1]
    # exclusive cumsum of group steps, reset at each level's first group
    gcum = np.zeros(len(steps_per_grp), dtype=np.int64)
    gcum[1:] = np.cumsum(steps_per_grp)[:-1]
    grp_new_lvl = np.ones(len(grp_lvl), dtype=bool)
    grp_new_lvl[1:] = np.diff(grp_lvl) != 0
    lvl_first_cum = gcum[grp_new_lvl]
    within = gcum - lvl_first_cum[np.cumsum(grp_new_lvl) - 1]
    step_sorted = level_base[glvl] + within[gid] + rank // chunk
    lane_step = np.empty(lanes.count, dtype=np.int64)
    lane_step[order] = step_sorted
    return lane_step, int(steps_per_level.sum())


# -- pass 2b: dependency-aware step compaction --------------------------------

def _levels_are_tight(A: CSR, level_of: np.ndarray) -> bool:
    """level(i) == 1 + max(level(dep)) for every row (recomputed levels)."""
    indptr = np.asarray(A.indptr, dtype=np.int64)
    m = _segment_max(level_of[A.indices], indptr, empty=-1)
    return bool(np.array_equal(level_of, m + 1))


def _assign_compact(lanes: _Lanes, A: CSR, level_of: np.ndarray,
                    num_levels: int, chunk: int):
    """Greedy dependency-aware list scheduling (module DESIGN §2).

    Fast paths exploit *tight* levels (level == 1 + max dep level): while the
    previous level landed entirely in the current frontier step, every lane
    of the next level has earliest-step exactly frontier+1, so runs of
    regular levels are batch-assigned without touching the dependency lists.
    Oversized levels and spill-recovery zones fall back to honest per-lane
    earliest-step computation with capacity backfill, which is what lets
    under-full steps absorb rows from later levels.
    """
    n = A.n_rows
    if lanes.count == 0:
        return np.zeros(0, dtype=np.int64), 0
    tight = _levels_are_tight(A, level_of)
    S_fin = np.full(n, -1, dtype=np.int64)          # step finalizing each row
    lane_step = np.zeros(lanes.count, dtype=np.int64)
    lvl_ptr = lanes.lvl_ptr
    lvl_sizes = np.diff(lvl_ptr)
    split_lane = ~lanes.final | (lanes.seg > 0)
    has_split = np.zeros(num_levels, dtype=bool)
    if lanes.has_splits:
        np.logical_or.at(has_split, lanes.lvl[split_lane], True)
    regular = (lvl_sizes <= chunk) & ~has_split
    if not tight:
        regular[:] = False      # skipped levels => always schedule honestly
    # next non-regular level at or after l (for clean-run batching)
    nxt = np.where(~regular, np.arange(num_levels), num_levels)
    nxt = np.minimum.accumulate(nxt[::-1])[::-1]
    occ = np.zeros(num_levels + 64, dtype=np.int64)

    def _ensure_occ(hi):
        nonlocal occ
        if hi >= occ.size:
            occ = np.concatenate(
                [occ, np.zeros(max(hi + 1 - occ.size, occ.size), np.int64)])

    max_step = -1
    # `uniform` <=> all rows of the previous level sit in step `max_step`
    # (then tight levels give est == max_step + 1 for every next-level lane,
    # so batch placement is *lossless*).  `stalled` <=> the last honest
    # level found no backfillable slack; batching is then merely *valid*
    # (est <= max_step + 1 always) and we stop paying for honest scans.
    uniform = True
    stalled = False
    lvl = 0
    while lvl < num_levels:
        lo = int(lvl_ptr[lvl])
        if tight and regular[lvl] and (uniform or stalled):
            # batch run lvl..end-1: one fresh step per level
            end = max(int(nxt[lvl]), lvl + 1)
            hi = int(lvl_ptr[end])
            sl = slice(lo, hi)
            base = max_step + 1 - lvl
            steps = lanes.lvl[sl] + base
            lane_step[sl] = steps
            S_fin[lanes.row[sl]] = steps        # batched lanes are all final
            _ensure_occ(end - 1 + base)
            occ[lvl + base:end + base] += lvl_sizes[lvl:end]
            max_step = end - 1 + base
            uniform = True
            lvl = end
            continue
        hi = int(lvl_ptr[lvl + 1])
        if hi == lo:
            lvl += 1
            continue
        size = hi - lo
        if tight and uniform and not has_split[lvl]:
            # oversized level, uniform est: chunked run of fresh steps
            sl = slice(lo, hi)
            steps = max_step + 1 + np.arange(size) // chunk
            lane_step[sl] = steps
            S_fin[lanes.row[sl]] = steps
            nsteps = -(-size // chunk)
            _ensure_occ(max_step + nsteps)
            occ[max_step + 1:max_step + 1 + nsteps] = chunk
            occ[max_step + nsteps] = size - (nsteps - 1) * chunk
            max_step += nsteps
            uniform = nsteps == 1
            stalled = False     # the partial tail step is fresh slack
            lvl += 1
            continue
        # honest earliest-step per lane: 1 + max step of the rows it reads
        ecols = lanes.ent_cols[lanes.ptr[lo]:lanes.ptr[hi]]
        lptr = lanes.ptr[lo:hi + 1] - lanes.ptr[lo]
        if tight:       # every lane of a tight level > 0 has deps
            est = np.maximum.reduceat(S_fin[ecols], lptr[:-1]) + 1
        else:
            est = _segment_max(S_fin[ecols], lptr, empty=-1) + 1
        sp = split_lane[lo:hi] if lanes.has_splits else None
        simple = np.flatnonzero(~sp) if sp is not None else None
        prev_max = max_step
        lvl_max = -1
        lvl_min = 1 << 60
        # vectorized capacity cascade for simple (one-segment) lanes
        e = est if simple is None else est[simple]
        if e.size:
            emin, emax = int(e.min()), int(e.max())
            if emin == emax:
                order, t = None, e
            else:
                order = np.argsort(e, kind="stable")
                t = e[order]
            _ensure_occ(emax + e.size // chunk + 2)
            while True:
                mn, mx = int(t[0]), int(t[-1])
                cnts = np.bincount(t - mn, minlength=mx - mn + 1)
                free = chunk - occ[mn:mx + 1]       # occ <= chunk invariant
                if (cnts <= free).all():
                    break
                if order is None:       # cascade may break uniformity
                    order = np.arange(e.size)
                    t = t.copy()
                rank = np.arange(t.size) - np.searchsorted(t, t)
                t[rank >= free[t - mn]] += 1
                _ensure_occ(int(t[-1]) + 1)
            occ[mn:mx + 1] += cnts
            if order is None:
                idx = slice(lo, hi) if simple is None else lo + simple
            else:
                idx = lo + (order if simple is None else simple[order])
            lane_step[idx] = t
            S_fin[lanes.row[idx]] = t
            lvl_min, lvl_max = int(t[0]), int(t[-1])
        # split-row segments: rare; place one by one, chaining steps
        if sp is not None:
            prev_row, prev_t = -1, -1
            for k in np.flatnonzero(sp):
                ln = lo + int(k)
                r = int(lanes.row[ln])
                t = int(est[k])
                if r == prev_row:
                    t = max(t, prev_t + 1)
                _ensure_occ(t + 1)
                while occ[t] >= chunk:
                    t += 1
                    _ensure_occ(t + 1)
                occ[t] += 1
                lane_step[ln] = t
                if lanes.final[ln]:
                    S_fin[r] = t
                prev_row, prev_t = r, t
                lvl_min = min(lvl_min, t)
                lvl_max = max(lvl_max, t)
        uniform = lvl_min == lvl_max and lvl_max >= max_step
        stalled = lvl_min > prev_max      # honest scan found no slack
        max_step = max(max_step, lvl_max)
        lvl += 1
    return lane_step, max_step + 1


# -- passes 3+4: width bucketing and tile materialization ---------------------

def _bucket_widths(widths, max_deps: int, wmax: int):
    """Effective bucket boundaries: configured widths clipped to the widest
    real lane, always covering it."""
    wmax = max(int(wmax), 1)
    cand = sorted({min(int(w), max_deps, wmax) for w in widths if w > 0})
    if not cand or cand[-1] < wmax:
        cand.append(wmax)
    return cand


def _materialize(lanes: _Lanes, lane_step: np.ndarray, num_steps: int,
                 diag: np.ndarray, n: int, widths, max_deps: int,
                 dtype, force_tile=None) -> tuple:
    """Fill every width group's ELL tiles in one globally vectorized pass:
    lanes are sorted once by (group, step), per-group tiles live in two
    concatenated buffers (lane scalars / dep slots) sliced into views, and
    all scatters run over the full lane / entry population at once.

    Lane capacity per step is already bounded by `chunk` upstream (step
    assignment); C_g here is just the realized per-class maximum, rounded
    to the sublane tile.  force_tile=(C, D) pins a single group to a fixed
    tile shape (the legacy chunk x max_deps layout) for apples-to-apples
    benchmarking."""
    wmax = int(lanes.width.max()) if lanes.count else 1
    if force_tile is not None:
        buckets = np.asarray([force_tile[1]], dtype=np.int64)
        gi = np.zeros(lanes.count, dtype=np.int64)
    else:
        buckets = np.asarray(_bucket_widths(widths, max_deps, wmax),
                             dtype=np.int64)
        gi = np.searchsorted(buckets, np.maximum(lanes.width, 1))
        # drop empty width classes (keep at least one)
        pop = np.bincount(gi, minlength=len(buckets))
        if (pop == 0).any() and len(buckets) > 1:
            keep = pop > 0
            if not keep.any():
                keep[0] = True
            buckets = buckets[keep]
            gi = (np.cumsum(keep) - 1)[gi]
    G = len(buckets)
    S = num_steps       # 0 only for an empty system (no lanes at all)
    dinv_of = np.zeros(n + 1, dtype=dtype)
    if n:
        dinv_of[:n] = 1.0 / np.asarray(diag, dtype=dtype)
    ent_vals = lanes.ent_vals if lanes.ent_vals.dtype == dtype \
        else lanes.ent_vals.astype(dtype)
    # one stable sort by (group, step) gives every lane its tile slot
    key = gi * S + lane_step
    order = np.argsort(key, kind="stable")
    key_s = key[order]
    cnt = np.bincount(key_s, minlength=G * S)
    if force_tile is not None:
        Cg = np.asarray([force_tile[0]], dtype=np.int64)
    else:
        Cg = np.maximum(
            8, ((cnt.reshape(G, S).max(axis=1, initial=0) + 7) // 8) * 8)
    base = np.zeros(G * S, dtype=np.int64)
    base[1:] = np.cumsum(cnt)[:-1]
    rank = np.arange(lanes.count) - base[key_s]
    gi_s = gi[order]
    flat = lane_step[order] * Cg[gi_s] + rank     # slot in (S, C_g) grid
    loff = np.zeros(G + 1, dtype=np.int64)        # lane-scalar buffer offsets
    np.cumsum(S * Cg, out=loff[1:])
    slot = loff[gi_s] + flat
    Dg = buckets
    doff = np.zeros(G + 1, dtype=np.int64)        # dep-slot buffer offsets
    np.cumsum(S * Cg * Dg, out=doff[1:])
    # lane scalars (padding: row n, dinv 0)
    row_buf = np.full(loff[-1], n, dtype=np.int32)
    dinv_buf = np.zeros(loff[-1], dtype=dtype)
    fin = lanes.final[order]
    rows = lanes.row[order]
    if lanes.has_splits and not fin.all():
        row_buf[slot] = np.where(fin, rows, n)
        dinv_buf[slot] = np.where(fin, dinv_of[rows], 0)
    else:
        row_buf[slot] = rows
        dinv_buf[slot] = dinv_of[rows]
    cin_buf = cout_buf = None
    if lanes.has_splits:
        # padding reads the always-zero slot n_carry, writes the sink
        cin_buf = np.full(loff[-1], lanes.n_carry, dtype=np.int32)
        cout_buf = np.full(loff[-1], lanes.n_carry + 1, dtype=np.int32)
        cin_buf[slot] = lanes.cin[order]
        cout_buf[slot] = lanes.cout[order]
    # dep slots (padding gathers x[0] with coef 0 — inert, and np.zeros
    # keeps the pad pages untouched)
    dep_idx_buf = np.zeros(doff[-1], dtype=np.int32)
    dep_coef_buf = np.zeros(doff[-1], dtype=dtype)
    dep_base = np.empty(lanes.count, dtype=np.int64)   # back to lane order
    dep_base[order] = doff[gi_s] + flat * Dg[gi_s]
    dst = np.repeat(dep_base, lanes.width) + \
        (np.arange(lanes.ptr[-1]) - np.repeat(lanes.ptr[:-1], lanes.width))
    dep_idx_buf[dst] = lanes.ent_cols
    dep_coef_buf[dst] = ent_vals
    plan = SchedValuePlan(nnz=lanes.nnz, ent_src=lanes.ent_src, coef_dst=dst,
                          lane_slot=slot, lane_row=rows, lane_final=fin)
    groups = []
    for g in range(G):
        C, D = int(Cg[g]), int(Dg[g])
        sl = slice(int(loff[g]), int(loff[g + 1]))
        dsl = slice(int(doff[g]), int(doff[g + 1]))
        carry_in = carry_out = None
        if cin_buf is not None:
            cin_v = cin_buf[sl]
            cout_v = cout_buf[sl]
            if not (cin_v == lanes.n_carry).all() or \
                    not (cout_v == lanes.n_carry + 1).all():
                carry_in = cin_v.reshape(S, C)
                carry_out = cout_v.reshape(S, C)
        groups.append(WidthGroup(
            width=D, n=n,
            row_ids=row_buf[sl].reshape(S, C),
            dep_idx=dep_idx_buf[dsl].reshape(S, C, D),
            dep_coef=dep_coef_buf[dsl].reshape(S, C, D),
            dinv=dinv_buf[sl].reshape(S, C),
            carry_in=carry_in,
            carry_out=carry_out))
    return tuple(groups), plan


# -- driver -------------------------------------------------------------------

def build_schedule(A: CSR, diag: np.ndarray, level_of: np.ndarray,
                   chunk: int = 256, max_deps: int = 16,
                   dtype=np.float32, compact: bool = True,
                   widths=DEFAULT_WIDTHS,
                   legacy_shape: bool = False) -> LevelSchedule:
    """Compile (A strict-lower, diag, level assignment) into a LevelSchedule.

    compact=True runs dependency-aware step compaction; widths sets the
    ELL bucket boundaries (clipped to max_deps / the widest real lane).
    legacy_shape=True reproduces the original fixed chunk x max_deps tile
    layout (one group, no compaction) — the benchmarking baseline.
    """
    t0 = time.perf_counter()
    n = A.n_rows
    num_levels = int(level_of.max()) + 1 if n else 0
    lanes = _Lanes(A, np.asarray(level_of, dtype=np.int64), num_levels,
                   max_deps)
    if compact and not legacy_shape:
        lane_step, num_steps = _assign_compact(
            lanes, A, np.asarray(level_of, dtype=np.int64), num_levels, chunk)
    else:
        lane_step, num_steps = _assign_level_aligned(lanes, num_levels, chunk)
    groups, plan = _materialize(
        lanes, lane_step, num_steps, diag, n, widths, max_deps, dtype,
        force_tile=(chunk, max_deps) if legacy_shape else None)
    build_ms = (time.perf_counter() - t0) * 1e3
    return LevelSchedule(groups=groups, n=n, n_carry=lanes.n_carry,
                         num_levels=num_levels, chunk=chunk,
                         max_deps=max_deps,
                         compacted=compact and not legacy_shape,
                         build_ms=build_ms, value_plan=plan)


def repack_schedule_values(sched: LevelSchedule, new_data: np.ndarray,
                           new_diag: np.ndarray) -> LevelSchedule:
    """Refill a schedule's numeric payload for new values on the frozen
    pattern — the value-update fast path.

    Only `dep_coef` and `dinv` change; `row_ids`/`dep_idx`/carry arrays (the
    pattern-derived structure) are shared with the input schedule, so no
    lane construction, step assignment, or width bucketing runs.  Fresh
    buffers are allocated (never mutated in place): compiled engine
    functions and staged device arrays may still reference the old ones.

    `new_data` must be in the same entry order as the matrix the schedule
    was built from (`sched.value_plan.nnz` entries); the result is bitwise
    identical to `build_schedule` on the new values.
    """
    plan = sched.value_plan
    if plan is None:
        raise ValueError(
            "schedule carries no SchedValuePlan — it was not produced by "
            "build_schedule; rebuild instead of repacking")
    vals = np.asarray(new_data)
    if vals.shape != (plan.nnz,):
        raise ValueError(
            f"repack_schedule_values: expected {plan.nnz} values for the "
            f"frozen pattern, got shape {vals.shape}")
    t0 = time.perf_counter()
    dtype = sched.dtype
    n = sched.n
    # buffer geometry reconstructed from the materialized group shapes
    lsizes = [g.row_ids.size for g in sched.groups]
    dsizes = [g.dep_idx.size for g in sched.groups]
    dinv_of = np.zeros(n + 1, dtype=dtype)
    if n:
        dinv_of[:n] = 1.0 / np.asarray(new_diag, dtype=dtype)
    ent_vals = vals if plan.ent_src is None else vals[plan.ent_src]
    if ent_vals.dtype != dtype:
        ent_vals = ent_vals.astype(dtype)
    dep_coef_buf = np.zeros(sum(dsizes), dtype=dtype)
    dep_coef_buf[plan.coef_dst] = ent_vals
    dinv_buf = np.zeros(sum(lsizes), dtype=dtype)
    if plan.lane_final.all():
        dinv_buf[plan.lane_slot] = dinv_of[plan.lane_row]
    else:
        dinv_buf[plan.lane_slot] = np.where(plan.lane_final,
                                            dinv_of[plan.lane_row], 0)
    groups = []
    lo = do = 0
    for g, ls, ds in zip(sched.groups, lsizes, dsizes):
        groups.append(dataclasses.replace(
            g, dep_coef=dep_coef_buf[do:do + ds].reshape(g.dep_coef.shape),
            dinv=dinv_buf[lo:lo + ls].reshape(g.dinv.shape)))
        lo += ls
        do += ds
    build_ms = (time.perf_counter() - t0) * 1e3
    return dataclasses.replace(sched, groups=tuple(groups), build_ms=build_ms)


def validate_schedule(sched: LevelSchedule, A: CSR, diag: np.ndarray) -> None:
    """Structural audit of a compiled schedule.  Thin shim over the full
    verifier (`repro.analysis.verify.verify_level_schedule`), kept for the
    historical call sites and tests; new code should call the verifier
    directly and keep the returned `ScheduleCertificate`.  Raises
    `ScheduleInvariantError` (a subclass of AssertionError is NOT used —
    the typed resilience taxonomy is) on violation."""
    from ..analysis.verify import verify_level_schedule
    verify_level_schedule(sched, A, diag, where="validate_schedule")


def schedule_for_csr(L: CSR, levels: LevelSets, chunk: int = 256,
                     max_deps: int = 16, dtype=np.float32,
                     compact: bool = True,
                     widths=DEFAULT_WIDTHS) -> LevelSchedule:
    """Schedule for an untransformed lower-triangular L (diag inside L)."""
    from ..sparse.csr import tril
    A = tril(L, keep_diagonal=False)
    return build_schedule(A, L.diagonal_fast(), levels.level_of,
                          chunk=chunk, max_deps=max_deps, dtype=dtype,
                          compact=compact, widths=widths)


def schedule_for_transformed(ts, assigned: bool = False, chunk: int = 256,
                             max_deps: int = 16, dtype=np.float32,
                             compact: bool = True,
                             widths=DEFAULT_WIDTHS) -> LevelSchedule:
    """Schedule for a TransformedSystem (A', d) — preamble handled separately."""
    lof = ts.level_of_assigned if assigned else ts.level_of_recomputed
    return build_schedule(ts.A, ts.diag, lof, chunk=chunk, max_deps=max_deps,
                          dtype=dtype, compact=compact, widths=widths)


def schedule_for_preamble(ts, chunk: int = 256, max_deps: int = 16,
                          dtype=np.float32, compact: bool = True,
                          widths=DEFAULT_WIDTHS):
    """The b-preamble c = (I+T)^{-1} b[src] is ITSELF a unit-diagonal
    triangular system over entities — so it runs through the same
    level-scheduled engines/kernels as the main solve.

    Entity ids are not topologically ordered (aux ids exceed the row ids
    they feed), so entities are renumbered by (src, id) — strictly
    topological because every reference targets a smaller source row.

    Returns (schedule, src_sorted, row_pos): the schedule solves
    (I+T') c' = b[src_sorted]; c[i] = c'[row_pos[i]] for original rows i.
    Returns (None, None, None) for identity preambles.
    """
    if ts.T.nnz == 0:
        return None, None, None
    from ..sparse.csr import from_coo
    from ..sparse.levels import build_levels
    from ..core.transform import _with_diag
    T, src = ts.T, ts.src
    n_ent = T.n_rows
    perm = np.lexsort((np.arange(n_ent), src))       # old id -> rank by src
    inv = np.empty(n_ent, dtype=np.int64)
    inv[perm] = np.arange(n_ent)
    rows_old = np.repeat(np.arange(n_ent), T.row_nnz())
    T2 = from_coo(inv[rows_old], inv[T.indices], T.data, (n_ent, n_ent))
    lv = build_levels(_with_diag(T2))
    sched = build_schedule(T2, np.ones(n_ent), lv.level_of, chunk=chunk,
                           max_deps=max_deps, dtype=dtype, compact=compact,
                           widths=widths)
    # Compose the (pattern-only) T -> T2 renumbering permutation into the
    # value plan, so a pattern-frozen repack consumes T.data directly.  The
    # from_coo above mirrors its own lexsort; duplicate (row, col) pairs in
    # T would be value-summed by it (none of the shipped strategies produce
    # them) — the equality check drops the plan rather than risk a wrong
    # repack, and callers fall back to rebuilding the preamble schedule.
    t2_perm = np.lexsort((inv[T.indices], inv[rows_old]))
    plan = sched.value_plan
    if T2.nnz == T.nnz and plan is not None \
            and np.array_equal(T2.data, T.data[t2_perm]):
        ent_src = t2_perm if plan.ent_src is None else t2_perm[plan.ent_src]
        plan = dataclasses.replace(plan, nnz=T.nnz, ent_src=ent_src)
    else:
        plan = None
    sched = dataclasses.replace(sched, value_plan=plan)
    return sched, src[perm], inv[:ts.A.n_rows]

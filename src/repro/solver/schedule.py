"""ELL-packed level schedule — the execution form of a (transformed) system.

The paper's testbed compiles a matrix into specialized C code; our TPU-native
analogue compiles it into a *static ELL schedule* (DESIGN.md §3): the solve is
a sequence of fixed-shape steps, each handling up to `chunk` rows of ONE level
padded to `chunk` rows x `max_deps` dependency slots.  Levels bigger than
`chunk` are split into several steps; a thin level still occupies a whole step
— so the step count (and on TPU the sequential-scan length / per-level
collective count) is exactly what the graph transformation minimizes.

Row splitting: rows with more dependencies than `max_deps` are split into
multiple *partial rows* within the same step group: the leading segments
accumulate partial dot products into a carry slot, the final segment adds the
carry, subtracts from c and divides.  This bounds the ELL pad width (VMEM
tile width) regardless of how fat the transformation made a row.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..sparse.csr import CSR
from ..sparse.levels import LevelSets

__all__ = ["LevelSchedule", "build_schedule", "schedule_for_csr",
           "schedule_for_transformed"]


@dataclasses.dataclass(frozen=True)
class LevelSchedule:
    """Static ELL schedule (numpy arrays; solver layers convert to jnp).

    All step arrays have leading dim S (number of steps).
      row_ids:  (S, C) int32   output row per lane; n => padding lane
      dep_idx:  (S, C, D) int32 gather indices into x (n => zero slot)
      dep_coef: (S, C, D) float32/float64
      dinv:     (S, C) float    1/diag for the row (0 for padding/partial)
      carry_in: (S, C) int32    carry slot to add (n_carry => zero slot)
      carry_out:(S, C) int32    carry slot to write (n_carry+1 => sink;
                                 the zero slot is never written)
      c_ids:    (S, C) int32    which c entry feeds the row (n => 0)
      is_final: (S, C) bool     lane finalizes a row (divides and scatters)
    level_ptr: (num_levels+1,) step offsets per level — steps of one level are
      independent; steps of different levels are ordered (barrier between).
    """

    row_ids: np.ndarray
    dep_idx: np.ndarray
    dep_coef: np.ndarray
    dinv: np.ndarray
    carry_in: np.ndarray
    carry_out: np.ndarray
    c_ids: np.ndarray
    is_final: np.ndarray
    level_ptr: np.ndarray
    n: int
    n_carry: int

    @property
    def num_steps(self) -> int:
        return int(self.row_ids.shape[0])

    @property
    def chunk(self) -> int:
        return int(self.row_ids.shape[1])

    @property
    def max_deps(self) -> int:
        return int(self.dep_idx.shape[2])

    @property
    def num_levels(self) -> int:
        return int(self.level_ptr.shape[0] - 1)

    def memory_bytes(self) -> int:
        return sum(a.nbytes for a in (
            self.row_ids, self.dep_idx, self.dep_coef, self.dinv,
            self.carry_in, self.carry_out, self.c_ids, self.is_final))

    def flops(self) -> int:
        """Real FLOPs executed (2 per dep + 1 div per final lane)."""
        return int(2 * (self.dep_coef != 0).sum() + self.is_final.sum())

    def padded_flops(self) -> int:
        """FLOPs including padding lanes — what the hardware actually does."""
        s, c, d = self.dep_idx.shape
        return int(2 * s * c * d + s * c)


def build_schedule(A: CSR, diag: np.ndarray, level_of: np.ndarray,
                   chunk: int = 256, max_deps: int = 16,
                   dtype=np.float32) -> LevelSchedule:
    """Pack (A strict-lower, diag, level assignment) into a LevelSchedule."""
    n = A.n_rows
    num_levels = int(level_of.max()) + 1 if n else 0
    order = np.lexsort((np.arange(n), level_of))
    indptr, indices, data = A.indptr, A.indices, A.data
    deg = np.diff(indptr)

    # lane streams per level
    step_rows: list[np.ndarray] = []
    level_ptr = [0]
    carry_next = 0
    lane_rows: list[int] = []
    lane_deps: list[tuple[int, int]] = []  # (lo, hi) into A arrays
    lane_carry_in: list[int] = []
    lane_carry_out: list[int] = []
    lane_final: list[bool] = []
    lanes_per_level: list[int] = []

    pos = 0
    for lvl in range(num_levels):
        lanes_start = len(lane_rows)
        while pos < n and level_of[order[pos]] == lvl:
            i = int(order[pos]); pos += 1
            lo, hi = int(indptr[i]), int(indptr[i + 1])
            nseg = max(1, -(-(hi - lo) // max_deps))
            if nseg == 1:
                lane_rows.append(i)
                lane_deps.append((lo, hi))
                lane_carry_in.append(-1)
                lane_carry_out.append(-1)
                lane_final.append(True)
            else:
                # partial-row split: segments chain through a carry slot
                prev_c = -1
                for s in range(nseg):
                    a = lo + s * max_deps
                    b = min(lo + (s + 1) * max_deps, hi)
                    last = s == nseg - 1
                    lane_rows.append(i)
                    lane_deps.append((a, b))
                    lane_carry_in.append(prev_c)
                    if last:
                        lane_carry_out.append(-1)
                    else:
                        lane_carry_out.append(carry_next)
                        prev_c = carry_next
                        carry_next += 1
                    lane_final.append(last)
        lanes_per_level.append(len(lane_rows) - lanes_start)

    # NOTE: partial-row segments of one row are ordered; placing them in the
    # same level would race.  We serialize them by assigning segment s of a
    # row to sub-step ceil position: here simply put every segment in its own
    # step batch within the level (steps within a level run in order in the
    # scan — only cross-level ordering is semantically required, so intra-
    # level sequencing of segments is free).
    S_list = []
    total_lanes = len(lane_rows)
    lane_ptr = 0
    n_carry = max(carry_next, 1)
    for lvl in range(num_levels):
        cnt = lanes_per_level[lvl]
        # segments of the same row must land in increasing steps; lanes were
        # appended in segment order, and chunk-sequential packing preserves
        # in-level lane order across steps only if a row's segments are in
        # different steps.  Force that by spacing: pack lanes round-robin.
        lanes = list(range(lane_ptr, lane_ptr + cnt))
        lane_ptr += cnt
        # group lanes: same-row segments must be in distinct, increasing steps
        by_row_seen: dict[int, int] = {}
        buckets: list[list[int]] = []
        for ln in lanes:
            r = lane_rows[ln]
            k = by_row_seen.get(r, 0)
            by_row_seen[r] = k + 1
            while len(buckets) <= k:
                buckets.append([])
            buckets[k].append(ln)
        lvl_steps: list[list[int]] = []
        for bucket in buckets:
            for s in range(0, len(bucket), chunk):
                lvl_steps.append(bucket[s:s + chunk])
        if not lvl_steps:
            lvl_steps = [[]]
        S_list.append(lvl_steps)

    S = sum(len(x) for x in S_list)
    C, D = chunk, max_deps
    row_ids = np.full((S, C), n, dtype=np.int32)
    dep_idx = np.full((S, C, D), n, dtype=np.int32)
    dep_coef = np.zeros((S, C, D), dtype=dtype)
    dinv = np.zeros((S, C), dtype=dtype)
    carry_in = np.full((S, C), n_carry, dtype=np.int32)      # zero slot
    carry_out = np.full((S, C), n_carry + 1, dtype=np.int32)  # write sink
    c_ids = np.full((S, C), n, dtype=np.int32)
    is_final = np.zeros((S, C), dtype=bool)

    level_ptr = np.zeros(num_levels + 1, dtype=np.int64)
    si = 0
    for lvl in range(num_levels):
        for lanes in S_list[lvl]:
            for lane_pos, ln in enumerate(lanes):
                i = lane_rows[ln]
                lo, hi = lane_deps[ln]
                k = hi - lo
                dep_idx[si, lane_pos, :k] = indices[lo:hi]
                dep_coef[si, lane_pos, :k] = data[lo:hi]
                if lane_carry_in[ln] >= 0:
                    carry_in[si, lane_pos] = lane_carry_in[ln]
                if lane_carry_out[ln] >= 0:
                    carry_out[si, lane_pos] = lane_carry_out[ln]
                if lane_final[ln]:
                    # only final segments scatter into x; partial segments
                    # keep row_ids at the padding slot and write their carry
                    row_ids[si, lane_pos] = i
                    is_final[si, lane_pos] = True
                    dinv[si, lane_pos] = 1.0 / diag[i]
                    c_ids[si, lane_pos] = i
            si += 1
        level_ptr[lvl + 1] = si
    assert si == S
    return LevelSchedule(row_ids=row_ids, dep_idx=dep_idx, dep_coef=dep_coef,
                         dinv=dinv.astype(dtype), carry_in=carry_in,
                         carry_out=carry_out, c_ids=c_ids, is_final=is_final,
                         level_ptr=level_ptr, n=n, n_carry=n_carry)


def schedule_for_csr(L: CSR, levels: LevelSets, chunk: int = 256,
                     max_deps: int = 16, dtype=np.float32) -> LevelSchedule:
    """Schedule for an untransformed lower-triangular L (diag inside L)."""
    from ..sparse.csr import tril
    A = tril(L, keep_diagonal=False)
    return build_schedule(A, L.diagonal_fast(), levels.level_of,
                          chunk=chunk, max_deps=max_deps, dtype=dtype)


def schedule_for_transformed(ts, assigned: bool = False, chunk: int = 256,
                             max_deps: int = 16,
                             dtype=np.float32) -> LevelSchedule:
    """Schedule for a TransformedSystem (A', d) — preamble handled separately."""
    lof = ts.level_of_assigned if assigned else ts.level_of_recomputed
    return build_schedule(ts.A, ts.diag, lof, chunk=chunk, max_deps=max_deps,
                          dtype=dtype)


def schedule_for_preamble(ts, chunk: int = 256, max_deps: int = 16,
                          dtype=np.float32):
    """The b-preamble c = (I+T)^{-1} b[src] is ITSELF a unit-diagonal
    triangular system over entities — so it runs through the same
    level-scheduled engines/kernels as the main solve.

    Entity ids are not topologically ordered (aux ids exceed the row ids
    they feed), so entities are renumbered by (src, id) — strictly
    topological because every reference targets a smaller source row.

    Returns (schedule, src_sorted, row_pos): the schedule solves
    (I+T') c' = b[src_sorted]; c[i] = c'[row_pos[i]] for original rows i.
    Returns (None, None, None) for identity preambles.
    """
    if ts.T.nnz == 0:
        return None, None, None
    from ..sparse.csr import from_coo
    from ..sparse.levels import build_levels
    from ..core.transform import _with_diag
    T, src = ts.T, ts.src
    n_ent = T.n_rows
    perm = np.lexsort((np.arange(n_ent), src))       # old id -> rank by src
    inv = np.empty(n_ent, dtype=np.int64)
    inv[perm] = np.arange(n_ent)
    rows_old = np.repeat(np.arange(n_ent), T.row_nnz())
    T2 = from_coo(inv[rows_old], inv[T.indices], T.data, (n_ent, n_ent))
    lv = build_levels(_with_diag(T2))
    sched = build_schedule(T2, np.ones(n_ent), lv.level_of, chunk=chunk,
                           max_deps=max_deps, dtype=dtype)
    return sched, src[perm], inv[:ts.A.n_rows]

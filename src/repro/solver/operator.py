"""TriangularOperator: cached, auto-tuned, end-to-end SpTRSV facade.

The serving-path entry point (docs/architecture.md):

    op = TriangularOperator.from_csr(L, tune="auto")   # tune + compile once
    x  = op.solve(b)                                   # b: (n,) or (n, k)

`from_csr` runs the strategy-portfolio auto-tuner (repro.core.portfolio),
compiles the winning transform into a width-bucketed LevelSchedule, and
caches the whole artifact — transform, schedule, ranked tuner report —
keyed by a matrix fingerprint, in memory and persistently on disk
(REPRO_CACHE_DIR or ~/.cache/repro-sptrsv).  Repeat construction for the
same matrix + configuration is a cache hit: no transform, no tuning, no
schedule compile.

`solve` accepts a single right-hand side or a batched (n, k) block — the
engines and the Pallas kernel stream the schedule once for all k columns,
so one transformed matrix amortizes over many b's (the serving scenario).
Device math runs in the schedule dtype (float32 by default); full float64
accuracy is recovered by iterative refinement against the ORIGINAL matrix
(r = b - Lx in float64 on host, correct with another device solve), which
converges in 2-3 rounds for the diagonally-dominant systems here and makes
the operator match the sequential reference to ~1e-10 relative.

Per-solve stats (wall time, refinement rounds, residuals) are recorded on
`op.stats`.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import os
import pickle
import time
from pathlib import Path

import numpy as np

from ..sparse.csr import CSR

__all__ = ["TriangularOperator", "OperatorStats", "matrix_fingerprint",
           "default_cache_dir"]

CACHE_VERSION = 1


def default_cache_dir() -> Path:
    """REPRO_CACHE_DIR env override, else ~/.cache/repro-sptrsv."""
    env = os.environ.get("REPRO_CACHE_DIR")
    if env:
        return Path(env)
    return Path(os.path.expanduser("~/.cache")) / "repro-sptrsv"


def matrix_fingerprint(L: CSR, include_values: bool = True) -> str:
    """Stable hash of a CSR matrix: shape + pattern (+ values by default).

    Values are hashed because the compiled schedule bakes coefficients into
    its ELL tiles; pass include_values=False for a pattern-only key (e.g.
    reusing a tuner *decision* across numerically-refreshed factors).
    """
    h = hashlib.sha256()
    h.update(repr((CACHE_VERSION, L.shape)).encode())
    h.update(np.ascontiguousarray(L.indptr).tobytes())
    h.update(np.ascontiguousarray(L.indices).tobytes())
    if include_values:
        h.update(np.ascontiguousarray(L.data).tobytes())
    return h.hexdigest()[:32]


@dataclasses.dataclass
class OperatorStats:
    """Mutable per-operator counters, updated by every solve()."""

    solves: int = 0
    rhs_columns: int = 0
    refine_rounds: int = 0
    total_solve_ms: float = 0.0
    last_solve_ms: float = 0.0
    last_residual: float = float("nan")
    cache_source: str = "built"        # "built" | "memory" | "disk"
    tune_ms: float = 0.0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class TriangularOperator:
    """Compiled triangular-solve operator for one matrix (see module doc)."""

    # bounded LRU: payloads hold full transforms + ELL tiles (MB-scale per
    # large matrix), so a long-lived server over many matrices must not
    # accumulate them forever; overflow falls back to the disk cache
    _memory_cache_max: int = 16
    _memory_cache = collections.OrderedDict()

    @classmethod
    def _memory_get(cls, key: str):
        payload = cls._memory_cache.get(key)
        if payload is not None:
            cls._memory_cache.move_to_end(key)
        return payload

    @classmethod
    def _memory_put(cls, key: str, payload: dict) -> None:
        cls._memory_cache[key] = payload
        cls._memory_cache.move_to_end(key)
        while len(cls._memory_cache) > cls._memory_cache_max:
            cls._memory_cache.popitem(last=False)

    def __init__(self, L: CSR, payload: dict, cache_source: str):
        self._L = L
        self._ts = payload["ts"]
        self._sched = payload["sched"]
        self.report = payload.get("report")        # slim PortfolioReport|None
        self.strategy = payload["strategy"]        # winning strategy label
        self.engine = payload["config"]["engine"]
        self._dsched = None
        self._jitted = {}
        self.stats = OperatorStats(cache_source=cache_source,
                                   tune_ms=payload.get("tune_ms", 0.0))

    # -- construction ---------------------------------------------------------
    @classmethod
    def from_csr(cls, L: CSR, tune="auto", *, chunk: int = 256,
                 max_deps: int = 16, dtype=np.float32, engine: str = "scan",
                 cache: bool = True, cache_dir=None, portfolio=None,
                 cost_model=None,
                 measure_top_k: int = 0) -> "TriangularOperator":
        """Build (or load) the operator for lower-triangular L.

        tune:   "auto" — run the StrategyPortfolio tuner and take its pick;
                a stable strategy name ("avgLevelCost", ...) or a Strategy
                instance — skip tuning and use that strategy as-is.
        cache:  look up / persist the compiled artifact (memory + disk,
                keyed by matrix fingerprint and configuration).
        cost_model: tuner scoring constants (a portfolio CostModel, e.g.
                CostModel.cpu() when the scan engine serves on CPU); part
                of the cache key.  tune="auto" only.
        portfolio: a fully custom StrategyPortfolio (tune="auto" only);
                cost_model/measure_top_k are forwarded when constructing
                the default one.  A custom portfolio's configuration is not
                part of the cache key, so passing one disables caching for
                that build.
        """
        import dataclasses as _dc
        from ..core.portfolio import StrategyPortfolio, make_strategy
        from ..core.strategies import strategy_label
        from .schedule import schedule_for_transformed

        cache = cache and portfolio is None
        tune_key = "auto" if tune == "auto" else \
            strategy_label(make_strategy(tune))
        cfg = {"tune": tune_key, "chunk": chunk, "max_deps": max_deps,
               "dtype": np.dtype(dtype).name, "engine": engine,
               "measure_top_k": measure_top_k,
               "cost_model": (None if cost_model is None
                              else sorted(_dc.asdict(cost_model).items()))}
        key = matrix_fingerprint(L) + "-" + hashlib.sha256(
            repr(sorted(cfg.items())).encode()).hexdigest()[:16]

        if cache:
            payload = cls._memory_get(key)
            if payload is not None:
                return cls(L, payload, cache_source="memory")
            payload = cls._disk_load(key, cache_dir)
            if payload is not None:
                cls._memory_put(key, payload)
                return cls(L, payload, cache_source="disk")

        t0 = time.perf_counter()
        report = None
        if tune == "auto":
            tuner = portfolio if portfolio is not None else StrategyPortfolio(
                chunk=chunk, max_deps=max_deps, dtype=dtype,
                cost_model=cost_model, measure_top_k=measure_top_k)
            report = tuner.tune(L)
            best = report.best
            ts, sched, label = best.ts, best.sched, best.label
            report = report.slim()      # candidates keep stats, drop arrays
        else:
            strat = make_strategy(tune)
            label = strategy_label(strat)
            from ..core.transform import transform
            ts = transform(L, strat, validate=False, codegen=False)
            sched = schedule_for_transformed(ts, chunk=chunk,
                                             max_deps=max_deps, dtype=dtype)
        payload = {"version": CACHE_VERSION, "strategy": label, "ts": ts,
                   "sched": sched, "report": report, "config": cfg,
                   "tune_ms": (time.perf_counter() - t0) * 1e3}
        if cache:
            cls._memory_put(key, payload)
            cls._disk_store(key, payload, cache_dir)
        return cls(L, payload, cache_source="built")

    @staticmethod
    def _cache_path(key: str, cache_dir) -> Path:
        d = Path(cache_dir) if cache_dir is not None else default_cache_dir()
        return d / f"op-{key}.pkl"

    @classmethod
    def _disk_load(cls, key: str, cache_dir) -> dict | None:
        path = cls._cache_path(key, cache_dir)
        if not path.exists():
            return None
        try:
            with open(path, "rb") as f:
                payload = pickle.load(f)
            if payload.get("version") != CACHE_VERSION:
                return None
            return payload
        except Exception:
            return None     # corrupt cache entries are silently rebuilt

    @classmethod
    def _disk_store(cls, key: str, payload: dict, cache_dir) -> None:
        path = cls._cache_path(key, cache_dir)
        try:
            path.parent.mkdir(parents=True, exist_ok=True)
            tmp = path.with_suffix(".tmp")
            with open(tmp, "wb") as f:
                pickle.dump(payload, f)
            os.replace(tmp, path)       # atomic vs concurrent builders
        except OSError:
            pass        # read-only cache dir: operator still works, unseeded

    @classmethod
    def clear_memory_cache(cls) -> None:
        cls._memory_cache.clear()

    # -- solving --------------------------------------------------------------
    @property
    def n(self) -> int:
        return self._L.n_rows

    @property
    def schedule(self):
        return self._sched

    @property
    def transformed(self):
        return self._ts

    def _staged(self):
        if self._dsched is None:
            from .levelset import to_device
            self._dsched = to_device(self._sched)
        return self._dsched

    def _device_solve(self, c: np.ndarray, engine: str) -> np.ndarray:
        """One schedule execution in the schedule dtype."""
        import jax
        import jax.numpy as jnp
        ds = self._staged()      # staged once, reused by every solve/refine
        if engine == "pallas":
            from ..kernels import ops
            return ops.sptrsv_solve(self._sched, c, dsched=ds)
        from .levelset import solve_scan, solve_unrolled
        fn = self._jitted.get(engine)
        if fn is None:
            raw = solve_scan if engine == "scan" else solve_unrolled
            fn = jax.jit(lambda cc: raw(ds, cc))
            self._jitted[engine] = fn
        return np.asarray(fn(jnp.asarray(c, dtype=ds.dtype)))

    def solve(self, b: np.ndarray, *, engine: str | None = None,
              refine_tol: float = 1e-10, max_refine: int = 6) -> np.ndarray:
        """Solve L x = b for b of shape (n,) or batched (n, k).

        Runs the preamble + compiled schedule in the schedule dtype, then
        iteratively refines in float64 against the original L until the
        relative residual max|b - Lx| / max(1, max|b|) <= refine_tol (or
        max_refine correction rounds).  Set max_refine=0 for the raw device
        output with no residual computed (stats.last_residual stays NaN) —
        the cheapest per-solve path.  Returns float64, same leading shape
        as b.
        """
        engine = self.engine if engine is None else engine
        b = np.asarray(b, dtype=np.float64)
        if b.ndim not in (1, 2) or b.shape[0] != self.n:
            raise ValueError(f"b must be ({self.n},) or ({self.n}, k), "
                             f"got {b.shape}")
        t0 = time.perf_counter()
        x = self._device_solve(self._ts.preamble(b), engine) \
            .astype(np.float64)
        bscale = max(1.0, float(np.abs(b).max(initial=0.0)))
        resid = float("nan")
        rounds = 0
        while max_refine > 0:       # refinement off => skip the host matvec
            r = b - self._L.matvec(x)
            resid = float(np.abs(r).max(initial=0.0)) / bscale
            if resid <= refine_tol or rounds >= max_refine:
                break
            x = x + self._device_solve(self._ts.preamble(r), engine) \
                .astype(np.float64)
            rounds += 1
        ms = (time.perf_counter() - t0) * 1e3
        st = self.stats
        st.solves += 1
        st.rhs_columns += 1 if b.ndim == 1 else b.shape[1]
        st.refine_rounds += rounds
        st.total_solve_ms += ms
        st.last_solve_ms = ms
        st.last_residual = resid
        return x

    def __repr__(self) -> str:  # pragma: no cover
        return (f"TriangularOperator(n={self.n}, strategy={self.strategy!r}, "
                f"steps={self._sched.num_steps}, engine={self.engine!r}, "
                f"cache={self.stats.cache_source})")

"""TriangularOperator: cached, auto-tuned, end-to-end SpTRSV facade.

The serving-path entry point (docs/architecture.md):

    op = TriangularOperator.from_csr(L, tune="auto")   # tune + compile once
    x  = op.solve(b)                                   # b: (n,) or (n, k)

`from_csr` runs the strategy-portfolio auto-tuner (repro.core.portfolio),
compiles the winning transform into a width-bucketed LevelSchedule, and
caches the whole artifact — transform, schedule, ranked tuner report —
in memory and persistently on disk (REPRO_CACHE_DIR or
~/.cache/repro-sptrsv).  Repeat construction for the same matrix +
configuration is a cache hit: no transform, no tuning, no schedule compile.

The cache key is split into a PATTERN fingerprint and a VALUE fingerprint
(`op-{pattern}-{config}-{values}.pkl`): the pattern part keys everything
derived from the sparsity structure alone (level analysis, the
transformation's replay plan, the tuner pick, tile layout), the value part
only the numeric payload.  A `from_csr` for a matrix whose pattern+config
matches a cached artifact but whose values differ derives the new payload
through the refactorization fast path (replay_transform +
repack_schedule_values — `stats.cache_source == "pattern"`) instead of
re-tuning.  `op.update_values(new_L)` is the in-place form for
time-stepping loops; a changed pattern raises `PatternMismatchError`
(docs/refactorization.md).

All four triangular sweeps share the one lower-triangular pipeline:
`side="lower"|"upper"` selects the stored triangle, `transpose=True` solves
with its transpose (the backward sweep of an ILU/IC preconditioner).  The
effective system is always reduced to a lower-triangular one — transposing
and/or reversing both axes (sparse.csr.reverse_both) — so every strategy,
the width-bucketed schedule compiler, and every registered engine work for
both sweeps with no kernel changes.  `op.transposed()` returns the adjoint
operator (same matrix, flipped sweep): it is the backward pass of the
forward solve, which is what `repro.solver.api.sptrsv` builds its
`jax.custom_vjp` on.  Orientation bits are part of the cache key.

Engines resolve through the repro.solver.engines registry: `engine=` takes
a registered name, an Engine instance, or None for the default scan engine;
unknown names raise with the registered options.

`solve` accepts a single right-hand side or a batched (n, k) block — the
engines and the Pallas kernel stream the schedule once for all k columns,
so one transformed matrix amortizes over many b's (the serving scenario).
Device math runs in the schedule dtype (float32 by default); full float64
accuracy is recovered by iterative refinement against the ORIGINAL matrix
(r = b - Lx in float64 on host, correct with another device solve), which
converges in 2-3 rounds for the diagonally-dominant systems here and makes
the operator match the sequential reference to ~1e-10 relative.

Per-solve stats (wall time, refinement rounds, residuals) are recorded on
`op.stats`.

Resilience (docs/robustness.md)
===============================
Every host `solve()` runs under a `SolveGuard` (repro.core.resilience):
non-finite right-hand sides raise a typed `NumericalHealthError`; a
non-finite (or, under `health="strict"`, inaccurate) solution is raised,
repaired by sanitize-and-refine, or replaced by the guaranteed host
reference solve per the resolved `HealthPolicy` (`health=` argument, else
the `REPRO_HEALTH_CHECKS` environment default).  When the preferred
engine's compile or solve fails — Pallas unavailable, dtype capability
rejected, mesh devices lost — the solve walks the registry's fallback
chain (`engines.engine_fallbacks`, e.g. pallas -> scan); every downgrade
is recorded in `OperatorStats` and surfaced as an `EngineFallbackWarning`,
and a chain with no survivor raises `EngineFallbackError` naming each
attempt.  `device_solve_fn` is the raw traced pipeline and is NOT
guarded — host-side checks cannot observe jitted applications (the
jit-native Krylov drivers carry their own in-loop breakdown detection).

Disk artifacts are crash- and concurrency-safe: writes go to a uniquely
named temporary sibling and publish via atomic `os.replace`, so a reader
can never observe a torn pickle; entries that still fail to load (corrupt
bytes, stale CACHE_VERSION) are quarantined to a `.bad/` sibling
directory — preserved for diagnosis, never silently deleted — with a
`CacheQuarantineWarning`, and the artifact is rebuilt.
"""
from __future__ import annotations

import collections
import hashlib
import os
import pickle
import threading
import time
import uuid
import warnings
from pathlib import Path

import numpy as np

from ..obs import trace as _obs
from ..obs.metrics import MetricsRegistry
from ..sparse.csr import CSR, reverse_both

__all__ = ["TriangularOperator", "OperatorStats", "matrix_fingerprint",
           "value_fingerprint", "default_cache_dir", "orient_lower",
           "compose_sweep_fn"]

# 3: cache key split into pattern/config/value segments; payloads carry the
# transform replay plan + schedule value plans for pattern-frozen derivation
# (version-2 artifacts quarantine cleanly through the stale-version path)
CACHE_VERSION = 3


def orient_lower(A: CSR, side: str, transpose: bool) -> tuple:
    """Reduce any triangular solve to a lower-triangular one.

    Returns (L_eff, reversed): solve(A, b, side, transpose) ==
    unreverse(solve_lower(L_eff, reverse(b))), where reverse flips axis 0
    iff `reversed`.  The four sweeps:

      (lower, False)  L x  = b   ->  L itself
      (upper, True)   U'x  = b   ->  U' (already lower)
      (lower, True)   L'x  = b   ->  P L' P, rows/cols reversed
      (upper, False)  U x  = b   ->  P U  P, rows/cols reversed

    (P is the reversal permutation; PMP of an upper-triangular M is lower-
    triangular with the identical dependency DAG, so level sets, transform
    strategies, and step compaction all apply unchanged.)
    """
    if side not in ("lower", "upper"):
        raise ValueError(f"side must be 'lower' or 'upper', got {side!r}")
    lower = side == "lower"
    if lower and not transpose:
        return A, False
    if not lower and transpose:
        return A.transpose(), False
    if lower:                       # lower, transpose
        return reverse_both(A.transpose()), True
    return reverse_both(A), True    # upper, no transpose


def compose_sweep_fn(main_fn, schedule_dtype, pre_fn, src, row_pos,
                     reversed_: bool):
    """Compose one triangular sweep as a pure JAX callable: axis reversal
    (transpose/upper orientations) -> T-factor preamble -> main schedule
    -> un-reverse, in the schedule dtype, cast back to the input's dtype.

    The ONE definition of the served device pipeline: both
    `TriangularOperator.device_solve_fn` (production applications) and
    `Preconditioner._measure_pair` (measured pair tuning) build on it, so
    the tuner always times exactly the computation it selects for.
    `pre_fn`/`src`/`row_pos` are None for identity preambles.
    """
    import jax.numpy as jnp

    def fn(v):
        out_dtype = v.dtype
        c = jnp.asarray(v, dtype=schedule_dtype)
        if reversed_:
            c = jnp.flip(c, axis=0)
        if pre_fn is not None:
            c = pre_fn(c[src])[row_pos]
        x = main_fn(c)
        if reversed_:
            x = jnp.flip(x, axis=0)
        return x.astype(out_dtype)

    return fn


def default_cache_dir() -> Path:
    """REPRO_CACHE_DIR env override, else ~/.cache/repro-sptrsv."""
    env = os.environ.get("REPRO_CACHE_DIR")
    if env:
        return Path(env)
    return Path(os.path.expanduser("~/.cache")) / "repro-sptrsv"


def matrix_fingerprint(L: CSR, include_values: bool = True) -> str:
    """Stable hash of a CSR matrix: shape + pattern (+ values by default).

    Values are hashed because the compiled schedule bakes coefficients into
    its ELL tiles; pass include_values=False for a pattern-only key (e.g.
    reusing a tuner *decision* across numerically-refreshed factors).
    """
    h = hashlib.sha256()
    h.update(repr((CACHE_VERSION, L.shape)).encode())
    h.update(np.ascontiguousarray(L.indptr).tobytes())
    h.update(np.ascontiguousarray(L.indices).tobytes())
    if include_values:
        h.update(np.ascontiguousarray(L.data).tobytes())
    return h.hexdigest()[:32]


def value_fingerprint(L: CSR) -> str:
    """Stable hash of the numeric payload alone (16 hex chars).

    The value segment of the operator cache key: two matrices with the same
    pattern and different values share their pattern fingerprint but never
    their value fingerprint, so pattern-derived work (schedule layout,
    tuner pick, replay plan) is shared while numeric payloads stay distinct.
    """
    h = hashlib.sha256()
    h.update(repr((CACHE_VERSION, L.shape)).encode())
    h.update(np.ascontiguousarray(L.data).tobytes())
    return h.hexdigest()[:16]


class OperatorStats:
    """Per-operator stats plane: a VIEW over a `repro.obs` metrics
    registry (prefix "repro_operator"), updated by every solve().

    Every field is backed by one instrument — Counter, Gauge, or Text —
    in `self.registry`; reading a field reads the instrument, and
    Prometheus/JSON export reads the SAME instruments, so there is no
    second ledger to drift (docs/observability.md).  Updates are atomic
    per event: each record_* call commits its instruments under the
    registry's one shared lock, so concurrent `solve()` calls from a
    serving tier's worker threads never interleave a half-written record
    (`solves` and `total_solve_ms` always describe the same set of
    solves, which is what `repro.serving.ServiceStats` aggregation
    relies on).  Reads of individual fields are committed values;
    `to_dict()` snapshots the whole record consistently.

    Fallback counter semantics (made explicit after a double-count
    hazard: the old single counter incremented per retry attempt while
    its warning fired once per pair):

    * `fallbacks` counts DOWNGRADED DISPATCHES — every oriented device
      dispatch served by a non-requested engine.  A refined solve
      dispatches its engine 1 + rounds times, so `fallbacks` can
      legitimately exceed `solves` on a broken-engine operator; that is
      attempt accounting, not double counting.
    * `fallback_downgrades` counts UNIQUE (requested -> used) pairs —
      exactly the events that emit an `EngineFallbackWarning` (which
      warns once per pair).
    """

    _COUNTER_FIELDS = (
        ("solves", "host solve() calls completed"),
        ("rhs_columns", "right-hand-side columns solved"),
        ("refine_rounds", "iterative-refinement correction rounds"),
        ("value_updates", "update_values() calls served"),
        ("fallbacks", "downgraded engine dispatches (attempts)"),
        ("fallback_downgrades", "unique requested->used engine downgrades"),
        ("health_events", "health violations detected"),
    )
    _GAUGE_FIELDS = (
        ("total_solve_ms", 0.0, "cumulative solve wall time (ms)"),
        ("last_solve_ms", 0.0, "wall time of the last solve (ms)"),
        ("last_residual", float("nan"),
         "relative residual of the last solve"),
        ("tune_ms", 0.0, "wall time of the tuner run behind the payload"),
        ("last_update_ms", 0.0, "wall time of the last value update (ms)"),
    )
    _TEXT_FIELDS = (
        # "built" | "memory" | "disk" | "pattern" (payload derived from
        # an equal-pattern artifact via the refactorization fast path)
        ("cache_source", "how the payload was obtained"),
        ("last_fallback", "last downgrade as requested->used"),
        ("last_health_event", "last health event as stage:action"),
    )
    # to_dict() key order: the historical field order, with the new
    # fallback_downgrades riding directly after fallbacks
    _FIELDS = ("solves", "rhs_columns", "refine_rounds", "total_solve_ms",
               "last_solve_ms", "last_residual", "cache_source", "tune_ms",
               "value_updates", "last_update_ms", "fallbacks",
               "fallback_downgrades", "last_fallback", "health_events",
               "last_health_event")

    def __init__(self, cache_source: str = "built", tune_ms: float = 0.0,
                 registry: MetricsRegistry | None = None):
        self.registry = registry if registry is not None else \
            MetricsRegistry(prefix="repro_operator")
        r = self.registry
        self._lock = r.lock
        self._inst = {}
        for name, help in self._COUNTER_FIELDS:
            self._inst[name] = r.counter(name, help)
        for name, default, help in self._GAUGE_FIELDS:
            self._inst[name] = r.gauge(name, help, default=default)
        for name, help in self._TEXT_FIELDS:
            self._inst[name] = r.text(name, help)
        self._inst["cache_source"].set(cache_source)
        self._inst["tune_ms"].set(float(tune_ms))

    def to_dict(self) -> dict:
        with self._lock:
            return {name: getattr(self, name) for name in self._FIELDS}

    # -- atomic mutation (one lock acquisition per event) ---------------------
    def record_solve(self, *, ms: float, columns: int, rounds: int,
                     residual: float) -> None:
        with self._lock:
            self._inst["solves"].inc()
            self._inst["rhs_columns"].inc(columns)
            self._inst["refine_rounds"].inc(rounds)
            self._inst["total_solve_ms"].add(ms)
            self._inst["last_solve_ms"].set(ms)
            self._inst["last_residual"].set(residual)

    def record_fallback(self, last: str, *, new_pair: bool = False) -> None:
        """One downgraded dispatch; `new_pair` marks the first sighting
        of this (requested, used) pair (class doc: attempts vs. unique
        downgrades)."""
        with self._lock:
            self._inst["fallbacks"].inc()
            if new_pair:
                self._inst["fallback_downgrades"].inc()
            self._inst["last_fallback"].set(last)

    def record_health_event(self, last: str = "") -> None:
        """Count a health violation; the action suffix is committed by
        record_health_action once the recovery path is known."""
        with self._lock:
            self._inst["health_events"].inc()
            if last:
                self._inst["last_health_event"].set(last)

    def record_health_action(self, last: str) -> None:
        self._inst["last_health_event"].set(last)

    def record_value_update(self, *, ms: float, cache_source: str) -> None:
        with self._lock:
            self._inst["value_updates"].inc()
            self._inst["last_update_ms"].set(ms)
            self._inst["cache_source"].set(cache_source)

    def __repr__(self) -> str:    # pragma: no cover
        return "OperatorStats(" + ", ".join(
            f"{k}={v!r}" for k, v in self.to_dict().items()) + ")"


def _stats_field_property(name: str) -> property:
    """Field access for OperatorStats: reads/writes the backing
    instrument (writes keep the old dataclass-style assignment working;
    a counter write commits the delta so the monotonic series survives)."""

    def _get(self):
        return self._inst[name].value()

    def _set(self, v):
        inst = self._inst[name]
        with self._lock:
            if inst.kind == "counter":
                inst.inc(v - inst.value())
            else:
                inst.set(v)

    return property(_get, _set)


for _name, *_rest in (OperatorStats._COUNTER_FIELDS
                      + OperatorStats._GAUGE_FIELDS
                      + OperatorStats._TEXT_FIELDS):
    setattr(OperatorStats, _name, _stats_field_property(_name))
del _name, _rest


class TriangularOperator:
    """Compiled triangular-solve operator for one matrix (see module doc)."""

    # bounded LRU: payloads hold full transforms + ELL tiles (MB-scale per
    # large matrix), so a long-lived server over many matrices must not
    # accumulate them forever; overflow falls back to the disk cache
    _memory_cache_max: int = 16
    _memory_cache = collections.OrderedDict()
    # pattern segment of the key ("{pattern32}-{config16}") -> latest full
    # key stored: lets from_csr find an equal-pattern payload to derive
    # from without scanning the LRU
    _pattern_index: dict = {}
    # one lock for cache + index: the serving tier's worker and tuner
    # threads hit from_csr/update_values concurrently, and an OrderedDict
    # mid-move_to_end/popitem is not safe to mutate from two threads
    # (the disk side is already safe via atomic os.replace)
    _cache_lock = threading.RLock()

    @classmethod
    def _memory_get(cls, key: str):
        with cls._cache_lock:
            payload = cls._memory_cache.get(key)
            if payload is not None:
                cls._memory_cache.move_to_end(key)
            return payload

    @classmethod
    def _memory_put(cls, key: str, payload: dict) -> None:
        with cls._cache_lock:
            cls._memory_cache[key] = payload
            cls._memory_cache.move_to_end(key)
            cls._pattern_index[key.rsplit("-", 1)[0]] = key
            while len(cls._memory_cache) > cls._memory_cache_max:
                cls._memory_cache.popitem(last=False)

    @classmethod
    def _memory_get_pattern(cls, pattern_key: str):
        """Newest in-memory payload whose pattern+config segment matches
        (one lock acquisition for index lookup + LRU touch)."""
        with cls._cache_lock:
            return cls._memory_get(cls._pattern_index.get(pattern_key, ""))

    def __init__(self, L: CSR, payload: dict, cache_source: str):
        self._L = L                 # the ORIGINAL matrix, as handed in
        self._payload = payload     # update_values derives from + rebinds it
        self._ts = payload["ts"]    # transform of the oriented lower system
        self._sched = payload["sched"]
        self.report = payload.get("report")        # slim PortfolioReport|None
        self.strategy = payload["strategy"]        # winning strategy label
        cfg = payload["config"]
        self._config = cfg
        self.side = cfg.get("side", "lower")
        self.transpose = bool(cfg.get("transpose", False))
        # recorded by orient_lower at build time (single source of truth
        # for which sweeps reverse the axes)
        self._reversed = bool(payload["reversed"])
        # from_csr overrides this with the actually-resolved instance (which
        # may be an unregistered/custom-configured Engine the registry does
        # not know); name-only resolution is just the cached-payload default
        from .engines import get_engine
        self._engine_name = payload.get("engine", "scan")
        try:
            self._engine = get_engine(self._engine_name)
        except ValueError:          # custom engine: injected by from_csr
            self._engine = None
        self._build_kwargs = {}     # filled by from_csr for transposed()
        # staged schedule + compiled fns live on the payload, NOT the
        # operator, so memory-cache hits share them across from_csr calls
        # (the disk writer strips "_"-prefixed keys; jitted fns can't
        # pickle).  Maps engine name -> (engine instance, compiled fn); the
        # instance is kept for an identity check so two differently
        # configured engines sharing a name never swap compiled code.
        self._runtime = payload.setdefault("_runtime", {"compiled": {}})
        self.stats = OperatorStats(cache_source=cache_source,
                                   tune_ms=payload.get("tune_ms", 0.0))

    @property
    def engine(self) -> str:
        """Name of the default engine (back-compat accessor)."""
        return self._engine.name if self._engine is not None \
            else self._engine_name

    # -- construction ---------------------------------------------------------
    @classmethod
    def from_csr(cls, L: CSR, tune="auto", *, side: str = "lower",
                 transpose: bool = False, chunk: int = 256,
                 max_deps: int = 16, dtype=np.float32, engine=None,
                 mesh=None, mesh_axis: str = "model",
                 cache: bool = True, cache_dir=None, portfolio=None,
                 cost_model=None, measure_top_k: int = 0,
                 health=None) -> "TriangularOperator":
        """Build (or load) the operator for triangular L.

        side/transpose: which sweep this operator performs — `side` names
                the stored triangle ("lower" or "upper"), `transpose=True`
                solves with its transpose (L^T / U^T).  The effective
                system is reduced to lower-triangular form (orient_lower),
                so strategies/compiler/engines are shared by all sweeps.
        tune:   "auto" — run the StrategyPortfolio tuner and take its pick;
                a stable strategy name ("avgLevelCost", ...) or a Strategy
                instance — skip tuning and use that strategy as-is.
        engine: default execution engine — a registered name, an Engine
                from repro.solver.engines, or None for the scan engine.
        mesh/mesh_axis: serve sharded sweeps — a jax Mesh routes every
                solve through the ShardedEngine over `mesh_axis` (one
                all_gather family per step; docs/distributed.md).
                Mutually exclusive with `engine=`.  With tune="auto" and
                no explicit cost_model, tuning defaults to
                CostModel.sharded() so the tuner prices the per-step
                collective it will actually pay.  The compiled artifact
                is otherwise mesh-independent: fixed-strategy sharded and
                single-device operators for the same matrix share the
                cache (auto-tuned ones differ through the cost model in
                the key).
        cache:  look up / persist the compiled artifact (memory + disk,
                keyed by matrix fingerprint and configuration, orientation
                bits included).
        cost_model: tuner scoring constants (a portfolio CostModel, e.g.
                CostModel.cpu() when the scan engine serves on CPU); part
                of the cache key.  tune="auto" only.
        portfolio: a fully custom StrategyPortfolio (tune="auto" only);
                cost_model/measure_top_k are forwarded when constructing
                the default one.  A custom portfolio's configuration is not
                part of the cache key, so passing one disables caching for
                that build.
        health: health policy spec (same forms as solve()'s `health=`).
                Under a policy with `verify_schedule` (the "strict" level),
                the static verifier certifies the compiled artifact ONCE
                per built payload — the `ScheduleCertificate` rides the
                cached payload, so cache hits skip re-verification
                (docs/analysis.md).  Not part of the cache key: verifying
                does not change the artifact.
        """
        import dataclasses as _dc
        from ..core.portfolio import StrategyPortfolio, make_strategy
        from ..core.strategies import strategy_label
        from .engines import resolve_engine
        from .schedule import schedule_for_transformed

        if side not in ("lower", "upper"):
            raise ValueError(f"side must be 'lower' or 'upper', got {side!r}")
        eng = resolve_engine(engine, mesh=mesh, mesh_axis=mesh_axis)
        if tune == "auto" and cost_model is None:
            # sharded engines imply the cost model that charges their
            # per-step collective (docs/distributed.md)
            from ..core.portfolio import default_cost_model_for
            cost_model = default_cost_model_for(eng)
        cache = cache and portfolio is None
        tune_key = "auto" if tune == "auto" else \
            strategy_label(make_strategy(tune))
        # the compiled artifact is engine-independent (engine is a
        # solve-time choice), EXCEPT when measured re-ranking ran: then the
        # tuner's pick depends on which engine was timed (cache_token, not
        # name: sharded engines over different meshes time differently)
        cfg = {"tune": tune_key, "side": side, "transpose": bool(transpose),
               "chunk": chunk, "max_deps": max_deps,
               "dtype": np.dtype(dtype).name,
               "engine": (getattr(eng, "cache_token", lambda: eng.name)()
                          if measure_top_k > 0 else None),
               "measure_top_k": measure_top_k,
               "cost_model": (None if cost_model is None
                              else sorted(_dc.asdict(cost_model).items()))}
        build_kwargs = {"tune": tune, "side": side,
                        "transpose": bool(transpose), "chunk": chunk,
                        "max_deps": max_deps, "dtype": dtype, "engine": eng,
                        "cache": cache, "cache_dir": cache_dir,
                        "portfolio": portfolio, "cost_model": cost_model,
                        "measure_top_k": measure_top_k}
        # pattern segment keys the structure-derived artifact (levels,
        # transform plan, tuner pick, tile layout); the value segment pins
        # the numeric payload.  Same pattern + different values is served
        # by the refactorization fast path below.
        pattern_key = cls._pattern_cache_key(L, cfg)
        key = f"{pattern_key}-{value_fingerprint(L)}"
        from ..core.resilience import resolve_health_policy
        policy = resolve_health_policy(health)

        def _finish(payload, source):
            if policy.verify_schedule and "certificate" not in payload:
                # once per built payload: the certificate rides the cached
                # payload (memory + disk), so hits skip re-verification
                from ..analysis.verify import verify_operator_payload
                verify_operator_payload(
                    payload,
                    where=f"TriangularOperator.from_csr(n={L.n_rows})")
            op = cls(L, payload, cache_source=source)
            op._engine = eng        # the resolved instance, not a name
            op._build_kwargs = build_kwargs
            _obs.event("operator.cache", source=source, n=L.n_rows,
                       strategy=payload["strategy"])
            return op

        if cache:
            payload = cls._memory_get(key)
            if payload is not None:
                return _finish(payload, "memory")
            payload = cls._disk_load(key, cache_dir)
            if payload is not None:
                cls._memory_put(key, payload)
                return _finish(payload, "disk")
            # no exact hit: an equal-pattern artifact (any values) can be
            # numerically re-bound without re-tuning or re-compiling
            base = cls._memory_get_pattern(pattern_key)
            if base is None:
                base = cls._disk_load_pattern(pattern_key, cache_dir)
            if base is not None:
                payload = cls._try_derive_payload(base, L)
                if payload is not None:
                    cls._memory_put(key, payload)
                    cls._disk_store(key, payload, cache_dir)
                    return _finish(payload, "pattern")

        L_eff, reversed_ = orient_lower(L, side, bool(transpose))
        t0 = time.perf_counter()
        report = None
        with _obs.span("operator.tune", n=L.n_rows, tune=tune_key):
            if tune == "auto":
                tuner = portfolio if portfolio is not None else \
                    StrategyPortfolio(
                        chunk=chunk, max_deps=max_deps, dtype=dtype,
                        cost_model=cost_model, measure_top_k=measure_top_k,
                        engine=eng)
                report = tuner.tune(L_eff)
                best = report.best
                ts, sched, label = best.ts, best.sched, best.label
                report = report.slim()  # candidates keep stats, drop arrays
            else:
                strat = make_strategy(tune)
                label = strategy_label(strat)
                from ..core.transform import transform
                ts = transform(L_eff, strat, validate=False, codegen=False)
                sched = schedule_for_transformed(ts, chunk=chunk,
                                                 max_deps=max_deps,
                                                 dtype=dtype)
        payload = {"version": CACHE_VERSION, "strategy": label, "ts": ts,
                   "sched": sched, "report": report, "config": cfg,
                   "reversed": reversed_, "engine": eng.name,
                   "tune_ms": (time.perf_counter() - t0) * 1e3}
        if policy.verify_schedule:
            # certify BEFORE the payload is persisted so the certificate
            # rides the disk artifact too — _finish then has nothing to do
            from ..analysis.verify import verify_operator_payload
            verify_operator_payload(
                payload, where=f"TriangularOperator.from_csr(n={L.n_rows})")
        if cache:
            cls._memory_put(key, payload)
            cls._disk_store(key, payload, cache_dir)
        return _finish(payload, "built")

    def transposed(self) -> "TriangularOperator":
        """The adjoint operator: same stored triangle, flipped sweep.

        For a forward `L x = b` operator this is the `L^T y = g` operator —
        exactly the cotangent solve of the forward one, which is what
        `sptrsv`'s custom VJP runs as its backward pass.  Goes through
        from_csr, so it shares the memory/disk cache.
        """
        kw = dict(self._build_kwargs)
        if not kw:      # constructed without from_csr bookkeeping
            kw = {"tune": self.strategy, "side": self.side,
                  "transpose": self.transpose, "engine": self._engine}
        kw["transpose"] = not kw["transpose"]
        tune = kw.pop("tune")
        return TriangularOperator.from_csr(self._L, tune, **kw)

    # -- pattern-frozen refactorization (docs/refactorization.md) -------------
    @classmethod
    def _derive_payload(cls, base: dict, L_new: CSR) -> dict:
        """Re-bind an equal-pattern payload to new numeric values.

        Reuses everything structure-derived from `base` — level analysis,
        the winning strategy's transformation (replayed numerically via its
        commit log), the schedule's tile layout — and re-runs only the
        value packing.  Raises PatternMismatchError if the new values make
        the replayed transformation's pattern drift (exact cancellation
        creating/removing fill), ValueError if `base` predates the plans.
        """
        from ..core.transform import replay_transform
        # module attribute lookup, not a from-import: fault injection
        # (core.faults.corrupt_values_payload) patches the schedule module
        from . import schedule as _schedule
        cfg = base["config"]
        chunk = cfg.get("chunk", 256)
        max_deps = cfg.get("max_deps", 16)
        dtype = np.dtype(cfg.get("dtype", "float32"))
        L_eff, reversed_ = orient_lower(L_new, cfg.get("side", "lower"),
                                        bool(cfg.get("transpose", False)))
        ts_new = replay_transform(L_eff, base["ts"],
                                  where="TriangularOperator.update_values")
        sched_new = _schedule.repack_schedule_values(
            base["sched"], ts_new.A.data, ts_new.diag)
        # the preamble schedule (solve with the T factor) is value-bound
        # too; repack it from the base entry when its value plan survived
        # renumbering.  If the base never materialized it, stay lazy — the
        # operator's _preamble_host builds it from the NEW transform on
        # first use, so the update itself never enters build_schedule.
        new_runtime: dict = {"compiled": {}}
        entry = base.get("_runtime", {}).get("preamble_host")
        if entry is not None:
            psched = entry[0]
            if psched is None:
                new_runtime["preamble_host"] = entry
            elif psched.value_plan is not None:
                new_runtime["preamble_host"] = (
                    _schedule.repack_schedule_values(
                        psched, ts_new.T.data, np.ones(ts_new.T.n_rows)),
                    entry[1], entry[2])
            else:
                new_runtime["preamble_host"] = _schedule.schedule_for_preamble(
                    ts_new, chunk=chunk, max_deps=max_deps, dtype=dtype)
        return {"version": CACHE_VERSION, "strategy": base["strategy"],
                "ts": ts_new, "sched": sched_new,
                "report": base.get("report"), "config": cfg,
                "reversed": reversed_, "engine": base.get("engine", "scan"),
                "tune_ms": base.get("tune_ms", 0.0),
                "_runtime": new_runtime}

    @classmethod
    def _try_derive_payload(cls, base: dict, L_new: CSR) -> dict | None:
        """_derive_payload for opportunistic from_csr use: a pattern drift
        or a pre-plan payload means "can't fast-path", not an error — the
        caller falls through to a full build."""
        from ..core.resilience import PatternMismatchError
        try:
            return cls._derive_payload(base, L_new)
        except (PatternMismatchError, ValueError):
            return None

    def update_values(self, new_L: CSR, *, health=None) -> "TriangularOperator":
        """Re-bind this operator to new numeric values on the SAME pattern.

        The refactorization fast path for time-stepping / Newton loops
        where the sparsity pattern is fixed and values change every step:
        level analysis, the graph transformation, the tuner's pick and the
        compiled engine executables are all reused — only the numeric
        payload is re-derived (transform replay + schedule value repack).

        Mutates the operator in place and returns self.  A matrix whose
        pattern differs from the frozen one raises PatternMismatchError
        (rebuild with from_csr instead); non-finite values raise
        NumericalHealthError under any health policy that checks inputs
        (`health=` accepts the same specs as solve()).
        """
        from ..core.resilience import (NumericalHealthError,
                                       PatternMismatchError,
                                       resolve_health_policy)
        from ..sparse.csr import same_pattern
        where = f"TriangularOperator.update_values(n={self.n})"
        if not same_pattern(new_L, self._L):
            if new_L.shape != self._L.shape:
                detail = f"shape {new_L.shape} != {self._L.shape}"
            elif new_L.nnz != self._L.nnz:
                detail = f"nnz {new_L.nnz} != {self._L.nnz}"
            elif not np.array_equal(new_L.indptr, self._L.indptr):
                detail = "row pointer drift"
            else:
                detail = "column index drift"
            raise PatternMismatchError(
                "matrix pattern differs from the frozen operator pattern; "
                "rebuild with from_csr", where=where, detail=detail)
        policy = resolve_health_policy(health)
        if policy.check_inputs and not np.all(np.isfinite(new_L.data)):
            raise NumericalHealthError(
                f"new matrix values contain non-finite entries in {where}",
                stage="input", where=where)
        t0 = time.perf_counter()
        with _obs.span("operator.update_values", n=self.n) as usp:
            cache = bool(self._build_kwargs.get("cache", False))
            cache_dir = self._build_kwargs.get("cache_dir")
            pattern_key = self._pattern_cache_key(new_L, self._config)
            key = f"{pattern_key}-{value_fingerprint(new_L)}"
            payload, source = None, "pattern"
            if cache:
                payload = self._memory_get(key)
                if payload is not None:
                    source = "memory"
                else:
                    payload = self._disk_load(key, cache_dir)
                    if payload is not None:
                        source = "disk"
                        self._memory_put(key, payload)
            derived = payload is None
            if derived:
                payload = self._derive_payload(self._payload, new_L)
            if policy.verify_schedule:
                # the structure was certified at build time; the fast path
                # re-audits only what the value re-bind changed (transform
                # replay facts + packed values/dinv) and fails BEFORE the
                # operator mutates or the payload is cached
                from ..analysis.verify import (audit_transformed_system,
                                               verify_schedule_values)
                audit_transformed_system(payload["ts"], where=where)
                verify_schedule_values(payload["sched"], payload["ts"].A,
                                       payload["ts"].diag, where=where)
            if derived and cache:
                self._memory_put(key, payload)
                self._disk_store(key, payload, cache_dir)
            usp.set(source=source)
        self._L = new_L
        self._payload = payload
        self._ts = payload["ts"]
        self._sched = payload["sched"]
        self._reversed = bool(payload["reversed"])
        self._runtime = payload.setdefault("_runtime", {"compiled": {}})
        self.stats.record_value_update(
            ms=(time.perf_counter() - t0) * 1e3, cache_source=source)
        return self

    # -- static verification (docs/analysis.md) -------------------------------
    @property
    def certificate(self):
        """The `ScheduleCertificate` this operator's payload carries, or
        None when it was never verified (build without strict health and
        no explicit verify() call)."""
        return self._payload.get("certificate")

    def verify(self, *, devices: int = 1, collectives: bool = False):
        """Run the full static verifier on the compiled artifact now.

        Audits the transformed system and certifies the schedule
        regardless of health policy; returns the `ScheduleCertificate`
        and stashes it on the payload (so a later strict-mode cache hit
        skips re-verification).  Raises `ScheduleInvariantError` /
        `TransformInvariantError` on violation.
        """
        from ..analysis.verify import verify_operator_payload
        return verify_operator_payload(
            self._payload, devices=devices, collectives=collectives,
            where=f"TriangularOperator.verify(n={self.n})")

    # -- cache plumbing -------------------------------------------------------
    @classmethod
    def _pattern_cache_key(cls, L: CSR, cfg: dict) -> str:
        """Pattern+config segment of the cache key (values excluded)."""
        return (matrix_fingerprint(L, include_values=False) + "-" +
                hashlib.sha256(
                    repr(sorted(cfg.items())).encode()).hexdigest()[:16])

    @staticmethod
    def _cache_path(key: str, cache_dir) -> Path:
        d = Path(cache_dir) if cache_dir is not None else default_cache_dir()
        return d / f"op-{key}.pkl"

    @classmethod
    def _disk_load(cls, key: str, cache_dir) -> dict | None:
        return cls._disk_load_path(cls._cache_path(key, cache_dir))

    @classmethod
    def _disk_load_pattern(cls, pattern_key: str, cache_dir) -> dict | None:
        """Any healthy on-disk payload whose pattern+config segment matches
        (its values don't matter — the caller re-derives them)."""
        d = Path(cache_dir) if cache_dir is not None else default_cache_dir()
        if not d.exists():
            return None
        for path in sorted(d.glob(f"op-{pattern_key}-*.pkl")):
            payload = cls._disk_load_path(path)
            if payload is not None:
                return payload
        return None

    @classmethod
    def _disk_load_path(cls, path: Path) -> dict | None:
        if not path.exists():
            return None
        try:
            with open(path, "rb") as f:
                payload = pickle.load(f)
            if payload.get("version") != CACHE_VERSION:
                cls._quarantine(
                    path, f"stale version {payload.get('version')!r} "
                    f"(expected {CACHE_VERSION})")
                return None
            return payload
        except Exception as e:          # corrupt entry: quarantine + rebuild
            cls._quarantine(path, f"unreadable ({type(e).__name__}: {e})")
            return None

    @staticmethod
    def _quarantine(path: Path, reason: str) -> None:
        """Move a bad cache entry into a `.bad/` sibling directory — kept
        for diagnosis, never silently deleted — and warn; the caller then
        rebuilds the artifact.  A quarantine that itself fails (read-only
        dir, racing quarantiners) is non-fatal: the rebuild proceeds and
        the next atomic store overwrites the bad entry in place."""
        from ..core.resilience import CacheQuarantineWarning
        dest = path.parent / ".bad" / path.name
        try:
            dest.parent.mkdir(parents=True, exist_ok=True)
            os.replace(path, dest)
            placed = f"quarantined to {dest}"
        except OSError:
            placed = "left in place (quarantine move failed)"
        warnings.warn(
            f"disk cache entry {path.name} is {reason}; {placed}, "
            "rebuilding the artifact", CacheQuarantineWarning, stacklevel=4)

    @classmethod
    def _disk_store(cls, key: str, payload: dict, cache_dir) -> None:
        path = cls._cache_path(key, cache_dir)
        # "_"-prefixed keys are process-local runtime state (staged device
        # arrays, compiled fns) — never serialized
        payload = {k: v for k, v in payload.items() if not k.startswith("_")}
        try:
            path.parent.mkdir(parents=True, exist_ok=True)
            # unique tmp name per writer: concurrent builders of the same
            # key each publish a complete file via atomic os.replace, so a
            # reader can never observe a torn pickle (last writer wins)
            tmp = path.parent / (
                f"{path.name}.{os.getpid()}-{uuid.uuid4().hex[:8]}.tmp")
            try:
                with open(tmp, "wb") as f:
                    pickle.dump(payload, f)
                os.replace(tmp, path)
            except BaseException:
                tmp.unlink(missing_ok=True)
                raise
        except OSError:
            pass        # read-only cache dir: operator still works, unseeded

    @classmethod
    def clear_memory_cache(cls) -> None:
        with cls._cache_lock:
            cls._memory_cache.clear()
            cls._pattern_index.clear()

    # -- solving --------------------------------------------------------------
    @property
    def n(self) -> int:
        return self._L.n_rows

    @property
    def schedule(self):
        return self._sched

    @property
    def transformed(self):
        return self._ts

    def _staged(self):
        ds = self._runtime.get("dsched")
        if ds is None:
            import jax
            from .levelset import to_device
            # staging may be triggered lazily from INSIDE a jit trace (an
            # operator first used as a traced preconditioner); the staged
            # arrays are cached on the shared payload, so they must be
            # concrete, never tracers
            with jax.ensure_compile_time_eval():
                ds = self._runtime["dsched"] = to_device(self._sched)
        return ds

    def _compiled_fn(self, engine):
        """engine -> compiled schedule fn, cached on the shared payload.

        Host-lowering engines (ShardedEngine: numpy padding + its own
        staging, memoized per schedule identity) get the host schedule
        directly — staging the unpadded arrays would pin a device copy
        the engine never reads (engines.compile_source)."""
        from .engines import compile_source
        cached = self._runtime["compiled"].get(engine.name)
        if cached is not None and cached[0] is engine:
            return cached[1]
        with _obs.span("engine.compile", engine=engine.name, n=self.n,
                       steps=self._sched.num_steps):
            fn = engine.compile(
                compile_source(engine, self._sched, self._staged))
        self._runtime["compiled"][engine.name] = (engine, fn)
        return fn

    def _canon_dtype(self):
        """The schedule dtype as jax will actually realize it, resolved
        once per payload: under default (non-x64) config a float64
        schedule executes in float32, and requesting float64 per solve
        would emit jax's truncation UserWarning on every call."""
        dt = self._runtime.get("canon_dtype")
        if dt is None:
            import jax.numpy as jnp
            dt = self._runtime["canon_dtype"] = \
                jnp.empty(0, dtype=self._sched.dtype).dtype
        return dt

    def _device_solve(self, c: np.ndarray, engine) -> np.ndarray:
        """One schedule execution in the schedule dtype."""
        import jax.numpy as jnp
        return np.asarray(self._compiled_fn(engine)(
            jnp.asarray(c, dtype=self._canon_dtype())))

    def _preamble_host(self):
        """(LevelSchedule|None, src, row_pos) for the T-factor preamble,
        compiled once on the shared payload (None = identity preamble)."""
        entry = self._runtime.get("preamble_host")
        if entry is None:
            from .schedule import schedule_for_preamble
            entry = self._runtime["preamble_host"] = schedule_for_preamble(
                self._ts, chunk=self._config.get("chunk", 256),
                max_deps=self._config.get("max_deps", 16),
                dtype=np.dtype(self._config.get("dtype", "float32")))
        return entry

    def _preamble_staged(self):
        """_preamble_host with the schedule staged to device, once on the
        shared payload (for engines that compile DeviceSchedules; host-
        lowering engines take _preamble_host directly)."""
        entry = self._runtime.get("preamble")
        if entry is None:
            import jax
            from .levelset import to_device
            psched, src, row_pos = self._preamble_host()
            with jax.ensure_compile_time_eval():    # see _staged
                entry = ((to_device(psched) if psched is not None else None),
                         src, row_pos)
            self._runtime["preamble"] = entry
        return entry

    def device_solve_fn(self, engine=None):
        """The operator's sweep as a pure JAX callable — jit/while_loop
        composable, no host callbacks.

        Returns fn(v) -> x for v of shape (n,) or (n, k): axis reversal
        (transpose/upper sweeps), the T-factor preamble (compiled through
        the SAME level-scheduled engines via schedule_for_preamble), and
        the main schedule all run on device in the schedule dtype; the
        result is cast back to v's dtype.  No float64 iterative
        refinement — this is the raw device pipeline, which is exactly
        what preconditioner applications inside jit-native Krylov loops
        want (M^-1 is approximate by construction; see
        repro.iterative/docs/iterative.md).
        """
        from .engines import resolve_engine
        eng = self._engine if engine is None else resolve_engine(engine)
        if eng is None:
            raise ValueError(
                "operator has no resolvable default engine "
                f"({self._engine_name!r}); pass engine= explicitly")
        from .engines import compile_source
        main_fn = self._compiled_fn(eng)
        psched, src, row_pos = self._preamble_host()
        pre_fn = None
        if psched is not None:
            pre_compiled = self._runtime.setdefault("pre_compiled", {})
            cached = pre_compiled.get(eng.name)
            if cached is not None and cached[0] is eng:
                pre_fn = cached[1]
            else:
                # same host-vs-staged branch as _compiled_fn
                pre_fn = eng.compile(compile_source(
                    eng, psched, lambda: self._preamble_staged()[0]))
                pre_compiled[eng.name] = (eng, pre_fn)
        return compose_sweep_fn(main_fn, self._canon_dtype(), pre_fn, src,
                                row_pos, self._reversed)

    def _oriented_solve(self, v: np.ndarray, engine,
                        out_dtype=None) -> np.ndarray:
        """Device solve of the oriented system for an original-orientation
        right-hand side v: reverse, preamble, schedule, un-reverse.

        out_dtype=None returns the schedule dtype's natural output (the
        no-refinement serving path); the refinement loop passes float64 so
        corrections accumulate at full precision."""
        if self._reversed:
            v = v[::-1]
        x = self._device_solve(self._ts.preamble(v), engine)
        if out_dtype is not None:
            x = x.astype(out_dtype)
        return x[::-1] if self._reversed else x

    def _reference_solve(self, b: np.ndarray) -> np.ndarray:
        """Guaranteed host solve of this sweep in float64 — scipy's
        `spsolve_triangular` when available, else the sequential reference
        loop — built directly from the ORIGINAL matrix, so it cannot be
        poisoned by a bad schedule payload or a failing engine.  The health
        policy's "fallback"/"repair" escape hatch (never the serving path:
        it is host-sequential and slow)."""
        entry = self._runtime.get("ref_system")
        if entry is None:
            L_eff, rev = orient_lower(self._L, self.side, self.transpose)
            try:
                import scipy.sparse as sp
                mat = sp.csr_matrix(
                    (np.asarray(L_eff.data, dtype=np.float64),
                     L_eff.indices, L_eff.indptr), shape=L_eff.shape)
                entry = ("scipy", mat, rev)
            except ImportError:  # pragma: no cover - scipy ships in the env
                entry = ("seq", L_eff, rev)
            self._runtime["ref_system"] = entry
        kind, mat, rev = entry
        v = np.asarray(b, dtype=np.float64)
        if rev:
            v = v[::-1]
        if kind == "scipy":
            from scipy.sparse.linalg import spsolve_triangular
            x = spsolve_triangular(mat, v, lower=True)
        else:
            from .reference import solve_csr_seq
            x = solve_csr_seq(mat, v) if v.ndim == 1 else np.stack(
                [solve_csr_seq(mat, v[:, j]) for j in range(v.shape[1])],
                axis=1)
        return np.asarray(x[::-1] if rev else x, dtype=np.float64)

    def _relative_residual(self, b, x) -> float:
        b64 = np.asarray(b, dtype=np.float64)
        r = b64 - self._L.matvec(np.asarray(x, dtype=np.float64),
                                 transpose=self.transpose)
        scale = max(1.0, float(np.abs(b64).max(initial=0.0)))
        return float(np.abs(r).max(initial=0.0)) / scale

    def _fallback_solve(self, v, eng, out_dtype=None):
        """`_oriented_solve` through `eng`, walking the registry's fallback
        chain (engines.engine_fallbacks) when an engine is unavailable or
        its compile/solve raises.  Returns (x, engine_used).

        Failures are memoized on the shared payload, so a known-broken
        engine is not re-tried on every solve of a hot operator; each
        downgrade bumps `stats.fallbacks` and warns once per
        (requested, used) pair; an exhausted chain raises
        EngineFallbackError naming every attempt and its reason.
        """
        from ..core.resilience import EngineFallbackError
        from .engines import engine_fallbacks
        failures = self._runtime.setdefault("engine_failures", {})
        attempts = []
        for cand in (eng, *engine_fallbacks(eng)):
            known = failures.get(cand.name)
            if known is not None:
                attempts.append((cand.name, f"previously failed ({known})"))
                continue
            try:
                if not cand.available():
                    raise RuntimeError("engine reports unavailable")
                with _obs.span("engine.solve", engine=cand.name):
                    x = self._oriented_solve(v, cand, out_dtype=out_dtype)
            except Exception as e:  # compile, lowering, or solve failure
                reason = f"{type(e).__name__}: {e}"
                failures[cand.name] = reason
                attempts.append((cand.name, reason))
                continue
            if attempts:            # served, but not by the requested engine
                self._note_fallback(eng, cand, attempts)
            return x, cand
        raise EngineFallbackError(
            f"TriangularOperator(n={self.n}, engine={eng.name!r})", attempts)

    def _note_fallback(self, requested, used, attempts) -> None:
        # warn once per (requested, used) pair; `fallbacks` counts every
        # downgraded dispatch and `fallback_downgrades` only the first
        # sighting of a pair, matching the warning (OperatorStats doc)
        warned = self._runtime.setdefault("warned_fallbacks", set())
        pair = (requested.name, used.name)
        new_pair = pair not in warned
        self.stats.record_fallback(f"{requested.name}->{used.name}",
                                   new_pair=new_pair)
        _obs.event("engine.fallback", requested=requested.name,
                   used=used.name, new_pair=new_pair)
        if new_pair:
            warned.add(pair)
            from ..core.resilience import EngineFallbackWarning
            detail = "; ".join(f"{n}: {r}" for n, r in attempts)
            warnings.warn(
                f"engine {requested.name!r} failed, solve downgraded to "
                f"{used.name!r} [{detail}]", EngineFallbackWarning,
                stacklevel=4)

    def _health_recover(self, b, x, reason, stage, guard, eng):
        """Apply the policy's on_nonfinite action to an unhealthy solve:
        "repair" sanitizes non-finite entries and iteratively refines
        through the device chain, escalating to the host reference after
        max_repair_rounds; "fallback" goes straight to the reference;
        anything else (or an unrecoverable solve) raises a typed
        NumericalHealthError naming what was attempted."""
        from ..core.resilience import (HealthRepairWarning,
                                       NumericalHealthError, ResilienceError)
        policy, st = guard.policy, self.stats
        st.record_health_event()
        _obs.event("health.violation", stage=stage, reason=reason)
        attempted = []
        if policy.on_nonfinite == "repair":
            attempted.append("repair")
            xr = np.where(np.isfinite(x), x, 0.0).astype(np.float64)
            for _ in range(policy.max_repair_rounds):
                r = b - self._L.matvec(xr, transpose=self.transpose)
                if not np.isfinite(r).all():
                    break
                try:
                    xr = xr + self._fallback_solve(r, eng,
                                                   out_dtype=np.float64)[0]
                except ResilienceError:
                    break       # no usable device engine: escalate
                if not np.isfinite(xr).all():
                    break       # corrections are poisoned too: escalate
                resid = self._relative_residual(b, xr)
                if resid <= policy.residual_tol:
                    st.record_health_action(f"{stage}:repaired")
                    warnings.warn(
                        f"unhealthy solve ({reason}) repaired by iterative "
                        f"refinement in {guard.where}", HealthRepairWarning,
                        stacklevel=3)
                    return xr, resid
        if policy.on_nonfinite in ("repair", "fallback"):
            attempted.append("reference")
            xref = self._reference_solve(b)
            if np.isfinite(xref).all():
                resid = self._relative_residual(b, xref)
                st.record_health_action(f"{stage}:reference")
                warnings.warn(
                    f"unhealthy solve ({reason}) recovered via the host "
                    f"reference solve in {guard.where}", HealthRepairWarning,
                    stacklevel=3)
                return xref, resid
        st.record_health_action(f"{stage}:raised")
        raise NumericalHealthError(reason, stage=stage, where=guard.where,
                                   fallbacks=attempted)

    def solve(self, b: np.ndarray, *, engine=None,
              refine_tol: float = 1e-10, max_refine: int = 6,
              health=None) -> np.ndarray:
        """Solve the operator's sweep (L, L^T, U, or U^T) x = b for b of
        shape (n,) or batched (n, k).

        Runs the preamble + compiled schedule in the schedule dtype, then
        iteratively refines in float64 against the original matrix until
        the relative residual max|b - Ax| / max(1, max|b|) <= refine_tol
        (or max_refine correction rounds); the residual matvec is
        transpose-aware, so L^T/U^T solves refine against the transposed
        operator.  Refined solves return float64, same leading shape as b.

        Set max_refine=0 for the cheapest per-solve path: no residual is
        computed (stats.last_residual stays NaN), b is NOT promoted to a
        float64 host copy, and the result comes back in the schedule
        dtype's natural output (float32 by default) — the raw device
        pipeline, exactly what refinement-free serving wants.

        health: a HealthPolicy, a named level ("off" | "on" | "strict" |
        "repair" | "fallback"), or None for the REPRO_HEALTH_CHECKS
        environment default ("on").  Controls the SolveGuard around this
        solve — a non-finite b raises NumericalHealthError; an unhealthy
        solution is raised, repaired, or replaced by the host reference
        solve; engine failures walk the registry fallback chain (module
        doc; docs/robustness.md).  Health recoveries return float64
        regardless of max_refine.
        """
        from ..core.resilience import (EngineFallbackError,
                                       HealthRepairWarning, SolveGuard,
                                       resolve_health_policy)
        from .engines import resolve_engine
        eng = self._engine if engine is None else resolve_engine(engine)
        if eng is None:     # payload names a custom engine we don't hold
            raise ValueError(
                "operator has no resolvable default engine "
                f"({self._engine_name!r}); pass engine= explicitly")
        policy = resolve_health_policy(health)
        guard = SolveGuard(policy, where=f"TriangularOperator(n={self.n}, "
                                         f"engine={eng.name!r})")
        # refinement-off solves skip the float64 promotion entirely: no
        # fp64 copy of b, no fp64 cast of the device result
        b = np.asarray(b, dtype=np.float64) if max_refine > 0 \
            else np.asarray(b)
        if b.ndim not in (1, 2) or b.shape[0] != self.n:
            raise ValueError(f"b must be ({self.n},) or ({self.n}, k), "
                             f"got {b.shape}")
        guard.require_finite_input(b)
        t0 = time.perf_counter()
        resid = float("nan")
        rounds = 0
        served_by_reference = False
        with _obs.span("operator.solve", n=self.n, engine=eng.name,
                       columns=1 if b.ndim == 1 else b.shape[1]) as sp:
            try:
                x, eng = self._fallback_solve(
                    b, eng, out_dtype=np.float64 if max_refine > 0 else None)
            except EngineFallbackError:
                # no device engine survived the chain; a recovering policy
                # may still serve the solve from the host reference
                if policy.on_nonfinite == "raise":
                    raise
                self.stats.record_health_event("engine:reference")
                warnings.warn(
                    "every engine in the fallback chain failed; solve served "
                    f"by the host reference in {guard.where}",
                    HealthRepairWarning, stacklevel=2)
                x = self._reference_solve(b)
                served_by_reference = True
            if served_by_reference:
                resid = self._relative_residual(b, x)
            elif max_refine > 0:    # refinement off => skip the host matvec
                bscale = max(1.0, float(np.abs(b).max(initial=0.0)))
                with _obs.span("operator.refine", tol=refine_tol) as rsp:
                    while True:
                        r = b - self._L.matvec(x, transpose=self.transpose)
                        resid = float(np.abs(r).max(initial=0.0)) / bscale
                        if not np.isfinite(resid):
                            break   # poisoned pipeline: corrections would
                                    # be NaN too — the health action below
                                    # decides
                        if resid <= refine_tol or rounds >= max_refine:
                            break
                        x = x + self._fallback_solve(
                            r, eng, out_dtype=np.float64)[0]
                        rounds += 1
                    rsp.set(rounds=rounds, residual=resid)
            if not served_by_reference:
                reason, stage = guard.output_unhealthy(x), "output"
                if reason is None and policy.residual_check:
                    if not np.isfinite(resid):  # nan: unset (max_refine=0)
                        resid = self._relative_residual(b, x)   # or poisoned
                    reason, stage = guard.residual_unhealthy(resid), \
                        "residual"
                if reason is not None:
                    x, resid = self._health_recover(b, x, reason, stage,
                                                    guard, eng)
            ms = (time.perf_counter() - t0) * 1e3
            sp.set(ms=ms, rounds=rounds, engine_used=eng.name,
                   reference=served_by_reference)
            self.stats.record_solve(
                ms=ms, columns=1 if b.ndim == 1 else b.shape[1],
                rounds=rounds, residual=resid)
        return x

    def __repr__(self) -> str:  # pragma: no cover
        return (f"TriangularOperator(n={self.n}, side={self.side!r}, "
                f"transpose={self.transpose}, strategy={self.strategy!r}, "
                f"steps={self._sched.num_steps}, engine={self.engine!r}, "
                f"cache={self.stats.cache_source})")

"""Serving driver: batched prefill + greedy decode with KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --reduced --batch 4 --prompt-len 32 --gen 16

Exercises the same prefill/decode entry points the dry-run lowers at
production shapes (prefill_32k / decode_32k / long_500k).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_reduced
from ..models.api import get_model


def generate(cfg, params, tokens, gen_steps: int, cache_len: int,
             extra: dict | None = None):
    model = get_model(cfg)
    extra = extra or {}
    prefill = jax.jit(lambda p, t, **kw: model.prefill(
        p, t, cfg, cache_len=cache_len, **kw))
    decode = jax.jit(lambda p, t, c, pos: model.decode_step(p, t, c, pos,
                                                            cfg))
    logits, cache = prefill(params, tokens, **extra)
    out = [jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)]
    pos = tokens.shape[1] + (cfg.frontend_positions
                             if cfg.frontend != "none" else 0)
    for i in range(gen_steps - 1):
        logits, cache = decode(params, out[-1], cache, jnp.int32(pos + i))
        out.append(jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32))
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = get_model(cfg)
    rng = np.random.default_rng(args.seed)
    params = model.init_params(jax.random.PRNGKey(args.seed), cfg)
    B, S = args.batch, args.prompt_len
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    extra = {}
    if cfg.family == "encdec":
        extra["src_embeds"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    elif cfg.frontend != "none":
        extra["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.frontend_positions, cfg.d_model)),
            jnp.float32)
    cache_len = S + args.gen + 8
    t0 = time.time()
    out = generate(cfg, params, tokens, args.gen, cache_len, extra)
    dt = time.time() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({B * args.gen / dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(out[0][:12]))
    return out


if __name__ == "__main__":
    main()

"""ShapeDtypeStruct stand-ins for every (arch x input-shape) dry-run cell.

Shapes (assignment):
    train_4k      seq 4,096   global_batch 256   (train_step)
    prefill_32k   seq 32,768  global_batch 32    (serve prefill)
    decode_32k    seq 32,768  global_batch 128   (serve decode: 1 new token
                                                  against a seq-long cache)
    long_500k     seq 524,288 global_batch 1     (decode; SSM/hybrid only)

long_500k is SKIPPED for pure full-attention archs (DESIGN.md §4).
Encoder-decoder (seamless) runs decode shapes (it has a decoder).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ArchConfig

__all__ = ["SHAPES", "ShapeDef", "input_specs", "cell_is_applicable",
           "skip_reason"]


@dataclasses.dataclass(frozen=True)
class ShapeDef:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeDef("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeDef("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeDef("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeDef("long_500k", 524288, 1, "decode"),
}


def cell_is_applicable(cfg: ArchConfig, shape: str) -> bool:
    if shape == "long_500k":
        return cfg.sub_quadratic
    return True


def skip_reason(cfg: ArchConfig, shape: str) -> str:
    if shape == "long_500k" and not cfg.sub_quadratic:
        return ("full-attention arch: 524k dense-decode KV window excluded "
                "by assignment (sub-quadratic archs only)")
    return ""


def f(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape_name: str) -> dict:
    """Returns {"kind", "batch"/"tokens"/..., per-kind structure}."""
    sd = SHAPES[shape_name]
    B, S = sd.global_batch, sd.seq_len
    i32, f32 = jnp.int32, jnp.float32
    bf16 = jnp.bfloat16
    d = cfg.d_model

    if sd.kind == "train":
        if cfg.family == "encdec":
            batch = {
                "src_embeds": f((B, S, d), f32),
                "tokens": f((B, S), i32),
                "labels": f((B, S), i32),
                "mask": f((B, S), f32),
            }
        elif cfg.frontend != "none":
            P = cfg.frontend_positions
            batch = {
                "prefix_embeds": f((B, P, d), f32),
                "tokens": f((B, S - P), i32),
                "labels": f((B, S - P), i32),
                "mask": f((B, S - P), f32),
            }
        else:
            batch = {"tokens": f((B, S), i32), "labels": f((B, S), i32),
                     "mask": f((B, S), f32)}
        return {"kind": "train", "batch": batch}

    if sd.kind == "prefill":
        out = {"kind": "prefill", "tokens": f((B, S), i32), "cache_len": S}
        if cfg.family == "encdec":
            out["src_embeds"] = f((B, S, d), f32)
        elif cfg.frontend != "none":
            P = cfg.frontend_positions
            out["tokens"] = f((B, S - P), i32)
            out["prefix_embeds"] = f((B, P, d), f32)
        return out

    # decode: one new token against a seq-long cache/state
    hd = cfg.resolved_head_dim
    out = {"kind": "decode", "token": f((B, 1), i32), "pos": S - 1,
           "cache_len": S}
    if cfg.family == "ssm":
        s = cfg.ssm
        d_inner = s.expand * d
        H = d_inner // s.head_dim
        conv_dim = d_inner + 2 * s.state_dim
        out["cache"] = {
            "conv": f((cfg.n_layers, B, s.conv_width - 1, conv_dim), bf16),
            "ssd": f((cfg.n_layers, B, H, s.head_dim, s.state_dim), f32),
        }
    elif cfg.family == "hybrid":
        from ..models.rglru import _pattern, _lru_width
        w = _lru_width(cfg)
        cache = []
        for kind in _pattern(cfg):
            if kind == "attn":
                win = min(S, cfg.window or S)
                cache.append({"k": f((B, win, cfg.n_kv, hd), bf16),
                              "v": f((B, win, cfg.n_kv, hd), bf16)})
            else:
                cache.append({"conv": f((B, 3, w), bf16),
                              "h": f((B, w), f32)})
        out["cache"] = cache
    elif cfg.family == "encdec":
        L = cfg.n_layers_decoder
        out["cache"] = {
            "k": f((L, B, S, cfg.n_kv, hd), bf16),
            "v": f((L, B, S, cfg.n_kv, hd), bf16),
            "xk": f((L, B, S, cfg.n_kv, hd), bf16),
            "xv": f((L, B, S, cfg.n_kv, hd), bf16),
        }
    else:
        L = cfg.n_layers
        out["cache"] = {
            "k": f((L, B, S, cfg.n_kv, hd), bf16),
            "v": f((L, B, S, cfg.n_kv, hd), bf16),
        }
    return out

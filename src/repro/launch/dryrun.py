import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
# placeholder host devices; record memory/cost analysis + collective stats.
#
#     PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
#         --shape train_4k [--multi-pod]
#     PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/
#
# The XLA_FLAGS line above MUST stay the first statement: jax locks the
# device count at first init.  (Smoke tests and benchmarks never import this
# module.)

import argparse
import functools
import json
import re
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import all_arch_ids, get_config
from ..models.api import get_model
from ..train.optimizer import AdamWConfig
from ..train import train_step as ts_mod
from ..train.sharding import param_shardings, batch_specs
from .mesh import make_production_mesh
from .specs import SHAPES, cell_is_applicable, input_specs, skip_reason

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# v5e hardware model (roofline constants)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link (ICI)


def _bytes_of_shape(dtype: str, dims: str) -> int:
    sizes = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
             "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
             "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
    b = sizes.get(dtype, 4)
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return b * n


_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def collective_stats(hlo_text: str) -> dict:
    """Sum output-shape bytes + count per collective op kind.

    Convention: bytes = op RESULT size (all-gather: full gathered tensor;
    all-reduce: tensor size; reduce-scatter: shard size).  Counts are per
    compiled program (scan bodies count once per op, multiplied at runtime
    by trip count — recorded separately as 'static').
    """
    stats = {k: {"count": 0, "bytes": 0} for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.lstrip()
        m = re.match(r"%?[\w\.\-]+\s*=\s*(.*)", s)
        if not m:
            continue
        rhs = m.group(1)
        for kind in COLLECTIVES:
            # match the op name (e.g. "all-gather(", "all-gather-start(")
            if re.search(rf"\b{kind}(-start)?\(", rhs):
                shapes = _SHAPE_RE.findall(rhs.split(kind)[0])
                nbytes = sum(_bytes_of_shape(d, dims) for d, dims in shapes)
                stats[kind]["count"] += 1
                stats[kind]["bytes"] += nbytes
                break
    stats["total_bytes"] = sum(v["bytes"] for k, v in stats.items()
                               if isinstance(v, dict))
    stats["total_count"] = sum(v["count"] for k, v in stats.items()
                               if isinstance(v, dict))
    return stats


def _sds(tree):
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


def _batch_shardings(batch_shape, mesh):
    bspec = batch_specs(mesh)
    sz = int(np.prod([mesh.shape[a] for a in bspec]))

    def one(leaf):
        first = bspec if leaf.shape and leaf.shape[0] % sz == 0 else None
        entries = [first] + [None] * (len(leaf.shape) - 1)
        return NamedSharding(mesh, P(*entries))
    return jax.tree.map(one, batch_shape)


def _cache_shardings(cache_shape, mesh, seq_axis="model"):
    """KV caches: batch over data(+pod), long seq dims over 'model'."""
    bspec = batch_specs(mesh)
    dsz = int(np.prod([mesh.shape[a] for a in bspec]))
    msz = mesh.shape[seq_axis]

    def one(leaf):
        shp = leaf.shape
        entries = [None] * len(shp)
        if len(shp) == 5:          # (L, B, S, K, hd)
            if shp[1] % dsz == 0:
                entries[1] = bspec
            if shp[2] % msz == 0:
                entries[2] = seq_axis
        elif len(shp) == 4:        # (B, win, K, hd) or (L?, B, ...) hybrid
            if shp[0] % dsz == 0:
                entries[0] = bspec
            if shp[1] % msz == 0 and shp[1] >= 1024:
                entries[1] = seq_axis
        elif len(shp) >= 2:        # conv/ssd/lru states: batch-ish leading
            lead = 1 if len(shp) >= 3 and shp[0] <= 64 else 0
            if shp[lead] % dsz == 0:
                entries[lead] = bspec
        return NamedSharding(mesh, P(*entries))
    return jax.tree.map(one, cache_shape)


def run_cell(arch: str, shape: str, multi_pod: bool = False,
             mesh=None, variant: dict | None = None) -> dict:
    """Lower+compile one cell; returns the roofline record."""
    cfg = get_config(arch)
    variant = variant or {}
    for k, v in variant.items():
        cfg = cfg.__class__(**{**cfg.__dict__, k: v}) if hasattr(cfg, k) \
            else cfg
    if not cell_is_applicable(cfg, shape):
        return {"arch": arch, "shape": shape, "skip": skip_reason(cfg, shape)}
    mesh = mesh if mesh is not None else make_production_mesh(
        multi_pod=multi_pod)
    from ..train.meshctx import set_mesh_context
    set_mesh_context(mesh, batch_specs(mesh))
    model = get_model(cfg)
    spec = input_specs(cfg, shape)
    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(functools.partial(model.init_params,
                                                    cfg=cfg), key)
    p_shard = param_shardings(params_shape, mesh)
    t0 = time.time()

    if spec["kind"] == "train":
        state_shape = {"params": params_shape,
                       "opt": jax.eval_shape(
                           lambda p: __import__(
                               "repro.train.optimizer",
                               fromlist=["init_opt_state"]).init_opt_state(p),
                           params_shape)}
        s_shard = ts_mod.state_shardings(state_shape, mesh)
        b_shard = _batch_shardings(spec["batch"], mesh)
        step = ts_mod.make_train_step(cfg, AdamWConfig())
        fn = jax.jit(step, in_shardings=(s_shard, b_shard),
                     out_shardings=(s_shard, None))
        lowered = fn.lower(_sds(state_shape), _sds(spec["batch"]))
    elif spec["kind"] == "prefill":
        extra_names = [k for k in ("src_embeds", "prefix_embeds")
                       if k in spec]
        extra_vals = [spec[k] for k in extra_names]
        extra_shards = [_batch_shardings({"x": v}, mesh)["x"]
                        for v in extra_vals]
        cache_len = spec["cache_len"]

        def prefill_fn(params, tokens, *extras):
            kwargs = dict(zip(extra_names, extras))
            return model.prefill(params, tokens, cfg, cache_len=cache_len,
                                 **kwargs)
        tok_shard = _batch_shardings({"t": spec["tokens"]}, mesh)["t"]
        fn = jax.jit(prefill_fn,
                     in_shardings=(p_shard, tok_shard, *extra_shards),
                     out_shardings=None)
        lowered = fn.lower(params_shape, spec["tokens"], *extra_vals)
    else:  # decode
        cache_shape = spec["cache"]
        c_shard = _cache_shardings(cache_shape, mesh)
        tok_shard = _batch_shardings({"t": spec["token"]}, mesh)["t"]

        def decode_fn(params, token, cache, pos):
            return model.decode_step(params, token, cache, pos, cfg)
        fn = jax.jit(decode_fn,
                     in_shardings=(p_shard, tok_shard, c_shard, None),
                     out_shardings=(None, c_shard))
        lowered = fn.lower(params_shape, spec["token"], cache_shape,
                           jax.ShapeDtypeStruct((), jnp.int32))

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_stats(hlo)
    from .hlo_analysis import analyze_hlo
    corrected = analyze_hlo(hlo)
    n_chips = int(np.prod(list(mesh.shape.values())))

    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    rec = {
        "arch": arch, "shape": shape,
        "mesh": dict(mesh.shape), "chips": n_chips,
        "kind": spec["kind"],
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        # raw cost_analysis numbers (scan bodies counted ONCE — see
        # hlo_analysis.py); kept for transparency
        "hlo_flops_raw": flops, "hlo_bytes_raw": bytes_acc,
        # loop-corrected per-chip totals
        "hlo_flops": corrected["flops"], "hlo_bytes": corrected["bytes"],
        "hlo_bytes_min": corrected["bytes_min"],
        "collectives_raw": coll,
        "collectives": {
            "total_bytes": corrected["collective_bytes"],
            "counts": corrected["collective_counts"],
            "total_count": int(sum(corrected["collective_counts"]
                                   .values())),
        },
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
    }
    rec["roofline"] = roofline_terms(rec, cfg, SHAPES[shape])
    return rec


def roofline_terms(rec: dict, cfg, sd) -> dict:
    """Per-chip roofline terms from the loop-corrected HLO totals (the
    compiled module is the per-device SPMD program)."""
    flops, bts = rec["hlo_flops"], rec["hlo_bytes"]
    cbytes = rec["collectives"]["total_bytes"]
    compute_s = flops / PEAK_FLOPS
    memory_s = bts / HBM_BW
    memory_min_s = rec.get("hlo_bytes_min", bts) / HBM_BW
    collective_s = cbytes / LINK_BW
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s), key=lambda kv: kv[1])[0]
    # MODEL_FLOPS: 6*N*D for train (fwd+bwd), 2*N*D for single fwd
    N = cfg.active_params_count()
    D = sd.global_batch * (sd.seq_len if rec["kind"] != "decode" else 1)
    mult = 6 if rec["kind"] == "train" else 2
    model_flops = mult * N * D / rec["chips"]    # per chip
    step_s = max(compute_s, memory_s, collective_s)
    step_min_s = max(compute_s, memory_min_s, collective_s)
    dominant_min = max(("compute", compute_s), ("memory", memory_min_s),
                       ("collective", collective_s), key=lambda kv: kv[1])[0]
    return {
        "compute_s": compute_s, "memory_s": memory_s,
        "memory_min_s": memory_min_s,
        "collective_s": collective_s, "dominant": dominant,
        "dominant_min": dominant_min,
        "model_flops_per_chip": model_flops,
        "useful_fraction": model_flops / flops if flops else 0.0,
        # fraction of peak compute achieved if the dominant term bounds the
        # step (the roofline score): MODEL_FLOPS / (step_time * peak).
        # _min variant assumes perfect elementwise fusion (TPU-realistic).
        "roofline_mfu": (model_flops / (step_s * PEAK_FLOPS)
                         if step_s > 0 else 0.0),
        "roofline_mfu_min": (model_flops / (step_min_s * PEAK_FLOPS)
                             if step_min_s > 0 else 0.0),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments")
    args = ap.parse_args()

    cells = []
    archs = all_arch_ids() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    results = []
    for mp in meshes:
        mesh = make_production_mesh(multi_pod=mp)
        for arch in archs:
            for shape in shapes:
                tag = f"{arch}|{shape}|{'2pod' if mp else '1pod'}"
                try:
                    rec = run_cell(arch, shape, multi_pod=mp, mesh=mesh)
                    rec["mesh_tag"] = "2pod" if mp else "1pod"
                    if "skip" in rec:
                        print(f"SKIP {tag}: {rec['skip']}", flush=True)
                    else:
                        r = rec["roofline"]
                        print(f"OK   {tag}: compile {rec['compile_s']}s "
                              f"flops {rec['hlo_flops']:.3e} "
                              f"dom={r['dominant']} "
                              f"useful={r['useful_fraction']:.2f}",
                              flush=True)
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": shape,
                           "mesh_tag": "2pod" if mp else "1pod",
                           "error": f"{type(e).__name__}: {e}"}
                    print(f"FAIL {tag}: {rec['error']}", flush=True)
                results.append(rec)
                (outdir / "dryrun_results.json").write_text(
                    json.dumps(results, indent=1, default=str))
    print(f"wrote {outdir/'dryrun_results.json'}")


if __name__ == "__main__":
    main()

"""Production mesh construction.

make_production_mesh is a FUNCTION (never a module-level constant) so that
importing this module never touches jax device state; dryrun.py sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Mesh over whatever devices exist locally (tests / CPU runs)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))

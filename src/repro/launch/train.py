"""End-to-end training driver (runs for real on local devices).

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Production path: same code with --no-reduced on a TPU fleet; the mesh is
whatever jax.devices() provides.  Features exercised: sharded train_step,
synthetic data pipeline with background prefetch, async step-atomic
checkpointing, NaN rollback, straggler monitor, restart (--resume) and
elastic re-mesh (the mesh is rebuilt from live devices at startup).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_reduced
from ..models.api import get_model
from ..train import checkpoint as ckpt_mod
from ..train.data import Prefetcher, SyntheticLM
from ..train.meshctx import set_mesh_context
from ..train.optimizer import AdamWConfig, init_opt_state
from ..train.resilience import RunGuard, StepMonitor, replan_mesh
from ..train.sharding import batch_specs
from ..train import train_step as ts_mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--no-reduced", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default=None)
    ap.add_argument("--abort-after", type=int, default=0,
                    help="simulate a node failure after N steps (no final "
                         "save; restart with --resume)")
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = get_model(cfg)

    # elastic mesh from live devices
    mesh = replan_mesh(len(jax.devices()), prefer_model=1)
    set_mesh_context(mesh, batch_specs(mesh))
    print(f"mesh: {dict(mesh.shape)} devices={len(jax.devices())}")

    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(10, args.steps // 20))
    step_fn = ts_mod.make_train_step(cfg, opt_cfg,
                                     microbatch=args.microbatch)

    key = jax.random.PRNGKey(args.seed)
    state = {"params": model.init_params(key, cfg)}
    state["opt"] = init_opt_state(state["params"])

    start_step = 0
    ckpt_dir = Path(args.ckpt_dir)
    if args.resume and ckpt_mod.latest_step(ckpt_dir) is not None:
        state, start_step = ckpt_mod.restore(ckpt_dir, state)
        print(f"resumed from step {start_step}")

    jit_step = jax.jit(step_fn, donate_argnums=(0,))

    data = SyntheticLM(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed, frontend=cfg.frontend,
        frontend_positions=cfg.frontend_positions, d_model=cfg.d_model,
        encdec=cfg.family == "encdec")

    def stream():
        s = start_step
        while True:
            yield data.batch_at(s)
            s += 1

    it = Prefetcher(stream(), depth=2)
    ckptr = ckpt_mod.Checkpointer(ckpt_dir)
    guard = RunGuard(ckptr, interval=args.ckpt_every)
    mon = StepMonitor(hard_timeout_s=3600.0)
    losses = []

    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        mon.start()
        state, metrics = jit_step(state, batch)
        loss = float(metrics["loss"])
        t = mon.finish()
        if not guard.check_loss(loss):
            print(f"step {step}: non-finite loss, rolling back")
            state, rb = ckpt_mod.restore(ckpt_dir, state)
            continue
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"dt {t['step_time_s']*1e3:.0f}ms"
                  + (" [straggler]" if t["straggler_alarm"] else ""),
                  flush=True)
        if guard.should_save(step):
            # state is post-update: the resume point is the NEXT step
            ckptr.save_async(step + 1, state, extra={"loss": loss})
        if args.abort_after and step - start_step + 1 >= args.abort_after:
            ckptr.wait()
            print(f"simulated failure after step {step} — restart with "
                  f"--resume")
            return losses
    ckptr.wait()
    ckpt_mod.save(ckpt_dir, args.steps, jax.tree.map(np.asarray, state))
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f}); "
          f"stragglers={mon.stragglers}")
    if args.metrics_out:
        Path(args.metrics_out).write_text(json.dumps(
            {"losses": losses, "first": losses[0], "final": losses[-1]}))
    return losses


if __name__ == "__main__":
    main()

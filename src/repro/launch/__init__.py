# NOTE: dryrun is intentionally NOT imported here — it sets XLA_FLAGS at
# import time and must only be run as a __main__ module.
from . import mesh, specs

__all__ = ["mesh", "specs"]

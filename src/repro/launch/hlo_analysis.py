"""Corrected HLO program analysis.

XLA's `compiled.cost_analysis()` counts while-loop bodies ONCE, so any
scanned program (layer stacks, flash-attention chunk loops, microbatching)
is undercounted by the trip counts.  This module re-derives program totals
from `compiled.as_text()`:

  * per-computation symbol tables resolve operand shapes (the optimized HLO
    dialect prints shapes only at definition sites);
  * `while` ops multiply their body totals by the trip count taken from the
    op's `backend_config known_trip_count` (canonical lax.scan lowering),
    falling back to the condition's compare constant;
  * `fusion`/`call` ops pull dot-FLOPs from their callee computation and
    charge memory traffic at the fusion boundary (operands + result);
  * collectives are summed with loop multipliers (result-shape bytes:
    all-gather => gathered size, all-reduce => tensor size, reduce-scatter
    => shard size).

flops counts dot ops only (elementwise flops are bandwidth-dominated and a
few % of any cell here); bytes approximates HBM traffic as the sum of
top-level operand+result sizes at fusion granularity.
"""
from __future__ import annotations

import dataclasses
import re

__all__ = ["analyze_hlo", "HloTotals"]

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
                "c64": 8, "c128": 16}

_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"calls=%?([\w\.\-]+)")
_BODY = re.compile(r"body=%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")
_LHS_C = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_REF = re.compile(r"%([\w\.\-]+)")
_OPCODE = re.compile(r"\)?\s*([\w\-]+)\(")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _nbytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype, 4)
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return b * n


def _nelems(dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclasses.dataclass
class _Op:
    var: str
    result_shapes: list          # [(dtype, dims), ...]
    opcode: str
    operands: list               # var names
    line: str


class _Comp:
    def __init__(self, name: str, header: str):
        self.name = name
        self.ops: list[_Op] = []
        self.symbols: dict[str, list] = {}
        # header params: "p0: f32[1,2], p1: (s32[], f32[3])"
        for pm in re.finditer(r"([\w\.\-]+)\s*:\s*([^,()]*(?:\([^)]*\))?"
                              r"[^,]*)", header):
            shapes = _SHAPE.findall(pm.group(2))
            if shapes:
                self.symbols[pm.group(1)] = shapes

    def add_op(self, line: str):
        s = line.strip()
        if s.startswith("ROOT "):
            s = s[5:]
        m = re.match(r"%?([\w\.\-]+)\s*=\s*(.*)$", s)
        if not m:
            return
        var, rhs = m.group(1), m.group(2)
        om = _OPCODE.search(rhs)
        if not om:
            return
        # everything before the opcode is the result type signature
        type_part = rhs[:om.start() + (1 if rhs[om.start()] == ")" else 0)]
        opcode = om.group(1)
        result_shapes = _SHAPE.findall(type_part)
        args_part = rhs[om.end():]
        # operand references up to the closing paren of the op call
        depth = 1
        end = len(args_part)
        for i, ch in enumerate(args_part):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = _REF.findall(args_part[:end])
        self.symbols[var] = result_shapes
        self.ops.append(_Op(var, result_shapes, opcode, operands, rhs))

    def shape_of(self, var: str):
        return self.symbols.get(var, [])


def _split(text: str) -> tuple[dict[str, "_Comp"], str | None]:
    comps: dict[str, _Comp] = {}
    entry = None
    cur: _Comp | None = None
    for line in text.splitlines():
        if cur is None:
            if line.rstrip().endswith("{"):
                m = _COMP_HDR.match(line.strip())
                if m:
                    cur = _Comp(m.group(1), m.group(2))
                    comps[cur.name] = cur
                    if line.startswith("ENTRY"):
                        entry = cur.name
        else:
            if line.strip() == "}":
                cur = None
            else:
                cur.add_op(line)
    return comps, entry


@dataclasses.dataclass
class HloTotals:
    flops: float = 0.0
    bytes: float = 0.0        # all top-level ops (upper bound, CPU-fusion
    #                           granularity; TPU fuses more)
    bytes_min: float = 0.0    # dots/copies/slice-updates/collectives only
    #                           (lower bound: elementwise assumed fused away)
    coll_bytes: float = 0.0
    coll_counts: dict | None = None

    def add(self, other, mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.bytes_min += other.bytes_min * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, v in (other.coll_counts or {}).items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v * mult


def _dot_flops(comp: _Comp, op: _Op) -> float:
    if not op.result_shapes or not op.operands:
        return 0.0
    lhs_shapes = comp.shape_of(op.operands[0])
    if not lhs_shapes:
        return 0.0
    lhs_dims = lhs_shapes[0][1].split(",") if lhs_shapes[0][1].strip() else []
    contract = 1
    m = _LHS_C.search(op.line)
    if m and m.group(1).strip():
        for i in m.group(1).split(","):
            idx = int(i)
            if idx < len(lhs_dims):
                contract *= int(lhs_dims[idx])
    out = _nelems(op.result_shapes[0][1])
    return 2.0 * out * contract


def analyze_hlo(text: str) -> dict:
    comps, entry = _split(text)
    if entry is None:
        entry = next((c for c in comps if c.startswith("main")),
                     next(iter(comps), None))
    dot_memo: dict[str, float] = {}

    def dot_total(name: str) -> float:
        if name in dot_memo:
            return dot_memo[name]
        dot_memo[name] = 0.0
        comp = comps.get(name)
        if comp is None:
            return 0.0
        tot = 0.0
        for op in comp.ops:
            if op.opcode == "dot":
                tot += _dot_flops(comp, op)
            elif op.opcode in ("fusion", "call", "conditional"):
                cm = _CALLS.search(op.line) or re.search(
                    r"to_apply=%?([\w\.\-]+)", op.line)
                if cm:
                    tot += dot_total(cm.group(1))
        dot_memo[name] = tot
        return tot

    memo: dict[str, HloTotals] = {}

    def walk(name: str) -> HloTotals:
        if name in memo:
            return memo[name]
        t = HloTotals(coll_counts={})
        memo[name] = t
        comp = comps.get(name)
        if comp is None:
            return t
        for op in comp.ops:
            if op.opcode == "while":
                tm = _TRIP.search(op.line)
                trips = int(tm.group(1)) if tm else 1
                bm = _BODY.search(op.line)
                if bm:
                    t.add(walk(bm.group(1)), mult=trips)
                continue
            if op.opcode in ("parameter", "constant", "get-tuple-element",
                             "tuple", "bitcast", "after-all"):
                continue
            nbytes_out = sum(_nbytes(d, dims) for d, dims in
                             op.result_shapes)
            nbytes_in = 0
            for o in op.operands:
                nbytes_in += sum(_nbytes(d, dims)
                                 for d, dims in comp.shape_of(o))
            if op.opcode == "dynamic-update-slice":
                # in-place update: traffic = update slice (read+write), not
                # the whole buffer (operand 0 aliases the result)
                upd = (sum(_nbytes(d, dims)
                           for d, dims in comp.shape_of(op.operands[1]))
                       if len(op.operands) > 1 else 0)
                t.bytes += 2 * upd
                t.bytes_min += 2 * upd
            elif op.opcode == "dynamic-slice":
                t.bytes += 2 * nbytes_out   # read + write of the slice
                t.bytes_min += 2 * nbytes_out
            else:
                t.bytes += nbytes_out + nbytes_in
                if op.opcode in ("dot", "copy", "convolution",
                                 "concatenate") or \
                        op.opcode.replace("-start", "") in COLLECTIVES:
                    t.bytes_min += nbytes_out + nbytes_in
            if op.opcode == "dot":
                t.flops += _dot_flops(comp, op)
            elif op.opcode in ("fusion", "call"):
                cm = _CALLS.search(op.line) or re.search(
                    r"to_apply=%?([\w\.\-]+)", op.line)
                if cm:
                    t.flops += dot_total(cm.group(1))
            base = op.opcode.replace("-start", "")
            if base in COLLECTIVES:
                t.coll_bytes += nbytes_out
                t.coll_counts[base] = t.coll_counts.get(base, 0) + 1
        return t

    tot = walk(entry) if entry else HloTotals(coll_counts={})
    return {
        "flops": tot.flops,
        "bytes": tot.bytes,
        "bytes_min": tot.bytes_min,
        "collective_bytes": tot.coll_bytes,
        "collective_counts": {k: int(v)
                              for k, v in (tot.coll_counts or {}).items()},
    }

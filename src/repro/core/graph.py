"""Dependency-graph view + the paper's cost model (§III).

cost(row)  = 2*nnz(row) - 1          (nnz includes the diagonal)
           = 2*|strict-lower deps| + 1
cost(level)= sum of row costs = 2*sum(nnz) - n_rows_in_level
avgLevelCost = totalCost / numLevels   (FIXED during transformation)

The paper's cost model treats the right-hand-side combination of a rewritten
row (our B' entries) as *free* — its prototype bakes b into generated code.
We additionally track `operator_cost`, which charges 2*|B'| - 1 for rows whose
B' is not the trivial identity row, i.e. the honest any-b solve cost.  The
B'-combination is dependency-free (a pure SpMV preamble), so the paper cost is
exactly the cost of the *dependency-constrained* part of the solve.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..sparse.csr import CSR
from ..sparse.levels import LevelSets, build_levels

__all__ = ["CostModel", "GraphView"]


PAPER_ROW_COST = lambda n_deps: 2 * n_deps + 1  # noqa: E731


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Paper cost model; see module docstring."""

    @staticmethod
    def row_cost(n_deps: int) -> int:
        return 2 * n_deps + 1

    @staticmethod
    def operator_row_cost(n_deps: int, n_b: int, trivial_b: bool) -> int:
        base = 2 * n_deps + 1
        return base if trivial_b else base + 2 * n_b - 1


class GraphView:
    """Levels + costs of a lower-triangular CSR matrix (read-only snapshot)."""

    def __init__(self, L: CSR, levels: LevelSets | None = None):
        self.L = L
        self.levels = levels if levels is not None else build_levels(L)
        deps = L.row_nnz() - 1  # strict-lower count (diagonal always present)
        self.row_cost = (2 * deps + 1).astype(np.int64)
        self.level_cost = np.zeros(self.levels.num_levels, dtype=np.int64)
        np.add.at(self.level_cost, self.levels.level_of, self.row_cost)

    @property
    def num_levels(self) -> int:
        return self.levels.num_levels

    @property
    def total_cost(self) -> int:
        return int(self.level_cost.sum())

    @property
    def avg_level_cost(self) -> float:
        return self.total_cost / max(self.num_levels, 1)

    def thin_levels(self) -> np.ndarray:
        """Levels with cost < avgLevelCost (paper's thin-level criterion)."""
        return np.flatnonzero(self.level_cost < self.avg_level_cost)

"""The paper's primary contribution: SpTRSV dependency-graph transformation.

Pipeline: CSR L -> GraphView (levels + cost model) -> Strategy (avgLevelCost /
manual / constrained) mutating an EquationStore -> TransformedSystem
(A', B', d, level schedule) consumed by repro.solver engines.
"""
from .graph import CostModel, GraphView
from .rewrite import EquationStore, RewriteResult
from .strategies import (AvgLevelCost, ConstrainedAvgLevelCost,
                         CriticalPathRewrite, ManualEveryK, NoRewrite,
                         strategy_label)
from .transform import (ReplayPlan, TransformMetrics, TransformedSystem,
                        replay_transform, transform)
from .codegen import generate_c_source, generated_code_bytes
from .portfolio import (PairReport, PortfolioCandidate, PortfolioReport,
                        StrategyPortfolio, default_candidates, make_strategy)
from .portfolio import CostModel as TuningCostModel
from .resilience import (CacheQuarantineWarning, EngineFallbackError,
                         EngineFallbackWarning, HealthPolicy,
                         HealthRepairWarning, NumericalHealthError,
                         PatternMismatchError, ResilienceError,
                         ResilienceWarning, RetryPolicy, SolveGuard,
                         resolve_health_policy)

__all__ = [
    "CostModel", "GraphView", "EquationStore", "RewriteResult",
    "NoRewrite", "AvgLevelCost", "ManualEveryK", "ConstrainedAvgLevelCost",
    "CriticalPathRewrite", "strategy_label",
    "TransformMetrics", "TransformedSystem", "transform",
    "ReplayPlan", "replay_transform",
    "generate_c_source", "generated_code_bytes",
    "StrategyPortfolio", "PortfolioCandidate", "PortfolioReport",
    "PairReport", "TuningCostModel", "default_candidates", "make_strategy",
    "ResilienceError", "NumericalHealthError", "EngineFallbackError",
    "PatternMismatchError",
    "ResilienceWarning", "EngineFallbackWarning", "HealthRepairWarning",
    "CacheQuarantineWarning", "HealthPolicy", "SolveGuard", "RetryPolicy",
    "resolve_health_policy",
]

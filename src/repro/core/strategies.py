"""Graph-transformation strategies.

- NoRewrite:            identity (baseline column of Table I).
- AvgLevelCost:         THE PAPER's automated naive strategy (§III).
- ManualEveryK:         the manual strategy of prior work [12]: every k-1
                        consecutive thin levels rewritten into the k-th
                        (paper: "every 9 levels is rewritten to the 10th").
- ConstrainedAvgLevelCost: beyond-paper — AvgLevelCost plus the constraints
                        the paper *proposes* in §III.A but does not implement:
                        (1) in-degree cap alpha, (2) rewrite-distance cap beta,
                        (3) coefficient-magnitude cap (numerical stability,
                        §IV observation), (4) optional dynamic avg update.

All strategies mutate an EquationStore and return per-strategy stats; the
driver in transform.py assembles the TransformedSystem and metrics.

Naming contract (documented in docs/strategies.md): every strategy class has
a STABLE `name` (used as the type identity — cache keys, CSV columns, CLI
specs) and every instance a `label` = name plus a canonical parameter suffix
(used to tell candidates of one portfolio sweep apart).  For parameter-free
strategies label == name.  See docs/strategies.md for when the portfolio
tuner (portfolio.py) prefers each strategy.
"""
from __future__ import annotations

import dataclasses
from typing import Protocol

import numpy as np

from ..sparse.csr import CSR
from ..sparse.levels import LevelSets
from .graph import GraphView
from .rewrite import EquationStore

__all__ = [
    "Strategy", "NoRewrite", "AvgLevelCost", "ManualEveryK",
    "ConstrainedAvgLevelCost", "CriticalPathRewrite", "strategy_label",
]


def strategy_label(strategy) -> str:
    """Instance label: stable `name` + canonical parameter suffix."""
    return getattr(strategy, "label", strategy.name)


@dataclasses.dataclass
class StrategyStats:
    rows_rewritten: int = 0
    rows_skipped_constraint: int = 0
    substitutions: int = 0
    max_rewrite_distance: int = 0
    max_abs_coef: float = 0.0


class Strategy(Protocol):
    name: str

    def apply(self, store: EquationStore, view: GraphView) -> StrategyStats: ...


class NoRewrite:
    name = "no_rewriting"

    def apply(self, store: EquationStore, view: GraphView) -> StrategyStats:
        return StrategyStats()


class AvgLevelCost:
    """Paper §III, faithful.

    avgLevelCost is computed once and FIXED.  Thin levels (cost < avg) are
    walked in order; the first thin level is the initial target; rows of later
    thin levels are tentatively rewritten to the target (exact rearranged
    cost via EquationStore).  If the target's accumulated cost would exceed
    avgLevelCost, the walk re-targets: the level of the offending row becomes
    the new target (its not-yet-moved rows stay), and the walk continues.
    Emptied source levels are deleted on compaction (transform.py).
    """

    name = "avgLevelCost"

    def apply(self, store: EquationStore, view: GraphView) -> StrategyStats:
        stats = StrategyStats()
        avg = view.avg_level_cost
        thin = view.thin_levels()
        if thin.size < 2:
            return stats
        levels: LevelSets = view.levels
        target = int(thin[0])
        target_cost = float(view.level_cost[target])
        for lvl_idx in range(1, thin.size):
            lvl = int(thin[lvl_idx])
            rows = levels.rows_in_level(lvl)
            moved_any = False
            for pos, r in enumerate(rows):
                r = int(r)
                res = store.rewrite_to_level(r, target)
                c = res.paper_cost
                if target_cost + c <= avg:
                    store.commit(r, target, res)
                    target_cost += c
                    stats.rows_rewritten += 1
                    moved_any = True
                else:
                    # re-target at this level: remaining rows stay here
                    target = lvl
                    target_cost = float(
                        sum(store.row_paper_cost(int(q)) for q in rows[pos:]))
                    break
            del moved_any
        stats.substitutions = store.total_subs
        stats.max_rewrite_distance = store.max_rewrite_distance
        stats.max_abs_coef = store.max_abs_coef_seen
        return stats


class ManualEveryK:
    """Prior-work [12] manual strategy, automated the way the paper applies it:

    Among the thin levels (paper, torso2: "we picked all levels with a cost
    smaller than avgLevelCost and rewrote every 9 level of these to the
    10th"), take consecutive groups of k; the FIRST level of each group is the
    target; ALL rows of the remaining k-1 levels are rewritten into it,
    unconditionally (no cost cap — which is exactly why this strategy inflates
    torso2's total cost by ~40% in the paper's Table I).
    """

    name = "manual_every_k"

    def __init__(self, k: int = 10, max_gap: int = 1):
        self.k = k
        self.max_gap = max_gap  # paper: "levels close to each other are
        #                          prioritized to form groups"
        self.label = f"manual_every_k(k={k},gap={max_gap})"

    def apply(self, store: EquationStore, view: GraphView) -> StrategyStats:
        stats = StrategyStats()
        thin = view.thin_levels()
        if thin.size < 2:
            return stats
        levels = view.levels
        # split the thin list into runs of near-consecutive levels, then
        # group every k levels within a run
        runs: list[list[int]] = [[int(thin[0])]]
        for lvl in thin[1:]:
            if int(lvl) - runs[-1][-1] <= self.max_gap:
                runs[-1].append(int(lvl))
            else:
                runs.append([int(lvl)])
        for run in runs:
            for g in range(0, len(run), self.k):
                group = run[g:g + self.k]
                if len(group) < 2:
                    continue
                target = group[0]
                for lvl in group[1:]:
                    for r in levels.rows_in_level(lvl):
                        r = int(r)
                        res = store.rewrite_to_level(r, target)
                        store.commit(r, target, res)
                        stats.rows_rewritten += 1
        stats.substitutions = store.total_subs
        stats.max_rewrite_distance = store.max_rewrite_distance
        stats.max_abs_coef = store.max_abs_coef_seen
        return stats


class CriticalPathRewrite:
    """Beyond-paper: §III.A proposal (2) — "rewrite if row is on critical
    path".

    The DAG's depth is set by rows with depth(i) + height(i) == depth_max.
    Each round rewrites every critical row in the DEEPEST level upward by at
    most `beta` levels (subject to an in-degree cap); if afterwards the
    recomputed depth did not shrink, the round is a fixpoint and we stop.
    Unlike avgLevelCost this touches only rows that actually gate the
    synchronization count, so rows-rewritten is minimal per level removed.
    """

    name = "critical_path"

    def __init__(self, beta: int = 8, alpha: int = 32,
                 max_rounds: int = 10_000):
        self.beta, self.alpha, self.max_rounds = beta, alpha, max_rounds
        self.label = (f"critical_path(beta={beta},alpha={alpha},"
                      f"rounds={max_rounds})")

    def apply(self, store: EquationStore, view: GraphView) -> StrategyStats:
        stats = StrategyStats()
        level_of = store.level_of
        for _ in range(self.max_rounds):
            depth = int(level_of.max())
            if depth == 0:
                break
            deepest = np.flatnonzero(level_of == depth)
            target = max(0, depth - self.beta)
            moved = False
            for r in deepest:
                r = int(r)
                res = store.rewrite_to_level(r, target)
                if self.alpha is not None and res.indegree > self.alpha:
                    stats.rows_skipped_constraint += 1
                    continue
                store.commit(r, target, res)
                stats.rows_rewritten += 1
                moved = True
            if not moved:
                break
        stats.substitutions = store.total_subs
        stats.max_rewrite_distance = store.max_rewrite_distance
        stats.max_abs_coef = store.max_abs_coef_seen
        return stats


class ConstrainedAvgLevelCost:
    """Beyond-paper: AvgLevelCost + the §III.A constraints.

    alpha:      max in-degree of a rewritten row (paper: "rewrite if row's
                indegree < alpha") — also caps cost/precision growth.
    beta:       max rewrite distance in levels ("distance between indegrees"
                is a locality proxy; we use level distance, the quantity the
                paper's own limitation discussion centres on).
    coef_cap:   max |coefficient| growth factor vs the original matrix
                (numerical-stability guard, paper §IV Fig. 3 observation).
    update_avg: recompute the average as levels are deleted (ablation of the
                paper's "avgLevelCost kept fixed" choice).
    """

    name = "constrained_avg"

    def __init__(self, alpha: int | None = 8, beta: int | None = 64,
                 coef_cap: float | None = 1e6, update_avg: bool = False):
        self.alpha, self.beta, self.coef_cap = alpha, beta, coef_cap
        self.update_avg = update_avg
        cap = "none" if coef_cap is None else f"{coef_cap:g}"
        self.label = (f"constrained_avg(a={alpha},b={beta},"
                      f"c={cap},dyn={int(update_avg)})")

    def apply(self, store: EquationStore, view: GraphView) -> StrategyStats:
        stats = StrategyStats()
        base_coef = float(np.abs(view.L.data).max()) if view.L.nnz else 1.0
        avg = view.avg_level_cost
        thin = view.thin_levels()
        if thin.size < 2:
            return stats
        levels = view.levels
        total_cost = float(view.total_cost)
        n_levels = view.num_levels
        target = int(thin[0])
        target_cost = float(view.level_cost[target])
        for lvl_idx in range(1, thin.size):
            lvl = int(thin[lvl_idx])
            rows = levels.rows_in_level(lvl)
            emptied = True
            for pos, r in enumerate(rows):
                r = int(r)
                if self.beta is not None and lvl - target > self.beta:
                    stats.rows_skipped_constraint += len(rows) - pos
                    emptied = False
                    target, target_cost = lvl, float(
                        sum(store.row_paper_cost(int(q)) for q in rows[pos:]))
                    break
                res = store.rewrite_to_level(r, target)
                if self.alpha is not None and res.indegree > self.alpha:
                    stats.rows_skipped_constraint += 1
                    emptied = False
                    continue
                if (self.coef_cap is not None
                        and res.max_abs_coef > self.coef_cap * base_coef):
                    stats.rows_skipped_constraint += 1
                    emptied = False
                    continue
                c = res.paper_cost
                if target_cost + c <= avg:
                    old_c = store.row_paper_cost(r)
                    store.commit(r, target, res)
                    target_cost += c
                    total_cost += c - old_c
                    stats.rows_rewritten += 1
                else:
                    target = lvl
                    target_cost = float(
                        sum(store.row_paper_cost(int(q)) for q in rows[pos:]))
                    emptied = False
                    break
            if emptied and self.update_avg:
                n_levels -= 1
                avg = total_cost / max(n_levels, 1)
        stats.substitutions = store.total_subs
        stats.max_rewrite_distance = store.max_rewrite_distance
        stats.max_abs_coef = store.max_abs_coef_seen
        return stats

"""Specialized C-source generator — the analogue of the paper's testbed.

The paper's SpTRSV implementation [12] emits specialized C code per matrix
(Fig. 3/4) and Table I reports "size of code (MB)".  Crucially the prototype
bakes the *numeric* right-hand side into the code: every rewritten row's
b-combination folds to a single constant (Fig. 3 middle/bottom show literal
constants).  That is why torso2's code size stays flat even though rewriting
adds b-side work.  We reproduce the metric exactly the same way: one statement
per row, constants folded against a sample b (default: b = ones), so

    code bytes  ~  f(nnz(A') + n)          — independent of the B' size.

The generated code is a metric artifact and a debugging aid; execution uses
the JAX level-scheduled solver (DESIGN.md §3).
"""
from __future__ import annotations

import numpy as np

from ..sparse.csr import CSR

__all__ = ["generate_c_source", "generated_code_bytes"]


def _const_row(i: int, c: np.ndarray | None) -> str:
    # folded constant for row i (baked b); without a preamble vector the
    # constant is b[i] itself — emit the literal the paper's codegen would.
    if c is None:
        return f"b{i}_"
    return f"{c[i]:.17g}"


def generate_c_source(A: CSR, c: np.ndarray | None, d: np.ndarray,
                      level_of: np.ndarray,
                      max_rows: int | None = None) -> str:
    """Emit specialized forward-substitution C source, one function per level.

    Row statement (rearranged Lx=b form, paper Fig. 3 middle/bottom):
        x[i] = (CONST - a0*x[c0] - a1*x[c1] ...) / DIAG;
    `c` is the folded preamble constant vector (B'b for a sample b); pass
    None to emit symbolic placeholders.
    """
    n = A.n_rows
    num_levels = int(level_of.max()) + 1 if n else 0
    order = np.lexsort((np.arange(n), level_of))
    out: list[str] = []
    emitted = 0
    pos = 0
    for lvl in range(num_levels):
        out.append(f"void calculate{lvl}(double* x) {{\n")
        while pos < n and level_of[order[pos]] == lvl:
            i = int(order[pos]); pos += 1
            acols, avals = A.row(i)
            terms = "".join(f"-{v:.17g}*x[{int(cc)}]"
                            for cc, v in zip(acols, avals))
            out.append(f"  x[{i}] = ({_const_row(i, c)}{terms})/{d[i]:.17g};\n")
            emitted += 1
            if max_rows is not None and emitted >= max_rows:
                out.append("}\n")
                return "".join(out)
        out.append("}\n")
    return "".join(out)


def generated_code_bytes(A: CSR, c: np.ndarray | None, d: np.ndarray,
                         level_of: np.ndarray) -> int:
    """Byte size of the specialized source, computed without materializing
    one giant string.

    Vectorized: every row statement is scaffold + folded constant + one
    "-%.17g*x[%d]" term per A' entry + "/%.17g;".  Constant and coefficient
    literals are length-estimated at the %.17g average (float64 random values
    format to ~18-19 chars; we use the exact lengths for the index digits and
    a calibrated 19 for value literals — the same estimator is applied to all
    strategies, so Table-I ratios are unaffected).
    """
    n = A.n_rows
    num_levels = int(level_of.max()) + 1 if n else 0
    VAL = 19  # average %.17g literal length for float64
    digits_idx = np.char.str_len(np.arange(n).astype("U"))
    # per-level function scaffolding
    total = sum(len(f"void calculate{lvl}(double* x) {{\n}}\n")
                for lvl in range(num_levels))
    # per-row scaffold: "  x[i] = (" + CONST + ")/" + VAL + ";\n"
    total += int(np.sum(10 + digits_idx + VAL + 2 + VAL + 2))
    # per-entry terms: "-" + VAL + "*x[" + digits(col) + "]"
    if A.nnz:
        total += int(np.sum(1 + VAL + 3 + digits_idx[A.indices] + 1))
    return total

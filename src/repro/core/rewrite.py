"""Equation-rewriting engine (paper §II.B) with rearrangement.

Canonical row form (semantics of the triple (A, B, d)):

    d_i * x_i + sum_l A[i,l] * x_l  =  sum_k B[i,k] * b_k
    =>  x_i = ( B_i . b  -  A_i . x ) / d_i

The original system Lx=b is the special case A = strict-lower(L), B = I.

Substituting a dependency x_j out of row i ("rewriting", paper Fig. 2) with
rearrangement (grouping common multipliers — paper §II.B) is one exact sparse
elimination step with multiplier s = A[i,j]/d_j:

    A[i,l] -= s * A[j,l]     (A[i,j] -> 0)
    B[i,k] -= s * B[j,k]

Representation choice (performance-critical): the x-side (A) is materialized
eagerly — it is what the paper's cost model measures — while the b-side is
recorded as one-step *elimination pairs* (j, s).  Stacked, the pairs form a
strictly-lower-triangular factor T with

    B' = (I + T)^{-1}        (unit-triangular inverse)

so the solve preamble c = B'b is itself a cheap sparse triangular solve
(I+T)c = b with nnz(T) = number of substitutions.  B' rows can optionally be
materialized (`materialize_b`) when rewrite distances are modest; for
unbounded faithful runs on torso2-scale graphs B' rows are dense-ish and the
T-factor path is the only tractable one.  The paper's own prototype sidesteps
this entirely by baking the numeric b into generated code — our codegen
reproduces that for the code-size metric (see codegen.py).

Expansion closures are memoized per target cutoff (the paper's "costMap" made
exact): rewrite(j, target) — row j's equation with all deps < target — is
mathematically unique no matter when it is computed (substitution is exact
algebra and rows only move to earlier levels), so entries never go stale
within one cutoff.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..sparse.csr import CSR

__all__ = ["EquationStore", "RewriteResult"]


@dataclasses.dataclass
class RewriteResult:
    """Outcome of a tentative rewrite of one row to a target level."""
    A: dict[int, float]
    elim: list[tuple[int, float]]   # one-step elimination pairs (row, s)
    n_subs: int                     # substitutions in this expansion
    max_abs_coef: float             # max |A coefficient| (stability proxy)

    @property
    def paper_cost(self) -> int:
        return 2 * len(self.A) + 1

    @property
    def indegree(self) -> int:
        return len(self.A)


class EquationStore:
    """Current equations of the system being transformed.

    Unmodified rows are materialized lazily from the CSR matrix; rewritten
    rows live in python dicts.  `level_of` is the *current* level assignment
    (mutated by strategies as rows move).
    """

    def __init__(self, L: CSR, level_of: np.ndarray):
        self.L = L
        self.diag = L.diagonal_fast()
        if np.any(self.diag == 0):
            raise ValueError("zero diagonal — not a valid triangular system")
        self.level_of = level_of.copy()
        self._rew_A: dict[int, dict[int, float]] = {}
        # Persisted elimination recursion (the T-factor), entity-indexed.
        # Entities 0..n-1 are the original rows; auxiliary entities (one per
        # (closure node, cutoff) pair — a node expanded under two different
        # target cutoffs has two *different* valid (A, b-combination) forms,
        # so each cutoff gets its own entity) are appended after.
        self._ent_elim: dict[int, list[tuple[int, float]]] = {}  # ent -> pairs
        self._aux_src: list[int] = []                 # aux entity -> src row
        self._aux_index: dict[tuple, int] = {}        # (row, cutoff) -> ent
        self._commit_version: dict[int, int] = {}     # row -> re-commit count
        self.rows_rewritten: set[int] = set()
        # memoized expansion closures, keyed per target cutoff (paper costMap)
        self._memo: dict[int, tuple[dict, list]] = {}
        self._memo_target: int = -1
        self._memo_subs: int = 0
        self.total_subs = 0
        self.max_rewrite_distance = 0
        self.max_abs_coef_seen = float(np.abs(L.data).max()) if L.nnz else 0.0
        # ordered (row, target) commit log — the pattern-frozen replay plan:
        # re-running exactly these commits against a matrix with the same
        # pattern (new values) reproduces the transformation numerically
        # without consulting any strategy (core.transform.replay_transform)
        self.commit_log: list[tuple[int, int]] = []

    # -- row access ----------------------------------------------------------
    def deps(self, i: int) -> dict[int, float]:
        """Strict-lower coefficients of row i (current equation)."""
        got = self._rew_A.get(i)
        if got is not None:
            return got
        cols, vals = self.L.row(i)
        return {int(c): float(v) for c, v in zip(cols, vals) if c != i}

    def indegree(self, i: int) -> int:
        got = self._rew_A.get(i)
        if got is not None:
            return len(got)
        return int(self.L.indptr[i + 1] - self.L.indptr[i]) - 1

    def row_paper_cost(self, i: int) -> int:
        return 2 * self.indegree(i) + 1

    # -- rewriting -----------------------------------------------------------
    def rewrite_to_level(self, i: int, target: int) -> RewriteResult:
        """Tentatively rewrite row i so all remaining deps have level < target.

        Does NOT commit; call `commit` with the result to apply.
        """
        if self._memo_target != target:
            self._memo = {}
            self._memo_target = target
        before = self._memo_subs
        A, elim = self._expand(i, target, memoize_root=False)
        n_subs = self._memo_subs - before
        mx = max((abs(v) for v in A.values()), default=0.0)
        return RewriteResult(A=A, elim=elim, n_subs=n_subs, max_abs_coef=mx)

    def _expand(self, root: int, target: int, memoize_root: bool = True):
        """(A, elim) of row `root` with all deps at level < target.

        Iterative post-order over the >=target dependency closure with an
        explicit stack (chains can be hundreds of levels deep).
        """
        memo = self._memo
        got = memo.get(root)
        if got is not None:
            return dict(got[0]), got[1]
        level_of = self.level_of
        stack = [root]
        while stack:
            j = stack[-1]
            if j in memo:  # duplicate push (shared dep) — already resolved
                stack.pop()
                continue
            deps_j = self.deps(j)
            pend = [k for k in deps_j
                    if level_of[k] >= target and k not in memo]
            if pend:
                stack.extend(pend)
                continue
            stack.pop()
            A = dict(deps_j)
            elim: list[tuple[int, float]] = []
            for k in [k for k in A if level_of[k] >= target]:
                s = A.pop(k) / self.diag[k]
                elim.append((k, s))
                Ak, _ = memo[k]
                for l, a in Ak.items():
                    v = A.get(l, 0.0) - s * a
                    if v == 0.0:
                        A.pop(l, None)
                    else:
                        A[l] = v
                self._memo_subs += 1
            if j == root and not memoize_root:
                return A, elim
            memo[j] = (A, elim)
        A, elim = memo[root]
        return dict(A), elim

    def commit(self, i: int, target: int, res: RewriteResult) -> None:
        """Apply a tentative rewrite: move row i to `target`.

        Persists the elimination pairs of i and of every auxiliary closure
        node reachable from them (so the T-factor can rebuild B'b for any b
        after the transient per-target memo is gone).
        """
        dist = int(self.level_of[i]) - target
        resolved = self._resolve_pairs(res.elim, target)
        self._rew_A[i] = res.A
        # a re-commit (row rewritten again at a lower cutoff — e.g. the
        # critical-path strategy) eliminates INCREMENTALLY from the committed
        # form, so its pairs APPEND to the existing recursion
        self._ent_elim[i] = self._ent_elim.get(i, []) + resolved
        self._commit_version[i] = self._commit_version.get(i, 0) + 1
        self.level_of[i] = target
        self.rows_rewritten.add(i)
        self.commit_log.append((int(i), int(target)))
        self.total_subs += res.n_subs
        self.max_rewrite_distance = max(self.max_rewrite_distance, dist)
        self.max_abs_coef_seen = max(self.max_abs_coef_seen, res.max_abs_coef)

    def _resolve_pairs(self, elim: list[tuple[int, float]],
                       cutoff: int) -> list[tuple[int, float]]:
        """Map raw elimination pairs (row, s) to entity ids, creating
        auxiliary entities for uncommitted closure nodes at this cutoff.

        Committed rows resolve to an immutable SNAPSHOT of their current
        recursion (a strategy may re-rewrite a committed row at a lower
        cutoff later — critical-path does — which appends to the row's own
        entity; earlier references must keep the old meaning).
        """
        n = self.L.n_rows
        rew, aux, memo = self._rew_A, self._aux_index, self._memo

        def snap(k: int) -> int:
            """Immutable copy of a committed row's current recursion."""
            key = ("snap", k, self._commit_version.get(k, 0))
            ent = aux.get(key)
            if ent is None:
                ent = n + len(self._aux_src)
                aux[key] = ent
                self._aux_src.append(k)
                self._ent_elim[ent] = list(self._ent_elim.get(k, []))
            return ent

        def needs_further(k: int) -> bool:
            # a committed row eliminated at a cutoff BELOW its commit level
            # was expanded further: its aux entity must chain the committed
            # recursion with the additional eliminations
            got = memo.get(k)
            return got is not None and bool(got[1])

        def akey(k: int):
            return (k, cutoff, self._commit_version.get(k, 0))

        def ref(k: int) -> int:
            if k in rew and not needs_further(k):
                return snap(k)
            return aux[akey(k)]

        def pend_of(k: int) -> bool:
            return akey(k) not in aux and (k not in rew or needs_further(k))

        # ensure aux entities exist for the whole closure (iterative
        # post-order; chains can be hundreds of levels deep)
        stack = [k for k, _ in elim if pend_of(k)]
        while stack:
            k = stack[-1]
            if akey(k) in aux:
                stack.pop()
                continue
            pend = [kk for kk, _ in memo[k][1] if pend_of(kk)]
            if pend:
                stack.extend(pend)
                continue
            stack.pop()
            ent = n + len(self._aux_src)
            aux[akey(k)] = ent
            self._aux_src.append(k)
            base = list(self._ent_elim.get(k, [])) if k in rew else []
            self._ent_elim[ent] = base + [(ref(kk), s)
                                          for kk, s in memo[k][1]]
        return [(ref(k), s) for k, s in elim]

    # -- export ---------------------------------------------------------------
    def export(self) -> tuple[CSR, CSR, np.ndarray, np.ndarray]:
        """Assemble (A', T, src, d).

        T is the entity-indexed elimination factor: entities [0, n) are the
        original rows, entities [n, n_ent) are auxiliary (closure node,
        cutoff) pairs; `src` maps entity -> original row.  The preamble
        c = B'b solves (I+T)c = b[src] in src-ascending entity order (every
        reference points to a strictly smaller original row).
        """
        n = self.L.n_rows
        indptr, indices, data = self.L.indptr, self.L.indices, self.L.data
        # A' — vectorized fast path for untouched rows
        a_rows, a_cols, a_vals = [], [], []
        rew = self._rew_A
        for i in sorted(rew):
            got = rew[i]
            for c in sorted(got):
                a_rows.append(i); a_cols.append(c); a_vals.append(got[c])
        touched = np.zeros(n, dtype=bool)
        if rew:
            touched[np.fromiter(rew.keys(), dtype=np.int64)] = True
        all_rows = np.repeat(np.arange(n), np.diff(indptr))
        keep = (~touched[all_rows]) & (indices != all_rows)
        from ..sparse.csr import from_coo
        rows_np = np.concatenate([all_rows[keep],
                                  np.asarray(a_rows, dtype=np.int64)])
        cols_np = np.concatenate([indices[keep],
                                  np.asarray(a_cols, dtype=np.int64)])
        vals_np = np.concatenate([data[keep],
                                  np.asarray(a_vals, dtype=np.float64)])
        A = from_coo(rows_np, cols_np, vals_np, self.L.shape,
                     sum_duplicates=False)
        # T factor over entities
        n_ent = n + len(self._aux_src)
        t_rows, t_cols, t_vals = [], [], []
        for e, pairs in self._ent_elim.items():
            for k, s in pairs:
                t_rows.append(e); t_cols.append(k); t_vals.append(s)
        T = from_coo(t_rows, t_cols, t_vals, (n_ent, n_ent),
                     sum_duplicates=False)
        src = np.concatenate([np.arange(n, dtype=np.int64),
                              np.asarray(self._aux_src, dtype=np.int64)])
        return A, T, src, self.diag.copy()

    @staticmethod
    def preamble_from_T(T: CSR, src: np.ndarray, b: np.ndarray) -> np.ndarray:
        """c[:n] with (I+T)c = b[src]; processed in src-ascending order."""
        n = b.shape[0]
        c = np.asarray(b)[src].astype(np.result_type(T.data, b), copy=True)
        nz = np.flatnonzero(T.row_nnz() > 0)
        order = nz[np.argsort(src[nz], kind="stable")]
        indptr, indices, data = T.indptr, T.indices, T.data
        for e in order:
            lo, hi = indptr[e], indptr[e + 1]
            c[e] = b[src[e]] - data[lo:hi] @ c[indices[lo:hi]]
        return c[:n]

    def materialize_b(self, T: CSR, src: np.ndarray,
                      max_entries: int = 50_000_000) -> CSR:
        """B' rows = unit-triangular inverse rows of (I+T), mapped back to
        original-row space; tractable for modest rewrite distances."""
        n = self.L.n_rows
        brows: dict[int, dict[int, float]] = {}
        total = 0
        nz = np.flatnonzero(T.row_nnz() > 0)
        order = nz[np.argsort(src[nz], kind="stable")]
        from ..sparse.csr import from_coo
        for e in order:
            cols, vals = T.row(int(e))
            B = {int(src[e]): 1.0}
            for k, s in zip(cols, vals):
                Bk = brows.get(int(k))
                if Bk is None:
                    l = int(src[k])
                    v = B.get(l, 0.0) - s
                    if v == 0.0:
                        B.pop(l, None)
                    else:
                        B[l] = v
                else:
                    for l, bv in Bk.items():
                        v = B.get(l, 0.0) - s * bv
                        if v == 0.0:
                            B.pop(l, None)
                        else:
                            B[l] = v
            brows[int(e)] = B
            total += len(B)
            if total > max_entries:
                raise MemoryError(
                    f"B' materialization exceeds {max_entries} entries; "
                    "use the T-factor preamble instead")
        b_rows, b_cols, b_vals = [], [], []
        for i in range(n):
            Bi = brows.get(i)
            if Bi is None or i not in self.rows_rewritten:
                b_rows.append(i); b_cols.append(i); b_vals.append(1.0)
            else:
                for col in sorted(Bi):
                    b_rows.append(i); b_cols.append(col); b_vals.append(Bi[col])
        return from_coo(b_rows, b_cols, b_vals, self.L.shape,
                        sum_duplicates=False)

"""Transformation driver: strategy -> TransformedSystem (A', T, d, levels).

The transformed system solves Lx=b for ANY b:

    c = B' @ b  where  B' = (I + T)^{-1}      (preamble; see rewrite.py)
    for each level (in order):
        x[rows] = (c[rows] - A'[rows,:] @ x) / d[rows]

The preamble has two realizations:
  * T-factor: solve (I+T)c = b — nnz(T) = #substitutions, always tractable,
    but depth = original elimination depth (cheap, tiny width).
  * materialized B': a dependency-free SpMV — fully parallel, but B' rows can
    be large for long rewrite distances (the paper hides this by baking the
    numeric b into generated code; Table-I costs charge neither, and we report
    `operator_total_cost_after` so the any-b overhead is visible).

Two level assignments are carried:
  * `assigned`  — the paper's bookkeeping (rows land exactly on their target
    level; emptied levels deleted).  Used for Table-I-comparable metrics.
  * `recomputed` — true dependency levels of A' (never more levels than
    assigned; rows whose deps were fully eliminated drop to level 0).  Used by
    the solver schedule (beyond-paper freebie, flag-selectable).

The full pipeline (EquationStore -> strategy -> transform -> schedule
compiler -> engines) is documented in docs/architecture.md; per-strategy
selection guidance lives in docs/strategies.md.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..sparse.csr import CSR
from ..sparse.levels import LevelSets, build_levels
from .graph import GraphView
from .resilience import PatternMismatchError
from .rewrite import EquationStore
from .strategies import Strategy, StrategyStats, strategy_label

__all__ = ["TransformedSystem", "transform", "TransformMetrics",
           "ReplayPlan", "replay_transform"]


@dataclasses.dataclass(frozen=True)
class TransformMetrics:
    strategy: str
    num_levels_before: int
    num_levels_after: int
    num_levels_recomputed: int
    avg_level_cost_before: float
    avg_level_cost_after: float
    total_level_cost_before: int
    total_level_cost_after: int
    operator_total_cost_after: int   # charges the T-factor preamble (any-b)
    rows_rewritten: int
    rows_skipped_constraint: int
    substitutions: int
    max_rewrite_distance: int
    max_abs_coef: float
    code_bytes_before: int
    code_bytes_after: int
    nnz_A: int
    nnz_T: int

    def table1_row(self) -> dict:
        b, a = self.num_levels_before, self.num_levels_after
        return {
            "strategy": self.strategy,
            "num_levels": a,
            "levels_reduction_pct": 100.0 * (b - a) / b if b else 0.0,
            "avg_level_cost": self.avg_level_cost_after,
            "avg_cost_ratio": (self.avg_level_cost_after
                               / self.avg_level_cost_before
                               if self.avg_level_cost_before else 0.0),
            "total_level_cost": self.total_level_cost_after,
            "total_cost_delta_pct": (100.0 * (self.total_level_cost_after
                                              - self.total_level_cost_before)
                                     / self.total_level_cost_before),
            "code_MB": self.code_bytes_after / 1e6,
            "rows_rewritten": self.rows_rewritten,
        }


@dataclasses.dataclass(frozen=True)
class ReplayPlan:
    """Frozen transformation decisions, for pattern-frozen refactorization.

    A strategy's decisions — which rows move and to which target level, in
    which order — depend on the sparsity pattern (and, for the constrained
    strategy, on coefficient magnitudes, which is why the plan records the
    *outcome*, not the policy).  Replaying exactly these commits against a
    same-pattern matrix with new values re-runs only the numeric
    elimination algebra: no level analysis, no strategy, no tuner.
    """
    level_of0: np.ndarray               # pre-strategy level assignment
    commits: tuple[tuple[int, int], ...]  # ordered (row, target) commits


@dataclasses.dataclass(frozen=True)
class TransformedSystem:
    """(A', T, src, d) + level schedule for the transformed solve."""
    A: CSR                      # strict-lower dependency coefficients
    T: CSR                      # entity-indexed elim factor (rewrite.py)
    src: np.ndarray             # entity -> original row
    diag: np.ndarray            # diagonal of L
    level_of_assigned: np.ndarray
    level_of_recomputed: np.ndarray
    metrics: TransformMetrics
    B: CSR | None = None        # materialized B' (optional)
    plan: ReplayPlan | None = None  # replay plan (replay_transform)

    def levelsets(self, assigned: bool = False) -> LevelSets:
        lof = self.level_of_assigned if assigned else self.level_of_recomputed
        n = lof.shape[0]
        order = np.lexsort((np.arange(n), lof))
        num = int(lof.max()) + 1 if n else 0
        counts = np.bincount(lof, minlength=num)
        ptr = np.zeros(num + 1, dtype=np.int64)
        ptr[1:] = np.cumsum(counts)
        return LevelSets(level_of=lof, order=order, level_ptr=ptr)

    def preamble(self, b: np.ndarray) -> np.ndarray:
        """c = B'b via the T-factor (unit-triangular solve over entities)."""
        if self.T.nnz == 0:
            return np.asarray(b, dtype=np.result_type(self.T.data, b)).copy()
        from .rewrite import EquationStore
        return EquationStore.preamble_from_T(self.T, self.src, b)

    @property
    def identity_preamble(self) -> bool:
        return self.T.nnz == 0


def _compact_levels(level_of: np.ndarray) -> np.ndarray:
    """Delete empty levels: relabel to consecutive ids preserving order."""
    used = np.unique(level_of)
    remap = np.zeros(used.max() + 1, dtype=np.int64) if used.size else np.zeros(0, np.int64)
    remap[used] = np.arange(used.size)
    return remap[level_of]


def _paper_costs(A: CSR, level_of: np.ndarray) -> tuple[np.ndarray, int]:
    """Per-level paper cost given strict-lower dep matrix A'."""
    deps = A.row_nnz()
    rc = 2 * deps + 1
    num = int(level_of.max()) + 1 if level_of.size else 0
    lc = np.zeros(num, dtype=np.int64)
    np.add.at(lc, level_of, rc)
    return lc, int(rc.sum())


def transform(L: CSR, strategy: Strategy, validate: bool = True,
              codegen: bool = True, materialize_b: bool = False,
              rng_seed: int = 0) -> TransformedSystem:
    view = GraphView(L)
    store = EquationStore(L, view.levels.level_of)
    stats: StrategyStats = strategy.apply(store, view)
    A, T, src, d = store.export()

    assigned = _compact_levels(store.level_of)
    # recomputed: true dependency depth of A'
    recomputed = _recompute_levels(A)
    # invariants
    assert int(recomputed.max(initial=0)) <= int(assigned.max(initial=0)), \
        "recomputed levels must never exceed assigned"
    _check_level_validity(A, assigned)

    lc_after, total_after = _paper_costs(A, assigned)
    num_after = int(lc_after.shape[0])
    # operator cost: the T-factor preamble charges 2*nnz per applied row
    op_total = total_after + int(2 * T.nnz)

    from .codegen import generated_code_bytes
    cb_before = generated_code_bytes(
        _strict_lower_csr(L), None, L.diagonal_fast(),
        view.levels.level_of) if codegen else 0
    cb_after = generated_code_bytes(A, None, d, assigned) if codegen else 0

    metrics = TransformMetrics(
        strategy=strategy_label(strategy),
        num_levels_before=view.num_levels,
        num_levels_after=num_after,
        num_levels_recomputed=int(recomputed.max(initial=-1)) + 1,
        avg_level_cost_before=view.avg_level_cost,
        avg_level_cost_after=total_after / max(num_after, 1),
        total_level_cost_before=view.total_cost,
        total_level_cost_after=total_after,
        operator_total_cost_after=op_total,
        rows_rewritten=stats.rows_rewritten,
        rows_skipped_constraint=stats.rows_skipped_constraint,
        substitutions=stats.substitutions,
        max_rewrite_distance=stats.max_rewrite_distance,
        max_abs_coef=stats.max_abs_coef,
        code_bytes_before=cb_before,
        code_bytes_after=cb_after,
        nnz_A=A.nnz, nnz_T=T.nnz,
    )
    B = store.materialize_b(T, src) if materialize_b else None
    plan = ReplayPlan(level_of0=view.levels.level_of.copy(),
                      commits=tuple(store.commit_log))
    ts = TransformedSystem(A=A, T=T, src=src, diag=d,
                           level_of_assigned=assigned,
                           level_of_recomputed=recomputed, metrics=metrics,
                           B=B, plan=plan)
    if validate:
        _validate_equivalence(L, ts, rng_seed)
    return ts


def replay_transform(L_new: CSR, ts: TransformedSystem,
                     where: str = "replay_transform") -> TransformedSystem:
    """Re-run a frozen transformation against new values on the same pattern.

    Replays `ts.plan` (the committed (row, target) sequence) through a fresh
    EquationStore on `L_new` — pure numeric elimination over decisions that
    are already made, so level analysis (`GraphView`/`build_levels`), the
    strategy, and validation solves are all skipped.  The exported A'/T/src
    patterns are verified against the frozen ones: an exact floating-point
    cancellation in the new values can change the rewritten system's fill,
    and packing drifted values into the frozen schedule would be a finite
    but wrong answer — so drift raises `PatternMismatchError` instead.

    The caller is responsible for checking that `L_new`'s pattern matches
    the matrix `ts` was built from (`sparse.csr.same_pattern`); this
    function only has the transformed system to compare against.
    """
    plan = ts.plan
    if plan is None:
        raise ValueError(
            f"{where}: TransformedSystem carries no ReplayPlan (built before "
            "the refactorization fast path existed) — rebuild with "
            "transform()/from_csr()")
    if L_new.n_rows != ts.diag.shape[0]:
        raise PatternMismatchError(
            f"matrix has {L_new.n_rows} rows, frozen system has "
            f"{ts.diag.shape[0]}", where=where, detail="shape")
    store = EquationStore(L_new, plan.level_of0)
    for i, target in plan.commits:
        res = store.rewrite_to_level(i, target)
        store.commit(i, target, res)
    A, T, src, d = store.export()
    from ..sparse.csr import same_pattern
    if not (same_pattern(A, ts.A) and same_pattern(T, ts.T)
            and np.array_equal(src, ts.src)):
        raise PatternMismatchError(
            "replayed transformation produced different fill than the frozen "
            "system (an exact cancellation changed the rewritten pattern) — "
            "rebuild with transform()/from_csr()",
            where=where, detail="transformed-pattern drift")
    metrics = dataclasses.replace(ts.metrics,
                                  max_abs_coef=store.max_abs_coef_seen)
    B = store.materialize_b(T, src) if ts.B is not None else None
    return dataclasses.replace(ts, A=A, T=T, src=src, diag=d,
                               metrics=metrics, B=B)


def _strict_lower_csr(L: CSR) -> CSR:
    from ..sparse.csr import tril
    return tril(L, keep_diagonal=False)


def _recompute_levels(A: CSR) -> np.ndarray:
    """Dependency depth over A' (strict lower by construction — substitution
    only reaches earlier rows)."""
    assert A.nnz == 0 or bool((A.indices < np.repeat(
        np.arange(A.n_rows), A.row_nnz())).all()), "A' not strict lower"
    lv = build_levels(_with_diag(A))
    return lv.level_of


def _with_diag(A: CSR) -> CSR:
    """A' + unit diagonal so the level-set builder (which expects a full
    triangular matrix) applies."""
    from ..sparse.csr import from_coo
    rows = np.repeat(np.arange(A.n_rows), A.row_nnz())
    rows = np.concatenate([rows, np.arange(A.n_rows)])
    cols = np.concatenate([A.indices, np.arange(A.n_rows)])
    vals = np.concatenate([A.data, np.ones(A.n_rows)])
    return from_coo(rows, cols, vals, A.shape, sum_duplicates=False)


def _check_level_validity(A: CSR, level_of: np.ndarray) -> None:
    """Every dependency must live at a strictly lower level."""
    rows = np.repeat(np.arange(A.n_rows), A.row_nnz())
    if rows.size:
        assert (level_of[A.indices] < level_of[rows]).all(), \
            "level assignment violates dependencies"


def _validate_equivalence(L: CSR, ts: TransformedSystem, seed: int) -> None:
    """Transformed solve == original solve for random b (forward subst)."""
    from ..solver.reference import solve_csr_seq, solve_transformed_seq
    rng = np.random.default_rng(seed)
    b = rng.standard_normal(L.n_rows)
    x0 = solve_csr_seq(L, b)
    x1 = solve_transformed_seq(ts, b)
    scale = np.maximum(1.0, np.abs(x0).max())
    err = np.abs(x0 - x1).max() / scale
    assert err < 1e-8, f"transform changed the solution: rel err {err:.3e}"

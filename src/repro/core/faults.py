"""Fault-injection harness: chaos-test the solve path's resilience layer.

Each injector is a context manager that makes one failure class real —
NaN-poisoned schedule payloads, corrupt cache pickles, engines whose
compile fails, a mesh whose devices are gone — so `tests/test_resilience.py`
can prove every fault either recovers (via `repro.core.resilience`'s
guards and fallback chains) or raises a typed, actionable error:

    from repro.core import faults

    with faults.nan_schedule_payload():
        op = TriangularOperator.from_csr(L, cache=False)     # poisoned
        op.solve(b, health="fallback")         # recovers via host oracle

    with faults.fail_engine_compile("pallas-interpret"):
        op.solve(b, engine="pallas-interpret")  # downgrades to scan

Injectors patch the repo's own seams (schedule construction, the engine
registry, sharded lowering) — they never monkeypatch jax or numpy, so a
fault is scoped, deterministic, and cannot leak outside the context.
They are test/tooling utilities: nothing in the serving path imports this
module.
"""
from __future__ import annotations

import contextlib
import dataclasses
import pickle
from pathlib import Path

import numpy as np

__all__ = [
    "poison_schedule", "scale_schedule", "nan_schedule_payload",
    "wrong_schedule_values", "corrupt_values_payload", "pattern_drift",
    "corrupt_cache_entries", "fail_engine_compile",
    "engine_unavailable", "lose_mesh", "fail_tuner", "slow_tuner",
    "slow_step",
]


@contextlib.contextmanager
def _patched(obj, name: str, value):
    """Set an attribute for the context's duration; restores exactly the
    prior state (including 'attribute absent from the instance dict')."""
    missing = object()
    prior = obj.__dict__.get(name, missing) if hasattr(obj, "__dict__") \
        else getattr(obj, name, missing)
    setattr(obj, name, value)
    try:
        yield
    finally:
        if prior is missing:
            try:
                delattr(obj, name)
            except AttributeError:  # pragma: no cover - class attr shadowed
                pass
        else:
            setattr(obj, name, prior)


# -- schedule-payload faults --------------------------------------------------


def poison_schedule(sched, value: float = np.nan):
    """A copy of a LevelSchedule whose per-row 1/diag payload is `value`
    everywhere — every device solve through it emits `value`-poisoned
    output while shapes, steps, and engine lowering stay valid."""
    groups = tuple(
        dataclasses.replace(g, dinv=np.full_like(g.dinv, value))
        for g in sched.groups)
    return dataclasses.replace(sched, groups=groups)


def scale_schedule(sched, factor: float):
    """A copy with every 1/diag payload scaled by `factor`: a finite but
    WRONG schedule — the silent-wrong-answer fault class that only a
    residual check can catch."""
    groups = tuple(
        dataclasses.replace(g, dinv=g.dinv * factor) for g in sched.groups)
    return dataclasses.replace(sched, groups=groups)


@contextlib.contextmanager
def _schedule_fault(mutate):
    from ..solver import schedule as _sched
    real = _sched.schedule_for_transformed

    def faulty(*args, **kwargs):
        return mutate(real(*args, **kwargs))

    with _patched(_sched, "schedule_for_transformed", faulty):
        yield


def nan_schedule_payload(value: float = np.nan):
    """Every schedule compiled inside the context carries a non-finite
    payload (poison_schedule), so device solves produce NaN/Inf output."""
    return _schedule_fault(lambda s: poison_schedule(s, value))


def wrong_schedule_values(factor: float = 2.0):
    """Every schedule compiled inside the context is finitely WRONG
    (scale_schedule) — the solve succeeds, finiteness checks pass, and
    only a residual check against the original matrix can detect it."""
    return _schedule_fault(lambda s: scale_schedule(s, factor))


# -- refactorization faults ---------------------------------------------------


@contextlib.contextmanager
def corrupt_values_payload(value: float = np.nan):
    """Every schedule value-repack inside the context — the seam the
    `update_values` / `Preconditioner.refactor` fast path routes its
    numeric payload through — returns a `value`-poisoned schedule, so
    solves through the updated operator emit non-finite output unless a
    health guard catches them.  Yields {"calls": n} for asserting the
    fault actually fired."""
    from ..solver import schedule as _sched
    real = _sched.repack_schedule_values
    count = {"calls": 0}

    def faulty(sched, new_data, new_diag):
        count["calls"] += 1
        return poison_schedule(real(sched, new_data, new_diag), value)

    with _patched(_sched, "repack_schedule_values", faulty):
        yield count


def pattern_drift(L):
    """A same-shape, same-nnz copy of a CSR with ONE strict-lower entry's
    column silently shifted left — the pattern drift that value-level
    checks cannot see (finiteness, norms and fingerprint length all
    match).  update_values / refactor must reject it with a typed
    PatternMismatchError, never produce a finite wrong answer."""
    from ..sparse.csr import CSR
    indices = L.indices.copy()
    rows = np.repeat(np.arange(L.n_rows), np.diff(L.indptr))
    for p in range(L.nnz):
        c, r = int(indices[p]), int(rows[p])
        if not 0 < c < r:               # need a shiftable strict-lower entry
            continue
        if p > 0 and rows[p - 1] == r and indices[p - 1] == c - 1:
            continue                    # (r, c-1) occupied: stay sorted/unique
        indices[p] = c - 1
        return CSR(indptr=L.indptr, indices=indices, data=L.data.copy(),
                   shape=L.shape)
    raise ValueError("pattern_drift: no shiftable strict-lower entry "
                     "(matrix too small/diagonal)")


# -- cache faults -------------------------------------------------------------


def corrupt_cache_entries(cache_dir, mode: str = "garbage") -> list:
    """Corrupt every operator artifact under `cache_dir` in place.

    mode: "garbage"  — non-pickle bytes (torn write from a crashed
                       process without atomic replace),
          "truncate" — valid pickle prefix cut short (partial write),
          "stale"    — a well-formed pickle whose version field predates
                       CACHE_VERSION.
    Returns the corrupted paths.
    """
    paths = sorted(Path(cache_dir).glob("op-*.pkl"))
    for p in paths:
        if mode == "garbage":
            p.write_bytes(b"\x80\x05this is not a valid pickle stream")
        elif mode == "truncate":
            raw = p.read_bytes()
            p.write_bytes(raw[: max(1, len(raw) // 3)])
        elif mode == "stale":
            payload = pickle.loads(p.read_bytes())
            payload["version"] = -1
            p.write_bytes(pickle.dumps(payload))
        else:
            raise ValueError(f"unknown corruption mode {mode!r}")
    return paths


# -- engine faults ------------------------------------------------------------


@contextlib.contextmanager
def fail_engine_compile(name: str, times: int | None = None, exc=None):
    """The named REGISTERED engine's compile() raises for the first
    `times` calls inside the context (None = every call).  Yields a
    counter dict: {"calls": total compile calls, "failed": injected
    failures} for asserting the fault actually fired."""
    from ..solver.engines import get_engine
    eng = get_engine(name)
    real = eng.compile                  # bound method of the live instance
    count = {"calls": 0, "failed": 0}

    def faulty(dsched):
        count["calls"] += 1
        if times is None or count["calls"] <= times:
            count["failed"] += 1
            raise (exc if exc is not None else RuntimeError(
                f"injected compile failure in engine {name!r} "
                f"(call {count['calls']})"))
        return real(dsched)

    with _patched(eng, "compile", faulty):
        yield count


@contextlib.contextmanager
def engine_unavailable(name: str):
    """The named registered engine reports available() == False inside the
    context (e.g. "Pallas missing from this process")."""
    from ..solver.engines import get_engine
    eng = get_engine(name)
    with _patched(eng, "available", lambda: False):
        yield


# -- tuner faults -------------------------------------------------------------


@contextlib.contextmanager
def fail_tuner(exc=None):
    """Every `StrategyPortfolio.tune` call inside the context raises — the
    fault class a serving tier's BACKGROUND tuning worker must survive:
    admission already served the untuned operator, so a tuner blow-up may
    degrade the entry (no hot-swap, `TunerFailureWarning`) but must never
    poison it or block the request path.  Yields {"calls": n} for
    asserting the fault actually fired."""
    from .portfolio import StrategyPortfolio
    count = {"calls": 0}

    def faulty(self, L):
        count["calls"] += 1
        raise (exc if exc is not None else RuntimeError(
            f"injected tuner failure (call {count['calls']})"))

    with _patched(StrategyPortfolio, "tune", faulty):
        yield count


@contextlib.contextmanager
def slow_tuner(delay_s: float = 0.5):
    """Every `StrategyPortfolio.tune` call inside the context stalls for
    `delay_s` before running for real — the stalled-background-tuner fault:
    entries stay "warming" while requests keep flowing through the untuned
    operator, and the eventual hot-swap still lands.  Yields {"calls": n}."""
    import time
    from .portfolio import StrategyPortfolio
    real = StrategyPortfolio.tune
    count = {"calls": 0}

    def slow(self, L):
        count["calls"] += 1
        time.sleep(delay_s)
        return real(self, L)

    with _patched(StrategyPortfolio, "tune", slow):
        yield count


# -- profiler faults ----------------------------------------------------------


def slow_step(step_idx: int, seconds: float):
    """Every TIMED (non-warmup) pass of the `repro.obs.profile` schedule
    profiler inside the context stalls for `seconds` before executing step
    `step_idx` — the one-slow-step fault (a preempted core, a collective
    straggler) the per-step histogram must localize: the chaos test
    asserts `argmax(step_ms) == step_idx` and that the stall is visible
    inside the profile span's trace."""
    from ..obs import profile as _prof
    return _patched(_prof, "_STEP_FAULT",
                    (int(step_idx), float(seconds)))


# -- mesh faults --------------------------------------------------------------


@contextlib.contextmanager
def lose_mesh(exc=None):
    """Sharded lowering fails as if the mesh's devices were lost: every
    `lower_sharded` call inside the context raises.  Schedules the sharded
    engine lowered BEFORE the fault keep their memoized callables — a real
    device loss also only breaks new work, which is exactly what the
    fallback chain must cover."""
    from ..solver import distributed as _dist

    def faulty(*args, **kwargs):
        raise (exc if exc is not None else RuntimeError(
            "injected mesh device loss: sharded lowering unavailable"))

    with _patched(_dist, "lower_sharded", faulty):
        yield

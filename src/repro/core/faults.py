"""Fault-injection harness: chaos-test the solve path's resilience layer.

Each injector is a context manager that makes one failure class real —
NaN-poisoned schedule payloads, corrupt cache pickles, engines whose
compile fails, a mesh whose devices are gone — so `tests/test_resilience.py`
can prove every fault either recovers (via `repro.core.resilience`'s
guards and fallback chains) or raises a typed, actionable error:

    from repro.core import faults

    with faults.nan_schedule_payload():
        op = TriangularOperator.from_csr(L, cache=False)     # poisoned
        op.solve(b, health="fallback")         # recovers via host oracle

    with faults.fail_engine_compile("pallas-interpret"):
        op.solve(b, engine="pallas-interpret")  # downgrades to scan

Injectors patch the repo's own seams (schedule construction, the engine
registry, sharded lowering) — they never monkeypatch jax or numpy, so a
fault is scoped, deterministic, and cannot leak outside the context.
They are test/tooling utilities: nothing in the serving path imports this
module.
"""
from __future__ import annotations

import contextlib
import dataclasses
import pickle
from pathlib import Path

import numpy as np

__all__ = [
    "poison_schedule", "scale_schedule", "nan_schedule_payload",
    "wrong_schedule_values", "corrupt_values_payload", "pattern_drift",
    "corrupt_cache_entries", "fail_engine_compile",
    "engine_unavailable", "lose_mesh", "fail_tuner", "slow_tuner",
    "slow_step",
    # static defects the analysis verifier must reject (docs/analysis.md)
    "swap_schedule_steps", "duplicate_schedule_row", "oob_schedule_index",
    "corrupt_plan", "reorder_schedule_step", "duplicate_lane_row",
    "oob_ell_index", "corrupt_replay_plan",
]


@contextlib.contextmanager
def _patched(obj, name: str, value):
    """Set an attribute for the context's duration; restores exactly the
    prior state (including 'attribute absent from the instance dict')."""
    missing = object()
    prior = obj.__dict__.get(name, missing) if hasattr(obj, "__dict__") \
        else getattr(obj, name, missing)
    setattr(obj, name, value)
    try:
        yield
    finally:
        if prior is missing:
            try:
                delattr(obj, name)
            except AttributeError:  # pragma: no cover - class attr shadowed
                pass
        else:
            setattr(obj, name, prior)


# -- schedule-payload faults --------------------------------------------------


def poison_schedule(sched, value: float = np.nan):
    """A copy of a LevelSchedule whose per-row 1/diag payload is `value`
    everywhere — every device solve through it emits `value`-poisoned
    output while shapes, steps, and engine lowering stay valid."""
    groups = tuple(
        dataclasses.replace(g, dinv=np.full_like(g.dinv, value))
        for g in sched.groups)
    return dataclasses.replace(sched, groups=groups)


def scale_schedule(sched, factor: float):
    """A copy with every 1/diag payload scaled by `factor`: a finite but
    WRONG schedule — the silent-wrong-answer fault class that only a
    residual check can catch."""
    groups = tuple(
        dataclasses.replace(g, dinv=g.dinv * factor) for g in sched.groups)
    return dataclasses.replace(sched, groups=groups)


@contextlib.contextmanager
def _schedule_fault(mutate):
    from ..solver import schedule as _sched
    real = _sched.schedule_for_transformed

    def faulty(*args, **kwargs):
        return mutate(real(*args, **kwargs))

    with _patched(_sched, "schedule_for_transformed", faulty):
        yield


def nan_schedule_payload(value: float = np.nan):
    """Every schedule compiled inside the context carries a non-finite
    payload (poison_schedule), so device solves produce NaN/Inf output."""
    return _schedule_fault(lambda s: poison_schedule(s, value))


def wrong_schedule_values(factor: float = 2.0):
    """Every schedule compiled inside the context is finitely WRONG
    (scale_schedule) — the solve succeeds, finiteness checks pass, and
    only a residual check against the original matrix can detect it."""
    return _schedule_fault(lambda s: scale_schedule(s, factor))


# -- refactorization faults ---------------------------------------------------


@contextlib.contextmanager
def corrupt_values_payload(value: float = np.nan):
    """Every schedule value-repack inside the context — the seam the
    `update_values` / `Preconditioner.refactor` fast path routes its
    numeric payload through — returns a `value`-poisoned schedule, so
    solves through the updated operator emit non-finite output unless a
    health guard catches them.  Yields {"calls": n} for asserting the
    fault actually fired."""
    from ..solver import schedule as _sched
    real = _sched.repack_schedule_values
    count = {"calls": 0}

    def faulty(sched, new_data, new_diag):
        count["calls"] += 1
        return poison_schedule(real(sched, new_data, new_diag), value)

    with _patched(_sched, "repack_schedule_values", faulty):
        yield count


def pattern_drift(L):
    """A same-shape, same-nnz copy of a CSR with ONE strict-lower entry's
    column silently shifted left — the pattern drift that value-level
    checks cannot see (finiteness, norms and fingerprint length all
    match).  update_values / refactor must reject it with a typed
    PatternMismatchError, never produce a finite wrong answer."""
    from ..sparse.csr import CSR
    indices = L.indices.copy()
    rows = np.repeat(np.arange(L.n_rows), np.diff(L.indptr))
    for p in range(L.nnz):
        c, r = int(indices[p]), int(rows[p])
        if not 0 < c < r:               # need a shiftable strict-lower entry
            continue
        if p > 0 and rows[p - 1] == r and indices[p - 1] == c - 1:
            continue                    # (r, c-1) occupied: stay sorted/unique
        indices[p] = c - 1
        return CSR(indptr=L.indptr, indices=indices, data=L.data.copy(),
                   shape=L.shape)
    raise ValueError("pattern_drift: no shiftable strict-lower entry "
                     "(matrix too small/diagonal)")


# -- static schedule defects (docs/analysis.md) -------------------------------
#
# Each pure mutator manufactures one class of structurally-broken-but-
# plausible artifact: shapes, dtypes and engine lowering all stay valid,
# so WITHOUT the static verifier the defect surfaces only as a finite
# wrong answer at solve time.  The chaos tests prove
# `repro.analysis.verify` rejects every class with a typed error naming
# the check/step/lane BEFORE anything executes.


def swap_schedule_steps(sched, a: int = 0, b: int | None = None):
    """A copy of a LevelSchedule with steps `a` and `b` (default: last)
    exchanged in every width group — the classic scheduling race: work
    that depended on step `a` now runs before it."""
    S = sched.num_steps
    b = S - 1 if b is None else b
    if S < 2 or a == b:
        raise ValueError(f"need two distinct steps to swap, have {S}")

    def swap(arr):
        if arr is None:
            return None
        out = arr.copy()
        out[[a, b]] = out[[b, a]]
        return out

    groups = tuple(
        dataclasses.replace(g, row_ids=swap(g.row_ids),
                            dep_idx=swap(g.dep_idx),
                            dep_coef=swap(g.dep_coef), dinv=swap(g.dinv),
                            carry_in=swap(g.carry_in),
                            carry_out=swap(g.carry_out))
        for g in sched.groups)
    return dataclasses.replace(sched, groups=groups)


def duplicate_schedule_row(sched):
    """A copy in which one finalized row is finalized AGAIN on a padding
    lane of a later step — the double-commit defect (lane/row bijection
    broken; last writer wins at runtime, so the answer can still be
    finite)."""
    n = sched.n
    sink = sched.n_carry + 1
    for gi, g in enumerate(sched.groups):
        fin = g.row_ids != n
        if g.carry_out is not None:
            fin &= g.carry_out == sink      # don't also duplicate a carry
        for s in range(g.row_ids.shape[0]):
            src = np.flatnonzero(fin[s])
            pad = np.flatnonzero(g.row_ids[s] == n)
            if src.size and pad.size:
                c_src, c_dst = int(src[0]), int(pad[0])
                row_ids = g.row_ids.copy()
                dinv = g.dinv.copy()
                row_ids[s, c_dst] = row_ids[s, c_src]
                dinv[s, c_dst] = dinv[s, c_src]
                groups = list(sched.groups)
                groups[gi] = dataclasses.replace(g, row_ids=row_ids,
                                                 dinv=dinv)
                return dataclasses.replace(sched, groups=tuple(groups))
    raise ValueError("duplicate_schedule_row: no (final lane, padding "
                     "lane) pair in any step")


def oob_schedule_index(sched, offset: int = 7):
    """A copy with ONE live ELL dependency slot's gather index pushed past
    the x-buffer (n + offset) — the out-of-bounds read that jax gather
    clamps into a silent wrong value instead of a crash."""
    n = sched.n
    for gi, g in enumerate(sched.groups):
        live = g.row_ids != n
        if g.carry_out is not None:
            live |= g.carry_out != sched.n_carry + 1
        hot = np.argwhere((g.dep_coef != 0) & live[..., None])
        if hot.size:
            s, c, d = (int(v) for v in hot[0])
            dep_idx = g.dep_idx.copy()
            dep_idx[s, c, d] = n + offset
            groups = list(sched.groups)
            groups[gi] = dataclasses.replace(g, dep_idx=dep_idx)
            return dataclasses.replace(sched, groups=tuple(groups))
    raise ValueError("oob_schedule_index: schedule has no live dependency "
                     "slots (diagonal system?)")


def corrupt_plan(ts, mode: str = "target"):
    """A copy of a TransformedSystem whose ReplayPlan is corrupt:

    mode "target" — the first commit's target level is pushed to (or past)
                    the row's own level, so replaying it would rewrite a
                    row with its own not-yet-eliminated dependencies;
         "row"    — the first commit names a row outside [0, n).
    A plan with no commits gains one bogus out-of-range commit either way.
    """
    from .transform import ReplayPlan
    plan = ts.plan
    if plan is None:
        raise ValueError("corrupt_plan: system carries no ReplayPlan")
    n = int(plan.level_of0.shape[0])
    commits = list(plan.commits)
    if not commits:
        commits = [(n + 3, 0)]
    elif mode == "target":
        row, _ = commits[0]
        commits[0] = (row, int(plan.level_of0[row]) + 1)
    elif mode == "row":
        _, target = commits[0]
        commits[0] = (n + 3, target)
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")
    bad = ReplayPlan(level_of0=plan.level_of0, commits=tuple(commits))
    return dataclasses.replace(ts, plan=bad)


@contextlib.contextmanager
def _counted_schedule_fault(mutate):
    """Like _schedule_fault, but yields {"calls": n} and tolerates
    schedules the mutator cannot corrupt (too small: passed through)."""
    from ..solver import schedule as _sched
    real = _sched.schedule_for_transformed
    count = {"calls": 0}

    def faulty(*args, **kwargs):
        sched = real(*args, **kwargs)
        try:
            sched = mutate(sched)
            count["calls"] += 1
        except ValueError:      # nothing to corrupt in this schedule
            pass
        return sched

    with _patched(_sched, "schedule_for_transformed", faulty):
        yield count


def reorder_schedule_step(a: int = 0, b: int | None = None):
    """Every schedule compiled inside the context has steps `a` and `b`
    swapped (swap_schedule_steps) — a scheduling race the static verifier
    must reject as check="race" before a solve can run.  Yields
    {"calls": n}."""
    return _counted_schedule_fault(lambda s: swap_schedule_steps(s, a, b))


def duplicate_lane_row():
    """Every schedule compiled inside the context finalizes one row twice
    (duplicate_schedule_row) — rejected as check="bijection".  Yields
    {"calls": n}."""
    return _counted_schedule_fault(duplicate_schedule_row)


def oob_ell_index(offset: int = 7):
    """Every schedule compiled inside the context carries one live
    out-of-bounds ELL gather (oob_schedule_index) — rejected as
    check="index-bounds".  Yields {"calls": n}."""
    return _counted_schedule_fault(lambda s: oob_schedule_index(s, offset))


@contextlib.contextmanager
def corrupt_replay_plan(mode: str = "target"):
    """Every transform built inside the context exports a corrupt
    ReplayPlan (corrupt_plan) — the transform auditor must reject it as
    check="replay-bounds" before update_values can replay it.  Yields
    {"calls": n}."""
    import importlib
    # the package re-exports the transform FUNCTION under the submodule's
    # name, so `from . import transform` would grab the function
    _tr = importlib.import_module(".transform", __package__)
    real = _tr.transform
    count = {"calls": 0}

    def faulty(*args, **kwargs):
        count["calls"] += 1
        return corrupt_plan(real(*args, **kwargs), mode=mode)

    with _patched(_tr, "transform", faulty):
        yield count


# -- cache faults -------------------------------------------------------------


def corrupt_cache_entries(cache_dir, mode: str = "garbage") -> list:
    """Corrupt every operator artifact under `cache_dir` in place.

    mode: "garbage"  — non-pickle bytes (torn write from a crashed
                       process without atomic replace),
          "truncate" — valid pickle prefix cut short (partial write),
          "stale"    — a well-formed pickle whose version field predates
                       CACHE_VERSION.
    Returns the corrupted paths.
    """
    paths = sorted(Path(cache_dir).glob("op-*.pkl"))
    for p in paths:
        if mode == "garbage":
            p.write_bytes(b"\x80\x05this is not a valid pickle stream")
        elif mode == "truncate":
            raw = p.read_bytes()
            p.write_bytes(raw[: max(1, len(raw) // 3)])
        elif mode == "stale":
            payload = pickle.loads(p.read_bytes())
            payload["version"] = -1
            p.write_bytes(pickle.dumps(payload))
        else:
            raise ValueError(f"unknown corruption mode {mode!r}")
    return paths


# -- engine faults ------------------------------------------------------------


@contextlib.contextmanager
def fail_engine_compile(name: str, times: int | None = None, exc=None):
    """The named REGISTERED engine's compile() raises for the first
    `times` calls inside the context (None = every call).  Yields a
    counter dict: {"calls": total compile calls, "failed": injected
    failures} for asserting the fault actually fired."""
    from ..solver.engines import get_engine
    eng = get_engine(name)
    real = eng.compile                  # bound method of the live instance
    count = {"calls": 0, "failed": 0}

    def faulty(dsched):
        count["calls"] += 1
        if times is None or count["calls"] <= times:
            count["failed"] += 1
            raise (exc if exc is not None else RuntimeError(
                f"injected compile failure in engine {name!r} "
                f"(call {count['calls']})"))
        return real(dsched)

    with _patched(eng, "compile", faulty):
        yield count


@contextlib.contextmanager
def engine_unavailable(name: str):
    """The named registered engine reports available() == False inside the
    context (e.g. "Pallas missing from this process")."""
    from ..solver.engines import get_engine
    eng = get_engine(name)
    with _patched(eng, "available", lambda: False):
        yield


# -- tuner faults -------------------------------------------------------------


@contextlib.contextmanager
def fail_tuner(exc=None):
    """Every `StrategyPortfolio.tune` call inside the context raises — the
    fault class a serving tier's BACKGROUND tuning worker must survive:
    admission already served the untuned operator, so a tuner blow-up may
    degrade the entry (no hot-swap, `TunerFailureWarning`) but must never
    poison it or block the request path.  Yields {"calls": n} for
    asserting the fault actually fired."""
    from .portfolio import StrategyPortfolio
    count = {"calls": 0}

    def faulty(self, L):
        count["calls"] += 1
        raise (exc if exc is not None else RuntimeError(
            f"injected tuner failure (call {count['calls']})"))

    with _patched(StrategyPortfolio, "tune", faulty):
        yield count


@contextlib.contextmanager
def slow_tuner(delay_s: float = 0.5):
    """Every `StrategyPortfolio.tune` call inside the context stalls for
    `delay_s` before running for real — the stalled-background-tuner fault:
    entries stay "warming" while requests keep flowing through the untuned
    operator, and the eventual hot-swap still lands.  Yields {"calls": n}."""
    import time
    from .portfolio import StrategyPortfolio
    real = StrategyPortfolio.tune
    count = {"calls": 0}

    def slow(self, L):
        count["calls"] += 1
        time.sleep(delay_s)
        return real(self, L)

    with _patched(StrategyPortfolio, "tune", slow):
        yield count


# -- profiler faults ----------------------------------------------------------


def slow_step(step_idx: int, seconds: float):
    """Every TIMED (non-warmup) pass of the `repro.obs.profile` schedule
    profiler inside the context stalls for `seconds` before executing step
    `step_idx` — the one-slow-step fault (a preempted core, a collective
    straggler) the per-step histogram must localize: the chaos test
    asserts `argmax(step_ms) == step_idx` and that the stall is visible
    inside the profile span's trace."""
    from ..obs import profile as _prof
    return _patched(_prof, "_STEP_FAULT",
                    (int(step_idx), float(seconds)))


# -- mesh faults --------------------------------------------------------------


@contextlib.contextmanager
def lose_mesh(exc=None):
    """Sharded lowering fails as if the mesh's devices were lost: every
    `lower_sharded` call inside the context raises.  Schedules the sharded
    engine lowered BEFORE the fault keep their memoized callables — a real
    device loss also only breaks new work, which is exactly what the
    fallback chain must cover."""
    from ..solver import distributed as _dist

    def faulty(*args, **kwargs):
        raise (exc if exc is not None else RuntimeError(
            "injected mesh device loss: sharded lowering unavailable"))

    with _patched(_dist, "lower_sharded", faulty):
        yield

"""Cross-cutting solve-path resilience: health policies, guards, retries.

The stack below this module has per-component error paths (Manteuffel
shift retries in `precond.factorize`, eager dtype/shape validation in
`solver.engines`), but no shared story for the failure modes a production
solve service actually meets: a non-finite right-hand side, a kernel that
silently emits NaN, a preferred engine whose compile fails mid-request, a
torn cache file from a crashed writer.  This module is that story's
vocabulary — the typed error taxonomy, the configurable `HealthPolicy`,
the `SolveGuard` that enforces it, and the declarative `RetryPolicy` the
factorization retries share — consumed by:

* `repro.solver.operator.TriangularOperator.solve` (+ `sptrsv`,
  `Preconditioner.apply`): input/output health checks with
  raise / fallback / repair actions,
* `repro.solver.engines`: engine fallback chains (`engine_fallbacks`),
  each downgrade warned and recorded in `OperatorStats`,
* `repro.precond.factorize`: breakdown-shift retries via `RetryPolicy`,
* `repro.solver.operator._disk_load/_disk_store`: atomic artifact writes
  and quarantine of corrupt entries (`CacheQuarantineWarning`).

Error taxonomy
==============
    ResilienceError(RuntimeError)
    ├── NumericalHealthError     non-finite / inaccurate solve data; carries
    │                            `.stage` ("input"|"output"|"residual"),
    │                            `.where`, and `.fallbacks` attempted
    ├── EngineFallbackError      every engine in a fallback chain failed;
    │                            carries `.attempts` [(engine, reason), ...]
    ├── PatternMismatchError     a value-only refactorization (`update_values`
    │                            / `Preconditioner.refactor`) was handed a
    │                            matrix whose sparsity pattern differs from
    │                            the frozen one; carries `.where` and
    │                            `.detail` (docs/refactorization.md)
    ├── AdmissionError           the serving tier rejected a request before
    │                            it entered a queue (per-tenant depth cap,
    │                            closed service); carries `.tenant`,
    │                            `.depth`, `.limit` (docs/serving.md)
    ├── ScheduleInvariantError   a compiled LevelSchedule failed static
    │                            verification (`repro.analysis.verify`):
    │                            a scheduling race, a broken lane/row
    │                            bijection, an out-of-bounds ELL index —
    │                            carries `.check`, `.step`, `.lane`,
    │                            `.group` (docs/analysis.md)
    └── TransformInvariantError  a TransformedSystem / ReplayPlan failed the
                                 transform audit (triangularity, level
                                 monotonicity, fill accounting, replay
                                 index bounds); carries `.check` and
                                 `.where` (docs/analysis.md)

    ResilienceWarning(UserWarning)
    ├── EngineFallbackWarning    an engine was downgraded (never silent)
    ├── HealthRepairWarning      a health violation was repaired/fallen back
    ├── CacheQuarantineWarning   a corrupt/stale cache entry was quarantined
    └── TunerFailureWarning      a background tuning job failed or was
                                 abandoned; the service keeps serving the
                                 untuned operator (docs/serving.md)

Health policy
=============
`HealthPolicy` is resolved per solve: an explicit `HealthPolicy` instance,
a named level (`"off" | "on" | "strict" | "repair" | "fallback"`), or
`None` for the `REPRO_HEALTH_CHECKS` environment default (same names;
unset means `"on"`).  `"on"` checks input/output finiteness and raises
typed errors; `"strict"` additionally verifies the relative residual
against the original matrix and statically certifies compiled schedules
via `repro.analysis.verify` (docs/analysis.md); `"repair"` / `"fallback"`
recover instead of raising (docs/robustness.md walks every knob).
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

__all__ = [
    "ResilienceError", "NumericalHealthError", "EngineFallbackError",
    "PatternMismatchError", "AdmissionError",
    "ScheduleInvariantError", "TransformInvariantError",
    "ResilienceWarning", "EngineFallbackWarning", "HealthRepairWarning",
    "CacheQuarantineWarning", "TunerFailureWarning",
    "HealthPolicy", "SolveGuard", "RetryPolicy", "resolve_health_policy",
]


# -- error taxonomy -----------------------------------------------------------


class ResilienceError(RuntimeError):
    """Base class for typed solve-path failures (module doc taxonomy)."""


class NumericalHealthError(ResilienceError):
    """A solve's data failed a health check.

    stage:     "input" (non-finite right-hand side), "output" (non-finite
               solution), or "residual" (finite but inaccurate solution).
    where:     the component that detected it (operator repr, facade name).
    fallbacks: recovery paths attempted before raising (empty when the
               policy action is "raise").
    """

    def __init__(self, message: str, *, stage: str, where: str = "",
                 fallbacks: tuple = ()):
        self.stage = stage
        self.where = where
        self.fallbacks = tuple(fallbacks)
        tail = f" (attempted fallbacks: {list(self.fallbacks)})" \
            if self.fallbacks else ""
        super().__init__(f"[{stage}] {message}{tail}")


class EngineFallbackError(ResilienceError):
    """Every engine in a fallback chain failed to compile or solve.

    attempts: [(engine_name, reason), ...] in the order they were tried —
    the error message names each one, so the failure is actionable.
    """

    def __init__(self, where: str, attempts: list):
        self.where = where
        self.attempts = list(attempts)
        detail = "; ".join(f"{name}: {reason}" for name, reason in attempts)
        super().__init__(
            f"{where}: every engine in the fallback chain failed — {detail}")


class PatternMismatchError(ResilienceError):
    """A value-only refactorization received a different sparsity pattern.

    The pattern-frozen fast paths (`TriangularOperator.update_values`,
    `Preconditioner.refactor`, `precond.factorize.refactor`) reuse level
    analysis, the graph transformation, the tuner pick, and factorization
    index plans verbatim — all of which are functions of the sparsity
    pattern alone.  A matrix whose pattern differs (shape, indptr, or
    indices) would silently produce a *finite but wrong* answer if packed
    into the frozen structures, so the mismatch is a typed, eager error:
    rebuild with `from_csr` / `ic0` / `ilu0` instead.

    where:  the component that detected the mismatch.
    detail: what differed — "shape", "indptr", "indices", "nnz", or
            "transformed-pattern drift" (an exact cancellation changed the
            rewritten system's fill during replay).
    """

    def __init__(self, message: str, *, where: str = "", detail: str = ""):
        self.where = where
        self.detail = detail
        tail = f" [{detail}]" if detail else ""
        super().__init__(f"{where + ': ' if where else ''}{message}{tail}")


class AdmissionError(ResilienceError):
    """The serving tier rejected a request before it entered a queue.

    Raised eagerly by `repro.serving.SolveService.submit` — a rejected
    request never consumes queue capacity, never holds a future, and the
    caller can retry/shed load immediately (docs/serving.md).

    tenant: the tenant whose request was rejected.
    depth:  the tenant's in-flight depth at rejection time.
    limit:  the configured cap (None when the rejection is not depth-based,
            e.g. submitting to a closed service).
    """

    def __init__(self, message: str, *, tenant: str = "default",
                 depth: int = 0, limit: int | None = None):
        self.tenant = tenant
        self.depth = depth
        self.limit = limit
        tail = f" (tenant {tenant!r}: depth {depth}" + \
            (f" >= cap {limit})" if limit is not None else ")")
        super().__init__(f"{message}{tail}")


class ScheduleInvariantError(ResilienceError):
    """A compiled schedule failed static verification.

    Raised by `repro.analysis.verify.verify_level_schedule` (and through it
    by `validate_schedule` and strict-mode operator builds) when a
    `LevelSchedule` violates a structural invariant: a lane reads a row or
    carry segment that is not finalized at a strictly earlier step, a row
    is finalized more or fewer than exactly once, an ELL index or carry
    slot is out of bounds, or the packed nnz disagrees with the matrix.
    The schedule must never execute — a violating schedule can return a
    *finite but wrong* answer (docs/analysis.md).

    check: the invariant that failed (e.g. "race", "bijection",
           "index-bounds", "carry-order", "nnz", "dtype", "collectives").
    step:  the first offending step index (-1 when not step-local).
    lane:  the first offending lane index within that step (-1 when not
           lane-local).
    group: the width-group index the lane belongs to (-1 when global).
    """

    def __init__(self, message: str, *, check: str, step: int = -1,
                 lane: int = -1, group: int = -1, where: str = ""):
        self.check = check
        self.step = int(step)
        self.lane = int(lane)
        self.group = int(group)
        self.where = where
        loc = ""
        if step >= 0:
            loc = f" at step {step}"
            if lane >= 0:
                loc += f", lane {lane}"
            if group >= 0:
                loc += f" (group {group})"
        head = f"{where}: " if where else ""
        super().__init__(f"{head}[{check}] {message}{loc}")


class TransformInvariantError(ResilienceError):
    """A TransformedSystem or its ReplayPlan failed the transform audit.

    Raised by `repro.analysis.verify.audit_transformed_system`: the
    rewritten dependency matrix is not strictly lower triangular, a level
    assignment is non-monotone along an edge, the fill accounting disagrees
    with `TransformMetrics`, or a replay-plan commit indexes out of bounds.
    Replaying or scheduling such a system would produce a finite wrong
    answer, so the audit is an eager, typed error (docs/analysis.md).

    check: the invariant that failed (e.g. "triangularity",
           "level-monotonicity", "fill-accounting", "replay-bounds").
    """

    def __init__(self, message: str, *, check: str, where: str = ""):
        self.check = check
        self.where = where
        head = f"{where}: " if where else ""
        super().__init__(f"{head}[{check}] {message}")


class ResilienceWarning(UserWarning):
    """Base class for resilience-layer warnings (downgrades are loud)."""


class EngineFallbackWarning(ResilienceWarning):
    """A solve was downgraded to a fallback engine."""


class HealthRepairWarning(ResilienceWarning):
    """A health violation was repaired or recovered via fallback."""


class CacheQuarantineWarning(ResilienceWarning):
    """A corrupt/stale disk-cache entry was quarantined to `.bad/`."""


class TunerFailureWarning(ResilienceWarning):
    """A background tuning job failed; the untuned operator keeps serving.

    Emitted by `repro.serving.OperatorRegistry` when a `StrategyPortfolio`
    run raises off the request path: the entry is marked "degraded"
    (visible in `ServiceStats`/`registry.snapshot()`), requests continue
    through the admitted `no_rewriting` operator, and nothing blocks."""


# -- health policy ------------------------------------------------------------

_NONFINITE_ACTIONS = ("raise", "fallback", "repair")
HEALTH_ENV_VAR = "REPRO_HEALTH_CHECKS"


@dataclasses.dataclass(frozen=True)
class HealthPolicy:
    """What SolveGuard checks and how violations are handled.

    check_inputs:     reject non-finite right-hand sides (always an error:
                      garbage in cannot be repaired).
    check_outputs:    detect non-finite solutions.
    on_nonfinite:     action for an unhealthy OUTPUT — "raise" a
                      NumericalHealthError; "fallback" to the guaranteed
                      host reference solve; "repair" by sanitizing +
                      iterative refinement, escalating to the fallback if
                      refinement cannot reach `residual_tol`.
    residual_check:   additionally verify the relative residual
                      max|b - Ax| / max(1, max|b|) against the ORIGINAL
                      matrix on every solve (catches finite-but-wrong
                      answers; costs one host matvec).
    residual_tol:     threshold for the residual check and the repair
                      target.  Intentionally looser than the refinement
                      tolerance: it flags wrong answers, not last-ulp
                      noise.
    max_repair_rounds: refinement rounds "repair" may spend before
                      escalating to the fallback.
    verify_schedule:  statically verify compiled schedules and transform
                      plans (`repro.analysis.verify`) before they serve a
                      solve: operator builds certify the schedule once
                      (cached artifacts keep their certificate, so cache
                      hits re-verify nothing), value updates re-audit the
                      numeric payload.  Violations raise
                      ScheduleInvariantError / TransformInvariantError.
    """

    check_inputs: bool = True
    check_outputs: bool = True
    on_nonfinite: str = "raise"
    residual_check: bool = False
    residual_tol: float = 1e-5
    max_repair_rounds: int = 3
    verify_schedule: bool = False

    def __post_init__(self):
        if self.on_nonfinite not in _NONFINITE_ACTIONS:
            raise ValueError(
                f"on_nonfinite must be one of {_NONFINITE_ACTIONS}, got "
                f"{self.on_nonfinite!r}")

    @property
    def enabled(self) -> bool:
        return self.check_inputs or self.check_outputs or self.residual_check

    @classmethod
    def off(cls) -> "HealthPolicy":
        return cls(check_inputs=False, check_outputs=False,
                   residual_check=False)

    @classmethod
    def strict(cls) -> "HealthPolicy":
        """Finiteness + residual + static schedule verification,
        violations raise."""
        return cls(residual_check=True, verify_schedule=True)


_NAMED_POLICIES = {
    "off": HealthPolicy.off,
    "0": HealthPolicy.off,
    "on": HealthPolicy,
    "1": HealthPolicy,
    "strict": HealthPolicy.strict,
    "repair": lambda: HealthPolicy(on_nonfinite="repair"),
    "fallback": lambda: HealthPolicy(on_nonfinite="fallback"),
}


def resolve_health_policy(spec=None) -> HealthPolicy:
    """Resolve a health spec: a HealthPolicy passes through, a named level
    constructs one, None reads REPRO_HEALTH_CHECKS (default "on")."""
    if isinstance(spec, HealthPolicy):
        return spec
    if spec is None:
        spec = os.environ.get(HEALTH_ENV_VAR, "on").strip().lower() or "on"
    if isinstance(spec, str):
        try:
            return _NAMED_POLICIES[spec.strip().lower()]()
        except KeyError:
            raise ValueError(
                f"unknown health policy {spec!r}; expected one of "
                f"{sorted(_NAMED_POLICIES)} or a HealthPolicy") from None
    raise TypeError(f"health spec must be None, a named level, or a "
                    f"HealthPolicy, got {type(spec).__name__}")


class SolveGuard:
    """Health validation for one solve component, per a HealthPolicy.

    The guard only *detects* and *classifies* — recovery (reference
    fallback, refinement repair) is the owning component's job, because it
    alone holds the original matrix and the device pipeline.  See
    `TriangularOperator.solve` for the canonical consumer.
    """

    def __init__(self, policy: HealthPolicy, where: str = "solve"):
        self.policy = policy
        self.where = where

    def require_finite_input(self, b) -> None:
        """Non-finite right-hand sides are always an error: no recovery
        can reconstruct the caller's intent."""
        if not self.policy.check_inputs:
            return
        if not np.isfinite(np.asarray(b)).all():
            raise NumericalHealthError(
                f"right-hand side contains NaN/Inf entries in {self.where}",
                stage="input", where=self.where)

    def output_unhealthy(self, x) -> str | None:
        """Classify an output: None (healthy) or a reason string."""
        if self.policy.check_outputs and \
                not np.isfinite(np.asarray(x)).all():
            return "solution contains NaN/Inf entries"
        return None

    def residual_unhealthy(self, resid: float) -> str | None:
        """Classify a relative residual (NaN counts as unhealthy)."""
        if not self.policy.residual_check:
            return None
        if not (resid <= self.policy.residual_tol):
            return (f"relative residual {resid:.3e} exceeds "
                    f"{self.policy.residual_tol:.1e}")
        return None


# -- declarative retry --------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Geometric-backoff retry shared by the flaky host-side paths.

    One attempt runs with parameter 0.0; each retry grows the parameter
    geometrically from `scale0` (Manteuffel diagonal shifts in
    `precond.factorize`, where the parameter is the shift alpha — but the
    policy is payload-agnostic: any `attempt(param)` callable works).

    max_attempts: retries after the first attempt (0 = no retry; the
                  first failure propagates).
    scale0:       parameter of the first retry.
    growth:       multiplier per further retry.
    """

    max_attempts: int = 20
    scale0: float = 1e-3
    growth: float = 2.0

    def params(self):
        """0.0, scale0, scale0*growth, ... — max_attempts + 1 values."""
        yield 0.0
        p = self.scale0
        for _ in range(self.max_attempts):
            yield p
            p *= self.growth

    def run(self, attempt, *, retry_on: tuple = (Exception,)):
        """Run `attempt(param)` over the parameter ladder.

        Returns (result, param, attempts) on the first success; re-raises
        the last `retry_on` exception when the ladder is exhausted.  Other
        exception types propagate immediately.
        """
        attempts = 0
        last = None
        for param in self.params():
            attempts += 1
            try:
                return attempt(param), param, attempts
            except retry_on as e:
                last = e
        raise last

"""Strategy-portfolio auto-tuner: pick the best transform per matrix.

The paper's conclusion is that no single rewrite wins everywhere — the
results "provide several hints on how to craft a collection of strategies".
This module makes that operational: a `StrategyPortfolio` enumerates
candidate strategies (the four shipped ones plus parameter sweeps), runs the
full transform + schedule compile for each, scores every candidate with an
analytic per-solve cost model, and returns a ranked `PortfolioReport`.

Cost model (per solve, microseconds; all constants calibratable):

    main     = steps * step_overhead_us
             + padded_flops * us_per_padded_flop      (width-bucketed tiles)
             + schedule_bytes * us_per_byte           (HBM streaming)
    preamble = nnz_T * us_per_preamble_nnz            (T-factor any-b charge)
    total    = main + preamble

`steps` and `padded_flops` come from the *compiled* LevelSchedule (so step
compaction and width bucketing are credited), `nnz_T` from TransformMetrics.
The defaults mirror the TPU roofline constants of benchmarks/solver_bench.py;
`CostModel.cpu()` is calibrated for the CPU scan engine, where per-step scan
overhead dominates.  An optional *measured* mode micro-benchmarks the top-k
candidates through the real engine and re-ranks them by wall time.

Strategy selection guidance (which matrix shapes favour which strategy) is
documented in docs/strategies.md; the end-to-end serving facade that consumes
this tuner is repro.solver.operator.TriangularOperator.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..obs import trace as _obs
from ..obs.metrics import default_registry as _default_registry
from ..sparse.csr import CSR
from .strategies import (AvgLevelCost, ConstrainedAvgLevelCost,
                         CriticalPathRewrite, ManualEveryK, NoRewrite,
                         Strategy, strategy_label)
from .transform import TransformMetrics, TransformedSystem, transform

__all__ = ["CostModel", "PortfolioCandidate", "PortfolioReport",
           "PairReport", "StrategyPortfolio", "default_candidates",
           "default_cost_model_for", "make_strategy", "STRATEGY_REGISTRY"]

# stable strategy name -> zero-arg-constructible class (docs/strategies.md)
STRATEGY_REGISTRY = {
    "no_rewriting": NoRewrite,
    "avgLevelCost": AvgLevelCost,
    "manual_every_k": ManualEveryK,
    "constrained_avg": ConstrainedAvgLevelCost,
    "critical_path": CriticalPathRewrite,
}


def make_strategy(spec) -> Strategy:
    """Resolve a strategy spec: a Strategy instance passes through, a stable
    name string (see STRATEGY_REGISTRY) constructs the default instance."""
    if isinstance(spec, str):
        try:
            return STRATEGY_REGISTRY[spec]()
        except KeyError:
            raise ValueError(
                f"unknown strategy {spec!r}; expected one of "
                f"{sorted(STRATEGY_REGISTRY)} or a Strategy instance") from None
    if not hasattr(spec, "apply"):
        raise TypeError(f"not a Strategy: {spec!r}")
    return spec


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Calibratable constants of the analytic per-solve cost (microseconds).

    Defaults model a TPU chip (HBM ~819 GB/s, VPU ~4 TF/s f32, ~2 us grid
    step); `cpu()` re-weights for the jitted CPU scan engine where the
    per-step dispatch overhead dominates everything else; `sharded()`
    charges every step its all_gather family so the tuner ranks strategies
    by synchronization cost.
    """

    step_overhead_us: float = 2.0
    us_per_padded_flop: float = 1.0 / 4e6       # 4 TF/s  -> 4e6 flop/us
    us_per_byte: float = 1.0 / 819e3            # 819 GB/s -> 819e3 B/us
    us_per_preamble_nnz: float = 5e-3           # T-factor any-b charge
    # sharded serving (repro.solver.distributed): each step ends in exactly
    # one all_gather family, so the collective charge is latency x steps —
    # the paper's "95% fewer barriers" as a first-class tuning objective.
    # 0 (the default) models single-device serving
    collective_latency_us: float = 0.0

    @classmethod
    def cpu(cls) -> "CostModel":
        """Weights calibrated against the measured CPU scan engine
        (BENCH_schedule.json: ~10-16 us/step, flops nearly free)."""
        return cls(step_overhead_us=12.0, us_per_padded_flop=1.0 / 1e5,
                   us_per_byte=1.0 / 4e6, us_per_preamble_nnz=5e-3)

    @classmethod
    def sharded(cls, collective_latency_us: float = 5.0,
                base: "CostModel | None" = None) -> "CostModel":
        """`base` (default TPU weights) plus a per-step collective charge —
        the model for ShardedEngine serving, where every schedule step is
        one cross-device synchronization barrier (~1-10 us on an ICI/NVLink
        mesh, more over DCN; calibrate for the target fabric)."""
        return dataclasses.replace(
            base if base is not None else cls(),
            collective_latency_us=collective_latency_us)

    def calibrate(self, profile) -> "CostModel":
        """Refit the per-step constants from a measured `ScheduleProfile`
        (repro.obs.profile) and return the calibrated model.

        Least-squares of per-step time against per-step padded FLOPs and
        bytes, intercept -> `step_overhead_us`.  Two profiler realities
        are handled explicitly:

        * when the profile carries a collective split (sharded engines),
          the fit runs on COMPUTE time and `collective_latency_us` is set
          to the median per-step collective time — the objective the
          sharded ranking charges per step;
        * width-bucketed schedules often have (near-)constant per-step
          FLOPs/bytes, a degenerate design matrix.  Constant columns are
          excluded from the fit, their charge (at the model's existing
          rate) is subtracted out of the intercept, and the residual
          becomes the overhead — so `predict()` with the calibrated model
          still reproduces the fitted per-step time.
        """
        t_us = np.asarray(profile.step_ms, dtype=float) * 1e3
        if t_us.size == 0:
            return self
        updates: dict = {}
        coll = getattr(profile, "collective_ms", None)
        if coll is not None:
            coll_us = np.asarray(coll, dtype=float) * 1e3
            t_us = np.maximum(t_us - coll_us, 0.0)
            updates["collective_latency_us"] = float(np.median(coll_us))
        feats = [
            ("us_per_padded_flop",
             np.asarray(profile.step_padded_flops, dtype=float)),
            ("us_per_byte", np.asarray(profile.step_bytes, dtype=float)),
        ]
        included, excluded = [], []
        for name, col in feats:
            scale = max(1.0, float(np.abs(col).mean()))
            (included if float(col.std()) > 1e-9 * scale
             else excluded).append((name, col))
        design = np.column_stack(
            [np.ones_like(t_us)] + [col for _, col in included])
        coef, *_ = np.linalg.lstsq(design, t_us, rcond=None)
        coef = np.maximum(coef, 0.0)
        overhead = float(coef[0])
        for (name, _), v in zip(included, coef[1:]):
            updates[name] = float(v)
        for name, col in excluded:
            overhead -= getattr(self, name) * float(col.mean())
        updates["step_overhead_us"] = max(0.0, overhead)
        return dataclasses.replace(self, **updates)

    def predict(self, sched, metrics: TransformMetrics) -> dict:
        """Cost breakdown (us) for one compiled schedule + its transform."""
        steps_us = sched.num_steps * self.step_overhead_us
        flops_us = sched.padded_flops() * self.us_per_padded_flop
        bytes_us = sched.memory_bytes() * self.us_per_byte
        pre_us = metrics.nnz_T * self.us_per_preamble_nnz
        # collective count == step count (the sharded-body invariant that
        # count_all_gathers audits), so the charge scales with num_steps
        coll_us = sched.num_steps * self.collective_latency_us
        return {
            "steps_us": steps_us, "flops_us": flops_us,
            "bytes_us": bytes_us, "preamble_us": pre_us,
            "collectives_us": coll_us,
            "total_us": steps_us + flops_us + bytes_us + pre_us + coll_us,
        }


def default_cost_model_for(engine) -> "CostModel | None":
    """The auto-tune cost model an engine implies when the caller passes
    none: `CostModel.sharded()` for sharded engines (the serving
    configuration and the tuning objective must agree — each step is one
    collective there), else None (the single-device default).  The ONE
    definition both facades (`TriangularOperator.from_csr` and
    `Preconditioner._pair_decision`) consult, so operator-level and
    pair-level auto-tuning always rank with the same objective for the
    same mesh."""
    from ..solver.engines import ShardedEngine
    if isinstance(engine, ShardedEngine):
        return CostModel.sharded()
    return None


@dataclasses.dataclass
class PortfolioCandidate:
    """One scored (strategy, transform, schedule) triple.

    `ts`/`sched`/`strategy` are dropped by `slim()` (persistent caches store
    only the chosen artifact, not every candidate's)."""

    label: str
    predicted_us: float
    breakdown: dict
    steps: int
    num_levels: int
    padded_flops: int
    memory_bytes: int
    nnz_T: int
    metrics: TransformMetrics | None = None
    measured_us: float | None = None
    error: str | None = None
    measure_note: str | None = None     # timeout / outlier / failure detail
    strategy: Strategy | None = None
    ts: TransformedSystem | None = None
    sched: object | None = None

    def slim(self) -> "PortfolioCandidate":
        return dataclasses.replace(self, strategy=None, ts=None, sched=None)


@dataclasses.dataclass
class PortfolioReport:
    """Ranked tuner output: candidates[0] is the pick."""

    matrix: dict
    candidates: list
    cost_model: CostModel
    measured_top_k: int
    tune_ms: float

    @property
    def best(self) -> PortfolioCandidate:
        return self.candidates[0]

    def slim(self) -> "PortfolioReport":
        return dataclasses.replace(
            self, candidates=[c.slim() for c in self.candidates])

    def to_dict(self) -> dict:
        return {
            "matrix": self.matrix,
            "cost_model": dataclasses.asdict(self.cost_model),
            "measured_top_k": self.measured_top_k,
            "tune_ms": round(self.tune_ms, 2),
            "candidates": [{
                "rank": i, "label": c.label,
                "predicted_us": (None if not np.isfinite(c.predicted_us)
                                 else round(c.predicted_us, 1)),
                "measured_us": (None if c.measured_us is None
                                else round(c.measured_us, 1)),
                "steps": c.steps, "levels": c.num_levels,
                "padded_flops": c.padded_flops,
                "memory_bytes": c.memory_bytes, "nnz_T": c.nnz_T,
                "breakdown": {k: round(v, 2) for k, v in c.breakdown.items()},
                "error": c.error,
                "measure_note": c.measure_note,
            } for i, c in enumerate(self.candidates)],
        }

    def table(self) -> str:
        """Human-readable ranked table (what quickstart.py prints)."""
        hdr = (f"{'rank':>4}  {'strategy':<42} {'pred_us':>10} "
               f"{'meas_us':>10} {'steps':>6} {'levels':>6} "
               f"{'padded_flops':>12} {'nnz_T':>8}")
        lines = [hdr, "-" * len(hdr)]
        for i, c in enumerate(self.candidates):
            meas = f"{c.measured_us:10.1f}" if c.measured_us is not None \
                else f"{'-':>10}"
            if c.error is not None:
                lines.append(f"{i:>4}  {c.label:<42} {'FAILED':>10} "
                             f"{'-':>10}  {c.error[:40]}")
                continue
            lines.append(f"{i:>4}  {c.label:<42} {c.predicted_us:10.1f} "
                         f"{meas} {c.steps:>6} {c.num_levels:>6} "
                         f"{c.padded_flops:>12} {c.nnz_T:>8}")
        return "\n".join(lines)


@dataclasses.dataclass
class PairReport:
    """Joint tuning decision for a forward/backward triangular-operator pair.

    A preconditioner application M^-1 r is TWO sweeps back to back (L then
    L^T, or L then U), and the strategy is chosen ONCE for the pair: per
    candidate label, the pair cost is the sum of the per-side costs, and
    `best_label` minimizes that sum.  Ranking mirrors `tune()`'s contract —
    labels measured on BOTH sides rank first by measured sum; the rest
    follow by predicted sum (never interleaved: wall-clock and model cost
    are different scales).

    `fwd`/`bwd` keep the full per-side PortfolioReports, so per-sweep
    diagnostics (steps, padded FLOPs, nnz_T) stay inspectable.
    """

    fwd: PortfolioReport
    bwd: PortfolioReport
    combined: list                  # [{label, fwd_us, bwd_us, total_us,
    #                                  measured}] ranked, [0] is the pick
    best_label: str

    @property
    def tune_ms(self) -> float:
        return self.fwd.tune_ms + self.bwd.tune_ms

    def slim(self) -> "PairReport":
        return dataclasses.replace(self, fwd=self.fwd.slim(),
                                   bwd=self.bwd.slim())

    def to_dict(self) -> dict:
        return {
            "best_label": self.best_label,
            "combined": self.combined,
            "fwd": self.fwd.to_dict(),
            "bwd": self.bwd.to_dict(),
        }

    def table(self) -> str:
        hdr = (f"{'rank':>4}  {'strategy':<42} {'fwd_us':>10} "
               f"{'bwd_us':>10} {'pair_us':>10} {'scored':>9}")
        lines = [hdr, "-" * len(hdr)]
        for i, c in enumerate(self.combined):
            lines.append(f"{i:>4}  {c['label']:<42} {c['fwd_us']:>10.1f} "
                         f"{c['bwd_us']:>10.1f} {c['total_us']:>10.1f} "
                         f"{'measured' if c['measured'] else 'model':>9}")
        return "\n".join(lines)


def default_candidates() -> list:
    """The shipped portfolio: the four strategies plus parameter sweeps over
    ManualEveryK / ConstrainedAvgLevelCost / CriticalPathRewrite."""
    return [
        NoRewrite(),
        AvgLevelCost(),
        ManualEveryK(k=5),
        ManualEveryK(k=10),
        ManualEveryK(k=20),
        ConstrainedAvgLevelCost(),                          # a=8, b=64
        ConstrainedAvgLevelCost(alpha=16, beta=128),
        ConstrainedAvgLevelCost(alpha=4, beta=32),
        CriticalPathRewrite(beta=8),
        CriticalPathRewrite(beta=32),
    ]


class StrategyPortfolio:
    """Enumerate -> transform -> compile -> score -> rank.

    candidates:     Strategy instances to try (default_candidates() if None).
    cost_model:     CostModel constants (TPU defaults; CostModel.cpu() for
                    CPU-engine calibration).
    chunk/max_deps/dtype: schedule-compiler configuration, forwarded to
                    schedule_for_transformed.
    measure_top_k:  if > 0, micro-benchmark the k model-best candidates with
                    the real engine (preamble included) and re-rank those
                    by measured wall time.
    measure_iters:  timing repetitions per measured candidate.
    measure_timeout_s: wall-clock budget per measured candidate — sampling
                    stops at the deadline and whatever was collected
                    decides (a pathologically slow candidate must not hang
                    the whole tuning run).
    measure_outlier_ratio: when the samples of one candidate disagree by
                    more than this factor (a scheduler hiccup or GC pause
                    polluting a rep), the candidate is re-measured once
                    and the extra samples are pooled in; the recorded time
                    is the pooled minimum (the microbenchmark noise
                    floor).  What happened is recorded on the candidate's
                    `measure_note`.
    engine:         engine used by the measured mode — a registered name,
                    an Engine from repro.solver.engines, or None for the
                    default scan engine (resolved through the registry).
    """

    def __init__(self, candidates=None, cost_model: CostModel | None = None,
                 chunk: int = 256, max_deps: int = 16, dtype=np.float32,
                 measure_top_k: int = 0, measure_iters: int = 3,
                 measure_timeout_s: float = 10.0,
                 measure_outlier_ratio: float = 4.0,
                 engine=None):
        self.candidates = (default_candidates() if candidates is None
                           else list(candidates))
        self.cost_model = cost_model if cost_model is not None else CostModel()
        self.chunk, self.max_deps, self.dtype = chunk, max_deps, dtype
        self.measure_top_k = measure_top_k
        self.measure_iters = measure_iters
        self.measure_timeout_s = measure_timeout_s
        self.measure_outlier_ratio = measure_outlier_ratio
        self.engine = engine

    def tune(self, L: CSR) -> PortfolioReport:
        with _obs.span("portfolio.tune", n=L.n_rows,
                       candidates=len(self.candidates),
                       measure_top_k=self.measure_top_k) as sp:
            report = self._tune(L)
            sp.set(best=report.best.label, tune_ms=report.tune_ms)
        reg = _default_registry()
        with reg.lock:
            reg.counter("portfolio_tunes", "portfolio tuning runs").inc()
            failures = reg.counter(
                "portfolio_candidate_failures",
                "candidates whose transform/compile raised")
            notes = reg.counter(
                "portfolio_measure_notes",
                "measured-mode anomalies by kind "
                "(timeout|outliers|measure_failed)")
            for c in report.candidates:
                if c.error is not None:
                    failures.inc()
                if c.measure_note:
                    kind = ("timeout" if c.measure_note.startswith("timeout")
                            else "measure_failed"
                            if c.measure_note.startswith("measure failed")
                            else "outliers")
                    notes.inc(kind=kind)
        return report

    def _tune(self, L: CSR) -> PortfolioReport:
        import time
        from ..solver.schedule import schedule_for_transformed
        t0 = time.perf_counter()
        scored: list[PortfolioCandidate] = []
        failed: list[PortfolioCandidate] = []
        for strat in self.candidates:
            label = strategy_label(strat)
            try:
                ts = transform(L, strat, validate=False, codegen=False)
                sched = schedule_for_transformed(
                    ts, chunk=self.chunk, max_deps=self.max_deps,
                    dtype=self.dtype)
            except Exception as e:  # a candidate blowing up must not kill
                failed.append(PortfolioCandidate(   # the whole tuning run
                    label=label, predicted_us=float("inf"), breakdown={},
                    steps=-1, num_levels=-1, padded_flops=-1,
                    memory_bytes=-1, nnz_T=-1,
                    error=f"{type(e).__name__}: {e}"))
                continue
            bd = self.cost_model.predict(sched, ts.metrics)
            scored.append(PortfolioCandidate(
                label=label, predicted_us=bd["total_us"], breakdown=bd,
                steps=sched.num_steps, num_levels=ts.metrics.num_levels_after,
                padded_flops=sched.padded_flops(),
                memory_bytes=sched.memory_bytes(),
                nnz_T=ts.metrics.nnz_T, metrics=ts.metrics,
                strategy=strat, ts=ts, sched=sched))
        if not scored:
            raise RuntimeError("every portfolio candidate failed: " +
                               "; ".join(c.error or "" for c in failed))
        scored.sort(key=lambda c: c.predicted_us)
        if self.measure_top_k > 0:
            # re-rank WITHIN the model's top-k by measured wall time; wall
            # time (CPU us) and model cost (device us) are different scales,
            # so measured candidates must never be sorted against unmeasured
            # predictions — the top-k stay ahead of the rest by model rank
            top = scored[:self.measure_top_k]
            for c in top:
                try:
                    self._measure(c)
                except Exception as e:
                    # a candidate whose MEASUREMENT fails (engine compile
                    # blew up, device lost mid-benchmark) is still a valid
                    # compiled artifact — park it at the bottom of the
                    # measured group instead of killing the tuning run
                    c.measured_us = float("inf")
                    c.measure_note = (f"measure failed: "
                                      f"{type(e).__name__}: {e}")
            top.sort(key=lambda c: c.measured_us)
            scored = top + scored[self.measure_top_k:]
        lv_before = scored[0].metrics.num_levels_before
        report = PortfolioReport(
            matrix={"n": L.n_rows, "nnz": L.nnz, "levels": lv_before},
            candidates=scored + failed, cost_model=self.cost_model,
            measured_top_k=self.measure_top_k,
            tune_ms=(time.perf_counter() - t0) * 1e3)
        return report

    def tune_pair(self, fwd: CSR, bwd: CSR) -> PairReport:
        """Tune a forward/backward operator pair jointly (see PairReport).

        `fwd` and `bwd` are the two ORIENTED lower-triangular systems of a
        preconditioner's sweeps (repro.solver.operator.orient_lower output
        for the L and L^T/U halves).  Each side runs the normal `tune()`;
        the pick minimizes the summed pair cost over labels that succeeded
        on both sides.
        """
        rf, rb = self.tune(fwd), self.tune(bwd)

        def _by_label(report):
            return {c.label: c for c in report.candidates if c.error is None}

        cf, cb = _by_label(rf), _by_label(rb)
        shared = [lbl for lbl in cf if lbl in cb]
        if not shared:
            raise RuntimeError("no strategy succeeded on both sides of the "
                               "operator pair")
        combined = []
        for lbl in shared:
            f, b = cf[lbl], cb[lbl]
            measured = f.measured_us is not None and b.measured_us is not None
            fwd_us = f.measured_us if measured else f.predicted_us
            bwd_us = b.measured_us if measured else b.predicted_us
            combined.append({"label": lbl, "fwd_us": round(fwd_us, 1),
                             "bwd_us": round(bwd_us, 1),
                             "total_us": round(fwd_us + bwd_us, 1),
                             "measured": measured})
        combined.sort(key=lambda c: (not c["measured"], c["total_us"]))
        return PairReport(fwd=rf, bwd=rb, combined=combined,
                          best_label=combined[0]["label"])

    def _measure(self, cand: PortfolioCandidate) -> float:
        """End-to-end per-solve wall time (host preamble + compiled engine),
        dispatched through the engine registry; sets `cand.measured_us`
        (and `cand.measure_note` when something noteworthy happened).

        Hardened against flaky hosts: per-candidate sampling stops at the
        `measure_timeout_s` deadline, and a sample spread wider than
        `measure_outlier_ratio` triggers one re-measurement whose samples
        are pooled in.  The recorded time is the pooled MINIMUM — the
        standard microbenchmark noise floor, robust to one-sided timing
        noise (a rep can only ever be measured too slow, never too fast).
        """
        import time
        import jax.numpy as jnp
        from ..solver.engines import compile_source, resolve_engine
        from ..solver.levelset import to_device
        eng = resolve_engine(self.engine)
        # host-lowering engines (sharded) stage their own padded copy;
        # handing them an unpadded DeviceSchedule would just pin device
        # memory they never read (engines.compile_source)
        fn = eng.compile(compile_source(eng, cand.sched,
                                        lambda: to_device(cand.sched)))
        b = np.random.default_rng(0).standard_normal(cand.ts.A.n_rows)
        c = jnp.asarray(cand.ts.preamble(b), dtype=cand.sched.dtype)
        jnp.asarray(fn(c)).block_until_ready()         # compile outside timer

        def sample_until(deadline: float) -> list:
            out = []
            for _ in range(self.measure_iters):
                t0 = time.perf_counter()
                cc = jnp.asarray(cand.ts.preamble(b), dtype=cand.sched.dtype)
                jnp.asarray(fn(cc)).block_until_ready()
                out.append((time.perf_counter() - t0) * 1e6)
                if time.perf_counter() >= deadline:
                    break
            return out

        deadline = time.perf_counter() + self.measure_timeout_s
        samples = sample_until(deadline)
        note = None
        if len(samples) < self.measure_iters:
            note = (f"timeout: {len(samples)}/{self.measure_iters} reps "
                    f"within {self.measure_timeout_s:g}s")
        elif max(samples) > self.measure_outlier_ratio * min(samples):
            spread = max(samples) / min(samples)
            samples += sample_until(
                time.perf_counter() + self.measure_timeout_s)
            note = (f"outliers (spread {spread:.1f}x > "
                    f"{self.measure_outlier_ratio:g}x): re-measured, "
                    f"{len(samples)} samples pooled")
        cand.measured_us = min(samples)
        cand.measure_note = note
        return cand.measured_us

"""SolveService: the multi-tenant front door over batcher + registry.

One object owns the whole request path:

    with SolveService(max_width=16, max_linger_s=0.002) as svc:
        fut = svc.submit(b, matrix=L, tenant="alice")   # future
        x = fut.result()
        x = svc.solve(b2, matrix=L)                     # sync sugar

`submit` admits the matrix through the `OperatorRegistry` (cold builds
are synchronous but untuned; tuning runs behind — see registry.py),
enforces the per-tenant in-flight cap (a typed
`repro.core.resilience.AdmissionError` on overflow; one tenant's burst
cannot exhaust another's headroom), and enqueues into the
`MicroBatcher`.  Batches flush by width (inline, on the submitting
thread's notification) or by linger deadline (the dispatcher thread
sleeps until `next_deadline()`), and execute on a small worker pool:
under the owning entry's lock, the batch's value fingerprint is
re-bound via `ensure_values`, the stacked (n, k) right-hand side is
solved once, and each column resolves its request's future.

Determinism for tests: construct with `auto_dispatch=False` and no
thread is spawned — width-full batches queue instead of dispatching,
and `pump()` drains everything synchronously on the calling thread, so
batching behavior is exactly reproducible.

`ServiceStats` is the observability plane: request/batch counters, the
batch-width histogram (is coalescing actually happening?), cache-hit
sources (registry vs the operator cache's built/memory/disk/pattern),
and separate queue-vs-solve latency reservoirs with percentiles — plus
the registry's lifecycle counters (states, hot swaps, tuner failures)
merged into every snapshot.
"""
from __future__ import annotations

import collections
import concurrent.futures
import threading
import time

import numpy as np

from ..core.resilience import AdmissionError
from .batcher import MicroBatcher, SolveRequest
from .registry import EntryKey, OperatorRegistry

__all__ = ["SolveService", "ServiceStats"]

_RESERVOIR = 100_000     # latency samples retained per series


def _percentile(samples, q: float) -> float:
    """Nearest-rank percentile of a list (NaN when empty)."""
    if not samples:
        return float("nan")
    s = sorted(samples)
    rank = min(len(s) - 1, max(0, int(round(q / 100.0 * (len(s) - 1)))))
    return float(s[rank])


class ServiceStats:
    """Thread-safe counters + latency reservoirs for one SolveService."""

    def __init__(self):
        self._lock = threading.Lock()
        self.submitted = 0
        self.completed = 0
        self.rejected = 0            # AdmissionError (tenant cap)
        self.failed = 0              # requests resolved with an exception
        self.batches = 0
        self.batch_errors = 0
        self.width_hist = collections.Counter()     # batch width -> count
        self.flush_reasons = collections.Counter()  # width | linger | drain
        self.cache_sources = collections.Counter()  # registry|built|memory|...
        self.rejected_by_tenant = collections.Counter()
        self.queue_ms: list = []     # enqueue -> dispatch, per request
        self.solve_ms: list = []     # dispatch -> solved, per batch

    # -- recording ------------------------------------------------------------
    def record_submit(self, source: str) -> None:
        with self._lock:
            self.submitted += 1
            self.cache_sources[source] += 1

    def record_reject(self, tenant: str) -> None:
        with self._lock:
            self.rejected += 1
            self.rejected_by_tenant[tenant] += 1

    def record_batch(self, batch, queue_ms, solve_ms: float) -> None:
        with self._lock:
            self.batches += 1
            self.completed += batch.width
            self.width_hist[batch.width] += 1
            self.flush_reasons[batch.reason] += 1
            if len(self.queue_ms) < _RESERVOIR:
                self.queue_ms.extend(queue_ms)
            if len(self.solve_ms) < _RESERVOIR:
                self.solve_ms.append(solve_ms)

    def record_batch_error(self, batch) -> None:
        with self._lock:
            self.batches += 1
            self.batch_errors += 1
            self.failed += batch.width
            self.width_hist[batch.width] += 1
            self.flush_reasons[batch.reason] += 1

    # -- reading --------------------------------------------------------------
    def mean_width(self) -> float:
        with self._lock:
            n = sum(self.width_hist.values())
            return (sum(w * c for w, c in self.width_hist.items()) / n
                    if n else float("nan"))

    def snapshot(self, registry: OperatorRegistry | None = None) -> dict:
        with self._lock:
            snap = {
                "submitted": self.submitted, "completed": self.completed,
                "rejected": self.rejected, "failed": self.failed,
                "batches": self.batches, "batch_errors": self.batch_errors,
                "width_hist": dict(sorted(self.width_hist.items())),
                "flush_reasons": dict(self.flush_reasons),
                "cache_sources": dict(self.cache_sources),
                "rejected_by_tenant": dict(self.rejected_by_tenant),
                "queue_ms": {"p50": _percentile(self.queue_ms, 50),
                             "p99": _percentile(self.queue_ms, 99)},
                "solve_ms": {"p50": _percentile(self.solve_ms, 50),
                             "p99": _percentile(self.solve_ms, 99)},
            }
        n = sum(snap["width_hist"].values())
        snap["mean_width"] = (sum(w * c for w, c in snap["width_hist"]
                                  .items()) / n) if n else float("nan")
        if registry is not None:
            reg = registry.stats()
            reg.pop("entries", None)    # per-entry detail stays opt-in
            snap["registry"] = reg
        return snap


class SolveService:
    """Multi-tenant micro-batching solve service (see module doc).

    max_width / max_linger_s: the batcher's flush policy.
    tenant_cap:   per-tenant in-flight request bound (None = unlimited);
                  exceeding it raises AdmissionError instead of queueing.
    workers:      batched-solve worker threads (distinct keys solve
                  concurrently; one key's batches serialize on its entry
                  lock regardless, so more workers than hot keys is waste).
    auto_dispatch: False spawns NO threads — batches accumulate until
                  `pump()` runs them on the calling thread (deterministic
                  tests); width/linger policy is otherwise identical.
    pad_widths:   pad every multi-column batch to the next power-of-two
                  width with zero columns before solving (default True).
                  The engines jit-compile per right-hand-side shape, so
                  unpadded serving retraces on every new batch width —
                  a ~100ms stall mid-traffic; bucketing caps the shape
                  set at log2(max_width) + 1, the same trick the
                  schedule compiler plays with width-bucketed ELL tiles.
                  Zero columns solve to zero and are sliced off before
                  futures resolve.
    solve_kwargs: forwarded to every TriangularOperator.solve; the default
                  {"max_refine": 0} is the raw float32 device path —
                  serving wants throughput, callers wanting refined
                  float64 pass {"max_refine": 6} etc.
    registry:     a pre-configured OperatorRegistry; default builds one
                  from **registry_kwargs (tune_mode=, cache=, ...).
    """

    def __init__(self, *, max_width: int = 16, max_linger_s: float = 0.002,
                 tenant_cap: int | None = 64, workers: int = 2,
                 auto_dispatch: bool = True, pad_widths: bool = True,
                 solve_kwargs: dict | None = None,
                 registry: OperatorRegistry | None = None,
                 **registry_kwargs):
        # a caller-supplied registry is shared state (e.g. one tuned
        # registry reused across benchmark sweeps): the service never
        # closes it
        self._own_registry = registry is None
        self.registry = registry if registry is not None \
            else OperatorRegistry(**registry_kwargs)
        self.tenant_cap = tenant_cap
        self.solve_kwargs = {"max_refine": 0} if solve_kwargs is None \
            else dict(solve_kwargs)
        self.stats = ServiceStats()
        self.pad_widths = bool(pad_widths)
        self._clock = time.perf_counter
        self._batcher = MicroBatcher(max_width=max_width,
                                     max_linger_s=max_linger_s)
        self._cond = threading.Condition()
        self._pending: list = []          # batches awaiting pump/dispatch
        self._inflight = collections.Counter()      # tenant -> open requests
        self._tenant_lock = threading.Lock()
        self._closed = False
        self._auto = bool(auto_dispatch)
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=max(1, workers), thread_name_prefix="repro-solve") \
            if self._auto else None
        self._dispatcher = None
        if self._auto:
            self._dispatcher = threading.Thread(
                target=self._dispatch_loop, name="repro-dispatch",
                daemon=True)
            self._dispatcher.start()

    # -- request path ---------------------------------------------------------
    def submit(self, b, matrix, *, tenant: str = "default",
               dtype: str = "float32", side: str = "lower",
               transpose: bool = False) -> concurrent.futures.Future:
        """Admit `matrix` (cold patterns build untuned, synchronously) and
        enqueue one solve of `b` against it.  Returns a Future resolving
        to the solution column; raises AdmissionError when `tenant`
        already has `tenant_cap` requests in flight."""
        if self._closed:
            raise RuntimeError("service is closed")
        with self._tenant_lock:
            depth = self._inflight[tenant]
            if self.tenant_cap is not None and depth >= self.tenant_cap:
                self.stats.record_reject(tenant)
                raise AdmissionError("tenant queue depth cap reached",
                                     tenant=tenant, depth=depth,
                                     limit=self.tenant_cap)
            self._inflight[tenant] += 1
        try:
            entry, bkey, created = self.registry.admit(
                matrix, dtype=dtype, side=side, transpose=transpose)
        except BaseException:
            self._release(tenant)
            raise
        b = np.asarray(b)
        if b.ndim != 1 or b.shape[0] != matrix.n_rows:
            # reject HERE: a wrong-shape column must fail its own request,
            # never reach stack() and poison a shared batch
            self._release(tenant)
            raise ValueError(
                f"b must be ({matrix.n_rows},), got {b.shape}")
        # cold admissions surface the operator cache's source (built /
        # memory / disk / pattern); warm ones hit the live registry
        self.stats.record_submit(
            entry.op.stats.cache_source if created else "registry")
        fut = concurrent.futures.Future()
        fut.add_done_callback(lambda _f, t=tenant: self._release(t))
        req = SolveRequest(key=bkey, b=b, tenant=tenant, future=fut)
        with self._cond:
            if self._closed:    # closed between the early check and here:
                fut.cancel()    # cancellation releases the tenant slot
                raise RuntimeError("service is closed")
            batch = self._batcher.enqueue(req, self._clock())
            if batch is not None and not self._auto:
                self._pending.append(batch)
            self._cond.notify()
        if batch is not None and self._auto:
            self._pool.submit(self._run_batch, batch)
        return fut

    def solve(self, b, matrix, **kwargs) -> np.ndarray:
        """Synchronous sugar: submit and wait."""
        return self.submit(b, matrix, **kwargs).result()

    def _release(self, tenant: str) -> None:
        with self._tenant_lock:
            self._inflight[tenant] -= 1
            if self._inflight[tenant] <= 0:
                del self._inflight[tenant]

    def inflight(self, tenant: str | None = None) -> int:
        with self._tenant_lock:
            return sum(self._inflight.values()) if tenant is None \
                else self._inflight[tenant]

    # -- dispatch -------------------------------------------------------------
    def _dispatch_loop(self) -> None:
        while True:
            with self._cond:
                if self._closed:
                    batches = self._batcher.flush_all(self._clock())
                else:
                    now = self._clock()
                    deadline = self._batcher.next_deadline()
                    if deadline is None or deadline > now:
                        timeout = 0.05 if deadline is None \
                            else min(deadline - now, 0.05)
                        self._cond.wait(timeout=timeout)
                        continue
                    batches = self._batcher.due(now)
            for batch in batches:
                self._pool.submit(self._run_batch, batch)
            if self._closed:
                return

    def pump(self) -> int:
        """Drain every queued request synchronously on the calling thread
        (auto_dispatch=False mode); returns the number of batches run."""
        with self._cond:
            batches, self._pending = self._pending, []
            batches += self._batcher.flush_all(self._clock())
        for batch in batches:
            self._run_batch(batch)
        return len(batches)

    def _run_batch(self, batch) -> None:
        t0 = self._clock()
        key = batch.key
        try:
            entry = self.registry.get(EntryKey(
                pattern_fp=key.pattern_fp, dtype=key.dtype, side=key.side,
                transpose=key.transpose))
            if entry is None:
                raise RuntimeError(
                    f"no registry entry for pattern {key.pattern_fp[:8]} "
                    "(evicted mid-flight?)")
            B = batch.stack()
            if self.pad_widths and B.ndim == 2:
                bucket = 1 << (B.shape[1] - 1).bit_length()
                if bucket > B.shape[1]:
                    B = np.concatenate(
                        [B, np.zeros((B.shape[0], bucket - B.shape[1]),
                                     dtype=B.dtype)], axis=1)
            # one lock span covers re-bind + solve: a concurrent value
            # update or hot-swap lands before or after this batch, never
            # inside it
            with entry.lock:
                op = entry.ensure_values(key.value_fp)
                x = op.solve(B, **self.solve_kwargs)
        except BaseException as exc:   # noqa: BLE001 - resolve the futures
            for r in batch.requests:
                if r.future is not None and not r.future.done():
                    r.future.set_exception(exc)
            self.stats.record_batch_error(batch)
            return
        t1 = self._clock()
        for j, r in enumerate(batch.requests):
            if r.future is not None:
                r.future.set_result(np.array(batch.column(x, j)))
        self.stats.record_batch(
            batch, [(t0 - r.t_enqueue) * 1e3 for r in batch.requests],
            (t1 - t0) * 1e3)

    # -- observability --------------------------------------------------------
    def snapshot(self) -> dict:
        return self.stats.snapshot(self.registry)

    def wait_warm(self, timeout: float | None = None) -> bool:
        return self.registry.wait_warm(timeout)

    # -- lifecycle ------------------------------------------------------------
    def close(self, wait: bool = True) -> None:
        """Stop intake, drain queued batches, stop workers and tuner."""
        with self._cond:
            if self._closed:
                return
            self._closed = True
            self._cond.notify_all()
        if self._auto:
            self._dispatcher.join(timeout=5.0)
            self._pool.shutdown(wait=wait)
        else:
            self.pump()
        if self._own_registry:
            self.registry.close(wait=wait)

    def __enter__(self) -> "SolveService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

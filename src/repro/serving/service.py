"""SolveService: the multi-tenant front door over batcher + registry.

One object owns the whole request path:

    with SolveService(max_width=16, max_linger_s=0.002) as svc:
        fut = svc.submit(b, matrix=L, tenant="alice")   # future
        x = fut.result()
        x = svc.solve(b2, matrix=L)                     # sync sugar

`submit` admits the matrix through the `OperatorRegistry` (cold builds
are synchronous but untuned; tuning runs behind — see registry.py),
enforces the per-tenant in-flight cap (a typed
`repro.core.resilience.AdmissionError` on overflow; one tenant's burst
cannot exhaust another's headroom), and enqueues into the
`MicroBatcher`.  Batches flush by width (inline, on the submitting
thread's notification) or by linger deadline (the dispatcher thread
sleeps until `next_deadline()`), and execute on a small worker pool:
under the owning entry's lock, the batch's value fingerprint is
re-bound via `ensure_values`, the stacked (n, k) right-hand side is
solved once, and each column resolves its request's future.

Determinism for tests: construct with `auto_dispatch=False` and no
thread is spawned — width-full batches queue instead of dispatching,
and `pump()` drains everything synchronously on the calling thread, so
batching behavior is exactly reproducible.

`ServiceStats` is the observability plane: request/batch counters, the
batch-width histogram (is coalescing actually happening?), cache-hit
sources (registry vs the operator cache's built/memory/disk/pattern),
and separate queue-vs-solve latency reservoirs with percentiles — plus
the registry's lifecycle counters (states, hot swaps, tuner failures)
merged into every snapshot.
"""
from __future__ import annotations

import collections
import concurrent.futures
import threading
import time

import numpy as np

from ..core.resilience import AdmissionError
from ..obs import trace as _obs
from ..obs.metrics import MetricsRegistry, nearest_rank_percentile
from .batcher import MicroBatcher, SolveRequest
from .registry import EntryKey, OperatorRegistry

__all__ = ["SolveService", "ServiceStats"]

_RESERVOIR = 100_000     # latency samples retained per series


def _percentile(samples, q: float) -> float:
    """Nearest-rank percentile of a list (NaN when empty) — the one
    formula, now owned by repro.obs.metrics."""
    return nearest_rank_percentile(samples, q)


class ServiceStats:
    """The service's stats plane: a VIEW over a `repro.obs` metrics
    registry (prefix "repro_service") — counters, labeled counters for
    the width/flush/source breakdowns, and two latency histograms whose
    bounded reservoirs feed the nearest-rank percentiles.  `snapshot()`
    and the Prometheus exporter read the SAME instruments; there is no
    second ledger (docs/observability.md).  Multi-instrument events
    commit atomically under the registry's one shared lock."""

    def __init__(self, registry: MetricsRegistry | None = None):
        self.registry = registry if registry is not None \
            else MetricsRegistry(prefix="repro_service")
        r = self.registry
        self._lock = r.lock
        self._submitted = r.counter("submitted", "requests admitted")
        self._completed = r.counter("completed",
                                    "requests resolved with a solution")
        self._rejected = r.counter("rejected",
                                   "requests rejected by the tenant cap")
        self._failed = r.counter("failed",
                                 "requests resolved with an exception")
        self._batches = r.counter("batches", "batches executed")
        self._batch_errors = r.counter("batch_errors", "batches that raised")
        self._width_hist = r.counter("batch_width", "batches by width")
        self._flush_reasons = r.counter(
            "batch_flush", "batches by flush reason (width|linger|drain)")
        self._cache_sources = r.counter(
            "cache_source", "admissions by operator cache source")
        self._rejected_by_tenant = r.counter("rejected_tenant",
                                             "rejections per tenant")
        self._queue_ms = r.histogram(
            "queue_ms", "enqueue->dispatch wait per request (ms)",
            reservoir=_RESERVOIR)
        self._solve_ms = r.histogram(
            "solve_ms", "dispatch->solved per batch (ms)",
            reservoir=_RESERVOIR)

    # -- attribute views (the pre-registry public surface) --------------------
    @property
    def submitted(self) -> int:
        return self._submitted.value()

    @property
    def completed(self) -> int:
        return self._completed.value()

    @property
    def rejected(self) -> int:
        return self._rejected.value()

    @property
    def failed(self) -> int:
        return self._failed.value()

    @property
    def batches(self) -> int:
        return self._batches.value()

    @property
    def batch_errors(self) -> int:
        return self._batch_errors.value()

    @staticmethod
    def _labeled(counter, label, cast=lambda v: v):
        return collections.Counter(
            {cast(dict(k)[label]): v for k, v in counter.series().items()})

    @property
    def width_hist(self):               # batch width -> count
        return self._labeled(self._width_hist, "width", int)

    @property
    def flush_reasons(self):            # width | linger | drain
        return self._labeled(self._flush_reasons, "reason")

    @property
    def cache_sources(self):            # registry|built|memory|...
        return self._labeled(self._cache_sources, "source")

    @property
    def rejected_by_tenant(self):
        return self._labeled(self._rejected_by_tenant, "tenant")

    @property
    def queue_ms(self) -> list:         # enqueue -> dispatch, per request
        return self._queue_ms.samples()

    @property
    def solve_ms(self) -> list:         # dispatch -> solved, per batch
        return self._solve_ms.samples()

    # -- recording ------------------------------------------------------------
    def record_submit(self, source: str) -> None:
        with self._lock:
            self._submitted.inc()
            self._cache_sources.inc(source=source)

    def record_reject(self, tenant: str) -> None:
        with self._lock:
            self._rejected.inc()
            self._rejected_by_tenant.inc(tenant=tenant)

    def record_batch(self, batch, queue_ms, solve_ms: float) -> None:
        with self._lock:
            self._batches.inc()
            self._completed.inc(batch.width)
            self._width_hist.inc(width=int(batch.width))
            self._flush_reasons.inc(reason=batch.reason)
            for v in queue_ms:
                self._queue_ms.observe(v)
            self._solve_ms.observe(solve_ms)

    def record_batch_error(self, batch) -> None:
        with self._lock:
            self._batches.inc()
            self._batch_errors.inc()
            self._failed.inc(batch.width)
            self._width_hist.inc(width=int(batch.width))
            self._flush_reasons.inc(reason=batch.reason)

    # -- reading --------------------------------------------------------------
    def mean_width(self) -> float:
        with self._lock:
            hist = self.width_hist
            n = sum(hist.values())
            return (sum(w * c for w, c in hist.items()) / n
                    if n else float("nan"))

    def snapshot(self, registry: OperatorRegistry | None = None) -> dict:
        with self._lock:
            snap = {
                "submitted": self.submitted, "completed": self.completed,
                "rejected": self.rejected, "failed": self.failed,
                "batches": self.batches, "batch_errors": self.batch_errors,
                "width_hist": dict(sorted(self.width_hist.items())),
                "flush_reasons": dict(self.flush_reasons),
                "cache_sources": dict(self.cache_sources),
                "rejected_by_tenant": dict(self.rejected_by_tenant),
                "queue_ms": {"p50": self._queue_ms.percentile(50),
                             "p99": self._queue_ms.percentile(99)},
                "solve_ms": {"p50": self._solve_ms.percentile(50),
                             "p99": self._solve_ms.percentile(99)},
            }
        n = sum(snap["width_hist"].values())
        snap["mean_width"] = (sum(w * c for w, c in snap["width_hist"]
                                  .items()) / n) if n else float("nan")
        if registry is not None:
            reg = registry.stats()
            reg.pop("entries", None)    # per-entry detail stays opt-in
            snap["registry"] = reg
        return snap


class SolveService:
    """Multi-tenant micro-batching solve service (see module doc).

    max_width / max_linger_s: the batcher's flush policy.
    tenant_cap:   per-tenant in-flight request bound (None = unlimited);
                  exceeding it raises AdmissionError instead of queueing.
    workers:      batched-solve worker threads (distinct keys solve
                  concurrently; one key's batches serialize on its entry
                  lock regardless, so more workers than hot keys is waste).
    auto_dispatch: False spawns NO threads — batches accumulate until
                  `pump()` runs them on the calling thread (deterministic
                  tests); width/linger policy is otherwise identical.
    pad_widths:   pad every multi-column batch to the next power-of-two
                  width with zero columns before solving (default True).
                  The engines jit-compile per right-hand-side shape, so
                  unpadded serving retraces on every new batch width —
                  a ~100ms stall mid-traffic; bucketing caps the shape
                  set at log2(max_width) + 1, the same trick the
                  schedule compiler plays with width-bucketed ELL tiles.
                  Zero columns solve to zero and are sliced off before
                  futures resolve.
    solve_kwargs: forwarded to every TriangularOperator.solve; the default
                  {"max_refine": 0} is the raw float32 device path —
                  serving wants throughput, callers wanting refined
                  float64 pass {"max_refine": 6} etc.
    registry:     a pre-configured OperatorRegistry; default builds one
                  from **registry_kwargs (tune_mode=, cache=, ...).
    """

    def __init__(self, *, max_width: int = 16, max_linger_s: float = 0.002,
                 tenant_cap: int | None = 64, workers: int = 2,
                 auto_dispatch: bool = True, pad_widths: bool = True,
                 solve_kwargs: dict | None = None,
                 registry: OperatorRegistry | None = None,
                 **registry_kwargs):
        # a caller-supplied registry is shared state (e.g. one tuned
        # registry reused across benchmark sweeps): the service never
        # closes it
        self._own_registry = registry is None
        self.registry = registry if registry is not None \
            else OperatorRegistry(**registry_kwargs)
        self.tenant_cap = tenant_cap
        self.solve_kwargs = {"max_refine": 0} if solve_kwargs is None \
            else dict(solve_kwargs)
        self.stats = ServiceStats()
        self.pad_widths = bool(pad_widths)
        self._clock = time.perf_counter
        self._batcher = MicroBatcher(max_width=max_width,
                                     max_linger_s=max_linger_s)
        self._cond = threading.Condition()
        self._pending: list = []          # batches awaiting pump/dispatch
        self._inflight = collections.Counter()      # tenant -> open requests
        self._tenant_lock = threading.Lock()
        self._closed = False
        self._auto = bool(auto_dispatch)
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=max(1, workers), thread_name_prefix="repro-solve") \
            if self._auto else None
        self._dispatcher = None
        if self._auto:
            self._dispatcher = threading.Thread(
                target=self._dispatch_loop, name="repro-dispatch",
                daemon=True)
            self._dispatcher.start()

    # -- request path ---------------------------------------------------------
    def submit(self, b, matrix, *, tenant: str = "default",
               dtype: str = "float32", side: str = "lower",
               transpose: bool = False) -> concurrent.futures.Future:
        """Admit `matrix` (cold patterns build untuned, synchronously) and
        enqueue one solve of `b` against it.  Returns a Future resolving
        to the solution column; raises AdmissionError when `tenant`
        already has `tenant_cap` requests in flight."""
        if self._closed:
            raise RuntimeError("service is closed")
        with _obs.span("serving.submit", tenant=tenant) as ssp:
            with self._tenant_lock:
                depth = self._inflight[tenant]
                if self.tenant_cap is not None and depth >= self.tenant_cap:
                    self.stats.record_reject(tenant)
                    raise AdmissionError("tenant queue depth cap reached",
                                         tenant=tenant, depth=depth,
                                         limit=self.tenant_cap)
                self._inflight[tenant] += 1
            try:
                entry, bkey, created = self.registry.admit(
                    matrix, dtype=dtype, side=side, transpose=transpose)
            except BaseException:
                self._release(tenant)
                raise
            b = np.asarray(b)
            if b.ndim != 1 or b.shape[0] != matrix.n_rows:
                # reject HERE: a wrong-shape column must fail its own
                # request, never reach stack() and poison a shared batch
                self._release(tenant)
                raise ValueError(
                    f"b must be ({matrix.n_rows},), got {b.shape}")
            # cold admissions surface the operator cache's source (built /
            # memory / disk / pattern); warm ones hit the live registry
            source = entry.op.stats.cache_source if created else "registry"
            self.stats.record_submit(source)
            ssp.set(source=source, created=created,
                    pattern=bkey.pattern_fp[:8])
            fut = concurrent.futures.Future()
            fut.add_done_callback(lambda _f, t=tenant: self._release(t))
            req = SolveRequest(key=bkey, b=b, tenant=tenant, future=fut)
            with self._cond:
                if self._closed:  # closed between the early check and here:
                    fut.cancel()  # cancellation releases the tenant slot
                    raise RuntimeError("service is closed")
                batch = self._batcher.enqueue(req, self._clock())
                if batch is not None and not self._auto:
                    self._pending.append(batch)
                self._cond.notify()
            if batch is not None and self._auto:
                self._pool.submit(self._run_batch, batch)
            return fut

    def solve(self, b, matrix, **kwargs) -> np.ndarray:
        """Synchronous sugar: submit and wait."""
        return self.submit(b, matrix, **kwargs).result()

    def _release(self, tenant: str) -> None:
        with self._tenant_lock:
            self._inflight[tenant] -= 1
            if self._inflight[tenant] <= 0:
                del self._inflight[tenant]

    def inflight(self, tenant: str | None = None) -> int:
        with self._tenant_lock:
            return sum(self._inflight.values()) if tenant is None \
                else self._inflight[tenant]

    # -- dispatch -------------------------------------------------------------
    def _dispatch_loop(self) -> None:
        while True:
            with self._cond:
                if self._closed:
                    batches = self._batcher.flush_all(self._clock())
                else:
                    now = self._clock()
                    deadline = self._batcher.next_deadline()
                    if deadline is None or deadline > now:
                        timeout = 0.05 if deadline is None \
                            else min(deadline - now, 0.05)
                        self._cond.wait(timeout=timeout)
                        continue
                    batches = self._batcher.due(now)
            for batch in batches:
                self._pool.submit(self._run_batch, batch)
            if self._closed:
                return

    def pump(self) -> int:
        """Drain every queued request synchronously on the calling thread
        (auto_dispatch=False mode); returns the number of batches run."""
        with self._cond:
            batches, self._pending = self._pending, []
            batches += self._batcher.flush_all(self._clock())
        for batch in batches:
            self._run_batch(batch)
        return len(batches)

    def _run_batch(self, batch) -> None:
        t0 = self._clock()
        key = batch.key
        with _obs.span("serving.batch", width=batch.width,
                       reason=batch.reason,
                       pattern=key.pattern_fp[:8]) as bsp:
            # queue waits happened before this span on other threads;
            # record them retroactively as children (both ends measured on
            # the tracer's default perf_counter timebase)
            for r in batch.requests:
                _obs.record_span("serving.queue", r.t_enqueue, t0,
                                 parent=bsp, tenant=r.tenant)
            try:
                entry = self.registry.get(EntryKey(
                    pattern_fp=key.pattern_fp, dtype=key.dtype,
                    side=key.side, transpose=key.transpose))
                if entry is None:
                    raise RuntimeError(
                        f"no registry entry for pattern "
                        f"{key.pattern_fp[:8]} (evicted mid-flight?)")
                B = batch.stack()
                if self.pad_widths and B.ndim == 2:
                    bucket = 1 << (B.shape[1] - 1).bit_length()
                    if bucket > B.shape[1]:
                        B = np.concatenate(
                            [B, np.zeros((B.shape[0], bucket - B.shape[1]),
                                         dtype=B.dtype)], axis=1)
                        bsp.set(padded_width=bucket)
                # one lock span covers re-bind + solve: a concurrent value
                # update or hot-swap lands before or after this batch,
                # never inside it
                with entry.lock:
                    op = entry.ensure_values(key.value_fp)
                    with _obs.span("serving.solve", columns=B.shape[-1]
                                   if B.ndim == 2 else 1):
                        x = op.solve(B, **self.solve_kwargs)
            except BaseException as exc:  # noqa: BLE001 - resolve futures
                for r in batch.requests:
                    if r.future is not None and not r.future.done():
                        r.future.set_exception(exc)
                self.stats.record_batch_error(batch)
                return
            t1 = self._clock()
            for j, r in enumerate(batch.requests):
                if r.future is not None:
                    r.future.set_result(np.array(batch.column(x, j)))
            self.stats.record_batch(
                batch, [(t0 - r.t_enqueue) * 1e3 for r in batch.requests],
                (t1 - t0) * 1e3)
            bsp.set(solve_ms=(t1 - t0) * 1e3)

    # -- observability --------------------------------------------------------
    def snapshot(self) -> dict:
        return self.stats.snapshot(self.registry)

    def prometheus_text(self) -> str:
        """One Prometheus text page over every live metrics plane: the
        service's own registry, the operator registry's lifecycle
        counters, and each live entry's per-operator stats (labeled
        `entry=<pattern_fp[:8]>`); see docs/observability.md."""
        from ..obs.export import prometheus_text
        sources: list = [self.stats.registry]
        reg_metrics = getattr(self.registry, "metrics", None)
        if reg_metrics is not None:
            sources.append(reg_metrics)
        for ekey, entry in list(self.registry.entries()):
            op = entry.op
            if op is not None:
                sources.append((op.stats.registry,
                                {"entry": ekey.pattern_fp[:8]}))
        return prometheus_text(*sources)

    def wait_warm(self, timeout: float | None = None) -> bool:
        return self.registry.wait_warm(timeout)

    # -- lifecycle ------------------------------------------------------------
    def close(self, wait: bool = True) -> None:
        """Stop intake, drain queued batches, stop workers and tuner."""
        with self._cond:
            if self._closed:
                return
            self._closed = True
            self._cond.notify_all()
        if self._auto:
            self._dispatcher.join(timeout=5.0)
            self._pool.shutdown(wait=wait)
        else:
            self.pump()
        if self._own_registry:
            self.registry.close(wait=wait)

    def __enter__(self) -> "SolveService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

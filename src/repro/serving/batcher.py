"""Micro-batching scheduler: coalesce concurrent solves into (n, k) blocks.

The cheapest parallelism the stack owns is the batched right-hand side:
every engine streams the compiled schedule ONCE for all k columns of an
(n, k) solve, so k concurrent requests against the same operator cost
barely more than one (the per-step overhead — launches on a single
device, one all_gather family per step under a mesh — is amortized over
the whole block).  This module turns that into a serving-tier policy:
requests sharing a `BatchKey` (pattern fingerprint, value fingerprint,
dtype, sweep orientation) are coalesced into one batch, flushed by the
first of two deterministic triggers:

* **width flush** — the key reaches `max_width` pending requests: the
  batch is returned synchronously from `enqueue()` (the k-th submitter
  pays zero linger).
* **linger flush** — the OLDEST pending request of a key reaches its
  deadline (`t_enqueue + max_linger_s`): `due(now)` returns the batch.
  `next_deadline()` tells the caller when to poll next.

The scheduler is PURE LOGIC: time enters only as the `now` argument, no
clock is read, no thread is spawned, and no locking happens here (the
owning `SolveService` serializes access).  That makes the flush policy
unit-testable without wall-clock races — the property suite
(tests/test_serving_batcher.py) drives it with synthetic clocks and
asserts the three invariants every batch must satisfy:

1. a batch never mixes keys (fingerprints, dtypes, orientations),
2. no request lingers past its deadline (given `due` is polled at or
   after `next_deadline()`),
3. FIFO holds within a key: requests are batched in enqueue order, and
   no later request of a key is served before an earlier one.

Batches retain per-request enqueue metadata so the service can split
queue latency (enqueue -> dispatch) from solve latency in its stats.
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np

__all__ = ["BatchKey", "SolveRequest", "Batch", "MicroBatcher"]


@dataclasses.dataclass(frozen=True)
class BatchKey:
    """What may legally share one batched solve.

    Two requests coalesce only when every field matches: the pattern
    fingerprint pins the schedule/tuner artifact, the value fingerprint
    pins the numeric payload (a value update is a NEW key — in-flight
    requests against the old values keep their own batch), dtype pins the
    device math, and side/transpose pin the sweep orientation.
    """

    pattern_fp: str
    value_fp: str
    dtype: str = "float32"
    side: str = "lower"
    transpose: bool = False


@dataclasses.dataclass
class SolveRequest:
    """One tenant's solve against an admitted operator.

    `seq`, `t_enqueue`, and `deadline` are assigned by the batcher at
    enqueue time; `future` is attached by the service (None for direct
    batcher use).  `b` must be a 1-D right-hand side of the operator's n.
    """

    key: BatchKey
    b: np.ndarray
    tenant: str = "default"
    seq: int = -1
    t_enqueue: float = 0.0
    deadline: float = 0.0
    future: object = None


@dataclasses.dataclass
class Batch:
    """An ordered group of same-key requests, ready to solve as (n, k)."""

    key: BatchKey
    requests: list
    t_flush: float = 0.0        # the `now` at which the batch was formed
    reason: str = ""            # "width" | "linger" | "drain"

    @property
    def width(self) -> int:
        return len(self.requests)

    def stack(self) -> np.ndarray:
        """The batched right-hand side: (n,) for one request, (n, k) in
        enqueue order otherwise — column j belongs to requests[j]."""
        if len(self.requests) == 1:
            return np.asarray(self.requests[0].b)
        return np.stack([np.asarray(r.b) for r in self.requests], axis=1)

    def column(self, x: np.ndarray, j: int) -> np.ndarray:
        """requests[j]'s slice of a solved stack()."""
        return x if x.ndim == 1 else x[:, j]


class MicroBatcher:
    """Deterministic width/linger batching over per-key FIFO queues.

    max_width:    flush a key the moment it holds this many requests
                  (also the widest batch ever returned).
    max_linger_s: the longest any request may wait for co-batchable
                  traffic; a request enqueued at t has deadline
                  t + max_linger_s, and `due(now)` flushes every key whose
                  oldest deadline is <= now.  0 disables lingering —
                  every enqueue returns a width-1 batch immediately.
    """

    def __init__(self, max_width: int = 16, max_linger_s: float = 0.002):
        if max_width < 1:
            raise ValueError(f"max_width must be >= 1, got {max_width}")
        if max_linger_s < 0:
            raise ValueError(
                f"max_linger_s must be >= 0, got {max_linger_s}")
        self.max_width = max_width
        self.max_linger_s = max_linger_s
        self._queues: "collections.OrderedDict[BatchKey, collections.deque]" \
            = collections.OrderedDict()
        self._seq = 0

    # -- enqueue / flush ------------------------------------------------------
    def enqueue(self, req: SolveRequest, now: float) -> Batch | None:
        """Add a request at time `now`; returns the full-width batch when
        this request is the max_width-th of its key (or a width-1 batch
        when lingering is disabled), else None."""
        self._seq += 1
        req.seq = self._seq
        req.t_enqueue = now
        req.deadline = now + self.max_linger_s
        q = self._queues.get(req.key)
        if q is None:
            q = self._queues[req.key] = collections.deque()
        q.append(req)
        if len(q) >= self.max_width or self.max_linger_s == 0:
            return self._flush_key(req.key, now, "width")
        return None

    def due(self, now: float) -> list:
        """Flush every key whose oldest request's deadline is <= now, in
        deadline order.  Idempotent between enqueues: a flushed key holds
        nothing, so calling again returns []."""
        ready = sorted(
            (q[0].deadline, key) for key, q in self._queues.items()
            if q and q[0].deadline <= now)
        return [self._flush_key(key, now, "linger") for _, key in ready]

    def flush_all(self, now: float = float("inf")) -> list:
        """Drain every pending request regardless of deadline (service
        shutdown / deterministic pump), oldest key first."""
        keys = [key for key, q in self._queues.items() if q]
        keys.sort(key=lambda k: self._queues[k][0].seq)
        return [self._flush_key(key, now, "drain") for key in keys]

    def _flush_key(self, key: BatchKey, now: float, reason: str) -> Batch:
        q = self._queues[key]
        take = min(len(q), self.max_width)
        reqs = [q.popleft() for _ in range(take)]
        if not q:
            del self._queues[key]
        return Batch(key=key, requests=reqs, t_flush=now, reason=reason)

    # -- introspection --------------------------------------------------------
    def pending(self) -> int:
        """Total requests currently queued across all keys."""
        return sum(len(q) for q in self._queues.values())

    def pending_keys(self) -> int:
        return sum(1 for q in self._queues.values() if q)

    def next_deadline(self) -> float | None:
        """The earliest pending deadline — when `due()` next has work —
        or None when nothing is queued."""
        deadlines = [q[0].deadline for q in self._queues.values() if q]
        return min(deadlines) if deadlines else None

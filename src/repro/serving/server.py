"""Synthetic-workload driver for the solve service.

    python -m repro.serving.server --requests 200 --tenants 3 --smoke

Stands up an in-process `SolveService` and drives the mixed workload the
serving tier is built for — hot repeat solves, cold admissions of new
patterns, and value-only refreshes that route through `update_values` —
from several tenant threads, then prints the full stats snapshot as
JSON and exits non-zero if anything was dropped (CI's serving smoke job
runs exactly this).  Every solved column is checked against the host
reference oracle, so the run is a correctness gate, not just a liveness
probe.

This is the SOLVE service's driver.  `repro.launch.serve` is a
different program — the LM-side prefill/decode launcher that CONSUMES
triangular solves; see docs/serving.md for how the two relate.
"""
from __future__ import annotations

import argparse
import concurrent.futures
import json
import sys
import threading

import numpy as np

from ..solver.reference import solve_csr_seq
from ..sparse import generators
from .service import SolveService


def step_values(L, step: int):
    """Step k's matrix: same pattern, perturbed values (diagonal scaled,
    not noised, so the triangular systems stay well-conditioned)."""
    rng = np.random.default_rng(1000 + step)
    rows = np.repeat(np.arange(L.n_rows), L.row_nnz())
    d_mask = L.indices == rows
    data = L.data * (1.0 + 0.2 * rng.standard_normal(L.nnz))
    data[d_mask] = L.data[d_mask] * (1.2 + 0.1 * step)
    return L.with_data(data)


def build_matrices(scale: float, patterns: int, seed: int) -> list:
    """A pattern pool: the paper's two analogues plus random fills."""
    pool = [generators.lung2_like(scale=scale),
            generators.torso2_like(scale=scale)]
    n = max(64, int(600 * scale))
    for i in range(max(0, patterns - len(pool))):
        pool.append(generators.random_lower(n, avg_offdiag=3.0,
                                            seed=seed + i))
    return pool[:patterns]


def run_workload(svc: SolveService, matrices: list, *, requests: int,
                 tenants: int, value_steps: int, seed: int,
                 check: bool = True, rel_tol: float = 5e-5) -> dict:
    """Drive a deterministic mixed workload from `tenants` threads.

    Request i: matrix i % len(matrices), value step (i // 7) % value_steps
    (so hot repeats dominate but update_values traffic recurs), tenant
    i % tenants.  Returns {"errors": [...], "checked": n}.
    """
    rng = np.random.default_rng(seed)
    variants = [[m if s == 0 else step_values(m, s) for s in range(value_steps)]
                for m in matrices]
    rhs = [rng.standard_normal(m.n_rows) for m in matrices]
    errors: list = []
    checked = {"n": 0}
    err_lock = threading.Lock()

    def one(i: int) -> None:
        mi = i % len(matrices)
        L = variants[mi][(i // 7) % value_steps]
        b = rhs[mi]
        try:
            x = svc.submit(b, L, tenant=f"tenant-{i % tenants}").result(
                timeout=120)
            if check:
                ref = solve_csr_seq(L, b.astype(np.float64))
                err = float(np.max(np.abs(np.asarray(x, dtype=np.float64)
                                          - ref)))
                scale = float(np.max(np.abs(ref))) or 1.0
                if err / scale > rel_tol:  # default: float32 device path
                    raise AssertionError(
                        f"request {i}: relative error {err / scale:.2e}")
                with err_lock:
                    checked["n"] += 1
        except Exception as exc:    # noqa: BLE001 - collect, don't die
            with err_lock:
                errors.append(f"request {i}: {type(exc).__name__}: {exc}")

    with concurrent.futures.ThreadPoolExecutor(max_workers=tenants) as pool:
        list(pool.map(one, range(requests)))
    return {"errors": errors, "checked": checked["n"]}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--patterns", type=int, default=3)
    ap.add_argument("--value-steps", type=int, default=3)
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--max-width", type=int, default=8)
    ap.add_argument("--linger-ms", type=float, default=2.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-check", action="store_true",
                    help="skip the per-request oracle check")
    ap.add_argument("--smoke", action="store_true",
                    help="small fast preset (CI)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable tracing and write a Chrome trace-event "
                         "JSON of the whole run to PATH")
    ap.add_argument("--prom-out", default=None, metavar="PATH",
                    help="write the final Prometheus text page to PATH")
    args = ap.parse_args(argv)
    if args.smoke:
        args.requests = min(args.requests, 120)
        args.scale = min(args.scale, 0.03)

    from .. import obs
    tracer = obs.enable() if args.trace_out else None

    matrices = build_matrices(args.scale, args.patterns, args.seed)
    svc = SolveService(max_width=args.max_width,
                       max_linger_s=args.linger_ms * 1e-3,
                       tenant_cap=256, workers=2, cache=False)
    try:
        result = run_workload(svc, matrices, requests=args.requests,
                              tenants=args.tenants,
                              value_steps=args.value_steps, seed=args.seed,
                              check=not args.no_check)
        svc.wait_warm(timeout=300)
        prom = svc.prometheus_text() if args.prom_out else None
    finally:
        svc.close()             # drains workers: the snapshot below is final
    snap = svc.snapshot()
    if tracer is not None:
        obs.disable()
        obs.export.write_chrome_trace(args.trace_out, tracer)
    if prom is not None:
        with open(args.prom_out, "w") as fh:
            fh.write(prom)

    report = {"requests": args.requests, "tenants": args.tenants,
              "patterns": len(matrices), "checked": result["checked"],
              "errors": result["errors"], "stats": snap}
    json.dump(report, sys.stdout, indent=2, default=str)
    print()
    dropped = snap["submitted"] - snap["completed"]
    ok = (not result["errors"] and dropped == 0
          and snap["registry"]["hot_swaps"] >= 1)
    if not ok:      # pragma: no cover - failure path
        print(f"FAIL: dropped={dropped} errors={len(result['errors'])} "
              f"hot_swaps={snap['registry']['hot_swaps']}", file=sys.stderr)
    return 0 if ok else 1


if __name__ == "__main__":      # pragma: no cover
    sys.exit(main())

"""Warm-cache admission tier: cold operators serve NOW, tuning runs behind.

The registry owns one `OperatorEntry` per admitted (pattern, dtype,
orientation) and enforces the serving tier's core latency contract:

* **cold** admission NEVER waits for the auto-tuner.  A first-seen matrix
  is built synchronously with `tune="no_rewriting"` — plain level
  scheduling, no strategy search — which is the cheap part of a build
  (the portfolio sweep is what costs ~10x), so the first request's
  response time is bounded by one untuned compile + solve.
* the entry enters **warming**: a background worker runs the full
  `StrategyPortfolio` search (`tune="auto"`) OFF the request path,
  through the same `TriangularOperator.from_csr` disk/memory cache every
  offline build uses (a previously tuned pattern hot-swaps instantly).
* when tuning lands, the tuned operator is **hot-swapped** atomically
  under the entry lock: requests in flight finish on the operator they
  started with, the next dispatch sees the tuned one, and if the entry's
  values drifted while tuning ran (update_values traffic), the tuned
  operator is re-bound to the LATEST values before it is published —
  a swap can never resurrect stale numerics.  The entry is now **hot**.
* a tuner failure (chaos-tested via `repro.core.faults.fail_tuner`)
  marks the entry **degraded**: the untuned operator keeps serving, a
  `TunerFailureWarning` is emitted, and the error is retained on the
  entry for the stats plane.  Tuning never poisons the request path.

Value-only refreshes (same pattern, new numeric payload — the
time-stepping workload of PR 7) do not re-admit: `entry.note_values`
registers the new payload and `entry.ensure_values` re-binds the live
operator through `update_values` at batch-dispatch time, under the same
entry lock that serializes solves, updates, and swaps for that entry.
"""
from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import threading
import time
import warnings

import numpy as np

from ..core.resilience import TunerFailureWarning
from ..obs import trace as _obs
from ..obs.metrics import MetricsRegistry
from ..solver.operator import (TriangularOperator, matrix_fingerprint,
                               value_fingerprint)
from .batcher import BatchKey

__all__ = ["EntryKey", "OperatorEntry", "OperatorRegistry"]

# newest value payloads retained per entry, so in-flight batches keyed by
# an older value fingerprint can still re-bind and solve correctly while
# newer updates stream in
_VALUE_MEMO = 8


@dataclasses.dataclass(frozen=True)
class EntryKey:
    """One admitted operator: pattern + dtype + sweep orientation.

    Value fingerprints are deliberately absent — value refreshes re-bind
    the SAME entry (that is the whole point of the update_values path).
    """

    pattern_fp: str
    dtype: str = "float32"
    side: str = "lower"
    transpose: bool = False


class OperatorEntry:
    """The registry's unit of ownership: one live operator + its lifecycle.

    `lock` serializes everything that touches the operator binding for
    this key — batched solves, value re-binding, and the tuned hot-swap —
    because `update_values` mutates the operator in place and a solve
    must never observe a half-rebound payload.  Distinct entries never
    contend: the lock is per-key, so hot traffic on one matrix cannot
    stall admissions or solves on another.
    """

    def __init__(self, ekey: EntryKey):
        self.ekey = ekey
        self.lock = threading.RLock()
        self.op: TriangularOperator | None = None
        self.state = "cold"          # cold | warming | hot | degraded
        self.bound_fp = ""           # value fingerprint the op is bound to
        self.latest_fp = ""          # newest value fingerprint ever seen
        self.hot_swaps = 0
        self.untuned_solves = 0      # solves served before the swap landed
        self.value_rebinds = 0       # dispatch-time update_values re-binds
                                     # (survives the swap, unlike op.stats)
        self.tune_error = ""
        self.admitted_at = 0.0
        self._values: "collections.OrderedDict[str, object]" = \
            collections.OrderedDict()   # value_fp -> CSR

    # -- value payloads -------------------------------------------------------
    def note_values(self, L, value_fp: str) -> None:
        """Register a numeric payload under its fingerprint (bounded memo;
        newest payloads win) and mark it the entry's latest."""
        with self.lock:
            self._values[value_fp] = L
            self._values.move_to_end(value_fp)
            while len(self._values) > _VALUE_MEMO:
                self._values.popitem(last=False)
            self.latest_fp = value_fp

    def ensure_values(self, value_fp: str):
        """Re-bind the live operator to `value_fp`'s payload (no-op when
        already bound).  Called under dispatch, immediately before the
        batched solve, holding `lock` — so every request in a batch keyed
        by `value_fp` solves exactly those values.  Returns the operator.
        """
        with self.lock:
            if self.op is None:
                raise RuntimeError(
                    f"entry {self.ekey} has no operator (not admitted?)")
            if value_fp != self.bound_fp:
                L = self._values.get(value_fp)
                if L is None:
                    raise KeyError(
                        f"value payload {value_fp!r} expired from entry "
                        f"{self.ekey} (memo keeps {_VALUE_MEMO})")
                self.op.update_values(L)
                self.bound_fp = value_fp
                self.value_rebinds += 1
            return self.op

    def batch_key(self, value_fp: str) -> BatchKey:
        return BatchKey(pattern_fp=self.ekey.pattern_fp, value_fp=value_fp,
                        dtype=self.ekey.dtype, side=self.ekey.side,
                        transpose=self.ekey.transpose)

    # -- introspection --------------------------------------------------------
    def snapshot(self) -> dict:
        with self.lock:
            op_stats = self.op.stats.to_dict() if self.op is not None else {}
            return {"state": self.state, "hot_swaps": self.hot_swaps,
                    "untuned_solves": self.untuned_solves,
                    "value_rebinds": self.value_rebinds,
                    "tune_error": self.tune_error,
                    "bound_fp": self.bound_fp, "latest_fp": self.latest_fp,
                    "strategy": getattr(self.op, "strategy", None),
                    "op": op_stats}


class OperatorRegistry:
    """Get-or-admit operators; run the portfolio tuner off the request path.

    tune_mode: "background" — admit untuned, tune on a worker thread and
                   hot-swap when done (the serving default);
               "sync"       — tune inline during admit (entries are hot
                   immediately; offline/batch jobs and deterministic tests);
               "off"        — never tune (entries stay cold; isolates the
                   batching tier in tests and benchmarks).
    untuned:   strategy for the admission build ("no_rewriting": plain
               level scheduling, no search).
    tune:      strategy spec for the background build ("auto" runs the
               full StrategyPortfolio).
    max_entries: bound on live entries; admission past the bound evicts
               the least-recently-admitted idle entry (its disk-cache
               artifact survives, so re-admission is cheap).
    clock:     injected time source for `admitted_at` stamps (defaults to
               `time.perf_counter`; tests pass a synthetic clock).
    from_csr_kwargs: forwarded to every `TriangularOperator.from_csr`
               (cache=, cache_dir=, chunk=, engine=, mesh=, ...).
    """

    def __init__(self, *, tune="auto", untuned="no_rewriting",
                 tune_mode: str = "background", max_entries: int | None = None,
                 clock=time.perf_counter, **from_csr_kwargs):
        if tune_mode not in ("background", "sync", "off"):
            raise ValueError(
                f"tune_mode must be background|sync|off, got {tune_mode!r}")
        self._clock = clock
        self._tune = tune
        self._untuned = untuned
        self.tune_mode = tune_mode
        self.max_entries = max_entries
        self._kwargs = dict(from_csr_kwargs)
        self._lock = threading.RLock()
        self._entries: "collections.OrderedDict[EntryKey, OperatorEntry]" = \
            collections.OrderedDict()
        self._tuner: concurrent.futures.ThreadPoolExecutor | None = None
        self._tune_jobs: dict = {}        # EntryKey -> Future
        self._closed = False
        # registry-wide lifecycle counters live in a metrics registry so
        # stats() and the Prometheus page read the same ledger; the
        # hot_swaps/value_rebinds/states aggregates stay entry-derived at
        # read time (no dual bookkeeping)
        self.metrics = MetricsRegistry(prefix="repro_registry")
        self._admissions = self.metrics.counter(
            "admissions", "first-seen patterns admitted")
        self._evictions = self.metrics.counter(
            "evictions", "idle entries evicted over max_entries")
        self._tuner_failures = self.metrics.counter(
            "tuner_failures", "background tunes that raised (degraded)")

    @property
    def admissions(self) -> int:
        return self._admissions.value()

    @property
    def evictions(self) -> int:
        return self._evictions.value()

    @property
    def tuner_failures(self) -> int:
        return self._tuner_failures.value()

    # -- admission ------------------------------------------------------------
    def admit(self, L, *, dtype="float32", side: str = "lower",
              transpose: bool = False):
        """Get-or-create the entry for L's pattern; returns
        (entry, batch_key, created) with the batch key pinned to L's
        CURRENT value fingerprint.  First admission (created=True) builds
        the untuned operator synchronously (bounded latency) and, in
        background mode, schedules the portfolio tune; re-admission with
        new values registers the payload for dispatch-time re-binding and
        touches nothing else.
        """
        dtype = np.dtype(dtype).name
        with _obs.span("registry.admit", dtype=dtype) as asp:
            ekey = EntryKey(
                pattern_fp=matrix_fingerprint(L, include_values=False),
                dtype=dtype, side=side, transpose=bool(transpose))
            value_fp = value_fingerprint(L)
            with self._lock:
                if self._closed:
                    raise RuntimeError("registry is closed")
                entry = self._entries.get(ekey)
                created = entry is None
                if created:
                    entry = self._entries[ekey] = OperatorEntry(ekey)
                    self._admissions.inc()
                    # hold the entry lock BEFORE it escapes the registry
                    # lock: concurrent admitters / dispatchers block on
                    # entry.lock until the untuned operator exists, instead
                    # of observing a published-but-empty entry
                    entry.lock.acquire()
                self._entries.move_to_end(ekey)
            asp.set(created=created, pattern=ekey.pattern_fp[:8])
            if created:
                try:
                    entry.note_values(L, value_fp)
                    entry.admitted_at = self._clock()
                    if self.tune_mode == "sync":
                        entry.op = self._build(L, self._tune, ekey)
                        entry.state = "hot"
                    else:
                        entry.op = self._build(L, self._untuned, ekey)
                        if self.tune_mode == "background":
                            entry.state = "warming"
                            self._schedule_tune(entry, L)
                        # "off": stays cold — batching-tier isolation
                    entry.bound_fp = value_fp
                finally:
                    entry.lock.release()
                self._evict_over_cap()
            else:
                entry.note_values(L, value_fp)
            return entry, entry.batch_key(value_fp), created

    def _build(self, L, tune, ekey: EntryKey) -> TriangularOperator:
        return TriangularOperator.from_csr(
            L, tune=tune, side=ekey.side, transpose=ekey.transpose,
            dtype=np.dtype(ekey.dtype), **self._kwargs)

    # -- background tuning ----------------------------------------------------
    def _schedule_tune(self, entry: OperatorEntry, L) -> None:
        with self._lock:
            if self._tuner is None:
                self._tuner = concurrent.futures.ThreadPoolExecutor(
                    max_workers=1, thread_name_prefix="repro-tuner")
            self._tune_jobs[entry.ekey] = self._tuner.submit(
                self._tune_and_swap, entry, L)

    def _tune_and_swap(self, entry: OperatorEntry, L) -> None:
        pat = entry.ekey.pattern_fp[:8]
        with _obs.span("registry.tune", pattern=pat) as tsp:
            try:
                # the slow part runs UNLOCKED: requests keep flowing
                # through the untuned operator while the portfolio searches
                tuned = self._build(L, self._tune, entry.ekey)
            except Exception as exc:     # noqa: BLE001 - any tuner blow-up
                with entry.lock:
                    entry.state = "degraded"
                    entry.tune_error = f"{type(exc).__name__}: {exc}"
                self._tuner_failures.inc()
                tsp.set(outcome="degraded")
                _obs.event("registry.tune_failed", pattern=pat,
                           error=type(exc).__name__)
                warnings.warn(
                    f"background tuning failed for {pat}; serving "
                    f"continues on the untuned operator ({exc})",
                    TunerFailureWarning, stacklevel=2)
                return
            with entry.lock:
                if entry.bound_fp and \
                        entry.bound_fp != value_fingerprint(tuned._L):
                    # values drifted while tuning ran: re-bind the tuned
                    # operator to the entry's CURRENT payload before it is
                    # visible to anyone — the swap must not roll numerics
                    # back
                    tuned.update_values(entry._values[entry.bound_fp])
                    entry.value_rebinds += 1
                entry.untuned_solves = entry.op.stats.solves \
                    if entry.op is not None else 0
                entry.op = tuned
                entry.state = "hot"
                entry.hot_swaps += 1
            tsp.set(outcome="hot_swap")
            _obs.event("registry.hot_swap", pattern=pat,
                       strategy=getattr(tuned, "strategy", None))

    def wait_warm(self, timeout: float | None = None) -> bool:
        """Block until every scheduled tune has finished (swapped or
        degraded).  Returns False on timeout.  Test/benchmark hook — the
        serving path never calls this."""
        with self._lock:
            jobs = list(self._tune_jobs.values())
        done, not_done = concurrent.futures.wait(jobs, timeout=timeout)
        return not not_done

    # -- capacity -------------------------------------------------------------
    def _evict_over_cap(self) -> None:
        if self.max_entries is None:
            return
        with self._lock:
            while len(self._entries) > self.max_entries:
                victim_key = next(iter(self._entries))   # oldest admission
                job = self._tune_jobs.get(victim_key)
                if job is not None and not job.done():
                    break   # never evict mid-tune; retry on next admission
                del self._entries[victim_key]
                self._tune_jobs.pop(victim_key, None)
                self._evictions.inc()

    # -- lookup / stats -------------------------------------------------------
    def get(self, ekey: EntryKey) -> OperatorEntry | None:
        with self._lock:
            return self._entries.get(ekey)

    def entries(self) -> list:
        """Live (EntryKey, OperatorEntry) pairs (scrape/introspection)."""
        with self._lock:
            return list(self._entries.items())

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def stats(self) -> dict:
        with self._lock:
            entries = dict(self._entries)
            counters = {"admissions": self.admissions,
                        "evictions": self.evictions,
                        "tuner_failures": self.tuner_failures}
        snaps = {f"{k.pattern_fp[:8]}:{k.dtype}:{k.side}"
                 f"{':T' if k.transpose else ''}": e.snapshot()
                 for k, e in entries.items()}
        counters["hot_swaps"] = sum(s["hot_swaps"] for s in snaps.values())
        counters["value_rebinds"] = sum(s["value_rebinds"]
                                        for s in snaps.values())
        counters["states"] = collections.Counter(
            s["state"] for s in snaps.values())
        counters["entries"] = snaps
        return counters

    # -- lifecycle ------------------------------------------------------------
    def close(self, wait: bool = True) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            tuner = self._tuner
        if tuner is not None:
            tuner.shutdown(wait=wait, cancel_futures=not wait)

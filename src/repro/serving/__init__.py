"""Multi-tenant SpTRSV solve service (docs/serving.md).

Layers, bottom-up:

* `batcher`  — pure-logic micro-batching: same-fingerprint requests
  coalesce into one (n, k) solve under a width/linger flush policy.
* `registry` — warm-cache admission: cold patterns serve immediately via
  an untuned build, the `StrategyPortfolio` tunes in the background, and
  the tuned operator hot-swaps atomically; value-only refreshes re-bind
  through `TriangularOperator.update_values`.
* `service`  — the front door: `submit()` futures, per-tenant in-flight
  caps (typed `AdmissionError`), worker pool, `ServiceStats`.
* `server`   — `python -m repro.serving.server`: a synthetic mixed
  workload driver for smoke-testing a live service (the LM-side launch
  driver `repro.launch.serve` is a different program; see docs).
"""
from ..core.resilience import AdmissionError, TunerFailureWarning
from .batcher import Batch, BatchKey, MicroBatcher, SolveRequest
from .registry import EntryKey, OperatorEntry, OperatorRegistry
from .service import ServiceStats, SolveService

__all__ = [
    "Batch", "BatchKey", "MicroBatcher", "SolveRequest",
    "EntryKey", "OperatorEntry", "OperatorRegistry",
    "ServiceStats", "SolveService",
    "AdmissionError", "TunerFailureWarning",
]

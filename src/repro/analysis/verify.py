"""Schedule race detector + invariant certifier (docs/analysis.md).

The paper's premise is that the transformed dependency graph stays
*equivalent* while gaining parallelism.  Dynamic checks (sampled solves
against the host oracle, residual guards) can only catch a bad schedule
after it has produced a wrong answer; this module proves the structural
half statically, before anything executes:

* `verify_level_schedule` — vectorized O(nnz) checks over a
  `LevelSchedule` (or a `DeviceSchedule`, via its host back-pointer):
  every ELL dependency and carry segment is produced at a strictly
  earlier step (scheduling-race detection, split-row carry chains
  included), every row is finalized exactly once (lane/row bijection),
  every ELL / carry / value-plan index is in bounds with padding lanes
  fully inert, the numeric payload is finite with `dinv` bitwise equal
  to `1/diag` in the schedule dtype, width buckets are well-formed, and
  (optionally) one collective family per step on the sharded lowering.
  Returns a `ScheduleCertificate` carrying the *certified* quality
  metrics — step count, critical-path length, cross-device edge count —
  that BENCH_schedule and the cost model can cite from a verified
  source.  Violations raise `ScheduleInvariantError` naming the check,
  step, and lane.
* `audit_transformed_system` — the transform auditor: triangularity of
  the rewritten system, level monotonicity along every dependency edge,
  fill accounting against `TransformMetrics`, T-factor source
  monotonicity, and `ReplayPlan` commit bounds.  Violations raise
  `TransformInvariantError`.
* `verify_schedule_values` — the cheap value-only re-audit the
  `update_values` refactorization fast path runs under strict health:
  packed-nnz accounting, payload finiteness, and `dinv` agreement on a
  structure that was already certified at build time.

`solver.schedule.validate_schedule` is a thin shim over
`verify_level_schedule` (one implementation); strict-mode operator
builds call the verifier exactly once per built artifact and stash the
certificate in the cached payload, so cache hits re-verify nothing.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.resilience import ScheduleInvariantError, TransformInvariantError

__all__ = [
    "ScheduleCertificate", "certificate_dict", "verify_level_schedule",
    "verify_schedule_values", "audit_transformed_system",
    "verify_operator_payload",
]

#: checks verify_level_schedule runs, in order (certificate.checks)
STRUCTURAL_CHECKS = (
    "shape", "index-bounds", "padding", "bijection", "race", "carry-order",
    "dtype", "value-plan",
)
VALUE_CHECKS = ("nnz", "finite", "dinv")


@dataclasses.dataclass(frozen=True)
class ScheduleCertificate:
    """Proof-carrying summary of one verified LevelSchedule.

    Every field is derived during verification, so citing it is citing a
    *certified* quantity (docs/analysis.md lists the invariant catalog):

    n / nnz:        system size and packed nonzero count (== matrix nnz).
    steps:          certified step count — every dependency crosses a step
                    boundary, so `steps` barriers are sufficient.
    levels:         level count of the input assignment (steps <= levels
                    for compacted schedules).
    critical_path:  longest dependency chain through lanes and carry
                    segments, in steps — no schedule for this lane split
                    can use fewer steps, so `steps - critical_path` is the
                    certified compaction slack.
    cross_device_edges: dependency edges whose producer and consumer lanes
                    live on different devices under block lane sharding
                    over `devices` devices (0 when devices == 1) — the
                    quantity the communication-avoiding partitioner must
                    minimize.
    devices:        device count the cross-device count was computed for.
    n_carry:        carry slots (split-row chains).
    group_widths:   ELL width buckets.
    flops / padded_flops: real and padded work (== LevelSchedule.flops()/
                    padded_flops(), re-derived from the verified tiles).
    dtype:          schedule value dtype name.
    collective_families: per-step all_gather families counted on the
                    traced sharded lowering, or None when the collectives
                    check was skipped (default; it requires a jax trace).
    checks:         names of the checks that ran.
    """

    n: int
    nnz: int
    steps: int
    levels: int
    critical_path: int
    cross_device_edges: int
    devices: int
    n_carry: int
    group_widths: tuple
    flops: int
    padded_flops: int
    dtype: str
    collective_families: int | None
    checks: tuple


def certificate_dict(cert: ScheduleCertificate) -> dict:
    """JSON-able view (BENCH_schedule's per-matrix `certificate` block)."""
    d = dataclasses.asdict(cert)
    d["group_widths"] = list(cert.group_widths)
    d["checks"] = list(cert.checks)
    return d


def _host(sched):
    """Unwrap a DeviceSchedule to its host LevelSchedule."""
    return getattr(sched, "host", sched)


def _fail(msg, *, check, step=-1, lane=-1, group=-1, where=""):
    raise ScheduleInvariantError(msg, check=check, step=step, lane=lane,
                                 group=group, where=where)


def _first_bad(mask):
    """(step, lane) of the first True in a (S, C[, D]) mask."""
    idx = np.argwhere(mask)[0]
    return int(idx[0]), int(idx[1])


def _check_shapes(sched, where):
    S = sched.num_steps
    prev_w = 0
    for gi, g in enumerate(sched.groups):
        s, c = g.row_ids.shape
        if s != S:
            _fail(f"group {gi} has {s} steps, group 0 has {S}",
                  check="shape", group=gi, where=where)
        if g.dep_idx.shape != (s, c, g.width) or \
                g.dep_coef.shape != g.dep_idx.shape or \
                g.dinv.shape != (s, c):
            _fail(f"group {gi} tile shapes disagree with width {g.width}: "
                  f"dep_idx {g.dep_idx.shape}, dep_coef {g.dep_coef.shape}, "
                  f"dinv {g.dinv.shape}", check="shape", group=gi,
                  where=where)
        if not 0 < g.width <= sched.max_deps:
            _fail(f"group {gi} width {g.width} outside (0, max_deps="
                  f"{sched.max_deps}]", check="shape", group=gi, where=where)
        if g.width <= prev_w:
            _fail(f"group widths not strictly increasing at group {gi} "
                  f"({g.width} after {prev_w})", check="shape", group=gi,
                  where=where)
        prev_w = g.width
        if (g.carry_in is None) != (g.carry_out is None):
            _fail(f"group {gi} has only one of carry_in/carry_out",
                  check="shape", group=gi, where=where)
        if g.carry_in is not None and (g.carry_in.shape != (s, c) or
                                       g.carry_out.shape != (s, c)):
            _fail(f"group {gi} carry shapes {g.carry_in.shape}/"
                  f"{g.carry_out.shape} != {(s, c)}", check="shape",
                  group=gi, where=where)
        if g.n != sched.n:
            _fail(f"group {gi} n={g.n} != schedule n={sched.n}",
                  check="shape", group=gi, where=where)


def _check_bounds(sched, where):
    n, nc = sched.n, sched.n_carry
    for gi, g in enumerate(sched.groups):
        for name, arr, hi in (("row_ids", g.row_ids, n),
                              ("dep_idx", g.dep_idx, n)):
            bad = (arr < 0) | (arr > hi)
            if bad.any():
                st, ln = _first_bad(bad if arr.ndim == 2 else bad.any(2))
                _fail(f"{name} value {int(arr[bad][0])} outside [0, {hi}]",
                      check="index-bounds", step=st, lane=ln, group=gi,
                      where=where)
        if g.carry_in is not None:
            bad = (g.carry_in < 0) | (g.carry_in > nc)
            if bad.any():
                st, ln = _first_bad(bad)
                _fail(f"carry_in slot {int(g.carry_in[bad][0])} outside "
                      f"[0, {nc}]", check="index-bounds", step=st, lane=ln,
                      group=gi, where=where)
            bad = (g.carry_out < 0) | (g.carry_out > nc + 1) | \
                (g.carry_out == nc)
            if bad.any():
                st, ln = _first_bad(bad)
                _fail(f"carry_out slot {int(g.carry_out[bad][0])} outside "
                      f"[0, {nc}) u {{sink {nc + 1}}} (slot {nc} is the "
                      f"read-only zero slot)", check="index-bounds", step=st,
                      lane=ln, group=gi, where=where)


def _live_mask(sched, g):
    live = g.row_ids != sched.n
    if g.carry_out is not None:
        live = live | (g.carry_out != sched.n_carry + 1)
    return live


def _check_padding(sched, where):
    """Dead lanes are fully inert: no live coefficient, no dinv, and live
    coefficients never gather the zero slot (row n) — a live coef on an
    out-of-range row would read zero and silently corrupt the sum."""
    n = sched.n
    for gi, g in enumerate(sched.groups):
        live = _live_mask(sched, g)
        real = g.dep_coef != 0
        bad = real & ~live[:, :, None]
        if bad.any():
            st, ln = _first_bad(bad.any(2))
            _fail("nonzero dep_coef on a padding lane", check="padding",
                  step=st, lane=ln, group=gi, where=where)
        bad = real & (g.dep_idx == n)
        if bad.any():
            st, ln = _first_bad(bad.any(2))
            _fail("live coefficient gathers the zero slot (row n)",
                  check="padding", step=st, lane=ln, group=gi, where=where)
        bad = (g.row_ids == n) & (g.dinv != 0)
        if bad.any():
            st, ln = _first_bad(bad)
            _fail("nonzero dinv on a lane that finalizes no row",
                  check="padding", step=st, lane=ln, group=gi, where=where)


def _finalize_steps(sched, where):
    """fin_step[row] = step finalizing the row; enforces the bijection."""
    n = sched.n
    seen = np.zeros(n, dtype=np.int64)
    fin_step = np.full(n + 1, -1, dtype=np.int64)
    for gi, g in enumerate(sched.groups):
        fin = g.is_final
        rows = g.row_ids[fin]
        np.add.at(seen, rows, 1)
        steps = np.broadcast_to(
            np.arange(g.row_ids.shape[0])[:, None], g.row_ids.shape)[fin]
        fin_step[rows] = steps
    if (seen != 1).any():
        row = int(np.argwhere(seen != 1)[0][0])
        # locate the offending lane for the error message
        for gi, g in enumerate(sched.groups):
            hit = (g.row_ids == row) & g.is_final
            if hit.any():
                st, ln = _first_bad(hit)
                _fail(f"row {row} finalized {int(seen[row])} times (first "
                      f"duplicate lane shown)", check="bijection", step=st,
                      lane=ln, group=gi, where=where)
        _fail(f"row {row} finalized {int(seen[row])} times",
              check="bijection", where=where)
    return fin_step


def _check_races(sched, fin_step, where):
    """Every live dependency reads a row finalized at a STRICTLY earlier
    step — the scheduling-race invariant compaction must preserve."""
    for gi, g in enumerate(sched.groups):
        real = g.dep_coef != 0
        if not real.any():
            continue
        steps = np.arange(g.row_ids.shape[0])[:, None, None]
        prod = fin_step[g.dep_idx]          # -1 for never-finalized rows
        bad = real & (prod >= steps)
        if bad.any():
            st, ln = _first_bad(bad.any(2))
            dep = int(g.dep_idx[bad][0])
            _fail(f"dependency on row {dep} finalized at step "
                  f"{int(fin_step[dep])} (not strictly earlier) — "
                  f"scheduling race", check="race", step=st, lane=ln,
                  group=gi, where=where)


def _check_carry_order(sched, where):
    """Carry chains: every slot written exactly once, every read strictly
    after its write (split-row segments must land before the tail sums
    them)."""
    nc = sched.n_carry
    if nc <= 0:
        return
    writes = np.zeros(nc, dtype=np.int64)
    wstep = np.full(nc + 1, -1, dtype=np.int64)   # slot nc = zero slot
    for g in sched.groups:
        if g.carry_out is None:
            continue
        realw = g.carry_out != nc + 1
        slots = g.carry_out[realw]
        np.add.at(writes, slots, 1)
        steps = np.broadcast_to(
            np.arange(g.carry_out.shape[0])[:, None], g.carry_out.shape)
        wstep[slots] = steps[realw]
    # slot 0 may legitimately be unused on schedules without splits, but a
    # double write is always a lost segment
    if (writes > 1).any():
        slot = int(np.argwhere(writes > 1)[0][0])
        _fail(f"carry slot {slot} written {int(writes[slot])} times",
              check="carry-order", where=where)
    wstep[nc] = -1                                # zero slot: always ready
    for gi, g in enumerate(sched.groups):
        if g.carry_in is None:
            continue
        live = _live_mask(sched, g)
        used = live & (g.carry_in != nc)
        if not used.any():
            continue
        steps = np.arange(g.carry_in.shape[0])[:, None]
        ws = wstep[g.carry_in]
        bad = used & (ws < 0)
        if bad.any():
            st, ln = _first_bad(bad)
            _fail(f"carry slot {int(g.carry_in[bad][0])} read but never "
                  f"written", check="carry-order", step=st, lane=ln,
                  group=gi, where=where)
        bad = used & (ws >= steps)
        if bad.any():
            st, ln = _first_bad(bad)
            slot = int(g.carry_in[bad][0])
            _fail(f"carry slot {slot} read at or before its write step "
                  f"{int(wstep[slot])} — split-row race", check="carry-order",
                  step=st, lane=ln, group=gi, where=where)


def _check_dtypes(sched, where):
    dtype = np.dtype(sched.dtype)
    if dtype.kind != "f":
        _fail(f"schedule dtype {dtype} is not floating", check="dtype",
              where=where)
    for gi, g in enumerate(sched.groups):
        if g.dep_coef.dtype != dtype or g.dinv.dtype != dtype:
            _fail(f"group {gi} payload dtypes {g.dep_coef.dtype}/"
                  f"{g.dinv.dtype} != schedule dtype {dtype}", check="dtype",
                  group=gi, where=where)
        for name, arr in (("row_ids", g.row_ids), ("dep_idx", g.dep_idx),
                          ("carry_in", g.carry_in),
                          ("carry_out", g.carry_out)):
            if arr is not None and arr.dtype.kind not in "iu":
                _fail(f"group {gi} {name} dtype {arr.dtype} is not integer",
                      check="dtype", group=gi, where=where)


def _check_value_plan(sched, where):
    plan = sched.value_plan
    if plan is None:
        return
    n = sched.n
    lanes = sum(g.row_ids.size for g in sched.groups)
    slots = sum(g.dep_idx.size for g in sched.groups)
    if plan.ent_src is not None:
        if plan.ent_src.shape != (plan.nnz,):
            _fail(f"value-plan ent_src shape {plan.ent_src.shape} != "
                  f"({plan.nnz},)", check="value-plan", where=where)
        if plan.nnz and not ((plan.ent_src >= 0) &
                             (plan.ent_src < plan.nnz)).all():
            _fail("value-plan ent_src index outside [0, nnz)",
                  check="value-plan", where=where)
    if plan.coef_dst.shape != (plan.nnz,):
        _fail(f"value-plan coef_dst shape {plan.coef_dst.shape} != "
              f"({plan.nnz},)", check="value-plan", where=where)
    if plan.nnz and (np.unique(plan.coef_dst).size != plan.nnz or
                     not ((plan.coef_dst >= 0) &
                          (plan.coef_dst < slots)).all()):
        _fail("value-plan coef_dst is not an injection into the dep-slot "
              "buffer", check="value-plan", where=where)
    ln = plan.lane_slot.shape[0]
    if plan.lane_row.shape[0] != ln or plan.lane_final.shape[0] != ln:
        _fail("value-plan lane arrays disagree in length",
              check="value-plan", where=where)
    if ln and (np.unique(plan.lane_slot).size != ln or
               not ((plan.lane_slot >= 0) & (plan.lane_slot < lanes)).all()):
        _fail("value-plan lane_slot is not an injection into the lane "
              "buffer", check="value-plan", where=where)
    if ln and not ((plan.lane_row >= 0) & (plan.lane_row <= n)).all():
        _fail("value-plan lane_row outside [0, n]", check="value-plan",
              where=where)


def _check_values(sched, A, diag, where):
    """The value-level audit: packed-nnz accounting, payload finiteness,
    dinv bitwise equal to 1/diag in the schedule dtype."""
    packed = sum(int((g.dep_coef != 0).sum()) for g in sched.groups)
    if A is not None:
        want = int((np.asarray(A.data) != 0).sum())
        if packed != want:
            _fail(f"packed nnz {packed} != matrix nnz {want} — entries "
                  f"lost or duplicated", check="nnz", where=where)
    for gi, g in enumerate(sched.groups):
        bad = ~np.isfinite(g.dep_coef)
        if bad.any():
            st, ln = _first_bad(bad.any(2))
            _fail("non-finite dep_coef", check="finite", step=st, lane=ln,
                  group=gi, where=where)
        bad = ~np.isfinite(g.dinv)
        if bad.any():
            st, ln = _first_bad(bad)
            _fail("non-finite dinv", check="finite", step=st, lane=ln,
                  group=gi, where=where)
    if diag is not None:
        dtype = np.dtype(sched.dtype)
        dinv_of = np.zeros(sched.n + 1, dtype=dtype)
        if sched.n:
            dinv_of[:sched.n] = 1.0 / np.asarray(diag, dtype=dtype)
        for gi, g in enumerate(sched.groups):
            fin = g.is_final
            bad = fin & (g.dinv != dinv_of[g.row_ids])
            if bad.any():
                st, ln = _first_bad(bad)
                row = int(g.row_ids[bad][0])
                _fail(f"dinv disagrees with 1/diag[{row}] in {dtype}",
                      check="dinv", step=st, lane=ln, group=gi, where=where)
    return packed


def _lane_devices(g, devices: int) -> np.ndarray:
    """Device of each lane under the padded block sharding the sharded
    engine uses (lane axis padded to a multiple of `devices`, split in
    contiguous blocks)."""
    c = g.row_ids.shape[1]
    c_pad = -(-c // devices) * devices
    return np.minimum(np.arange(c) // (c_pad // devices), devices - 1)


def _critical_path_and_edges(sched, fin_step, devices: int):
    """One pass over steps: longest dependency chain through lanes and
    carry segments (in steps), and the cross-device dependency-edge count
    under block lane sharding over `devices` devices."""
    n, nc = sched.n, sched.n_carry
    depth = np.zeros(n + 1, dtype=np.int64)        # row n: zero slot
    cdepth = np.zeros(nc + 2, dtype=np.int64)
    dev_of_row = np.zeros(n + 1, dtype=np.int64)
    dev_of_carry = np.full(nc + 2, -1, dtype=np.int64)
    cross = 0
    lane_dev = [(_lane_devices(g, devices) if devices > 1 else None)
                for g in sched.groups]
    for s in range(sched.num_steps):
        updates = []
        for gi, g in enumerate(sched.groups):
            real = g.dep_coef[s] != 0                  # (C, D)
            dep_depth = np.where(real, depth[g.dep_idx[s]], 0).max(
                axis=1, initial=0)
            if g.carry_in is not None:
                dep_depth = np.maximum(dep_depth, cdepth[g.carry_in[s]])
            lane_depth = dep_depth + 1
            if devices > 1:
                dev = lane_dev[gi]
                prod = np.where(real, dev_of_row[g.dep_idx[s]],
                                dev[:, None])
                cross += int((real & (prod != dev[:, None])).sum())
                if g.carry_in is not None:
                    cprod = dev_of_carry[g.carry_in[s]]
                    cross += int(((cprod >= 0) & (cprod != dev)).sum())
            updates.append((g, lane_depth))
        for gi, (g, lane_depth) in enumerate(updates):
            fin = g.is_final[s]
            depth[g.row_ids[s][fin]] = lane_depth[fin]
            if devices > 1:
                dev_of_row[g.row_ids[s][fin]] = lane_dev[gi][fin]
            if g.carry_out is not None:
                w = g.carry_out[s] != nc + 1
                cdepth[g.carry_out[s][w]] = lane_depth[w]
                if devices > 1:
                    dev_of_carry[g.carry_out[s][w]] = lane_dev[gi][w]
    return int(depth[:n].max(initial=0)), cross


def verify_collectives(sched, mesh=None, axis: str = "model") -> int:
    """Trace the sharded lowering and certify one all_gather family per
    step (the sharded engine's synchronization invariant).  Returns the
    family count; requires jax, so it only runs when requested."""
    from ..solver.distributed import count_all_gathers
    g = count_all_gathers(_host(sched), mesh=mesh, axis=axis)
    if g["families"] != g["steps"]:
        _fail(f"sharded lowering issued collectives in {g['families']} of "
              f"{g['steps']} steps — not one family per step ({g})",
              check="collectives", where="verify_collectives")
    return int(g["families"])


def verify_level_schedule(sched, A=None, diag=None, *, devices: int = 1,
                          collectives: bool = False, mesh=None,
                          mesh_axis: str = "model",
                          where: str = "verify_level_schedule"
                          ) -> ScheduleCertificate:
    """Statically verify a LevelSchedule/DeviceSchedule; return its
    certificate.

    A / diag:  the strict-lower matrix and diagonal the schedule was
               compiled from — enables the packed-nnz and dinv-agreement
               checks (structure-only verification runs without them).
    devices:   compute `cross_device_edges` for block lane sharding over
               this many devices (1 = single device, 0 edges).
    collectives: additionally trace the sharded lowering and certify one
               all_gather family per step (needs jax; off by default).
    Raises ScheduleInvariantError (a ResilienceError) on the first
    violation, naming the check, step, and lane.
    """
    sched = _host(sched)
    if devices < 1:
        raise ValueError(f"devices must be >= 1, got {devices}")
    checks = list(STRUCTURAL_CHECKS)
    if sched.num_steps == 0 or not sched.groups:
        if sched.n != 0:
            _fail(f"empty schedule for n={sched.n}", check="bijection",
                  where=where)
        return ScheduleCertificate(
            n=0, nnz=0, steps=0, levels=sched.num_levels, critical_path=0,
            cross_device_edges=0, devices=devices, n_carry=sched.n_carry,
            group_widths=(), flops=0, padded_flops=0,
            dtype=np.dtype(sched.dtype).name if sched.groups else "float32",
            collective_families=None, checks=tuple(checks))
    _check_shapes(sched, where)
    _check_bounds(sched, where)
    _check_padding(sched, where)
    fin_step = _finalize_steps(sched, where)
    _check_races(sched, fin_step, where)
    _check_carry_order(sched, where)
    _check_dtypes(sched, where)
    _check_value_plan(sched, where)
    checks += list(VALUE_CHECKS)
    packed = _check_values(sched, A, diag, where)
    crit, cross = _critical_path_and_edges(sched, fin_step, devices)
    fams = None
    if collectives:
        fams = verify_collectives(sched, mesh=mesh, axis=mesh_axis)
        checks.append("collectives")
    return ScheduleCertificate(
        n=sched.n, nnz=packed, steps=sched.num_steps,
        levels=sched.num_levels, critical_path=crit,
        cross_device_edges=cross, devices=devices, n_carry=sched.n_carry,
        group_widths=tuple(sched.group_widths), flops=sched.flops(),
        padded_flops=sched.padded_flops(),
        dtype=np.dtype(sched.dtype).name, collective_families=fams,
        checks=tuple(checks))


def verify_schedule_values(sched, A=None, diag=None, *,
                           where: str = "verify_schedule_values") -> int:
    """The value-only re-audit for pattern-frozen repacks: nnz accounting,
    finiteness, dinv agreement — O(nnz), no structural re-verification
    (the structure was certified when the pattern was built).  Returns the
    packed nnz; raises ScheduleInvariantError on violation."""
    return _check_values(_host(sched), A, diag, where)


def audit_transformed_system(ts, *, where: str = "audit_transformed_system"
                             ) -> dict:
    """Statically audit a TransformedSystem + its ReplayPlan commit log.

    Checks (docs/analysis.md): the rewritten dependency matrix is strictly
    lower triangular; both level assignments are monotone along every
    dependency edge (and recomputed never exceeds assigned); the fill
    accounting matches TransformMetrics (nnz_A, nnz_T, num_levels_after,
    rows_rewritten == committed rows); the T factor's references are
    source-monotone (every entity reads entities of strictly smaller
    source rows — what makes the preamble a triangular solve); the diagonal
    is finite and nonzero; replay-plan commits index in bounds and target
    strictly earlier levels, each row committed at most once.

    Returns {"rows": n, "commits": len(commits), ...} audit facts; raises
    TransformInvariantError on the first violation.
    """
    n = int(ts.diag.shape[0])
    d = np.asarray(ts.diag)
    if not np.isfinite(d).all() or (d == 0).any():
        raise TransformInvariantError(
            "diagonal contains zero or non-finite entries",
            check="diagonal", where=where)
    A = ts.A
    if A.n_rows != n:
        raise TransformInvariantError(
            f"A has {A.n_rows} rows, diagonal has {n}", check="shape",
            where=where)
    rows = np.repeat(np.arange(n), np.diff(A.indptr))
    if A.nnz and not (A.indices < rows).all():
        p = int(np.argwhere(A.indices >= rows)[0][0])
        raise TransformInvariantError(
            f"entry ({int(rows[p])}, {int(A.indices[p])}) is not strictly "
            f"lower triangular", check="triangularity", where=where)
    for name, lof in (("assigned", ts.level_of_assigned),
                      ("recomputed", ts.level_of_recomputed)):
        if lof.shape[0] != n:
            raise TransformInvariantError(
                f"{name} level assignment has {lof.shape[0]} entries, "
                f"system has {n}", check="level-monotonicity", where=where)
        if A.nnz and not (lof[A.indices] < lof[rows]).all():
            bad = np.argwhere(lof[A.indices] >= lof[rows])[0][0]
            raise TransformInvariantError(
                f"{name} levels non-monotone along edge "
                f"({int(rows[bad])}, {int(A.indices[bad])})",
                check="level-monotonicity", where=where)
    if n and int(ts.level_of_recomputed.max()) > \
            int(ts.level_of_assigned.max()):
        raise TransformInvariantError(
            "recomputed level count exceeds assigned",
            check="level-monotonicity", where=where)
    m = ts.metrics
    if m.nnz_A != A.nnz or m.nnz_T != ts.T.nnz:
        raise TransformInvariantError(
            f"fill accounting drift: metrics say nnz_A={m.nnz_A}/"
            f"nnz_T={m.nnz_T}, system has {A.nnz}/{ts.T.nnz}",
            check="fill-accounting", where=where)
    want_levels = int(ts.level_of_assigned.max()) + 1 if n else 0
    if m.num_levels_after != want_levels:
        raise TransformInvariantError(
            f"metrics num_levels_after={m.num_levels_after}, assigned "
            f"levels={want_levels}", check="fill-accounting", where=where)
    T = ts.T
    if T.nnz:
        if ts.src.shape[0] != T.n_rows:
            raise TransformInvariantError(
                f"src maps {ts.src.shape[0]} entities, T has {T.n_rows}",
                check="t-factor", where=where)
        if not ((ts.src >= 0) & (ts.src < n)).all():
            raise TransformInvariantError(
                "entity source row outside [0, n)", check="t-factor",
                where=where)
        trows = np.repeat(np.arange(T.n_rows), np.diff(T.indptr))
        if not (ts.src[T.indices] < ts.src[trows]).all():
            raise TransformInvariantError(
                "T-factor reference is not source-monotone (an entity "
                "reads an entity of an equal or later source row) — the "
                "preamble would not be a triangular solve",
                check="t-factor", where=where)
    plan = ts.plan
    commits = 0
    if plan is not None:
        if plan.level_of0.shape[0] != n:
            raise TransformInvariantError(
                f"replay plan covers {plan.level_of0.shape[0]} rows, "
                f"system has {n}", check="replay-bounds", where=where)
        # re-commits are legal (EquationStore._commit_version): a strategy
        # may move the same row again, but only ever DOWNWARD — each
        # commit's target must be strictly below the row's current level
        cur = {}
        for k, (row, target) in enumerate(plan.commits):
            if not 0 <= row < n:
                raise TransformInvariantError(
                    f"commit {k} rewrites row {row} outside [0, {n})",
                    check="replay-bounds", where=where)
            level = cur.get(row, int(plan.level_of0[row]))
            if not 0 <= target < level:
                raise TransformInvariantError(
                    f"commit {k} moves row {row} to level {target}, not "
                    f"strictly earlier than its level {level}",
                    check="replay-bounds", where=where)
            cur[row] = target
        commits = len(plan.commits)
        if m.rows_rewritten != commits:
            raise TransformInvariantError(
                f"metrics count {m.rows_rewritten} rewritten rows, replay "
                f"plan commits {commits}", check="fill-accounting",
                where=where)
    return {"rows": n, "nnz_A": A.nnz, "nnz_T": T.nnz, "commits": commits,
            "levels_assigned": want_levels}


def verify_operator_payload(payload: dict, *, devices: int = 1,
                            collectives: bool = False,
                            where: str = "verify_operator_payload"
                            ) -> ScheduleCertificate:
    """Verify one TriangularOperator payload end to end: audit the
    transformed system, then certify its schedule against ts.A/ts.diag.
    The certificate is stashed under payload["certificate"], so cached
    artifacts carry their proof and are never re-verified."""
    ts = payload["ts"]
    audit_transformed_system(ts, where=where)
    cert = verify_level_schedule(payload["sched"], ts.A, ts.diag,
                                 devices=devices, collectives=collectives,
                                 where=where)
    payload["certificate"] = cert
    return cert

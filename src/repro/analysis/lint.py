"""Repo-rule static lint: the house invariants as an AST pass.

PRs 4-9 learned a set of conventions the hard way — host callbacks
sneaking into jit-traced loop bodies, wall-clock reads in logic that is
documented clock-injected, memo writes racing their lock, engines
skipping the dtype gate, bare excepts swallowing typed errors.  This
module mechanizes them over `src/repro` with nothing but the standard
library, so `python -m tools.lint` can gate CI.

Rules (docs/analysis.md carries the catalog with rationale):

  bare-except             `except:` without an exception type — swallows
                          the typed ResilienceError taxonomy and
                          KeyboardInterrupt alike.
  wall-clock              a direct `time.time()/perf_counter()/
                          monotonic()` (or `datetime.now()`) CALL inside a
                          clock-injected module (`CLOCK_INJECTED`): the
                          serving batcher/registry/service and the obs
                          tier take time as an injected `clock`/`now`
                          argument so tests drive them with synthetic
                          clocks.  Referencing `time.perf_counter` as a
                          default value is fine — calling it is not.
  host-callback-in-loop   `jax.pure_callback`/`io_callback` or a host
                          numpy call inside a function passed to
                          `lax.scan` / `lax.while_loop` / `lax.fori_loop`
                          — a host round-trip per traced step, and numpy
                          on traced values is a trace-time crash at best.
  unlocked-memo-mutation  a module- or class-level dict/OrderedDict memo
                          that has a sibling lock is mutated inside a
                          function outside any `with <lock>` block
                          (`TriangularOperator._memory_cache` /
                          `_cache_lock` is the canonical pair).
  require-dtype-gate      a concrete Engine subclass whose `compile()`
                          never calls `_require_dtype` — the capability
                          contract "never a silent dtype fallback".

Per-line suppression: append `# lint: allow=<rule>[,<rule>...]` to the
offending line.  Suppressed findings are reported (and counted) but do
not fail the run.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path

__all__ = ["Finding", "RULES", "CLOCK_INJECTED", "lint_source",
           "lint_paths", "render_report"]

RULES = {
    "bare-except": "except: without an exception type",
    "wall-clock": "direct wall-clock call in a clock-injected module",
    "host-callback-in-loop": "pure_callback / host numpy inside a "
                             "jit-traced loop body",
    "unlocked-memo-mutation": "memo/LRU mutated outside its lock",
    "require-dtype-gate": "Engine.compile() without a _require_dtype gate",
}

#: modules (path suffixes) whose logic is documented clock-injected: time
#: enters only as a `now`/`clock` argument so tests can drive them with
#: synthetic clocks (serving/batcher.py module doc, obs tracer/profiler)
CLOCK_INJECTED = (
    "serving/batcher.py", "serving/registry.py", "serving/service.py",
    "obs/trace.py", "obs/metrics.py", "obs/profile.py", "obs/export.py",
)

_WALL_CLOCK_TIME_FNS = {"time", "perf_counter", "monotonic",
                        "process_time", "perf_counter_ns", "monotonic_ns",
                        "time_ns"}
_MUTATOR_METHODS = {"pop", "popitem", "clear", "update", "setdefault",
                    "move_to_end", "append"}
_LOOP_TRACERS = {"scan": (0,), "while_loop": (0, 1), "fori_loop": (2,)}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint violation (or suppressed would-be violation)."""
    path: str
    line: int
    rule: str
    message: str
    suppressed: bool = False

    def render(self) -> str:
        sup = "  [suppressed]" if self.suppressed else ""
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}{sup}"


def _suppressions(src: str) -> dict:
    """line number -> set of rules allowed on that line."""
    out: dict = {}
    marker = "# lint: allow="
    for i, text in enumerate(src.splitlines(), start=1):
        j = text.find(marker)
        if j >= 0:
            rules = text[j + len(marker):].split("#")[0]
            out[i] = {r.strip() for r in rules.split(",") if r.strip()}
    return out


def _attr_chain(node) -> list:
    """`a.b.c` -> ["a", "b", "c"]; non-name bases terminate the chain."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    parts.reverse()
    return parts


def _is_dict_ctor(node) -> bool:
    if isinstance(node, ast.Dict) and not node.keys:
        return True
    if isinstance(node, ast.Call):
        chain = _attr_chain(node.func)
        return bool(chain) and chain[-1] in ("dict", "OrderedDict")
    return False


def _is_lock_ctor(node) -> bool:
    if isinstance(node, ast.Call):
        chain = _attr_chain(node.func)
        return bool(chain) and chain[-1] in ("Lock", "RLock")
    return False


def _mentions_lock(node) -> bool:
    """Does a with-item expression reference a lock-ish name?"""
    for sub in ast.walk(node):
        name = None
        if isinstance(sub, ast.Name):
            name = sub.id
        elif isinstance(sub, ast.Attribute):
            name = sub.attr
        if name is not None and "lock" in name.lower():
            return True
    return False


class _Aliases:
    """Module-level import aliases for numpy / time / datetime / jax."""

    def __init__(self, tree: ast.Module):
        self.numpy: set = set()
        self.time: set = set()
        self.datetime: set = set()
        self.time_fns: set = set()       # from time import perf_counter
        self.pure_callback: set = set()  # from jax import pure_callback
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    bind = a.asname or a.name.split(".")[0]
                    if a.name == "numpy":
                        self.numpy.add(bind)
                    elif a.name == "time":
                        self.time.add(bind)
                    elif a.name == "datetime":
                        self.datetime.add(bind)
            elif isinstance(node, ast.ImportFrom) and node.level == 0:
                if node.module == "time":
                    for a in node.names:
                        if a.name in _WALL_CLOCK_TIME_FNS:
                            self.time_fns.add(a.asname or a.name)
                elif node.module == "datetime":
                    for a in node.names:
                        if a.name == "datetime":
                            self.datetime.add(a.asname or a.name)
                elif node.module in ("jax", "jax.experimental"):
                    for a in node.names:
                        if a.name in ("pure_callback", "io_callback"):
                            self.pure_callback.add(a.asname or a.name)


def _wall_clock_call(node: ast.Call, al: _Aliases):
    """Name of the wall-clock function if this call reads the clock."""
    f = node.func
    if isinstance(f, ast.Name) and f.id in al.time_fns:
        return f.id
    chain = _attr_chain(f)
    if len(chain) >= 2 and chain[0] in al.time and \
            chain[-1] in _WALL_CLOCK_TIME_FNS:
        return ".".join(chain)
    if len(chain) >= 2 and chain[-1] in ("now", "utcnow", "today") and \
            chain[0] in al.datetime:
        return ".".join(chain)
    return None


def _host_call(node: ast.Call, al: _Aliases):
    """Host-side call (numpy / pure_callback) name, if any."""
    f = node.func
    if isinstance(f, ast.Name) and f.id in al.pure_callback:
        return f.id
    chain = _attr_chain(f)
    if not chain:
        return None
    if chain[-1] in ("pure_callback", "io_callback"):
        return ".".join(chain)
    if chain[0] in al.numpy and len(chain) >= 2:
        return ".".join(chain)
    return None


def _loop_body_args(node: ast.Call):
    """Function-valued operands of a lax.scan/while_loop/fori_loop call."""
    chain = _attr_chain(node.func)
    if not chain or chain[-1] not in _LOOP_TRACERS:
        return
    if len(chain) >= 2 and chain[-2] not in ("lax", "jax"):
        return
    for pos in _LOOP_TRACERS[chain[-1]]:
        if pos < len(node.args):
            yield node.args[pos]
    for kw in node.keywords:
        if kw.arg in ("f", "body_fun", "cond_fun"):
            yield kw.value


def _check_loop_bodies(tree, al, add):
    """host-callback-in-loop: resolve each traced-loop body argument to a
    local def / lambda and scan it for host calls."""
    defs: dict = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs[node.name] = node      # last def wins, like the runtime

    def scan_body(fn, loop_line):
        for sub in ast.walk(fn):
            if isinstance(sub, ast.Call):
                host = _host_call(sub, al)
                if host is not None:
                    add(sub.lineno, "host-callback-in-loop",
                        f"`{host}` inside a loop body traced at line "
                        f"{loop_line} runs per traced step on the host")

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        for arg in _loop_body_args(node):
            if isinstance(arg, ast.Lambda):
                scan_body(arg, node.lineno)
            elif isinstance(arg, ast.Name) and arg.id in defs:
                scan_body(defs[arg.id], node.lineno)


def _scope_memos(body) -> tuple:
    """(memo names, has_lock) declared by simple assignments in a module
    or class body."""
    memos, locks = set(), set()
    for stmt in body:
        if isinstance(stmt, ast.Assign):
            targets = [t for t in stmt.targets if isinstance(t, ast.Name)]
            value = stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None \
                and isinstance(stmt.target, ast.Name):
            targets = [stmt.target]
            value = stmt.value
        else:
            continue
        for t in targets:
            if _is_dict_ctor(value):
                memos.add(t.id)
            elif _is_lock_ctor(value) and "lock" in t.id.lower():
                locks.add(t.id)
    return memos, bool(locks)


def _mutation_target(node):
    """The container expression a statement/call mutates, or None."""
    if isinstance(node, (ast.Assign, ast.AugAssign)):
        targets = node.targets if isinstance(node, ast.Assign) \
            else [node.target]
        for t in targets:
            if isinstance(t, ast.Subscript):
                return t.value
    elif isinstance(node, ast.Delete):
        for t in node.targets:
            if isinstance(t, ast.Subscript):
                return t.value
    elif isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
        if node.func.attr in _MUTATOR_METHODS:
            return node.func.value
    return None


def _base_memo_name(node, memos):
    """Memo name if `node` resolves to one: bare NAME, or any
    `<obj>.NAME` attribute access (self/cls/Class qualified)."""
    if isinstance(node, ast.Name) and node.id in memos:
        return node.id
    if isinstance(node, ast.Attribute) and node.attr in memos:
        return node.attr
    return None


def _check_memo_locks(tree, add):
    """unlocked-memo-mutation, for every scope that declares both a
    dict-valued memo and a lock."""
    scopes = [tree] + [n for n in ast.walk(tree)
                       if isinstance(n, ast.ClassDef)]
    memos: set = set()
    for scope in scopes:
        m, has_lock = _scope_memos(scope.body)
        if has_lock:
            memos |= m
    if not memos:
        return

    def visit(node, lock_depth, in_function):
        if isinstance(node, ast.With):
            if any(_mentions_lock(item.context_expr)
                   for item in node.items):
                lock_depth += 1
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            in_function = True
        if in_function and lock_depth == 0:
            target = _mutation_target(node)
            if target is not None:
                name = _base_memo_name(target, memos)
                if name is not None:
                    add(node.lineno, "unlocked-memo-mutation",
                        f"`{name}` is mutated outside its lock")
        for child in ast.iter_child_nodes(node):
            visit(child, lock_depth, in_function)

    visit(tree, 0, False)


def _check_engines(tree, add):
    """require-dtype-gate: concrete Engine subclasses must gate dtypes in
    compile()."""
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        bases = {chain[-1] for base in node.bases
                 for chain in [_attr_chain(base)] if chain}
        if "Engine" not in bases:
            continue
        for item in node.body:
            if not isinstance(item, ast.FunctionDef) or \
                    item.name != "compile":
                continue
            # an abstract compile (body is just raise/docstring) is exempt
            real = [s for s in item.body
                    if not (isinstance(s, ast.Expr)
                            and isinstance(s.value, ast.Constant))]
            if real and all(isinstance(s, ast.Raise) for s in real):
                continue
            gated = any(
                isinstance(sub, ast.Call)
                and isinstance(sub.func, ast.Attribute)
                and sub.func.attr == "_require_dtype"
                for sub in ast.walk(item))
            if not gated:
                add(item.lineno, "require-dtype-gate",
                    f"{node.name}.compile() never calls _require_dtype — "
                    f"silent dtype fallback")


def lint_source(src: str, relpath: str) -> list:
    """Lint one module's source; relpath (posix, repo-relative) scopes the
    module-set rules.  Returns all findings, suppressed ones included."""
    tree = ast.parse(src, filename=relpath)
    allowed = _suppressions(src)
    findings: list = []

    def add(line, rule, message):
        findings.append(Finding(
            path=relpath, line=line, rule=rule, message=message,
            suppressed=rule in allowed.get(line, ())))

    al = _Aliases(tree)
    for node in ast.walk(tree):
        if isinstance(node, ast.ExceptHandler) and node.type is None:
            add(node.lineno, "bare-except",
                "bare `except:` swallows KeyboardInterrupt and the typed "
                "error taxonomy — name the exceptions")
    if relpath.endswith(CLOCK_INJECTED):
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                clock = _wall_clock_call(node, al)
                if clock is not None:
                    add(node.lineno, "wall-clock",
                        f"`{clock}()` called directly in a clock-injected "
                        f"module — take the clock as an argument")
    _check_loop_bodies(tree, al, add)
    _check_memo_locks(tree, add)
    _check_engines(tree, add)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def lint_paths(paths, root=None) -> list:
    """Lint every .py file under `paths` (files or directories).  Paths in
    findings are reported relative to `root` (default: cwd)."""
    root = Path(root) if root is not None else Path.cwd()
    files: list = []
    for p in paths:
        p = Path(p)
        files.extend(sorted(p.rglob("*.py")) if p.is_dir() else [p])
    findings: list = []
    for f in files:
        try:
            rel = f.resolve().relative_to(root.resolve()).as_posix()
        except ValueError:
            rel = f.as_posix()
        findings.extend(lint_source(f.read_text(), rel))
    return findings


def render_report(findings) -> str:
    """Human-readable report + summary line."""
    lines = [f.render() for f in findings]
    live = sum(1 for f in findings if not f.suppressed)
    sup = len(findings) - live
    lines.append(f"{live} finding(s), {sup} suppressed")
    return "\n".join(lines)

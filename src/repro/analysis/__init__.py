"""Static verification of compiled solve artifacts (docs/analysis.md).

Two passes, both zero-execution:

* `repro.analysis.verify` — the schedule race detector + invariant
  certifier: vectorized O(nnz) structural checks over `LevelSchedule` /
  `DeviceSchedule` (every dependency and carry segment produced strictly
  earlier, lane/row bijection, index bounds, padding sentinels, dtype
  flow, one collective family per sharded step) returning a typed
  `ScheduleCertificate`, plus the transform auditor over
  `TransformedSystem` / `ReplayPlan` commit logs.
* `repro.analysis.lint` — the repo-rule AST lint (`python -m tools.lint`)
  encoding the house invariants: no host callbacks in jit-traced loop
  bodies, injected clocks only in the pure scheduling tiers, memo
  mutation only under its lock, engines gate dtypes, no bare except.
"""
from .verify import (ScheduleCertificate, audit_transformed_system,
                     certificate_dict, verify_level_schedule,
                     verify_operator_payload, verify_schedule_values)
from .lint import Finding, lint_paths, lint_source

__all__ = [
    "ScheduleCertificate", "audit_transformed_system", "certificate_dict",
    "verify_level_schedule", "verify_operator_payload",
    "verify_schedule_values",
    "Finding", "lint_paths", "lint_source",
]
